"""Streaming subsystem: chunked merges, device-tree top-k, planner cache.

Multi-device cases run in a subprocess (pattern from test_sharding.py) so
the forced host-device-count flag never leaks into other tests.
"""
import json
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.kway import kway_merge_pallas
from repro.kernels.loms_merge import loms_merge2_pallas
from repro.core.loms import loms_kway
from repro.streaming import (
    AutotuneCache,
    MergePlan,
    autotune_merge2,
    chunked_merge,
    chunked_merge_k,
    plan_chunked,
    plan_key,
    plan_merge2,
    tree_topk,
)

RNG = np.random.default_rng(7)


def _sorted(shape, dtype=jnp.float32, hi=50_000):
    return jnp.sort(jnp.asarray(RNG.integers(0, hi, shape)).astype(dtype), -1)


# ---------------------------------------------------------------------------
# chunked merges
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
def test_chunked_merge_long_sequences(dtype):
    """>=16x the tile size, ragged lengths, ragged batch: bit-identical to
    np.sort of the concatenation."""
    tile = 64
    hi = 200 if dtype == jnp.bfloat16 else 50_000  # keep bf16 exact
    a = jnp.sort(jnp.asarray(RNG.integers(0, hi, (3, 16 * tile))).astype(dtype), -1)
    b = jnp.sort(jnp.asarray(RNG.integers(0, hi, (3, 16 * tile + 37))).astype(dtype), -1)
    out = chunked_merge(a, b, tile=tile)
    ref = np.sort(np.concatenate([np.asarray(a), np.asarray(b)], -1), -1)
    assert out.dtype == a.dtype
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_chunked_merge_unbatched_and_tiny():
    a = _sorted((1000,))
    b = _sorted((3,))
    out = chunked_merge(a, b, tile=32)
    np.testing.assert_array_equal(
        np.asarray(out), np.sort(np.concatenate([np.asarray(a), np.asarray(b)]))
    )


def test_chunked_merge_matches_plan_default():
    a, b = _sorted((2, 700)), _sorted((2, 700))
    plan = plan_chunked(700, 700, batch=2, dtype=jnp.float32)
    out = chunked_merge(a, b, plan=plan)
    ref = np.sort(np.concatenate([np.asarray(a), np.asarray(b)], -1), -1)
    np.testing.assert_array_equal(np.asarray(out), ref)


@pytest.mark.parametrize("lens", [(100, 45, 210), (64, 64, 64, 64), (33, 1, 500)])
def test_chunked_merge_k(lens):
    lists = [_sorted((2, n)) for n in lens]
    out = chunked_merge_k(lists, tile=32)
    ref = np.sort(np.concatenate([np.asarray(x) for x in lists], -1), -1)
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_chunked_merge_k_long():
    """k-way with every list >=16x the tile."""
    tile = 16
    lists = [_sorted((1, 16 * tile + d)) for d in (0, 5, 11)]
    out = chunked_merge_k(lists, tile=tile)
    ref = np.sort(np.concatenate([np.asarray(x) for x in lists], -1), -1)
    np.testing.assert_array_equal(np.asarray(out), ref)


# ---------------------------------------------------------------------------
# ragged-batch auto padding in the kernels (satellite)
# ---------------------------------------------------------------------------


def test_loms_merge2_ragged_batch():
    a, b = _sorted((5, 8)), _sorted((5, 12))
    out = loms_merge2_pallas(a, b, block_batch=4)
    ref = np.sort(np.concatenate([np.asarray(a), np.asarray(b)], -1), -1)
    assert out.shape == (5, 20)
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_kway_ragged_batch():
    sched = loms_kway((4, 4, 4))
    x = jnp.concatenate([_sorted((7, 4)) for _ in range(3)], -1)
    out = kway_merge_pallas(x, sched, block_batch=4)
    assert out.shape == (7, 12)
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x), -1))


# ---------------------------------------------------------------------------
# device-tree top-k
# ---------------------------------------------------------------------------


def test_tree_topk_single_device():
    x = jnp.asarray(RNG.standard_normal((4, 1000)), jnp.float32)
    v, i = tree_topk(x, 8)
    rv, ri = jax.lax.top_k(x, 8)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


MULTIDEV_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.streaming import tree_topk
from repro.parallel.sharding import Parallelism, vocab_topk_axis
from repro.serving.sample import sample_topk

rng = np.random.default_rng(3)
results = {}

# butterfly (8 shards, power of two) and gather-tree (6 shards) paths
for shards in (8, 6):
    mesh = Mesh(np.array(jax.devices()[:shards]).reshape(1, shards),
                ("data", "model"))
    x = jnp.asarray(rng.standard_normal((4, shards * 96)), jnp.float32)
    v, i = tree_topk(x, 16, mesh=mesh, axis="model")
    rv, ri = jax.lax.top_k(x, 16)
    results[f"vals_{shards}"] = bool(np.allclose(np.asarray(v), np.asarray(rv)))
    results[f"idx_{shards}"] = bool(np.array_equal(np.asarray(i), np.asarray(ri)))

# serving sampler path: sharded vocab top-k feeds the categorical draw
mesh = Mesh(np.array(jax.devices()).reshape(1, 8), ("data", "model"))
par = Parallelism(mesh=mesh, dp_axes=("data",), tp_axis="model")
logits = jnp.asarray(rng.standard_normal((8, 8 * 128)), jnp.float32)
results["axis"] = vocab_topk_axis(par, logits.shape[-1])
toks = sample_topk(jax.random.PRNGKey(0), logits, k=8, temperature=1.0,
                   par=par)
support = np.asarray(jax.lax.top_k(logits, 8)[1])
results["sampler_in_support"] = bool(all(
    int(toks[b]) in support[b] for b in range(logits.shape[0])))
print(json.dumps(results))
"""


@pytest.mark.slow
def test_tree_topk_sharded_matches_lax_topk():
    out = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SNIPPET],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests", 1)[0],
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["vals_8"] and res["idx_8"], res
    assert res["vals_6"] and res["idx_6"], res
    assert res["axis"] == "model"
    assert res["sampler_in_support"]


# ---------------------------------------------------------------------------
# planner + autotune cache
# ---------------------------------------------------------------------------


def test_plan_merge2_heuristics():
    p = plan_merge2(64, 64, batch=8, dtype=jnp.float32)
    assert p.kind == "loms" and 64 % p.n_cols == 0 and p.block_batch >= 1
    # integer values must avoid the lossy f32 one-hot matmul
    assert plan_merge2(64, 64, batch=8, dtype=jnp.int32).use_mxu is False
    # ragged sizes fall back to the schedule executor
    assert plan_merge2(7, 11, batch=8, dtype=jnp.float32).kind == "schedule"


def test_autotune_cache_roundtrip(tmp_path):
    path = str(tmp_path / "autotune.json")
    cache = AutotuneCache(path)
    plan = autotune_merge2(16, 16, batch=4, dtype=jnp.float32, cache=cache,
                           iters=1)
    assert plan.source == "autotune"
    # same problem again: served from the in-memory cache
    again = autotune_merge2(16, 16, batch=4, dtype=jnp.float32, cache=cache,
                            iters=1)
    assert again.source == "cache"
    assert (again.n_cols, again.block_batch, again.use_mxu) == (
        plan.n_cols, plan.block_batch, plan.use_mxu)
    # and from a fresh process-equivalent: a new object reading the file
    fresh = AutotuneCache(path)
    key = plan_key("merge2", shapes=(4, 16, 16), dtype="float32")
    entry = fresh.get(key)
    assert entry is not None and "us" in entry
    assert MergePlan.from_entry(entry).n_cols == plan.n_cols


def test_autotuned_plan_is_correct(tmp_path):
    """Whatever the tuner picks must still produce the exact merge."""
    cache = AutotuneCache(str(tmp_path / "t.json"))
    plan = autotune_merge2(32, 32, batch=4, dtype=jnp.float32, cache=cache,
                           iters=1)
    a, b = _sorted((4, 32)), _sorted((4, 32))
    out = loms_merge2_pallas(a, b, n_cols=plan.n_cols,
                             block_batch=plan.block_batch,
                             use_mxu=plan.use_mxu)
    ref = np.sort(np.concatenate([np.asarray(a), np.asarray(b)], -1), -1)
    np.testing.assert_array_equal(np.asarray(out), ref)
