"""Hypothesis property sweeps for the unified API (repro.api).

Randomized versions of the deterministic checks in test_api.py: the new
namespace must match jnp.sort / jax.lax.top_k references for any shape,
axis, direction, tie pattern, and dtype in {f32, bf16, i32}, and pytree
payloads must ride the permutation exactly.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import repro

RNG = np.random.default_rng(23)
DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32, jnp.uint32]
FLOAT_DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(shape, dtype, lo=0, hi=100):
    return jnp.asarray(RNG.integers(lo, hi, shape)).astype(dtype)


@given(data=st.data())
@settings(max_examples=30, deadline=None)
def test_sort_property_any_axis_direction_dtype(data):
    dtype = data.draw(st.sampled_from(DTYPES))
    ndim = data.draw(st.integers(1, 3))
    shape = tuple(data.draw(st.integers(1, 9)) for _ in range(ndim))
    axis = data.draw(st.integers(-ndim, ndim - 1))
    descending = data.draw(st.booleans())
    x = _rand(shape, dtype)
    out = repro.sort(x, axis=axis, descending=descending)
    ref = np.sort(np.asarray(x.astype(jnp.float32)), axis=axis)
    if descending:
        ref = np.flip(ref, axis=axis)
    np.testing.assert_array_equal(np.asarray(out.astype(jnp.float32)), ref)


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_sort_stable_property_matches_stable_argsort(data):
    dtype = data.draw(st.sampled_from(DTYPES))
    n = data.draw(st.integers(2, 24))
    descending = data.draw(st.booleans())
    x = _rand((3, n), dtype, hi=5)  # heavy ties
    out, perm = repro.sort(x, stable=True, descending=descending,
                           payload=jnp.broadcast_to(
                               jnp.arange(n, dtype=jnp.int32), (3, n)))
    xa = np.asarray(x.astype(jnp.float32))
    order = np.argsort(-xa if descending else xa, axis=-1, kind="stable")
    np.testing.assert_array_equal(
        np.asarray(out.astype(jnp.float32)), np.take_along_axis(xa, order, -1))
    np.testing.assert_array_equal(np.asarray(perm), order)


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_merge_property_matches_sorted_concat(data):
    dtype = data.draw(st.sampled_from(DTYPES))
    m = data.draw(st.integers(1, 20))
    n = data.draw(st.integers(1, 20))
    descending = data.draw(st.booleans())
    a = jnp.sort(_rand((2, m), dtype), -1)
    b = jnp.sort(_rand((2, n), dtype), -1)
    if descending:
        a, b = a[..., ::-1], b[..., ::-1]
    out = repro.merge(a, b, descending=descending)
    ref = np.sort(np.concatenate(
        [np.asarray(a.astype(jnp.float32)), np.asarray(b.astype(jnp.float32))],
        -1), -1)
    if descending:
        ref = ref[..., ::-1]
    np.testing.assert_array_equal(np.asarray(out.astype(jnp.float32)), ref)


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_topk_property_matches_lax_topk(data):
    dtype = data.draw(st.sampled_from(DTYPES))
    n = data.draw(st.integers(4, 200))
    k = data.draw(st.integers(1, min(n, 16)))
    x = _rand((3, n), dtype, hi=10_000)
    v, i = repro.topk(x, k)
    rv, _ = jax.lax.top_k(x.astype(jnp.float32), k)
    np.testing.assert_array_equal(np.asarray(v.astype(jnp.float32)),
                                  np.asarray(rv))
    taken = np.take_along_axis(np.asarray(x.astype(jnp.float32)),
                               np.asarray(i), -1)
    np.testing.assert_array_equal(taken, np.asarray(rv))


def _with_specials(shape):
    """Float data sprinkled with NaN/+inf/-inf (nan_policy='last' cases)."""
    base = RNG.standard_normal(shape)
    m = RNG.random(shape)
    base = np.where(m < 0.2, np.nan, base)
    base = np.where((m >= 0.2) & (m < 0.35), np.inf, base)
    base = np.where((m >= 0.35) & (m < 0.5), -np.inf, base)
    return base


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_sort_nan_inf_property_matches_jnp(data):
    dtype = data.draw(st.sampled_from(FLOAT_DTYPES))
    n = data.draw(st.integers(2, 24))
    descending = data.draw(st.booleans())
    x = jnp.asarray(_with_specials((2, n))).astype(dtype)
    out = repro.sort(x, descending=descending)
    ref = np.sort(np.asarray(x.astype(jnp.float32)), axis=-1)  # NaNs last
    if descending:
        ref = ref[..., ::-1]
    np.testing.assert_array_equal(np.asarray(out.astype(jnp.float32)), ref)


@given(data=st.data())
@settings(max_examples=20, deadline=None)
def test_merge_nan_inf_property_matches_sorted_concat(data):
    dtype = data.draw(st.sampled_from(FLOAT_DTYPES))
    m = data.draw(st.integers(1, 16))
    n = data.draw(st.integers(1, 16))
    a = jnp.sort(jnp.asarray(_with_specials((2, m))).astype(dtype), -1)
    b = jnp.sort(jnp.asarray(_with_specials((2, n))).astype(dtype), -1)
    out = repro.merge(a, b)
    ref = np.sort(np.concatenate(
        [np.asarray(a.astype(jnp.float32)), np.asarray(b.astype(jnp.float32))],
        -1), -1)
    np.testing.assert_array_equal(np.asarray(out.astype(jnp.float32)), ref)


@given(data=st.data())
@settings(max_examples=20, deadline=None)
def test_topk_nan_inf_property(data):
    """Descending top-k under nan_policy='last': NaNs rank above +inf
    (the flipped ascending order), masked -inf logits stay candidates."""
    n = data.draw(st.integers(4, 64))
    k = data.draw(st.integers(1, min(n, 8)))
    x = jnp.asarray(_with_specials((3, n)), jnp.float32)
    v, i = repro.topk(x, k)
    ref = np.sort(np.asarray(x), axis=-1)[..., ::-1][..., :k]
    np.testing.assert_array_equal(np.asarray(v), ref)
    taken = np.take_along_axis(np.asarray(x), np.asarray(i), -1)
    np.testing.assert_array_equal(taken, np.asarray(v))


@given(data=st.data())
@settings(max_examples=15, deadline=None)
def test_payload_property_rides_permutation(data):
    dtype = data.draw(st.sampled_from(DTYPES))
    n = data.draw(st.integers(2, 24))
    x = _rand((2, n), dtype, hi=8)  # ties: payload must follow its exact key
    feat = jnp.asarray(RNG.standard_normal((2, n, 3)), jnp.float32)
    out, tree = repro.sort(x, payload={"pos": jnp.broadcast_to(
        jnp.arange(n, dtype=jnp.int32), (2, n)), "feat": feat})
    perm = np.asarray(tree["pos"])
    xa = np.asarray(x.astype(jnp.float32))
    # the permutation reproduces the sorted values...
    np.testing.assert_array_equal(np.take_along_axis(xa, perm, -1),
                                  np.asarray(out.astype(jnp.float32)))
    # ...and every payload leaf was gathered by that same permutation
    np.testing.assert_array_equal(
        np.asarray(tree["feat"]),
        np.take_along_axis(np.asarray(feat), perm[..., None], 1))
