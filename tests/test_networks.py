"""Pluggable comparator-network layer tests (PR 8).

Every registered family (LOMS column device, single-stage S2MS,
3-periodic, Batcher bitonic) proves correct by the 0-1 principle: merge
programs lift into ``core.networks`` Schedules and run the complete
``validate_01_merge`` sweep at every emitted width; sort programs
compose into one Schedule where the levels allow (loms / s2ms) and take
an exhaustive executor-level 2^w 0-1 sweep otherwise. Bit-equality of
the kernel wrappers against lax covers NaN/±inf, descending, and payload
lanes for every family — as a deterministic grid always, and as
hypothesis sweeps when hypothesis is installed. The divisor fix for
``pick_merge_cols`` is regression-tested against the paper's
C* = sqrt(m*n/(m+n)) optimum, and an AST scan enforces the registry-only
rule: no kernel or streaming module imports a family generator directly.
"""
from __future__ import annotations

import ast
import math
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # the deterministic grids below still run
    HAVE_HYPOTHESIS = False

from repro.core.networks import validate_01_merge, validate_01_sort
from repro.networks import (
    PERIODIC3_MAX_WIDTH,
    capable_families,
    divisor_cols,
    family_names,
    merge_program,
    merge_runs,
    pick_merge_cols,
    program_to_schedule,
    run_sort_program,
    sort_program,
    sort_program_to_schedule,
)

FAMILIES = ("loms", "s2ms", "periodic3", "bitonic")

#: every family's emitted merge widths under test — equal, ragged-divisor,
#: coprime (s2ms/periodic3), and non-equal pow2-total (bitonic) shapes
MERGE_SHAPES = {
    "loms": [(1, 1), (4, 4), (7, 7), (8, 8), (12, 9), (16, 16), (32, 32)],
    "s2ms": [(1, 1), (4, 4), (7, 5), (8, 8), (12, 9), (16, 16)],
    "periodic3": [(1, 1), (3, 5), (4, 4), (8, 8), (16, 16), (32, 32)],
    "bitonic": [(1, 1), (1, 7), (3, 5), (4, 4), (8, 8), (16, 16), (32, 32)],
}

RNG = np.random.default_rng(0)


def test_builtin_families_registered():
    assert set(FAMILIES) <= set(family_names())


# ---------------------------------------------------------------------------
# 0-1-principle validation (complete proofs, per family, per width)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "family,shape",
    [(f, s) for f in FAMILIES for s in MERGE_SHAPES[f]],
    ids=lambda v: str(v).replace(" ", ""),
)
def test_merge_program_01_valid(family, shape):
    m, n = shape
    sched = program_to_schedule(merge_program(family, m, n))
    assert validate_01_merge(sched, (m, n)), (family, shape)


@pytest.mark.parametrize("family", ("loms", "s2ms"))
@pytest.mark.parametrize("width", (8, 16))
def test_sort_program_01_valid_composable(family, width):
    # below the column-device cutover every loms/s2ms level is a depth-1
    # group merge, so the whole tree composes into one Schedule and the
    # exhaustive 0-1 sort validator applies to the composed network
    sched = sort_program_to_schedule(sort_program(family, width))
    assert validate_01_sort(sched), (family, width)


@pytest.mark.parametrize("family", FAMILIES)
def test_sort_executor_01_exhaustive(family):
    # executor-level complete proof at w=8: all 2^8 0-1 rows through
    # run_sort_program must come out ascending (covers the pair families,
    # whose levels don't compose into a single Schedule)
    w = 8
    prog = sort_program(family, w)
    pats = ((np.arange(2 ** w)[:, None] >> np.arange(w)[None, :]) & 1)
    keys, _ = run_sort_program(prog, jnp.asarray(pats, jnp.int32), None,
                               False)
    out = np.asarray(keys)
    assert (np.diff(out, axis=-1) >= 0).all(), family


def test_capability_gates():
    # bitonic needs a pow2 total; periodic3 is capped by construction cost
    assert "bitonic" in capable_families("merge2", (3, 5))
    assert "bitonic" not in capable_families("merge2", (3, 4))
    assert "periodic3" not in capable_families(
        "merge2", (PERIODIC3_MAX_WIDTH, PERIODIC3_MAX_WIDTH))
    for lens in ((3, 4), (3, 5), (8, 8)):
        assert "loms" in capable_families("merge2", lens)
        assert "s2ms" in capable_families("merge2", lens)


# ---------------------------------------------------------------------------
# pick_merge_cols: true divisors + the paper's C* optimum
# ---------------------------------------------------------------------------


def test_divisor_cols_are_actual_common_divisors():
    for m, n in ((12, 9), (7, 7), (18, 12), (512, 512), (7, 5)):
        cols = divisor_cols(m, n)
        assert all(m % c == 0 and n % c == 0 and c >= 2 for c in cols)
        g = math.gcd(m, n)
        assert set(cols) == {c for c in range(2, g + 1) if g % c == 0}


def test_pick_merge_cols_nearest_cstar():
    # the old hardcoded (2, 4, 8, 16) grid missed non-pow2 divisors and
    # every column count past 16; the divisor rule lands on the cost
    # optimum C* = sqrt(m*n/(m+n)) for each shape
    for m, n, expect in (
        (512, 512, 16),   # C* = 16 exactly
        (7, 7, 7),        # gcd divisor 7: invisible to the pow2 grid
        (12, 9, 3),       # non-pow2 divisor
        (7, 5, 1),        # coprime: no common column, single S2MS
    ):
        assert pick_merge_cols(m, n) == expect, (m, n)
    for m, n in ((24, 24), (36, 24), (128, 64), (64, 64), (512, 512)):
        cstar = math.sqrt(m * n / (m + n))
        picked = pick_merge_cols(m, n)
        assert all(
            abs(picked - cstar) <= abs(c - cstar) for c in divisor_cols(m, n))


# ---------------------------------------------------------------------------
# bit-equality vs lax (deterministic grid + hypothesis sweeps)
# ---------------------------------------------------------------------------


def _with_specials(shape):
    base = RNG.standard_normal(shape)
    m = RNG.random(shape)
    base = np.where(m < 0.2, np.nan, base)
    base = np.where((m >= 0.2) & (m < 0.35), np.inf, base)
    base = np.where((m >= 0.35) & (m < 0.5), -np.inf, base)
    return base.astype(np.float32)


def _check_merge_bits(family, m, n, descending):
    from repro.kernels.loms_merge import loms_merge2_pallas

    a = np.sort(_with_specials((3, m)), -1)
    b = np.sort(_with_specials((3, n)), -1)
    ref = np.sort(np.concatenate([a, b], -1), -1)  # NaNs last, like encode
    if descending:
        a, b, ref = a[:, ::-1], b[:, ::-1], ref[:, ::-1]
    n_cols = max(pick_merge_cols(m, n), 1) if family == "loms" else 2
    out = loms_merge2_pallas(
        jnp.asarray(a), jnp.asarray(b), network=family, n_cols=n_cols,
        block_batch=1, use_mxu=False, key_dtype="float32",
        descending=descending, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), ref)


def _check_sort_bits(family, n, descending):
    from repro.kernels.sort import loms_sort_pallas

    x = _with_specials((2, n))
    ref = np.sort(x, -1)
    if descending:
        ref = ref[:, ::-1]
    out = loms_sort_pallas(
        jnp.asarray(x), network=family, block_batch=1, use_mxu=False,
        key_dtype="float32", descending=descending, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), ref)


def _check_sort_payload(family, n):
    # tie-safe payload check for the non-stable families: the returned
    # permutation must reproduce both the values and every payload lane
    # by one gather from the raw input (no stable-argsort assumption)
    from repro.kernels.sort import loms_sort_pallas

    x = np.asarray(
        RNG.integers(0, 4, (2, n)), np.float32)  # duplicates guaranteed
    pay = np.arange(2 * n, dtype=np.int32).reshape(2, n)
    out, perm, (pout,) = loms_sort_pallas(
        jnp.asarray(x), (jnp.asarray(pay),), network=family, block_batch=1,
        use_mxu=False, want_perm=True, interpret=True)
    out, perm, pout = np.asarray(out), np.asarray(perm), np.asarray(pout)
    np.testing.assert_array_equal(out, np.sort(x, -1))
    np.testing.assert_array_equal(np.take_along_axis(x, perm, -1), out)
    np.testing.assert_array_equal(np.take_along_axis(pay, perm, -1), pout)


@pytest.mark.parametrize("descending", (False, True))
@pytest.mark.parametrize(
    "family,shape",
    [("loms", (8, 8)), ("loms", (12, 9)), ("s2ms", (7, 5)),
     ("s2ms", (16, 16)), ("periodic3", (3, 5)), ("periodic3", (8, 8)),
     ("bitonic", (3, 5)), ("bitonic", (16, 16))],
    ids=lambda v: str(v).replace(" ", ""),
)
def test_merge_bit_equality_grid(family, shape, descending):
    _check_merge_bits(family, *shape, descending)


@pytest.mark.parametrize("descending", (False, True))
@pytest.mark.parametrize("family", FAMILIES)
def test_sort_bit_equality_grid(family, descending):
    for n in (2, 5, 24):
        _check_sort_bits(family, n, descending)


@pytest.mark.parametrize("family", FAMILIES)
def test_sort_payload_rides_actual_permutation(family):
    for n in (4, 9, 16):
        _check_sort_payload(family, n)


if HAVE_HYPOTHESIS:

    def _family_merge_shape(data, family):
        if family == "bitonic":
            total = data.draw(st.sampled_from((8, 16, 32)))
            m = data.draw(st.integers(1, total - 1))
            return m, total - m
        return data.draw(st.integers(1, 16)), data.draw(st.integers(1, 16))

    @given(data=st.data())
    @settings(max_examples=20, deadline=None)
    def test_merge_bit_equality_hypothesis(data):
        family = data.draw(st.sampled_from(("s2ms", "periodic3", "bitonic")))
        m, n = _family_merge_shape(data, family)
        _check_merge_bits(family, m, n, data.draw(st.booleans()))

    @given(data=st.data())
    @settings(max_examples=15, deadline=None)
    def test_sort_bit_equality_hypothesis(data):
        _check_sort_bits(data.draw(st.sampled_from(FAMILIES)),
                         data.draw(st.integers(2, 24)),
                         data.draw(st.booleans()))


# ---------------------------------------------------------------------------
# payload consistency at the program level (all families, one sweep)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILIES)
def test_merge_runs_payload_tracks_values(family):
    m, n = (8, 8)
    a = np.sort(RNG.integers(0, 6, (4, m)), -1).astype(np.int32)
    b = np.sort(RNG.integers(0, 6, (4, n)), -1).astype(np.int32)
    prog = merge_program(family, m, n)
    pa = np.arange(m, dtype=np.int32)[None].repeat(4, 0)
    pb = (np.arange(n, dtype=np.int32) + m)[None].repeat(4, 0)
    vals, pos = merge_runs(prog, jnp.asarray(a), jnp.asarray(b),
                           payload=(jnp.asarray(pa), jnp.asarray(pb)),
                           use_mxu=False)
    vals, pos = np.asarray(vals), np.asarray(pos)
    cat = np.concatenate([a, b], -1)
    np.testing.assert_array_equal(vals, np.sort(cat, -1))
    np.testing.assert_array_equal(np.take_along_axis(cat, pos, -1), vals)


# ---------------------------------------------------------------------------
# registry-only enforcement: kernels execute programs, never generators
# ---------------------------------------------------------------------------

#: modules no kernel/streaming file may import: the family generators
#: themselves (the networks registry is the only door) and the core LOMS
#: schedule builders the generators wrap
_GENERATOR_MODULES = ("repro.core.loms", "repro.networks.families")


def _forbidden_imports(path: pathlib.Path):
    tree = ast.parse(path.read_text())
    hits = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            hits += [a.name for a in node.names
                     if a.name.startswith(_GENERATOR_MODULES)]
        elif isinstance(node, ast.ImportFrom) and node.module:
            if node.module.startswith(_GENERATOR_MODULES):
                hits.append(node.module)
            if node.module == "repro.core":
                hits += [f"repro.core.{a.name}" for a in node.names
                         if a.name == "loms"]
            if node.module == "repro.networks":
                hits += [f"repro.networks.{a.name}" for a in node.names
                         if a.name == "families"]
    return hits


def test_kernels_import_registry_not_generators():
    src = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"
    scanned = 0
    for sub in ("kernels", "streaming"):
        for path in sorted((src / sub).glob("*.py")):
            scanned += 1
            assert not _forbidden_imports(path), (
                f"{path} imports a network family generator directly; "
                "kernels must execute registry-provided programs")
    assert scanned >= 10  # the rule actually covered the kernel layer
