"""Autotune cache + VMEM-aware tile planner tests (PR 4 satellites).

Covers the cache contract the fused pipeline depends on: keys encode
(op, shapes, dtype, k, platform); hits skip re-tuning entirely;
stale-schema entries are ignored; and the block_batch fix — a prime batch
no longer degenerates to a 1-wide tile, because tiles are picked by VMEM
fit and padded, not by divisibility.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.streaming.cache import SCHEMA_VERSION, AutotuneCache, plan_key
from repro.streaming.planner import (
    MergePlan,
    autotune_merge2,
    autotune_sort,
    plan_merge2,
    plan_op,
    plan_segmented,
    plan_sort,
    sort_fits_vmem,
)


@pytest.fixture
def cache(tmp_path):
    return AutotuneCache(path=str(tmp_path / "autotune.json"))


# ---------------------------------------------------------------------------
# keying
# ---------------------------------------------------------------------------


def test_plan_key_encodes_op_shape_dtype_platform():
    k = plan_key("merge2", shapes=(8, 64, 32), dtype="float32")
    assert k.startswith("merge2|8x64x32|k-|float32|")
    assert k.endswith(jax.default_backend())
    # every component is discriminating
    assert k != plan_key("sort", shapes=(8, 64, 32), dtype="float32")
    assert k != plan_key("merge2", shapes=(8, 64, 64), dtype="float32")
    assert k != plan_key("merge2", shapes=(8, 64, 32), dtype="int32")
    assert k != plan_key("merge2", shapes=(8, 64, 32), dtype="float32", k=4)
    assert k != plan_key("merge2", shapes=(8, 64, 32), dtype="float32",
                         backend="tpu")


# ---------------------------------------------------------------------------
# hits skip re-tuning
# ---------------------------------------------------------------------------


def test_cache_hit_skips_retuning(cache, monkeypatch):
    plan = autotune_merge2(16, 16, batch=4, dtype=jnp.float32, cache=cache,
                           iters=1)
    assert plan.source == "autotune"
    # poison the measurement path: a hit must never reach it
    import repro.streaming.planner as planner

    def boom(*a, **k):
        raise AssertionError("cache hit must skip measurement")

    monkeypatch.setattr(planner, "_time_call", boom)
    hit = autotune_merge2(16, 16, batch=4, dtype=jnp.float32, cache=cache)
    assert hit.source == "cache"
    assert (hit.n_cols, hit.block_batch, hit.use_mxu) == (
        plan.n_cols, plan.block_batch, plan.use_mxu)


def test_autotune_sort_persists_and_plan_op_reads_it(cache):
    plan = autotune_sort(32, batch=4, dtype=jnp.float32, cache=cache, iters=1)
    assert plan.source == "autotune"
    via_plan = plan_op("sort", (32,), batch=4, dtype=jnp.float32, cache=cache)
    assert via_plan.source == "cache"
    assert via_plan.block_batch == plan.block_batch
    # a different shape misses and falls back to the heuristic
    miss = plan_op("sort", (64,), batch=4, dtype=jnp.float32, cache=cache)
    assert miss.source == "heuristic"


# ---------------------------------------------------------------------------
# stale schema entries are ignored
# ---------------------------------------------------------------------------


def test_stale_schema_entries_ignored(cache):
    key = plan_key("merge2", shapes=(8, 16, 16), dtype="float32")
    cache.put(key, MergePlan(block_batch=2).to_entry())
    assert cache.get(key) is not None  # current schema round-trips

    # rewrite the entry as an older/foreign schema on disk
    with open(cache.path) as f:
        data = json.load(f)
    data[key]["_schema"] = SCHEMA_VERSION - 1
    with open(cache.path, "w") as f:
        json.dump(data, f)
    stale = AutotuneCache(path=cache.path)
    assert stale.get(key) is None
    # and plan_op degrades to the heuristic instead of mis-parameterizing
    plan = plan_op("merge2", (16, 16), batch=8, dtype=jnp.float32,
                   cache=stale)
    assert plan.source == "heuristic"


def test_unversioned_entries_ignored(cache):
    key = plan_key("merge2", shapes=(8, 16, 16), dtype="float32")
    cache._entries[key] = {"n_cols": 2, "block_batch": 4, "use_mxu": True}
    assert cache.get(key) is None  # pre-schema entry (no stamp)


def test_corrupt_cache_file_starts_empty(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text("{not json")
    c = AutotuneCache(path=str(p))
    assert len(c) == 0


def test_pre_segmented_caches_ignored(cache):
    # PR 5 regression: v3 bumped the schema for the segmented plan family
    # (block_batch now counts segments per class tile). A v2-era entry —
    # even one sitting under a key the segmented planner would hit — must
    # degrade to the heuristic, never mis-tile a class launch.
    assert SCHEMA_VERSION >= 3
    key = plan_key("segmented", shapes=(64, 128), dtype="float32")
    cache._entries[key] = dict(
        MergePlan(block_batch=16).to_entry(), _schema=2)
    assert cache.get(key) is None
    plan = plan_op("segmented", (128,), batch=64, dtype=jnp.float32,
                   cache=cache)
    assert plan.source == "heuristic"
    # current-schema entries round-trip as cache hits
    cache.put(key, MergePlan(block_batch=4).to_entry())
    hit = plan_op("segmented", (128,), batch=64, dtype=jnp.float32,
                  cache=cache)
    assert hit.source == "cache" and hit.block_batch == 4


# ---------------------------------------------------------------------------
# segmented class plans (plan_segmented)
# ---------------------------------------------------------------------------


def test_plan_segmented_sort_class_fits_budget():
    from repro.streaming.planner import _vmem_bytes_sort, vmem_budget

    plan = plan_segmented((256,), n_segments=1007, dtype=jnp.float32)
    assert plan.block_batch > 1  # ragged segment counts pad, never degrade
    assert _vmem_bytes_sort(256, plan.block_batch, jnp.float32) \
        <= vmem_budget()


def test_plan_segmented_merge_class_picks_columns():
    plan = plan_segmented((64, 128), n_segments=32, dtype=jnp.float32)
    assert plan.n_cols >= 2  # pow2 class pair always has a common column
    degenerate = plan_segmented((1, 8), n_segments=4, dtype=jnp.float32)
    assert degenerate.n_cols == 1  # width-1 run: single-stage S2MS fallback


# ---------------------------------------------------------------------------
# VMEM-fit block_batch (the _pick_block_batch satellite)
# ---------------------------------------------------------------------------


def test_prime_batch_gets_wide_tile():
    # B=1007 is prime: the old divisor rule forced block_batch=1 and a
    # 1007-step grid; the VMEM-fit rule tiles wide and pads
    plan = plan_merge2(64, 64, batch=1007, dtype=jnp.float32)
    assert plan.block_batch > 1
    plan = plan_sort(128, batch=1007, dtype=jnp.float32)
    assert plan.block_batch > 1


def test_block_batch_never_overruns_budget():
    from repro.streaming.planner import _vmem_bytes_sort, vmem_budget

    # n=1024 fits per-row but not at the full target tile: the picker must
    # shrink the tile until the working set fits
    plan = plan_sort(1024, batch=64, dtype=jnp.float32)
    assert plan.block_batch >= 1
    assert _vmem_bytes_sort(1024, plan.block_batch, jnp.float32) \
        <= vmem_budget()


def test_small_batch_never_overpads():
    # one pad-up to the next power of two is allowed, never more
    for batch in (1, 2, 3, 5, 8, 13):
        plan = plan_sort(64, batch=batch, dtype=jnp.float32)
        assert plan.block_batch < 2 * batch, (batch, plan.block_batch)


def test_sort_fits_vmem_gates():
    assert sort_fits_vmem(1024)
    assert not sort_fits_vmem(1 << 17)


# ---------------------------------------------------------------------------
# network-family tournament (PR 8)
# ---------------------------------------------------------------------------


def test_tournament_sweeps_multiple_families(cache):
    from repro.networks import family_names
    from repro.streaming.planner import _merge2_candidates, _sort_candidates

    cands = list(_merge2_candidates(16, 16, batch=8, dtype=jnp.float32))
    families = {c.network for c in cands}
    assert len(families) > 1 and families <= set(family_names())
    assert {"loms", "s2ms", "bitonic", "periodic3"} <= families
    # pow2-total constraint: bitonic drops out of a (12, 9) class
    ragged = {c.network
              for c in _merge2_candidates(12, 9, batch=8, dtype=jnp.float32)}
    assert "bitonic" not in ragged and "s2ms" in ragged
    # sort sweeps offer the same pluggable families
    sort_fams = {c.network
                 for c in _sort_candidates(32, batch=8, dtype=jnp.float32)}
    assert len(sort_fams) > 1


def test_tournament_winner_round_trips_network(cache):
    plan = autotune_merge2(16, 16, batch=4, dtype=jnp.float32, cache=cache,
                           iters=1)
    assert plan.source == "autotune"
    from repro.networks import family_names

    assert plan.network in family_names()
    # the v4 entry persists the family and a cache hit replays it
    hit = plan_op("merge2", (16, 16), batch=4, dtype=jnp.float32, cache=cache)
    assert hit.source == "cache"
    assert hit.network == plan.network
    entry = cache.get(plan_key("merge2", shapes=(4, 16, 16), dtype="float32"))
    assert entry["network"] == plan.network
    assert entry["_schema"] == SCHEMA_VERSION


def test_v3_entries_without_network_ignored(cache):
    # v3 entries were tuned LOMS-only: replaying one would pin the class
    # to the column device and silently skip the tournament's choice
    assert SCHEMA_VERSION >= 4
    key = plan_key("merge2", shapes=(8, 32, 32), dtype="float32")
    v3 = {k: v for k, v in MergePlan(block_batch=4).to_entry().items()
          if k != "network"}
    cache._entries[key] = dict(v3, _schema=3)
    assert cache.get(key) is None
    plan = plan_op("merge2", (32, 32), batch=8, dtype=jnp.float32,
                   cache=cache)
    assert plan.source == "heuristic" and plan.network == "loms"


def test_network_defaults_loms_for_foreign_entries(cache):
    # a hand-written current-schema entry without the field degrades to
    # the LOMS default rather than KeyErroring
    key = plan_key("sort", shapes=(8, 64), dtype="float32")
    entry = {k: v for k, v in MergePlan(block_batch=4).to_entry().items()
             if k != "network"}
    cache.put(key, entry)
    plan = plan_op("sort", (64,), batch=8, dtype=jnp.float32, cache=cache)
    assert plan.source == "cache" and plan.network == "loms"


def test_autotune_segmented_persists_and_plan_op_reads_it(cache):
    from repro.streaming.planner import autotune_segmented

    plan = autotune_segmented((16,), n_segments=4, dtype=jnp.float32,
                              cache=cache, iters=1)
    assert plan.source == "autotune"
    hit = plan_op("segmented", (16,), batch=4, dtype=jnp.float32, cache=cache)
    assert hit.source == "cache"
    assert hit.network == plan.network
    # the merge-class flavor tunes (wa, wb) pairs under the same keying
    mplan = autotune_segmented((8, 16), n_segments=4, dtype=jnp.float32,
                               cache=cache, iters=1)
    mhit = plan_op("segmented", (8, 16), batch=4, dtype=jnp.float32,
                   cache=cache)
    assert mhit.source == "cache" and mhit.network == mplan.network


def test_tournament_counters(cache):
    import repro.obs as obs
    from repro.obs import metrics as obs_metrics

    prev = obs.set_enabled(True)
    try:
        picks = obs_metrics.counter("tournament.picks")
        sweeps = obs_metrics.counter("tournament.sweeps")
        p0, s0 = picks.total(), sweeps.total()
        plan = autotune_merge2(8, 8, batch=4, dtype=jnp.float32, cache=cache,
                               iters=1)
        assert picks.total() == p0 + 1
        assert picks.value(op="merge2", family=plan.network) >= 1
        assert sweeps.total() == s0 + 1  # >1 family competed at (8, 8)
    finally:
        obs.set_enabled(prev)


def test_decision_table_carries_network():
    from repro.api.dispatch import decision_table

    rows = decision_table("tpu")
    assert all("network" in r for r in rows)
    pallas = [r for r in rows if r["backend"] == "pallas"]
    assert pallas and all(r["network"] for r in pallas)


def test_prime_batch_kernel_runs_padded():
    # end-to-end: a ragged batch through the pallas merge wrapper
    from repro.kernels.ops import merge2

    rng = np.random.default_rng(0)
    a = jnp.sort(jnp.asarray(rng.normal(size=(13, 16)).astype(np.float32)), -1)
    b = jnp.sort(jnp.asarray(rng.normal(size=(13, 16)).astype(np.float32)), -1)
    out = merge2(a, b)
    np.testing.assert_array_equal(
        np.asarray(out),
        np.sort(np.concatenate([np.asarray(a), np.asarray(b)], -1), -1))
