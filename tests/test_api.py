"""Unified dispatch API (repro.api): semantics, routing, shims.

Covers the acceptance criteria of the api_redesign issue:
  * planner choices are inspectable and match the decision table —
    tree_topk under a TP-sharded Parallelism, vocab_topk for large
    unsharded vocab on TPU, the schedule path on CPU;
  * uniform semantics (axis, descending, stable, pytree payloads) match
    jnp.sort / jax.lax.top_k references across dtypes (randomized
    hypothesis sweeps of the same properties live in
    test_api_properties.py);
  * the expired repro.core.api shims raise pointed ImportErrors;
  * the padded top-k sentinel index regression (-1, never an aliasing 0).
"""
import types

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro
from repro import SortSpec
from repro.api import schedules
from repro.api.dispatch import ROUTER_TOPK_MAX, plan
from repro.api.registry import Backend, get_backend, register_backend

RNG = np.random.default_rng(11)
DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32]


def _rand(shape, dtype, lo=0, hi=100):
    # small integer support: exact in every dtype (incl. bf16), tie-heavy
    return jnp.asarray(RNG.integers(lo, hi, shape)).astype(dtype)


def _sorted(shape, dtype, descending=False):
    x = jnp.sort(_rand(shape, dtype), axis=-1)
    return x[..., ::-1] if descending else x


# ---------------------------------------------------------------------------
# planner decisions (the acceptance-criteria routing table)
# ---------------------------------------------------------------------------


def test_plan_topk_cpu_takes_schedule_path():
    dec = plan(SortSpec(op="topk", lengths=(32_000,), k=64, batch=8,
                        device="cpu"))
    assert dec.backend == "schedule"


def test_plan_topk_tpu_large_vocab_takes_vocab_kernel():
    dec = plan(SortSpec(op="topk", lengths=(152_064,), k=64, batch=8,
                        device="tpu"))
    assert (dec.backend, dec.detail) == ("pallas", "vocab_topk")


def test_plan_topk_tpu_small_axis_takes_router_kernel():
    dec = plan(SortSpec(op="topk", lengths=(ROUTER_TOPK_MAX,), k=8, batch=64,
                        device="tpu"))
    assert (dec.backend, dec.detail) == ("pallas", "router_topk")


def test_plan_topk_sharded_takes_tree():
    dec = plan(SortSpec(op="topk", lengths=(32_000,), k=64, batch=8,
                        device="tpu", sharded=True))
    assert (dec.backend, dec.detail) == ("sharded", "tree_topk")


def test_topk_auto_routes_to_tree_topk_with_tp_parallelism():
    """repro.topk(backend='auto') marks the spec sharded for a TP-sharded
    Parallelism whose axis divides the vocab — the planner then picks
    tree_topk without the caller ever importing it."""
    par = types.SimpleNamespace(tp_size=8, tp_axis="model", mesh=None)
    x = jnp.zeros((4, 8 * 128), jnp.float32)
    from repro.parallel.sharding import vocab_topk_axis

    assert vocab_topk_axis(par, x.shape[-1]) == "model"
    spec = SortSpec(op="topk", lengths=(x.shape[-1],), k=16, batch=4,
                    device=jax.default_backend(), sharded=True)
    assert plan(spec, par).backend == "sharded"
    # an indivisible vocab falls off the sharded path
    assert vocab_topk_axis(par, 1001) is None


def test_plan_merge_routes_by_shape_and_budget():
    assert plan(SortSpec(op="merge", lengths=(7, 5), device="tpu")).backend \
        == "schedule"  # ragged
    assert plan(SortSpec(op="merge", lengths=(512, 512), batch=8,
                         device="tpu")).backend == "pallas"
    assert plan(SortSpec(op="merge", lengths=(512, 512), batch=8,
                         device="cpu")).backend == "schedule"
    assert plan(SortSpec(op="merge", lengths=(100_000, 100_000),
                         device="tpu")).backend == "streaming"
    # payload rides the fused kernel permutes on TPU (single launch)
    assert plan(SortSpec(op="merge", lengths=(512, 512), device="tpu",
                         has_payload=True)).backend == "pallas"
    # ... but stable's tie pass is an XLA post-pass: executor
    assert plan(SortSpec(op="merge", lengths=(512, 512), device="tpu",
                         has_payload=True, stable=True)).backend == "schedule"
    # and off-TPU payload merges stay on the executor under auto
    assert plan(SortSpec(op="merge", lengths=(512, 512), device="cpu",
                         has_payload=True)).backend == "schedule"


def test_plan_explicit_backend_validated():
    with pytest.raises(ValueError, match="cannot run"):
        plan(SortSpec(op="merge", lengths=(8, 8), stable=True,
                      backend="pallas"))
    with pytest.raises(ValueError, match="unknown backend"):
        plan(SortSpec(op="merge", lengths=(8, 8), backend="fpga"))


def test_registry_is_pluggable():
    calls = []

    def toy_sort(x, *, spec, pos=None):
        calls.append(spec.op)
        return jnp.sort(x, axis=-1), None

    register_backend(Backend(
        name="toy", run={"sort": toy_sort}, supports=lambda s: s.op == "sort",
    ), overwrite=True)
    x = _rand((2, 9), jnp.float32)
    out = repro.sort(x, backend="toy")
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x), -1))
    assert calls == ["sort"]
    assert "toy" in repro.backend_names()
    with pytest.raises(ValueError, match="already registered"):
        register_backend(get_backend("toy"))


# ---------------------------------------------------------------------------
# uniform semantics: axis / descending / stable / payload (hypothesis)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape,axis", [((13,), 0), ((4, 9), 0), ((4, 9), -1),
                                        ((3, 5, 7), 1), ((3, 5, 7), -3)])
@pytest.mark.parametrize("descending", [False, True])
def test_sort_matches_jnp_sort_any_axis_any_direction(dtype, shape, axis,
                                                      descending):
    x = _rand(shape, dtype)
    out = repro.sort(x, axis=axis, descending=descending)
    ref = np.sort(np.asarray(x.astype(jnp.float32)), axis=axis)
    if descending:
        ref = np.flip(ref, axis=axis)
    np.testing.assert_array_equal(np.asarray(out.astype(jnp.float32)), ref)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("descending", [False, True])
def test_sort_stable_matches_stable_argsort(dtype, descending):
    n = 17
    x = _rand((3, n), dtype, hi=5)  # heavy ties
    out, perm = repro.sort(x, stable=True, descending=descending,
                           payload=jnp.broadcast_to(
                               jnp.arange(n, dtype=jnp.int32), (3, n)))
    xa = np.asarray(x.astype(jnp.float32))
    key = -xa if descending else xa
    order = np.argsort(key, axis=-1, kind="stable")
    np.testing.assert_array_equal(
        np.asarray(out.astype(jnp.float32)), np.take_along_axis(xa, order, -1))
    np.testing.assert_array_equal(np.asarray(perm), order)


def test_sort_payload_pytree_with_feature_dims():
    x = _rand((4, 10), jnp.float32, hi=1000)
    emb = jnp.asarray(RNG.standard_normal((4, 10, 3)), jnp.float32)
    out, tree = repro.sort(x, payload={"emb": emb, "mirror": x})
    order = np.argsort(np.asarray(x), axis=-1, kind="stable")
    np.testing.assert_array_equal(np.asarray(tree["mirror"]),
                                  np.asarray(out))
    np.testing.assert_array_equal(
        np.asarray(tree["emb"]),
        np.take_along_axis(np.asarray(emb), order[..., None], 1))


def test_sort_axis0_with_payload():
    x = _rand((6, 5), jnp.int32, hi=20)
    out, perm = repro.sort(x, axis=0, payload=jnp.broadcast_to(
        jnp.arange(6, dtype=jnp.int32)[:, None], (6, 5)))
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x), 0))
    np.testing.assert_array_equal(
        np.take_along_axis(np.asarray(x), np.asarray(perm), 0),
        np.asarray(out))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("m,n", [(1, 1), (7, 5), (16, 16), (3, 14), (20, 1)])
@pytest.mark.parametrize("descending", [False, True])
def test_merge_matches_sorted_concat(dtype, m, n, descending):
    a = _sorted((2, m), dtype, descending)
    b = _sorted((2, n), dtype, descending)
    out = repro.merge(a, b, descending=descending)
    ref = np.sort(np.concatenate(
        [np.asarray(a.astype(jnp.float32)), np.asarray(b.astype(jnp.float32))],
        -1), -1)
    if descending:
        ref = ref[..., ::-1]
    np.testing.assert_array_equal(np.asarray(out.astype(jnp.float32)), ref)


def test_merge_axis0_and_stable_payload():
    a = _sorted((8, 3), jnp.float32).T  # sorted along axis 0 after transpose
    b = _sorted((8, 3), jnp.float32).T
    out = repro.merge(a, b, axis=0)
    ref = np.sort(np.concatenate([np.asarray(a), np.asarray(b)], 0), 0)
    np.testing.assert_array_equal(np.asarray(out), ref)
    # stable: ties ordered a-before-b, by position within each list
    av = jnp.asarray([[0.0, 1.0, 1.0, 5.0]])
    bv = jnp.asarray([[1.0, 1.0, 2.0]])
    src = ({"who": jnp.asarray([[0, 1, 2, 3]])}, {"who": jnp.asarray([[10, 11, 12]])})
    mv, mt = repro.merge(av, bv, stable=True, payload=src)
    np.testing.assert_array_equal(np.asarray(mv[0]),
                                  [0.0, 1.0, 1.0, 1.0, 1.0, 2.0, 5.0])
    np.testing.assert_array_equal(np.asarray(mt["who"][0]),
                                  [0, 1, 2, 10, 11, 12, 3])


def test_merge_k_payload_tracks_sources():
    lists = [_sorted((2, n), jnp.float32) for n in (4, 6, 2)]
    pls = [{"src": jnp.full(l.shape, i, jnp.int32)} for i, l in enumerate(lists)]
    out, tree = repro.merge_k(lists, payload=pls)
    ref = np.sort(np.concatenate([np.asarray(x) for x in lists], -1), -1)
    np.testing.assert_array_equal(np.asarray(out), ref)
    # every carried source tag must point at a list containing that value
    for row in range(2):
        for j in range(ref.shape[-1]):
            src = int(tree["src"][row, j])
            assert float(out[row, j]) in np.asarray(lists[src][row]), (row, j)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n,k", [(4, 1), (37, 5), (160, 16), (200, 7)])
def test_topk_matches_lax_topk(dtype, n, k):
    x = _rand((3, n), dtype, hi=10_000)
    v, i = repro.topk(x, k)
    rv, _ = jax.lax.top_k(x.astype(jnp.float32), k)
    np.testing.assert_array_equal(np.asarray(v.astype(jnp.float32)),
                                  np.asarray(rv))
    taken = np.take_along_axis(np.asarray(x.astype(jnp.float32)),
                               np.asarray(i), -1)
    np.testing.assert_array_equal(taken, np.asarray(rv))


def test_topk_bottom_k_and_axis():
    x = _rand((5, 12), jnp.float32, hi=1000)
    v, i = repro.topk(x, 4, descending=False)
    np.testing.assert_array_equal(np.asarray(v), np.sort(np.asarray(x), -1)[:, :4])
    v0, i0 = repro.topk(x, 2, axis=0)
    np.testing.assert_array_equal(np.asarray(v0),
                                  -np.sort(-np.asarray(x), axis=0)[:2])


def test_topk_stable_orders_ties_by_index():
    x = jnp.asarray([[3.0, 7.0, 7.0, 1.0, 7.0, 9.0]])
    v, i = repro.topk(x, 4, stable=True)
    np.testing.assert_array_equal(np.asarray(v[0]), [9.0, 7.0, 7.0, 7.0])
    np.testing.assert_array_equal(np.asarray(i[0]), [5, 1, 2, 4])


def test_topk_payload_rides_selection():
    x = _rand((4, 64), jnp.float32, hi=10_000)
    aux = jnp.asarray(RNG.standard_normal((4, 64, 2)), jnp.float32)
    v, i, tree = repro.topk(x, 8, payload={"aux": aux})
    np.testing.assert_array_equal(
        np.asarray(tree["aux"]),
        np.take_along_axis(np.asarray(aux), np.asarray(i)[..., None], 1))


# ---------------------------------------------------------------------------
# regression: padded top-k sentinel slots must not alias index 0
# ---------------------------------------------------------------------------


def test_topk_pad_index_regression():
    """A real -inf ties with the -inf block padding; before the fix the pad
    slot carried index 0 and could alias x[..., 0]'s position. Pads now
    carry -1 and any non-negative returned index must gather its value."""
    x = jnp.asarray([[5.0, -jnp.inf, 3.0]])
    v, i = schedules.topk(x, 3, block=2)  # pads 3 -> 4, one sentinel slot
    np.testing.assert_array_equal(np.asarray(v[0]), [5.0, 3.0, -np.inf])
    iv = np.asarray(i[0])
    vv = np.asarray(v[0])
    xa = np.asarray(x[0])
    for j in range(3):
        if iv[j] >= 0:
            assert xa[iv[j]] == vv[j], (j, iv[j])
        else:
            assert vv[j] == -np.inf  # only sentinel slots may carry -1
    # indices of finite winners are exact
    assert list(iv[:2]) == [0, 2]


def test_topk_pad_index_regression_unified_api():
    x = jnp.asarray([[5.0, -jnp.inf, 3.0]])
    v, i = repro.topk(x, 3, block=2, backend="schedule")
    iv, vv, xa = np.asarray(i[0]), np.asarray(v[0]), np.asarray(x[0])
    assert all(xa[iv[j]] == vv[j] for j in range(3) if iv[j] >= 0)


def _assert_sentinel_index_contract(x, v, i):
    """Every non-negative returned index must gather its value; -1 only on
    dtype-min sentinels."""
    xa = np.asarray(x)
    iv, vv = np.asarray(i), np.asarray(v)
    n = xa.shape[-1]
    lo = np.finfo(xa.dtype).min
    for r in range(xa.shape[0]):
        for j in range(iv.shape[-1]):
            if iv[r, j] >= 0:
                assert iv[r, j] < n, (r, j, iv[r, j])
                assert xa[r, iv[r, j]] == vv[r, j], (r, j)
            else:
                assert vv[r, j] == lo, (r, j)


def test_topk_pad_index_regression_pallas_router():
    """Router kernel: dtype-min values tie with odd-group merge pads; the
    pads must carry -1, not an aliasing 0."""
    from repro.kernels.topk import router_topk_pallas

    lo = float(np.finfo(np.float32).min)
    x = jnp.full((8, 96), lo, jnp.float32).at[:, 5].set(1.0)
    v, i = router_topk_pallas(x, k=4, block=32, block_batch=4, interpret=True)
    _assert_sentinel_index_contract(x, v, i)
    assert np.asarray(i)[0, 0] == 5


def test_topk_pad_index_regression_pallas_vocab():
    """Vocab kernel: V-padding slots must carry -1, never positions >= V."""
    from repro.kernels.topk import vocab_topk_pallas

    lo = float(np.finfo(np.float32).min)
    x = jnp.full((4, 600), lo, jnp.float32).at[:, 7].set(1.0)
    v, i = vocab_topk_pallas(x, k=4, block=128, block_batch=4, interpret=True)
    _assert_sentinel_index_contract(x, v, i)
    assert np.asarray(i)[0, 0] == 7


def test_topk_pad_index_regression_tree():
    """Device-tree local path: block padding must carry -1 indices."""
    from repro.streaming.tree import local_topk_desc

    lo = float(np.finfo(np.float32).min)
    x = jnp.full((2, 130), lo, jnp.float32).at[:, 129].set(2.0)
    v, i = local_topk_desc(x, 4, block=128)
    _assert_sentinel_index_contract(x, v, i)
    assert np.asarray(i)[0, 0] == 129


def test_topk_stable_orders_pad_sentinels_last():
    """A masked -inf logit ties the dtype-min pad; stable=True must keep
    real indices ahead of the -1 sentinels in the tie run."""
    x = jnp.asarray([[5.0, -jnp.inf, 3.0, -jnp.inf]])
    v, i = repro.topk(x, 4, block=3, backend="schedule", stable=True)
    iv, vv = np.asarray(i[0]), np.asarray(v[0])
    np.testing.assert_array_equal(vv, [5.0, 3.0, -np.inf, -np.inf])
    seen_sentinel = False
    for j in range(4):
        if iv[j] < 0:
            seen_sentinel = True
        else:
            assert not seen_sentinel, f"real index {iv[j]} after a -1 pad"
            assert np.asarray(x[0])[iv[j]] == vv[j]
    assert list(iv[:2]) == [0, 2]


def test_plan_non_default_network_stays_on_schedule():
    """An explicit Batcher/MWMS/tree network ask must not be silently
    swapped for the LOMS kernels on TPU."""
    dec = plan(SortSpec(op="merge", lengths=(8, 8), device="tpu",
                        network="batcher-oe"))
    assert dec.backend == "schedule"
    dec = plan(SortSpec(op="merge_k", lengths=(8, 8, 8), device="tpu",
                        network="tree"))
    assert dec.backend == "schedule"
    a = jnp.sort(jnp.asarray(RNG.standard_normal((2, 8)), jnp.float32), -1)
    b = jnp.sort(jnp.asarray(RNG.standard_normal((2, 8)), jnp.float32), -1)
    out = repro.merge(a, b, network="batcher-oe")
    np.testing.assert_array_equal(
        np.asarray(out), np.sort(np.concatenate([a, b], -1), -1))


def test_stable_sort_large_axis_lexsort_path():
    """Past STABILIZE_CLOUD_MAX the stabilization switches to the run-id
    lexsort — the result must stay identical to a stable argsort."""
    from repro.api.payload import STABILIZE_CLOUD_MAX

    n = STABILIZE_CLOUD_MAX + 64
    x = _rand((2, n), jnp.int32, hi=7)  # tie-heavy
    out, perm = repro.sort(x, stable=True, descending=True,
                           payload=jnp.broadcast_to(
                               jnp.arange(n, dtype=jnp.int32), (2, n)))
    xa = np.asarray(x)
    order = np.argsort(-xa, axis=-1, kind="stable")
    np.testing.assert_array_equal(np.asarray(out),
                                  np.take_along_axis(xa, order, -1))
    np.testing.assert_array_equal(np.asarray(perm), order)


# ---------------------------------------------------------------------------
# cross-backend agreement + deprecation shims
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["schedule", "pallas", "streaming", "lax"])
def test_merge_backends_agree(backend):
    a, b = _sorted((4, 16), jnp.float32), _sorted((4, 16), jnp.float32)
    out = repro.merge(a, b, backend=backend)
    ref = np.sort(np.concatenate([np.asarray(a), np.asarray(b)], -1), -1)
    np.testing.assert_array_equal(np.asarray(out), ref)


@pytest.mark.parametrize("backend", ["schedule", "pallas", "lax"])
def test_topk_backends_agree(backend):
    x = _rand((4, 640), jnp.float32, hi=100_000)
    v, i = repro.topk(x, 16, backend=backend)
    rv, _ = jax.lax.top_k(x, 16)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))


def test_core_api_shims_removed_with_pointed_errors():
    # the PR 2 one-release deprecation shims expired: every legacy entry
    # point now raises ImportError naming its replacement, and nothing in
    # the tree imports them anymore
    from repro.core import api as old_api

    for name, repl in (("merge", "repro.merge"),
                       ("merge_k", "repro.merge_k"),
                       ("sort", "repro.sort"),
                       ("topk", "repro.topk"),
                       ("median_of_lists", "repro.median_of_lists"),
                       ("merge_schedule", "repro.api.schedules"),
                       ("median9", "repro.api.schedules"),
                       ("chunked_merge", "repro.streaming"),
                       ("chunked_merge_k", "repro.streaming"),
                       ("tree_topk", "repro.streaming"),
                       ("plan_merge", "repro.streaming.plan_merge2")):
        with pytest.raises(ImportError, match=repl.replace(".", r"\.")):
            getattr(old_api, name)
    # unknown attributes stay AttributeError (not ImportError)
    with pytest.raises(AttributeError):
        old_api.does_not_exist


def test_unified_api_jit_and_grad_safe():
    x = _rand((4, 32), jnp.float32, hi=1000)

    @jax.jit
    def f(x):
        v, _ = repro.topk(x, 4)
        return v.sum()

    g = jax.grad(f)(x)
    assert g.shape == x.shape
    # gradient flows only into the selected entries
    assert int((np.asarray(g) != 0).sum()) == 4 * 4


# ---------------------------------------------------------------------------
# measured-cost dispatch (route samples override the static ladder)
# ---------------------------------------------------------------------------


def _route_cache(tmp_path):
    from repro.streaming.cache import AutotuneCache

    return AutotuneCache(path=str(tmp_path / "routes.json"), autosave=False)


def test_measured_dispatch_prefers_faster_recorded_backend(tmp_path):
    from repro.api.dispatch import record_route_us
    from repro.streaming.cache import set_default_cache

    prev = set_default_cache(_route_cache(tmp_path))
    try:
        spec = SortSpec(op="merge", lengths=(64, 64), batch=4,
                        dtype="float32", device="cpu")
        base = plan(spec)
        assert base.backend == "schedule" and base.source == "rule"
        record_route_us(spec, "schedule", 120.0)
        record_route_us(spec, "streaming", 40.0)
        dec = plan(spec)
        assert dec.backend == "streaming"
        assert dec.source == "measured"
        assert dec.measured_us == 40.0
        # recorder keeps the fastest sample (noise-robust minimum)
        record_route_us(spec, "streaming", 900.0)
        assert plan(spec).measured_us == 40.0
        # re-measuring the rule's own choice faster flips routing back;
        # winner == rule keeps source="rule" with the sample annotated
        record_route_us(spec, "schedule", 10.0)
        dec2 = plan(spec)
        assert dec2.backend == "schedule" and dec2.source == "rule"
        assert dec2.measured_us == 10.0
    finally:
        set_default_cache(prev)


def test_measured_dispatch_needs_two_samples(tmp_path):
    from repro.api.dispatch import record_route_us
    from repro.streaming.cache import set_default_cache

    prev = set_default_cache(_route_cache(tmp_path))
    try:
        spec = SortSpec(op="merge", lengths=(64, 64), batch=4,
                        dtype="float32", device="cpu")
        record_route_us(spec, "streaming", 5.0)
        dec = plan(spec)  # one sample cannot rank alternatives
        assert dec.backend == "schedule" and dec.source == "rule"
        assert dec.measured_us is None
    finally:
        set_default_cache(prev)


def test_measured_dispatch_respects_optout_and_explicit(tmp_path, monkeypatch):
    from repro.api.dispatch import record_route_us
    from repro.streaming.cache import set_default_cache

    prev = set_default_cache(_route_cache(tmp_path))
    try:
        spec = SortSpec(op="merge", lengths=(64, 64), batch=4,
                        dtype="float32", device="cpu")
        record_route_us(spec, "schedule", 120.0)
        record_route_us(spec, "streaming", 40.0)
        assert plan(spec).backend == "streaming"
        monkeypatch.setenv("REPRO_MEASURED_DISPATCH", "0")
        assert plan(spec).backend == "schedule"
        monkeypatch.delenv("REPRO_MEASURED_DISPATCH")
        # explicit caller override is never second-guessed
        explicit = SortSpec(op="merge", lengths=(64, 64), batch=4,
                            dtype="float32", device="cpu", backend="schedule")
        dec = plan(explicit)
        assert dec.backend == "schedule" and dec.source == "rule"
    finally:
        set_default_cache(prev)


def test_decision_table_carries_measured_columns():
    rows = repro.decision_table(device="cpu")
    assert all("source" in r and "measured_us" in r and "tuned_us" in r
               for r in rows)
    assert all(r["source"] in ("rule", "measured") for r in rows)
