"""Fused single-launch pipeline tests (PR 4 tentpole).

Bit-equality of the fused pallas paths against the unfused executor
pipeline for the hard cases — NaN/±inf under ``nan_policy="last"``,
pytree payloads (incl. trailing feature dims), descending inputs,
non-power-of-two lengths, int dtypes — plus the acceptance check: a
float32 ``repro.sort`` with a payload lowers to exactly one
``pallas_call`` with no XLA-level encode/decode/gather around it, and the
grid-resident chunked merge is a single launch that matches the legacy
per-tile loop bit for bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro

RNG = np.random.default_rng(20250731)


def _vals_equal(a, b):
    np.testing.assert_array_equal(
        np.where(np.isnan(np.asarray(a)), np.float32(0), np.asarray(a))
        if np.asarray(a).dtype.kind == "f" else np.asarray(a),
        np.where(np.isnan(np.asarray(b)), np.float32(0), np.asarray(b))
        if np.asarray(b).dtype.kind == "f" else np.asarray(b),
    )
    if np.asarray(a).dtype.kind == "f":
        np.testing.assert_array_equal(np.isnan(np.asarray(a)),
                                      np.isnan(np.asarray(b)))


def _specials(shape):
    """float32 rows salted with NaN / +inf / -inf / ±0 / extremes."""
    x = RNG.normal(size=shape).astype(np.float32)
    flat = x.reshape(-1)
    picks = RNG.choice(flat.size, size=min(8, flat.size), replace=False)
    specials = [np.nan, np.inf, -np.inf, 0.0, -0.0,
                np.finfo(np.float32).max, np.finfo(np.float32).min, 1.0]
    for i, p in enumerate(picks):
        flat[p] = specials[i % len(specials)]
    return jnp.asarray(x)


# ---------------------------------------------------------------------------
# bit-equality: fused pallas vs unfused executor
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [32, 37, 128])
@pytest.mark.parametrize("descending", [False, True])
def test_fused_sort_specials_match_schedule(n, descending):
    x = _specials((4, n))
    f = repro.sort(x, descending=descending, backend="pallas")
    s = repro.sort(x, descending=descending, backend="schedule")
    _vals_equal(f, s)


def test_fused_sort_pytree_payload_matches_schedule():
    x = jnp.asarray(RNG.permutation(4 * 33).reshape(4, 33).astype(np.float32))
    pay = {"idx": jnp.asarray(RNG.integers(0, 99, (4, 33)), jnp.int32),
           "emb": jnp.asarray(RNG.normal(size=(4, 33, 5)).astype(np.float32))}
    fv, fp = repro.sort(x, payload=pay, backend="pallas")
    sv, sp = repro.sort(x, payload=pay, backend="schedule")
    _vals_equal(fv, sv)
    np.testing.assert_array_equal(np.asarray(fp["idx"]), np.asarray(sp["idx"]))
    np.testing.assert_array_equal(np.asarray(fp["emb"]), np.asarray(sp["emb"]))


def test_fused_sort_intmax_tie_payload_valid():
    # a genuine INT32_MAX ties the in-kernel pad sentinel (non-pow2 pad):
    # the position lane, not the value, must decide the live prefix
    x = jnp.asarray([[2147483647, 5, 2147483647, 1, 7],
                     [3, 1, 2, 2147483647, 2147483647]], jnp.int32)
    pay = jnp.arange(10, dtype=jnp.int32).reshape(2, 5)
    fv, fp = repro.sort(x, payload=pay, backend="pallas")
    sv, sp = repro.sort(x, payload=pay, backend="schedule")
    np.testing.assert_array_equal(np.asarray(fv), np.asarray(sv))
    for r in range(2):  # tie order is unspecified; the index set is not
        assert sorted(np.asarray(fp)[r]) == sorted(np.asarray(sp)[r])


@pytest.mark.parametrize("descending", [False, True])
def test_fused_merge_specials_match_schedule(descending):
    a = jnp.sort(_specials((3, 16)), -1)
    b = jnp.sort(_specials((3, 24)), -1)
    if descending:
        a, b = a[:, ::-1], b[:, ::-1]
    f = repro.merge(a, b, descending=descending, backend="pallas")
    s = repro.merge(a, b, descending=descending, backend="schedule")
    _vals_equal(f, s)


def test_fused_merge_k_payload_matches_schedule():
    lens = (8, 12, 4)
    # one global permutation split across lists: values stay unique, so
    # the fused and executor permutations must agree exactly
    pool = RNG.permutation(2 * sum(lens)).astype(np.float32).reshape(2, -1)
    offs = np.cumsum((0,) + lens)
    lists = [jnp.asarray(np.sort(pool[:, offs[i]:offs[i + 1]], -1))
             for i in range(len(lens))]
    pays = [jnp.asarray(RNG.integers(0, 99, l.shape), jnp.int32)
            for l in lists]
    fv, fp = repro.merge_k(lists, payload=pays, backend="pallas")
    sv, sp = repro.merge_k(lists, payload=pays, backend="schedule")
    _vals_equal(fv, sv)
    np.testing.assert_array_equal(np.asarray(fp), np.asarray(sp))


def test_fused_topk_specials_match_schedule():
    x = _specials((4, 96))
    fv, fi = repro.topk(x, 8, backend="pallas")
    sv, si = repro.topk(x, 8, backend="schedule")
    _vals_equal(fv, sv)
    np.testing.assert_array_equal(np.asarray(fi), np.asarray(si))


def test_fused_sort_uint32_non_pow2():
    # regression: the in-kernel pad fill must go through np_fill — a bare
    # python uint32-max overflows JAX's weak-int32 promotion
    x = jnp.asarray([[5, 4294967295, 1, 3, 2],
                     [7, 0, 4294967295, 2, 9]], jnp.uint32)
    np.testing.assert_array_equal(
        np.asarray(repro.sort(x, backend="pallas")),
        np.sort(np.asarray(x), -1))
    pay = jnp.arange(10, dtype=jnp.int32).reshape(2, 5)
    fv, fp = repro.sort(x, payload=pay, backend="pallas")
    sv, sp = repro.sort(x, payload=pay, backend="schedule")
    np.testing.assert_array_equal(np.asarray(fv), np.asarray(sv))
    np.testing.assert_array_equal(np.asarray(fp), np.asarray(sp))


def test_bitonic_merge_ragged_batch_pads():
    # regression: the VMEM-fit (non-divisor) block_batch must pad through
    # the bitonic wrapper too, not trip its grid assertion
    from repro.kernels.ops import merge2

    a = jnp.sort(jnp.asarray(RNG.normal(size=(13, 16)).astype(np.float32)), -1)
    b = jnp.sort(jnp.asarray(RNG.normal(size=(13, 16)).astype(np.float32)), -1)
    out = merge2(a, b, kind="bitonic")
    np.testing.assert_array_equal(
        np.asarray(out),
        np.sort(np.concatenate([np.asarray(a), np.asarray(b)], -1), -1))


def test_fused_int_and_unsafe_paths():
    xi = jnp.asarray(RNG.integers(-1000, 1000, (5, 19)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(repro.sort(xi, backend="pallas")),
        np.sort(np.asarray(xi), -1))
    xf = jnp.asarray(RNG.normal(size=(5, 24)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(repro.sort(xf, nan_policy="unsafe", backend="pallas")),
        np.sort(np.asarray(xf), -1))


# ---------------------------------------------------------------------------
# the acceptance check: one pallas_call, no XLA encode/decode/gather
# ---------------------------------------------------------------------------


def _collect_prims(jaxpr, names, into_kernels=False):
    for eqn in jaxpr.eqns:
        names.append(eqn.primitive.name)
        if eqn.primitive.name == "pallas_call" and not into_kernels:
            continue
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                _collect_prims(v.jaxpr, names, into_kernels)
            elif isinstance(v, (list, tuple)):
                for vi in v:
                    if hasattr(vi, "jaxpr"):
                        _collect_prims(vi.jaxpr, names, into_kernels)
    return names


def test_fused_sort_is_single_pallas_call_no_xla_passes():
    x = jnp.asarray(RNG.normal(size=(4, 64)).astype(np.float32))
    pay = jnp.asarray(RNG.integers(0, 64, (4, 64)), jnp.int32)

    jaxpr = jax.make_jaxpr(
        lambda a, p: repro.sort(a, payload=p, nan_policy="last",
                                backend="pallas"))(x, pay)
    names = _collect_prims(jaxpr.jaxpr, [])
    assert names.count("pallas_call") == 1, names
    # the key transform, payload gather and value sort all live inside the
    # kernel: none of their XLA realizations may appear around it
    for banned in ("sort", "gather", "scatter",
                   "bitcast_convert_type", "take_along_axis"):
        assert names.count(banned) == 0, (banned, names)


def test_unfused_pipeline_has_the_xla_passes():
    # sanity for the test above: with fusion disabled the XLA-level passes
    # reappear, so the assertion actually discriminates
    from repro.api import fused as fused_mod

    x = jnp.asarray(RNG.normal(size=(4, 64)).astype(np.float32))
    pay = jnp.asarray(RNG.integers(0, 64, (4, 64)), jnp.int32)
    prev = fused_mod.set_fused_enabled(False)
    try:
        jaxpr = jax.make_jaxpr(
            lambda a, p: repro.sort(a, payload=p, backend="pallas"))(x, pay)
    finally:
        fused_mod.set_fused_enabled(prev)
    names = _collect_prims(jaxpr.jaxpr, [])
    assert names.count("pallas_call") == 0  # executor fallback
    assert "bitcast_convert_type" in names or "gather" in names


def test_fused_merge_is_single_pallas_call():
    a = jnp.sort(jnp.asarray(RNG.normal(size=(4, 32)).astype(np.float32)), -1)
    b = jnp.sort(jnp.asarray(RNG.normal(size=(4, 32)).astype(np.float32)), -1)
    jaxpr = jax.make_jaxpr(
        lambda a, b: repro.merge(a, b, backend="pallas"))(a, b)
    names = _collect_prims(jaxpr.jaxpr, [])
    assert names.count("pallas_call") == 1, names


def test_plan_routes_sort_to_fused_pallas_on_tpu():
    from repro.api.dispatch import plan
    from repro.api.spec import SortSpec

    dec = plan(SortSpec(op="sort", lengths=(1024,), batch=8, device="tpu"))
    assert (dec.backend, dec.detail) == ("pallas", "loms_sort_fused")
    # payload rides the same fused launch
    dec = plan(SortSpec(op="sort", lengths=(1024,), batch=8, device="tpu",
                        has_payload=True))
    assert dec.backend == "pallas"
    # stable's tie pass is an XLA post-pass: executor
    dec = plan(SortSpec(op="sort", lengths=(1024,), batch=8, device="tpu",
                        stable=True))
    assert dec.backend == "schedule"
    # past the fused-sort VMEM gate: executor merge tree
    dec = plan(SortSpec(op="sort", lengths=(1 << 17,), batch=1, device="tpu"))
    assert dec.backend == "schedule"
    # CPU hosts keep the executor under auto (interpret mode is opt-in)
    dec = plan(SortSpec(op="sort", lengths=(1024,), batch=8, device="cpu"))
    assert dec.backend == "schedule"


# ---------------------------------------------------------------------------
# gradients through the fused paths
# ---------------------------------------------------------------------------


def test_fused_sort_grad_matches_schedule():
    x = jnp.asarray(RNG.normal(size=(3, 16)).astype(np.float32))
    gf = jax.grad(lambda x: (repro.sort(x, backend="pallas") ** 2).sum())(x)
    gs = jax.grad(lambda x: (repro.sort(x, backend="schedule") ** 2).sum())(x)
    np.testing.assert_allclose(np.asarray(gf), np.asarray(gs), rtol=1e-6)


def test_fused_topk_grad_matches_schedule():
    x = jnp.asarray(RNG.normal(size=(4, 96)).astype(np.float32))
    gf = jax.grad(lambda x: repro.topk(x, 4, backend="pallas")[0].sum())(x)
    gs = jax.grad(lambda x: repro.topk(x, 4, backend="schedule")[0].sum())(x)
    np.testing.assert_array_equal(np.asarray(gf), np.asarray(gs))


def test_fused_payload_grad_on_ties_matches_forward():
    # regression: the payload gather is a concrete linear map, so its VJP
    # must use the kernel's *actual* permutation — the column devices'
    # tie order need not match a stable argsort's reconstruction
    a = jnp.full((1, 8), 5.0, jnp.float32)
    b = jnp.full((1, 8), 5.0, jnp.float32)
    pa = jnp.arange(8, dtype=jnp.float32)[None]
    pb = jnp.arange(8, 16, dtype=jnp.float32)[None]

    def f(pa, pb):
        _, (po_a,) = repro.merge(a, b, payload=((pa,), (pb,)),
                                 backend="pallas")
        return po_a

    out, vjp = jax.vjp(f, pa, pb)
    ct = jnp.zeros_like(out).at[0, 0].set(1.0)
    g_pa, g_pb = vjp(ct)
    src = int(out[0, 0])  # payload value == source slot in concat(pa, pb)
    g_cat = np.concatenate([np.asarray(g_pa), np.asarray(g_pb)], -1)
    assert g_cat[0, src] == 1.0 and np.abs(g_cat).sum() == 1.0

    # same through the fused sort with every value tied (column devices
    # engage at run >= 64)
    x = jnp.full((1, 256), 1.0, jnp.float32)
    p = jnp.arange(256, dtype=jnp.float32)[None]
    out, vjp = jax.vjp(
        lambda p: repro.sort(x, payload=p, backend="pallas")[1], p)
    (g,) = vjp(jnp.zeros_like(out).at[0, 0].set(1.0))
    src = int(out[0, 0])
    assert float(g[0, src]) == 1.0 and float(np.abs(np.asarray(g)).sum()) == 1.0


def test_disable_flag_reverts_auto_routing():
    # regression: the escape hatch must stop auto routing to the fused
    # pallas rows, not just the ops-layer short-circuit
    from repro.api import fused as fused_mod
    from repro.api.dispatch import plan
    from repro.api.spec import SortSpec

    prev = fused_mod.set_fused_enabled(False)
    try:
        assert plan(SortSpec(op="sort", lengths=(1024,), batch=8,
                             device="tpu")).backend == "schedule"
        assert plan(SortSpec(op="merge", lengths=(512, 512), device="tpu",
                             has_payload=True)).backend == "schedule"
    finally:
        fused_mod.set_fused_enabled(prev)
    assert plan(SortSpec(op="sort", lengths=(1024,), batch=8,
                         device="tpu")).backend == "pallas"


def test_fused_payload_leaf_grad_flows():
    x = jnp.asarray(RNG.permutation(48).reshape(3, 16).astype(np.float32))
    p = jnp.asarray(RNG.normal(size=(3, 16)).astype(np.float32))
    g = jax.grad(
        lambda p: repro.sort(x, payload=p, backend="pallas")[1].sum())(p)
    np.testing.assert_array_equal(np.asarray(g), np.ones((3, 16), np.float32))


# ---------------------------------------------------------------------------
# grid-resident chunked merge
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("na,nb,tile", [(500, 300, 64), (130, 1000, 32)])
def test_grid_merge_matches_loop_and_reference(na, nb, tile):
    from repro.streaming.chunked import chunked_merge

    a = jnp.sort(jnp.asarray(RNG.normal(size=(2, na)).astype(np.float32)), -1)
    b = jnp.sort(jnp.asarray(RNG.normal(size=(2, nb)).astype(np.float32)), -1)
    ref = jnp.sort(jnp.concatenate([a, b], -1), -1)
    g = chunked_merge(a, b, tile=tile, mode="grid")
    l = chunked_merge(a, b, tile=tile, mode="loop")
    np.testing.assert_array_equal(np.asarray(g), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(l), np.asarray(ref))


def test_grid_merge_is_single_pallas_call():
    from repro.streaming.chunked import chunked_merge

    a = jnp.sort(jnp.asarray(RNG.normal(size=(1, 600)).astype(np.float32)), -1)
    b = jnp.sort(jnp.asarray(RNG.normal(size=(1, 500)).astype(np.float32)), -1)
    jaxpr = jax.make_jaxpr(
        lambda a, b: chunked_merge(a, b, tile=128, mode="grid"))(a, b)
    names = _collect_prims(jaxpr.jaxpr, [])
    assert names.count("pallas_call") == 1, names


def test_grid_merge_int_keys_dtype():
    from repro.streaming.chunked import chunked_merge

    a = jnp.sort(jnp.asarray(RNG.integers(-9, 9, (2, 77)), jnp.int32), -1)
    b = jnp.sort(jnp.asarray(RNG.integers(-9, 9, (2, 99)), jnp.int32), -1)
    np.testing.assert_array_equal(
        np.asarray(chunked_merge(a, b, tile=16)),
        np.sort(np.concatenate([np.asarray(a), np.asarray(b)], -1), -1))


# ---------------------------------------------------------------------------
# fused-vs-unfused flag plumbing
# ---------------------------------------------------------------------------


def test_disable_flag_restores_executor_results():
    from repro.api import fused as fused_mod

    x = _specials((3, 40))
    pay = jnp.asarray(RNG.integers(0, 40, (3, 40)), jnp.int32)
    fv, fp = repro.sort(x, payload=pay, backend="pallas")
    prev = fused_mod.set_fused_enabled(False)
    try:
        uv, up = repro.sort(x, payload=pay, backend="pallas")
    finally:
        fused_mod.set_fused_enabled(prev)
    _vals_equal(fv, uv)
    np.testing.assert_array_equal(np.asarray(fp), np.asarray(up))
