"""Segmented (CSR ragged) subsystem tests — PR 5.

Covers the acceptance criteria:

* ``repro.segment_sort`` / ``segment_merge`` / ``segment_topk`` /
  ``segment_argmax`` bit-identical to a per-segment ``jnp.sort`` / top-k
  reference across ragged offset patterns (empty / length-1 / prime /
  all-equal segments, NaN & ±inf keys, descending, pytree payloads), on
  both the auto route and the forced kernel path;
* each size-class bucket lowers to exactly one ``pallas_call``
  (jaxpr-verified), singleton classes to none;
* the escape hatch (``set_segmented_enabled``) reverts auto dispatch to
  the per-segment XLA reference;
* the ``kernels/common.py`` guards: ``ceil_pow2`` degenerate inputs,
  zero-width ``stable_compact`` / ``pad_tail_sorted``;
* the MoE ragged-capacity dispatch and the mixed-k serving sampler route
  through the segmented backend and stay consistent with their dense
  equivalents.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro
from repro.segmented import set_segmented_enabled

RNG = np.random.default_rng(7)

#: ragged offset patterns: empty, length-1, prime, all-equal, mixed
OFFSET_CASES = [
    (0,),  # no segments at all
    (0, 0),  # one empty segment
    (0, 1),  # one singleton
    (0, 5),  # one tiny segment
    (0, 0, 1, 1, 2),  # empties interleaved with singletons
    (0, 7, 14, 21),  # all-equal prime lengths
    (0, 3, 3, 4, 17, 17, 64, 111),  # the kitchen sink
    (0, 13, 26, 39, 52),  # all-equal, non-pow2
    (0, 1, 2, 3, 4, 5),  # all singletons
]


def _ref_sort(x, offs, descending=False):
    parts = []
    for a, b in zip(offs, offs[1:]):
        s = np.sort(np.asarray(x[a:b]))
        parts.append(s[::-1] if descending else s)
    return np.concatenate(parts) if parts else np.asarray(x[:0])


def _collect_prims(jaxpr, names):
    for eqn in jaxpr.eqns:
        names.append(eqn.primitive.name)
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                _collect_prims(v.jaxpr, names)
            elif isinstance(v, (list, tuple)):
                for vi in v:
                    if hasattr(vi, "jaxpr"):
                        _collect_prims(vi.jaxpr, names)
    return names


def _n_pallas(fn, *args):
    return _collect_prims(jax.make_jaxpr(fn)(*args).jaxpr, []).count(
        "pallas_call")


# ---------------------------------------------------------------------------
# common.py guards (satellite)
# ---------------------------------------------------------------------------


def test_ceil_pow2_degenerate_guard():
    from repro.kernels.common import ceil_pow2

    assert ceil_pow2(0) == 1  # never a 0-width (or phantom 2-wide) network
    assert ceil_pow2(1) == 1
    assert [ceil_pow2(n) for n in (2, 3, 4, 5, 8, 9)] == [2, 4, 4, 8, 8, 16]


def test_stable_compact_zero_width_and_singleton():
    from repro.kernels.common import stable_compact

    empty = jnp.zeros((3, 0), jnp.float32)
    assert stable_compact(jnp.zeros((3, 0), bool), empty).shape == (3, 0)
    one = jnp.ones((2, 1), jnp.float32)
    out = stable_compact(jnp.ones((2, 1), bool), one)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(one))


def test_pad_tail_sorted_zero_width():
    from repro.kernels.common import pad_tail_sorted, sentinel_max, sentinel_min

    empty = jnp.zeros((2, 0), jnp.float32)
    up = pad_tail_sorted(empty, 4)
    assert up.shape == (2, 4)
    assert float(up[0, 0]) == sentinel_max(jnp.float32)
    down = pad_tail_sorted(jnp.zeros((2, 0), jnp.int32), 3, descending=True)
    assert int(down[0, 0]) == sentinel_min(jnp.int32)


def test_bucketer_drops_empties_and_rejects_traced_offsets():
    from repro.segmented import bucket_segments, normalize_offsets

    classes, spill = bucket_segments(np.array([0, 1, 0, 3, 8, 9]), 64)
    assert not spill
    widths = {c.width: c.seg_ids for c in classes}
    assert widths == {1: (1,), 4: (3,), 8: (4,), 16: (5,)}
    with pytest.raises(TypeError, match="static"):
        jax.jit(lambda o: normalize_offsets(o))(jnp.arange(3))
    # concrete (non-traced) arrays of any flavor are fine
    assert normalize_offsets(jnp.asarray([0, 3, 7])) == (0, 3, 7)
    assert normalize_offsets(np.asarray([0, 3, 7])) == (0, 3, 7)
    with pytest.raises(ValueError, match="non-decreasing"):
        normalize_offsets((0, 5, 3))


# ---------------------------------------------------------------------------
# bit-equality vs the per-segment reference (deterministic sweep)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("offs", OFFSET_CASES)
@pytest.mark.parametrize("backend", ["auto", "segmented"])
@pytest.mark.parametrize("descending", [False, True])
def test_segment_sort_matches_reference(offs, backend, descending):
    x = jnp.asarray(RNG.normal(size=(offs[-1],)).astype(np.float32))
    out = repro.segment_sort(x, offs, backend=backend, descending=descending)
    np.testing.assert_array_equal(
        np.asarray(out), _ref_sort(x, offs, descending))


@pytest.mark.parametrize("backend", ["auto", "segmented"])
def test_segment_sort_nan_inf(backend):
    offs = (0, 4, 4, 9, 40)
    x = RNG.normal(size=(offs[-1],)).astype(np.float32)
    x[1] = np.nan
    x[5] = np.inf
    x[6] = -np.inf
    x[20] = np.nan
    out = repro.segment_sort(jnp.asarray(x), offs, backend=backend)
    np.testing.assert_array_equal(
        np.asarray(out), _ref_sort(x, offs), err_msg="NaNs must sort last")
    outd = repro.segment_sort(jnp.asarray(x), offs, backend=backend,
                              descending=True)
    np.testing.assert_array_equal(np.asarray(outd), _ref_sort(x, offs, True))


@pytest.mark.parametrize("dtype", [jnp.int32, jnp.uint32])
@pytest.mark.parametrize("backend", ["auto", "segmented"])
def test_segment_sort_int_dtypes(dtype, backend):
    offs = (0, 3, 3, 20, 51)
    hi = np.iinfo(np.dtype(dtype)).max
    x = jnp.asarray(
        RNG.integers(0, hi, (offs[-1],), dtype=np.dtype(dtype).name))
    out = repro.segment_sort(x, offs, backend=backend)
    np.testing.assert_array_equal(np.asarray(out), _ref_sort(x, offs))


@pytest.mark.parametrize("backend", ["auto", "segmented"])
def test_segment_sort_payload_pytree(backend):
    offs = (0, 2, 2, 9, 41, 42)
    n = offs[-1]
    x = jnp.asarray(RNG.permutation(n).astype(np.int32))  # unique keys
    pay = {"emb": jnp.asarray(RNG.normal(size=(n, 3)).astype(np.float32)),
           "pos": jnp.arange(n, dtype=jnp.int32)}
    out, tree = repro.segment_sort(x, offs, backend=backend, payload=pay)
    for a, b in zip(offs, offs[1:]):
        order = np.argsort(np.asarray(x[a:b]), kind="stable")
        np.testing.assert_array_equal(np.asarray(out[a:b]),
                                      np.asarray(x[a:b])[order])
        np.testing.assert_array_equal(np.asarray(tree["emb"][a:b]),
                                      np.asarray(pay["emb"][a:b])[order])
        np.testing.assert_array_equal(np.asarray(tree["pos"][a:b]),
                                      np.asarray(pay["pos"][a:b])[order])


@pytest.mark.parametrize("backend", ["auto", "segmented"])
def test_segment_merge_ragged_pairs(backend):
    offs_a = (0, 0, 3, 10, 14, 30)
    offs_b = (0, 2, 2, 9, 30, 41)
    a = RNG.normal(size=(offs_a[-1],)).astype(np.float32)
    b = RNG.normal(size=(offs_b[-1],)).astype(np.float32)
    for o0, o1 in zip(offs_a, offs_a[1:]):
        a[o0:o1] = np.sort(a[o0:o1])
    for o0, o1 in zip(offs_b, offs_b[1:]):
        b[o0:o1] = np.sort(b[o0:o1])
    out, oo = repro.segment_merge(jnp.asarray(a), jnp.asarray(b),
                                  offs_a, offs_b, backend=backend)
    assert oo == tuple(x + y for x, y in zip(offs_a, offs_b))
    for s in range(len(offs_a) - 1):
        ref = np.sort(np.concatenate([a[offs_a[s]:offs_a[s + 1]],
                                      b[offs_b[s]:offs_b[s + 1]]]))
        np.testing.assert_array_equal(np.asarray(out[oo[s]:oo[s + 1]]), ref)


@pytest.mark.parametrize("backend", ["auto", "segmented"])
def test_segment_merge_descending_with_payload(backend):
    offs_a = (0, 4, 9)
    offs_b = (0, 6, 7)
    a = np.sort(RNG.normal(size=(9,)).astype(np.float32))[::-1].copy()
    a[:4] = np.sort(a[:4])[::-1]
    a[4:] = np.sort(a[4:])[::-1]
    b = RNG.normal(size=(7,)).astype(np.float32)
    b[:6] = np.sort(b[:6])[::-1]
    pa = jnp.arange(9, dtype=jnp.int32)
    pb = jnp.arange(7, dtype=jnp.int32) + 100
    out, tree, oo = repro.segment_merge(
        jnp.asarray(a), jnp.asarray(b), offs_a, offs_b, backend=backend,
        descending=True, payload=(pa, pb))
    for s in range(2):
        seg = np.concatenate([a[offs_a[s]:offs_a[s + 1]],
                              b[offs_b[s]:offs_b[s + 1]]])
        np.testing.assert_array_equal(np.asarray(out[oo[s]:oo[s + 1]]),
                                      np.sort(seg)[::-1])
    # payload consistency: each slot's tag resolves to its own value
    for j in range(oo[-1]):
        s = max(i for i in range(2) if oo[i] <= j)
        tag = int(tree[j])
        src = (a[offs_a[s]:offs_a[s + 1]] if tag < 100
               else b[offs_b[s]:offs_b[s + 1]])
        base = offs_a[s] if tag < 100 else offs_b[s] + 100
        assert np.float32(src[tag - base]) == np.asarray(out[j])


@pytest.mark.parametrize("backend", ["auto", "segmented"])
@pytest.mark.parametrize("descending", [True, False])
def test_segment_topk_mixed_k(backend, descending):
    offs = (0, 0, 1, 8, 15, 47, 111)
    ks = (3, 2, 5, 1, 8, 64)
    x = RNG.normal(size=(offs[-1],)).astype(np.float32)
    vals, idx, oo = repro.segment_topk(
        jnp.asarray(x), offs, ks, backend=backend, descending=descending)
    for s, (o0, o1) in enumerate(zip(offs, offs[1:])):
        cnt = min(ks[s], o1 - o0)
        assert oo[s + 1] - oo[s] == cnt
        srt = np.sort(x[o0:o1])
        ref = (srt[::-1] if descending else srt)[:cnt]
        got = np.asarray(vals[oo[s]:oo[s + 1]])
        np.testing.assert_array_equal(got, ref)
        # idx are within-segment positions that reproduce the values
        np.testing.assert_array_equal(
            x[o0:o1][np.asarray(idx[oo[s]:oo[s + 1]])], got)


@pytest.mark.parametrize("backend", ["auto", "segmented"])
def test_segment_argmax(backend):
    offs = (0, 0, 1, 8, 15, 47)
    x = RNG.normal(size=(offs[-1],)).astype(np.float32)
    v, i = repro.segment_argmax(jnp.asarray(x), offs, backend=backend)
    for s, (o0, o1) in enumerate(zip(offs, offs[1:])):
        if o1 == o0:
            assert int(i[s]) == -1
        else:
            assert int(i[s]) == int(np.argmax(x[o0:o1]))
            assert np.float32(np.max(x[o0:o1])) == np.asarray(v[s])


def test_segment_sort_spill_long_segments():
    from repro.segmented import max_class_width

    mw = max_class_width(jnp.float32)
    ln = 2 * mw + 37
    offs = (0, 5, 5 + ln, 5 + 2 * ln, 5 + 2 * ln + 9)
    x = RNG.normal(size=(offs[-1],)).astype(np.float32)
    out = repro.segment_sort(jnp.asarray(x), offs, backend="segmented")
    np.testing.assert_array_equal(np.asarray(out), _ref_sort(x, offs))
    # perm-carrying spill takes the batched XLA path but stays exact
    out2, perm = repro.segment_sort(jnp.asarray(x), offs,
                                    backend="segmented",
                                    payload=jnp.arange(offs[-1],
                                                       dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(out2), _ref_sort(x, offs))


def test_segment_sort_spill_with_feature_dim_payload():
    # regression: the spill paths' take_along_axis must broadcast the
    # permutation over trailing feature dims ((N, F) leaves crashed)
    from repro.segmented import max_class_width

    mw = max_class_width(jnp.float32)
    offs = (0, 3, 3 + mw + 17)
    n = offs[-1]
    x = jnp.asarray(RNG.permutation(n).astype(np.float32))  # unique keys
    pay = {"emb": jnp.asarray(RNG.normal(size=(n, 3)).astype(np.float32)),
           "pos": jnp.arange(n, dtype=jnp.int32)}
    out, tree = repro.segment_sort(x, offs, backend="segmented", payload=pay)
    for a, b in zip(offs, offs[1:]):
        order = np.argsort(np.asarray(x[a:b]), kind="stable")
        np.testing.assert_array_equal(np.asarray(out[a:b]),
                                      np.asarray(x[a:b])[order])
        np.testing.assert_array_equal(np.asarray(tree["emb"][a:b]),
                                      np.asarray(pay["emb"][a:b])[order])
    # merge and topk spill loops share the broadcast helper
    ln = mw + 9
    a_v = jnp.asarray(np.sort(RNG.normal(size=(ln,)).astype(np.float32)))
    b_v = jnp.asarray(np.sort(RNG.normal(size=(ln,)).astype(np.float32)))
    pa = jnp.asarray(RNG.normal(size=(ln, 2)).astype(np.float32))
    pb = jnp.asarray(RNG.normal(size=(ln, 2)).astype(np.float32))
    out_m, tree_m, oo = repro.segment_merge(
        a_v, b_v, (0, ln), (0, ln), backend="segmented", payload=(pa, pb))
    assert tree_m.shape == (2 * ln, 2)
    vals, idx, ptree, oo2 = repro.segment_topk(
        x, offs, 5, backend="segmented",
        payload=jnp.asarray(RNG.normal(size=(n, 4)).astype(np.float32)))
    assert ptree.shape == (oo2[-1], 4)


@pytest.mark.parametrize("descending", [False, True])
def test_tie_convention_matches_between_kernel_and_reference(descending):
    # regression: descending used to mean reverse-of-stable-ascending in
    # the reference but stable-sort-of-flipped-keys in the kernels, so
    # perm/idx diverged on ties by platform. Both now use the flipped-key
    # stable convention. (Scope: stable sub-paths — widths below the
    # column-device cutover. Wider classes make no tie-order promise,
    # like the dense API without stable=True; values stay bit-identical.)
    x = jnp.asarray(np.array([1, 1, 1, 2, 2, 0, 0, 3], np.float32))
    offs = (0, 8)
    pay = jnp.arange(8, dtype=jnp.int32)
    out_k, perm_k = repro.segment_sort(x, offs, backend="segmented",
                                       descending=descending, payload=pay)
    prev = set_segmented_enabled(False)
    try:
        out_r, perm_r = repro.segment_sort(x, offs, descending=descending,
                                           payload=pay)
    finally:
        set_segmented_enabled(prev)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    np.testing.assert_array_equal(np.asarray(perm_k), np.asarray(perm_r))
    vk, ik, _ = repro.segment_topk(x, offs, 3, backend="segmented",
                                   descending=descending)
    prev = set_segmented_enabled(False)
    try:
        vr, ir, _ = repro.segment_topk(x, offs, 3, descending=descending)
    finally:
        set_segmented_enabled(prev)
    np.testing.assert_array_equal(np.asarray(vk), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))


# ---------------------------------------------------------------------------
# one pallas_call per size-class bucket (jaxpr-verified acceptance)
# ---------------------------------------------------------------------------


def test_each_size_class_is_single_pallas_call():
    # classes: width 4 (two members), 16, 32; plus one singleton (no call)
    offs = (0, 3, 6, 20, 52, 53)
    x = jnp.asarray(RNG.normal(size=(offs[-1],)).astype(np.float32))
    n = _n_pallas(
        lambda v: repro.segment_sort(v, offs, backend="segmented"), x)
    assert n == 3, n


def test_singleton_class_emits_no_network():
    offs = (0, 1, 2, 3)  # all length-1: pure layout, zero launches
    x = jnp.asarray(RNG.normal(size=(3,)).astype(np.float32))
    n = _n_pallas(
        lambda v: repro.segment_sort(v, offs, backend="segmented"), x)
    assert n == 0, n
    np.testing.assert_array_equal(
        np.asarray(repro.segment_sort(x, offs, backend="segmented")),
        np.asarray(x))


def test_mixed_k_topk_equal_vocab_is_one_launch():
    # the continuous-batching case: equal segment lengths, ragged k ->
    # a single size class -> one launch for the whole batch
    b, v = 4, 64
    offs = tuple(range(0, (b + 1) * v, v))
    x = jnp.asarray(RNG.normal(size=(b * v,)).astype(np.float32))
    n = _n_pallas(
        lambda t: repro.segment_topk(t, offs, (1, 8, 3, 64),
                                     backend="segmented"), x)
    assert n == 1, n


def test_reference_route_has_no_pallas_calls():
    offs = (0, 3, 6, 20)
    x = jnp.asarray(RNG.normal(size=(20,)).astype(np.float32))
    prev = set_segmented_enabled(False)
    try:
        dec = repro.plan(repro.SortSpec(
            op="sort", lengths=(20,), batch=3, device="tpu",
            segment_offsets=((0, 3, 6, 20),)))
        assert (dec.backend, dec.detail) == ("segmented", "reference")
        n = _n_pallas(lambda t: repro.segment_sort(t, offs), x)
        assert n == 0, n
    finally:
        set_segmented_enabled(prev)


def test_plan_routes_segmented_specs():
    spec = repro.SortSpec(op="sort", lengths=(20,), batch=3, device="tpu",
                          segment_offsets=((0, 3, 6, 20),))
    dec = repro.plan(spec)
    assert (dec.backend, dec.detail) == ("segmented", "bucketed_pallas")
    cpu = repro.plan(dataclasses.replace(spec, device="cpu"))
    assert (cpu.backend, cpu.detail) == ("segmented", "reference")
    # dense backends refuse segmented specs loudly
    with pytest.raises(ValueError):
        repro.plan(dataclasses.replace(spec, backend="schedule"))
    # and the decision table carries segmented rows
    rows = repro.decision_table(device="tpu")
    seg_rows = [r for r in rows if r["segments"]]
    assert seg_rows and all(r["backend"] == "segmented" for r in seg_rows)


# ---------------------------------------------------------------------------
# hypothesis ragged sweeps
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @st.composite
    def _offsets(draw, max_segments=7, max_len=33):
        lens = draw(st.lists(st.integers(0, max_len), min_size=0,
                             max_size=max_segments))
        offs = [0]
        for ln in lens:
            offs.append(offs[-1] + ln)
        return tuple(offs)

    @given(data=st.data())
    @settings(max_examples=25, deadline=None)
    def test_segment_sort_hypothesis_sweep(data):
        offs = data.draw(_offsets())
        descending = data.draw(st.booleans())
        backend = data.draw(st.sampled_from(["auto", "segmented"]))
        use_special = data.draw(st.booleans())
        x = RNG.normal(size=(offs[-1],)).astype(np.float32)
        if use_special and offs[-1]:
            spots = RNG.integers(0, offs[-1], size=min(4, offs[-1]))
            x[spots] = RNG.choice(
                [np.nan, np.inf, -np.inf]).astype(np.float32)
        out = repro.segment_sort(jnp.asarray(x), offs, backend=backend,
                                 descending=descending)
        np.testing.assert_array_equal(np.asarray(out),
                                      _ref_sort(x, offs, descending))

    @given(data=st.data())
    @settings(max_examples=20, deadline=None)
    def test_segment_topk_hypothesis_sweep(data):
        offs = data.draw(_offsets())
        n_segs = len(offs) - 1
        ks = tuple(data.draw(st.integers(0, 40)) for _ in range(n_segs))
        backend = data.draw(st.sampled_from(["auto", "segmented"]))
        x = RNG.normal(size=(offs[-1],)).astype(np.float32)
        vals, idx, oo = repro.segment_topk(jnp.asarray(x), offs, ks,
                                           backend=backend)
        for s, (o0, o1) in enumerate(zip(offs, offs[1:])):
            cnt = min(ks[s], o1 - o0)
            assert oo[s + 1] - oo[s] == cnt
            np.testing.assert_array_equal(
                np.asarray(vals[oo[s]:oo[s + 1]]),
                np.sort(x[o0:o1])[::-1][:cnt])

    @given(data=st.data())
    @settings(max_examples=20, deadline=None)
    def test_segment_merge_hypothesis_sweep(data):
        offs_a = data.draw(_offsets(max_segments=5, max_len=21))
        lens_b = tuple(data.draw(st.integers(0, 21))
                       for _ in range(len(offs_a) - 1))
        offs_b = (0,) + tuple(np.cumsum(lens_b).tolist())
        backend = data.draw(st.sampled_from(["auto", "segmented"]))
        a = RNG.normal(size=(offs_a[-1],)).astype(np.float32)
        b = RNG.normal(size=(offs_b[-1],)).astype(np.float32)
        for o0, o1 in zip(offs_a, offs_a[1:]):
            a[o0:o1] = np.sort(a[o0:o1])
        for o0, o1 in zip(offs_b, offs_b[1:]):
            b[o0:o1] = np.sort(b[o0:o1])
        out, oo = repro.segment_merge(jnp.asarray(a), jnp.asarray(b),
                                      offs_a, offs_b, backend=backend)
        for s in range(len(offs_a) - 1):
            ref = np.sort(np.concatenate([a[offs_a[s]:offs_a[s + 1]],
                                          b[offs_b[s]:offs_b[s + 1]]]))
            np.testing.assert_array_equal(
                np.asarray(out[oo[s]:oo[s + 1]]), ref)


# ---------------------------------------------------------------------------
# call-site integration: MoE ragged capacities + mixed-k serving
# ---------------------------------------------------------------------------


def _moe_cfg(moe):
    from repro.configs.base import ModelConfig

    return ModelConfig(name="t", family="moe", n_layers=1, d_model=16,
                       n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                       moe=moe)


def test_moe_uniform_ragged_capacities_bit_identical():
    from repro.configs.base import MoEConfig
    from repro.models.moe import moe_apply, moe_init

    base = MoEConfig(n_experts=4, top_k=2, d_expert=8, router_block=4,
                     capacity_factor=8.0, dispatch="sorted")
    cfg_u = _moe_cfg(base)
    t = 12
    x = jnp.asarray(RNG.normal(size=(1, t, 16)).astype(np.float32))
    p, _ = moe_init(jax.random.PRNGKey(1), cfg_u)
    y_u = moe_apply(p, x, cfg_u)
    cap = int(np.ceil(t * 2 / 4 * 8.0))
    cap = max(4, cap + (-cap) % 4)
    cfg_r = _moe_cfg(dataclasses.replace(base,
                                         expert_capacities=(cap,) * 4))
    y_r = moe_apply(p, x, cfg_r)
    np.testing.assert_array_equal(np.asarray(y_u), np.asarray(y_r))


def test_moe_ragged_capacities_drop_overflow_per_expert():
    from repro.configs.base import MoEConfig
    from repro.models.moe import moe_apply, moe_init

    base = MoEConfig(n_experts=4, top_k=2, d_expert=8, router_block=4,
                     capacity_factor=8.0, dispatch="scatter")
    x = jnp.asarray(RNG.normal(size=(1, 10, 16)).astype(np.float32))
    p, _ = moe_init(jax.random.PRNGKey(2), cfg := _moe_cfg(base))
    y_full = moe_apply(p, x, cfg)
    # big ragged capacities admit every token -> equals the uniform path
    cfg_big = _moe_cfg(dataclasses.replace(base,
                                           expert_capacities=(40,) * 4))
    np.testing.assert_array_equal(np.asarray(y_full),
                                  np.asarray(moe_apply(p, x, cfg_big)))
    # tiny ragged capacities still produce finite output of the right shape
    cfg_tiny = _moe_cfg(dataclasses.replace(base,
                                            expert_capacities=(4, 8, 4, 16)))
    y_tiny = moe_apply(p, x, cfg_tiny)
    assert y_tiny.shape == y_full.shape
    assert bool(jnp.isfinite(y_tiny).all())


def test_moe_sorted_dispatch_hatch_equivalence():
    # the oblivious grouping sort routes through segment_sort on TPU when
    # the escape hatch is open (executor elsewhere); toggling the hatch
    # must be output-invariant on every platform
    from repro.configs.base import MoEConfig
    from repro.models.moe import moe_apply, moe_init

    base = MoEConfig(n_experts=4, top_k=2, d_expert=8, router_block=4,
                     capacity_factor=8.0, dispatch="sorted")
    cfg = _moe_cfg(base)
    x = jnp.asarray(RNG.normal(size=(1, 8, 16)).astype(np.float32))
    p, _ = moe_init(jax.random.PRNGKey(3), cfg)
    y_seg = moe_apply(p, x, cfg)
    prev = set_segmented_enabled(False)
    try:
        y_ref = moe_apply(p, x, cfg)
    finally:
        set_segmented_enabled(prev)
    np.testing.assert_array_equal(np.asarray(y_seg), np.asarray(y_ref))


def test_moe_grouping_sort_kernel_route_matches_executor():
    # the exact sort the TPU route runs (forced segmented kernel over the
    # composite grouping keys, interpret mode here) must agree with the
    # schedule-executor sort the other platforms keep
    n = 24
    flat_e = jnp.asarray(RNG.integers(0, 4, (n,)), jnp.int32)
    keys = flat_e * n + jnp.arange(n, dtype=jnp.int32)
    pos = jnp.arange(n, dtype=jnp.int32)
    out_k, perm_k = repro.segment_sort(keys, (0, n), payload=pos,
                                       backend="segmented")
    out_s, perm_s = repro.sort(keys, payload=pos, backend="schedule")
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_s))
    np.testing.assert_array_equal(np.asarray(perm_k), np.asarray(perm_s))


def test_sample_topk_ragged_matches_uniform():
    from repro.serving.sample import sample_topk

    logits = jnp.asarray(RNG.normal(size=(4, 64)).astype(np.float32))
    key = jax.random.PRNGKey(0)
    uniform = sample_topk(key, logits, k=8)
    ragged = sample_topk(key, logits, k=(8, 8, 8, 8))
    np.testing.assert_array_equal(np.asarray(uniform), np.asarray(ragged))
    # per-request k=1 rows are the argmax; larger-k rows draw from their
    # own candidate prefix only
    mixed = sample_topk(key, logits, k=(1, 1, 16, 64), temperature=0.25)
    np.testing.assert_array_equal(
        np.asarray(mixed[:2]), np.asarray(jnp.argmax(logits[:2], -1)))
    for r in (2, 3):
        k_r = (1, 1, 16, 64)[r]
        top = set(np.argsort(np.asarray(logits[r]))[::-1][:k_r].tolist())
        assert int(mixed[r]) in top


def test_serve_config_accepts_per_request_topk():
    from repro.serving.engine import ServeConfig

    sc = ServeConfig(top_k=(4, 8, 16))
    assert tuple(sc.top_k) == (4, 8, 16)
