"""Chaos suite for the resilience subsystem (DESIGN.md §16).

Three layers under test: the deterministic failpoints themselves, the
circuit-breaker + degradation ladder in the dispatch layer, and the
serving engine's behavior under injected faults. The gating invariants:

* degraded answers are bit-identical to healthy ones (every rung of the
  ladder realizes the same function);
* whatever faults fire, the scheduler drains — every request terminal,
  no slot or page leaked;
* requests that complete under chaos emit the exact token stream of a
  fault-free run;
* with ``REPRO_FAILPOINTS`` unset the seams are invisible: jaxpr op
  counts and results are unchanged.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.resilience import (
    CircuitBreaker,
    FailpointError,
    arm,
    LadderSkip,
    ResilienceExhausted,
    breaker_for,
    breaker_states,
    configure_breakers,
    failpoint,
    failpoints,
    fires,
    hits,
    reset_breakers,
    reset_failpoints,
    run_ladder,
    rungs_for,
    set_resilience_enabled,
)
from repro.api.spec import SortSpec


@pytest.fixture(autouse=True)
def _clean_resilience():
    reset_failpoints()
    reset_breakers()
    yield
    reset_failpoints()
    reset_breakers()


# ---------------------------------------------------------------------------
# failpoints: trigger grammar, hierarchy, determinism
# ---------------------------------------------------------------------------


def test_failpoint_triggers():
    with failpoints({"a": "once"}):
        with pytest.raises(FailpointError):
            failpoint("a")
        failpoint("a")  # disarmed after the first fire
        assert hits("a") == 2 and fires("a") == 1
    with failpoints({"b": "times:2"}):
        for _ in range(2):
            with pytest.raises(FailpointError):
                failpoint("b")
        failpoint("b")
    with failpoints({"c": "every:3"}):
        failpoint("c")
        failpoint("c")
        with pytest.raises(FailpointError):
            failpoint("c")
        assert fires("c") == 1
    with failpoints({"d": "off"}):
        failpoint("d")
        assert hits("d") == 1 and fires("d") == 0


def test_failpoint_probability_is_seeded_deterministic():
    def pattern():
        out = []
        with failpoints({"p": "p:0.5:7"}):
            for _ in range(32):
                try:
                    failpoint("p")
                    out.append(0)
                except FailpointError:
                    out.append(1)
        return out

    a, b = pattern(), pattern()
    assert a == b
    assert 0 < sum(a) < 32  # actually probabilistic, not constant


def test_failpoint_hierarchical_prefix_match():
    with failpoints({"kernel.launch": "always"}):
        with pytest.raises(FailpointError):
            failpoint("kernel.launch.sort")
        failpoint("kernel.launcher")  # not a dot-boundary match
    # exact arming wins over a prefix
    with failpoints({"k": "always", "k.x": "off"}):
        failpoint("k.x")
        with pytest.raises(FailpointError):
            failpoint("k.y")


def test_failpoint_error_carries_name():
    with failpoints({"seam": "always"}):
        with pytest.raises(FailpointError) as ei:
            failpoint("seam.child")
    assert ei.value.name == "seam.child"


def test_failpoints_context_restores_previous_arming():
    arm("outer", "always")
    with failpoints({"outer": "off", "inner": "always"}):
        failpoint("outer")
    with pytest.raises(FailpointError):
        failpoint("outer")
    failpoint("inner")  # context arming gone


# ---------------------------------------------------------------------------
# circuit breaker state machine
# ---------------------------------------------------------------------------


def test_breaker_opens_after_threshold_and_probes():
    br = CircuitBreaker(("op", "rung", "cls"), threshold=3, cooldown_s=0.0)
    for _ in range(2):
        br.record_failure()
        assert br.state == "closed" and br.allow()
    br.record_failure()
    assert br.state == "open"
    # cooldown 0: the next allow() is the half-open probe
    assert br.allow()
    assert br.state == "half_open"
    assert not br.allow()  # only one probe in flight
    br.record_success()
    assert br.state == "closed" and br.failures == 0
    # reopen instantly from half-open on a failed probe
    for _ in range(3):
        br.record_failure()
    assert br.allow() and br.state == "half_open"
    br.record_failure()
    assert br.state == "open"


def test_breaker_cooldown_blocks_until_elapsed():
    br = CircuitBreaker(("op", "rung", "cls"), threshold=1, cooldown_s=3600.0)
    br.record_failure()
    assert br.state == "open"
    assert not br.allow() and not br.peek()


def test_breaker_peek_does_not_consume_probe():
    br = CircuitBreaker(("op", "rung", "cls"), threshold=1, cooldown_s=0.0)
    br.record_failure()
    assert br.peek() and br.state == "open"  # peek never transitions
    assert br.allow() and br.state == "half_open"


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------


def _merge_inputs(n=64):
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, n)),
                    jnp.float32)
    h = n // 2
    return (jnp.sort(x[:, :h], -1), jnp.sort(x[:, h:], -1),
            np.sort(np.asarray(x), -1))


def test_ladder_degrades_bit_identically():
    a, b, ref = _merge_inputs()
    with failpoints({"executor.run": "always", "kernel.launch": "always",
                     "fused.launch": "always"}):
        out = repro.merge(a, b)
    np.testing.assert_array_equal(np.asarray(out), ref)
    # the failed rung fed its breaker
    assert any(k[1] in ("schedule", "pallas", "fused")
               for k in breaker_states())


def test_ladder_explicit_backend_ask_propagates():
    a, b, _ = _merge_inputs()
    with failpoints({"executor.run": "always"}):
        with pytest.raises(FailpointError):
            repro.merge(a, b, backend="schedule")
    assert breaker_states() == {}  # explicit asks never feed breakers


def test_ladder_disabled_propagates_first_rung_failure():
    a, b, _ = _merge_inputs()
    prev = set_resilience_enabled(False)
    try:
        with failpoints({"executor.run": "always"}):
            with pytest.raises(FailpointError):
                repro.merge(a, b)
    finally:
        set_resilience_enabled(prev)


def test_ladder_exhaustion_chains_last_error():
    spec = SortSpec(op="merge", lengths=(8, 8))

    def attempt(rung):
        raise RuntimeError(f"boom {rung}")

    with pytest.raises(ResilienceExhausted) as ei:
        run_ladder(spec, ["schedule", "lax"], attempt)
    assert "boom lax" in str(ei.value.__cause__)


def test_ladder_skip_is_not_a_failure():
    spec = SortSpec(op="merge", lengths=(8, 8))
    seen = []

    def attempt(rung):
        seen.append(rung)
        if rung == "fused":
            raise LadderSkip
        return rung

    assert run_ladder(spec, ["fused", "schedule"], attempt) == "schedule"
    assert breaker_states() == {}  # a declined rung feeds no breaker


def test_ladder_forces_last_rung_when_all_blocked():
    spec = SortSpec(op="merge", lengths=(8, 8))
    configure_breakers(threshold=1, cooldown_s=3600.0)
    for rung in ("schedule", "lax"):
        breaker_for("merge", rung, "16v").record_failure()

    def attempt(rung):
        return f"ran {rung}"

    # an answer beats a refusal: the most degraded rung is force-run
    assert run_ladder(spec, ["schedule", "lax"], attempt,
                      cls="16v") == "ran lax"


def test_resilience_events_carry_op_rung_class_labels():
    """End-to-end label contract (DESIGN.md §17): ladder fallbacks,
    forced runs, and breaker transitions surface with (op, rung, cls)
    labels in the metric registry *and* as flight-recorder events, so
    dashboards and post-mortems can slice degradation by size class."""
    import repro.obs as obs
    from repro.obs import metrics, recorder, trace

    prev = obs.set_enabled(True)
    trace.clear()
    metrics.reset()
    recorder.clear()
    configure_breakers(threshold=1, cooldown_s=3600.0)
    spec = SortSpec(op="merge", lengths=(8, 8))
    try:
        def failing(rung):
            if rung == "schedule":
                raise RuntimeError("boom")
            return rung

        assert run_ladder(spec, ["schedule", "lax"], failing,
                          cls="16v") == "lax"
        assert metrics.counter("resilience.fallbacks").value(
            op="merge", rung="schedule", cls="16v",
            err="RuntimeError") == 1
        # threshold=1: the recorded failure opened the breaker
        assert metrics.counter("breaker.transitions").value(
            op="merge", rung="schedule", cls="16v", frm="closed",
            to="open") == 1
        assert metrics.gauge("breaker.state").value(
            op="merge", rung="schedule", cls="16v") is not None

        breaker_for("merge", "lax", "16v").record_failure()
        assert run_ladder(spec, ["schedule", "lax"],
                          lambda rung: f"ran {rung}",
                          cls="16v") == "ran lax"
        assert metrics.counter("resilience.forced").value(
            op="merge", rung="lax", cls="16v") == 1

        by_kind = {}
        for ev in recorder.events():
            by_kind.setdefault(ev.kind, []).append(ev)
        assert [e.name for e in by_kind["fallback"]] == \
            ["merge/schedule/16v"]
        assert by_kind["fallback"][0].attrs["err"] == "RuntimeError"
        assert [e.name for e in by_kind["forced"]] == ["merge/lax/16v"]
        assert {e.name for e in by_kind["breaker"]} == \
            {"merge/schedule/16v", "merge/lax/16v"}
        assert by_kind["breaker"][0].attrs["to"] == "open"
    finally:
        trace.clear()
        metrics.reset()
        recorder.clear()
        obs.set_enabled(prev)


def test_open_breaker_reroutes_at_plan_time():
    a, b, ref = _merge_inputs()
    with failpoints({"executor.run": "always"}):
        for _ in range(3):  # DEFAULT_THRESHOLD failures open the breaker
            repro.merge(a, b)
    spec = SortSpec(op="merge", lengths=(32, 32))
    dec = repro.plan(spec)
    assert dec.source == "breaker" and dec.backend != "schedule"
    # and the op keeps answering, bit-identically, with no faults armed
    np.testing.assert_array_equal(np.asarray(repro.merge(a, b)), ref)


def test_rungs_for_shapes():
    spec = SortSpec(op="merge", lengths=(32, 32))
    dec = repro.plan(spec)
    rungs = rungs_for(spec, dec)
    assert rungs[0] == dec.backend and rungs[-1] == "lax"
    # explicit ask: exactly the named backend
    spec_x = SortSpec(op="merge", lengths=(32, 32), backend="lax")
    assert rungs_for(spec_x, repro.plan(spec_x)) == ["lax"]


def test_segmented_kernel_degrades_to_reference():
    """Unit-level: the segmented backend's kernel→reference degradation
    (the synthetic ``segmented_kernel`` rung) retries on the reference
    path, feeds the breaker, and skips the kernel once it opens."""
    from repro.api.ops import _segmented_degrade
    from repro.resilience.ladder import spec_class

    spec = SortSpec(op="sort", lengths=(16,),
                    segment_offsets=((0, 7, 16),))
    calls = []

    def call(use_kernel):
        calls.append(use_kernel)
        if use_kernel:
            raise RuntimeError("kernel boom")
        return "ref"

    assert _segmented_degrade(spec, call, True) == "ref"
    assert calls == [True, False]
    key = ("sort", "segmented_kernel", spec_class(spec))
    assert key in breaker_states()
    _segmented_degrade(spec, call, True)
    _segmented_degrade(spec, call, True)  # third failure opens the breaker
    calls.clear()
    assert _segmented_degrade(spec, call, True) == "ref"
    assert calls == [False], "open breaker must skip the kernel attempt"


def test_segment_sort_answers_under_spill_faults():
    vals = np.asarray(np.random.default_rng(3).standard_normal(24),
                      np.float32)
    offs = (0, 5, 12, 24)
    ref = np.concatenate([np.sort(vals[i:j]) for i, j in zip(offs, offs[1:])])
    with failpoints({"segmented.spill": "always"}):
        out = repro.segment_sort(vals, offs)
    np.testing.assert_array_equal(np.asarray(out), ref)


# ---------------------------------------------------------------------------
# zero overhead when disarmed
# ---------------------------------------------------------------------------


def _eqn_count(fn, *args) -> int:
    def walk(jaxpr):
        n = 0
        for eqn in jaxpr.eqns:
            n += 1
            if eqn.primitive.name == "pallas_call":
                continue
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    n += walk(v.jaxpr)
                elif isinstance(v, (list, tuple)):
                    for vi in v:
                        if hasattr(vi, "jaxpr"):
                            n += walk(vi.jaxpr)
        return n

    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


def test_failpoints_unset_zero_jaxpr_overhead():
    """The seams live on the Python side: with nothing armed (and even
    with an armed-but-off failpoint) the traced program is unchanged."""
    if os.environ.get("REPRO_FAILPOINTS"):
        pytest.skip("needs REPRO_FAILPOINTS unset")
    a, b, _ = _merge_inputs()

    def fn():
        return repro.merge(a, b)

    ops_off = _eqn_count(fn)
    val_off = np.asarray(jax.jit(fn)())
    with failpoints({"executor.run": "off", "kernel.launch": "off"}):
        ops_armed = _eqn_count(fn)
        val_armed = np.asarray(jax.jit(fn)())
    assert ops_armed == ops_off, "failpoint seams changed the jaxpr"
    np.testing.assert_array_equal(val_armed, val_off)


# ---------------------------------------------------------------------------
# autotune cache: quarantine, concurrent writers, store failures
# ---------------------------------------------------------------------------


def test_cache_quarantines_corrupt_json(tmp_path):
    from repro.streaming.cache import AutotuneCache

    path = str(tmp_path / "autotune.json")
    with open(path, "w") as f:
        f.write('{"torn": ')
    c = AutotuneCache(path=path)
    assert len(c) == 0
    assert os.path.exists(path + ".bad") and not os.path.exists(path)
    c.put("merge|8x8|k-|float32|cpu", {"block_batch": 8})
    assert AutotuneCache(path=path).get("merge|8x8|k-|float32|cpu") is not None


def test_cache_concurrent_writers_merge(tmp_path):
    from repro.streaming.cache import AutotuneCache

    path = str(tmp_path / "autotune.json")
    c1 = AutotuneCache(path=path)
    c2 = AutotuneCache(path=path)  # loaded before c1 writes
    c1.put("k1", {"v": 1})
    c2.put("k2", {"v": 2})  # must not clobber c1's entry
    c3 = AutotuneCache(path=path)
    assert c3.get("k1") is not None and c3.get("k2") is not None


def test_cache_store_failure_degrades_to_memory(tmp_path):
    from repro.streaming.cache import AutotuneCache

    c = AutotuneCache(path=str(tmp_path / "autotune.json"))
    with failpoints({"cache.store": "always"}):
        c.put("k", {"v": 1})  # must not raise
    assert c.get("k") is not None  # in-memory entry survives
    assert AutotuneCache(path=c.path).get("k") is None  # never hit disk
    c.put("k2", {"v": 2})
    assert AutotuneCache(path=c.path).get("k") is not None  # flushed now


def test_cache_load_failure_starts_empty(tmp_path):
    from repro.streaming.cache import AutotuneCache

    path = str(tmp_path / "autotune.json")
    AutotuneCache(path=path).put("k", {"v": 1})
    with failpoints({"cache.load": "always"}):
        c = AutotuneCache(path=path)
    assert len(c) == 0
    assert os.path.exists(path)  # load failure is not corruption: no .bad


# ---------------------------------------------------------------------------
# serving under failure
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def model():
    from repro.configs import get_smoke_config
    from repro.models import model_init

    cfg = get_smoke_config("chatglm3-6b")
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _specs():
    from repro.serving.scheduler import SamplingParams

    return [
        (5, SamplingParams(k=8, temperature=1.0, max_new_tokens=5, seed=11), 0),
        (9, SamplingParams(k=1, temperature=1.0, max_new_tokens=4, seed=33), 0),
        (3, SamplingParams(k=4, top_p=0.9, temperature=0.7, max_new_tokens=4,
                           seed=22), 1),
    ]


def _prompts(cfg, specs):
    rng = np.random.default_rng(1)
    return [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
            for n, _, _ in specs]


def _engine(cfg, params, **kw):
    from repro.serving.scheduler import ScheduledEngine, SchedulerConfig

    kw.setdefault("retry_backoff_s", 0.0)
    kw.setdefault("n_slots", 2)
    sched = SchedulerConfig(page_size=8, pages_per_slot=4, **kw)
    return ScheduledEngine(params, cfg, sched)


def _drain_invariants(eng):
    from repro.serving.scheduler.request import TERMINAL_STATES

    assert all(r.state in TERMINAL_STATES for r in eng.requests.values()), \
        {rid: r.state for rid, r in eng.requests.items()}
    assert not len(eng.queue) and not eng.active
    assert eng.slots.free_slot_count == eng.sc.n_slots, "leaked slot"
    # page 0 is the reserved scratch page, never allocatable
    assert eng.slots.free_page_count == eng.pool.n_pages - 1, "leaked pages"


def _oracle(cfg, params, specs, prompts):
    eng = _engine(cfg, params)
    rids = [eng.submit(p, sp, arrival=a)
            for p, (_, sp, a) in zip(prompts, specs)]
    return eng.run(), rids


def test_transient_faults_retry_to_completion(model):
    """One injected failure per launch kind: the bounded retry absorbs
    it and every request still matches the fault-free run bit-for-bit."""
    cfg, params = model
    specs, prompts = _specs(), _prompts(cfg, _specs())
    ref, ref_rids = _oracle(cfg, params, specs, prompts)
    eng = _engine(cfg, params)
    rids = [eng.submit(p, sp, arrival=a)
            for p, (_, sp, a) in zip(prompts, specs)]
    with failpoints({"sched.prefill": "once", "sched.insert": "once",
                     "sched.decode": "once"}):
        out = eng.run()
    _drain_invariants(eng)
    assert sorted(out) == sorted(ref_rids)
    for rid, ref_rid in zip(rids, ref_rids):
        np.testing.assert_array_equal(out[rid], ref[ref_rid])


def test_persistent_prefill_fault_fails_batch_and_drains(model):
    cfg, params = model
    from repro.serving.scheduler import RequestState

    specs, prompts = _specs(), _prompts(cfg, _specs())
    eng = _engine(cfg, params, max_retries=1)
    rids = [eng.submit(p, sp, arrival=a)
            for p, (_, sp, a) in zip(prompts, specs)]
    with failpoints({"sched.prefill": "always"}):
        out = eng.run()
    _drain_invariants(eng)
    assert out == {}
    for rid in rids:
        r = eng.requests[rid]
        assert r.state is RequestState.FAILED and "prefill" in r.error


def test_persistent_decode_fault_fails_active_and_drains(model):
    cfg, params = model
    from repro.serving.scheduler import RequestState

    specs, prompts = _specs(), _prompts(cfg, _specs())
    eng = _engine(cfg, params, max_retries=0)
    [eng.submit(p, sp, arrival=a)
     for p, (_, sp, a) in zip(prompts, specs)]
    with failpoints({"sched.decode": "always"}):
        eng.run()
    _drain_invariants(eng)
    states = {r.state for r in eng.requests.values()}
    assert states <= {RequestState.FAILED, RequestState.DONE}
    assert RequestState.FAILED in states


def test_seeded_chaos_drains_and_completions_match_oracle(model):
    """The headline gate: under seeded probabilistic faults across every
    scheduler seam, the engine drains with no leaks, and whatever
    completed is bit-identical to the fault-free run."""
    cfg, params = model
    specs, prompts = _specs(), _prompts(cfg, _specs())
    ref, ref_rids = _oracle(cfg, params, specs, prompts)
    eng = _engine(cfg, params, max_retries=1)
    rids = [eng.submit(p, sp, arrival=a)
            for p, (_, sp, a) in zip(prompts, specs)]
    with failpoints({"sched": "p:0.25:13"}):
        out = eng.run()
    _drain_invariants(eng)
    for rid, ref_rid in zip(rids, ref_rids):
        if rid in out:
            np.testing.assert_array_equal(out[rid], ref[ref_rid])


def test_ttl_ticks_times_out_running_request(model):
    cfg, params = model
    from repro.serving.scheduler import RequestState, SamplingParams

    eng = _engine(cfg, params)
    prompt = _prompts(cfg, _specs())[0]
    rid_t = eng.submit(prompt, SamplingParams(k=8, max_new_tokens=12, seed=1,
                                              ttl_ticks=2), arrival=0)
    rid_ok = eng.submit(prompt, SamplingParams(k=8, max_new_tokens=3, seed=2),
                        arrival=0)
    out = eng.run()
    _drain_invariants(eng)
    r = eng.requests[rid_t]
    assert r.state is RequestState.TIMED_OUT and rid_t not in out
    assert 0 < len(r.tokens) < 12  # it ran, then the deadline cut it
    # the survivor is untouched by its neighbor's timeout
    ref, _ = _oracle(cfg, params,
                     [(len(prompt), SamplingParams(k=8, max_new_tokens=3,
                                                   seed=2), 0)], [prompt])
    np.testing.assert_array_equal(out[rid_ok], ref[0])


def test_ttl_ticks_times_out_queued_request(model):
    cfg, params = model
    from repro.serving.scheduler import RequestState, SamplingParams

    # one slot: the blocker occupies it, the TTL request expires queued
    eng = _engine(cfg, params, n_slots=1)
    prompt = _prompts(cfg, _specs())[0]
    blocker = eng.submit(prompt, SamplingParams(max_new_tokens=8, seed=5),
                         arrival=0)
    rid = eng.submit(prompt, SamplingParams(max_new_tokens=2, ttl_ticks=2),
                     arrival=0)
    out = eng.run()
    _drain_invariants(eng)
    r = eng.requests[rid]
    assert r.state is RequestState.TIMED_OUT
    assert r.slot is None and not r.tokens  # never admitted, nothing held
    assert blocker in out and len(out[blocker]) == 8


def test_queue_full_rejects_with_retry_hint(model):
    cfg, params = model
    from repro.serving.scheduler import QueueFull, RequestState, SamplingParams

    eng = _engine(cfg, params, max_queue=2)
    prompt = _prompts(cfg, _specs())[0]
    eng.submit(prompt, SamplingParams(max_new_tokens=2), arrival=0)
    eng.submit(prompt, SamplingParams(max_new_tokens=2), arrival=0)
    with pytest.raises(QueueFull) as ei:
        eng.submit(prompt, SamplingParams(max_new_tokens=2), arrival=0)
    assert ei.value.depth == 2 and ei.value.max_queue == 2
    assert ei.value.retry_after_ticks >= 1
    rejected = [r for r in eng.requests.values()
                if r.state is RequestState.REJECTED]
    assert len(rejected) == 1 and "full" in rejected[0].error
    out = eng.run()  # the two admitted requests drain normally
    _drain_invariants(eng)
    assert len(out) == 2
