"""Distributed sample-sort tests.

Multi-device cases run in a subprocess with 8 forced host devices (same
pattern as test_sharding.py) so the main pytest process keeps its
single-device view. Fast in-process tests cover the planner rows, the
divisibility gate, and the P=1 degenerate pipeline.
"""
import json
import subprocess
import sys

import numpy as np
import pytest

MULTIDEV_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
import jax.numpy as jnp

import repro
from repro.parallel.sharding import make_parallelism
from repro.parallel.dist_sort import sample_merge_k, sample_sort

mesh = jax.make_mesh((1, 8), ("data", "model"))
par = make_parallelism(mesh)
rng = np.random.default_rng(7)
res = {"n_devices": jax.device_count()}

# --- direct sample_sort: values, perm, ties, int32 extremes -----------------
ii = np.iinfo(np.int32)
xi = jnp.asarray(rng.integers(0, 4, (3, 128)), jnp.int32)
xi = xi.at[0, 5].set(ii.max).at[1, 7].set(ii.min).at[2, :].set(ii.max)
pos = jnp.broadcast_to(jnp.arange(128, dtype=jnp.int32), (3, 128))
out, perm = sample_sort(xi, mesh=mesh, axis_name="model", pos=pos)
res["direct_values_ok"] = bool(
    (np.asarray(out) == np.sort(np.asarray(xi), -1)).all())
res["direct_perm_is_permutation"] = bool(
    (np.sort(np.asarray(perm), -1) == np.arange(128)).all())
res["direct_perm_reproduces"] = bool(
    (np.take_along_axis(np.asarray(xi), np.asarray(perm), -1)
     == np.asarray(out)).all())

# --- public API, explicit backend, float with NaN/inf -----------------------
x = jnp.asarray(rng.standard_normal((2, 4096)), jnp.float32)
x = x.at[0, 3].set(np.nan).at[1, 11].set(np.inf).at[0, 100].set(-np.inf)
d = repro.sort(x, backend="sharded", par=par)
s = repro.sort(x)
res["sort_nan_inf_bit_identical"] = bool(
    np.array_equal(np.asarray(d), np.asarray(s), equal_nan=True)
    and np.array_equal(np.asarray(d), np.sort(np.asarray(x), -1),
                       equal_nan=True))

# --- descending + stable + pytree payload, bit-identical --------------------
xs = jnp.asarray(rng.integers(0, 64, (2, 4096)), jnp.int32)
pl = {"q": jnp.broadcast_to(jnp.arange(4096, dtype=jnp.int32), (2, 4096)),
      "f": jnp.asarray(rng.standard_normal((2, 4096, 2)), jnp.float32)}
o_d, t_d = repro.sort(xs, descending=True, stable=True, payload=pl,
                      backend="sharded", par=par)
o_s, t_s = repro.sort(xs, descending=True, stable=True, payload=pl)
res["stable_payload_bit_identical"] = bool(
    np.array_equal(np.asarray(o_d), np.asarray(o_s))
    and np.array_equal(np.asarray(t_d["q"]), np.asarray(t_s["q"]))
    and np.array_equal(np.asarray(t_d["f"]), np.asarray(t_s["f"])))

# --- merge_k with ragged list lengths ---------------------------------------
lists = [jnp.sort(jnp.asarray(rng.integers(0, 1000, (2, n)), jnp.int32), -1)
         for n in (24, 64, 40)]
out, _ = sample_merge_k(lists, mesh=mesh, axis_name="model")
ref = np.sort(np.concatenate([np.asarray(l) for l in lists], -1), -1)
res["merge_k_ragged_ok"] = bool((np.asarray(out) == ref).all())

m_d = repro.merge_k(lists, backend="sharded", par=par)
m_s = repro.merge_k(lists)
res["merge_k_api_bit_identical"] = bool(
    np.array_equal(np.asarray(m_d), np.asarray(m_s)))

# --- auto routing past the threshold (values vs np reference) ---------------
big = jnp.asarray(rng.standard_normal((1, 16384)), jnp.float32)
from repro.api.dispatch import plan
from repro.api.spec import SortSpec
dec = plan(SortSpec(op="sort", lengths=(16384,), batch=1, sharded=True))
res["auto_backend"] = dec.backend
res["auto_detail"] = dec.detail
d = repro.sort(big, par=par)
res["auto_sort_ok"] = bool(
    np.array_equal(np.asarray(d), np.sort(np.asarray(big), -1)))

# --- sampler wiring: exact nucleus over a TP-sharded vocab ------------------
# vocab 8192 = the routing threshold: big enough for the sharded row, small
# enough that the single-device reference ranking stays affordable on CPU
from repro.serving.sample import sample_topp
logits = jnp.asarray(rng.standard_normal((2, 8192)), jnp.float32)
tok_d = sample_topp(jax.random.PRNGKey(0), logits, k_max=None, par=par)
tok_s = sample_topp(jax.random.PRNGKey(0), logits, k_max=None)
res["sampler_exact_nucleus_identical"] = bool(
    np.array_equal(np.asarray(tok_d), np.asarray(tok_s)))

print(json.dumps(res))
"""


@pytest.mark.slow
def test_dist_sort_multidevice_bit_identical():
    out = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SNIPPET],
        capture_output=True, text=True, timeout=1100,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests", 1)[0],
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_devices"] == 8
    assert res["auto_backend"] == "sharded"
    assert res["auto_detail"] == "sample_sort"
    for key, val in res.items():
        if key.endswith(("_ok", "_identical", "_is_permutation", "_reproduces")):
            assert val is True, (key, res)


# ---------------------------------------------------------------------------
# fast in-process coverage (single device)
# ---------------------------------------------------------------------------


def test_plan_routes_sharded_sort_and_merge_k():
    from repro.api.dispatch import plan
    from repro.api.spec import SortSpec

    dec = plan(SortSpec(op="sort", lengths=(1 << 20,), sharded=True))
    assert (dec.backend, dec.detail) == ("sharded", "sample_sort")
    dec = plan(SortSpec(op="merge_k", lengths=(50_000,) * 4, sharded=True))
    assert (dec.backend, dec.detail) == ("sharded", "sample_merge_k")
    # below the threshold the single-device ladder stays in charge
    dec = plan(SortSpec(op="sort", lengths=(1024,), sharded=True))
    assert dec.backend == "schedule"
    # payload/stable specs still shard (pos rides the exchanges)
    dec = plan(SortSpec(op="merge_k", lengths=(50_000,) * 4, sharded=True,
                        has_payload=True))
    assert dec.backend == "sharded"
    # non-LOMS network asks never silently shard
    dec = plan(SortSpec(op="sort", lengths=(1 << 20,), sharded=True,
                        network="batcher-bitonic"))
    assert dec.backend == "schedule"


def test_decision_table_contains_sharded_sort_rows():
    import repro

    rows = repro.decision_table(device="cpu")
    picked = {(r["op"], r["backend"]) for r in rows if r["sharded"]}
    assert ("sort", "sharded") in picked
    assert ("merge_k", "sharded") in picked
    assert ("topk", "sharded") in picked


def test_dist_sort_axis_divisibility_gate():
    from repro.parallel.sharding import dist_sort_axis

    class FakePar:
        tp_size = 8
        tp_axis = "model"

    assert dist_sort_axis(FakePar(), (4096,)) == "model"
    assert dist_sort_axis(FakePar(), (4096, 1024)) == "model"
    assert dist_sort_axis(FakePar(), (4095,)) is None  # not divisible
    assert dist_sort_axis(FakePar(), (4096, 12)) is None  # 12 % 8 != 0...
    assert dist_sort_axis(FakePar(), (4,)) is None  # shorter than the axis
    assert dist_sort_axis(None, (4096,)) is None

    class NoTP:
        tp_size = 1
        tp_axis = "model"

    assert dist_sort_axis(NoTP(), (4096,)) is None


def test_sample_sort_single_device_mesh_degenerates_cleanly():
    """P=1: the full pipeline (splitters, exchanges, rebalance) must be an
    identity wrapper around the local LOMS sort."""
    import jax
    import jax.numpy as jnp
    from repro.parallel.dist_sort import sample_merge_k, sample_sort

    mesh = jax.make_mesh((1,), ("model",))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 50, (2, 12)), jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(12, dtype=jnp.int32), (2, 12))
    out, perm = sample_sort(x, mesh=mesh, axis_name="model", pos=pos)
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x), -1))
    np.testing.assert_array_equal(
        np.take_along_axis(np.asarray(x), np.asarray(perm), -1),
        np.asarray(out))
    lists = [jnp.sort(jnp.asarray(rng.integers(0, 9, (2, n)), jnp.int32), -1)
             for n in (5, 3, 7)]
    out, _ = sample_merge_k(lists, mesh=mesh, axis_name="model")
    ref = np.sort(np.concatenate([np.asarray(l) for l in lists], -1), -1)
    np.testing.assert_array_equal(np.asarray(out), ref)
