"""Pipeline-parallel schedule correctness (4 stages, subprocess devices)."""
import json
import subprocess
import sys

import numpy as np
import pytest

SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.pipeline import pipeline_apply

mesh = jax.make_mesh((4,), ("pipe",))
rng = np.random.default_rng(0)
n_stages, n_micro, mb, d = 4, 6, 2, 8
ws = jnp.asarray(rng.standard_normal((n_stages, d, d)) * 0.3, jnp.float32)
x = jnp.asarray(rng.standard_normal((n_micro, mb, d)), jnp.float32)


def stage_fn(p, h):
    return jnp.tanh(h @ p["w"])


got = pipeline_apply(stage_fn, {"w": ws}, x, mesh, axis="pipe")

ref = x
for i in range(n_stages):
    ref = stage_fn({"w": ws[i]}, ref.reshape(-1, d)).reshape(ref.shape)
err = float(jnp.abs(got - ref).max())
print(json.dumps({"err": err}))
"""


@pytest.mark.slow
def test_pipeline_matches_sequential():
    out = subprocess.run(
        [sys.executable, "-c", SNIPPET],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests", 1)[0],
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-6, res
