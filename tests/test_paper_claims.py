"""EXPERIMENTS.md §Paper-validation: the paper's claims C1-C6 as tests."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.api.schedules import merge_schedule
from repro.core import (comparator_count, depth, loms_2way, loms_kway,
                        loms_median, table1_stages, validate_01_merge)
from repro.core.metrics import lut_proxy, series_levels, vmem_bytes
from repro.core.mwms import mwms_kway, mwms_median


def test_C1_loms_2way_always_two_stages_any_mixture():
    for m, n in [(1, 1), (1, 8), (8, 1), (7, 5), (3, 14), (32, 32), (9, 2)]:
        s = loms_2way(m, n)
        assert depth(s) == 2
        assert validate_01_merge(s, (m, n))
    # Batcher needs log2(m+n) stages and only handles equal powers of two
    assert depth(merge_schedule(32, 32, "batcher-oe")) == 6
    with pytest.raises(ValueError):
        merge_schedule(7, 5, "batcher-oe")


def test_C2_table1_stage_counts():
    for k in range(2, 9):
        s = loms_kway(tuple([3] * k))
        assert depth(s) == table1_stages(k), k


def test_C3_3way_vs_mwms():
    full = loms_kway((7, 7, 7))
    med, _ = loms_median((7, 7, 7))
    assert depth(full) == 3 and depth(med) == 2
    # published MWMS: 5 full / 4 median; our best reconstruction: 6 / 5
    assert depth(mwms_kway((7, 7, 7))) >= 5
    assert depth(mwms_median((7, 7, 7))[0]) >= 4


def test_C4_resource_ranking():
    for m in (8, 16, 32, 64):
        c_s2ms = comparator_count(merge_schedule(m, m, "s2ms"))
        c_loms = comparator_count(merge_schedule(m, m, "loms"))
        c_oems = comparator_count(merge_schedule(m, m, "batcher-oe"))
        assert c_oems < c_loms < c_s2ms  # paper Figs. 13/17 ordering
    # LUT proxy: LOMS beats S2MS from 32 outputs up (the paper's resource
    # advantage is for the LARGER devices, Fig. 17; tiny S2MS are cheap)
    for m in (32, 64, 128):
        assert (lut_proxy(merge_schedule(m, m, "loms"), 32) <
                lut_proxy(merge_schedule(m, m, "s2ms"), 32))


def test_C4_placement_analog_s2ms_doesnt_fit():
    # paper: UP-256/DN-256 S2MS did not place in the FPGA; the 8-column
    # LOMS did. VMEM analog: with a 2 MiB working-set budget per sorter
    # instance (16 MiB VMEM shared across ~8 concurrent instances for
    # pipelining), the flat S2MS-256 cloud does not fit; LOMS 8-col does —
    # and the gap is ~8x, the structural point of the paper's Fig. 10.
    budget = 2 * 2**20
    s2 = vmem_bytes(merge_schedule(256, 256, "s2ms"), 32, 8)
    lo = vmem_bytes(loms_2way(256, 256, n_cols=8), 32, 8)
    assert s2 > budget > lo
    assert s2 > 4 * lo


def test_C5_obliviousness_fixed_schedule():
    # the schedule is static: same comparator count/depth regardless of data;
    # and the 4insLUT mode costs one extra series level (paper §VI-A)
    s = loms_2way(16, 16)
    assert series_levels(s, "4insLUT") == series_levels(s, "2insLUT") + depth(s)


def test_C6_depth_speed_ordering():
    # structural delay ordering: S2MS < LOMS < Batcher for every size
    for m in (4, 8, 16, 32, 64, 128):
        assert (depth(merge_schedule(m, m, "s2ms"))
                < depth(merge_schedule(m, m, "loms"))
                < depth(merge_schedule(m, m, "batcher-bitonic")))


def test_paper_headline_22_speedup_depth_analog():
    # "UP-32/DN-32 ... speedup of 2.63 versus Batcher": depth analog is
    # 6 stages (Batcher 64-output) vs 2 (LOMS) = 3.0x structural; the
    # measured FPGA 2.63x sits between depth ratio and per-stage overheads.
    d_ratio = depth(merge_schedule(32, 32, "batcher-oe")) / depth(
        merge_schedule(32, 32, "loms"))
    assert d_ratio == 3.0
