"""Request scheduler + paged KV-cache slots (repro.serving.scheduler).

The load-bearing test is the bit-equality oracle: every request served
through the continuous-batching scheduler — whatever its slot, batch
composition, or arrival tick — produces the exact token stream of a solo
one-shot ``generate()`` with ``cache_len`` equal to the slot capacity.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import init_cache, model_init
from repro.serving.engine import ServeConfig, generate
from repro.serving.scheduler import (
    AdmissionQueue, PagedKVCache, SamplingParams, ScheduledEngine,
    SchedulerConfig, SlotManager,
)
from repro.serving.scheduler.paged import gather_view
from repro.serving.scheduler.request import Request

RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("chatglm3-6b")
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mixed_specs():
    """Distinct (k, top_p, temperature, seed, arrival) per request —
    greedy, plain top-k, nucleus, and mixed arrival ticks."""
    return [
        (5, SamplingParams(k=8, temperature=1.0, max_new_tokens=6, seed=11), 0),
        (11, SamplingParams(k=4, top_p=0.9, temperature=0.7, max_new_tokens=5, seed=22), 0),
        (9, SamplingParams(k=1, temperature=1.0, max_new_tokens=4, seed=33), 1),
        (3, SamplingParams(k=16, top_p=0.8, temperature=1.3, max_new_tokens=7, seed=44), 3),
        (7, SamplingParams(k=8, temperature=0.0, max_new_tokens=6, seed=55), 3),
    ]


def _prompts(cfg, specs):
    rng = np.random.default_rng(1)
    return [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
            for n, _, _ in specs]


def _run_scheduled(cfg, params, specs, prompts, **sched_kw):
    sched = SchedulerConfig(n_slots=2, page_size=8, pages_per_slot=4,
                            **sched_kw)
    eng = ScheduledEngine(params, cfg, sched)
    rids = [eng.submit(p, sp, arrival=a)
            for p, (_, sp, a) in zip(prompts, specs)]
    return eng.run(), rids, sched


# ---------------------------------------------------------------------------
# the oracle: scheduled == solo, bit for bit
# ---------------------------------------------------------------------------


def test_scheduler_bit_identical_to_solo_generate(model):
    cfg, params = model
    specs = _mixed_specs()
    prompts = _prompts(cfg, specs)
    out, rids, sched = _run_scheduled(cfg, params, specs, prompts)
    for rid, p, (_, sp, _) in zip(rids, prompts, specs):
        sc = ServeConfig(max_new_tokens=sp.max_new_tokens, top_k=sp.k,
                         top_p=sp.top_p, temperature=sp.temperature,
                         seed=sp.seed, cache_len=sched.slot_capacity)
        solo = generate(params, {"tokens": p[None]}, cfg, sc)["tokens"][0]
        np.testing.assert_array_equal(out[rid], solo)


def test_scheduler_deterministic_across_slot_order(model):
    """Same seeds + arrival trace => bit-identical tokens no matter which
    free slot each request lands in (fifo vs lifo reuse), including
    mixed-k / mixed-top-p batches."""
    cfg, params = model
    specs = _mixed_specs()
    prompts = _prompts(cfg, specs)
    out_a, rids_a, _ = _run_scheduled(cfg, params, specs, prompts,
                                      slot_order="fifo")
    out_b, rids_b, _ = _run_scheduled(cfg, params, specs, prompts,
                                      slot_order="lifo")
    assert rids_a == rids_b
    for rid in rids_a:
        np.testing.assert_array_equal(out_a[rid], out_b[rid])


def test_scheduler_rerun_is_bitwise_stable(model):
    cfg, params = model
    specs = _mixed_specs()[:3]
    prompts = _prompts(cfg, specs)
    out_a, rids, _ = _run_scheduled(cfg, params, specs, prompts)
    out_b, _, _ = _run_scheduled(cfg, params, specs, prompts)
    for rid in rids:
        np.testing.assert_array_equal(out_a[rid], out_b[rid])


# ---------------------------------------------------------------------------
# paged pool: gather == contiguous, insert round-trips
# ---------------------------------------------------------------------------


def test_gather_view_matches_contiguous_cache(model):
    """A slot's gathered page view is bit-identical to the same K/V laid
    out contiguously."""
    cfg, _ = model
    ps, npg, ns = 8, 4, 3
    pool = PagedKVCache(cfg, n_pages=1 + ns * npg, page_size=ps)
    rng = np.random.default_rng(3)
    # fill every non-scratch page with random values
    leaves = {}
    for name, leaf in pool.leaves.items():
        arr = rng.standard_normal(leaf.shape).astype(np.float32)
        arr[:, 0] = 0.0  # scratch page stays zeros
        leaves[name] = jnp.asarray(arr, leaf.dtype)
    pt = np.arange(1, 1 + ns * npg, dtype=np.int32).reshape(ns, npg)
    lengths = jnp.asarray(np.asarray([5, 17, 32], np.int32))
    view = gather_view(leaves, jnp.asarray(pt), lengths, ps)
    for name, leaf in leaves.items():
        got = np.asarray(view[name])
        # dense reference: concatenate each slot's pages along the seq axis
        seq_ax = {"k": -1, "v": -2}[name] + leaf.ndim  # pool axis
        rows = [np.concatenate([np.asarray(leaf[:, pid]) for pid in pt[s]],
                               axis=seq_ax - 1)  # row layout drops page axis
                for s in range(ns)]
        ref = np.stack(rows, axis=1)
        np.testing.assert_array_equal(got, ref)
    assert view["pos"].shape == (pool.n_layers, ns)
    np.testing.assert_array_equal(np.asarray(view["pos"][0]), [5, 17, 32])


def test_insert_then_gather_roundtrips_prefill_cache(model):
    """Prefill a prompt, insert its cache row into slot pages, gather the
    slot back — the valid prefix must equal the contiguous prefill cache
    bit for bit."""
    cfg, params = model
    ps, npg = 8, 4
    plen = 13
    toks = jnp.asarray(RNG.integers(1, cfg.vocab_size, (1, plen)), jnp.int32)
    cache = init_cache(cfg, 1, ps * npg)
    from repro.models import prefill
    _, cache = jax.jit(lambda p, b, c: prefill(p, b, c, cfg=cfg))(
        params, {"tokens": toks}, cache)

    eng = ScheduledEngine(params, cfg,
                          SchedulerConfig(n_slots=1, page_size=ps,
                                          pages_per_slot=npg))
    rid = eng.submit(np.asarray(toks[0]),
                     SamplingParams(max_new_tokens=1, temperature=0.0))
    eng.step()  # prefill + insert (+ finish: max_new=1)
    assert eng.requests[rid].tokens  # first token sampled
    # the request finished so its pages were released, but release only
    # edits the host page table — the device pool still holds the data
    pt = jnp.asarray(np.arange(1, 1 + npg, dtype=np.int32).reshape(1, npg))
    view = gather_view(eng.pool.leaves, pt,
                       jnp.asarray(np.asarray([plen], np.int32)), ps)
    for name in eng.pool.leaves:
        seq_ax = {"k": -1, "v": -2}[name]
        got = np.moveaxis(np.asarray(view[name]), seq_ax, -1)[..., :plen]
        ref = np.moveaxis(np.asarray(cache["body"][name]), seq_ax, -1)[..., :plen]
        np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# slot/page bookkeeping
# ---------------------------------------------------------------------------


def test_slot_manager_never_hands_out_scratch_page():
    sm = SlotManager(n_slots=3, pages_per_slot=4, n_pages=13)
    seen = set()
    slots = []
    for _ in range(3):
        slot, pages = sm.alloc(4)
        slots.append(slot)
        assert 0 not in pages
        assert not (set(pages.tolist()) & seen)
        seen |= set(pages.tolist())
    assert not sm.can_admit(1)
    for s in slots:
        sm.release(s)
    assert sm.free_slot_count == 3 and sm.free_page_count == 12
    assert (sm.page_table == 0).all()  # freed entries point at scratch


def test_slot_manager_fifo_vs_lifo_reuse_order():
    fifo = SlotManager(2, 2, 5, order="fifo")
    lifo = SlotManager(2, 2, 5, order="lifo")
    first = {}
    for name, sm in (("fifo", fifo), ("lifo", lifo)):
        s0, _ = sm.alloc(2)
        sm.release(s0)
        first[name] = s0
    s_f, _ = fifo.alloc(1)
    s_l, _ = lifo.alloc(1)
    assert s_f != first["fifo"]  # fifo cycles to the other slot
    assert s_l == first["lifo"]  # lifo reuses the one just freed


def test_admission_queue_orders_by_arrival_then_rid():
    q = AdmissionQueue()
    p = np.zeros(1, np.int32)
    sp = SamplingParams()
    for rid, arr in [(2, 5), (0, 5), (1, 0)]:
        q.push(Request(rid=rid, prompt=p, params=sp, arrival=arr))
    assert q.next_arrival() == 0
    assert [q.pop().rid for _ in range(3)] == [1, 0, 2]


def test_submit_rejects_oversized_request(model):
    cfg, params = model
    eng = ScheduledEngine(params, cfg,
                          SchedulerConfig(n_slots=1, page_size=8,
                                          pages_per_slot=2))
    with pytest.raises(ValueError):
        eng.submit(np.ones(12, np.int32), SamplingParams(max_new_tokens=8))


def test_scheduler_drains_staggered_arrivals(model):
    """CI smoke shape: more requests than slots, staggered arrivals, all
    complete with the right token counts."""
    cfg, params = model
    eng = ScheduledEngine(params, cfg,
                          SchedulerConfig(n_slots=2, page_size=8,
                                          pages_per_slot=3))
    rng = np.random.default_rng(9)
    rids = [
        eng.submit(rng.integers(1, cfg.vocab_size, 4 + i).astype(np.int32),
                   SamplingParams(k=4, temperature=0.5, max_new_tokens=3 + i % 3,
                                  seed=i),
                   arrival=i * 2)
        for i in range(5)
    ]
    out = eng.run()
    assert sorted(out) == sorted(rids)
    for i, rid in enumerate(rids):
        assert out[rid].shape == (3 + i % 3,)
        assert (out[rid] >= 0).all() and (out[rid] < cfg.vocab_size).all()
    assert eng.slots.free_slot_count == 2
    assert eng.slots.free_page_count == eng.pool.n_pages - 1


def test_request_waterfalls_reconcile_with_measured_latency(model):
    """The §17 tracing contract: with obs on, every completed request
    leaves a root span plus queue-wait/prefill/insert/decode-tick stage
    spans whose integer-ns sums reconcile *exactly* with the engine's
    measured TTFT and request latency (shared endpoints, no float
    rounding), with scheduler overhead surfacing as non-negative
    unaccounted time."""
    import repro.obs as obs
    from repro.obs import metrics, recorder, trace

    cfg, params = model
    prev = obs.set_enabled(True)
    trace.clear()
    metrics.reset()
    recorder.clear()
    try:
        eng = ScheduledEngine(params, cfg,
                              SchedulerConfig(n_slots=2, page_size=8,
                                              pages_per_slot=4))
        rng = np.random.default_rng(3)
        new_tokens = [3, 4, 3]
        rids = [
            eng.submit(rng.integers(1, cfg.vocab_size, 5).astype(np.int32),
                       SamplingParams(k=4, temperature=0.7,
                                      max_new_tokens=new_tokens[i], seed=i),
                       arrival=i)
            for i in range(3)
        ]
        out = eng.run()
        assert sorted(out) == sorted(rids)
        wfs = obs.request_waterfalls()
        assert sorted(w["rid"] for w in wfs) == sorted(rids)
        for w in wfs:
            r = eng.requests[w["rid"]]
            assert r.trace_id  # engine-assigned, unique per request
            assert w["state"] == "done"
            # exact integer-ns reconciliation against the engine's own
            # latency markers
            assert w["ttft_ns"] == r.t_first_ns - r.t_submit_ns
            assert w["total_ns"] == r.t_finish_ns - r.t_submit_ns
            assert w["unaccounted_ns"] >= 0
            assert w["decode_ticks"] == len(r.tokens) - 1
            stages = [s["name"] for s in w["stages"]]
            assert stages[:3] == ["req.queue_wait", "req.prefill",
                                  "req.insert"]
            # the non-decode stages tile [submit, first-token] with
            # shared endpoints
            nd = [s for s in w["stages"] if s["name"] != "req.decode"]
            assert nd[0]["t0_ns"] == r.t_submit_ns
            for a, b in zip(nd, nd[1:]):
                assert a["t0_ns"] + a["dur_ns"] == b["t0_ns"]
            assert nd[-1]["t0_ns"] + nd[-1]["dur_ns"] == r.t_first_ns
        tids = {eng.requests[rid].trace_id for rid in rids}
        assert len(tids) == len(rids)
        # exactly one decode tick per signature pays the compile
        dec = [sp for sp in trace.spans() if sp.name == "req.decode"]
        assert any(sp.attrs["compiled"] for sp in dec)
        by_tick = {}
        for sp in dec:
            by_tick.setdefault(sp.attrs["tick"], set()).add(
                sp.attrs["compiled"])
        assert all(len(v) == 1 for v in by_tick.values())
        # request terminals also land in the flight recorder
        done = [ev for ev in recorder.events()
                if ev.kind == "sched" and ev.name == "request.done"]
        assert sorted(ev.attrs["rid"] for ev in done) == sorted(rids)
        # and the per-request chrome trace stays schema-valid
        assert obs.validate_chrome_trace(obs.request_chrome_trace()) == []
    finally:
        trace.clear()
        metrics.reset()
        recorder.clear()
        obs.set_enabled(prev)
