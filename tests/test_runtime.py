"""Runtime subsystem tests: data, checkpoint, FT loop, MoE dispatch, optim."""
import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.data import DataConfig, TokenPipeline


def test_data_pipeline_deterministic_resume():
    cfg = get_smoke_config("qwen3-8b")
    dc = DataConfig(seq_len=32, global_batch=4, seed=5)
    p1 = TokenPipeline(cfg, dc)
    p2 = TokenPipeline(cfg, dc)
    b1 = p1.get_batch(17)
    b2 = p2.get_batch(17)  # fresh pipeline, same step -> same batch
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not (p1.get_batch(18)["tokens"] == b1["tokens"]).all()


def test_data_pipeline_host_sharding_disjoint():
    cfg = get_smoke_config("qwen3-8b")
    full = TokenPipeline(cfg, DataConfig(seq_len=16, global_batch=4,
                                         host_index=0, host_count=1))
    h0 = TokenPipeline(cfg, DataConfig(seq_len=16, global_batch=4,
                                       host_index=0, host_count=2))
    assert h0.get_batch(0)["tokens"].shape[0] == 2
    assert full.get_batch(0)["tokens"].shape[0] == 4


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    from repro.checkpoint import CheckpointManager

    ckpt = CheckpointManager(str(tmp_path), keep_last=2)
    state = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "nested": {"b": jnp.ones((4,))},
             "lst": [jnp.zeros((2,)), jnp.ones((2,))]}
    for step in (10, 20, 30):
        ckpt.save(step, state, extra={"note": f"s{step}"}, blocking=True)
    assert ckpt.all_steps() == [20, 30]  # keep_last GC
    restored, extra = ckpt.restore(30, state)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
    np.testing.assert_array_equal(np.asarray(restored["lst"][1]), np.ones((2,)))
    assert extra["step"] == 30
    # no .tmp dirs left behind
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_train_resume_exact(tmp_path):
    """Crash + restart must reproduce the exact same trajectory."""
    from repro.optim import OptConfig
    from repro.runtime import TrainConfig, train, train_with_retries

    cfg = get_smoke_config("chatglm3-6b")
    dc = DataConfig(seq_len=32, global_batch=4, seed=3)
    oc = OptConfig(lr=1e-3, warmup_steps=2, total_steps=12)

    tc_a = TrainConfig(steps=12, ckpt_every=4, ckpt_dir=str(tmp_path / "a"),
                       log_every=100)
    ref = train(cfg, dc, tc_a, oc)

    tc_b = TrainConfig(steps=12, ckpt_every=4, ckpt_dir=str(tmp_path / "b"),
                       log_every=100)
    out = train_with_retries(cfg, dc, tc_b, oc, retries=1, fail_at_step=6)
    assert abs(out["final_loss"] - ref["final_loss"]) < 1e-4


def test_moe_dispatch_modes_agree():
    """sorted (LOMS network) and scatter (cumsum) dispatch are bit-equal."""
    import dataclasses

    from repro.models import model_init
    from repro.models.moe import moe_ffn_local

    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    cfg_sorted = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="sorted"))
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    layer = jax.tree.map(lambda a: a[0], params["stack"]["body"])["ffn"]
    x = jnp.asarray(np.random.default_rng(0).standard_normal((64, cfg.d_model)),
                    jnp.float32)
    y_scatter = moe_ffn_local(layer, x, cfg)
    y_sorted = moe_ffn_local(layer, x, cfg_sorted)
    np.testing.assert_allclose(np.asarray(y_scatter), np.asarray(y_sorted),
                               rtol=1e-5, atol=1e-5)


def test_moe_router_matches_lax_topk_gates():
    from repro.models.moe import router_topk

    logits = jnp.asarray(np.random.default_rng(1).standard_normal((32, 64)),
                         jnp.float32)
    gates, idx = router_topk(logits, 6, block=16)
    ref_v, ref_i = jax.lax.top_k(logits, 6)
    np.testing.assert_array_equal(
        np.sort(np.asarray(idx), -1), np.sort(np.asarray(ref_i), -1))
    np.testing.assert_allclose(np.asarray(gates).sum(-1), 1.0, rtol=1e-5)


def test_gradient_compression_error_feedback():
    from repro.parallel.compress import compress, decompress

    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.standard_normal((1000,)), jnp.float32) * 0.01
    q, s = compress(g)
    g_hat = decompress(q, s, g.shape)
    rel = float(jnp.linalg.norm(g - g_hat) / jnp.linalg.norm(g))
    assert rel < 0.02  # int8 block quantization error
    # error feedback: accumulated residual stays bounded over steps
    err = jnp.zeros_like(g)
    for _ in range(10):
        q, s = compress(g + err)
        err = (g + err) - decompress(q, s, g.shape)
    assert float(jnp.linalg.norm(err)) < float(jnp.linalg.norm(g))


def test_optimizer_schedule_shapes():
    from repro.optim import OptConfig, schedule

    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(schedule(jnp.int32(0), oc)) == 0.0
    assert abs(float(schedule(jnp.int32(10), oc)) - 1.0) < 1e-6
    assert float(schedule(jnp.int32(100), oc)) == pytest.approx(0.1, rel=1e-3)


def test_straggler_monitor():
    from repro.runtime.train_loop import StragglerMonitor

    mon = StragglerMonitor(3.0)
    for _ in range(10):
        assert not mon.record(0.1)
    assert mon.record(1.0)  # 10x median -> flagged
    assert mon.flagged == 1
