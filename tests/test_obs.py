"""repro.obs: spans, metrics, recorder, export, timing — and the no-op
guarantees.

The load-bearing contracts (DESIGN.md §13, §17):

* with ``REPRO_OBS`` unset, instrumentation is invisible — identical
  jaxpr op counts, bit-identical outputs, sub-µs per-call overhead (the
  same contract covers the flight recorder's ``emit``);
* trace-time metrics count *compilations*, so they are deterministic
  under jit retracing;
* the exported Chrome trace passes its own schema check, including the
  recorder's instant events and under concurrent export;
* the flight-recorder ring stays bounded across wraparound with a
  monotonic, gap-revealing sequence;
* measured autotune wall time round-trips through the cache and
  surfaces in ``decision_table``.
"""
import json
import os
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
import repro.obs as obs
from repro.obs import export, metrics, recorder, timing, trace

RNG = np.random.default_rng(0)


@pytest.fixture
def obs_on():
    prev = obs.set_enabled(True)
    trace.clear()
    metrics.reset()
    recorder.clear()
    yield
    trace.clear()
    metrics.reset()
    recorder.clear()
    obs.set_enabled(prev)


@pytest.fixture
def obs_off():
    prev = obs.set_enabled(False)
    yield
    obs.set_enabled(prev)


# ---------------------------------------------------------------- spans


def test_span_nesting_records_parent_ids(obs_on):
    with obs.span("outer", kind="run", a=1):
        with obs.span("inner", kind="trace"):
            pass
        with obs.span("inner2", kind="run"):
            pass
    got = {sp.name: sp for sp in trace.spans()}
    assert set(got) == {"outer", "inner", "inner2"}
    assert got["inner"].parent_id == got["outer"].span_id
    assert got["inner2"].parent_id == got["outer"].span_id
    assert got["outer"].parent_id is None
    assert got["outer"].attrs == {"a": 1}
    assert got["inner"].kind == "trace" and got["outer"].kind == "run"
    # children complete (and are recorded) before the parent
    assert [sp.name for sp in trace.spans()] == ["inner", "inner2", "outer"]


def test_span_disabled_is_shared_null_context(obs_off):
    a, b = obs.span("x"), obs.span("y", kind="trace")
    assert a is b  # one preallocated null object, no per-call state
    with a:
        pass
    assert trace.spans() == ()


def test_traced_decorator(obs_on):
    @obs.traced("my.fn", kind="run")
    def fn(x):
        return x + 1

    assert fn(1) == 2
    assert [sp.name for sp in trace.spans()] == ["my.fn"]


def test_span_clear_resets_buffer_and_dropped(obs_on):
    with obs.span("s"):
        pass
    assert len(trace.spans()) == 1
    trace.clear()
    assert trace.spans() == () and trace.dropped() == 0


# -------------------------------------------------------------- metrics


def test_counter_gauge_histogram_snapshot(obs_on):
    metrics.counter("c", help="a counter").inc(op="sort")
    metrics.counter("c").inc(2, op="sort")
    metrics.counter("c").inc(op="merge")
    metrics.gauge("g").set(7.5, dev="cpu")
    h = metrics.histogram("h")
    for v in range(100):
        h.observe(float(v))

    assert metrics.counter("c").value(op="sort") == 3
    assert metrics.counter("c").total() == 4
    assert metrics.gauge("g").value(dev="cpu") == 7.5

    snap = metrics.snapshot()
    assert snap["c"]["kind"] == "counter" and snap["c"]["help"] == "a counter"
    hs = snap["h"]["series"][0]
    assert hs["count"] == 100 and hs["min"] == 0.0 and hs["max"] == 99.0
    assert hs["p50"] <= hs["p95"] <= hs["p99"] <= hs["max"]
    # reservoir occupancy rides along so an exhausted reservoir (count >
    # samples) is visible to percentile readers
    assert hs["samples"] == 100 and hs["reservoir_full"] is False


def test_histogram_reservoir_exhaustion_is_visible(obs_on):
    h = metrics.histogram("big")
    for v in range(h.max_samples + 50):
        h.observe(float(v))
    hs = metrics.snapshot()["big"]["series"][0]
    assert hs["count"] == h.max_samples + 50
    assert hs["samples"] == h.max_samples
    assert hs["reservoir_full"] is True


def test_metrics_disabled_are_inert(obs_off):
    metrics.reset()
    metrics.counter("dead").inc(5)
    metrics.gauge("deadg").set(1.0)
    metrics.histogram("deadh").observe(2.0)
    assert metrics.counter("dead").total() == 0
    assert metrics.gauge("deadg").value() is None
    assert metrics.histogram("deadh").stats() is None
    metrics.reset()


def test_metric_kind_collision_asserts(obs_on):
    metrics.counter("kc")
    with pytest.raises(AssertionError):
        metrics.gauge("kc")


def test_trace_time_counters_count_compilations_not_calls(obs_on):
    """Calling a jitted fn 3x with one shape traces once -> counter 1;
    a new shape retraces -> 2. Deterministic under retracing, by design."""
    fn = jax.jit(lambda v: repro.sort(v))
    x = jnp.asarray(RNG.normal(size=(2, 64)).astype(np.float32))
    before = metrics.counter("plan.decisions").total()
    for _ in range(3):
        fn(x).block_until_ready()
    assert metrics.counter("plan.decisions").total() == before + 1
    y = jnp.asarray(RNG.normal(size=(2, 128)).astype(np.float32))
    fn(y).block_until_ready()
    assert metrics.counter("plan.decisions").total() == before + 2


def test_autotune_cache_hit_miss_counters(obs_on, tmp_path):
    from repro.streaming.cache import AutotuneCache, plan_key

    cache = AutotuneCache(path=str(tmp_path / "at.json"))
    key = plan_key("sort", shapes=(4, 128), dtype="float32")
    assert cache.get(key) is None
    c = metrics.counter("autotune.cache")
    assert c.value(op="sort", result="miss") == 1
    cache.put(key, {"kind": "loms", "n_cols": 8, "block_batch": 4,
                    "use_mxu": False})
    assert cache.get(key) is not None
    assert c.value(op="sort", result="hit") == 1
    # stale-schema entries are counted and ignored
    cache._entries[key]["_schema"] = -1
    assert cache.get(key) is None
    assert c.value(op="sort", result="stale_schema") == 1


def test_segmented_bucketing_counters(obs_on):
    lengths = [8, 8, 16, 5, 64]
    offs = tuple(np.concatenate([[0], np.cumsum(lengths)]).tolist())
    x = jnp.asarray(RNG.normal(size=(offs[-1],)).astype(np.float32))
    repro.segment_sort(x, offs, backend="segmented")
    assert metrics.counter("segmented.class_launches").total() > 0
    padded = metrics.counter("segmented.padded_slots").total()
    valid = metrics.counter("segmented.valid_slots").total()
    assert padded >= 0 and valid > 0
    st = metrics.histogram("segmented.padded_waste_frac").stats(
        op="segment_sort")
    assert st is not None and 0.0 <= st["min"] <= st["max"] <= 1.0


# --------------------------------------------------------------- export


def test_snapshot_and_jsonl_schema(obs_on, tmp_path):
    with obs.span("region", kind="run", tag="t"):
        pass
    metrics.counter("c").inc(op="sort")
    snap = obs.snapshot()
    assert set(snap) == {"meta", "spans", "metrics", "events"}
    assert snap["meta"]["schema"] == 1 and snap["meta"]["dropped_spans"] == 0
    # span-buffer health surfaces in meta (satellite: the 100k cap is
    # visible, not silent)
    assert snap["meta"]["spans_recorded"] == 1
    assert snap["meta"]["span_cap"] == trace.MAX_SPANS
    assert snap["meta"]["events_overwritten"] == 0
    sp = snap["spans"][0]
    assert sp["name"] == "region" and sp["kind"] == "run"
    assert sp["dur_us"] >= 0 and sp["attrs"] == {"tag": "t"}
    assert sp["dur_ns"] >= 0 and sp["ts_ns"] > 0
    # every span close feeds the flight recorder
    assert [ev["kind"] for ev in snap["events"]] == ["span"]
    assert snap["events"][0]["name"] == "region"

    path = tmp_path / "out.jsonl"
    obs.write_jsonl(str(path), snap)
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert [ln["type"] for ln in lines] == ["meta", "span", "metric",
                                            "event"]


def test_chrome_trace_valid_and_loadable(obs_on, tmp_path):
    with obs.span("outer", kind="run"):
        with obs.span("inner", kind="trace"):
            pass
    metrics.counter("c").inc(3)
    path = tmp_path / "t.trace.json"
    obs.write_chrome_trace(str(path))
    loaded = json.loads(path.read_text())
    assert obs.validate_chrome_trace(loaded) == []
    evs = {ev["name"]: ev for ev in loaded["traceEvents"]}
    assert evs["outer"]["ph"] == "X" and evs["outer"]["cat"] == "run"
    assert evs["inner"]["cat"] == "trace"
    assert evs["inner"]["args"]["parent"] == evs["outer"]["args"]["span_id"]
    assert evs["c"]["ph"] == "C" and evs["c"]["args"]["total"] == 3


def test_validate_chrome_trace_catches_violations():
    assert export.validate_chrome_trace([]) == ["trace is not a JSON object"]
    assert export.validate_chrome_trace({}) == [
        "traceEvents missing or not a list"]
    errs = export.validate_chrome_trace({"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 1, "ts": -1.0, "dur": "x"},
        {"name": "ok", "ph": "Z", "pid": 1, "tid": 1},
    ]})
    assert any("missing 'name'" in e for e in errs)
    assert any("ts not a non-negative number" in e for e in errs)
    assert any("dur not a non-negative number" in e for e in errs)
    assert any("unknown phase 'Z'" in e for e in errs)


# ------------------------------------------------------------- recorder


def test_recorder_ring_wraparound_keeps_newest(obs_on):
    prev_cap = recorder.capacity()
    recorder.set_capacity(8)
    try:
        for i in range(20):
            recorder.emit("unit", f"ev{i}", i=i)
        evs = recorder.events()
        assert len(evs) == 8 == recorder.capacity()
        assert recorder.total_events() == 20
        assert recorder.overwritten() == 12
        # the newest events survive; seq stays monotonic and its gap from
        # 1 reveals exactly how much history was discarded
        assert [ev.attrs["i"] for ev in evs] == list(range(12, 20))
        seqs = [ev.seq for ev in evs]
        assert seqs == sorted(seqs) and seqs[0] == 13 and seqs[-1] == 20
    finally:
        recorder.set_capacity(prev_cap)


def test_recorder_disabled_emit_is_noop(obs_off):
    recorder.clear()
    recorder.emit("unit", "dead", a=1)
    assert recorder.events() == [] and recorder.total_events() == 0


def test_recorder_dump_and_chrome_events(obs_on, tmp_path):
    recorder.emit("breaker", "sort/pallas/b8", frm="closed", to="open")
    path = tmp_path / "flight.jsonl"
    snap = recorder.dump(str(path), reason="unit")
    assert snap["meta"]["reason"] == "unit"
    assert snap["meta"]["events"] == 1 and snap["meta"]["overwritten"] == 0
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert [ln["type"] for ln in lines] == ["meta", "event"]
    assert lines[1]["kind"] == "breaker"
    evs = recorder.chrome_trace_events(snap)
    assert evs[0]["ph"] == "i" and evs[0]["name"] == "breaker:sort/pallas/b8"
    # instant events pass the same schema gate as the span export
    assert export.validate_chrome_trace({"traceEvents": evs}) == []


def test_recorder_crash_dump_writes_env_path(obs_on, tmp_path, monkeypatch):
    recorder.emit("sched", "request.failed", rid=1)
    path = tmp_path / "crash.jsonl"
    monkeypatch.setenv("REPRO_OBS_DUMP", str(path))
    got = recorder.crash_dump("unit", RuntimeError("boom"))
    assert got == str(path) and path.exists()
    meta = json.loads(path.read_text().splitlines()[0])
    assert meta["type"] == "meta" and meta["reason"] == "crash:unit:RuntimeError"


def test_recorder_sigusr1_dump(obs_on, tmp_path):
    recorder.emit("unit", "alive", n=1)
    path = tmp_path / "sig.jsonl"
    assert recorder.install_signal_dump(str(path))
    try:
        os.kill(os.getpid(), signal.SIGUSR1)
        # CPython delivers the signal on the main thread at the next
        # bytecode boundary; poll briefly rather than assuming immediacy
        deadline = time.time() + 5.0
        while not path.exists() and time.time() < deadline:
            time.sleep(0.01)
        lines = [json.loads(ln) for ln in path.read_text().splitlines()]
        assert lines[0]["reason"] == "SIGUSR1"
        assert any(ln.get("name") == "alive" for ln in lines[1:])
    finally:
        recorder.uninstall_signal_dump()


def test_record_span_explicit_time(obs_on):
    sid = obs.record_span("req.queue_wait", 1000, 2500, rid=3)
    assert sid is not None
    sp = trace.spans()[-1]
    assert sp.name == "req.queue_wait"
    assert sp.t0_ns == 1000 and sp.dur_ns == 2500
    assert sp.attrs == {"rid": 3}
    # negative durations clamp to zero (clock weirdness never corrupts
    # the waterfall)
    obs.record_span("x", 5000, -10)
    assert trace.spans()[-1].dur_ns == 0


def test_record_span_disabled_returns_none(obs_off):
    assert obs.record_span("x", 0, 10) is None
    assert trace.spans() == ()


def test_prom_text_format_and_write(obs_on, tmp_path):
    metrics.counter("sched.completed").inc(3)
    metrics.gauge("sched.queue_depth").set(2.0)
    h = metrics.histogram("sched.ttft_s")
    for v in (0.1, 0.2, 0.3):
        h.observe(v, op="sort")
    txt = obs.prom_text()
    assert "# TYPE repro_sched_completed_total counter" in txt
    assert "repro_sched_completed_total 3" in txt
    assert "# TYPE repro_sched_queue_depth gauge" in txt
    assert "repro_sched_queue_depth 2.0" in txt
    assert "# TYPE repro_sched_ttft_s summary" in txt
    assert 'repro_sched_ttft_s_count{op="sort"} 3' in txt
    assert 'repro_sched_ttft_s{op="sort",quantile="0.5"}' in txt
    p = tmp_path / "metrics.prom"
    obs.write_prom(str(p))
    assert p.read_text() == txt


def test_export_under_concurrency_schema_valid(obs_on, tmp_path):
    """Two producer threads emit spans/metrics/events while the main
    thread exports: every export must stay schema-valid and every JSONL
    line parseable — no torn reads from the shared buffers."""
    stop = threading.Event()

    def producer(tid):
        i = 0
        while not stop.is_set():
            with obs.span(f"conc.{tid}", kind="run", i=i):
                metrics.counter("conc.ops").inc(tid=tid)
                recorder.emit("unit", f"conc.{tid}", i=i)
            i += 1

    threads = [threading.Thread(target=producer, args=(t,)) for t in (0, 1)]
    for t in threads:
        t.start()
    try:
        for j in range(5):
            snap = obs.snapshot()
            assert obs.validate_chrome_trace(obs.chrome_trace(snap)) == []
            path = tmp_path / f"conc{j}.jsonl"
            obs.write_jsonl(str(path), snap)
            types = [json.loads(ln)["type"]
                     for ln in path.read_text().splitlines()]
            assert types[0] == "meta"
            assert set(types) <= {"meta", "span", "metric", "event"}
    finally:
        stop.set()
        for t in threads:
            t.join()
    # both producers made it into the stores
    assert {"conc.0", "conc.1"} <= {sp.name for sp in trace.spans()}


# --------------------------------------------------------------- timing


def test_time_jitted_stats_ordering(obs_on):
    fn = jax.jit(lambda v: jnp.sort(v, axis=-1))
    x = jnp.asarray(RNG.normal(size=(4, 256)).astype(np.float32))
    st = timing.time_jitted(fn, x, warmup=1, iters=5, name="unit")
    assert st.n == 5 and len(st.samples_us) == 5
    assert st.min_us <= st.p50_us <= st.p95_us <= st.p99_us <= st.max_us
    assert st.p50_s == pytest.approx(st.p50_us * 1e-6)
    row = st.to_row()
    assert set(row) == {"p50_us", "p95_us", "p99_us"}
    assert metrics.histogram("timing.unit").stats()["count"] == 1
    assert any(sp.name == "timing.unit" for sp in trace.spans())


def test_time_once_blocks_and_returns_result():
    fn = jax.jit(lambda v: v * 2)
    x = jnp.ones((8,), jnp.float32)
    out, dt = timing.time_once(fn, x)
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones((8,)))
    assert dt > 0


# ------------------------------------------------- disabled-path no-ops


def _eqn_count(fn, *args) -> int:
    def walk(jaxpr):
        n = 0
        for eqn in jaxpr.eqns:
            n += 1
            if eqn.primitive.name == "pallas_call":
                continue
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    n += walk(v.jaxpr)
                elif isinstance(v, (list, tuple)):
                    for vi in v:
                        if hasattr(vi, "jaxpr"):
                            n += walk(vi.jaxpr)
        return n

    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


def _obs_cases():
    x = jnp.asarray(RNG.normal(size=(4, 128)).astype(np.float32))
    lists = [jnp.sort(jnp.asarray(
        RNG.normal(size=(4, n)).astype(np.float32)), -1) for n in (64, 96, 32)]
    offs = (0, 16, 80, 128)
    seg = jnp.asarray(RNG.normal(size=(offs[-1],)).astype(np.float32))
    return [
        ("sort", lambda: repro.sort(x)),
        ("merge_k", lambda: repro.merge_k(lists)),
        ("segment_topk", lambda: repro.segment_topk(
            seg, offs, 8, backend="segmented")[0]),
    ]


def test_obs_is_invisible_to_lowering_and_results():
    """Enabled vs disabled: same XLA-level op count, bit-identical values
    — the acceptance gate that REPRO_OBS never changes computation."""
    for name, fn in _obs_cases():
        prev = obs.set_enabled(False)
        try:
            ops_off = _eqn_count(fn)
            val_off = np.asarray(jax.jit(fn)())
            obs.set_enabled(True)
            ops_on = _eqn_count(fn)
            val_on = np.asarray(jax.jit(fn)())
        finally:
            obs.set_enabled(prev)
            trace.clear()
            metrics.reset()
        assert ops_on == ops_off, f"{name}: obs changed jaxpr op count"
        assert np.array_equal(val_on, val_off, equal_nan=True), (
            f"{name}: obs changed results")


def test_disabled_span_overhead_under_5us(obs_off):
    n = 20_000
    t0 = time.perf_counter()
    for _ in range(n):
        with obs.span("hot", kind="run", arg=1):
            pass
    per_call = (time.perf_counter() - t0) / n
    assert per_call < 5e-6, f"disabled span costs {per_call * 1e6:.2f}us/call"


# ----------------------------------------- measured-cost plumbing (us)


def test_merge_plan_us_roundtrip(tmp_path):
    from repro.streaming.planner import MergePlan

    plan = MergePlan(kind="loms", n_cols=8, block_batch=4, use_mxu=False,
                     tile=512, block=0, source="autotune", us=12.5)
    entry = plan.to_entry()
    assert entry["us"] == 12.5
    back = MergePlan.from_entry(entry)
    assert back.us == 12.5
    assert MergePlan.from_entry({k: v for k, v in entry.items()
                                 if k != "us"}).us is None
    # explicit us= wins over the field
    assert plan.to_entry(us=99.0)["us"] == 99.0


def test_decision_table_surfaces_tuned_us(tmp_path):
    from repro.api.dispatch import decision_table
    from repro.streaming.cache import (AutotuneCache, plan_key,
                                       set_default_cache)

    cache = AutotuneCache(path=str(tmp_path / "at.json"))
    # the decision_table sort case: batch=8, length 1024, float32
    key = plan_key("sort", shapes=(8, 1024), dtype="float32")
    cache.put(key, {"kind": "loms", "n_cols": 8, "block_batch": 8,
                    "use_mxu": False, "us": 42.0})
    prev = set_default_cache(cache)
    try:
        rows = decision_table(device="cpu")
    finally:
        set_default_cache(prev)
    assert all("tuned_us" in r for r in rows)
    tuned = {r["problem"]: r["tuned_us"] for r in rows}
    assert tuned["sort[1024] b=8 float32 (cpu)"] == 42.0
    # untuned points stay None rather than inventing numbers
    assert tuned["merge[512x512] b=8 float32 (cpu)"] is None


def test_estimate_vmem_bytes_positive_and_monotone():
    from repro.streaming.planner import MergePlan, estimate_vmem_bytes

    plan = MergePlan(kind="loms", n_cols=8, block_batch=4, use_mxu=False)
    small = estimate_vmem_bytes("merge2", (256, 256), plan)
    large = estimate_vmem_bytes("merge2", (4096, 4096), plan)
    assert 0 < small < large
    for op, lens in (("sort", (1024,)), ("kway", (64, 96, 32)),
                     ("topk", (4096,))):
        assert estimate_vmem_bytes(op, lens, plan) > 0


# ------------------------------------------------------------- serving


def test_generate_time_steps_percentiles_match_greedy():
    from repro.configs import get_smoke_config
    from repro.models import model_init
    from repro.serving.engine import ServeConfig, generate

    cfg = get_smoke_config("qwen3-8b")
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)}
    base = generate(params, batch, cfg,
                    ServeConfig(max_new_tokens=4, temperature=0.0))
    timed = generate(params, batch, cfg,
                     ServeConfig(max_new_tokens=4, temperature=0.0,
                                 time_steps=True))
    np.testing.assert_array_equal(base["tokens"], timed["tokens"])
    assert "decode_step_p50_us" not in base
    assert (timed["decode_step_p50_us"] <= timed["decode_step_p95_us"]
            <= timed["decode_step_p99_us"])
    assert len(timed["step_times_s"]) == 3  # max_new_tokens - 1 steps
    # the first timed step is the decode jit compile: reported apart and
    # excluded from the steady-state percentiles (no p95/p99 skew)
    assert timed["decode_step_compile_us"] == pytest.approx(
        timed["step_times_s"][0] * 1e6)
    steady_us = np.asarray(timed["step_times_s"][1:]) * 1e6
    assert timed["decode_step_p50_us"] == pytest.approx(
        float(np.percentile(steady_us, 50)))
    assert timed["decode_step_p99_us"] <= float(steady_us.max()) + 1e-9
