"""Per-arch smoke tests (reduced configs) + model-level invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import (decode_step, forward, init_cache, loss_fn,
                          model_init, prefill)

RNG = np.random.default_rng(0)


def make_batch(cfg, b=2, s=32):
    if cfg.family == "audio":
        return {"frames": jnp.asarray(
            RNG.standard_normal((b, s, cfg.frontend_dim)), jnp.float32),
            "targets": jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)))}
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s))),
             "targets": jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)))}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            RNG.standard_normal((b, cfg.frontend_len, cfg.frontend_dim)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_step(arch):
    cfg = get_smoke_config(arch)
    params, specs = model_init(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits = forward(params, batch, cfg)
    s_out = 32
    assert logits.shape == (2, s_out, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step_decreases_loss(arch):
    from repro.optim import OptConfig, opt_init, opt_update

    cfg = get_smoke_config(arch)
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    opt = opt_init(params)
    oc = OptConfig(lr=5e-3, warmup_steps=1, total_steps=20)
    batch = make_batch(cfg)

    @jax.jit
    def step(params, opt):
        loss, g = jax.value_and_grad(lambda p: loss_fn(p, batch, cfg))(params)
        params, opt, _ = opt_update(g, opt, params, oc)
        return params, opt, loss

    losses = []
    for _ in range(8):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses  # memorizes one batch


@pytest.mark.parametrize("arch", [a for a in sorted(ARCHS)
                                  if not get_config(a).is_encoder_only])
def test_prefill_decode_consistency(arch):
    """Greedy decode after prefill must match the full-sequence forward."""
    cfg = get_smoke_config(arch)
    params, _ = model_init(jax.random.PRNGKey(1), cfg)
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    extra = cfg.frontend_len if cfg.family == "vlm" else 0
    cache = init_cache(cfg, b, s + extra + 1)
    logits_p, cache = prefill(params, batch, cache, cfg)
    # forward on the same tokens: last-position logits must match prefill
    logits_f = forward(params, batch, cfg)[:, -1]
    np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                               np.asarray(logits_f, np.float32),
                               rtol=2e-2, atol=2e-2)
    # one decode step runs and is finite
    tok = jnp.argmax(logits_p, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((b, 1), s + extra, jnp.int32)
    logits_d, _ = decode_step(params, tok, cache, cfg, positions=pos)
    assert np.isfinite(np.asarray(logits_d, np.float32)).all()


def test_decode_matches_teacher_forcing_qwen():
    """Decoding token-by-token == full forward at every position (greedy)."""
    cfg = get_smoke_config("qwen3-8b")
    params, _ = model_init(jax.random.PRNGKey(2), cfg)
    b, s = 1, 12
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    full = forward(params, {"tokens": toks, "targets": toks}, cfg)
    cache = init_cache(cfg, b, s)
    # prefill only the first 4 tokens, then decode the rest teacher-forced
    logits_p, cache = prefill(
        params, {"tokens": toks[:, :4], "targets": toks[:, :4]}, cache, cfg)
    np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                               np.asarray(full[:, 3], np.float32),
                               rtol=2e-2, atol=2e-2)
    for t in range(4, s):
        logits_d, cache = decode_step(
            params, toks[:, t : t + 1], cache, cfg,
            positions=jnp.full((b, 1), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits_d, np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   rtol=5e-2, atol=5e-2)


def test_mamba2_chunked_equals_small_chunk():
    """SSD chunked algorithm is chunk-size invariant (algebraic identity)."""
    import dataclasses

    cfg = get_smoke_config("mamba2-780m")
    cfg16 = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=16))
    cfg4 = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=4))
    params, _ = model_init(jax.random.PRNGKey(3), cfg16)
    batch = make_batch(cfg, 2, 32)
    l16 = forward(params, batch, cfg16)
    l4 = forward(params, batch, cfg4)
    np.testing.assert_allclose(np.asarray(l16, np.float32),
                               np.asarray(l4, np.float32), rtol=2e-2, atol=2e-2)


def test_flash_attention_matches_naive():
    from repro.models.attention import flash_attention

    b, s, hkv, g, d = 2, 64, 2, 3, 16
    q = jnp.asarray(RNG.standard_normal((b, s, hkv, g, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, chunk=16)
    # naive reference
    s_ = jnp.einsum("bqhgd,bkhd->bqhgk", q, k) / np.sqrt(d)
    mask = np.tril(np.ones((s, s), bool))
    s_ = jnp.where(mask[None, :, None, None, :], s_, -1e30)
    ref = jnp.einsum("bqhgk,bkhd->bqhgd", jax.nn.softmax(s_, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


def test_flash_attention_gradient_matches_naive():
    from repro.models.attention import flash_attention

    b, s, hkv, g, d = 1, 32, 1, 2, 8
    q = jnp.asarray(RNG.standard_normal((b, s, hkv, g, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), jnp.float32)

    def loss_flash(q, k, v):
        return flash_attention(q, k, v, causal=True, chunk=8).sum()

    def loss_naive(q, k, v):
        s_ = jnp.einsum("bqhgd,bkhd->bqhgk", q, k) / np.sqrt(d)
        mask = np.tril(np.ones((s, s), bool))
        s_ = jnp.where(mask[None, :, None, None, :], s_, -1e30)
        return jnp.einsum("bqhgk,bkhd->bqhgd", jax.nn.softmax(s_, -1), v).sum()

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gf, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-3, atol=2e-3)
