"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import median_k, merge2, merge_k, topk
from repro.kernels.bitonic import bitonic_merge2_pallas
from repro.kernels.loms_merge import loms_merge2_pallas
from repro.kernels.kway import kway_merge_pallas
from repro.kernels.topk import router_topk_pallas, vocab_topk_pallas
from repro.kernels import ref
from repro.core.loms import loms_kway

RNG = np.random.default_rng(42)
DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32, jnp.uint8]


def _rand(shape, dtype, lo=0, hi=120):
    # small integer support so every dtype (incl. uint8/bf16) is exact and
    # tie-heavy (stresses stability)
    return jnp.asarray(RNG.integers(lo, hi, shape)).astype(dtype)


def _sorted(shape, dtype):
    return jnp.sort(_rand(shape, dtype), axis=-1)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("m,n,cols", [(8, 8, 2), (16, 16, 4), (32, 32, 8),
                                      (64, 64, 2), (16, 8, 4), (4, 12, 2)])
def test_loms_merge2_kernel_sweep(dtype, m, n, cols):
    a, b = _sorted((8, m), dtype), _sorted((8, n), dtype)
    got = loms_merge2_pallas(a, b, n_cols=cols, block_batch=4, interpret=True)
    want = ref.merge2_ref(a, b)
    np.testing.assert_array_equal(
        np.asarray(got.astype(jnp.float32)), np.asarray(want.astype(jnp.float32)))


@pytest.mark.parametrize("use_mxu", [True, False])
def test_loms_merge2_mxu_vs_fabric_paths(use_mxu):
    a, b = _sorted((8, 32), jnp.float32), _sorted((8, 32), jnp.float32)
    got = loms_merge2_pallas(a, b, n_cols=4, use_mxu=use_mxu, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.merge2_ref(a, b)))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("m", [4, 8, 32, 64])
def test_bitonic_kernel_sweep(dtype, m):
    a, b = _sorted((8, m), dtype), _sorted((8, m), dtype)
    got = bitonic_merge2_pallas(a, b, block_batch=4, interpret=True)
    want = ref.merge2_ref(a, b)
    np.testing.assert_array_equal(
        np.asarray(got.astype(jnp.float32)), np.asarray(want.astype(jnp.float32)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
@pytest.mark.parametrize("lens", [(7, 7, 7), (3, 3, 3), (5, 5, 5), (4, 6, 2),
                                  (3, 3, 3, 3)])
def test_kway_kernel_sweep(dtype, lens):
    lists = [_sorted((8, l), dtype) for l in lens]
    got = merge_k(lists)
    want = ref.merge_k_ref(jnp.concatenate(lists, axis=-1))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("lens", [(3, 3, 3), (7, 7, 7)])
def test_median_kernel(lens):
    lists = [_sorted((8, l), jnp.float32) for l in lens]
    got = median_k(lists)
    want = ref.median_ref(jnp.concatenate(lists, axis=-1))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("e,k,blk", [(160, 6, 20), (128, 8, 16), (160, 6, 32),
                                     (256, 8, 64), (96, 1, 16)])
def test_router_topk_kernel_sweep(dtype, e, k, blk):
    x = _rand((8, e), dtype, -100, 100) if dtype != jnp.uint8 else _rand((8, e), dtype)
    v, i = router_topk_pallas(x, k=k, block=blk, block_batch=4, interpret=True)
    rv, _ = ref.topk_ref(x, k)
    np.testing.assert_array_equal(
        np.asarray(v.astype(jnp.float32)), np.asarray(rv.astype(jnp.float32)))
    taken = np.take_along_axis(np.asarray(x), np.asarray(i), -1)
    np.testing.assert_array_equal(
        taken.astype(np.float32), np.asarray(rv.astype(jnp.float32)))


@pytest.mark.parametrize("v,k", [(1024, 16), (5000, 64), (4096, 1), (300, 50)])
def test_vocab_topk_kernel_sweep(v, k):
    x = jnp.asarray(RNG.standard_normal((4, v)), dtype=jnp.float32)
    got_v, got_i = vocab_topk_pallas(x, k=k, block=128, block_batch=4, interpret=True)
    rv, _ = ref.topk_ref(x, k)
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(rv))
    taken = np.take_along_axis(np.asarray(x), np.asarray(got_i), -1)
    np.testing.assert_allclose(taken, np.asarray(rv))


@given(data=st.data())
@settings(max_examples=20, deadline=None)
def test_topk_kernel_property(data):
    e = data.draw(st.sampled_from([64, 128, 160, 320]))
    k = data.draw(st.integers(1, 16))
    x = jnp.asarray(
        np.asarray(data.draw(st.lists(
            st.integers(-1000, 1000), min_size=4 * e, max_size=4 * e)))
        .reshape(4, e), dtype=jnp.int32)
    v, i = topk(x, k)
    rv, _ = ref.topk_ref(x, k)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))


def test_kernels_jit_under_vmap_grid():
    # kernels must compose with jit (they are called inside train steps)
    a, b = _sorted((16, 32), jnp.float32), _sorted((16, 32), jnp.float32)
    f = jax.jit(lambda a, b: merge2(a, b, n_cols=4))
    np.testing.assert_array_equal(np.asarray(f(a, b)), np.asarray(ref.merge2_ref(a, b)))
