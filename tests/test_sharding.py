"""Distribution-layer tests. Multi-device cases run in a subprocess so the
main pytest process keeps its single-device view (the dry-run flag must
never leak into other tests)."""
import json
import subprocess
import sys

import numpy as np
import pytest

MULTIDEV_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.models import loss_fn, model_init
from repro.parallel import build_param_pspecs, make_parallelism


def shapes_and_specs(cfg):  # local copy: importing launch.dryrun would
    cell = {}               # force the 512-device flag over our 8

    def only_params(key):
        p, s = model_init(key, cfg)
        cell["specs"] = s
        return p

    return jax.eval_shape(only_params, jax.random.PRNGKey(0)), cell["specs"]

mesh = jax.make_mesh((2, 4), ("data", "model"))
par = make_parallelism(mesh)
import dataclasses
cfg = get_smoke_config("qwen3-moe-30b-a3b")
# capacity semantics are per-shard under EP; use a no-drop factor so the
# sharded and local paths are numerically identical
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe,
                                                       capacity_factor=4.0))

params, _ = model_init(jax.random.PRNGKey(0), cfg)
shapes, specs = shapes_and_specs(cfg)
pspecs = build_param_pspecs(shapes, specs, mesh)
named = jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspecs,
                     is_leaf=lambda x: isinstance(x, P))
params = jax.device_put(params, named)

rng = np.random.default_rng(0)
batch = {
    "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32))),
    "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32))),
}
bspec = {"tokens": NamedSharding(mesh, P(("data",), None)),
         "targets": NamedSharding(mesh, P(("data",), None))}
batch = jax.device_put(batch, bspec)

# sharded loss with EP shard_map path == single-device loss
loss_sharded = jax.jit(lambda p, b: loss_fn(p, b, cfg, par=par))(params, batch)
loss_local = jax.jit(lambda p, b: loss_fn(p, b, cfg, par=None))(params, batch)
print(json.dumps({
    "loss_sharded": float(loss_sharded),
    "loss_local": float(loss_local),
    "n_devices": jax.device_count(),
    "some_param_sharded": str(
        jax.tree.leaves(params)[3].sharding.spec) != "PartitionSpec()",
}))
"""


@pytest.mark.slow
def test_sharded_moe_loss_matches_local():
    out = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SNIPPET],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests", 1)[0],
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_devices"] == 8
    # expert-parallel shard_map must be numerically equal to the local path
    np.testing.assert_allclose(res["loss_sharded"], res["loss_local"],
                               rtol=2e-3, atol=2e-3)


def test_param_pspecs_divisibility_fallback():
    """40 heads on a 16-way axis must fall back to replication, not fail."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import _pspec_for

    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    ps = _pspec_for((4096, 40, 128), ("embed", "heads", "head_dim"), FakeMesh())
    assert ps == P("data", None, None)
    ps = _pspec_for((4096, 32, 128), ("embed", "heads", "head_dim"), FakeMesh())
    assert ps == P("data", "model", None)


def test_cache_pspecs_never_shard_sequence():
    """Decode caches: TP on contraction dims, never on the written seq dim."""
    import jax

    from repro.configs import get_config
    from repro.models import init_cache
    from repro.parallel.sharding import Parallelism, cache_pspecs

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    par = Parallelism(mesh=FakeMesh(), dp_axes=("data",), tp_axis="model")
    for arch in ("qwen1.5-32b", "deepseek-v2-lite-16b", "chatglm3-6b"):
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda c=cfg: init_cache(c, 128, 4096))
        specs = cache_pspecs(cfg, par, shapes)
        body = specs["body"]
        for name in ("k", "ckv"):
            if name in body:
                spec = body[name]
                # cache layout puts the written sequence dim LAST; it must
                # never carry a mesh axis (decode DUS would rematerialize)
                assert spec[-1] is None, (arch, name, spec)
