"""Unit + property tests for the oblivious sorting core."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.api.schedules import (  # the former repro.core.api surface
    median9,
    median_of_lists,
    merge,
    merge_k,
    merge_schedule,
    sort,
    topk,
)
from repro.core import (
    apply_schedule,
    apply_schedule_with_payload,
    comparator_count,
    depth,
    loms_2way,
    loms_kway,
    loms_median,
    rank_merge_runs,
    rank_sort,
    table1_stages,
    validate_01_merge,
    validate_01_sort,
)
from repro.core.batcher import bitonic_merge, bitonic_sort, oems_merge, oems_sort
from repro.core.mwms import mwms_kway, mwms_median
from repro.core.setup_array import build_2way_setup, build_kway_setup

RNG = np.random.default_rng(1234)


# ---------------------------------------------------------------------------
# depth-1 primitives
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 3, 5, 8, 17, 64])
@pytest.mark.parametrize("dtype", [np.int32, np.float32, np.uint8])
def test_rank_sort_matches_npsort(n, dtype):
    x = RNG.integers(0, 10, size=(7, n)).astype(dtype)
    got = np.asarray(rank_sort(jnp.asarray(x)))
    np.testing.assert_array_equal(got, np.sort(x, axis=-1))


def test_rank_sort_stability_payload():
    x = jnp.asarray([3, 1, 3, 1, 2], dtype=jnp.int32)
    p = jnp.arange(5, dtype=jnp.int32)
    v, pl = rank_sort(x, p)
    np.testing.assert_array_equal(np.asarray(v), [1, 1, 2, 3, 3])
    np.testing.assert_array_equal(np.asarray(pl), [1, 3, 4, 0, 2])  # stable


@pytest.mark.parametrize("runs", [(3, 4), (1, 1), (5, 2, 6), (2, 2, 2, 2)])
def test_rank_merge_runs(runs):
    parts = [np.sort(RNG.integers(0, 20, size=(4, r))) for r in runs]
    x = np.concatenate(parts, axis=-1)
    got = np.asarray(rank_merge_runs(jnp.asarray(x), runs))
    np.testing.assert_array_equal(got, np.sort(x, axis=-1))


def test_rank_merge_stability():
    # equal keys: earlier run wins
    a = jnp.asarray([5, 5]); b = jnp.asarray([5])
    p = jnp.asarray([0, 1, 2])
    v, pl = rank_merge_runs(jnp.concatenate([a, b]), (2, 1), p)
    np.testing.assert_array_equal(np.asarray(pl), [0, 1, 2])


# ---------------------------------------------------------------------------
# LOMS 2-way: paper claims C1 (2 stages, any mixture)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,n", [(1, 1), (1, 8), (8, 1), (7, 5), (5, 7), (2, 2),
                                 (8, 8), (3, 14), (16, 16), (13, 4)])
def test_loms_2way_two_stages_and_01_valid(m, n):
    s = loms_2way(m, n)
    assert depth(s) == 2
    assert validate_01_merge(s, (m, n))


@pytest.mark.parametrize("cols", [2, 4, 8])
@pytest.mark.parametrize("m,n", [(8, 8), (16, 16), (32, 32), (16, 8)])
def test_loms_multicolumn(cols, m, n):
    s = loms_2way(m, n, n_cols=cols)
    assert depth(s) == 2
    x = np.sort(RNG.integers(0, 1000, m)); y = np.sort(RNG.integers(0, 1000, n))
    got = np.asarray(merge(jnp.asarray(x), jnp.asarray(y), n_cols=cols))
    np.testing.assert_array_equal(got, np.sort(np.concatenate([x, y])))


def test_2col_matches_appendixA_k2():
    # Section IV arrays == Appendix-A k=2 construction
    for (m, n) in [(8, 8), (1, 8), (8, 1), (7, 5), (3, 4)]:
        assert build_2way_setup(m, n, 2).grid == build_kway_setup((m, n)).grid


@given(
    m=st.integers(1, 24), n=st.integers(1, 24),
    data=st.data(),
)
@settings(max_examples=40, deadline=None)
def test_loms_2way_property_random_values(m, n, data):
    a = np.sort(np.asarray(data.draw(st.lists(
        st.integers(-1000, 1000), min_size=m, max_size=m))))
    b = np.sort(np.asarray(data.draw(st.lists(
        st.integers(-1000, 1000), min_size=n, max_size=n))))
    got = np.asarray(merge(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, np.sort(np.concatenate([a, b])))


def test_s2ms_merge_is_stable():
    # paper ref [2]: "STABLE Single-Stage 2-Way Merge Sorters" — S2MS is
    # stable (A's equal keys precede B's). LOMS does not claim stability.
    a = jnp.asarray([1.0, 2.0, 2.0]); b = jnp.asarray([2.0, 3.0])
    pa = jnp.asarray([10, 11, 12]); pb = jnp.asarray([20, 21])
    v, p = merge(a, b, kind="s2ms", payload=(pa, pb))
    np.testing.assert_array_equal(np.asarray(v), [1, 2, 2, 2, 3])
    np.testing.assert_array_equal(np.asarray(p), [10, 11, 12, 20, 21])


def test_loms_merge_payload_is_consistent_permutation():
    a = jnp.asarray([1.0, 2.0, 2.0]); b = jnp.asarray([2.0, 3.0])
    pa = jnp.asarray([10, 11, 12]); pb = jnp.asarray([20, 21])
    v, p = merge(a, b, payload=(pa, pb))
    np.testing.assert_array_equal(np.asarray(v), [1, 2, 2, 2, 3])
    assert sorted(np.asarray(p).tolist()) == [10, 11, 12, 20, 21]
    # payload moved with its key
    key_of = {10: 1.0, 11: 2.0, 12: 2.0, 20: 2.0, 21: 3.0}
    np.testing.assert_array_equal(
        np.asarray(v), [key_of[int(t)] for t in np.asarray(p)])


# ---------------------------------------------------------------------------
# LOMS k-way: paper claims C2/C3 (Table 1 stage counts; median early exit)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lens", [(7, 7, 7), (3, 3, 3), (5, 5, 5), (4, 6, 2),
                                  (3, 3, 3, 3), (2, 2, 2, 2, 2), (1, 5, 3)])
def test_loms_kway_validates_at_table1_stages(lens):
    s = loms_kway(lens)  # builder 0-1-validates internally
    assert depth(s) == table1_stages(len(lens))


@pytest.mark.parametrize("lens", [(3, 3, 3), (5, 5, 5), (7, 7, 7)])
def test_loms_median_after_two_stages(lens):
    sched, pos = loms_median(lens)
    assert depth(sched) == 2
    # exhaustive 0-1 check that the median cell is final after 2 stages
    from repro.core.networks import _per_list_sorted_01_patterns
    pats = _per_list_sorted_01_patterns(lens)
    out = np.asarray(apply_schedule(sched, jnp.asarray(pats)))
    want = np.sort(pats, axis=-1)[:, (sum(lens) - 1) // 2]
    np.testing.assert_array_equal(out[:, pos], want)


def test_paper_fig6_worst_case():
    A = jnp.asarray([1, 2, 3, 4, 5, 6, 7])
    B = jnp.asarray([8, 9, 10, 11, 12, 13, 14])
    C = jnp.asarray([15, 16, 17, 18, 19, 20, 21])
    np.testing.assert_array_equal(np.asarray(merge_k([A, B, C])), np.arange(1, 22))
    assert int(median_of_lists([A, B, C])) == 11


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_loms_3way_property(data):
    lists = [np.sort(np.asarray(data.draw(
        st.lists(st.integers(-50, 50), min_size=ln, max_size=ln))))
        for ln in (7, 7, 7)]
    got = np.asarray(merge_k([jnp.asarray(l) for l in lists]))
    np.testing.assert_array_equal(got, np.sort(np.concatenate(lists)))


# ---------------------------------------------------------------------------
# Batcher baselines + depth comparisons (paper claim C6 ordering)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [2, 4, 8, 16, 32])
def test_batcher_merges_valid(m):
    for sched in (oems_merge(m, m), bitonic_merge(m, m)):
        assert validate_01_merge(sched, (m, m))
        assert depth(sched) == int(np.log2(2 * m))


@pytest.mark.parametrize("n", [4, 8, 16])
def test_batcher_full_sorts_valid(n):
    assert validate_01_sort(oems_sort(n))
    assert validate_01_sort(bitonic_sort(n))


@pytest.mark.parametrize("m", [4, 8, 16, 32, 64])
def test_depth_ranking_s2ms_loms_batcher(m):
    d_s2ms = depth(merge_schedule(m, m, "s2ms"))
    d_loms = depth(merge_schedule(m, m, "loms"))
    d_bat = depth(merge_schedule(m, m, "batcher-oe"))
    assert d_s2ms == 1 and d_loms == 2 and d_bat == int(np.log2(2 * m))
    assert d_s2ms < d_loms < d_bat


@pytest.mark.parametrize("m", [8, 16, 32, 64])
def test_resource_ranking_loms_below_s2ms(m):
    # paper claim C4: LOMS uses fewer comparators than same-size S2MS
    c_s2ms = comparator_count(merge_schedule(m, m, "s2ms"))
    c_loms = comparator_count(merge_schedule(m, m, "loms"))
    assert c_loms < c_s2ms


# ---------------------------------------------------------------------------
# MWMS baseline (paper claim C3 comparison)
# ---------------------------------------------------------------------------


def test_mwms_3c7r():
    s = mwms_kway((7, 7, 7))
    assert depth(s) >= 5  # our reconstruction: 6; published device: 5
    sm, pos = mwms_median((7, 7, 7))
    assert depth(sm) >= 4
    # LOMS is strictly shallower either way
    assert depth(loms_kway((7, 7, 7))) < depth(s)
    assert depth(loms_median((7, 7, 7))[0]) < depth(sm)


# ---------------------------------------------------------------------------
# full sort + topk API
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["loms", "bitonic", "oems", "rank"])
@pytest.mark.parametrize("n", [1, 2, 7, 16, 33, 64])
def test_full_sort(kind, n):
    if kind == "rank" and n > 64:
        pytest.skip("rank sort quadratic")
    x = RNG.standard_normal((5, n)).astype(np.float32)
    got = np.asarray(sort(jnp.asarray(x), kind=kind))
    np.testing.assert_allclose(got, np.sort(x, axis=-1))


def test_sort_with_payload_is_permutation():
    x = RNG.integers(0, 100, size=(3, 20)).astype(np.int32)
    v, p = sort(jnp.asarray(x), kind="loms", payload=jnp.broadcast_to(
        jnp.arange(20, dtype=jnp.int32), (3, 20)))
    np.testing.assert_array_equal(
        np.take_along_axis(x, np.asarray(p), -1), np.asarray(v))


@pytest.mark.parametrize("n,k,block", [(160, 6, 20), (128, 8, 16), (100, 4, 16),
                                       (1000, 50, 64), (7, 7, 4)])
def test_topk(n, k, block):
    x = RNG.standard_normal((6, n)).astype(np.float32)
    v, i = topk(jnp.asarray(x), k, block=block)
    want = np.sort(x, axis=-1)[:, ::-1][:, :k]
    np.testing.assert_allclose(np.asarray(v), want)
    np.testing.assert_allclose(np.take_along_axis(x, np.asarray(i), -1), want)


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_topk_property(data):
    n = data.draw(st.integers(8, 200))
    k = data.draw(st.integers(1, min(n, 16)))
    x = np.asarray(data.draw(st.lists(
        st.integers(-10_000, 10_000), min_size=n, max_size=n, unique=True)),
        dtype=np.int32)
    v, i = topk(jnp.asarray(x), k)
    np.testing.assert_array_equal(np.asarray(v), np.sort(x)[::-1][:k])


def test_median9_matches_numpy():
    w = RNG.standard_normal((32, 9)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(median9(jnp.asarray(w))), np.median(w, axis=-1))


# ---------------------------------------------------------------------------
# oblivious-ness: the schedule executor is jit/vmap/grad-free & shape-stable
# ---------------------------------------------------------------------------


def test_executor_is_jittable_and_vmappable():
    f = jax.jit(lambda a, b: merge(a, b))
    a = jnp.asarray(np.sort(RNG.integers(0, 9, (4, 8)), axis=-1))
    b = jnp.asarray(np.sort(RNG.integers(0, 9, (4, 8)), axis=-1))
    out = jax.vmap(f)(a, b)
    assert out.shape == (4, 16)
    got2 = f(a, b)  # batched leading axes without vmap
    np.testing.assert_array_equal(np.asarray(out), np.asarray(got2))


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.uint8, jnp.int32, jnp.uint32,
                                   jnp.float32, jnp.bfloat16])
def test_dtype_sweep_8bit_32bit(dtype):
    # the paper characterizes 8-bit and 32-bit sorters; we sweep wider
    info_max = 120
    x = RNG.integers(0, info_max, size=(4, 16)).astype(np.int32)
    y = RNG.integers(0, info_max, size=(4, 16)).astype(np.int32)
    a = jnp.sort(jnp.asarray(x).astype(dtype), axis=-1)
    b = jnp.sort(jnp.asarray(y).astype(dtype), axis=-1)
    got = merge(a, b)
    want = jnp.sort(jnp.concatenate([a, b], axis=-1), axis=-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
