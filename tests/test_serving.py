"""Serving engine + samplers."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import model_init
from repro.serving.engine import ServeConfig, generate
from repro.serving.sample import sample_greedy, sample_topk, sample_topp

RNG = np.random.default_rng(0)


def test_topk_sampler_respects_support():
    logits = jnp.asarray(RNG.standard_normal((64, 500)), jnp.float32)
    key = jax.random.PRNGKey(0)
    toks = sample_topk(key, logits, k=8, temperature=1.0)
    top8 = np.asarray(jax.lax.top_k(logits, 8)[1])
    for b in range(64):
        assert int(toks[b]) in top8[b]


def test_topp_sampler_respects_nucleus():
    # peaked distribution: nucleus of p=0.5 is a handful of tokens
    logits = jnp.asarray(RNG.standard_normal((32, 1000)) * 5, jnp.float32)
    toks = sample_topp(jax.random.PRNGKey(1), logits, p=0.5)
    probs = np.asarray(jax.nn.softmax(logits, -1))
    for b in range(32):
        order = np.argsort(probs[b])[::-1]
        cum = np.cumsum(probs[b][order])
        nucleus = set(order[: int(np.searchsorted(cum, 0.5)) + 1].tolist())
        assert int(toks[b]) in nucleus


def test_greedy_is_argmax():
    logits = jnp.asarray(RNG.standard_normal((8, 100)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(sample_greedy(logits)), np.argmax(np.asarray(logits), -1))


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_generate_end_to_end(temperature):
    cfg = get_smoke_config("chatglm3-6b")
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)}
    out = generate(params, batch, cfg,
                   ServeConfig(max_new_tokens=6, top_k=8,
                               temperature=temperature))
    assert out["tokens"].shape == (2, 6)
    assert (out["tokens"] >= 0).all() and (out["tokens"] < cfg.vocab_size).all()
    assert out["tok_per_s"] > 0


def test_padded_prefill_logits_bit_identical_to_solo():
    """A right-padded ragged prefill batch yields each row's first-token
    logits bit-identical to prefilling that prompt alone, unpadded."""
    from repro.models import init_cache, prefill

    cfg = get_smoke_config("chatglm3-6b")
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    lens = [5, 11, 16, 3]
    pad = max(lens)
    toks = np.zeros((4, pad), np.int32)
    for r, n in enumerate(lens):
        toks[r, :n] = RNG.integers(1, cfg.vocab_size, n)
    cache = init_cache(cfg, 4, pad)
    logits, _ = jax.jit(lambda p, b, c, ln: prefill(
        p, b, c, cfg=cfg, lengths=ln))(
            params, {"tokens": jnp.asarray(toks)}, cache,
            jnp.asarray(lens, jnp.int32))
    for r, n in enumerate(lens):
        solo_cache = init_cache(cfg, 1, pad)
        solo, _ = jax.jit(lambda p, b, c: prefill(p, b, c, cfg=cfg))(
            params, {"tokens": jnp.asarray(toks[r:r + 1, :n])}, solo_cache)
        np.testing.assert_array_equal(np.asarray(logits[r]),
                                      np.asarray(solo[0]))


def test_ragged_generate_greedy_bit_identical_to_solo():
    """Right-padded ragged generate() decodes each row bit-identically to
    the unpadded solo run (equal cache_len pins the XLA reduction)."""
    cfg = get_smoke_config("chatglm3-6b")
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    lens = [5, 11, 16, 3]
    toks = np.zeros((4, 16), np.int32)
    for r, n in enumerate(lens):
        toks[r, :n] = RNG.integers(1, cfg.vocab_size, n)
    sc = ServeConfig(max_new_tokens=5, temperature=0.0, cache_len=32)
    out = generate(params, {"tokens": toks, "lengths": np.asarray(lens)},
                   cfg, sc)
    for r, n in enumerate(lens):
        solo = generate(params, {"tokens": toks[r:r + 1, :n]}, cfg, sc)
        np.testing.assert_array_equal(out["tokens"][r], solo["tokens"][0])


def test_generate_greedy_deterministic():
    cfg = get_smoke_config("qwen3-8b")
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)}
    sc = ServeConfig(max_new_tokens=5, temperature=0.0)
    a = generate(params, batch, cfg, sc)["tokens"]
    b = generate(params, batch, cfg, sc)["tokens"]
    np.testing.assert_array_equal(a, b)
