"""Sentinel-aliasing and NaN-policy regression tests.

Padding sentinels are finite dtype extremes, so genuine extreme values
(``INT32_MAX``, ``uint32`` zeros, float ±inf) can tie them. These tests
pin the contract: values are never dropped or reordered by a pad, indices
and payloads are decided by validity masks (never by comparing against
the sentinel value), and float specials follow the documented
``nan_policy="last"`` ordering (NaNs last, like ``jnp.sort``).
"""
import numpy as np
import jax.numpy as jnp
import pytest

import repro
from repro.api import schedules
from repro.api.keys import decode_keys, encode_keys, has_key_transform

I32 = np.iinfo(np.int32)


# ---------------------------------------------------------------------------
# integer sentinel aliasing
# ---------------------------------------------------------------------------


def test_topk_keeps_genuine_int32_min():
    """The block pad used to be -_dtype_max = min+1, which outranked a
    genuine iinfo.min and replaced it (wrong value, index -1)."""
    x = jnp.asarray([[I32.min, 5, I32.max, 0, I32.min, 7]], jnp.int32)
    v, i = repro.topk(x, 6)
    assert np.asarray(v)[0].tolist() == [I32.max, 7, 5, 0, I32.min, I32.min]
    assert sorted(np.asarray(i)[0].tolist()) == [0, 1, 2, 3, 4, 5]
    taken = np.take_along_axis(np.asarray(x), np.asarray(i), -1)
    np.testing.assert_array_equal(taken, np.asarray(v))


def test_topk_keeps_genuine_uint32_zeros():
    """uint32 pads used to wrap (-max -> 1) and sort above genuine 0s."""
    x = jnp.asarray([[0, 3, 2**32 - 1, 0, 1]], jnp.uint32)
    v, i = repro.topk(x, 5)
    assert np.asarray(v)[0].tolist() == [2**32 - 1, 3, 1, 0, 0]
    assert sorted(np.asarray(i)[0].tolist()) == [0, 1, 2, 3, 4]


def test_schedules_topk_direct_int_extremes():
    x = jnp.asarray([[I32.min, I32.min + 1, I32.min]], jnp.int32)
    v, i = schedules.topk(x, 3, block=2)  # forces a padded block
    assert np.asarray(v)[0].tolist() == [I32.min + 1, I32.min, I32.min]
    assert sorted(np.asarray(i)[0].tolist()) == [0, 1, 2]


def test_sort_payload_not_aliased_by_pow2_padding():
    """Non-power-of-two payload sorts pad with +max; a genuine INT32_MAX
    used to be able to swap payloads with a pad slot."""
    x = jnp.asarray([[I32.max, 1, I32.max, 0, 2]], jnp.int32)  # pads to 8
    pay = jnp.asarray([[10, 11, 12, 13, 14]], jnp.int32)
    out, tree = repro.sort(x, payload={"p": pay})
    assert np.asarray(out)[0].tolist() == [0, 1, 2, I32.max, I32.max]
    assert sorted(np.asarray(tree["p"])[0].tolist()) == [10, 11, 12, 13, 14]
    assert set(np.asarray(tree["p"])[0, 3:].tolist()) == {10, 12}


def test_sort_uint32_with_zeros_and_max():
    x = jnp.asarray([[2**32 - 1, 0, 7, 0, 2**32 - 1, 1, 0]], jnp.uint32)
    out = repro.sort(x)
    np.testing.assert_array_equal(
        np.asarray(out), np.sort(np.asarray(x), -1))


@pytest.mark.parametrize("dtype,hi", [(jnp.int32, I32.max), (jnp.uint32, 2**32 - 1)])
def test_chunked_merges_value_exact_at_extremes(dtype, hi):
    """Streaming drain tiles pad with the dtype max: a genuine extreme in
    the data must still come out (a tied sentinel stands in value-
    identically)."""
    from repro.streaming import chunked_merge, chunked_merge_k

    rng = np.random.default_rng(3)
    a = np.sort(rng.integers(0, 50, (2, 40)).astype(np.int64), -1)
    b = np.sort(rng.integers(0, 50, (2, 24)).astype(np.int64), -1)
    a[:, -3:] = hi  # saturated tails alias the drain sentinels
    b[0, :2] = 0
    ja, jb = jnp.asarray(a, dtype), jnp.asarray(b, dtype)
    out = chunked_merge(ja, jb, tile=8)
    ref = np.sort(np.concatenate([a, b], -1), -1)
    np.testing.assert_array_equal(np.asarray(out).astype(np.int64), ref)
    lists = [ja, jb, jnp.asarray(np.full((2, 16), hi), dtype)]
    out = chunked_merge_k(lists, tile=8)
    ref = np.sort(np.concatenate([a, b, np.full((2, 16), hi)], -1), -1)
    np.testing.assert_array_equal(np.asarray(out).astype(np.int64), ref)


def test_stable_compact_moves_invalid_last_stably():
    from repro.kernels.common import stable_compact

    vals = jnp.asarray([[1, 9, 2, 9, 3]], jnp.int32)
    pos = jnp.asarray([[0, -1, 1, -1, 2]], jnp.int32)
    v, p = stable_compact(pos >= 0, vals, pos)
    assert np.asarray(v)[0].tolist() == [1, 2, 3, 9, 9]
    assert np.asarray(p)[0].tolist() == [0, 1, 2, -1, -1]


def test_kernel_topk_int32_exact_past_mantissa():
    """kernels.ops.topk must not route int32 through the f32 one-hot
    matmul: values past 2^24 would come back corrupted."""
    from repro.kernels.ops import topk as kernel_topk

    base = 1 << 28
    x = jnp.asarray([[base + 3, base + 1, base + 7, base + 5]], jnp.int32)
    x = jnp.broadcast_to(x, (4, 4))
    v, i = kernel_topk(x, 2)
    assert np.asarray(v)[0].tolist() == [base + 7, base + 5]
    assert np.asarray(i)[0].tolist() == [2, 3]


# ---------------------------------------------------------------------------
# NaN policy / total-order keys
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_key_transform_roundtrip_and_order(dtype):
    xs = jnp.asarray([np.nan, -np.inf, -3.5, -0.0, 0.0, 1.0, np.inf], dtype)
    assert has_key_transform(dtype)
    k = encode_keys(xs)
    assert k.dtype == jnp.int32
    # strictly increasing keys for the strictly increasing specials, NaN last
    kk = np.asarray(k)
    order = np.argsort(kk, kind="stable")
    back = np.asarray(decode_keys(k, dtype).astype(jnp.float32))[order]
    np.testing.assert_array_equal(
        back, np.sort(np.asarray(xs.astype(jnp.float32))))
    # bijective: exact bit roundtrip (NaN canonicalized)
    np.testing.assert_array_equal(
        np.asarray(decode_keys(k, dtype).astype(jnp.float32)),
        np.asarray(xs.astype(jnp.float32)))


def test_sort_nans_last_like_jnp():
    x = jnp.asarray([[np.nan, 1.0, -np.inf, np.inf, 0.0, np.nan, -1.0]],
                    jnp.float32)
    np.testing.assert_array_equal(np.asarray(repro.sort(x)),
                                  np.sort(np.asarray(x), -1))
    np.testing.assert_array_equal(np.asarray(repro.sort(x, descending=True)),
                                  np.sort(np.asarray(x), -1)[:, ::-1])


def test_merge_with_inf_inputs_exact():
    a = jnp.asarray([[-np.inf, 0.0, np.inf]], jnp.float32)
    b = jnp.asarray([[-1.0, np.inf]], jnp.float32)
    out = repro.merge(a, b)
    np.testing.assert_array_equal(
        np.asarray(out),
        np.sort(np.concatenate([np.asarray(a), np.asarray(b)], -1), -1))


def test_topk_with_masked_neg_inf_logits():
    """Masked -inf logits used to sort below the finite -max pad; with the
    key transform they stay genuine candidates with real indices."""
    x = jnp.asarray([[1.0, -np.inf, 2.0, -np.inf]], jnp.float32)
    v, i = repro.topk(x, 4)
    assert np.asarray(v)[0].tolist() == [2.0, 1.0, -np.inf, -np.inf]
    assert sorted(np.asarray(i)[0].tolist()) == [0, 1, 2, 3]


def test_nan_policy_unsafe_skips_transform():
    """The opt-out keeps the raw-float path (exact on finite inputs)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 9)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(repro.sort(x, nan_policy="unsafe")),
        np.sort(np.asarray(x), -1))
    with pytest.raises(AssertionError):
        repro.sort(x, nan_policy="sometimes")


def test_values_only_sort_and_merge_stay_differentiable():
    """The key pre-pass must not sever gradients: the custom-VJP decode
    recovers the sort permutation in the backward pass."""
    import jax

    g = jax.grad(lambda v: repro.sort(v).sum())(jnp.asarray([3.0, 1.0, 2.0]))
    assert np.asarray(g).tolist() == [1.0, 1.0, 1.0]
    w = jnp.asarray([1.0, 2.0, 3.0])
    g = jax.grad(lambda v: (repro.sort(v, descending=True) * w).sum())(
        jnp.asarray([3.0, 1.0, 2.0]))
    assert np.asarray(g).tolist() == [1.0, 3.0, 2.0]
    a, b = jnp.asarray([[1.0, 4.0]]), jnp.asarray([[2.0, 3.0]])
    wm = jnp.asarray([1.0, 10.0, 100.0, 1000.0])
    ga = jax.grad(lambda x, y: (repro.merge(x, y) * wm).sum())(a, b)
    assert np.asarray(ga).tolist() == [[1.0, 1000.0]]


def test_median_stays_differentiable():
    import jax

    a0 = jnp.asarray([[2.0, 4.0, 6.0]])
    b = jnp.asarray([[1.0, 3.0, 9.0]])
    c = jnp.asarray([[0.0, 5.0, 7.0]])
    assert float(repro.median_of_lists([a0, b, c])[0]) == 4.0
    g = jax.grad(lambda a: repro.median_of_lists([a, b, c]).sum())(a0)
    assert np.asarray(g).tolist() == [[0.0, 1.0, 0.0]]


def test_sort_float64_nans_last_under_x64():
    import jax

    if not jax.config.read("jax_enable_x64"):
        pytest.skip("x64 disabled: no float64 arrays exist")
    x = jnp.asarray([[3.0, np.nan, 1.0, 2.0]], jnp.float64)
    np.testing.assert_array_equal(np.asarray(repro.sort(x)),
                                  np.sort(np.asarray(x), -1))


def test_merge_mixed_float_dtypes_promotes():
    """Mixed-width float lists must promote before key encoding: int16 and
    int32 keys are not comparable."""
    out = repro.merge(jnp.asarray([[0.5, 1.5, 2.5]], jnp.float32),
                      jnp.asarray([[1.0, 2.0, 3.0]], jnp.bfloat16))
    assert out.dtype == jnp.float32
    assert np.asarray(out).tolist() == [[0.5, 1.0, 1.5, 2.0, 2.5, 3.0]]


def test_median_with_inf():
    ls = [jnp.asarray([[-np.inf, 0.0, np.inf]], jnp.float32),
          jnp.asarray([[-1.0, 1.0, np.inf]], jnp.float32),
          jnp.asarray([[-np.inf, 2.0, 3.0]], jnp.float32)]
    m = repro.median_of_lists(ls)
    ref = np.sort(np.concatenate([np.asarray(l) for l in ls], -1), -1)[:, 4]
    np.testing.assert_array_equal(np.asarray(m), ref)
