"""Streaming subsystem walkthrough: chunked merges + planner + tree top-k.

  PYTHONPATH=src python examples/stream_merge.py

Merges two 100k-element sorted streams through a 512-wide LOMS pipeline,
4-way merges ragged shard lists, and shows the planner/autotune cache.
"""
import numpy as np
import jax
import jax.numpy as jnp

import repro
from repro import SortSpec
from repro.streaming import (
    autotune_merge2,
    chunked_merge_k,
    plan_chunked,
    tree_topk,
)
from repro.streaming.cache import AutotuneCache


def main():
    rng = np.random.default_rng(0)

    # 1) two sorted streams far larger than any single kernel tile: the
    #    unified API's planner routes this to the chunked pipeline itself
    a = jnp.sort(jnp.asarray(rng.standard_normal(100_000), jnp.float32))
    b = jnp.sort(jnp.asarray(rng.standard_normal(100_000), jnp.float32))
    dec = repro.plan(SortSpec(op="merge", lengths=(100_000, 100_000),
                              device=jax.default_backend()))
    plan = plan_chunked(a.shape[-1], b.shape[-1], batch=1)
    out = repro.merge(a, b)
    ok = bool(jnp.all(out[1:] >= out[:-1]))
    print(f"repro.merge -> {dec.backend}/{dec.detail}: merged "
          f"{out.shape[-1]} elems in {plan.tile}-wide tiles, sorted={ok}")

    # 2) k-way: ragged per-shard candidate lists
    lists = [jnp.sort(jnp.asarray(rng.standard_normal(n), jnp.float32))
             for n in (5000, 1234, 777, 4096)]
    outk = chunked_merge_k(lists, tile=128)
    print(f"chunked 4-way: {outk.shape[-1]} elems, "
          f"sorted={bool(jnp.all(outk[1:] >= outk[:-1]))}")

    # 3) device-tree top-k (single-device log-tree here; pass mesh/axis on a
    #    TP-sharded vocab to reduce over devices)
    logits = jnp.asarray(rng.standard_normal((4, 32_000)), jnp.float32)
    vals, idx = tree_topk(logits, 32)
    ref_vals, _ = jax.lax.top_k(logits, 32)
    print(f"tree top-k: match lax.top_k = "
          f"{bool(jnp.allclose(vals, ref_vals))}")

    # 4) planner autotune: measure once, cached on disk afterwards
    cache = AutotuneCache("/tmp/repro_example_autotune.json")
    tuned = autotune_merge2(256, 256, batch=8, cache=cache)
    again = autotune_merge2(256, 256, batch=8, cache=cache)
    print(f"autotune: picked n_cols={tuned.n_cols} "
          f"block_batch={tuned.block_batch} use_mxu={tuned.use_mxu} "
          f"(source={tuned.source}); second call source={again.source}")


if __name__ == "__main__":
    main()
