"""Serving example: batched generation with the LOMS top-k sampler.

  PYTHONPATH=src python examples/serve_topk.py [--arch qwen3-8b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model_init
from repro.serving.engine import ServeConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, 32)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.frontend_len, cfg.frontend_dim)),
            jnp.float32)
    out = generate(params, batch, cfg,
                   ServeConfig(max_new_tokens=args.new_tokens, top_k=16,
                               temperature=0.8))
    print("generated:", out["tokens"])
    print(f"{out['tok_per_s']:.1f} tok/s (LOMS top-k sampler)")


if __name__ == "__main__":
    main()
