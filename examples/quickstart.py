"""Quickstart: the paper's List Offset Merge Sorters as a JAX library.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (depth, comparator_count, loms_2way, loms_kway,
                        merge, merge_k, merge_schedule, median_of_lists,
                        sort, topk)


def main():
    rng = np.random.default_rng(0)

    # --- 2-way merge: any UP-x/DN-y mixture, always 2 stages -------------
    a = jnp.sort(jnp.asarray(rng.integers(0, 100, 7)))
    b = jnp.sort(jnp.asarray(rng.integers(0, 100, 5)))
    print("UP-7/DN-5 merged:", merge(a, b))
    print("  LOMS stages:", depth(loms_2way(7, 5)),
          "| Batcher 8+8 stages:", depth(merge_schedule(8, 8, "batcher-oe")))

    # --- 3-way merge + 2-stage median (paper Fig. 6) ----------------------
    lists = [jnp.sort(jnp.asarray(rng.integers(0, 100, 7))) for _ in range(3)]
    print("3c_7r merged:", merge_k(lists))
    print("median after 2 stages:", median_of_lists(lists))
    s3 = loms_kway((7, 7, 7))
    print("  stages:", depth(s3), "comparators:", comparator_count(s3))

    # --- batched full sort + top-k (the LLM hot paths) --------------------
    x = jnp.asarray(rng.standard_normal((4, 160)), jnp.float32)
    v, i = topk(x, 6, block=32)  # the MoE router op (blockwise LOMS merges)
    print("router top-6 values:", np.asarray(v[0]).round(2))
    print("full sort matches numpy:",
          bool((np.asarray(sort(x)) == np.sort(np.asarray(x), -1)).all()))


if __name__ == "__main__":
    main()
