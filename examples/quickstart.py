"""Quickstart: the paper's List Offset Merge Sorters behind one namespace.

  PYTHONPATH=src python examples/quickstart.py

``repro.merge / merge_k / sort / topk / median_of_lists`` — callers state
*what* to sort (any axis, either direction, stable or not, arbitrary
pytree payloads riding the permutation) and the planner picks *how*:
schedule executor, Pallas kernel, chunked streaming pipeline, or the
device-tree sharded reduction (DESIGN.md §9).
"""
import numpy as np
import jax.numpy as jnp

import repro
from repro import SortSpec
from repro.api import schedules
from repro.core import comparator_count, depth, loms_2way, loms_kway


def main():
    rng = np.random.default_rng(0)

    # --- 2-way merge: any UP-x/DN-y mixture, always a 2-stage device ------
    a = jnp.sort(jnp.asarray(rng.integers(0, 100, 7)))
    b = jnp.sort(jnp.asarray(rng.integers(0, 100, 5)))
    print("UP-7/DN-5 merged:", repro.merge(a, b))
    print("  LOMS stages:", depth(loms_2way(7, 5)),
          "| Batcher 8+8 stages:",
          depth(schedules.merge_schedule(8, 8, "batcher-oe")))

    # --- 3-way merge + 2-stage median (paper Fig. 6) ----------------------
    lists = [jnp.sort(jnp.asarray(rng.integers(0, 100, 7))) for _ in range(3)]
    print("3c_7r merged:", repro.merge_k(lists))
    print("median after 2 stages:", repro.median_of_lists(lists))
    s3 = loms_kway((7, 7, 7))
    print("  stages:", depth(s3), "comparators:", comparator_count(s3))

    # --- uniform semantics: axis, descending, stable, pytree payloads -----
    x = jnp.asarray(rng.standard_normal((4, 160)), jnp.float32)
    col_sorted = repro.sort(x, axis=0, descending=True)  # sort each column
    print("axis=0 descending sort ok:",
          bool((jnp.diff(col_sorted, axis=0) <= 0).all()))
    toks = jnp.asarray(rng.integers(0, 50, 12), jnp.int32)
    emb = jnp.asarray(rng.standard_normal((12, 8)), jnp.float32)
    sorted_toks, carried = repro.sort(
        toks, stable=True, payload={"emb": emb, "pos": jnp.arange(12)})
    print("pytree payload rides the permutation:",
          sorted_toks.shape, carried["emb"].shape, carried["pos"][:4])

    # --- top-k (the MoE-router / sampler primitive), planner-routed -------
    v, i = repro.topk(x, 6)
    print("router top-6 values:", np.asarray(v[0]).round(2))
    print("full sort matches numpy:",
          bool((np.asarray(repro.sort(x)) == np.sort(np.asarray(x), -1)).all()))

    # --- the dispatch layer is inspectable --------------------------------
    for spec in (
        SortSpec(op="topk", lengths=(x.shape[-1],), k=6, batch=4, device="cpu"),
        SortSpec(op="topk", lengths=(152_064,), k=64, batch=8, device="tpu"),
        SortSpec(op="merge", lengths=(100_000, 100_000), device="tpu"),
    ):
        d = repro.plan(spec)
        print(f"plan {spec.describe():42s} -> {d.backend}/{d.detail}")


if __name__ == "__main__":
    main()
