"""End-to-end driver: train a small MoE LM (deepseek-v2-lite family) with
LOMS routing for a few hundred steps on CPU, with checkpoint/restart.

  PYTHONPATH=src python examples/train_tiny_moe.py [--steps 200]
"""
import argparse
import dataclasses
import shutil

from repro.configs import get_smoke_config
from repro.data import DataConfig
from repro.optim import OptConfig
from repro.runtime import TrainConfig, train_with_retries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tiny_moe")
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config("deepseek-v2-lite-16b")
    # bump width a little so the loss curve is meaningful (~100M-class at
    # full scale; still CPU-friendly here)
    cfg = dataclasses.replace(cfg, d_model=128, n_layers=4)
    if args.fresh:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    out = train_with_retries(
        cfg,
        DataConfig(seq_len=128, global_batch=8, seed=7),
        TrainConfig(steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt_dir,
                    log_every=20),
        OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        retries=2,
    )
    print(f"loss: {out['losses'][0]:.3f} -> {out['final_loss']:.3f} "
          f"over {len(out['losses'])} steps")


if __name__ == "__main__":
    main()
