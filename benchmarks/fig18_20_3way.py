"""Paper Figs. 18/19/20 + Table 1: 3-way merge (3c_7r) and k-way stages.

LOMS 3c_7r: full merge in 3 stages, median in 2 — vs the MWMS baseline
(published device: 5/4 stages; our best non-offset reconstruction: 6/5).
Wall times are batched JAX executor runs; stage counts are structural.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (apply_schedule, comparator_count, depth, loms_kway,
                        loms_median, table1_stages)
from repro.core.mwms import mwms_kway, mwms_median
from .common import emit, timeit

BATCH = 256


def run():
    rng = np.random.default_rng(2)
    lens = (7, 7, 7)
    for bits, dt in ((8, jnp.uint8), (32, jnp.int32)):
        xs = [jnp.sort(jnp.asarray(
            rng.integers(0, 255 if bits == 8 else 1 << 20, (BATCH, 7))).astype(dt), -1)
            for _ in range(3)]
        x = jnp.concatenate(xs, axis=-1)
        # full merge
        for name, sched in (("loms", loms_kway(lens)), ("mwms", mwms_kway(lens))):
            f = jax.jit(lambda x, s=sched: apply_schedule(s, x))
            t = timeit(f, x)
            emit(f"fig19/{bits}b/{name}/3c_7r", t * 1e6,
                 f"stages={depth(sched)};cmps={comparator_count(sched)}")
        # median
        for name, (sched, pos) in (("loms", loms_median(lens)),
                                   ("mwms", mwms_median(lens))):
            f = jax.jit(lambda x, s=sched, p=pos: apply_schedule(s, x)[..., p])
            t = timeit(f, x)
            emit(f"fig18/{bits}b/{name}/3c_7r_median", t * 1e6,
                 f"stages={depth(sched)}")
    # fig 20 resources
    for name, sched in (("loms", loms_kway(lens)), ("mwms", mwms_kway(lens))):
        emit(f"fig20/{name}/3c_7r", 0.0, f"cmps={comparator_count(sched)}")
    # Table 1 stage counts, k = 2..8 (empirically 0-1-validated at build)
    for k in range(2, 9):
        lens_k = tuple([3] * k)
        sched = loms_kway(lens_k)
        emit(f"table1/k{k}", 0.0,
             f"stages={depth(sched)};paper={table1_stages(k)}")


if __name__ == "__main__":
    run()
