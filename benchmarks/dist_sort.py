"""Distributed sample-sort benchmark: single- vs multi-device throughput.

  PYTHONPATH=src python -m benchmarks.dist_sort
      spawns itself with XLA_FLAGS=--xla_force_host_platform_device_count=8
      so the PSRS pipeline actually spans 8 (virtual) devices, and records
      both configurations into the BENCH json flow
      (experiments/bench/dist_sort.json) alongside the usual CSV rows;

  PYTHONPATH=src python -m benchmarks.run --only dist_sort
      in-process single-configuration run at the current device count.

On a CPU host the 8 virtual devices share the same silicon, so the
multi-device rows measure pipeline overhead (partition + two all_to_alls),
not speedup — the json records device_count so downstream comparisons
know which regime they are reading.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Optional

RESULTS_PATH = os.path.join("experiments", "bench", "dist_sort.json")
_CHILD_ENV = "_REPRO_DIST_BENCH_CHILD"


def run(json_path: Optional[str] = None) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro
    from repro.parallel.sharding import Parallelism

    from .common import emit, timeit

    ndev = jax.device_count()
    rng = np.random.default_rng(0)
    par = None
    if ndev > 1:
        mesh = jax.make_mesh((ndev,), ("model",))
        par = Parallelism(mesh=mesh, dp_axes=(), tp_axis="model",
                          fsdp_axis=None)
    records = []
    for n in (16_384, 65_536):
        x = jnp.asarray(rng.standard_normal((1, n)), jnp.float32)
        f1 = jax.jit(lambda v: repro.sort(v))
        t1 = timeit(f1, x, warmup=1, iters=3)
        emit(f"dist_sort/single_n{n}", t1 * 1e6, f"{n / t1 / 1e6:.2f}Melem/s")
        records.append({"name": f"single_n{n}", "devices": 1,
                        "us_per_call": t1 * 1e6, "melem_per_s": n / t1 / 1e6})
        if par is not None:
            fd = jax.jit(lambda v: repro.sort(v, par=par))
            td = timeit(fd, x, warmup=1, iters=3)
            emit(f"dist_sort/dist{ndev}_n{n}", td * 1e6,
                 f"{n / td / 1e6:.2f}Melem/s")
            records.append({"name": f"dist{ndev}_n{n}", "devices": ndev,
                            "us_per_call": td * 1e6,
                            "melem_per_s": n / td / 1e6})
    # k-way merge: 4 pre-sorted lists
    lists = [jnp.sort(jnp.asarray(rng.standard_normal((1, 16_384)), jnp.float32), -1)
             for _ in range(4)]
    fm = jax.jit(lambda *ls: repro.merge_k(list(ls)))
    tm = timeit(fm, *lists, warmup=1, iters=3)
    emit("dist_sort/merge4_single_n16384", tm * 1e6)
    records.append({"name": "merge4_single_n16384", "devices": 1,
                    "us_per_call": tm * 1e6})
    if par is not None:
        fmd = jax.jit(lambda *ls: repro.merge_k(list(ls), par=par))
        tmd = timeit(fmd, *lists, warmup=1, iters=3)
        emit(f"dist_sort/merge4_dist{ndev}_n16384", tmd * 1e6)
        records.append({"name": f"merge4_dist{ndev}_n16384", "devices": ndev,
                        "us_per_call": tmd * 1e6})
    if json_path:
        os.makedirs(os.path.dirname(json_path), exist_ok=True)
        payload = {"bench": "dist_sort", "device_count": ndev,
                   "backend": jax.default_backend(), "rows": records}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {json_path}", file=sys.stderr)


def main() -> None:
    if os.environ.get(_CHILD_ENV) == "1":
        print("name,us_per_call,derived")
        run(json_path=RESULTS_PATH)
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env[_CHILD_ENV] = "1"
    env.setdefault("PYTHONPATH", "src")
    subprocess.run([sys.executable, "-m", "benchmarks.dist_sort"], env=env,
                   check=True)


if __name__ == "__main__":
    main()
