"""Framework-level benchmark: LOMS routing vs XLA sort/top_k baselines.

Covers the paper technique where it actually runs in the LLM: (a) router
top-k over experts (LOMS blockwise merge vs jax.lax.top_k), (b) vocab
top-k at decode (Pallas kernel vs jax.lax.top_k), (c) oblivious
position-in-expert (LOMS sort) vs cumsum dispatch.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.api import topk as unified_topk
from repro.models.moe import _positions_cumsum, _positions_sorted
from .common import emit, timeit


def run():
    rng = np.random.default_rng(3)
    # (a) router top-k: deepseek (64e top-6) and qwen3-moe (128e top-8)
    for e, k in ((64, 6), (128, 8), (160, 6)):
        logits = jnp.asarray(rng.standard_normal((4096, e)), jnp.float32)
        f_loms = jax.jit(lambda x: unified_topk(x, k, block=32,
                                                backend="schedule"))
        f_xla = jax.jit(lambda x: jax.lax.top_k(x, k))
        emit(f"moe_router/loms/e{e}k{k}", timeit(f_loms, logits) * 1e6,
             "blockwise LOMS merge")
        emit(f"moe_router/xla/e{e}k{k}", timeit(f_xla, logits) * 1e6,
             "jax.lax.top_k")
    # (b) vocab top-k (decode sampling)
    v = 32_000
    logits = jnp.asarray(rng.standard_normal((8, v)), jnp.float32)
    f_kern = jax.jit(lambda x: unified_topk(x, 64, backend="pallas"))
    f_xla = jax.jit(lambda x: jax.lax.top_k(x, 64))
    emit("vocab_topk/loms_kernel/v32k", timeit(f_kern, logits, iters=3) * 1e6, "")
    emit("vocab_topk/xla/v32k", timeit(f_xla, logits, iters=3) * 1e6, "")
    # (c) dispatch position computation
    eids = jnp.asarray(rng.integers(0, 16, (2048,)), jnp.int32)
    f_sort = jax.jit(lambda e: _positions_sorted(e, 16))
    f_csum = jax.jit(lambda e: _positions_cumsum(e, 16))
    np.testing.assert_array_equal(np.asarray(f_sort(eids)), np.asarray(f_csum(eids)))
    emit("dispatch_pos/loms_sorted/t2048", timeit(f_sort, eids) * 1e6,
         "oblivious (paper's security use case)")
    emit("dispatch_pos/cumsum/t2048", timeit(f_csum, eids) * 1e6, "")


if __name__ == "__main__":
    run()
