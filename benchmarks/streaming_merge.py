"""Streaming subsystem benchmarks: chunked merges, tree top-k, autotune.

  PYTHONPATH=src python -m benchmarks.run --only streaming

Rows:
  * chunked 2-way merge vs. monolithic jnp.sort of the concatenation, at
    input lengths far beyond a single kernel tile;
  * k-way chunked merge across tile sizes (the planner default vs. forced);
  * single-device tree top-k vs. jax.lax.top_k at vocab scale;
  * autotuned vs. heuristic plan for a mid-size 2-way merge.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.streaming import (
    autotune_merge2,
    chunked_merge,
    chunked_merge_k,
    plan_chunked,
    tree_topk,
)

from .common import emit, sorted_batch, timeit

RNG = np.random.default_rng(0)


def run() -> None:
    b = 4
    for n in (8192, 32768):
        a = sorted_batch(RNG, b, n)
        c = sorted_batch(RNG, b, n)
        tile = plan_chunked(n, n, batch=b).tile
        fn = jax.jit(functools.partial(chunked_merge, tile=tile))
        t = timeit(fn, a, c)
        emit(f"chunked_merge2_n{n}_tile{tile}", t * 1e6,
             f"{2 * n * b / t / 1e6:.1f}Melem/s")
        ref = jax.jit(lambda x, y: jnp.sort(jnp.concatenate([x, y], -1), -1))
        t_ref = timeit(ref, a, c)
        emit(f"concat_sort_n{n}", t_ref * 1e6, "baseline")

    lists = [sorted_batch(RNG, b, 2048) for _ in range(4)]
    for tile in (64, 128):
        fn = jax.jit(functools.partial(chunked_merge_k, tile=tile))
        t = timeit(fn, lists)
        emit(f"chunked_merge4_tile{tile}", t * 1e6,
             f"{4 * 2048 * b / t / 1e6:.1f}Melem/s")

    v = jnp.asarray(RNG.standard_normal((b, 32768)), jnp.float32)
    t = timeit(jax.jit(functools.partial(tree_topk, k=64)), v)
    emit("tree_topk_v32768_k64", t * 1e6, "")
    t_ref = timeit(jax.jit(lambda x: jax.lax.top_k(x, 64)), v)
    emit("lax_topk_v32768_k64", t_ref * 1e6, "baseline")

    from repro.kernels.loms_merge import loms_merge2_pallas
    from repro.streaming.cache import AutotuneCache

    m = n_ = 256
    a = sorted_batch(RNG, 8, m)
    c = sorted_batch(RNG, 8, n_)
    tuned = autotune_merge2(m, n_, batch=8, cache=AutotuneCache(
        path="/tmp/repro_bench_autotune.json"))
    for tag, plan in (("autotuned", tuned),):
        fn = jax.jit(functools.partial(
            loms_merge2_pallas, n_cols=plan.n_cols,
            block_batch=plan.block_batch, use_mxu=plan.use_mxu,
            interpret=jax.default_backend() != "tpu"))
        t = timeit(fn, a, c)
        emit(f"merge2_{m}x{n_}_{tag}", t * 1e6,
             f"ncols{plan.n_cols}_bb{plan.block_batch}_mxu{int(plan.use_mxu)}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
