"""Decode microbenchmark → ``BENCH_serve.json`` (+ the obs smoke gate).

For each case this runs the real serving path — ``init_cache`` →
``prefill`` → jitted decode loop with donated cache — on a smoke-scale
model config, with ``ServeConfig.time_steps`` on so every decode step is
host-timed, and reports:

* ``tok_per_s``             — decode throughput (batch tokens / decode wall)
* ``prefill_us``            — one synchronized prefill
* ``decode_step_p50/95/99`` — per-step latency percentiles

Rows land in the repo-root ``BENCH_serve.json`` trajectory (schema
mirrors ``BENCH_sort.json``). Wall numbers are informational off-TPU
(interpret-mode kernels); the ``--check`` gate asserts *structure*, never
timing:

* every case produced tokens in-range and ``tok_per_s > 0``;
* the p50/p95/p99 fields are present and ordered;
* with obs forced on, one generate() leaves ``serve.prefill`` /
  ``serve.decode`` spans and serve counters in the snapshot, and the
  exported Chrome trace (written next to the JSON) passes the
  trace-event schema check — the CI obs-enabled benchmark row.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import numpy as np

from .common import emit

BENCH_SERVE_JSON = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_serve.json")

#: (model, batch, prompt_len, new_tokens, top_k, temperature)
CASES = [
    ("chatglm3-6b", 2, 16, 8, 8, 1.0),
    ("qwen3-8b", 2, 12, 6, 0, 0.0),  # greedy decode
]


def _run_case(model, batch_size, prompt_len, new_tokens, top_k, temperature):
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.models import model_init
    from repro.serving.engine import ServeConfig, generate

    cfg = get_smoke_config(model)
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch_size, prompt_len)), jnp.int32)}
    sc = ServeConfig(max_new_tokens=new_tokens, top_k=top_k,
                     temperature=temperature, time_steps=True)
    out = generate(params, batch, cfg, sc)
    row = {
        "model": model,
        "batch": batch_size,
        "prompt_len": prompt_len,
        "new_tokens": new_tokens,
        "top_k": top_k,
        "temperature": temperature,
        "tok_per_s": round(float(out["tok_per_s"]), 2),
        "prefill_us": round(float(out["prefill_s"]) * 1e6, 1),
        "decode_us": round(float(out["decode_s"]) * 1e6, 1),
        "p50_us": round(out["decode_step_p50_us"], 1),
        "p95_us": round(out["decode_step_p95_us"], 1),
        "p99_us": round(out["decode_step_p99_us"], 1),
        "platform": jax.default_backend(),
    }
    failures = []
    toks = out["tokens"]
    if toks.shape != (batch_size, new_tokens):
        failures.append(f"{model}: tokens shape {toks.shape}")
    if not ((toks >= 0).all() and (toks < cfg.vocab_size).all()):
        failures.append(f"{model}: tokens out of vocab range")
    if not out["tok_per_s"] > 0:
        failures.append(f"{model}: tok_per_s {out['tok_per_s']}")
    if not (row["p50_us"] <= row["p95_us"] <= row["p99_us"]):
        failures.append(f"{model}: decode percentiles not ordered")
    return row, failures


def write_serve_json(rows) -> str:
    path = os.path.abspath(BENCH_SERVE_JSON)
    payload = {
        "schema": 1,
        "generated_unix": int(time.time()),
        "platform": jax.default_backend(),
        "note": ("tokens/sec + per-decode-step latency percentiles; "
                 "wall numbers are informational off-TPU (interpret-mode "
                 "kernels) — CI gates on structure, never timing"),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def _obs_smoke(failures) -> None:
    """The obs-enabled benchmark row: rerun one case with obs forced on,
    assert the snapshot carries serve spans + counters, and write a
    schema-validated Chrome trace next to BENCH_serve.json."""
    import repro.obs as obs

    prev = obs.set_enabled(True)
    obs.trace.clear()
    obs.metrics.reset()
    try:
        _run_case(*CASES[0])
        snap = obs.snapshot()
        names = {sp["name"] for sp in snap["spans"]}
        for want in ("serve.prefill", "serve.decode"):
            if want not in names:
                failures.append(f"obs: span {want!r} missing from snapshot")
        for want in ("serve.decode_steps", "serve.tokens", "plan.decisions"):
            if want not in snap["metrics"]:
                failures.append(f"obs: metric {want!r} missing from snapshot")
        trace_path = os.path.abspath(BENCH_SERVE_JSON).replace(
            ".json", ".trace.json")
        obs.write_chrome_trace(trace_path, snap)
        with open(trace_path) as f:
            errs = obs.validate_chrome_trace(json.load(f))
        for e in errs:
            failures.append(f"obs: chrome trace schema: {e}")
        print(f"# wrote {trace_path} ({len(snap['spans'])} spans)",
              file=sys.stderr)
    finally:
        obs.set_enabled(prev)


def collect_rows():
    rows, failures = [], []
    for case in CASES:
        row, fails = _run_case(*case)
        rows.append(row)
        failures += fails
        emit(f"serve_{case[0]}_b{case[1]}", row["p50_us"],
             f"tok/s {row['tok_per_s']} p99 {row['p99_us']}us")
    return rows, failures


def run():
    rows, failures = collect_rows()
    if rows:
        path = write_serve_json(rows)
        print(f"# wrote {path}", file=sys.stderr)
    for f in failures:
        print(f"SERVE-CHECK-FAIL {f}", file=sys.stderr)
    return rows, failures


def main(check: bool = False) -> int:
    rows, failures = collect_rows()
    if check:
        _obs_smoke(failures)
    if rows:
        path = write_serve_json(rows)
        print(f"# wrote {path}", file=sys.stderr)
    for f in failures:
        print(f"SERVE-CHECK-FAIL {f}", file=sys.stderr)
    if check and failures:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(check="--check" in sys.argv))
