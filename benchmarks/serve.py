"""Decode microbenchmark → ``BENCH_serve.json`` (+ the obs smoke gate).

For each case this runs the real serving path — ``init_cache`` →
``prefill`` → jitted decode loop with donated cache — on a smoke-scale
model config, with ``ServeConfig.time_steps`` on so every decode step is
host-timed, and reports:

* ``tok_per_s``             — decode throughput (batch tokens / decode wall)
* ``prefill_us``            — one synchronized prefill
* ``decode_step_p50/95/99`` — per-step latency percentiles

Rows land in the repo-root ``BENCH_serve.json`` trajectory (schema
mirrors ``BENCH_sort.json``). Wall numbers are informational off-TPU
(interpret-mode kernels); the ``--check`` gate asserts *structure*, never
timing:

* every case produced tokens in-range and ``tok_per_s > 0``;
* the p50/p95/p99 fields are present and ordered;
* with obs forced on, one generate() leaves ``serve.prefill`` /
  ``serve.decode`` spans and serve counters in the snapshot, and the
  exported Chrome trace (written next to the JSON) passes the
  trace-event schema check — the CI obs-enabled benchmark row.

Offered-load rows (``kind="offered_load"``): Poisson arrivals drive the
request scheduler (repro.serving.scheduler) — mixed per-request sampling
configs through paged slots and continuous batching — and report request
throughput plus p50/p99 request-latency and TTFT. ``--check``
additionally gates the scheduler rows: every request drains with the
right token count, the latency percentiles are ordered, and a sampled
pair of requests is re-run solo through one-shot ``generate()`` and must
match bit-for-bit (the scheduler's oracle contract).

Fault rows (``kind="faults"``, opt-in via ``--faults``): the offered-load
case re-run under seeded probabilistic faults on every scheduler seam
(``sched.prefill/insert/decode``) plus tight ``ttl_ticks`` deadlines,
reporting how many faults fired and how the requests ended
(done / timed-out / failed). The gate is the §16 drain invariant — every
request terminal, no slot or page leaked — plus bit-equality of every
*completed* request against the fault-free run.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import numpy as np

from .common import emit

BENCH_SERVE_JSON = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_serve.json")

#: (model, batch, prompt_len, new_tokens, top_k, temperature)
CASES = [
    ("chatglm3-6b", 2, 16, 8, 8, 1.0),
    ("qwen3-8b", 2, 12, 6, 0, 0.0),  # greedy decode
]


def _run_case(model, batch_size, prompt_len, new_tokens, top_k, temperature):
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.models import model_init
    from repro.serving.engine import ServeConfig, generate

    cfg = get_smoke_config(model)
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch_size, prompt_len)), jnp.int32)}
    sc = ServeConfig(max_new_tokens=new_tokens, top_k=top_k,
                     temperature=temperature, time_steps=True)
    out = generate(params, batch, cfg, sc)
    row = {
        "model": model,
        "batch": batch_size,
        "prompt_len": prompt_len,
        "new_tokens": new_tokens,
        "top_k": top_k,
        "temperature": temperature,
        "tok_per_s": round(float(out["tok_per_s"]), 2),
        "prefill_us": round(float(out["prefill_s"]) * 1e6, 1),
        "decode_us": round(float(out["decode_s"]) * 1e6, 1),
        "p50_us": round(out["decode_step_p50_us"], 1),
        "p95_us": round(out["decode_step_p95_us"], 1),
        "p99_us": round(out["decode_step_p99_us"], 1),
        # first decode step = jit compile; reported apart so the
        # steady-state percentiles above stay compile-free
        "compile_us": round(out["decode_step_compile_us"], 1),
        "platform": jax.default_backend(),
    }
    failures = []
    toks = out["tokens"]
    if toks.shape != (batch_size, new_tokens):
        failures.append(f"{model}: tokens shape {toks.shape}")
    if not ((toks >= 0).all() and (toks < cfg.vocab_size).all()):
        failures.append(f"{model}: tokens out of vocab range")
    if not out["tok_per_s"] > 0:
        failures.append(f"{model}: tok_per_s {out['tok_per_s']}")
    if not (row["p50_us"] <= row["p95_us"] <= row["p99_us"]):
        failures.append(f"{model}: decode percentiles not ordered")
    if not row["compile_us"] > 0:
        failures.append(f"{model}: compile_us {row['compile_us']}")
    return row, failures


#: (model, n_requests, rate req/tick, prompt_lo, prompt_hi, new_tokens,
#:  n_slots, page_size, pages_per_slot, seed)
LOAD_CASES = [
    ("chatglm3-6b", 8, 0.5, 3, 12, 4, 2, 8, 4, 0),
]


def _run_load_case(model, n_req, rate, p_lo, p_hi, new_tokens,
                   n_slots, page_size, pages_per_slot, seed):
    from repro.configs import get_smoke_config
    from repro.models import model_init
    from repro.serving.engine import ServeConfig, generate
    from repro.serving.scheduler import (
        SamplingParams, ScheduledEngine, SchedulerConfig)

    cfg = get_smoke_config(model)
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    # Poisson offered load: exponential inter-arrival gaps at `rate`
    # requests per scheduler tick, floored onto the virtual tick clock
    arrivals = np.floor(np.cumsum(
        rng.exponential(1.0 / rate, n_req))).astype(int)
    plens = rng.integers(p_lo, p_hi + 1, n_req)
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in plens]
    sps = [SamplingParams(k=int(rng.choice([1, 4, 8])),
                          temperature=float(rng.choice([0.0, 0.7, 1.0])),
                          top_p=float(rng.choice([1.0, 0.9])),
                          max_new_tokens=new_tokens, seed=int(i))
           for i in range(n_req)]
    sched = SchedulerConfig(n_slots=n_slots, page_size=page_size,
                            pages_per_slot=pages_per_slot)
    eng = ScheduledEngine(params, cfg, sched)
    t0 = time.perf_counter()
    rids = [eng.submit(p, sp, arrival=int(a))
            for p, sp, a in zip(prompts, sps, arrivals)]
    out = eng.run()
    wall = time.perf_counter() - t0
    lat_ms = np.asarray([(eng.requests[r].t_finish - eng.requests[r].t_submit)
                         * 1e3 for r in rids])
    ttft_ms = np.asarray([(eng.requests[r].t_first - eng.requests[r].t_submit)
                          * 1e3 for r in rids])
    total_toks = sum(len(v) for v in out.values())
    row = {
        "kind": "offered_load",
        "model": model,
        "n_requests": n_req,
        "rate_per_tick": rate,
        "n_slots": n_slots,
        "page_size": page_size,
        "pages_per_slot": pages_per_slot,
        "ticks": eng.t,
        "throughput_tok_per_s": round(total_toks / max(wall, 1e-9), 2),
        "req_p50_ms": round(float(np.percentile(lat_ms, 50)), 2),
        "req_p99_ms": round(float(np.percentile(lat_ms, 99)), 2),
        "ttft_p50_ms": round(float(np.percentile(ttft_ms, 50)), 2),
        "ttft_p99_ms": round(float(np.percentile(ttft_ms, 99)), 2),
        "platform": jax.default_backend(),
    }
    failures = []
    if sorted(out) != sorted(rids):
        failures.append(f"{model}: offered-load drain incomplete")
    for rid in rids:
        if rid in out and out[rid].shape != (new_tokens,):
            failures.append(f"{model}: rid {rid} token count {out[rid].shape}")
    if not (row["req_p50_ms"] <= row["req_p99_ms"]):
        failures.append(f"{model}: request latency percentiles not ordered")
    if not (row["ttft_p50_ms"] <= row["ttft_p99_ms"]):
        failures.append(f"{model}: TTFT percentiles not ordered")
    # the oracle gate: a sampled pair of scheduled requests must match a
    # solo one-shot generate() bit for bit (equal cache capacity)
    for rid in rids[:2]:
        sp = sps[rids.index(rid)]
        sc = ServeConfig(max_new_tokens=sp.max_new_tokens, top_k=sp.k,
                         top_p=sp.top_p, temperature=sp.temperature,
                         seed=sp.seed, cache_len=sched.slot_capacity)
        solo = generate(params, {"tokens": prompts[rids.index(rid)][None]},
                        cfg, sc)["tokens"][0]
        if not np.array_equal(out[rid], solo):
            failures.append(
                f"{model}: rid {rid} scheduler tokens differ from solo "
                f"generate ({out[rid].tolist()} vs {solo.tolist()})")
    return row, failures


def _run_fault_case(model, n_req, rate, p_lo, p_hi, new_tokens,
                    n_slots, page_size, pages_per_slot, seed):
    """The chaos row (DESIGN.md §16): the offered-load case re-run with
    seeded probabilistic faults armed across every scheduler seam plus a
    couple of tight virtual-tick deadlines. Reports how the engine
    degraded (done / timed-out / failed / retried); the gate asserts the
    drain invariant — every request terminal, no slot or page leaked —
    and that whatever *completed* matches the fault-free run bit for
    bit."""
    from repro.resilience import failpoints, fires, reset_failpoints
    from repro.serving.scheduler import (
        SamplingParams, ScheduledEngine, SchedulerConfig, TERMINAL_STATES)

    cfg = get_smoke_config_cached(model)
    params = model_params_cached(model)
    rng = np.random.default_rng(seed)
    arrivals = np.floor(np.cumsum(
        rng.exponential(1.0 / rate, n_req))).astype(int)
    plens = rng.integers(p_lo, p_hi + 1, n_req)
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in plens]
    # the last two requests carry tight virtual-tick TTLs so the row also
    # exercises deadline reclamation, not just launch faults
    sps = [SamplingParams(k=int(rng.choice([1, 4, 8])),
                          temperature=float(rng.choice([0.0, 0.7, 1.0])),
                          max_new_tokens=new_tokens, seed=int(i),
                          ttl_ticks=(3 if i >= n_req - 2 else None))
           for i in range(n_req)]

    def _drive(chaos: bool):
        sched = SchedulerConfig(n_slots=n_slots, page_size=page_size,
                                pages_per_slot=pages_per_slot,
                                max_retries=1, retry_backoff_s=0.0)
        eng = ScheduledEngine(params, cfg, sched)
        rids = [eng.submit(p, sp, arrival=int(a))
                for p, sp, a in zip(prompts, sps, arrivals)]
        if chaos:
            with failpoints({"sched": f"p:0.2:{seed + 7}"}):
                out = eng.run()
                n_fired = fires("sched")
        else:
            out, n_fired = eng.run(), 0
        return eng, rids, out, n_fired

    ref_eng, ref_rids, ref_out, _ = _drive(chaos=False)
    reset_failpoints()
    eng, rids, out, n_fired = _drive(chaos=True)
    by_state = {}
    for r in eng.requests.values():
        by_state[r.state.value] = by_state.get(r.state.value, 0) + 1
    row = {
        "kind": "faults",
        "model": model,
        "n_requests": n_req,
        "faults_injected": n_fired,
        "done": by_state.get("done", 0),
        "timed_out": by_state.get("timed_out", 0),
        "failed": by_state.get("failed", 0),
        "ticks": eng.t,
        "platform": jax.default_backend(),
    }
    failures = []
    nonterminal = [r.rid for r in eng.requests.values()
                   if r.state not in TERMINAL_STATES]
    if nonterminal:
        failures.append(f"{model}: non-terminal requests under faults: "
                        f"{nonterminal}")
    if eng.slots.free_slot_count != n_slots:
        failures.append(f"{model}: leaked slots under faults")
    if eng.slots.free_page_count != eng.pool.n_pages - 1:
        failures.append(f"{model}: leaked pages under faults")
    # a TTL request can *complete* under chaos yet time out fault-free
    # (a failed neighbor frees its slot earlier), so compare only the
    # requests that finished in both runs — the pytest chaos suite owns
    # the strict solo-generate oracle
    for rid, ref_rid in zip(rids, ref_rids):
        if (rid in out and ref_rid in ref_out
                and not np.array_equal(out[rid], ref_out[ref_rid])):
            failures.append(
                f"{model}: rid {rid} completed under faults but differs "
                f"from the fault-free run")
    return row, failures


def get_smoke_config_cached(model):
    from repro.configs import get_smoke_config

    return get_smoke_config(model)


_PARAMS_CACHE = {}


def model_params_cached(model):
    from repro.models import model_init

    if model not in _PARAMS_CACHE:
        _PARAMS_CACHE[model] = model_init(
            jax.random.PRNGKey(0), get_smoke_config_cached(model))[0]
    return _PARAMS_CACHE[model]


def collect_fault_rows():
    rows, failures = [], []
    for case in LOAD_CASES:
        row, fails = _run_fault_case(*case)
        rows.append(row)
        failures += fails
        emit(f"serve_faults_{case[0]}_n{case[1]}", row["ticks"],
             f"fired {row['faults_injected']} done {row['done']} "
             f"timed_out {row['timed_out']} failed {row['failed']}")
    return rows, failures


def collect_load_rows():
    rows, failures = [], []
    for case in LOAD_CASES:
        row, fails = _run_load_case(*case)
        rows.append(row)
        failures += fails
        emit(f"serve_load_{case[0]}_n{case[1]}", row["req_p50_ms"] * 1e3,
             f"tok/s {row['throughput_tok_per_s']} "
             f"p99 {row['req_p99_ms']}ms ttft50 {row['ttft_p50_ms']}ms")
    return rows, failures


def write_serve_json(rows) -> str:
    path = os.path.abspath(BENCH_SERVE_JSON)
    payload = {
        "schema": 1,
        "generated_unix": int(time.time()),
        "platform": jax.default_backend(),
        "note": ("tokens/sec + per-decode-step latency percentiles; "
                 "wall numbers are informational off-TPU (interpret-mode "
                 "kernels) — CI gates on structure, never timing"),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def _obs_smoke(failures) -> None:
    """The obs-enabled benchmark row: rerun one case with obs forced on,
    assert the snapshot carries serve spans + counters, and write a
    schema-validated Chrome trace next to BENCH_serve.json."""
    import repro.obs as obs

    prev = obs.set_enabled(True)
    obs.trace.clear()
    obs.metrics.reset()
    try:
        _run_case(*CASES[0])
        snap = obs.snapshot()
        names = {sp["name"] for sp in snap["spans"]}
        for want in ("serve.prefill", "serve.decode"):
            if want not in names:
                failures.append(f"obs: span {want!r} missing from snapshot")
        for want in ("serve.decode_steps", "serve.tokens", "plan.decisions"):
            if want not in snap["metrics"]:
                failures.append(f"obs: metric {want!r} missing from snapshot")
        trace_path = os.path.abspath(BENCH_SERVE_JSON).replace(
            ".json", ".trace.json")
        obs.write_chrome_trace(trace_path, snap)
        with open(trace_path) as f:
            errs = obs.validate_chrome_trace(json.load(f))
        for e in errs:
            failures.append(f"obs: chrome trace schema: {e}")
        print(f"# wrote {trace_path} ({len(snap['spans'])} spans)",
              file=sys.stderr)
    finally:
        obs.set_enabled(prev)


def _trace_requests(failures) -> None:
    """``--trace-requests``: drive the scheduler with obs forced on and
    write the per-request waterfall trace — one perfetto timeline row per
    request (queue-wait → prefill → insert → decode ticks) plus the
    ``waterfalls`` summary — into ``BENCH_serve.trace.json``. Gates the
    §17 reconciliation contract against the engine's *measured* markers:
    for every completed request the non-decode stage spans sum exactly
    (integer ns) to its TTFT, the root span matches its request latency,
    and unaccounted scheduler overhead is never negative."""
    import repro.obs as obs
    from repro.configs import get_smoke_config
    from repro.serving.scheduler import (
        SamplingParams, ScheduledEngine, SchedulerConfig)

    model, n_req, rate, p_lo, p_hi, new_tokens, n_slots, page_size, \
        pages_per_slot, seed = LOAD_CASES[0]
    cfg = get_smoke_config(model)
    params = model_params_cached(model)
    rng = np.random.default_rng(seed)
    arrivals = np.floor(np.cumsum(
        rng.exponential(1.0 / rate, n_req))).astype(int)
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in rng.integers(p_lo, p_hi + 1, n_req)]
    sps = [SamplingParams(k=int(rng.choice([1, 4, 8])),
                          temperature=float(rng.choice([0.0, 0.7, 1.0])),
                          max_new_tokens=new_tokens, seed=int(i))
           for i in range(n_req)]

    prev = obs.set_enabled(True)
    obs.trace.clear()
    obs.metrics.reset()
    obs.recorder.clear()
    try:
        eng = ScheduledEngine(params, cfg, SchedulerConfig(
            n_slots=n_slots, page_size=page_size,
            pages_per_slot=pages_per_slot))
        rids = [eng.submit(p, sp, arrival=int(a))
                for p, sp, a in zip(prompts, sps, arrivals)]
        eng.run()
        snap = obs.snapshot()
        wfs = obs.request_waterfalls(snap)
        if sorted(w["rid"] for w in wfs) != sorted(rids):
            failures.append(
                f"trace: waterfalls cover {sorted(w['rid'] for w in wfs)}, "
                f"expected {sorted(rids)}")
        for w in wfs:
            r = eng.requests[w["rid"]]
            if w["state"] != "done":
                continue
            if w["ttft_ns"] != r.t_first_ns - r.t_submit_ns:
                failures.append(
                    f"trace: rid {w['rid']} stage sum {w['ttft_ns']}ns != "
                    f"measured TTFT {r.t_first_ns - r.t_submit_ns}ns")
            if w["total_ns"] != r.t_finish_ns - r.t_submit_ns:
                failures.append(
                    f"trace: rid {w['rid']} root span != request latency")
            if w["unaccounted_ns"] < 0:
                failures.append(
                    f"trace: rid {w['rid']} negative unaccounted time")
            stages = [s["name"] for s in w["stages"]]
            for want in ("req.queue_wait", "req.prefill", "req.insert"):
                if want not in stages:
                    failures.append(
                        f"trace: rid {w['rid']} missing stage {want}")
            if w["decode_ticks"] != new_tokens - 1:
                failures.append(
                    f"trace: rid {w['rid']} has {w['decode_ticks']} decode "
                    f"ticks, expected {new_tokens - 1}")
        trace = obs.request_chrome_trace(snap)
        for e in obs.validate_chrome_trace(trace):
            failures.append(f"trace: chrome trace schema: {e}")
        trace_path = os.path.abspath(BENCH_SERVE_JSON).replace(
            ".json", ".trace.json")
        with open(trace_path, "w") as f:
            json.dump(trace, f, indent=1, sort_keys=True, default=str)
            f.write("\n")
        print(f"# wrote {trace_path} ({len(wfs)} request waterfalls, "
              f"{len(trace['traceEvents'])} events)", file=sys.stderr)
    finally:
        obs.set_enabled(prev)


def collect_rows():
    rows, failures = [], []
    for case in CASES:
        row, fails = _run_case(*case)
        rows.append(row)
        failures += fails
        emit(f"serve_{case[0]}_b{case[1]}", row["p50_us"],
             f"tok/s {row['tok_per_s']} p99 {row['p99_us']}us")
    return rows, failures


def run():
    rows, failures = collect_rows()
    lrows, lfails = collect_load_rows()
    rows += lrows
    failures += lfails
    if rows:
        path = write_serve_json(rows)
        print(f"# wrote {path}", file=sys.stderr)
    for f in failures:
        print(f"SERVE-CHECK-FAIL {f}", file=sys.stderr)
    return rows, failures


def main(check: bool = False, faults: bool = False,
         trace_requests: bool = False) -> int:
    failures = []
    if trace_requests:
        # standalone mode: only the request-trace gate runs (CI's schema
        # smoke); rows are untouched so the committed trajectory and the
        # sentinel baseline stay stable
        _trace_requests(failures)
        for f in failures:
            print(f"SERVE-CHECK-FAIL {f}", file=sys.stderr)
        return 1 if failures else 0
    rows, failures = collect_rows()
    lrows, lfails = collect_load_rows()
    rows += lrows
    failures += lfails
    if faults:
        frows, ffails = collect_fault_rows()
        rows += frows
        failures += ffails
    if check:
        _obs_smoke(failures)
    if rows:
        path = write_serve_json(rows)
        print(f"# wrote {path}", file=sys.stderr)
    for f in failures:
        print(f"SERVE-CHECK-FAIL {f}", file=sys.stderr)
    if check and failures:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(check="--check" in sys.argv,
                  faults="--faults" in sys.argv,
                  trace_requests="--trace-requests" in sys.argv))
