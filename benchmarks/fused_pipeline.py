"""Fused vs unfused pipeline benchmark (+ the BENCH_sort.json trajectory).

For each benchmarked (op, shape, dtype) this measures the **fused**
single-launch pallas path (in-kernel key transform, VMEM payload lanes)
against the **unfused** pre-fusion pipeline (XLA-level encode/decode,
executor payload carry) and reports two numbers per variant:

* ``xla_ops`` — the count of XLA-level jaxpr equations, descending into
  pjit/custom_vjp sub-jaxprs but *not* into Pallas kernel bodies. This is
  the deterministic proxy the fused pipeline optimizes: every eliminated
  eqn is a launch / HBM round-trip that no longer exists. CI gates on
  bit-equality and this proxy — never on wall time.
* ``wall_us`` — median wall time. Meaningful on TPU; on CPU hosts the
  kernels run in interpret mode (emulated per-op), so wall time is
  recorded for the trajectory but is **not** a pass/fail signal.

``python -m benchmarks.fused_pipeline --check`` runs the perf-smoke gate:
every fused result must be bit-identical to the ``jnp.sort``/``lax.top_k``
reference (NaN-position aware) and must not use more XLA-level ops than
the unfused pipeline. Exits non-zero on any mismatch.

``benchmarks.run`` calls :func:`collect_rows` and writes the repo-root
``BENCH_sort.json`` so perf regressions stay visible across PRs.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, timeit_stats

#: benchmarked shapes: (op, batch, lengths, payload?)
CASES = [
    ("sort", 8, (128,), True),
    ("sort", 8, (512,), True),
    ("sort", 4, (1024,), False),
    ("sort", 16, (1007,), True),  # non-pow2: in-kernel pad + compact
    ("merge", 8, (256, 256), True),
    ("merge", 8, (512, 256), False),
    ("merge_k", 8, (64, 96, 32), True),
    ("topk", 8, (256,), False),
    ("topk", 8, (4096,), False),
]
TOPK_K = 16


def count_xla_ops(fn, *args) -> int:
    """XLA-level eqn count: recurse into pjit / custom_vjp call jaxprs but
    stop at pallas_call (kernel internals are on-chip, not HBM traffic)."""
    def walk(jaxpr) -> int:
        n = 0
        for eqn in jaxpr.eqns:
            n += 1
            if eqn.primitive.name == "pallas_call":
                continue
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    n += walk(v.jaxpr)
                elif isinstance(v, (list, tuple)):
                    for vi in v:
                        if hasattr(vi, "jaxpr"):
                            n += walk(vi.jaxpr)
        return n

    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


def _inputs(rng, op, batch, lens, payload):
    if op == "sort":
        x = jnp.asarray(rng.normal(size=(batch, lens[0])).astype(np.float32))
        args = [x]
    elif op == "topk":
        args = [jnp.asarray(rng.normal(size=(batch, lens[0])).astype(np.float32))]
    else:
        args = [jnp.sort(jnp.asarray(
            rng.normal(size=(batch, n)).astype(np.float32)), -1) for n in lens]
    pay = None
    if payload:
        total = lens[0] if op in ("sort", "topk") else None
        if op == "sort":
            pay = jnp.asarray(rng.integers(0, total, (batch, total)), jnp.int32)
        else:
            pay = [jnp.asarray(rng.integers(0, 99, a.shape), jnp.int32)
                   for a in args]
    return args, pay


def _call(op, args, pay, backend):
    import repro

    if op == "sort":
        if pay is None:
            return repro.sort(args[0], backend=backend)
        return repro.sort(args[0], payload=pay, backend=backend)
    if op == "merge":
        if pay is None:
            return repro.merge(args[0], args[1], backend=backend)
        return repro.merge(args[0], args[1], payload=tuple(pay),
                           backend=backend)
    if op == "merge_k":
        if pay is None:
            return repro.merge_k(args, backend=backend)
        return repro.merge_k(args, payload=list(pay), backend=backend)
    assert op == "topk"
    return repro.topk(args[0], TOPK_K, backend=backend)


def _reference(op, args, pay):
    cat = jnp.concatenate(args, -1) if len(args) > 1 else args[0]
    if op == "topk":
        v, i = jax.lax.top_k(cat, TOPK_K)
        return v
    return jnp.sort(cat, -1)


def _flat_vals(res, op, pay):
    if op == "topk":
        return res[0]
    return res[0] if pay is not None else res


def collect_rows(iters: int = 3):
    """Measure every case fused and unfused; returns (rows, failures)."""
    from repro.api import fused as fused_mod

    from repro.streaming.planner import plan_op

    rng = np.random.default_rng(0)
    rows, failures = [], []
    plan_ops = {"sort": "sort", "merge": "merge2", "merge_k": "kway",
                "topk": "topk"}
    for op, batch, lens, payload in CASES:
        args, pay = _inputs(rng, op, batch, lens, payload)
        shape = f"{batch}x" + "+".join(str(n) for n in lens)
        # the comparator-network family the planner (tournament winner on
        # a tuned cache, LOMS heuristic otherwise) assigns this size class
        network = plan_op(plan_ops[op], lens, batch=batch,
                          dtype=jnp.float32,
                          k=TOPK_K if op == "topk" else None).network

        fused_fn = jax.jit(lambda *a, _op=op, _p=pay: _call(_op, list(a), _p,
                                                            "pallas"))
        prev = fused_mod.set_fused_enabled(False)
        try:
            unfused_fn = jax.jit(lambda *a, _op=op, _p=pay: _call(
                _op, list(a), _p, "pallas"))
            unfused_fn(*args)  # trace (and compile) while fusion is off
            unfused_ops = count_xla_ops(unfused_fn, *args)
        finally:
            fused_mod.set_fused_enabled(prev)
        fused_ops = count_xla_ops(fused_fn, *args)

        ref = _reference(op, args, pay)
        got = _flat_vals(fused_fn(*args), op, pay)
        ok = np.array_equal(np.asarray(got), np.asarray(ref), equal_nan=True)
        if not ok:
            failures.append(f"{op}[{shape}]: fused != reference")
        if fused_ops > unfused_ops:
            failures.append(
                f"{op}[{shape}]: fused xla_ops {fused_ops} > unfused "
                f"{unfused_ops}")
        st_fused = timeit_stats(fused_fn, *args, iters=iters)
        st_unfused = timeit_stats(unfused_fn, *args, iters=iters)
        for backend, ops, st in (("pallas-fused", fused_ops, st_fused),
                                 ("unfused", unfused_ops, st_unfused)):
            rows.append({
                "op": op,
                "shape": shape,
                "dtype": "float32",
                "payload": payload,
                "backend": backend,
                "wall_us": round(st.p50_us, 1),
                **st.to_row(),
                "xla_ops": ops,
                "network": network,
                "platform": jax.default_backend(),
            })
        emit(f"fused_{op}_{shape}", st_fused.p50_us,
             f"xla_ops {fused_ops} vs unfused {unfused_ops} "
             f"({st_unfused.p50_us:.0f}us)", stats=st_fused)
    return rows, failures


def run():
    rows, failures = collect_rows()
    for f in failures:
        print(f"FUSED-CHECK-FAIL {f}", file=sys.stderr)
    return rows, failures


def main(check: bool = False) -> int:
    rows, failures = run()
    if check and failures:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(check="--check" in sys.argv))
