"""Padded-dense vs segmented benchmark across raggedness ratios.

The segmented subsystem's claim is that bucketed size classes beat the
pad-everything-to-the-max fallback as raggedness grows. For each length
distribution this measures three realizations of the same per-segment
sort / top-k problem:

* ``padded-dense`` — every segment padded to the max length, one dense
  ``jnp.sort`` over the (S, max_len) matrix (the pre-PR 5 fallback);
* ``segmented`` — the bucketed class kernels (``backend="segmented"``);
* ``seg-reference`` — the per-segment XLA reference (the escape hatch).

Two deterministic proxies ride along with wall time:

* ``padded_slots`` — total network lanes processed: ``sum(n_c * W_c)``
  over the size classes vs ``S * ceil_pow2(max_len)`` for the dense pad.
  This is the comparator-count-shaped quantity the bucketing optimizes;
  it is exact at trace time and platform-independent.
* ``xla_ops`` — jaxpr equation count (HBM-level launches), as in
  benchmarks.fused_pipeline.

``python -m benchmarks.segmented --check`` runs the perf-smoke gate:
segmented results must be bit-identical to the per-segment reference on
every case, and ``padded_slots`` must never exceed the padded-dense
count. Wall time is recorded, never gated.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, timeit_stats
from .fused_pipeline import count_xla_ops

#: (name, segment length distribution) — lengths chosen so total work is
#: comparable while the max/mean ratio (raggedness) grows
CASES = [
    ("uniform", [64] * 48),
    ("mild", [32, 48, 64, 96] * 12),
    ("heavy", [8] * 24 + [16] * 12 + [64] * 8 + [256] * 4),
    ("extreme", [1] * 20 + [4] * 16 + [16] * 8 + [1024]),
]
TOPK_K = 8


def _padded_slots_segmented(lengths) -> int:
    from repro.kernels.common import ceil_pow2
    from repro.segmented import bucket_segments, max_class_width

    classes, spill = bucket_segments(np.asarray(lengths),
                                     max_class_width(jnp.float32))
    slots = sum(c.n * c.width for c in classes)
    slots += sum(c.n * ceil_pow2(c.width) for c in spill)
    return slots


def _padded_slots_dense(lengths) -> int:
    from repro.kernels.common import ceil_pow2

    return len(lengths) * ceil_pow2(max(lengths))


def _ref_sort(x, offs):
    parts = [np.sort(np.asarray(x[a:b])) for a, b in zip(offs, offs[1:])]
    return np.concatenate(parts) if parts else np.asarray(x[:0])


def _padded_dense_sort(x, offs, max_len):
    """The pre-segmented fallback: scatter into (S, max_len) with +inf
    pads, one dense sort, gather the live prefixes back. The same index
    map serves both directions — lane j of row r is CSR slot offs[r]+j
    going in, and (because +inf pads sort to the tail) coming out."""
    s = len(offs) - 1
    gmap = np.full((s, max_len), offs[-1], np.int64)
    for r, (a, b) in enumerate(zip(offs, offs[1:])):
        gmap[r, :b - a] = np.arange(a, b)
    ext = jnp.concatenate([x, jnp.full((1,), np.inf, x.dtype)])
    dense = jnp.sort(ext[jnp.asarray(gmap)], axis=-1)
    out = jnp.zeros((offs[-1] + 1,), x.dtype)
    return out.at[jnp.asarray(gmap).reshape(-1)].set(
        dense.reshape(-1))[:offs[-1]]


def collect_rows(iters: int = 3):
    import repro

    rng = np.random.default_rng(0)
    rows, failures = [], []
    for name, lengths in CASES:
        offs = tuple(np.concatenate([[0], np.cumsum(lengths)]).tolist())
        n = offs[-1]
        max_len = max(lengths)
        x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        shape = f"S{len(lengths)}xN{n}xmax{max_len}"
        # comparator-network family the planner assigns the dominant size
        # class (tournament winner on a tuned cache, LOMS heuristic
        # otherwise) — the per-class stamp the decision audit reads
        from repro.segmented.core import max_class_width
        from repro.streaming.planner import plan_op

        top_w = min(1 << (max(max_len - 1, 1)).bit_length(),
                    max_class_width(jnp.float32))
        network = plan_op("segmented", (top_w,), batch=len(lengths),
                          dtype=jnp.float32).network
        ref = _ref_sort(x, offs)

        from repro.segmented.core import segment_sort_impl

        seg_fn = jax.jit(lambda v, _o=offs: repro.segment_sort(
            v, _o, backend="segmented"))
        # pinned to the per-segment XLA reference on every platform (auto
        # routing would silently measure the kernels again on TPU)
        ref_fn = jax.jit(lambda v, _o=offs: segment_sort_impl(
            v, _o, use_kernel=False)[0])
        dense_fn = jax.jit(lambda v, _o=offs, _m=max_len:
                           _padded_dense_sort(v, _o, _m))

        got = np.asarray(seg_fn(x))
        if not np.array_equal(got, ref, equal_nan=True):
            failures.append(f"sort[{name}]: segmented != per-segment ref")
        if not np.array_equal(np.asarray(dense_fn(x)), ref, equal_nan=True):
            failures.append(f"sort[{name}]: padded-dense harness broken")

        slots_seg = _padded_slots_segmented(lengths)
        slots_dense = _padded_slots_dense(lengths)
        if slots_seg > slots_dense:
            failures.append(
                f"sort[{name}]: segmented padded_slots {slots_seg} > "
                f"dense {slots_dense}")

        variants = (("segmented", seg_fn, slots_seg),
                    ("seg-reference", ref_fn, slots_seg),
                    ("padded-dense", dense_fn, slots_dense))
        for backend, fn, slots in variants:
            st = timeit_stats(fn, x, iters=iters)
            rows.append({
                "op": "segment_sort",
                "shape": shape,
                "dtype": "float32",
                "payload": False,
                "backend": backend,
                "wall_us": round(st.p50_us, 1),
                **st.to_row(),
                "xla_ops": count_xla_ops(fn, x),
                "padded_slots": slots,
                "raggedness": round(max_len * len(lengths) / n, 2),
                "network": network,
                "platform": jax.default_backend(),
            })
        emit(f"segmented_sort_{name}", rows[-3]["wall_us"],
             f"slots {slots_seg} vs dense {slots_dense} "
             f"(x{slots_dense / max(slots_seg, 1):.1f} saved)")

        # mixed-k top-k: the continuous-batching shape
        ks = tuple(min(TOPK_K, ln) if ln else 0 for ln in lengths)
        topk_fn = jax.jit(lambda v, _o=offs, _k=ks: repro.segment_topk(
            v, _o, _k, backend="segmented")[0])
        vals = np.asarray(topk_fn(x))
        ref_parts = [np.sort(np.asarray(x[a:b]))[::-1][:k]
                     for (a, b), k in zip(zip(offs, offs[1:]), ks)]
        ref_topk = (np.concatenate(ref_parts) if ref_parts
                    else np.zeros((0,), np.float32))
        if not np.array_equal(vals, ref_topk, equal_nan=True):
            failures.append(f"topk[{name}]: segmented != per-segment ref")
        st = timeit_stats(topk_fn, x, iters=iters)
        rows.append({
            "op": "segment_topk",
            "shape": shape,
            "dtype": "float32",
            "payload": False,
            "backend": "segmented",
            "wall_us": round(st.p50_us, 1),
            **st.to_row(),
            "xla_ops": count_xla_ops(topk_fn, x),
            "padded_slots": slots_seg,
            "raggedness": round(max_len * len(lengths) / n, 2),
            "network": network,
            "platform": jax.default_backend(),
        })
    return rows, failures


def run():
    rows, failures = collect_rows()
    for f in failures:
        print(f"SEGMENTED-CHECK-FAIL {f}", file=sys.stderr)
    return rows, failures


def main(check: bool = False) -> int:
    rows, failures = run()
    if check and failures:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(check="--check" in sys.argv))
