"""Dispatch-layer benchmark: auto-routing overhead + the backend table.

Two questions the unified API (repro.api, DESIGN.md §9) must answer:

1. What does ``backend="auto"`` cost over calling the chosen realization
   directly? Measured both jitted (steady state — the planner runs at
   trace time, so the answer should be ~0) and eager (per-call planning +
   canonicalization overhead).
2. What does the planner actually choose? Emits the decision table for
   the README / DESIGN.md §9.

  PYTHONPATH=src python -m benchmarks.api_dispatch
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

import repro
from repro.api import schedules
from repro.kernels.loms_merge import loms_merge2_pallas
from repro.kernels.ops import topk as kernel_topk

from .common import emit, timeit


def dispatch_overhead():
    rng = np.random.default_rng(0)

    # --- merge: auto vs the direct kernel / executor calls ----------------
    a = jnp.sort(jnp.asarray(rng.standard_normal((8, 256)), jnp.float32), -1)
    b = jnp.sort(jnp.asarray(rng.standard_normal((8, 256)), jnp.float32), -1)
    f_auto = jax.jit(lambda x, y: repro.merge(x, y))
    f_sched = jax.jit(schedules.merge)
    f_kern = jax.jit(lambda x, y: loms_merge2_pallas(x, y, n_cols=4))
    emit("dispatch/merge_auto_jit/256", timeit(f_auto, a, b) * 1e6,
         "repro.merge, planner at trace time")
    emit("dispatch/merge_schedule_jit/256", timeit(f_sched, a, b) * 1e6,
         "schedules.merge direct")
    emit("dispatch/merge_kernel_jit/256", timeit(f_kern, a, b) * 1e6,
         "loms_merge2_pallas direct")
    # eager: per-call spec build + plan() + axis canonicalization
    emit("dispatch/merge_auto_eager/256",
         timeit(lambda x, y: repro.merge(x, y), a, b) * 1e6,
         "un-jitted, includes planning per call")
    emit("dispatch/merge_schedule_eager/256",
         timeit(schedules.merge, a, b) * 1e6, "un-jitted direct")

    # --- topk: auto vs direct ---------------------------------------------
    logits = jnp.asarray(rng.standard_normal((8, 4096)), jnp.float32)
    f_auto = jax.jit(lambda x: repro.topk(x, 64)[0])
    f_kern = jax.jit(lambda x: kernel_topk(x, 64)[0])
    f_sched = jax.jit(lambda x: schedules.topk(x, 64)[0])
    emit("dispatch/topk_auto_jit/4096", timeit(f_auto, logits) * 1e6,
         "repro.topk auto")
    emit("dispatch/topk_kernel_jit/4096", timeit(f_kern, logits) * 1e6,
         "kernels.ops.topk direct")
    emit("dispatch/topk_schedule_jit/4096", timeit(f_sched, logits) * 1e6,
         "schedules.topk direct")


def measure_routes():
    """Feed the measured-cost dispatcher: time the capable single-device
    backends on representative shapes and record the samples
    (repro.api.dispatch.record_route_us). Subsequent ``plan()`` calls for
    those exact points then rank on the measurements instead of the
    static ladder — the decision table marks such rows source=measured."""
    from repro.api.dispatch import record_route_us
    from repro.api.spec import SortSpec
    from repro.api.registry import get_backend

    rng = np.random.default_rng(0)
    dev = jax.default_backend()
    points = [
        ("merge", {"lengths": (256, 256), "batch": 8}),
        ("topk", {"lengths": (4096,), "batch": 8, "k": 64}),
    ]
    for op, kw in points:
        spec = SortSpec(op=op, dtype="float32", device=dev, **kw)
        if op == "merge":
            a = jnp.sort(jnp.asarray(
                rng.standard_normal((kw["batch"], kw["lengths"][0])),
                jnp.float32), -1)
            b = jnp.sort(jnp.asarray(
                rng.standard_normal((kw["batch"], kw["lengths"][1])),
                jnp.float32), -1)
            run_be = lambda be: timeit(
                jax.jit(lambda x, y: repro.merge(x, y, backend=be)), a, b)
        else:
            x = jnp.asarray(
                rng.standard_normal((kw["batch"], kw["lengths"][0])),
                jnp.float32)
            run_be = lambda be: timeit(
                jax.jit(lambda v: repro.topk(v, kw["k"], backend=be)[0]), x)
        for be in ("pallas", "schedule", "streaming"):
            if not get_backend(be).supports(spec):
                continue
            us = run_be(be) * 1e6
            record_route_us(spec, be, us)
            emit(f"dispatch/route_{op}_{be}", us, "measured route sample")


def backend_table():
    print("\nbackend-choice table (repro.decision_table):")
    rows = repro.decision_table()
    header = (f"{'problem':<44} {'payload':<8} {'sharded':<8} "
              f"{'backend':<10} {'source':<9} detail")
    print(header)
    print("-" * len(header))
    for r in rows:
        print(f"{r['problem']:<44} {str(r['payload']):<8} "
              f"{str(r['sharded']):<8} {r['backend']:<10} "
              f"{r['source']:<9} {r['detail']}")


def run(measure: bool = False):
    dispatch_overhead()
    if measure:
        measure_routes()
    backend_table()


if __name__ == "__main__":
    import sys

    run(measure="--measure-routes" in sys.argv)
