"""Paper Figs. 14-17 analog: 4insLUT (dense/slow) vs 2insLUT (fast/wide).

The paper's two LUT-packing methodologies map to our two permutation
paths: 'fabric' (scatter, VPU) vs 'MXU' (one-hot matmul) kernel modes plus
the 2ins/4ins LUT-proxy resource model. Figures 16/17 extend to the large
devices and reproduce the placement argument: the S2MS UP-256/DN-256
comparison cloud exceeds the VMEM tile budget while the 8-column LOMS
(8 x UP-32/DN-32 columns) fits.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.api.schedules import merge_schedule
from repro.core import depth, loms_2way, apply_schedule
from repro.core.metrics import lut_proxy, vmem_bytes
from repro.kernels.loms_merge import loms_merge2_pallas
from .common import emit, sorted_batch, timeit

VMEM_BUDGET = 16 * 2**20  # one v5e core's VMEM


def run():
    rng = np.random.default_rng(1)
    # small devices (figs 14/15): bitonic vs S2MS vs LOMS 2col
    for m in (2, 4, 8):
        for kind in ("s2ms", "loms", "batcher-bitonic"):
            sched = merge_schedule(m, m, kind)
            emit(f"fig14_15/{kind}/up{m}dn{m}", 0.0,
                 f"depth={depth(sched)};lut4ins={lut_proxy(sched, 32, '4insLUT')};"
                 f"lut2ins={lut_proxy(sched, 32, '2insLUT')}")
    # kernel path comparison: MXU (2insLUT-analog) vs fabric (4insLUT-analog)
    for m in (32, 64):
        a = sorted_batch(rng, 256, m)
        b = sorted_batch(rng, 256, m)
        for mode, use_mxu in (("mxu", True), ("fabric", False)):
            f = jax.jit(lambda a, b, u=use_mxu: loms_merge2_pallas(
                a, b, n_cols=4, use_mxu=u, interpret=True))
            t = timeit(f, a, b, iters=5)
            emit(f"fig14_15/kernel-{mode}/up{m}dn{m}", t * 1e6, "")
    # large devices (figs 16/17): who fits in VMEM?
    for m in (64, 128, 256):
        for kind, cols in (("s2ms", 1), ("loms", 2), ("loms", 4), ("loms", 8)):
            if kind == "s2ms":
                sched = merge_schedule(m, m, "s2ms")
                tag = "s2ms"
            else:
                sched = loms_2way(m, m, n_cols=cols)
                tag = f"loms{cols}col"
            vm = vmem_bytes(sched, 32, 8)
            fits = vm <= VMEM_BUDGET
            emit(f"fig16_17/{tag}/up{m}dn{m}", 0.0,
                 f"depth={depth(sched)};vmem={vm};fits={fits}")


if __name__ == "__main__":
    run()
