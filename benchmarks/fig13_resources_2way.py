"""Paper Fig. 13 analog: 2-way merge resource usage (LUT proxy), 32-bit.

Reproduces the paper's resource ranking: Batcher merges use the fewest
comparators; S2MS the most (O(mn) cloud); LOMS sits between and is the one
that still fits when S2MS does not (VMEM model)."""
from __future__ import annotations

from repro.api.schedules import merge_schedule
from repro.core import comparator_count
from repro.core.metrics import lut_proxy, vmem_bytes
from .common import emit

SIZES = [2, 4, 8, 16, 32, 64]


def run():
    for m in SIZES:
        for kind in ("s2ms", "loms", "batcher-oe", "batcher-bitonic"):
            sched = merge_schedule(m, m, kind)
            emit(
                f"fig13/32b/{kind}/up{m}dn{m}", 0.0,
                f"comparators={comparator_count(sched)};"
                f"lut2ins={lut_proxy(sched, 32, '2insLUT')};"
                f"vmem={vmem_bytes(sched, 32, 8)}")


if __name__ == "__main__":
    run()
