"""Paper Figs. 11/12 analog: Batcher vs S2MS 2-way merge speed, 8/32-bit.

The paper's y-axis is FPGA combinational propagation delay; our analogs are
(a) network depth (stage count — the structural delay) and (b) measured
wall time of the batched JAX executor on this host. Both reproduce the
paper's ordering: S2MS (depth 1) < LOMS (2) < Batcher (log2 N).
"""
from __future__ import annotations

import numpy as np
import jax

from repro.api.schedules import merge_schedule
from repro.core import depth, apply_schedule
from .common import emit, sorted_batch, timeit

SIZES = [2, 4, 8, 16, 32]  # per-list; output = 2x
BATCH = 256


def run():
    rng = np.random.default_rng(0)
    for bits, dtype in ((8, "uint8"), (32, "int32")):
        import jax.numpy as jnp

        dt = getattr(jnp, dtype)
        for m in SIZES:
            a = sorted_batch(rng, BATCH, m, dt, bits)
            b = sorted_batch(rng, BATCH, m, dt, bits)
            x = jnp.concatenate([a, b], axis=-1)
            for kind in ("s2ms", "loms", "batcher-oe", "batcher-bitonic"):
                sched = merge_schedule(m, m, kind)
                f = jax.jit(lambda x, s=sched: apply_schedule(s, x))
                t = timeit(f, x)
                emit(f"fig11_12/{bits}b/{kind}/up{m}dn{m}", t * 1e6,
                     f"depth={depth(sched)}")


if __name__ == "__main__":
    run()
