"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all sorter benches
  PYTHONPATH=src python -m benchmarks.run --roofline # + roofline table
  PYTHONPATH=src python -m benchmarks.run --obs      # + Chrome trace
Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
``--obs`` forces the obs layer on for the whole run and writes a
perfetto-loadable ``BENCH_sort.trace.json`` next to the JSON (schema
checked via :func:`repro.obs.validate_chrome_trace`).

Also writes the repo-root ``BENCH_sort.json`` trajectory — one entry per
(op, shape, dtype, backend) with wall time and the XLA-level op-count
proxy from :mod:`benchmarks.fused_pipeline` — so fused-path perf
regressions stay visible across PRs (CI gates on bit-equality and the
op-count proxy, never on wall time, which is noisy on shared runners).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_sort.json")


def write_bench_json(rows) -> str:
    path = os.path.abspath(BENCH_JSON)
    import jax

    payload = {
        "schema": 1,
        "generated_unix": int(time.time()),
        "platform": jax.default_backend(),
        "note": ("wall_us is informational (interpret mode off-TPU); "
                 "xla_ops is the deterministic regression proxy"),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--roofline", action="store_true",
                    help="also print the dry-run roofline table")
    ap.add_argument("--obs", action="store_true",
                    help="enable span tracing/metrics and write a Chrome "
                         "trace next to BENCH_sort.json")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    if args.obs:
        # before any benchmark module (and hence repro) import work runs,
        # so planner/dispatch trace-time spans are captured too
        os.environ["REPRO_OBS"] = "1"

    from . import api_dispatch, dist_sort, fig11_12_speed_2way
    from . import fig13_resources_2way, fig14_17_lut_modes, fig18_20_3way
    from . import fused_pipeline, moe_routing, segmented, serve
    from . import streaming_merge

    modules = {
        "fig11_12": fig11_12_speed_2way,
        "fig13": fig13_resources_2way,
        "fig14_17": fig14_17_lut_modes,
        "fig18_20": fig18_20_3way,
        "moe_routing": moe_routing,
        "streaming": streaming_merge,
        "api_dispatch": api_dispatch,
        "dist_sort": dist_sort,
        "fused": fused_pipeline,
        "segmented": segmented,
        "serve": serve,
    }
    print("name,us_per_call,derived")
    # the BENCH_sort.json trajectory collects rows from every module that
    # returns (rows, failures) — currently the fused pipeline and the
    # segmented raggedness sweep
    bench_rows = []
    wrote_any = False
    for name, mod in modules.items():
        if args.only and args.only != name:
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        out = mod.run()
        if name in ("fused", "segmented"):
            bench_rows += out[0]
            wrote_any = True
    if wrote_any:
        path = write_bench_json(bench_rows)
        print(f"# wrote {path}", file=sys.stderr)
    if args.obs:
        import repro.obs as obs

        trace_path = os.path.abspath(BENCH_JSON).replace(
            ".json", ".trace.json")
        snap = obs.snapshot()
        obs.write_chrome_trace(trace_path, snap)
        with open(trace_path) as f:
            errs = obs.validate_chrome_trace(json.load(f))
        for e in errs:
            print(f"# OBS-TRACE-INVALID {e}", file=sys.stderr)
        print(f"# wrote {trace_path} ({len(snap['spans'])} spans, "
              f"{len(snap['metrics'])} metric series)", file=sys.stderr)
        if errs:
            sys.exit(1)
    if args.roofline:
        from . import roofline

        roofline.run("pod")


if __name__ == "__main__":
    main()
