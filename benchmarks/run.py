"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all sorter benches
  PYTHONPATH=src python -m benchmarks.run --roofline # + roofline table
Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--roofline", action="store_true",
                    help="also print the dry-run roofline table")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import api_dispatch, dist_sort, fig11_12_speed_2way
    from . import fig13_resources_2way, fig14_17_lut_modes, fig18_20_3way
    from . import moe_routing, streaming_merge

    modules = {
        "fig11_12": fig11_12_speed_2way,
        "fig13": fig13_resources_2way,
        "fig14_17": fig14_17_lut_modes,
        "fig18_20": fig18_20_3way,
        "moe_routing": moe_routing,
        "streaming": streaming_merge,
        "api_dispatch": api_dispatch,
        "dist_sort": dist_sort,
    }
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        if args.only and args.only != name:
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        mod.run()
    if args.roofline:
        from . import roofline

        roofline.run("pod")


if __name__ == "__main__":
    main()
