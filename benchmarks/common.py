"""Shared benchmark utilities: timing, CSV emission, device table."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

ROWS: List[Dict] = []


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time per call (seconds) of a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append({"name": name, "us_per_call": us_per_call, "derived": derived})
    print(f"{name},{us_per_call:.2f},{derived}")


def sorted_batch(rng, batch, n, dtype=jnp.float32, bits=32):
    hi = 255 if bits == 8 else 100_000
    x = rng.integers(0, hi, size=(batch, n))
    return jnp.sort(jnp.asarray(x).astype(dtype), axis=-1)
