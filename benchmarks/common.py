"""Shared benchmark utilities: timing, CSV emission, device table.

Timing routes through :mod:`repro.obs.timing` — the one shared
warmup + ``block_until_ready`` + percentile helper — so every benchmark
reports the same p50/p95/p99 statistics that the autotuner persists and
``BENCH_*.json`` stamps.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.timing import TimingStats, time_jitted

ROWS: List[Dict] = []


def timeit_stats(fn: Callable, *args, warmup: int = 2,
                 iters: int = 10) -> TimingStats:
    """p50/p95/p99 wall-time stats (µs) of a jitted callable."""
    return time_jitted(fn, *args, warmup=warmup, iters=iters)


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """p50 wall-time per call (seconds) of a jitted callable."""
    return timeit_stats(fn, *args, warmup=warmup, iters=iters).p50_s


def emit(name: str, us_per_call: float, derived: str = "",
         stats: Optional[TimingStats] = None):
    row = {"name": name, "us_per_call": us_per_call, "derived": derived}
    if stats is not None:
        row.update(stats.to_row())
        derived = (derived + " " if derived else "") + (
            f"p95 {stats.p95_us:.0f}us p99 {stats.p99_us:.0f}us")
    ROWS.append(row)
    print(f"{name},{us_per_call:.2f},{derived}")


def sorted_batch(rng, batch, n, dtype=jnp.float32, bits=32):
    hi = 255 if bits == 8 else 100_000
    x = rng.integers(0, hi, size=(batch, n))
    return jnp.sort(jnp.asarray(x).astype(dtype), axis=-1)


__all__ = ["ROWS", "TimingStats", "emit", "sorted_batch", "timeit",
           "timeit_stats"]
