"""Roofline table builder: reads experiments/dryrun/*.json, emits the
three-term roofline per (arch x shape) on the single-pod mesh.

  compute    = HLO_FLOPs / (chips*197 TFLOP/s)     [extrapolated, per chip]
  memory     = HLO_bytes / (chips*819 GB/s)
  collective = collective_bytes / (chips*50 GB/s/link)

HLO_FLOPs / bytes / collective bytes come from the scan-unrolled analysis
variants (launch/dryrun.estimate_cost) because XLA cost_analysis counts
while bodies once. All values are already per-chip (the compiled module is
the per-device SPMD program). MODEL_FLOPS = 6*N_active*D for train, 2*N*D
for inference (forward only).
"""
from __future__ import annotations

import json
import os

from repro.configs import ARCHS, SHAPES, get_config

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s/link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def fused_bytes_lower(cfg, shape, n_chips):
    """Analytic HBM-traffic lower bound assuming TPU-grade fusion (flash
    scores stay in VMEM; elementwise chains fuse). Pairs with the HLO
    'bytes accessed' UPPER bound (XLA:CPU barely fuses, so every score
    intermediate is charged there). Production sits between; see
    EXPERIMENTS.md §Roofline caveats."""
    n = cfg.params_billions() * 1e9
    n_act = cfg.active_params_billions() * 1e9
    d = cfg.d_model
    if shape.kind == "train":
        # params: bf16 fwd + bwd + remat reads; opt: fp32 p/m/v read+write
        param_traffic = n * (3 * 2) + n * 6 * 4
        tok = shape.global_batch * shape.seq_len
        act = tok * d * cfg.n_layers * 4 * 2  # save+read+remat rw, bf16
        passes = 3.0
    elif shape.kind == "prefill":
        param_traffic = n_act * 2
        tok = shape.global_batch * shape.seq_len
        act = tok * d * cfg.n_layers * 2 * 2
        passes = 1.0
    else:  # decode: read all params + whole cache per token
        param_traffic = n_act * 2
        tok = shape.global_batch
        cache = 0.0
        if cfg.family not in ("ssm",) and cfg.causal:
            if cfg.mla is not None:
                per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
            else:
                per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
            cache = (shape.global_batch * shape.seq_len * per_tok
                     * cfg.n_layers * 2)
        act = cache
        passes = 1.0
    # flash attention kv re-reads (each q chunk streams all K/V)
    attn = 0.0
    if cfg.n_heads and shape.kind in ("train", "prefill"):
        nq = max(shape.seq_len // cfg.attn_chunk, 1)
        attn = (cfg.n_layers * shape.global_batch * nq * shape.seq_len
                * cfg.n_kv_heads * cfg.head_dim * 2 * 2) * passes
    return (param_traffic + act + attn) / n_chips


def model_flops_per_chip(cfg, shape, n_chips):
    n_act = cfg.active_params_billions() * 1e9
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6 * n_act * tokens / n_chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2 * n_act * tokens / n_chips
    return 2 * n_act * shape.global_batch / n_chips  # decode: 1 token/seq


def load_cell(mesh, arch, shape):
    path = os.path.join(DRYRUN_DIR, mesh, f"{arch}__{shape}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def roofline_row(rec, cfg, shape):
    ce = rec.get("cost_extrapolated", {})
    if "error" in ce or "flops" not in ce:
        return None
    flops = ce["flops"]
    bytes_hi = ce["bytes"]
    bytes_lo = fused_bytes_lower(cfg, shape, rec["n_chips"])
    coll = sum(ce["coll"].values())
    t_c = flops / PEAK_FLOPS
    t_m_hi = bytes_hi / HBM_BW
    t_m = bytes_lo / HBM_BW  # fused estimate drives the bottleneck call
    t_x = coll / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    mf = model_flops_per_chip(cfg, shape, rec["n_chips"])
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_memory_hi_s": t_m_hi,
        "t_collective_s": t_x,
        "bottleneck": dom[1],
        "model_flops": mf, "hlo_flops": flops,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_frac": t_c / max(t_c, t_m, t_x) if max(t_c, t_m, t_x) else 0.0,
        "mem_per_dev_gib": rec.get("memory", {}).get(
            "per_device_total_bytes", 0) / 2**30,
        "mem_tpu_est_gib": rec.get("memory", {}).get(
            "per_device_total_bytes_tpu_estimate",
            rec.get("memory", {}).get("per_device_total_bytes", 0)) / 2**30,
    }


def run(mesh="pod"):
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            rec = load_cell(mesh, arch, shape.name)
            if rec is None:
                continue
            if rec.get("status") == "skipped":
                rows.append({"arch": arch, "shape": shape.name,
                             "skip": rec["reason"]})
                continue
            if rec.get("status") != "ok":
                rows.append({"arch": arch, "shape": shape.name, "skip": "ERROR"})
                continue
            row = roofline_row(rec, cfg, shape)
            if row:
                rows.append(row)
    hdr = (f"{'arch':26s} {'shape':12s} {'t_comp':>8s} {'t_mem':>8s} "
           f"{'t_memHI':>8s} {'t_coll':>8s} {'bound':>10s} {'frac':>6s} "
           f"{'6ND/HLO':>8s} {'memRAW':>7s} {'memTPU':>7s}")
    print(hdr)
    for r in rows:
        if "skip" in r:
            print(f"{r['arch']:26s} {r['shape']:12s} SKIP: {r['skip']}")
            continue
        print(f"{r['arch']:26s} {r['shape']:12s} {r['t_compute_s']:8.4f} "
              f"{r['t_memory_s']:8.4f} {r['t_memory_hi_s']:8.4f} "
              f"{r['t_collective_s']:8.4f} {r['bottleneck']:>10s} "
              f"{r['roofline_frac']:6.3f} {r['useful_ratio']:8.3f} "
              f"{r['mem_per_dev_gib']:7.2f} {r['mem_tpu_est_gib']:7.2f}")
    return rows


if __name__ == "__main__":
    run()
