"""Perf-regression sentinel over the committed bench trajectory.

Wall-clock numbers off-TPU are noise (interpret-mode kernels under CI
virtualization), so CI cannot gate on them — but the repo's benchmarks
also report *deterministic* proxies that only change when the lowering
or the schedule actually changes:

* ``xla_ops``      — jaxpr equation count of the jitted callable
  (BENCH_sort.json, fused + segmented rows): a fusion regression shows
  up as an increase long before it is measurable on a laptop;
* ``padded_slots`` — total comparator lanes a segmented launch processes
  (``sum(n_c * W_c)``): a size-classing regression inflates it;
* ``ticks``        — virtual scheduler ticks to drain the seeded
  offered-load case (BENCH_serve.json): admission is tick-deterministic,
  so more ticks means the schedule itself regressed.

The sentinel diffs the *current* rows against the *committed* baseline —
``git show HEAD:BENCH_sort.json`` / ``HEAD:BENCH_serve.json`` by default,
so it works even after an earlier CI step rewrote the workspace files —
per row identity key (every identity field the row carries: op / model,
shape, backend, dtype, payload, network, platform, scheduler geometry).
Any proxy *increase* on a matched key, or a baseline key that vanished
(coverage loss), fails the run. Keys only present in the current rows
are new coverage and pass. Wall-time deltas are printed as an
informational table, never gated.

Usage (CI runs the first form inside perf-smoke)::

    python -m benchmarks.sentinel                 # fresh rows vs HEAD
    python -m benchmarks.sentinel --current-sort BENCH_sort.json \
        --current-serve BENCH_serve.json          # file vs HEAD
    python -m benchmarks.sentinel --baseline-sort old.json ...
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional, Tuple

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

#: fields that identify a row (whichever subset the row carries); the
#: key is the sorted (field, value) tuple so schema growth in *new* rows
#: never perturbs old keys that lack the field
ID_FIELDS = (
    "kind", "op", "model", "backend", "network", "dtype", "payload",
    "shape", "batch", "prompt_len", "new_tokens", "top_k", "temperature",
    "n_requests", "rate_per_tick", "n_slots", "page_size",
    "pages_per_slot", "raggedness", "platform",
)

#: deterministic proxies: any increase on a matched key is a regression
PROXY_FIELDS = ("xla_ops", "padded_slots", "ticks")

#: wall/throughput fields: reported, never gated
WALL_FIELDS = ("wall_us", "p50_us", "p95_us", "p99_us", "compile_us",
               "prefill_us", "decode_us", "tok_per_s",
               "throughput_tok_per_s", "req_p50_ms", "req_p99_ms",
               "ttft_p50_ms", "ttft_p99_ms")


def row_key(row: dict) -> Tuple:
    return tuple((f, row[f]) for f in ID_FIELDS if f in row)


def fmt_key(key: Tuple) -> str:
    return " ".join(f"{f}={v}" for f, v in key)


def _index(rows: List[dict]) -> Dict[Tuple, dict]:
    out: Dict[Tuple, dict] = {}
    for r in rows:
        out[row_key(r)] = r
    return out


def diff_rows(base_rows: List[dict], cur_rows: List[dict],
              label: str) -> Tuple[List[str], List[str]]:
    """Returns (regressions, info lines) for one trajectory file."""
    base, cur = _index(base_rows), _index(cur_rows)
    regressions: List[str] = []
    info: List[str] = []
    for key, brow in base.items():
        crow = cur.get(key)
        if crow is None:
            regressions.append(
                f"{label}: coverage lost — baseline row has no current "
                f"match: {fmt_key(key)}")
            continue
        for f in PROXY_FIELDS:
            if f not in brow or f not in crow:
                continue
            b, c = int(brow[f]), int(crow[f])
            if c > b:
                regressions.append(
                    f"{label}: {f} regressed {b} -> {c} on {fmt_key(key)}")
            elif c < b:
                info.append(
                    f"{label}: {f} improved {b} -> {c} on {fmt_key(key)}")
        for f in WALL_FIELDS:
            if f not in brow or f not in crow:
                continue
            b, c = float(brow[f]), float(crow[f])
            if b > 0 and abs(c - b) / b >= 0.05:
                info.append(
                    f"{label}: [wall, informational] {f} "
                    f"{b:.1f} -> {c:.1f} ({(c - b) / b:+.0%}) "
                    f"on {fmt_key(key)}")
    for key in cur:
        if key not in base:
            info.append(f"{label}: new coverage: {fmt_key(key)}")
    return regressions, info


def _git_show(rel: str) -> Optional[dict]:
    """The committed version of ``rel`` at HEAD, or None if unreadable —
    the workspace copy may have been rewritten by an earlier bench step,
    so the *committed* trajectory is the authoritative baseline."""
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{rel}"], cwd=REPO_ROOT,
            capture_output=True, check=True)
        return json.loads(out.stdout)
    except (subprocess.CalledProcessError, OSError, ValueError):
        return None


def _load(path: str) -> Optional[dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def load_baseline(rel: str, explicit: Optional[str]) -> Optional[dict]:
    if explicit:
        return _load(explicit)
    return _git_show(rel) or _load(os.path.join(REPO_ROOT, rel))


def fresh_sort_rows() -> List[dict]:
    """Recompute the BENCH_sort rows (fused + segmented) in-process; one
    timing iter since only the deterministic proxies are gated."""
    from . import fused_pipeline, segmented

    rows, _ = fused_pipeline.collect_rows(iters=1)
    srows, _ = segmented.collect_rows(iters=1)
    return rows + srows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline-sort", default=None,
                    help="baseline BENCH_sort.json (default: git HEAD)")
    ap.add_argument("--baseline-serve", default=None,
                    help="baseline BENCH_serve.json (default: git HEAD)")
    ap.add_argument("--current-sort", default=None,
                    help="current BENCH_sort.json (default: fresh re-run)")
    ap.add_argument("--current-serve", default=None,
                    help="current BENCH_serve.json (default: workspace "
                         "file, skipped if absent)")
    args = ap.parse_args(argv)

    regressions: List[str] = []
    info: List[str] = []

    base_sort = load_baseline("BENCH_sort.json", args.baseline_sort)
    if base_sort is None:
        print("# sentinel: no BENCH_sort.json baseline — nothing to gate",
              file=sys.stderr)
    else:
        if args.current_sort:
            cur = _load(args.current_sort)
            cur_rows = cur["rows"] if cur else []
        else:
            cur_rows = fresh_sort_rows()
        r, i = diff_rows(base_sort["rows"], cur_rows, "sort")
        regressions += r
        info += i

    base_serve = load_baseline("BENCH_serve.json", args.baseline_serve)
    cur_serve = _load(args.current_serve
                      or os.path.join(REPO_ROOT, "BENCH_serve.json"))
    if base_serve is not None and cur_serve is not None:
        r, i = diff_rows(base_serve["rows"], cur_serve["rows"], "serve")
        regressions += r
        info += i
    elif base_serve is not None:
        print("# sentinel: no current BENCH_serve.json — serve rows "
              "skipped", file=sys.stderr)

    for line in info:
        print(f"SENTINEL-INFO {line}")
    for line in regressions:
        print(f"SENTINEL-REGRESSION {line}")
    n_keys = (len(base_sort["rows"]) if base_sort else 0) + \
        (len(base_serve["rows"]) if base_serve else 0)
    print(f"# sentinel: {n_keys} baseline rows, "
          f"{len(regressions)} regressions, {len(info)} notes",
          file=sys.stderr)
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
