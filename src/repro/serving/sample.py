"""Decode-time sampling built on the LOMS top-k kernels.

Top-k over a ~152k vocab is the paper's merge problem at serving scale:
per-block sorted lists reduced by truncated UP-k/DN-k List Offset merges
(repro.kernels.topk). Sampling is data-oblivious up to the final categorical
draw — the paper's security/safety argument for oblivious sorting applies
to the scoring path.

Candidate scoring goes through the unified dispatch API (``repro.topk``):
with a :class:`~repro.parallel.sharding.Parallelism` whose TP axis divides
the vocab, the planner routes to the device-tree sharded top-k from
``repro.streaming.tree`` — each shard scores its vocab slice and the lists
reduce over the mesh axis in log depth instead of gathering the full
logits row onto one device; otherwise it picks the Pallas vocab kernel on
TPU and the schedule executor elsewhere.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.api import sort as unified_sort
from repro.api import topk as unified_topk


def sample_topk(
    key,
    logits: jnp.ndarray,  # (B, V)
    *,
    k: int = 64,
    temperature: float = 1.0,
    par=None,
) -> jnp.ndarray:
    """Top-k + temperature categorical sampling -> (B,) int32 tokens."""
    if temperature <= 0.0 or k == 1:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    vals, idx = unified_topk(logits, k, par=par)
    probs_logits = vals.astype(jnp.float32) / temperature
    choice = jax.random.categorical(key, probs_logits, axis=-1)  # (B,)
    return jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0].astype(jnp.int32)


def sample_greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_topp(
    key,
    logits: jnp.ndarray,  # (B, V)
    *,
    p: float = 0.9,
    k_max: Optional[int] = 256,
    temperature: float = 1.0,
    par=None,
) -> jnp.ndarray:
    """Nucleus sampling on the LOMS top-k prefix.

    The merge kernels hand back the candidates already sorted descending,
    so the nucleus is one cumulative sum over the k_max prefix — no extra
    sort. Candidates beyond k_max carry negligible mass for any practical
    p (< 1e-4 at p <= 0.99 for trained LMs).

    ``k_max=None`` makes the nucleus *exact*: the whole vocab row is
    ranked through ``repro.sort`` (descending, indices riding the
    permutation). With a TP-sharded :class:`Parallelism` whose axis
    divides the vocab, the planner routes that ranking to the distributed
    sample-sort backend — the full logits row is never gathered onto one
    device, same as the tree top-k path."""
    if k_max is None:
        v = logits.shape[-1]
        iota = jnp.broadcast_to(jnp.arange(v, dtype=jnp.int32), logits.shape)
        vals, idx = unified_sort(logits, descending=True, payload=iota, par=par)
    else:
        vals, idx = unified_topk(logits, k_max, par=par)  # descending
    probs = jax.nn.softmax(vals.astype(jnp.float32) / temperature, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep the smallest prefix with mass >= p (always keep the top-1)
    keep = jnp.concatenate(
        [jnp.ones_like(cum[:, :1], bool), cum[:, :-1] < p], axis=-1)
    masked = jnp.where(keep, jnp.log(probs + 1e-30), -jnp.inf)
    choice = jax.random.categorical(key, masked, axis=-1)
    return jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0].astype(jnp.int32)
