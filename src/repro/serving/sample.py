"""Decode-time sampling built on the LOMS top-k kernels.

Top-k over a ~152k vocab is the paper's merge problem at serving scale:
per-block sorted lists reduced by truncated UP-k/DN-k List Offset merges
(repro.kernels.topk). Sampling is data-oblivious up to the final categorical
draw — the paper's security/safety argument for oblivious sorting applies
to the scoring path.

Candidate scoring goes through the unified dispatch API (``repro.topk``):
with a :class:`~repro.parallel.sharding.Parallelism` whose TP axis divides
the vocab, the planner routes to the device-tree sharded top-k from
``repro.streaming.tree`` — each shard scores its vocab slice and the lists
reduce over the mesh axis in log depth instead of gathering the full
logits row onto one device; otherwise it picks the Pallas vocab kernel on
TPU and the schedule executor elsewhere.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import segment_topk
from repro.api import sort as unified_sort
from repro.api import topk as unified_topk


def nucleus_mask(probs_logits: jnp.ndarray, p) -> jnp.ndarray:
    """Mask a *descending* candidate row (…, k) of scaled logits down to
    the smallest prefix with probability mass >= p (top-1 always kept).
    ``p`` may be a python float or a broadcastable array (per-request)."""
    probs = jax.nn.softmax(probs_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = jnp.concatenate(
        [jnp.ones_like(cum[..., :1], bool), cum[..., :-1] < p], axis=-1)
    return jnp.where(keep, probs_logits, -jnp.inf)


def scored_draw(key, vals: jnp.ndarray, temperature, top_p=None) -> jnp.ndarray:
    """Categorical draw over descending top-k candidate *values* (…, k):
    temperature scale, optional nucleus truncation, one draw per row.

    This is the shared tail of :func:`sample_topk` and the scheduler's
    per-slot draws — both paths must produce bit-identical tokens given
    the same key and candidate values, so the arithmetic lives in one
    place. ``temperature``/``top_p`` may be python floats or f32 scalars
    (a float32 array holds the exact same value the weak-typed python
    float converts to, so either form gives the same bits)."""
    probs_logits = vals.astype(jnp.float32) / temperature
    if top_p is not None:
        probs_logits = nucleus_mask(probs_logits, top_p)
    return jax.random.categorical(key, probs_logits, axis=-1)


def canonical_token(logits: jnp.ndarray, vals: jnp.ndarray,
                    choice: jnp.ndarray) -> jnp.ndarray:
    """Map a drawn candidate back to a vocab id, canonicalizing ties.

    ``vals`` (…, k) are descending candidate values from *some* top-k
    backend; ``choice`` (…,) indexes into them. The emitted token is the
    lowest vocab id whose logit equals the drawn value — backends may
    order equal values differently, but the value itself (and hence this
    token) is backend-invariant. Equality is exact: candidate values are
    copies of logit entries in the same dtype."""
    chosen = jnp.take_along_axis(vals, choice[..., None], axis=-1)
    return jnp.argmax(logits == chosen, axis=-1).astype(jnp.int32)


def sample_topk(
    key,
    logits: jnp.ndarray,  # (B, V)
    *,
    k: Union[int, Sequence[int]] = 64,
    temperature: float = 1.0,
    top_p: Union[float, Sequence[float]] = 1.0,
    par=None,
) -> jnp.ndarray:
    """Top-k + temperature categorical sampling -> (B,) int32 tokens.

    ``k`` may be one static int per *request* (a continuous batch mixing
    sampling configs): the scoring then runs as one ragged
    ``repro.segment_topk`` call — every request's vocab row is a segment,
    per-request k, one launch per size class — instead of B separate
    kernels or a pad-to-max-k batch.

    ``top_p < 1.0`` applies nucleus truncation *within* the top-k
    candidate prefix (the kernels hand candidates back descending, so the
    nucleus is one cumsum — no extra sort). Per-request sequences are
    allowed alongside per-request ``k``.

    Tie canonicalization (unsharded path): the emitted token is the
    *lowest* vocab id whose logit equals the drawn candidate value, so
    tokens are independent of which top-k backend scored the row — the
    blockwise/pallas kernels and the segmented CSR path may order equal
    values differently, and the scheduler's bit-equality oracle compares
    across them."""
    if not isinstance(k, (int, np.integer)):
        tps = (tuple(float(x) for x in top_p)
               if not isinstance(top_p, (int, float)) else
               (float(top_p),) * len(tuple(k)))
        return _sample_topk_ragged(key, logits, tuple(int(x) for x in k),
                                   temperature, tps, par=par)
    assert isinstance(top_p, (int, float)), \
        "per-request top_p needs per-request k"
    if temperature <= 0.0 or k == 1:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    vals, idx = unified_topk(logits, k, par=par)
    choice = scored_draw(key, vals, temperature,
                         top_p if top_p < 1.0 else None)  # (B,)
    if par is not None:
        # sharded logits row: avoid the full-vocab compare (a gather)
        return jnp.take_along_axis(
            idx, choice[:, None], axis=-1)[:, 0].astype(jnp.int32)
    return canonical_token(logits, vals, choice)


def _sample_topk_ragged(key, logits: jnp.ndarray, ks, temperature: float,
                        top_ps=None, par=None):
    """Mixed-k continuous batch: per-request vocab top-k through the
    segmented backend, then one categorical draw over each request's own
    candidate prefix (shorter prefixes mask to -inf).

    With a TP-sharded ``par`` the scoring instead runs one *uniform*
    ``max(ks)`` top-k through the unified dispatch — the planner's
    device-tree sharded reduction stays engaged, the vocab row never
    gathers onto one device, and each request still draws only from its
    own ``k_r`` prefix of the descending candidates (identical sample
    law: the top-``k_r`` of a row is the ``k_r`` prefix of its top-k_max).
    """
    b, v = logits.shape
    assert len(ks) == b and all(1 <= x <= v for x in ks), (ks, logits.shape)
    if top_ps is None:
        top_ps = (1.0,) * b
    assert len(top_ps) == b and all(0.0 < x <= 1.0 for x in top_ps), top_ps
    if temperature <= 0.0 or all(x == 1 for x in ks):
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    k_max = max(ks)
    if par is not None:
        dense_v, dense_i = unified_topk(logits, k_max, par=par)  # (B, k_max)
        cnts = jnp.asarray(np.asarray(ks, np.int32))[:, None]
    else:
        offsets = tuple(range(0, (b + 1) * v, v))
        vals, idx, out_offs = segment_topk(logits.reshape(-1), offsets, ks)
        # CSR -> dense (B, k_max) via static maps; pad lanes mask to -inf
        # so the categorical never picks them
        gmap = np.full((b, k_max), out_offs[-1], np.int64)
        for r in range(b):
            cnt = out_offs[r + 1] - out_offs[r]
            gmap[r, :cnt] = out_offs[r] + np.arange(cnt)
        vals_ext = jnp.concatenate([vals, jnp.zeros((1,), vals.dtype)])
        idx_ext = jnp.concatenate([idx, jnp.zeros((1,), idx.dtype)])
        dense_v = vals_ext[jnp.asarray(gmap)]
        dense_i = idx_ext[jnp.asarray(gmap)]
        cnts = jnp.asarray(np.diff(np.asarray(out_offs)))[:, None]
    lane = jnp.arange(k_max)[None, :]
    probs_logits = jnp.where(lane < cnts,
                             dense_v.astype(jnp.float32) / temperature,
                             -jnp.inf)
    if any(p < 1.0 for p in top_ps):
        # per-request nucleus over each row's own valid prefix: -inf pad
        # lanes carry zero mass, so the row cumsum is the request's cumsum
        probs_logits = nucleus_mask(
            probs_logits, jnp.asarray(np.asarray(top_ps, np.float32))[:, None])
    choice = jax.random.categorical(key, probs_logits, axis=-1)  # (B,)
    if par is not None:
        return jnp.take_along_axis(
            dense_i, choice[:, None], axis=-1)[:, 0].astype(jnp.int32)
    return canonical_token(logits, dense_v, choice)


def sample_greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_topp(
    key,
    logits: jnp.ndarray,  # (B, V)
    *,
    p: float = 0.9,
    k_max: Optional[int] = 256,
    temperature: float = 1.0,
    par=None,
) -> jnp.ndarray:
    """Nucleus sampling on the LOMS top-k prefix.

    The merge kernels hand back the candidates already sorted descending,
    so the nucleus is one cumulative sum over the k_max prefix — no extra
    sort. Candidates beyond k_max carry negligible mass for any practical
    p (< 1e-4 at p <= 0.99 for trained LMs).

    ``k_max=None`` makes the nucleus *exact*: the whole vocab row is
    ranked through ``repro.sort`` (descending, indices riding the
    permutation). With a TP-sharded :class:`Parallelism` whose axis
    divides the vocab, the planner routes that ranking to the distributed
    sample-sort backend — the full logits row is never gathered onto one
    device, same as the tree top-k path."""
    if k_max is None:
        v = logits.shape[-1]
        iota = jnp.broadcast_to(jnp.arange(v, dtype=jnp.int32), logits.shape)
        vals, idx = unified_sort(logits, descending=True, payload=iota, par=par)
    else:
        vals, idx = unified_topk(logits, k_max, par=par)  # descending
    probs = jax.nn.softmax(vals.astype(jnp.float32) / temperature, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep the smallest prefix with mass >= p (always keep the top-1)
    keep = jnp.concatenate(
        [jnp.ones_like(cum[:, :1], bool), cum[:, :-1] < p], axis=-1)
    masked = jnp.where(keep, jnp.log(probs + 1e-30), -jnp.inf)
    choice = jax.random.categorical(key, masked, axis=-1)
    return jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0].astype(jnp.int32)
