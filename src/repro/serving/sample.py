"""Decode-time sampling built on the LOMS top-k kernels.

Top-k over a ~152k vocab is the paper's merge problem at serving scale:
per-block sorted lists reduced by truncated UP-k/DN-k List Offset merges
(repro.kernels.topk). Sampling is data-oblivious up to the final categorical
draw — the paper's security/safety argument for oblivious sorting applies
to the scoring path.

Candidate scoring goes through the unified dispatch API (``repro.topk``):
with a :class:`~repro.parallel.sharding.Parallelism` whose TP axis divides
the vocab, the planner routes to the device-tree sharded top-k from
``repro.streaming.tree`` — each shard scores its vocab slice and the lists
reduce over the mesh axis in log depth instead of gathering the full
logits row onto one device; otherwise it picks the Pallas vocab kernel on
TPU and the schedule executor elsewhere.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import segment_topk
from repro.api import sort as unified_sort
from repro.api import topk as unified_topk


def sample_topk(
    key,
    logits: jnp.ndarray,  # (B, V)
    *,
    k: Union[int, Sequence[int]] = 64,
    temperature: float = 1.0,
    par=None,
) -> jnp.ndarray:
    """Top-k + temperature categorical sampling -> (B,) int32 tokens.

    ``k`` may be one static int per *request* (a continuous batch mixing
    sampling configs): the scoring then runs as one ragged
    ``repro.segment_topk`` call — every request's vocab row is a segment,
    per-request k, one launch per size class — instead of B separate
    kernels or a pad-to-max-k batch."""
    if not isinstance(k, (int, np.integer)):
        return _sample_topk_ragged(key, logits, tuple(int(x) for x in k),
                                   temperature, par=par)
    if temperature <= 0.0 or k == 1:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    vals, idx = unified_topk(logits, k, par=par)
    probs_logits = vals.astype(jnp.float32) / temperature
    choice = jax.random.categorical(key, probs_logits, axis=-1)  # (B,)
    return jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0].astype(jnp.int32)


def _sample_topk_ragged(key, logits: jnp.ndarray, ks, temperature: float,
                        par=None):
    """Mixed-k continuous batch: per-request vocab top-k through the
    segmented backend, then one categorical draw over each request's own
    candidate prefix (shorter prefixes mask to -inf).

    With a TP-sharded ``par`` the scoring instead runs one *uniform*
    ``max(ks)`` top-k through the unified dispatch — the planner's
    device-tree sharded reduction stays engaged, the vocab row never
    gathers onto one device, and each request still draws only from its
    own ``k_r`` prefix of the descending candidates (identical sample
    law: the top-``k_r`` of a row is the ``k_r`` prefix of its top-k_max).
    """
    b, v = logits.shape
    assert len(ks) == b and all(1 <= x <= v for x in ks), (ks, logits.shape)
    if temperature <= 0.0 or all(x == 1 for x in ks):
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    k_max = max(ks)
    if par is not None:
        dense_v, dense_i = unified_topk(logits, k_max, par=par)  # (B, k_max)
        cnts = jnp.asarray(np.asarray(ks, np.int32))[:, None]
    else:
        offsets = tuple(range(0, (b + 1) * v, v))
        vals, idx, out_offs = segment_topk(logits.reshape(-1), offsets, ks)
        # CSR -> dense (B, k_max) via static maps; pad lanes mask to -inf
        # so the categorical never picks them
        gmap = np.full((b, k_max), out_offs[-1], np.int64)
        for r in range(b):
            cnt = out_offs[r + 1] - out_offs[r]
            gmap[r, :cnt] = out_offs[r] + np.arange(cnt)
        vals_ext = jnp.concatenate([vals, jnp.zeros((1,), vals.dtype)])
        idx_ext = jnp.concatenate([idx, jnp.zeros((1,), idx.dtype)])
        dense_v = vals_ext[jnp.asarray(gmap)]
        dense_i = idx_ext[jnp.asarray(gmap)]
        cnts = jnp.asarray(np.diff(np.asarray(out_offs)))[:, None]
    lane = jnp.arange(k_max)[None, :]
    probs_logits = jnp.where(lane < cnts,
                             dense_v.astype(jnp.float32) / temperature,
                             -jnp.inf)
    choice = jax.random.categorical(key, probs_logits, axis=-1)  # (B,)
    return jnp.take_along_axis(dense_i, choice[:, None], axis=-1)[:, 0].astype(jnp.int32)


def sample_greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_topp(
    key,
    logits: jnp.ndarray,  # (B, V)
    *,
    p: float = 0.9,
    k_max: Optional[int] = 256,
    temperature: float = 1.0,
    par=None,
) -> jnp.ndarray:
    """Nucleus sampling on the LOMS top-k prefix.

    The merge kernels hand back the candidates already sorted descending,
    so the nucleus is one cumulative sum over the k_max prefix — no extra
    sort. Candidates beyond k_max carry negligible mass for any practical
    p (< 1e-4 at p <= 0.99 for trained LMs).

    ``k_max=None`` makes the nucleus *exact*: the whole vocab row is
    ranked through ``repro.sort`` (descending, indices riding the
    permutation). With a TP-sharded :class:`Parallelism` whose axis
    divides the vocab, the planner routes that ranking to the distributed
    sample-sort backend — the full logits row is never gathered onto one
    device, same as the tree top-k path."""
    if k_max is None:
        v = logits.shape[-1]
        iota = jnp.broadcast_to(jnp.arange(v, dtype=jnp.int32), logits.shape)
        vals, idx = unified_sort(logits, descending=True, payload=iota, par=par)
    else:
        vals, idx = unified_topk(logits, k_max, par=par)  # descending
    probs = jax.nn.softmax(vals.astype(jnp.float32) / temperature, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep the smallest prefix with mass >= p (always keep the top-1)
    keep = jnp.concatenate(
        [jnp.ones_like(cum[:, :1], bool), cum[:, :-1] < p], axis=-1)
    masked = jnp.where(keep, jnp.log(probs + 1e-30), -jnp.inf)
    choice = jax.random.categorical(key, masked, axis=-1)
    return jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0].astype(jnp.int32)
