"""Batched serving engine: prefill + jitted decode loop + LOMS sampling.

The decode step (model decode + sampler) is one jitted function; the cache
is donated every step so serving runs at fixed memory. ``serve_step`` — the
function the decode dry-run shapes lower — is exposed separately for the
launcher/dryrun.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_cache, prefill
from .sample import sample_greedy, sample_topk


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 16
    #: one int, or one per request — a continuous batch mixing sampling
    #: configs scores through the segmented ragged top-k in one launch
    top_k: Union[int, Sequence[int]] = 64
    temperature: float = 1.0
    seed: int = 0


def make_serve_step(cfg: ModelConfig, par=None,
                    top_k: Union[int, Sequence[int]] = 64,
                    temperature: float = 1.0):
    """(params, tokens (B,1), cache, positions, key) -> (next (B,1), cache).

    ``top_k`` follows :func:`repro.serving.sample.sample_topk`: a static
    per-request sequence routes scoring through ``repro.segment_topk``."""

    def serve_step(params, tokens, cache, positions, key):
        logits, cache = decode_step(params, tokens, cache, cfg,
                                    positions=positions, par=par)
        if temperature <= 0.0:
            nxt = sample_greedy(logits)
        else:
            nxt = sample_topk(key, logits, k=top_k, temperature=temperature,
                              par=par)
        return nxt[:, None], cache

    return serve_step


def generate(
    params,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    sc: ServeConfig,
    par=None,
) -> Dict[str, np.ndarray]:
    """Prefill the prompt batch then decode ``max_new_tokens`` greedily or
    with LOMS top-k sampling. Returns tokens + timing stats."""
    bsz, prompt_len = batch["tokens"].shape
    total = prompt_len + sc.max_new_tokens
    if cfg.family == "vlm":
        total += cfg.frontend_len
        prompt_len += cfg.frontend_len
    cache = init_cache(cfg, bsz, total)

    t0 = time.perf_counter()
    logits, cache = jax.jit(
        functools.partial(prefill, cfg=cfg, par=par))(params, batch, cache)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    step = jax.jit(make_serve_step(cfg, par=par, top_k=sc.top_k,
                                   temperature=sc.temperature),
                   donate_argnums=(2,))
    key = jax.random.PRNGKey(sc.seed)
    if sc.temperature <= 0.0:
        tok = sample_greedy(logits)[:, None]
    else:
        key, sub = jax.random.split(key)
        tok = sample_topk(sub, logits, k=sc.top_k,
                          temperature=sc.temperature, par=par)[:, None]
    out = [np.asarray(tok)]
    t1 = time.perf_counter()
    for i in range(sc.max_new_tokens - 1):
        key, sub = jax.random.split(key)
        positions = jnp.full((bsz, 1), prompt_len + i, jnp.int32)
        tok, cache = step(params, tok, cache, positions, sub)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t1
    tokens = np.concatenate(out, axis=1)
    return {
        "tokens": tokens,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tok_per_s": bsz * max(sc.max_new_tokens - 1, 1) / max(t_decode, 1e-9),
    }
