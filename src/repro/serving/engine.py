"""Batched serving engine: prefill + jitted decode loop + LOMS sampling.

The decode step (model decode + sampler) is one jitted function; the cache
is donated every step so serving runs at fixed memory. ``serve_step`` — the
function the decode dry-run shapes lower — is exposed separately for the
launcher/dryrun.

Timing contract (DESIGN.md §13): the decode loop keeps every sampled
token **on device** and transfers once after a final ``block_until_ready``
— a per-step host transfer would serialize dispatch against execution and
the reported decode time would measure the transfer stalls, not the step
function. Per-step latency percentiles are opt-in
(``ServeConfig.time_steps``) because they require a sync per step; the
decode microbenchmark (benchmarks/serve.py) uses them for the
``BENCH_serve.json`` p50/p99 rows. Prefill and decode run under obs spans
and feed the ``serve.*`` metrics when ``REPRO_OBS`` is on.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_cache, prefill
from repro.obs import metrics as obs_metrics
from repro.obs.timing import time_once
from repro.obs.trace import span
from .sample import sample_greedy, sample_topk


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 16
    #: one int, or one per request — a continuous batch mixing sampling
    #: configs scores through the segmented ragged top-k in one launch
    top_k: Union[int, Sequence[int]] = 64
    #: nucleus truncation within the top-k prefix; one float, or one per
    #: request (the latter requires per-request ``top_k`` too)
    top_p: Union[float, Sequence[float]] = 1.0
    temperature: float = 1.0
    seed: int = 0
    #: KV-cache capacity override (must be >= prompt_len +
    #: max_new_tokens). XLA fuses the masked decode-attention reduction
    #: per cache length, so bit-equality across runs requires equal cache
    #: shapes: the scheduler's oracle tests size the solo cache to the
    #: paged slot capacity (pages_per_slot * page_size) to compare
    #: streams bit-for-bit. Positions past the valid length carry exactly
    #: zero attention weight, so capacity never changes the math.
    cache_len: Optional[int] = None
    #: synchronize after every decode step and record per-step wall
    #: times (returned as ``step_times_s`` + p50/p95/p99 µs). Costs one
    #: host sync per token — benchmark mode, off in production serving.
    time_steps: bool = False


def make_serve_step(cfg: ModelConfig, par=None,
                    top_k: Union[int, Sequence[int]] = 64,
                    temperature: float = 1.0,
                    top_p: Union[float, Sequence[float]] = 1.0):
    """(params, tokens (B,1), cache, positions, key) -> (next (B,1), cache).

    ``top_k`` follows :func:`repro.serving.sample.sample_topk`: a static
    per-request sequence routes scoring through ``repro.segment_topk``."""

    def serve_step(params, tokens, cache, positions, key):
        logits, cache = decode_step(params, tokens, cache, cfg,
                                    positions=positions, par=par)
        if temperature <= 0.0:
            nxt = sample_greedy(logits)
        else:
            nxt = sample_topk(key, logits, k=top_k, temperature=temperature,
                              top_p=top_p, par=par)
        return nxt[:, None], cache

    return serve_step


def _percentiles_us(times_s) -> Dict[str, float]:
    """Steady-state decode-step percentiles. The first timed step pays
    the decode jit compile (orders of magnitude above steady state) and
    used to land squarely in p95/p99 for short runs — it is reported
    separately as ``decode_step_compile_us`` and *excluded* from the
    percentiles whenever at least one steady-state step exists."""
    us = np.asarray(times_s, np.float64) * 1e6
    steady = us[1:] if us.size > 1 else us
    return {
        "decode_step_compile_us": float(us[0]),
        "decode_step_p50_us": float(np.percentile(steady, 50)),
        "decode_step_p95_us": float(np.percentile(steady, 95)),
        "decode_step_p99_us": float(np.percentile(steady, 99)),
    }


def generate(
    params,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    sc: ServeConfig,
    par=None,
) -> Dict[str, np.ndarray]:
    """Prefill the prompt batch then decode ``max_new_tokens`` greedily or
    with LOMS top-k sampling. Returns tokens + timing stats.

    ``batch["lengths"]`` (B,) marks right-padded ragged prompts: prefill
    gathers each row's logits at its own last valid position and decode
    continues from there — bit-identical per row to running the unpadded
    prompt alone (attention-cache families only)."""
    bsz, prompt_len = batch["tokens"].shape
    lengths = batch.get("lengths")
    if lengths is not None:
        assert cfg.family in ("dense", "moe"), \
            f"ragged prompts need attention caches, not {cfg.family}"
        lengths = np.asarray(lengths, np.int32)
        assert lengths.shape == (bsz,) and (lengths >= 1).all() \
            and (lengths <= prompt_len).all(), (lengths, batch["tokens"].shape)
        batch = {k: v for k, v in batch.items() if k != "lengths"}
        lengths = jnp.asarray(lengths)
    total = prompt_len + sc.max_new_tokens
    if cfg.family == "vlm":
        total += cfg.frontend_len
        prompt_len += cfg.frontend_len
    if sc.cache_len is not None:
        assert sc.cache_len >= total, (sc.cache_len, total)
        total = sc.cache_len
    cache = init_cache(cfg, bsz, total)

    with span("serve.prefill", kind="run", batch=bsz,
              prompt_len=prompt_len):
        (logits, cache), t_prefill = time_once(
            jax.jit(functools.partial(prefill, cfg=cfg, par=par,
                                      lengths=lengths)),
            params, batch, cache)

    step = jax.jit(make_serve_step(cfg, par=par, top_k=sc.top_k,
                                   temperature=sc.temperature,
                                   top_p=sc.top_p),
                   donate_argnums=(2,))
    key = jax.random.PRNGKey(sc.seed)
    if sc.temperature <= 0.0:
        tok = sample_greedy(logits)[:, None]
    else:
        key, sub = jax.random.split(key)
        tok = sample_topk(sub, logits, k=sc.top_k,
                          temperature=sc.temperature, top_p=sc.top_p,
                          par=par)[:, None]
    # device-resident token buffer: transferring (or even np.asarray-ing)
    # inside the loop would force a sync per step and serialize dispatch
    toks = [tok]
    step_times = [] if sc.time_steps else None
    n_steps = sc.max_new_tokens - 1
    t1 = time.perf_counter()
    with span("serve.decode", kind="run", batch=bsz, steps=n_steps):
        for i in range(n_steps):
            key, sub = jax.random.split(key)
            if lengths is None:
                positions = jnp.full((bsz, 1), prompt_len + i, jnp.int32)
            else:
                positions = (lengths + i)[:, None]
            if step_times is not None:
                (tok, cache), dt = time_once(step, params, tok, cache,
                                             positions, sub)
                step_times.append(dt)
            else:
                tok, cache = step(params, tok, cache, positions, sub)
            toks.append(tok)
        jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t1
    tokens = np.concatenate([np.asarray(t) for t in toks], axis=1)
    tok_per_s = bsz * max(n_steps, 1) / max(t_decode, 1e-9)
    obs_metrics.counter("serve.requests").inc(bsz)
    obs_metrics.counter("serve.decode_steps").inc(max(n_steps, 0))
    obs_metrics.counter("serve.tokens").inc(int(tokens.size))
    obs_metrics.histogram("serve.tok_per_s").observe(tok_per_s)
    out: Dict[str, np.ndarray] = {
        "tokens": tokens,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tok_per_s": tok_per_s,
    }
    if step_times:
        out["step_times_s"] = np.asarray(step_times)
        out.update(_percentiles_us(step_times))
    return out
