"""Production serving scheduler over the LOMS sampling kernels.

Disaggregated prefill/decode with continuous batching: an admission
queue feeds prompt-length-bucketed prefill batches, each admitted
request gets a page-granular KV-cache slot from a fixed pool, and one
persistent jitted decode step advances every occupied slot — drawing
each request's next token through a single segmented ``segment_topk``
launch (per-request k / top-p / temperature / seed).

The bit-equality contract: every request's token stream is identical to
running it alone through the one-shot :func:`repro.serving.engine.generate`
with ``cache_len`` equal to the slot capacity. DESIGN.md §14 documents
the request lifecycle and the invariants that make this hold.
"""
from .engine import ScheduledEngine, SchedulerConfig  # noqa: F401
from .paged import PagedKVCache, SlotManager  # noqa: F401
from .params import SamplingParams  # noqa: F401
from .queue import AdmissionQueue, QueueFull  # noqa: F401
from .request import Request, RequestState, TERMINAL_STATES  # noqa: F401
