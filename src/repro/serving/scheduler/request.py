"""Request lifecycle state (DESIGN.md §14, §16): QUEUED → RUNNING → DONE,
plus the three failure terminals.

Prefill + slot insert happen within one scheduler tick, so there is no
separate PREFILL state — a request is QUEUED until its cache row lands
in a slot, RUNNING while the slot decodes, DONE after eviction. The
failure terminals (each releasing any held slot and pages):

* **TIMED_OUT** — the request's ``deadline_ms`` / ``ttl_ticks`` elapsed,
  queued or running;
* **FAILED** — prefill/insert/decode exhausted the engine's bounded
  retries (``error`` records why);
* **REJECTED** — load-shed at ``submit()``: the admission queue was at
  ``max_queue`` (the raised :class:`~.queue.QueueFull` carries a
  retry-after hint).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np

from .params import SamplingParams


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    TIMED_OUT = "timed_out"
    FAILED = "failed"
    REJECTED = "rejected"


#: states a request can never leave (everything but QUEUED / RUNNING)
TERMINAL_STATES = frozenset({RequestState.DONE, RequestState.TIMED_OUT,
                             RequestState.FAILED, RequestState.REJECTED})


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (len,) int32
    params: SamplingParams
    arrival: int = 0  # virtual tick (admission is tick-deterministic)

    #: per-request trace id (engine-assigned at submit): groups this
    #: request's stage spans (queue-wait → prefill → insert → decode
    #: ticks) in the obs export so TTFT and tail latency decompose into
    #: named stages (DESIGN.md §17)
    trace_id: str = ""

    state: RequestState = RequestState.QUEUED
    slot: Optional[int] = None
    #: generated tokens (first one sampled from the prefill logits)
    tokens: List[int] = dataclasses.field(default_factory=list)
    #: cache depth: positions filled in the slot so far
    length: int = 0
    #: per-request PRNG chain — split exactly as the solo generate() does
    key: Optional[object] = None

    # wall-clock latency markers (metrics only; never affect scheduling —
    # except deadline_ms, which is wall-clock by definition)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_finish: float = 0.0
    # ns twins on the perf_counter_ns clock, shared with the span tracer
    # so per-request stage spans reconcile *exactly* (integer ns) with
    # the measured TTFT / request latency
    t_submit_ns: int = 0
    t_first_ns: int = 0
    t_finish_ns: int = 0
    admit_tick: int = -1
    finish_tick: int = -1
    #: why a FAILED/TIMED_OUT/REJECTED request ended (human-readable)
    error: Optional[str] = None

    def expired(self, tick: int, now: float) -> bool:
        """Whether the deadline has passed at virtual ``tick`` / wall
        ``now`` (perf_counter seconds)."""
        p = self.params
        if p.ttl_ticks is not None and tick - self.arrival >= p.ttl_ticks:
            return True
        return (p.deadline_ms is not None and self.t_submit > 0.0
                and (now - self.t_submit) * 1e3 >= p.deadline_ms)
