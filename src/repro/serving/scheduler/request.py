"""Request lifecycle state (DESIGN.md §14): QUEUED → RUNNING → DONE.

Prefill + slot insert happen within one scheduler tick, so there is no
separate PREFILL state — a request is QUEUED until its cache row lands
in a slot, RUNNING while the slot decodes, DONE after eviction.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np

from .params import SamplingParams


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (len,) int32
    params: SamplingParams
    arrival: int = 0  # virtual tick (admission is tick-deterministic)

    state: RequestState = RequestState.QUEUED
    slot: Optional[int] = None
    #: generated tokens (first one sampled from the prefill logits)
    tokens: List[int] = dataclasses.field(default_factory=list)
    #: cache depth: positions filled in the slot so far
    length: int = 0
    #: per-request PRNG chain — split exactly as the solo generate() does
    key: Optional[object] = None

    # wall-clock latency markers (metrics only; never affect scheduling)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_finish: float = 0.0
    admit_tick: int = -1
    finish_tick: int = -1
