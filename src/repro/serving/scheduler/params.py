"""Per-request sampling parameters.

``k``/``greedy``/``topp_active`` are *static* under the decode jit: the
scheduler compiles one decode-step program per batch composition (the
tuple of per-slot signatures), while temperature and top-p values ride
along as f32 scalars — an f32 array holds the exact value the weak-typed
python float converts to, so the arithmetic is bit-identical either way.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How one request samples. Mirrors the knobs of
    :class:`repro.serving.engine.ServeConfig` at per-request granularity."""

    k: int = 64
    top_p: float = 1.0
    temperature: float = 1.0
    max_new_tokens: int = 16
    seed: int = 0
    #: wall-clock deadline from ``submit()``: the request times out (slot
    #: and pages reclaimed) once this many milliseconds have elapsed —
    #: whether still queued or mid-decode. Wall time is inherently
    #: non-deterministic; chaos tests use ``ttl_ticks`` instead.
    deadline_ms: Optional[float] = None
    #: virtual-tick TTL: the request times out once
    #: ``tick - arrival >= ttl_ticks``. Deterministic under the
    #: scheduler's tick clock — the replayable deadline for tests.
    ttl_ticks: Optional[int] = None

    def __post_init__(self):
        assert self.k >= 1, self.k
        assert 0.0 < self.top_p <= 1.0, self.top_p
        assert self.max_new_tokens >= 1, self.max_new_tokens
        assert self.deadline_ms is None or self.deadline_ms > 0, self.deadline_ms
        assert self.ttl_ticks is None or self.ttl_ticks >= 1, self.ttl_ticks

    @property
    def greedy(self) -> bool:
        """Mirrors ``sample_topk``'s argmax shortcut (``temperature <= 0``
        or ``k == 1``) so scheduler draws match the solo path exactly."""
        return self.temperature <= 0.0 or self.k == 1

    @property
    def topp_active(self) -> bool:
        """Whether the nucleus mask applies — must mirror the solo path's
        ``top_p if top_p < 1.0 else None`` (p=1.0 with float-rounded
        cumsums could otherwise mask real lanes and change the draw)."""
        return not self.greedy and self.top_p < 1.0

    @property
    def sig(self):
        """Static per-slot decode signature: (k, greedy, topp_active)."""
        return (self.k, self.greedy, self.topp_active)
