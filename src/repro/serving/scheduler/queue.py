"""FIFO admission queue ordered by (arrival tick, request id).

Admission is head-of-line blocking on purpose: if the oldest arrived
request does not fit (no free slot / pages), nothing younger jumps it.
That makes the admission order — and therefore every compiled batch
composition — a pure function of the arrival trace, which the
determinism tests rely on.
"""
from __future__ import annotations

import heapq
from typing import List, Optional

from .request import Request


class AdmissionQueue:
    def __init__(self):
        self._heap: List[tuple] = []

    def push(self, req: Request) -> None:
        heapq.heappush(self._heap, (req.arrival, req.rid, req))

    def peek(self) -> Optional[Request]:
        return self._heap[0][2] if self._heap else None

    def pop(self) -> Request:
        return heapq.heappop(self._heap)[2]

    def next_arrival(self) -> Optional[int]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)
