"""FIFO admission queue ordered by (arrival tick, request id).

Admission is head-of-line blocking on purpose: if the oldest arrived
request does not fit (no free slot / pages), nothing younger jumps it.
That makes the admission order — and therefore every compiled batch
composition — a pure function of the arrival trace, which the
determinism tests rely on.

Backpressure (DESIGN.md §16): construct with ``max_queue`` to bound the
depth — ``push`` past the bound raises :class:`QueueFull` instead of
letting an overload grow the queue (and every queued deadline slip)
without limit. Deadline-expired queued requests are removed wholesale
with :func:`drain_expired`, which preserves the FIFO order of the
survivors.
"""
from __future__ import annotations

import heapq
from typing import Callable, List, Optional

from .request import Request


class QueueFull(RuntimeError):
    """Load-shed signal: the admission queue is at ``max_queue``.

    Carries the observed ``depth`` and bound, plus ``retry_after_ticks``
    — a hint of how many scheduler ticks until space is plausible (the
    caller backs off instead of hammering submit)."""

    def __init__(self, depth: int, max_queue: int,
                 retry_after_ticks: int = 1):
        super().__init__(
            f"admission queue full ({depth}/{max_queue}); "
            f"retry after ~{retry_after_ticks} tick(s)")
        self.depth = depth
        self.max_queue = max_queue
        self.retry_after_ticks = retry_after_ticks


class AdmissionQueue:
    def __init__(self, max_queue: Optional[int] = None):
        assert max_queue is None or max_queue >= 1, max_queue
        self.max_queue = max_queue
        self._heap: List[tuple] = []

    def push(self, req: Request) -> None:
        if self.max_queue is not None and len(self._heap) >= self.max_queue:
            raise QueueFull(len(self._heap), self.max_queue)
        heapq.heappush(self._heap, (req.arrival, req.rid, req))

    def peek(self) -> Optional[Request]:
        return self._heap[0][2] if self._heap else None

    def pop(self) -> Request:
        return heapq.heappop(self._heap)[2]

    def next_arrival(self) -> Optional[int]:
        return self._heap[0][0] if self._heap else None

    def drain_expired(self, expired: Callable[[Request], bool]) -> List[Request]:
        """Remove and return every queued request for which ``expired``
        holds; the survivors keep their (arrival, rid) order."""
        out = [req for _, _, req in self._heap if expired(req)]
        if out:
            self._heap = [e for e in self._heap if not expired(e[2])]
            heapq.heapify(self._heap)
        return sorted(out, key=lambda r: (r.arrival, r.rid))

    def __len__(self) -> int:
        return len(self._heap)
