"""Paged KV-cache pool: page-granular slots over the stacked layer caches.

The pool is one ``stack_cache_init(cfg, batch=n_pages, max_len=page_size)``
body — every leaf is ``(L, P, *block)`` with a ``page_size`` sequence dim
somewhere in ``block`` (axis per leaf name below). A *slot* is a
``pages_per_slot``-entry row of the page table; gathering a batch of slot
rows and merging the page axis into the sequence axis reconstructs a
dense ``(L, ns, ..., pages_per_slot * page_size, ...)`` cache view that
``decode_step`` consumes unchanged.

Invariants (DESIGN.md §14):
  * page 0 is reserved scratch — free page-table entries point at it, so
    a gather is always dense and in-bounds; positions past a slot's valid
    length carry exactly zero attention weight (the -1e30 mask underflows
    ``exp`` to 0.0), so scratch contents never reach the math.
  * decode writes land only in allocated pages: admission sizes the
    allocation to ``ceil((prompt + max_new) / page_size)`` up front, so
    ``lengths // page_size`` always indexes an owned page.
  * the gather/scatter round-trip is bit-exact — pages are copies, the
    merge is a reshape, and the write-back scatters the single written
    column, so a gathered view equals the contiguous cache bit-for-bit.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_cache

#: sequence axis of each cache leaf, indexed from the END of the per-page
#: block (valid for both the per-layer (B, *block) and pooled
#: (L, P, *block) layouts): k (B,hkv,hd,S), v (B,hkv,S,hd),
#: ckv (B,rank,S), kpe (B,rope,S)
_SEQ_AXIS = {"k": -1, "v": -2, "ckv": -1, "kpe": -1}


def gather_view(leaves: Dict[str, jnp.ndarray], page_table: jnp.ndarray,
                lengths: jnp.ndarray, page_size: int) -> Dict[str, jnp.ndarray]:
    """Reconstruct a dense batched cache body from slot page rows.

    ``page_table`` (ns, npg) int32, ``lengths`` (ns,) valid depths →
    body dict with leaves (L, ns, ..., npg*page_size, ...) plus a
    stacked ``pos`` of per-row lengths (the decode write index and
    attention valid-length both read ``cache["pos"]``)."""
    ns, npg = page_table.shape
    flat = page_table.reshape(-1)
    view: Dict[str, jnp.ndarray] = {}
    n_layers = 0
    for name, pool in leaves.items():
        n_layers = pool.shape[0]
        g = jnp.take(pool, flat, axis=1)
        g = g.reshape((n_layers, ns, npg) + pool.shape[2:])
        ax = g.ndim + _SEQ_AXIS[name]  # abs index of the page-seq axis
        g = jnp.moveaxis(g, 2, ax - 1)  # page axis next to its seq axis
        view[name] = g.reshape(
            g.shape[: ax - 1] + (npg * page_size,) + g.shape[ax + 1:])
    view["pos"] = jnp.broadcast_to(
        jnp.reshape(lengths, (1, ns)).astype(jnp.int32), (n_layers, ns))
    return view


def take_col(view_leaf: jnp.ndarray, name: str,
             positions: jnp.ndarray) -> jnp.ndarray:
    """Extract one sequence column per row: (L, ns, *block-with-seq) at
    per-row ``positions`` (ns,) → (L, ns, *block-without-seq)."""
    ax = view_leaf.ndim + _SEQ_AXIS[name]
    shape = [1] * view_leaf.ndim
    shape[1] = -1
    p = positions.reshape(shape).astype(jnp.int32)
    return jnp.squeeze(jnp.take_along_axis(view_leaf, p, axis=ax), axis=ax)


def scatter_col(pool: jnp.ndarray, name: str, col: jnp.ndarray,
                page_ids: jnp.ndarray, offs: jnp.ndarray) -> jnp.ndarray:
    """Write one column per slot into the pool: ``col`` (L, ns, *block-
    without-seq) lands at (page_ids[s], offs[s]) for each slot s.

    The two index arrays sit at non-adjacent axes (1 and the seq axis),
    so numpy advanced indexing moves the broadcast slot dim to the FRONT
    of the result — hence the moveaxis putting slots first."""
    idx = [slice(None)] * pool.ndim
    idx[1] = page_ids
    idx[pool.ndim + _SEQ_AXIS[name]] = offs
    return pool.at[tuple(idx)].set(jnp.moveaxis(col, 1, 0))


def split_pages(prefill_leaf: jnp.ndarray, name: str, row,
                npg: int, page_size: int) -> jnp.ndarray:
    """Slice one prefill-cache row into page blocks for a pool write:
    (L, bb, *block seq=blen) row → (L, npg, *block seq=page_size)."""
    rowv = jax.lax.dynamic_index_in_dim(prefill_leaf, row, 1, keepdims=False)
    ax = rowv.ndim + _SEQ_AXIS[name]
    rowv = jax.lax.slice_in_dim(rowv, 0, npg * page_size, axis=ax)
    rowv = rowv.reshape(rowv.shape[:ax] + (npg, page_size) + rowv.shape[ax + 1:])
    return jnp.moveaxis(rowv, ax, 1)


class PagedKVCache:
    """Device-side page pool: the stacked cache body with batch = pages.

    Only homogeneous attention stacks (cache = ``{"body": ...}``, leaf
    names in ``_SEQ_AXIS``) are supported — that covers the dense and
    qwen3-moe families the scheduler serves."""

    def __init__(self, cfg, n_pages: int, page_size: int):
        cache = init_cache(cfg, n_pages, page_size)
        assert set(cache.keys()) == {"body"}, (
            f"paged slots need a homogeneous attention stack, "
            f"got cache groups {sorted(cache)}")
        body = cache["body"]
        unknown = set(body) - set(_SEQ_AXIS) - {"pos"}
        assert not unknown, f"unsupported cache leaves: {sorted(unknown)}"
        self.leaves: Dict[str, jnp.ndarray] = {
            n: a for n, a in body.items() if n != "pos"}
        self.n_pages = n_pages
        self.page_size = page_size
        self.n_layers = next(iter(self.leaves.values())).shape[0]

    @property
    def nbytes(self) -> int:
        return sum(int(a.nbytes) for a in self.leaves.values())


class SlotManager:
    """Host-side slot + page bookkeeping (free lists, page table).

    ``order`` picks which free slot is reused next — "fifo" (queue) or
    "lifo" (stack). Token bits must be invariant to it (the determinism
    tests flip it); only metrics and memory layout may differ."""

    def __init__(self, n_slots: int, pages_per_slot: int, n_pages: int,
                 order: str = "fifo"):
        assert order in ("fifo", "lifo"), order
        assert n_slots >= 1 and pages_per_slot >= 1
        assert n_pages >= 1 + pages_per_slot, (
            "pool needs the reserved scratch page plus one full slot")
        self.n_slots = n_slots
        self.pages_per_slot = pages_per_slot
        self.order = order
        self.page_table = np.zeros((n_slots, pages_per_slot), np.int32)
        self._free_slots = deque(range(n_slots))
        self._free_pages = deque(range(1, n_pages))  # page 0 = scratch
        self._n_alloc: Dict[int, int] = {}

    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    @property
    def free_page_count(self) -> int:
        return len(self._free_pages)

    def can_admit(self, npg: int) -> bool:
        return bool(self._free_slots) and len(self._free_pages) >= npg

    def alloc(self, npg: int) -> Tuple[int, np.ndarray]:
        """Claim a slot and ``npg`` pages; unfilled page-table entries
        stay 0 (the scratch page), keeping gathers dense."""
        assert 0 < npg <= self.pages_per_slot, npg
        assert self.can_admit(npg), (npg, self.free_slot_count,
                                     self.free_page_count)
        slot = (self._free_slots.popleft() if self.order == "fifo"
                else self._free_slots.pop())
        pages = np.asarray([self._free_pages.popleft() for _ in range(npg)],
                           np.int32)
        self.page_table[slot] = 0
        self.page_table[slot, :npg] = pages
        self._n_alloc[slot] = npg
        return slot, pages

    def release(self, slot: int) -> None:
        npg = self._n_alloc.pop(slot)
        for p in self.page_table[slot, :npg]:
            self._free_pages.append(int(p))
        self.page_table[slot] = 0
        self._free_slots.append(slot)
