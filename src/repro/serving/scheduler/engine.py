"""Scheduled serving engine: admit → bucketed prefill → slot insert →
continuous-batching decode, bit-identical per request to solo generate().

Disaggregation: prefill batches compile per (bucket length, batch) pair;
the decode step compiles per batch *composition* — the tuple of per-slot
(k, greedy, top-p-active) signatures — and is reused for every tick with
that composition. One scheduler tick = admit everything arrived (FIFO,
head-of-line blocking), prefill + insert the admissions, then one decode
step over all occupied slots drawing every request's next token through
a single segmented ``segment_topk`` launch.

Bit-equality oracle (CI-gated, tests/test_scheduler.py): each request's
token stream equals running it alone through one-shot ``generate()`` with
``ServeConfig(cache_len = pages_per_slot * page_size)``. The load-bearing
pieces:
  * prefill logits are padding/batch-invariant (causal masking; the
    gather at ``lengths - 1`` picks each row's own last position);
  * ``decode_attention`` reduces per-row (``jax.lax.map``) so decode
    logits are invariant to how many slots share the batch;
  * candidate *values* from ``segment_topk`` match ``unified_topk``
    bitwise (selection copies inputs; no float arithmetic), and token
    emission canonicalizes ties to the lowest vocab id;
  * per-request PRNG chains split exactly like generate()'s
    (``vmap(split)`` produces the same per-row bits as solo splits);
  * the gathered slot view has the same sequence capacity
    (``pages_per_slot * page_size``) as the solo cache, so XLA lowers
    the same masked reduction.

Time is a virtual tick counter — admission order is a pure function of
the (arrival, rid) trace, never of wall clock. Wall time feeds only the
latency metrics (TTFT / TPOT / request latency) and the opt-in
``deadline_ms`` wall-clock deadline.

Failure hardening (DESIGN.md §16): ticks start by expiring requests past
their ``ttl_ticks``/``deadline_ms`` (slot + pages reclaimed); every
prefill/insert/decode launch runs under bounded retry with exponential
backoff, and exhausted retries turn the launch's requests terminal
FAILED instead of wedging the engine; ``submit`` load-sheds with
:class:`~.queue.QueueFull` once the queue holds ``max_queue`` requests.
The invariant the chaos suite gates: whatever faults fire, ``run()``
drains — every request reaches a terminal state and no slot or page
stays allocated.
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import segment_topk
from repro.configs.base import ModelConfig
from repro.models import decode_step, init_cache, prefill
from repro.obs import metrics as obs_metrics
from repro.obs import recorder as obs_recorder
from repro.obs.trace import enabled as obs_enabled
from repro.obs.trace import record_span, span
from repro.resilience.failpoints import failpoint

from ..sample import canonical_token, sample_greedy, sample_topk, scored_draw
from .paged import PagedKVCache, SlotManager, gather_view, scatter_col, split_pages, take_col
from .params import SamplingParams
from .queue import AdmissionQueue, QueueFull
from .request import Request, RequestState


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


@dataclasses.dataclass
class SchedulerConfig:
    n_slots: int = 4
    page_size: int = 16
    pages_per_slot: int = 8
    #: pool size; default reserves page 0 as scratch and gives every slot
    #: a full complement
    n_pages: Optional[int] = None
    max_prefill_batch: int = 4
    #: free-slot reuse order ("fifo" | "lifo") — token bits must not
    #: depend on it (determinism tests flip it)
    slot_order: str = "fifo"
    #: admission-queue bound; ``submit`` past it raises
    #: :class:`~.queue.QueueFull` (None = unbounded, the pre-§16 behavior)
    max_queue: Optional[int] = None
    #: retries per prefill/insert/decode launch before the batch's
    #: requests go FAILED (0 = fail on the first error)
    max_retries: int = 2
    #: base of the exponential retry backoff (seconds; attempt n sleeps
    #: ``retry_backoff_s * 2**n``)
    retry_backoff_s: float = 0.05

    def __post_init__(self):
        assert self.page_size >= 1 and (self.page_size & (self.page_size - 1)) == 0, \
            f"page_size must be a power of two, got {self.page_size}"
        assert self.max_prefill_batch >= 1
        assert self.max_queue is None or self.max_queue >= 1
        assert self.max_retries >= 0 and self.retry_backoff_s >= 0.0

    @property
    def slot_capacity(self) -> int:
        return self.pages_per_slot * self.page_size


class ScheduledEngine:
    """Continuous-batching engine over a paged slot pool.

    Usage: ``submit()`` any number of requests (each with its own
    :class:`SamplingParams` and arrival tick), then ``run()`` to drain —
    or drive ``step()`` tick by tick."""

    def __init__(self, params, cfg: ModelConfig, sched: Optional[SchedulerConfig] = None):
        sched = sched or SchedulerConfig()
        assert cfg.family in ("dense", "moe"), (
            f"scheduler serves homogeneous attention stacks, not {cfg.family}")
        self.params = params
        self.cfg = cfg
        self.sc = sched
        n_pages = sched.n_pages or 1 + sched.n_slots * sched.pages_per_slot
        self.pool = PagedKVCache(cfg, n_pages, sched.page_size)
        self.slots = SlotManager(sched.n_slots, sched.pages_per_slot,
                                 n_pages, order=sched.slot_order)
        self.queue = AdmissionQueue(sched.max_queue)
        self.requests: Dict[int, Request] = {}
        self.active: Dict[int, Request] = {}  # slot -> request
        self.t = 0
        self._next_rid = 0
        self._prefill_jits: Dict[tuple, object] = {}
        self._insert_jits: Dict[tuple, object] = {}
        self._decode_jits: Dict[tuple, object] = {}
        #: batch signatures whose decode jit has already been launched —
        #: the first tick per signature pays the compile and is tagged
        #: ``compiled=True`` on its ``req.decode`` spans
        self._decode_seen: set = set()
        self._trace_prefix = f"{os.getpid():x}-{id(self) & 0xFFFF:04x}"

    # ----------------------------------------------------------------- API

    def submit(self, prompt, params: Optional[SamplingParams] = None,
               arrival: int = 0) -> int:
        params = params or SamplingParams()
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size >= 1, "empty prompt"
        need = prompt.size + params.max_new_tokens
        if need > self.sc.slot_capacity:
            raise ValueError(
                f"prompt+max_new_tokens = {need} exceeds slot capacity "
                f"{self.sc.slot_capacity} (pages_per_slot * page_size)")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, params=params,
                      arrival=int(arrival),
                      trace_id=f"{self._trace_prefix}-{rid}")
        req.t_submit_ns = time.perf_counter_ns()
        req.t_submit = req.t_submit_ns * 1e-9
        self.requests[rid] = req
        try:
            self.queue.push(req)
        except QueueFull as e:
            # load-shed: the request is kept (terminal REJECTED, queryable)
            # but never queued; the raised error carries the retry hint
            req.state = RequestState.REJECTED
            req.error = str(e)
            req.finish_tick = self.t
            req.t_finish_ns = time.perf_counter_ns()
            req.t_finish = req.t_finish_ns * 1e-9
            obs_metrics.counter("sched.rejected").inc()
            self._record_request(req)
            raise
        obs_metrics.counter("sched.submitted").inc()
        return rid

    def step(self) -> None:
        """One scheduler tick: expire → admit → prefill/insert → one
        decode step."""
        self._expire()
        admitted = self._admit()
        if admitted:
            self._run_prefill(admitted)
        if self.active:
            self._run_decode()
        self._gauges()
        self.t += 1

    def run(self, max_steps: int = 1_000_000) -> Dict[int, np.ndarray]:
        """Drain the queue; returns {rid: generated tokens}.

        An *unhandled* exception (anything the retry/fail machinery did
        not absorb) dumps the flight recorder — to ``REPRO_OBS_DUMP`` if
        set, else a bounded event tail to stderr — before propagating,
        so the post-mortem has the breaker/failpoint/span history that
        led up to the crash."""
        steps = 0
        try:
            while (len(self.queue) or self.active) and steps < max_steps:
                if not self.active:
                    nxt = self.queue.next_arrival()
                    if nxt is not None and nxt > self.t:
                        self.t = nxt  # idle fast-forward to next arrival
                self.step()
                steps += 1
        except Exception as e:  # noqa: BLE001 — dump context, re-raise
            obs_recorder.crash_dump("sched.run", e)
            raise
        assert not len(self.queue) and not self.active, \
            f"drain incomplete after {steps} steps"
        return {rid: np.asarray(r.tokens, np.int32)
                for rid, r in self.requests.items()
                if r.state is RequestState.DONE}

    def result(self, rid: int) -> np.ndarray:
        r = self.requests[rid]
        assert r.state is RequestState.DONE, (
            f"request {rid} is {r.state.value}"
            + (f": {r.error}" if r.error else ""))
        return np.asarray(r.tokens, np.int32)

    # ----------------------------------------- deadlines, failures, retries

    def _expire(self) -> None:
        """Time out every request (queued or running) whose ``ttl_ticks``
        / ``deadline_ms`` has elapsed, reclaiming slot and pages."""
        now = time.perf_counter()
        for r in self.queue.drain_expired(lambda q: q.expired(self.t, now)):
            self._timeout(r)
        for r in [r for r in self.active.values() if r.expired(self.t, now)]:
            self._timeout(r)

    def _release(self, r: Request) -> None:
        if r.slot is not None:
            self.slots.release(r.slot)
            self.active.pop(r.slot, None)
            r.slot = None

    def _record_request(self, r: Request) -> None:
        """Close the request's root span at its terminal state. Stage
        spans (``req.queue_wait``/``req.prefill``/``req.insert``/
        ``req.decode``) were recorded as the stages ran; the root span
        carries the whole submit→terminal window plus the trace id, so
        the exporter (``obs.request_waterfalls``) can rebuild the
        per-request timeline and reconcile stage sums against the
        measured latency."""
        if not obs_enabled():
            return
        record_span("request", r.t_submit_ns,
                    (r.t_finish_ns or time.perf_counter_ns()) - r.t_submit_ns,
                    rid=r.rid, trace_id=r.trace_id, state=r.state.value,
                    tokens=len(r.tokens), arrival=r.arrival,
                    finish_tick=r.finish_tick)
        obs_recorder.emit("sched", f"request.{r.state.value}", rid=r.rid,
                          trace_id=r.trace_id, tokens=len(r.tokens))

    def _mark_finish(self, r: Request) -> None:
        r.finish_tick = self.t
        r.t_finish_ns = time.perf_counter_ns()
        r.t_finish = r.t_finish_ns * 1e-9

    def _timeout(self, r: Request) -> None:
        r.state = RequestState.TIMED_OUT
        r.error = f"deadline elapsed at tick {self.t}"
        self._mark_finish(r)
        self._release(r)
        obs_metrics.counter("sched.timed_out").inc()
        self._record_request(r)

    def _fail(self, r: Request, err: str) -> None:
        r.state = RequestState.FAILED
        r.error = err
        self._mark_finish(r)
        self._release(r)
        obs_metrics.counter("sched.failed").inc()
        self._record_request(r)

    def _with_retry(self, what: str, fn):
        """Run one launch closure with bounded retry + exponential
        backoff. The ``sched.{what}`` failpoint fires *before* the
        closure, so an injected fault never lands after a donated buffer
        was consumed — retries always see valid inputs."""
        attempt = 0
        while True:
            try:
                failpoint(f"sched.{what}")
                return fn()
            except Exception:
                if attempt >= self.sc.max_retries:
                    raise
                obs_metrics.counter("sched.retries").inc(what=what)
                if self.sc.retry_backoff_s:
                    time.sleep(self.sc.retry_backoff_s * (2 ** attempt))
                attempt += 1

    # ----------------------------------------------------------- admission

    def _npg_need(self, req: Request) -> int:
        return math.ceil(
            (req.prompt.size + req.params.max_new_tokens) / self.sc.page_size)

    def _admit(self) -> List[Request]:
        admitted = []
        free_slots = self.slots.free_slot_count
        free_pages = self.slots.free_page_count
        while len(self.queue):
            req = self.queue.peek()
            npg = self._npg_need(req)
            if req.arrival > self.t:
                break
            if free_slots < 1 or free_pages < npg:
                break  # head-of-line blocking keeps admission deterministic
            free_slots -= 1
            free_pages -= npg
            self.queue.pop()
            req.admit_tick = self.t
            admitted.append(req)
        if admitted:
            obs_metrics.counter("sched.admitted").inc(len(admitted))
        return admitted

    # ------------------------------------------------------------- prefill

    def _bucket(self, plen: int) -> int:
        return max(self.sc.page_size, _next_pow2(plen))

    def _prefill_fn(self, blen: int, bb: int):
        key = (blen, bb)
        if key not in self._prefill_jits:
            cfg = self.cfg

            def f(params, tokens, lengths):
                cache = init_cache(cfg, bb, blen)
                logits, cache = prefill(params, {"tokens": tokens}, cache,
                                        cfg, lengths=lengths)
                return logits, cache["body"]

            self._prefill_jits[key] = jax.jit(f)
        return self._prefill_jits[key]

    def _insert_fn(self, npg: int, blen: int, bb: int):
        key = (npg, blen, bb)
        if key not in self._insert_jits:
            ps = self.sc.page_size

            def f(leaves, body, row, page_ids):
                out = {}
                for name, pool_leaf in leaves.items():
                    val = split_pages(body[name], name, row, npg, ps)
                    out[name] = pool_leaf.at[:, page_ids].set(val)
                return out

            self._insert_jits[key] = jax.jit(f, donate_argnums=(0,))
        return self._insert_jits[key]

    def _first_token(self, logits_row, p: SamplingParams):
        """Sample the first token from the prefill logits, mirroring the
        head of generate(): greedy never touches the key; otherwise one
        split and a (1, V) eager sample_topk — exactly the solo shapes."""
        key = jax.random.PRNGKey(p.seed)
        if p.temperature <= 0.0:
            return int(sample_greedy(logits_row[None])[0]), key
        key, sub = jax.random.split(key)
        tok = sample_topk(sub, logits_row[None], k=p.k,
                          temperature=p.temperature, top_p=p.top_p)
        return int(tok[0]), key

    def _run_prefill(self, admitted: List[Request]) -> None:
        groups: Dict[int, List[Request]] = {}
        for r in admitted:
            groups.setdefault(self._bucket(r.prompt.size), []).append(r)
        for blen in sorted(groups):
            reqs = groups[blen]
            for i0 in range(0, len(reqs), self.sc.max_prefill_batch):
                self._prefill_batch(blen, reqs[i0:i0 + self.sc.max_prefill_batch])

    def _prefill_batch(self, blen: int, reqs: List[Request]) -> None:
        bb = len(reqs)
        toks = np.zeros((bb, blen), np.int32)
        lens = np.zeros((bb,), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :r.prompt.size] = r.prompt
            lens[i] = r.prompt.size
        def launch_prefill():
            logits, body = self._prefill_fn(blen, bb)(
                self.params, jnp.asarray(toks), jnp.asarray(lens))
            jax.block_until_ready(logits)
            return logits, body

        traced = obs_enabled()
        t_pf0 = time.perf_counter_ns()
        with span("sched.prefill", kind="run", batch=bb, bucket=blen):
            try:
                logits, body = self._with_retry("prefill", launch_prefill)
            except Exception as e:  # noqa: BLE001 — retries exhausted
                for r in reqs:  # no slots were allocated yet: nothing leaks
                    self._fail(r, f"prefill failed: {type(e).__name__}: {e}")
                return
        t_pf1 = time.perf_counter_ns()
        if traced:
            # per-request stage spans share integer-ns endpoints so the
            # waterfall reconciles *exactly*: queue_wait ends where prefill
            # starts; the insert spans below chain from t_pf1 so
            # queue_wait + prefill + insert == TTFT per request
            for r in reqs:
                record_span("req.queue_wait", r.t_submit_ns,
                            t_pf0 - r.t_submit_ns, rid=r.rid,
                            trace_id=r.trace_id, arrival=r.arrival,
                            admit_tick=r.admit_tick)
                record_span("req.prefill", t_pf0, t_pf1 - t_pf0, rid=r.rid,
                            trace_id=r.trace_id, bucket=blen, batch=bb)
        obs_metrics.counter("sched.prefill_batches").inc()
        ps = self.sc.page_size
        for i, r in enumerate(reqs):
            tok, key = self._first_token(logits[i], r.params)
            slot, pages = self.slots.alloc(self._npg_need(r))
            npg_store = math.ceil(r.prompt.size / ps)
            insert = self._insert_fn(npg_store, blen, bb)
            try:
                self.pool.leaves = self._with_retry(
                    "insert", lambda: insert(
                        self.pool.leaves, body, jnp.int32(i),
                        jnp.asarray(pages[:npg_store])))
            except Exception as e:  # noqa: BLE001 — retries exhausted
                self.slots.release(slot)  # not yet r.slot: reclaim directly
                self._fail(r, f"insert failed: {type(e).__name__}: {e}")
                continue
            r.state = RequestState.RUNNING
            r.slot = slot
            r.length = int(r.prompt.size)
            r.key = key
            r.tokens = [tok]
            r.t_first_ns = time.perf_counter_ns()
            r.t_first = r.t_first_ns * 1e-9
            if traced:
                # spans [t_pf1, t_first] per request: sampling + this (and
                # any earlier sibling's) insert — so each request's own
                # queue_wait/prefill/insert tile [t_submit, t_first]
                # exactly and their ns sum *is* its TTFT
                record_span("req.insert", t_pf1, r.t_first_ns - t_pf1,
                            rid=r.rid, trace_id=r.trace_id, slot=slot,
                            pages=npg_store)
            obs_metrics.histogram("sched.ttft_s").observe(r.t_first - r.t_submit)
            self.active[slot] = r
            if r.params.max_new_tokens == 1:
                self._finish(r)

    # -------------------------------------------------------------- decode

    def _decode_fn(self, sig: tuple):
        if sig in self._decode_jits:
            return self._decode_jits[sig]
        cfg, ps = self.cfg, self.sc.page_size
        v = cfg.vocab_size
        ns = len(sig)
        ks = tuple(s[0] for s in sig)
        offsets = tuple(range(0, (ns + 1) * v, v))

        def f(params, leaves, page_table, lengths, tokens, keys, temps, tps):
            view = gather_view(leaves, page_table, lengths, ps)
            logits, new_cache = decode_step(params, tokens, {"body": view},
                                            cfg, positions=lengths[:, None])
            body = new_cache["body"]
            page_ids = jnp.take_along_axis(
                page_table, (lengths // ps)[:, None], axis=1)[:, 0]
            offs = lengths % ps
            out = {
                name: scatter_col(leaves[name], name,
                                  take_col(body[name], name, lengths),
                                  page_ids, offs)
                for name in leaves
            }
            split = jax.vmap(jax.random.split)(keys)  # (ns, 2, 2)
            new_keys, subs = split[:, 0], split[:, 1]
            # one segmented launch scores every slot's vocab row with its
            # own k; the CSR layout is static (out_offs is a host tuple)
            vals, _, out_offs = segment_topk(logits.reshape(-1), offsets, ks)
            toks = []
            for s, (k_s, greedy, topp) in enumerate(sig):
                row = logits[s]
                if greedy:
                    toks.append(jnp.argmax(row, axis=-1).astype(jnp.int32))
                    continue
                vals_s = vals[out_offs[s]:out_offs[s + 1]][None]  # (1, k_s)
                choice = scored_draw(subs[s], vals_s, temps[s],
                                     tps[s] if topp else None)
                toks.append(canonical_token(row[None], vals_s, choice)[0])
            return out, new_keys, jnp.stack(toks)

        self._decode_jits[sig] = jax.jit(f, donate_argnums=(1,))
        return self._decode_jits[sig]

    def _run_decode(self) -> None:
        slots = sorted(self.active)
        reqs = [self.active[s] for s in slots]
        sig = tuple(r.params.sig for r in reqs)
        pt = jnp.asarray(self.slots.page_table[slots])
        lengths = jnp.asarray(np.asarray([r.length for r in reqs], np.int32))
        tokens = jnp.asarray(
            np.asarray([[r.tokens[-1]] for r in reqs], np.int32))
        keys = jnp.stack([r.key for r in reqs])
        temps = jnp.asarray(
            np.asarray([r.params.temperature for r in reqs], np.float32))
        tps = jnp.asarray(
            np.asarray([r.params.top_p for r in reqs], np.float32))
        compiled = sig not in self._decode_seen
        t_d0 = time.perf_counter_ns()
        with span("sched.decode", kind="run", batch=len(slots)):
            try:
                leaves, new_keys, toks = self._with_retry(
                    "decode", lambda: self._decode_fn(sig)(
                        self.params, self.pool.leaves, pt, lengths, tokens,
                        keys, temps, tps))
            except Exception as e:  # noqa: BLE001 — retries exhausted
                for r in reqs:
                    self._fail(r, f"decode failed: {type(e).__name__}: {e}")
                return
            toks = np.asarray(toks)
        t_d1 = time.perf_counter_ns()
        self._decode_seen.add(sig)
        if obs_enabled():
            # one tick span per request sharing the launch window; the
            # first tick per batch signature pays the jit compile and is
            # tagged so percentile readers can exclude it (DESIGN.md §17)
            for i, r in enumerate(reqs):
                record_span("req.decode", t_d0, t_d1 - t_d0, rid=r.rid,
                            trace_id=r.trace_id, tick=self.t, slot=slots[i],
                            batch=len(slots), compiled=compiled)
        self.pool.leaves = leaves
        obs_metrics.counter("sched.decode_steps").inc()
        obs_metrics.counter("sched.tokens").inc(len(slots))
        for i, r in enumerate(reqs):
            r.key = new_keys[i]
            r.length += 1
            r.tokens.append(int(toks[i]))
            if len(r.tokens) >= r.params.max_new_tokens:
                self._finish(r)

    # ------------------------------------------------------------- cleanup

    def _finish(self, r: Request) -> None:
        r.state = RequestState.DONE
        self._mark_finish(r)
        self.slots.release(r.slot)
        self.active.pop(r.slot, None)
        r.slot = None
        self._record_request(r)
        obs_metrics.counter("sched.completed").inc()
        obs_metrics.histogram("sched.request_latency_s").observe(
            r.t_finish - r.t_submit)
        if len(r.tokens) > 1 and r.t_first:
            obs_metrics.histogram("sched.tpot_s").observe(
                (r.t_finish - r.t_first) / (len(r.tokens) - 1))

    def _gauges(self) -> None:
        obs_metrics.gauge("sched.queue_depth").set(len(self.queue))
        obs_metrics.gauge("sched.slots_occupied").set(len(self.active))
        obs_metrics.gauge("sched.free_pages").set(self.slots.free_page_count)
