"""Architecture registry: the 10 assigned configs + reduced smoke variants.

Sources are the published configs cited in the assignment; every entry is
exact at the listed fields. Smoke variants keep the family topology
(MoE/MLA/SSM/hybrid/encoder) at toy width so one train step runs on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from .base import MLAConfig, MoEConfig, ModelConfig, SSMConfig

# --- full configs ----------------------------------------------------------

DEEPSEEK_V2_LITE = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944,  # dense FFN (first layer); experts use MoEConfig.d_expert
    vocab_size=102_400,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared_experts=2,
                  first_dense_layers=1),
)

QWEN3_MOE_30B = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=6144,  # dense fallback (unused: all layers MoE)
    vocab_size=151_936, qk_norm=True, rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=768, n_shared_experts=0,
                  first_dense_layers=0),
)

MAMBA2_780M = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50_280, tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=128),
)

INTERNVL2_26B = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16_384,
    vocab_size=92_553,
    frontend="patch", frontend_dim=3200, frontend_len=256,
)

QWEN15_32B = ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, d_ff=27_392,
    vocab_size=152_064, qkv_bias=True, rope_theta=1_000_000.0,
)

CHATGLM3_6B = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13_696,
    vocab_size=65_024, rope_fraction=0.5,  # 2D RoPE on half the head dims
)

DEEPSEEK_CODER_33B = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19_200,
    vocab_size=32_256, rope_theta=100_000.0,
)

QWEN3_8B = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=12_288, vocab_size=151_936, qk_norm=True, rope_theta=1_000_000.0,
)

ZAMBA2_2P7B = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10_240,
    vocab_size=32_000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
    attn_every=6,  # one shared attention block invoked every 6 mamba layers
)

HUBERT_XLARGE = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab_size=504, causal=False, mlp_act="gelu",
    frontend="frame", frontend_dim=512, frontend_len=0,  # frames = seq
)

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        DEEPSEEK_V2_LITE, QWEN3_MOE_30B, MAMBA2_780M, INTERNVL2_26B,
        QWEN15_32B, CHATGLM3_6B, DEEPSEEK_CODER_33B, QWEN3_8B,
        ZAMBA2_2P7B, HUBERT_XLARGE,
    )
}


def get_config(name: str) -> ModelConfig:
    return ARCHS[name]


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: tiny widths, few layers, small vocab."""
    full = ARCHS[name]
    kw = dict(
        name=full.name + "-smoke",
        n_layers=min(full.n_layers, 2 if full.attn_every == 0 else 4),
        d_model=64,
        n_heads=4 if full.n_heads else 0,
        n_kv_heads=min(full.n_kv_heads, 2) if full.n_kv_heads else 0,
        d_head=16 if full.n_heads else None,
        d_ff=128 if full.d_ff else 0,
        vocab_size=503 if full.family == "audio" else 256,
        attn_chunk=32,
    )
    if full.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                              qk_rope_head_dim=8, v_head_dim=16)
        kw["d_head"] = None
    if full.moe is not None:
        kw["moe"] = dataclasses.replace(
            full.moe, n_experts=8, top_k=2, d_expert=32, router_block=4,
            n_shared_experts=min(full.moe.n_shared_experts, 1))
    if full.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16)
    if full.attn_every:
        kw["attn_every"] = 2
    if full.frontend != "none":
        kw["frontend_dim"] = 32
        kw["frontend_len"] = 8 if full.frontend == "patch" else 0
    return dataclasses.replace(full, **kw)
