"""--arch mamba2_780m config (see registry.py for the exact fields)."""
from .registry import MAMBA2_780M as CONFIG  # noqa: F401
from .registry import get_smoke_config


def smoke_config():
    return get_smoke_config(CONFIG.name)
