"""--arch internvl2_26b config (see registry.py for the exact fields)."""
from .registry import INTERNVL2_26B as CONFIG  # noqa: F401
from .registry import get_smoke_config


def smoke_config():
    return get_smoke_config(CONFIG.name)
