"""Config system: one frozen dataclass tree per architecture.

Every assigned architecture gets a module in this package exporting
``CONFIG`` (the exact published shape) and ``smoke_config()`` (a reduced
same-family config for CPU tests). ``repro.configs.registry`` maps
``--arch`` ids to them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 64
    top_k: int = 6
    d_expert: int = 1408  # per-expert FFN hidden
    n_shared_experts: int = 0
    router_block: int = 32  # LOMS router top-k block size
    capacity_factor: float = 1.25
    dispatch: str = "scatter"  # scatter | sorted | einsum
    #: static per-expert capacities (len == n_experts). None = uniform
    #: capacity from capacity_factor. Ragged capacities switch the
    #: dispatch buffer to a CSR layout — experts get exactly their slots
    #: instead of padding every buffer to the max — and the expert FFN
    #: runs one batched einsum per capacity class (repro.segmented's
    #: size-class idea applied to expert compute). Non-EP paths only.
    expert_capacities: Optional[Tuple[int, ...]] = None
    moe_every: int = 1  # apply MoE FFN every Nth layer (1 = all)
    first_dense_layers: int = 1  # deepseek: first layer(s) dense


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None  # default d_model // n_heads
    # attention options
    causal: bool = True
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0  # chatglm3: rope on half the head dims
    attn_chunk: int = 1024  # kv-chunked (flash-style) attention block
    # sub-configs
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): shared attention block every N ssm layers
    attn_every: int = 0
    # modality frontend stub: none | patch (vlm) | frame (audio)
    frontend: str = "none"
    frontend_dim: int = 0
    frontend_len: int = 0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    mlp_act: str = "swiglu"  # swiglu | gelu
    dtype: str = "bfloat16"
    # serving: KV-cache dtype override (e.g. float8_e4m3fn halves the cache
    # for MHA archs whose 32k cache exceeds HBM at bf16)
    cache_dtype: "Optional[str]" = None
    # analysis only: python-unroll layer/chunk loops so XLA cost_analysis
    # (which counts while bodies once) sees every layer. Never set for
    # production configs — it blows up HLO size with depth.
    unroll_layers: bool = False

    @property
    def head_dim(self) -> int:
        if self.d_head is not None:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-token long-context shape?"""
        return self.family in ("ssm", "hybrid")

    def params_billions(self) -> float:
        """Rough total parameter count (for 6ND roofline bookkeeping)."""
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0.0
        hd = self.head_dim
        if self.family in ("ssm",):
            pass
        else:
            if self.mla is not None:
                m = self.mla
                per_layer += d * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                per_layer += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                per_layer += self.n_heads * m.v_head_dim * d
            else:
                per_layer += d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * d
        ff_mult = 3 if self.mlp_act == "swiglu" else 2
        if self.moe is not None:
            dense_ff = ff_mult * d * self.d_ff if self.d_ff else 0
            moe_ff = ff_mult * d * self.moe.d_expert * (
                self.moe.n_experts + self.moe.n_shared_experts
            )
            per_layer += moe_ff  # MoE layers dominate; dense first layer ignored
            _ = dense_ff
        elif self.d_ff:
            per_layer += ff_mult * d * self.d_ff
        if self.family in ("ssm", "hybrid"):
            s = self.ssm
            d_in = s.expand * d
            conv_dim = d_in + 2 * s.d_state
            per_layer_ssm = d * (2 * d_in + 2 * s.d_state + d_in // s.head_dim)
            per_layer_ssm += conv_dim * s.d_conv + d_in * d
            if self.family == "ssm":
                per_layer = per_layer_ssm
            else:
                per_layer += per_layer_ssm * 0  # hybrid: handled below
        total = emb + self.n_layers * per_layer
        if self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            conv_dim = d_in + 2 * s.d_state
            ssm_layer = d * (2 * d_in + 2 * s.d_state + d_in // s.head_dim)
            ssm_layer += conv_dim * s.d_conv + d_in * d
            shared_attn = d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * d
            shared_attn += ff_mult * d * self.d_ff
            total = emb + self.n_layers * ssm_layer + shared_attn
        return total / 1e9

    def active_params_billions(self) -> float:
        """Active (per-token) params: MoE counts only routed top-k experts."""
        if self.moe is None:
            return self.params_billions()
        d = self.d_model
        ff_mult = 3 if self.mlp_act == "swiglu" else 2
        full = self.params_billions()
        all_experts = ff_mult * d * self.moe.d_expert * self.moe.n_experts * self.n_layers / 1e9
        active = ff_mult * d * self.moe.d_expert * (
            self.moe.top_k + self.moe.n_shared_experts
        ) * self.n_layers / 1e9
        return full - all_experts + active - (
            ff_mult * d * self.moe.d_expert * self.moe.n_shared_experts * self.n_layers / 1e9
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
