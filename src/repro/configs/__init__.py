"""repro.configs — assigned architectures as selectable configs."""
from .registry import ARCHS, get_config, get_smoke_config  # noqa: F401
from .base import SHAPES, ModelConfig, ShapeConfig, get_shape  # noqa: F401
