"""--arch qwen3_8b config (see registry.py for the exact fields)."""
from .registry import QWEN3_8B as CONFIG  # noqa: F401
from .registry import get_smoke_config


def smoke_config():
    return get_smoke_config(CONFIG.name)
