"""--arch zamba2_2p7b config (see registry.py for the exact fields)."""
from .registry import ZAMBA2_2P7B as CONFIG  # noqa: F401
from .registry import get_smoke_config


def smoke_config():
    return get_smoke_config(CONFIG.name)
