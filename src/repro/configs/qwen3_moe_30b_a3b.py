"""--arch qwen3_moe_30b config (see registry.py for the exact fields)."""
from .registry import QWEN3_MOE_30B as CONFIG  # noqa: F401
from .registry import get_smoke_config


def smoke_config():
    return get_smoke_config(CONFIG.name)
