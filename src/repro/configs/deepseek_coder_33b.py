"""--arch deepseek_coder_33b config (see registry.py for the exact fields)."""
from .registry import DEEPSEEK_CODER_33B as CONFIG  # noqa: F401
from .registry import get_smoke_config


def smoke_config():
    return get_smoke_config(CONFIG.name)
