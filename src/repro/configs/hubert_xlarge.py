"""--arch hubert_xlarge config (see registry.py for the exact fields)."""
from .registry import HUBERT_XLARGE as CONFIG  # noqa: F401
from .registry import get_smoke_config


def smoke_config():
    return get_smoke_config(CONFIG.name)
