"""--arch deepseek_v2_lite config (see registry.py for the exact fields)."""
from .registry import DEEPSEEK_V2_LITE as CONFIG  # noqa: F401
from .registry import get_smoke_config


def smoke_config():
    return get_smoke_config(CONFIG.name)
