"""Deterministic sharded token pipeline (synthetic + memmapped bin files).

Resume contract: the pipeline is a pure function of (seed, step), so a
restarted job at step N sees exactly the batches it would have seen — no
iterator state beyond the step counter needs checkpointing. Each host
materializes only its slice (``host_count``/``host_index``), matching the
multi-host data-loading pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class DataConfig:
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 0
    bin_path: Optional[str] = None  # memmapped uint16/uint32 token file
    host_index: int = 0
    host_count: int = 1


class TokenPipeline:
    """get_batch(step) -> {'tokens','targets'} host-local numpy arrays."""

    def __init__(self, cfg: ModelConfig, dc: DataConfig):
        self.cfg = cfg
        self.dc = dc
        assert dc.global_batch % dc.host_count == 0
        self.local_batch = dc.global_batch // dc.host_count
        self._mm = None
        if dc.bin_path:
            self._mm = np.memmap(dc.bin_path, dtype=np.uint32, mode="r")

    def _tokens(self, step: int) -> np.ndarray:
        b, s = self.local_batch, self.dc.seq_len
        if self._mm is not None:
            n_tok = self._mm.shape[0]
            rng = np.random.default_rng((self.dc.seed, step))
            starts = rng.integers(0, n_tok - s - 1, size=(self.dc.global_batch,))
            starts = starts[self.dc.host_index * b : (self.dc.host_index + 1) * b]
            # read the memmap in offset order (sequential-ish I/O instead of
            # b random seeks) and scatter rows back to their batch slots, so
            # the emitted batch is bit-identical to the unsorted read
            order = np.argsort(starts)
            rows = np.stack([self._mm[st : st + s + 1] for st in starts[order]])
            out = np.empty_like(rows)
            out[order] = rows
            return out.astype(np.int32) % self.cfg.vocab_size
        rng = np.random.default_rng(
            (self.dc.seed, step, self.dc.host_index))
        # synthetic: markovian-ish stream so the loss actually decreases
        base = rng.integers(0, self.cfg.vocab_size, size=(b, s + 1), dtype=np.int64)
        drift = np.cumsum(rng.integers(0, 3, size=(b, s + 1)), axis=1)
        return ((base // 7 + drift) % self.cfg.vocab_size).astype(np.int32)

    def get_batch(self, step: int) -> Dict[str, np.ndarray]:
        toks = self._tokens(step)
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if self.cfg.family == "vlm":
            rng = np.random.default_rng((self.dc.seed, step, 99))
            batch["patches"] = rng.standard_normal(
                (self.local_batch, self.cfg.frontend_len, self.cfg.frontend_dim)
            ).astype(np.float32)
        if self.cfg.family == "audio":
            rng = np.random.default_rng((self.dc.seed, step, 98))
            frames = rng.standard_normal(
                (self.local_batch, self.dc.seq_len, self.cfg.frontend_dim)
            ).astype(np.float32)
            batch = {"frames": frames, "targets": batch["targets"]}
        return batch

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.get_batch(step)
            step += 1
