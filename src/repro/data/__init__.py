from .pipeline import DataConfig, TokenPipeline  # noqa: F401
