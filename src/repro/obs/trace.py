"""Lightweight span tracer for the whole stack (DESIGN.md §13).

One process-global span buffer, fed by a context manager / decorator that
is a **strict no-op when observability is off** — ``span(...)`` returns a
shared null context object, no state is touched, no jax context entered —
so instrumented hot paths (``plan()``, kernel wrappers, the serve loop)
pay nanoseconds, never allocations.

Two span kinds, matching the two clocks of a JAX program:

* ``kind="trace"`` — planning/lowering work that runs while Python traces
  a jit function (backend planning, bucketing, kernel wrapping). Enters
  ``jax.named_scope`` so the emitted XLA ops carry the span name in
  profiles; adds **zero** jaxpr equations, so enabled/disabled traces are
  op-for-op identical.
* ``kind="run"`` — host-timed execution regions whose caller has made the
  duration meaningful (``block_until_ready`` before exit, e.g. prefill /
  decode / train-step). Enters ``jax.profiler.TraceAnnotation`` so the
  region shows up on the host track of an XLA/perfetto profile.

Enablement: ``REPRO_OBS`` env var (any value but ``""``/``"0"``) or
:func:`set_enabled` (tests / embedding apps). Spans nest through a
thread-local stack; each records its parent id, so the exporter can
rebuild the tree.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax

_ENV = "REPRO_OBS"

#: programmatic override: None = follow the env var, True/False = forced
_forced: Optional[bool] = None

#: span buffer cap — beyond it spans are counted as dropped, not stored
MAX_SPANS = 100_000


def enabled() -> bool:
    """Whether observability is on (``REPRO_OBS`` or a forced override)."""
    if _forced is not None:
        return _forced
    return os.environ.get(_ENV, "") not in ("", "0")


def set_enabled(on: Optional[bool]) -> Optional[bool]:
    """Force obs on/off from code (``None`` = follow ``REPRO_OBS``).

    Returns the previous override so callers can restore it."""
    global _forced
    prev = _forced
    _forced = None if on is None else bool(on)
    return prev


@dataclasses.dataclass
class Span:
    """One recorded region. Times are ``time.perf_counter_ns`` host time."""

    name: str
    kind: str  # 'trace' (planning/lowering) | 'run' (host-timed execution)
    t0_ns: int
    dur_ns: int
    span_id: int
    parent_id: Optional[int]
    thread: int
    attrs: Dict[str, Any]

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "kind": self.kind,
            "ts_us": self.t0_ns / 1e3,
            "dur_us": self.dur_ns / 1e3,
            # integer-ns twins: stage spans reconcile *exactly* against
            # request latency in ns; the µs floats are display-only
            "ts_ns": self.t0_ns,
            "dur_ns": self.dur_ns,
            "id": self.span_id,
            "parent": self.parent_id,
            "thread": self.thread,
            "attrs": self.attrs,
        }


_lock = threading.Lock()
_spans: List[Span] = []
_dropped = 0
_ids = itertools.count(1)
_tls = threading.local()


def _store(sp: Span) -> None:
    """Append one completed span (respecting the cap) and feed the
    flight recorder's span-close event stream."""
    global _dropped
    with _lock:
        if len(_spans) < MAX_SPANS:
            _spans.append(sp)
        else:
            _dropped += 1
    from . import recorder as _recorder

    _recorder.emit("span", sp.name, dur_us=sp.dur_ns / 1e3,
                   span_kind=sp.kind, **sp.attrs)


class _NullSpan:
    """The disabled-path context: shared, stateless, allocation-free."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _LiveSpan:
    __slots__ = ("name", "kind", "attrs", "span_id", "parent_id",
                 "_t0", "_jax_ctx")

    def __init__(self, name: str, kind: str, attrs: Dict[str, Any]):
        self.name = name
        self.kind = kind
        self.attrs = attrs
        self.span_id = next(_ids)
        self.parent_id: Optional[int] = None
        self._t0 = 0
        self._jax_ctx = None

    def __enter__(self) -> "_LiveSpan":
        stack = getattr(_tls, "stack", None)
        if stack is None:
            stack = _tls.stack = []
        self.parent_id = stack[-1].span_id if stack else None
        stack.append(self)
        if self.kind == "trace":
            # names the ops emitted while this span is open; adds no eqns
            self._jax_ctx = jax.named_scope(self.name)
        else:
            # host-track annotation in XLA / perfetto profiles
            self._jax_ctx = jax.profiler.TraceAnnotation(self.name)
        self._jax_ctx.__enter__()
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter_ns() - self._t0
        self._jax_ctx.__exit__(*exc)
        stack = getattr(_tls, "stack", [])
        if stack and stack[-1] is self:
            stack.pop()
        _store(Span(name=self.name, kind=self.kind, t0_ns=self._t0,
                    dur_ns=dur, span_id=self.span_id,
                    parent_id=self.parent_id,
                    thread=threading.get_ident(), attrs=self.attrs))
        return False


def span(name: str, kind: str = "run", **attrs):
    """Context manager recording one region; no-op context when disabled.

    ``kind="trace"`` for planning/lowering spans (named_scope),
    ``kind="run"`` for host-timed execution (TraceAnnotation); ``attrs``
    are JSON-scalar annotations carried into the export."""
    if not enabled():
        return _NULL
    assert kind in ("trace", "run"), kind
    return _LiveSpan(name, kind, attrs)


def traced(name: Optional[str] = None, kind: str = "trace"):
    """Decorator form of :func:`span`; defaults to the function name."""

    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not enabled():
                return fn(*args, **kwargs)
            with _LiveSpan(label, kind, {}):
                return fn(*args, **kwargs)

        return wrapper

    return deco


def record_span(name: str, t0_ns: int, dur_ns: int, *, kind: str = "run",
                parent_id: Optional[int] = None,
                **attrs) -> Optional[int]:
    """Record a completed span with explicit host timestamps.

    The context-manager form times a code region; this form records a
    *derived* region — e.g. a request's queue-wait, which spans two call
    sites (``submit`` → admission) and belongs to no single ``with``
    block. ``t0_ns``/``dur_ns`` are ``time.perf_counter_ns`` values so
    explicit and context-managed spans share one clock. Returns the span
    id (``None`` when disabled: a strict no-op, nothing allocated)."""
    if not enabled():
        return None
    assert kind in ("trace", "run"), kind
    sid = next(_ids)
    _store(Span(name=name, kind=kind, t0_ns=int(t0_ns),
                dur_ns=max(int(dur_ns), 0), span_id=sid,
                parent_id=parent_id, thread=threading.get_ident(),
                attrs=attrs))
    return sid


def spans() -> Tuple[Span, ...]:
    """Snapshot of every recorded span (completion order)."""
    with _lock:
        return tuple(_spans)


def span_count() -> int:
    """How many spans the buffer currently holds (cap: MAX_SPANS)."""
    with _lock:
        return len(_spans)


def dropped() -> int:
    with _lock:
        return _dropped


def clear() -> None:
    """Drop all recorded spans (tests / between export epochs)."""
    global _dropped
    with _lock:
        _spans.clear()
        _dropped = 0
