"""Flight recorder: a bounded ring buffer of structured events for
post-mortems (DESIGN.md §17).

Spans answer "where did the time go"; metrics answer "how much". The
recorder answers the post-mortem question — *what happened, in order,
just before things went wrong* — without unbounded memory. One
process-global, thread-safe ring of the last ``capacity`` events:

* span closes (fed by :mod:`repro.obs.trace`),
* circuit-breaker transitions (``breaker``),
* degradation-ladder fallbacks / forced runs (``fallback`` / ``forced``),
* failpoint fires (``failpoint``),
* autotune tournament picks (``tournament``),
* autotune-cache quarantines (``quarantine``),
* scheduler lifecycle marks (``sched``).

Every producer calls :func:`emit`, which is a strict no-op when
``REPRO_OBS`` is off (one predicate call, no allocation) — the same
contract as spans and metrics, re-gated by ``tests/test_obs.py``.

Dumps happen three ways:

* on demand — :func:`dump` returns ``{meta, events}``; with a path it
  writes one JSON object per line (JSONL);
* on unhandled engine exception — :func:`crash_dump` (called by the
  serving scheduler's ``run()``) writes to ``REPRO_OBS_DUMP`` if set,
  else prints a bounded tail to stderr, then the exception propagates;
* on ``SIGUSR1`` — :func:`install_signal_dump` registers a handler so a
  wedged process can be asked for its recent history from outside.

Exporters: :func:`chrome_trace_events` maps events onto the existing
chrome-trace schema as instant (``ph: "i"``) events — mergeable with the
span export and checked by the same ``validate_chrome_trace`` — and
:func:`write_jsonl` is the event log sink. The Prometheus text-format
exporter for the metric registry lives in :func:`repro.obs.export.write_prom`.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os
import threading
import time
from typing import Any, Deque, Dict, List, Optional

from .trace import enabled

_DUMP_ENV = "REPRO_OBS_DUMP"

#: default ring capacity — events beyond it overwrite the oldest
DEFAULT_CAPACITY = 4096


@dataclasses.dataclass
class Event:
    """One recorded occurrence. ``seq`` is a monotonically increasing
    id that survives ring wraparound, so consumers can tell how much
    history was overwritten (``first seq > 1`` ⇒ older events lost)."""

    seq: int
    t_ns: int
    kind: str
    name: str
    attrs: Dict[str, Any]

    def to_dict(self) -> dict:
        return {"seq": self.seq, "ts_us": self.t_ns / 1e3,
                "kind": self.kind, "name": self.name, "attrs": self.attrs}


_lock = threading.Lock()
_ring: Deque[Event] = collections.deque(maxlen=DEFAULT_CAPACITY)
_seq = 0


def emit(kind: str, name: str, **attrs) -> None:
    """Record one event; strict no-op when observability is off."""
    if not enabled():
        return
    global _seq
    t = time.perf_counter_ns()
    with _lock:
        _seq += 1
        _ring.append(Event(seq=_seq, t_ns=t, kind=kind, name=name,
                           attrs=attrs))


def events() -> List[Event]:
    """Snapshot of the ring, oldest first."""
    with _lock:
        return list(_ring)


def total_events() -> int:
    """How many events were ever emitted (≥ ``len(events())``)."""
    with _lock:
        return _seq


def overwritten() -> int:
    """How many events the ring has discarded to stay bounded."""
    with _lock:
        return _seq - len(_ring)


def capacity() -> int:
    return _ring.maxlen or 0


def set_capacity(n: int) -> None:
    """Resize the ring (keeps the newest events that still fit)."""
    global _ring
    assert n >= 1, n
    with _lock:
        _ring = collections.deque(_ring, maxlen=int(n))


def clear() -> None:
    """Drop every recorded event and reset the sequence (tests)."""
    global _seq
    with _lock:
        _ring.clear()
        _seq = 0


# ---------------------------------------------------------------- dumps


def _dump_meta(reason: str) -> dict:
    with _lock:
        n, total = len(_ring), _seq
    return {
        "schema": 1,
        "reason": reason,
        "generated_unix": int(time.time()),
        "pid": os.getpid(),
        "events": n,
        "total_events": total,
        "overwritten": total - n,
        "capacity": capacity(),
    }


def dump(path: Optional[str] = None, reason: str = "on_demand") -> dict:
    """The ring as ``{meta, events}``; with ``path``, also written as
    JSONL (one ``{"type": "meta"|"event"}`` object per line)."""
    snap = {"meta": _dump_meta(reason),
            "events": [ev.to_dict() for ev in events()]}
    if path:
        write_jsonl(path, snap)
    return snap


def write_jsonl(path: str, snap: Optional[dict] = None) -> str:
    snap = snap if snap is not None else dump()
    with open(path, "w") as f:
        f.write(json.dumps({"type": "meta", **snap["meta"]}) + "\n")
        for ev in snap["events"]:
            f.write(json.dumps({"type": "event", **ev}, default=str) + "\n")
    return path


def crash_dump(context: str, error: BaseException) -> Optional[str]:
    """Best-effort dump for an unhandled exception: to the
    ``REPRO_OBS_DUMP`` path when set, else a bounded tail to stderr.
    Never raises (the original exception is the story); returns the
    path written, if any."""
    if not enabled():
        return None
    reason = f"crash:{context}:{type(error).__name__}"
    try:
        path = os.environ.get(_DUMP_ENV)
        if path:
            dump(path, reason=reason)
            return path
        import sys

        tail = [ev.to_dict() for ev in events()[-50:]]
        print(f"[repro.obs.recorder] {reason}: last {len(tail)} events:",
              file=sys.stderr)
        for ev in tail:
            print(f"  {json.dumps(ev, default=str)}", file=sys.stderr)
    except Exception:  # noqa: BLE001 — never mask the original error
        pass
    return None


_prev_handler = None
_signal_installed = False


def install_signal_dump(path: Optional[str] = None) -> bool:
    """Register a ``SIGUSR1`` handler that dumps the ring (idempotent;
    main thread only — returns False where signals are unavailable).
    ``path`` defaults to ``REPRO_OBS_DUMP`` or
    ``flight_recorder.<pid>.jsonl`` in the cwd."""
    global _prev_handler, _signal_installed
    if _signal_installed:
        return True
    import signal

    target = path or os.environ.get(_DUMP_ENV)

    def _handler(signum, frame):  # pragma: no cover - exercised via kill
        dump(target or f"flight_recorder.{os.getpid()}.jsonl",
             reason="SIGUSR1")

    try:
        _prev_handler = signal.signal(signal.SIGUSR1, _handler)
    except (ValueError, AttributeError, OSError):
        return False  # non-main thread / platform without SIGUSR1
    _signal_installed = True
    return True


def uninstall_signal_dump() -> None:
    """Restore the previous ``SIGUSR1`` handler (tests)."""
    global _prev_handler, _signal_installed
    if not _signal_installed:
        return
    import signal

    try:
        signal.signal(signal.SIGUSR1, _prev_handler or signal.SIG_DFL)
    except (ValueError, OSError):  # pragma: no cover
        pass
    _prev_handler = None
    _signal_installed = False


# ------------------------------------------------------------ exporters


def chrome_trace_events(snap: Optional[dict] = None) -> List[dict]:
    """Recorder events as chrome-trace instant events (``ph: "i"``),
    mergeable into the span export's ``traceEvents`` and valid under
    :func:`repro.obs.export.validate_chrome_trace`."""
    snap = snap if snap is not None else dump()
    pid = snap["meta"]["pid"]
    out = []
    for ev in snap["events"]:
        out.append({
            "name": f"{ev['kind']}:{ev['name']}",
            "cat": ev["kind"],
            "ph": "i",
            "s": "p",
            "ts": ev["ts_us"],
            "pid": pid,
            "tid": 0,
            "args": dict(ev["attrs"], seq=ev["seq"]),
        })
    return out
