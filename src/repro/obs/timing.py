"""The one timing helper for jitted callables (DESIGN.md §13).

Before this module, the warmup + ``block_until_ready`` + percentile
pattern existed in four divergent copies (benchmarks/common.py, the
planner's ``_time_call``, the serve loop, the train loop) with
inconsistent sync semantics. Everything now routes through:

* :func:`time_jitted` — warm up, then measure ``iters`` synchronized
  calls and report p50/p95/p99 (plus mean/min/max and the raw samples).
  This is what benchmarks and the autotuner use, and what the planner's
  measured cost model will consume.
* :func:`time_once` — one synchronized call, for code that times real
  work as it happens (train steps, prefill) rather than re-running it.

Both block on the *returned* pytree, so the measured interval covers
device execution, not just dispatch. When observability is on, each
measured region also emits a ``run`` span so timings land in the export.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import numpy as np

from . import metrics
from .trace import span


@dataclasses.dataclass(frozen=True)
class TimingStats:
    """Percentile summary of one measured callable (microseconds)."""

    n: int
    mean_us: float
    p50_us: float
    p95_us: float
    p99_us: float
    min_us: float
    max_us: float
    samples_us: Tuple[float, ...] = ()

    @property
    def p50_s(self) -> float:
        return self.p50_us * 1e-6

    def to_row(self, prefix: str = "") -> dict:
        """The BENCH_*.json row fragment: p50/p95/p99 stamped columns."""
        return {
            f"{prefix}p50_us": round(self.p50_us, 1),
            f"{prefix}p95_us": round(self.p95_us, 1),
            f"{prefix}p99_us": round(self.p99_us, 1),
        }

    @classmethod
    def from_samples(cls, samples_s: Sequence[float]) -> "TimingStats":
        us = np.asarray(samples_s, np.float64) * 1e6
        assert us.size, "at least one sample required"
        return cls(
            n=int(us.size),
            mean_us=float(us.mean()),
            p50_us=float(np.percentile(us, 50)),
            p95_us=float(np.percentile(us, 95)),
            p99_us=float(np.percentile(us, 99)),
            min_us=float(us.min()),
            max_us=float(us.max()),
            samples_us=tuple(float(x) for x in us),
        )


def time_jitted(
    fn: Callable,
    *args,
    warmup: int = 2,
    iters: int = 10,
    name: Optional[str] = None,
    **kwargs,
) -> TimingStats:
    """Measure a jitted callable: warm up (compile), then ``iters``
    host-timed synchronized calls. Returns percentile stats in µs.

    ``name`` (optional) tags the emitted span and feeds the
    ``timing.<name>`` histogram so repeated measurements accumulate."""
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args, **kwargs))
    samples = []
    with span(f"timing.{name}" if name else "timing", kind="run",
              iters=iters):
        for _ in range(max(iters, 1)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args, **kwargs))
            samples.append(time.perf_counter() - t0)
    stats = TimingStats.from_samples(samples)
    if name:
        metrics.histogram(f"timing.{name}").observe(stats.p50_us)
    return stats


def time_once(fn: Callable, *args, **kwargs) -> Tuple[Any, float]:
    """One synchronized call: returns ``(result, seconds)``.

    Blocks on every leaf of the result, so the duration covers device
    execution — the sync rule the train/serve loops previously each
    implemented their own way."""
    t0 = time.perf_counter()
    result = fn(*args, **kwargs)
    jax.block_until_ready(result)
    return result, time.perf_counter() - t0
