"""repro.obs — observability: spans, metrics, timing, recorder, export.

The measured-telemetry layer of the stack (DESIGN.md §13, §17): the
paper characterizes every sorter by measured speed and resource cost;
this package gives the TPU reproduction the same footing. Span tracing
(``trace``, including explicit-time ``record_span`` for per-request
timelines), a process-global metric registry (``metrics``), the one
shared timing helper (``timing``), a bounded flight recorder of
structured events for post-mortems (``recorder``), and JSONL /
Chrome-trace / Prometheus-text export (``export``). Everything is a
strict no-op unless ``REPRO_OBS`` is set (or :func:`set_enabled` forces
it on).

    import repro.obs as obs
    obs.set_enabled(True)
    with obs.span("my.region", kind="run"):
        jax.block_until_ready(fn(x))
    obs.snapshot()                      # {meta, spans, metrics, events}
    obs.write_chrome_trace("out.trace.json")   # perfetto-loadable
    obs.write_prom("metrics.prom")             # Prometheus text format
    obs.recorder.dump("flight.jsonl")          # ring-buffer post-mortem
"""
from . import export, metrics, recorder, timing, trace  # noqa: F401
from .export import (  # noqa: F401
    chrome_trace,
    prom_text,
    request_chrome_trace,
    request_waterfalls,
    snapshot,
    validate_chrome_trace,
    write_chrome_trace,
    write_jsonl,
    write_prom,
)
from .metrics import counter, gauge, histogram  # noqa: F401
from .timing import TimingStats, time_jitted, time_once  # noqa: F401
from .trace import enabled, record_span, set_enabled, span, traced  # noqa: F401

__all__ = [
    "TimingStats",
    "chrome_trace",
    "counter",
    "enabled",
    "export",
    "gauge",
    "histogram",
    "metrics",
    "prom_text",
    "record_span",
    "recorder",
    "request_chrome_trace",
    "request_waterfalls",
    "set_enabled",
    "snapshot",
    "span",
    "time_jitted",
    "time_once",
    "timing",
    "trace",
    "traced",
    "validate_chrome_trace",
    "write_chrome_trace",
    "write_jsonl",
    "write_prom",
]
