"""repro.obs — observability: spans, metrics, timing, export.

The measured-telemetry layer of the stack (DESIGN.md §13): the paper
characterizes every sorter by measured speed and resource cost; this
package gives the TPU reproduction the same footing. Span tracing
(``trace``), a process-global metric registry (``metrics``), the one
shared timing helper (``timing``), and JSONL / Chrome-trace export
(``export``). Everything is a strict no-op unless ``REPRO_OBS`` is set
(or :func:`set_enabled` forces it on).

    import repro.obs as obs
    obs.set_enabled(True)
    with obs.span("my.region", kind="run"):
        jax.block_until_ready(fn(x))
    obs.snapshot()                      # {meta, spans, metrics}
    obs.write_chrome_trace("out.trace.json")   # perfetto-loadable
"""
from . import export, metrics, timing, trace  # noqa: F401
from .export import (  # noqa: F401
    chrome_trace,
    snapshot,
    validate_chrome_trace,
    write_chrome_trace,
    write_jsonl,
)
from .metrics import counter, gauge, histogram  # noqa: F401
from .timing import TimingStats, time_jitted, time_once  # noqa: F401
from .trace import enabled, set_enabled, span, traced  # noqa: F401

__all__ = [
    "TimingStats",
    "chrome_trace",
    "counter",
    "enabled",
    "export",
    "gauge",
    "histogram",
    "metrics",
    "set_enabled",
    "snapshot",
    "span",
    "time_jitted",
    "time_once",
    "timing",
    "trace",
    "traced",
    "validate_chrome_trace",
    "write_chrome_trace",
    "write_jsonl",
]
