"""Export sinks for the observability layer (DESIGN.md §13).

Three consumers, three shapes:

* :func:`snapshot` — one JSON-ready dict ``{meta, spans, metrics}``, the
  programmatic API (tests, the serve benchmark, future planner cost
  models read this).
* :func:`write_jsonl` — line-oriented sink (one ``{"type": ...}`` object
  per line: ``meta``, then every span, then every metric) for log
  shippers and offline analysis.
* :func:`chrome_trace` / :func:`write_chrome_trace` — Chrome trace-event
  JSON (``traceEvents`` with ``ph: "X"`` complete events), loadable in
  ``chrome://tracing`` and perfetto. Span kinds become categories, so
  trace-time (planning/lowering) and run-time spans are separately
  filterable.

``REPRO_OBS_EXPORT=<path>`` auto-writes at interpreter exit when obs is
on: ``*.jsonl`` selects the JSONL sink, anything else the Chrome trace.

:func:`validate_chrome_trace` is the schema check CI gates the exported
trace against — it returns a list of violations (empty = valid) instead
of raising, so callers can aggregate.
"""
from __future__ import annotations

import atexit
import json
import os
import time
from typing import List, Optional

from . import metrics as _metrics
from . import recorder as _recorder
from . import trace as _trace

_EXPORT_ENV = "REPRO_OBS_EXPORT"


def _meta() -> dict:
    import jax

    return {
        "schema": 1,
        "generated_unix": int(time.time()),
        "pid": os.getpid(),
        "platform": jax.default_backend(),
        # buffer health: dropped > 0 or recorded == cap means the span
        # buffer saturated and percentile/waterfall views are truncated
        "dropped_spans": _trace.dropped(),
        "spans_recorded": _trace.span_count(),
        "span_cap": _trace.MAX_SPANS,
        "events_overwritten": _recorder.overwritten(),
    }


def snapshot() -> dict:
    """Everything recorded so far: ``{meta, spans, metrics, events}``."""
    return {
        "meta": _meta(),
        "spans": [sp.to_dict() for sp in _trace.spans()],
        "metrics": _metrics.snapshot(),
        "events": [ev.to_dict() for ev in _recorder.events()],
    }


def write_jsonl(path: str, snap: Optional[dict] = None) -> str:
    """One JSON object per line: meta, spans, metrics, recorder events."""
    snap = snap if snap is not None else snapshot()
    with open(path, "w") as f:
        f.write(json.dumps({"type": "meta", **snap["meta"]}) + "\n")
        for sp in snap["spans"]:
            f.write(json.dumps({"type": "span", **sp}, default=str) + "\n")
        for m in snap["metrics"].values():
            f.write(json.dumps({"type": "metric", **m}) + "\n")
        for ev in snap.get("events", ()):
            f.write(json.dumps({"type": "event", **ev}, default=str) + "\n")
    return path


def chrome_trace(snap: Optional[dict] = None) -> dict:
    """Chrome trace-event JSON (perfetto-loadable) from recorded spans.

    Spans map to ``ph: "X"`` complete events (ts/dur in µs on the
    host-monotonic clock); metric totals ride along as one ``ph: "C"``
    counter sample each so headline counts are visible on the timeline."""
    snap = snap if snap is not None else snapshot()
    pid = snap["meta"]["pid"]
    events: List[dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": f"repro.obs ({snap['meta']['platform']})"},
    }]
    t0 = min((sp["ts_us"] for sp in snap["spans"]), default=0.0)
    for sp in snap["spans"]:
        events.append({
            "name": sp["name"],
            "cat": sp["kind"],
            "ph": "X",
            "ts": sp["ts_us"] - t0,
            "dur": sp["dur_us"],
            "pid": pid,
            "tid": sp["thread"] % (1 << 31),
            "args": dict(sp["attrs"], span_id=sp["id"],
                         parent=sp["parent"]),
        })
    for ev in snap.get("events", ()):
        # flight-recorder events ride along as instant marks on the same
        # normalized clock, filterable by their kind category
        events.append({
            "name": f"{ev['kind']}:{ev['name']}",
            "cat": ev["kind"],
            "ph": "i",
            "s": "p",
            "ts": max(ev["ts_us"] - t0, 0.0),
            "pid": pid,
            "tid": 0,
            "args": dict(ev["attrs"], seq=ev["seq"]),
        })
    for name, m in snap["metrics"].items():
        if m["kind"] != "counter":
            continue
        total = sum(s["value"] for s in m["series"])
        events.append({
            "name": name, "ph": "C", "ts": 0.0, "pid": pid, "tid": 0,
            "args": {"total": total},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, snap: Optional[dict] = None) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(snap), f)
        f.write("\n")
    return path


def validate_chrome_trace(obj: dict) -> List[str]:
    """Schema check for the exported trace; returns violations (empty =
    valid). Covers the invariants chrome://tracing / perfetto require:
    a ``traceEvents`` list whose events carry name/ph/pid/tid, complete
    (``X``) events with numeric non-negative ts/dur."""
    errs: List[str] = []
    if not isinstance(obj, dict):
        return ["trace is not a JSON object"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        for field in ("name", "ph", "pid", "tid"):
            if field not in ev:
                errs.append(f"{where}: missing {field!r}")
        ph = ev.get("ph")
        if ph not in ("X", "M", "C", "B", "E", "i"):
            errs.append(f"{where}: unknown phase {ph!r}")
        if ph == "X":
            for field in ("ts", "dur"):
                v = ev.get(field)
                if not isinstance(v, (int, float)) or v < 0:
                    errs.append(f"{where}: {field} not a non-negative number")
            if not isinstance(ev.get("cat", ""), str):
                errs.append(f"{where}: cat not a string")
    return errs


# --------------------------------------------------- prometheus export


def _prom_name(name: str, suffix: str = "") -> str:
    """Metric-name mapping (DESIGN.md §17): ``repro_`` prefix, dots and
    other non-alphanumerics to underscores — ``sched.ttft_s`` becomes
    ``repro_sched_ttft_s``."""
    safe = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return f"repro_{safe}{suffix}"


def _prom_labels(labels: dict) -> str:
    if not labels:
        return ""
    esc = {k: str(v).replace("\\", "\\\\").replace('"', '\\"')
           for k, v in labels.items()}
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(esc.items()))
    return "{" + inner + "}"


def prom_text(snap: Optional[dict] = None) -> str:
    """The metric registry in Prometheus text exposition format.

    Counters get the ``_total`` suffix, gauges export verbatim,
    histograms export ``_count``/``_sum``/``_min``/``_max`` plus
    reservoir percentiles as ``{quantile="0.5|0.95|0.99"}`` series (a
    summary-style view; the reservoir keeps the first 1024 samples)."""
    metrics = (snap["metrics"] if snap is not None
               else _metrics.snapshot())
    lines = []
    for name in sorted(metrics):
        m = metrics[name]
        kind = m["kind"]
        prom_kind = {"counter": "counter", "gauge": "gauge",
                     "histogram": "summary"}[kind]
        base = _prom_name(name, "_total" if kind == "counter" else "")
        if m.get("help"):
            lines.append(f"# HELP {base} {m['help']}")
        lines.append(f"# TYPE {base} {prom_kind}")
        for s in m["series"]:
            labels = s["labels"]
            if kind in ("counter", "gauge"):
                lines.append(f"{base}{_prom_labels(labels)} {s['value']}")
                continue
            stem = _prom_name(name)
            lines.append(f"{stem}_count{_prom_labels(labels)} {s['count']}")
            lines.append(f"{stem}_sum{_prom_labels(labels)} {s['sum']}")
            lines.append(f"{stem}_min{_prom_labels(labels)} {s['min']}")
            lines.append(f"{stem}_max{_prom_labels(labels)} {s['max']}")
            for p, q in ((50, "0.5"), (95, "0.95"), (99, "0.99")):
                if f"p{p}" in s:
                    lines.append(
                        f"{stem}{_prom_labels(dict(labels, quantile=q))} "
                        f"{s[f'p{p}']}")
    return "\n".join(lines) + "\n"


def write_prom(path: str, snap: Optional[dict] = None) -> str:
    """Write :func:`prom_text` to ``path`` (node-exporter textfile /
    scrape-target style)."""
    with open(path, "w") as f:
        f.write(prom_text(snap))
    return path


# -------------------------------------------- per-request waterfalls


#: per-request stage spans the scheduler emits (engine.py); ``request``
#: is the root span recorded at the terminal state
REQUEST_ROOT = "request"
REQUEST_STAGES = ("req.queue_wait", "req.prefill", "req.insert",
                  "req.decode")


def request_waterfalls(snap: Optional[dict] = None) -> List[dict]:
    """Per-request causal timelines from the scheduler's request spans.

    Groups ``req.*`` stage spans by their ``rid`` attribute under each
    ``request`` root span and checks the reconciliation contract:
    queue-wait, prefill, and insert are *contiguous* (shared endpoints),
    so their sum equals TTFT exactly; decode ticks account for the rest
    up to scheduler overhead, surfaced as ``unaccounted_us`` (≥ 0 —
    stages never overlap or exceed the measured request latency)."""
    snap = snap if snap is not None else snapshot()
    roots: dict = {}
    stages: dict = {}
    for sp in snap["spans"]:
        rid = sp["attrs"].get("rid")
        if rid is None:
            continue
        if sp["name"] == REQUEST_ROOT:
            roots[rid] = sp
        elif sp["name"] in REQUEST_STAGES:
            stages.setdefault(rid, []).append(sp)
    out = []
    for rid in sorted(roots):
        root = roots[rid]
        st = sorted(stages.get(rid, []), key=lambda s: s["ts_us"])
        # reconcile on the integer-ns twins: stage endpoints are shared
        # by construction, so exact equality holds (no float µs rounding)
        total_ns = root["dur_ns"]
        accounted_ns = sum(s["dur_ns"] for s in st)
        ttft_ns = sum(s["dur_ns"] for s in st
                      if s["name"] != "req.decode")
        decode_ticks = sum(1 for s in st if s["name"] == "req.decode")
        out.append({
            "rid": rid,
            "state": root["attrs"].get("state"),
            "total_us": total_ns / 1e3,
            "ttft_us": ttft_ns / 1e3,
            "decode_ticks": decode_ticks,
            "accounted_us": accounted_ns / 1e3,
            "unaccounted_us": (total_ns - accounted_ns) / 1e3,
            "total_ns": total_ns,
            "ttft_ns": ttft_ns,
            "accounted_ns": accounted_ns,
            "unaccounted_ns": total_ns - accounted_ns,
            "stages": [{"name": s["name"], "t0_us": s["ts_us"],
                        "dur_us": s["dur_us"], "t0_ns": s["ts_ns"],
                        "dur_ns": s["dur_ns"], "attrs": s["attrs"]}
                       for s in st],
        })
    return out


def request_chrome_trace(snap: Optional[dict] = None) -> dict:
    """Chrome-trace view with one timeline row per request (tid = rid),
    so the per-request waterfall reads top-to-bottom in perfetto. Spans
    without a ``rid`` keep their thread row; recorder events and counter
    samples ride along unchanged."""
    snap = snap if snap is not None else snapshot()
    base = chrome_trace(snap)
    pid = snap["meta"]["pid"]
    rids = set()
    # chrome_trace lays out [process-meta] + spans (in order) + events +
    # counters, so zipping the tail against snap["spans"] pairs them up
    for ev, sp in zip(base["traceEvents"][1:], snap["spans"]):
        rid = sp["attrs"].get("rid")
        if rid is None:
            continue
        ev["tid"] = 1 + int(rid)
        rids.add(int(rid))
    for rid in sorted(rids):
        base["traceEvents"].append({
            "name": "thread_name", "ph": "M", "pid": pid, "tid": 1 + rid,
            "args": {"name": f"request {rid}"},
        })
    base["waterfalls"] = request_waterfalls(snap)
    return base


def _export_at_exit() -> None:  # pragma: no cover - exit hook
    path = os.environ.get(_EXPORT_ENV)
    if not path or not _trace.enabled():
        return
    try:
        if path.endswith(".jsonl"):
            write_jsonl(path)
        else:
            write_chrome_trace(path)
    except Exception as e:  # noqa: BLE001 — never fail interpreter exit
        print(f"[repro.obs] export to {path} failed: {e}")


_atexit_registered = False


def install_atexit_export() -> None:
    """Idempotently register the ``REPRO_OBS_EXPORT`` exit hook."""
    global _atexit_registered
    if not _atexit_registered:
        atexit.register(_export_at_exit)
        _atexit_registered = True


if os.environ.get(_EXPORT_ENV):
    install_atexit_export()
