"""Export sinks for the observability layer (DESIGN.md §13).

Three consumers, three shapes:

* :func:`snapshot` — one JSON-ready dict ``{meta, spans, metrics}``, the
  programmatic API (tests, the serve benchmark, future planner cost
  models read this).
* :func:`write_jsonl` — line-oriented sink (one ``{"type": ...}`` object
  per line: ``meta``, then every span, then every metric) for log
  shippers and offline analysis.
* :func:`chrome_trace` / :func:`write_chrome_trace` — Chrome trace-event
  JSON (``traceEvents`` with ``ph: "X"`` complete events), loadable in
  ``chrome://tracing`` and perfetto. Span kinds become categories, so
  trace-time (planning/lowering) and run-time spans are separately
  filterable.

``REPRO_OBS_EXPORT=<path>`` auto-writes at interpreter exit when obs is
on: ``*.jsonl`` selects the JSONL sink, anything else the Chrome trace.

:func:`validate_chrome_trace` is the schema check CI gates the exported
trace against — it returns a list of violations (empty = valid) instead
of raising, so callers can aggregate.
"""
from __future__ import annotations

import atexit
import json
import os
import time
from typing import List, Optional

from . import metrics as _metrics
from . import trace as _trace

_EXPORT_ENV = "REPRO_OBS_EXPORT"


def _meta() -> dict:
    import jax

    return {
        "schema": 1,
        "generated_unix": int(time.time()),
        "pid": os.getpid(),
        "platform": jax.default_backend(),
        "dropped_spans": _trace.dropped(),
    }


def snapshot() -> dict:
    """Everything recorded so far: ``{meta, spans, metrics}``."""
    return {
        "meta": _meta(),
        "spans": [sp.to_dict() for sp in _trace.spans()],
        "metrics": _metrics.snapshot(),
    }


def write_jsonl(path: str, snap: Optional[dict] = None) -> str:
    """One JSON object per line: meta, spans, metrics."""
    snap = snap if snap is not None else snapshot()
    with open(path, "w") as f:
        f.write(json.dumps({"type": "meta", **snap["meta"]}) + "\n")
        for sp in snap["spans"]:
            f.write(json.dumps({"type": "span", **sp}) + "\n")
        for m in snap["metrics"].values():
            f.write(json.dumps({"type": "metric", **m}) + "\n")
    return path


def chrome_trace(snap: Optional[dict] = None) -> dict:
    """Chrome trace-event JSON (perfetto-loadable) from recorded spans.

    Spans map to ``ph: "X"`` complete events (ts/dur in µs on the
    host-monotonic clock); metric totals ride along as one ``ph: "C"``
    counter sample each so headline counts are visible on the timeline."""
    snap = snap if snap is not None else snapshot()
    pid = snap["meta"]["pid"]
    events: List[dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": f"repro.obs ({snap['meta']['platform']})"},
    }]
    t0 = min((sp["ts_us"] for sp in snap["spans"]), default=0.0)
    for sp in snap["spans"]:
        events.append({
            "name": sp["name"],
            "cat": sp["kind"],
            "ph": "X",
            "ts": sp["ts_us"] - t0,
            "dur": sp["dur_us"],
            "pid": pid,
            "tid": sp["thread"] % (1 << 31),
            "args": dict(sp["attrs"], span_id=sp["id"],
                         parent=sp["parent"]),
        })
    for name, m in snap["metrics"].items():
        if m["kind"] != "counter":
            continue
        total = sum(s["value"] for s in m["series"])
        events.append({
            "name": name, "ph": "C", "ts": 0.0, "pid": pid, "tid": 0,
            "args": {"total": total},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, snap: Optional[dict] = None) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(snap), f)
        f.write("\n")
    return path


def validate_chrome_trace(obj: dict) -> List[str]:
    """Schema check for the exported trace; returns violations (empty =
    valid). Covers the invariants chrome://tracing / perfetto require:
    a ``traceEvents`` list whose events carry name/ph/pid/tid, complete
    (``X``) events with numeric non-negative ts/dur."""
    errs: List[str] = []
    if not isinstance(obj, dict):
        return ["trace is not a JSON object"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        for field in ("name", "ph", "pid", "tid"):
            if field not in ev:
                errs.append(f"{where}: missing {field!r}")
        ph = ev.get("ph")
        if ph not in ("X", "M", "C", "B", "E", "i"):
            errs.append(f"{where}: unknown phase {ph!r}")
        if ph == "X":
            for field in ("ts", "dur"):
                v = ev.get(field)
                if not isinstance(v, (int, float)) or v < 0:
                    errs.append(f"{where}: {field} not a non-negative number")
            if not isinstance(ev.get("cat", ""), str):
                errs.append(f"{where}: cat not a string")
    return errs


def _export_at_exit() -> None:  # pragma: no cover - exit hook
    path = os.environ.get(_EXPORT_ENV)
    if not path or not _trace.enabled():
        return
    try:
        if path.endswith(".jsonl"):
            write_jsonl(path)
        else:
            write_chrome_trace(path)
    except Exception as e:  # noqa: BLE001 — never fail interpreter exit
        print(f"[repro.obs] export to {path} failed: {e}")


_atexit_registered = False


def install_atexit_export() -> None:
    """Idempotently register the ``REPRO_OBS_EXPORT`` exit hook."""
    global _atexit_registered
    if not _atexit_registered:
        atexit.register(_export_at_exit)
        _atexit_registered = True


if os.environ.get(_EXPORT_ENV):
    install_atexit_export()
