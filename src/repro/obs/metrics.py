"""Process-global metric registry: counters, gauges, histograms.

The registry is the numeric half of the observability layer (spans are
the temporal half): dispatch decisions per backend, autotune cache
hit/miss/stale-schema, segmented spill and padded-slot waste, grid-merge
refill tiles, dist-sort all_to_all bytes, per-plan VMEM estimates.

Semantics:

* Every mutator (``inc``/``set``/``observe``) is gated on
  :func:`repro.obs.trace.enabled` — with ``REPRO_OBS`` unset the whole
  registry is inert and costs one predicate call.
* Labels are keyword arguments; each distinct label combination is one
  series. Keep cardinality low (op names, backends — never shapes-per-
  element or request ids).
* Many instrumented functions run at **jit trace time** (planning,
  bucketing, kernel wrapping). Their metrics count *traces*, not calls:
  calling a jitted function three times with the same shapes bumps a
  trace-time counter once. That is the useful number — it counts
  compilations and plan decisions, which is what the planner's measured
  cost model needs — and it is deterministic under retracing.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Tuple

from .trace import enabled

_LabelKey = Tuple[Tuple[str, Any], ...]


def _lkey(labels: Dict[str, Any]) -> _LabelKey:
    return tuple(sorted(labels.items()))


class Metric:
    kind = "metric"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()

    def series(self) -> List[dict]:  # pragma: no cover - overridden
        raise NotImplementedError

    def to_dict(self) -> dict:
        return {"name": self.name, "kind": self.kind, "help": self.help,
                "series": self.series()}


class Counter(Metric):
    """Monotonic sum per label set."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._vals: Dict[_LabelKey, float] = {}

    def inc(self, n: float = 1, **labels) -> None:
        if not enabled():
            return
        key = _lkey(labels)
        with self._lock:
            self._vals[key] = self._vals.get(key, 0) + n

    def value(self, **labels) -> float:
        with self._lock:
            return self._vals.get(_lkey(labels), 0)

    def total(self) -> float:
        with self._lock:
            return sum(self._vals.values())

    def series(self) -> List[dict]:
        with self._lock:
            return [{"labels": dict(k), "value": v}
                    for k, v in sorted(self._vals.items())]


class Gauge(Metric):
    """Last-written value per label set."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._vals: Dict[_LabelKey, float] = {}

    def set(self, value: float, **labels) -> None:
        if not enabled():
            return
        with self._lock:
            self._vals[_lkey(labels)] = float(value)

    def value(self, **labels) -> Optional[float]:
        with self._lock:
            return self._vals.get(_lkey(labels))

    def series(self) -> List[dict]:
        with self._lock:
            return [{"labels": dict(k), "value": v}
                    for k, v in sorted(self._vals.items())]


class Histogram(Metric):
    """count/sum/min/max plus a bounded sample reservoir (first
    ``max_samples`` observations) for percentile estimates in exports."""

    kind = "histogram"
    max_samples = 1024

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._stats: Dict[_LabelKey, dict] = {}

    def observe(self, value: float, **labels) -> None:
        if not enabled():
            return
        value = float(value)
        key = _lkey(labels)
        with self._lock:
            st = self._stats.get(key)
            if st is None:
                st = self._stats[key] = {
                    "count": 0, "sum": 0.0, "min": value, "max": value,
                    "samples": [],
                }
            st["count"] += 1
            st["sum"] += value
            st["min"] = min(st["min"], value)
            st["max"] = max(st["max"], value)
            if len(st["samples"]) < self.max_samples:
                st["samples"].append(value)

    def stats(self, **labels) -> Optional[dict]:
        with self._lock:
            st = self._stats.get(_lkey(labels))
            return dict(st, samples=list(st["samples"])) if st else None

    def series(self) -> List[dict]:
        with self._lock:
            out = []
            for k, st in sorted(self._stats.items()):
                # ``samples`` (reservoir occupancy) rides along so an
                # exhausted reservoir is visible: count > samples means
                # the percentiles below cover only the first
                # ``max_samples`` observations, not the full series
                row = {"labels": dict(k), "count": st["count"],
                       "sum": st["sum"], "min": st["min"], "max": st["max"],
                       "samples": len(st["samples"]),
                       "reservoir_full": len(st["samples"]) >= self.max_samples}
                samples = sorted(st["samples"])
                if samples:
                    for p in (50, 95, 99):
                        idx = min(len(samples) - 1,
                                  int(round(p / 100 * (len(samples) - 1))))
                        row[f"p{p}"] = samples[idx]
                out.append(row)
            return out


_reg_lock = threading.Lock()
_registry: Dict[str, Metric] = {}


def _get_or_create(name: str, cls, help: str) -> Metric:
    with _reg_lock:
        m = _registry.get(name)
        if m is None:
            m = _registry[name] = cls(name, help)
        assert isinstance(m, cls), (
            f"metric {name!r} already registered as {m.kind}")
        return m


def counter(name: str, help: str = "") -> Counter:
    return _get_or_create(name, Counter, help)


def gauge(name: str, help: str = "") -> Gauge:
    return _get_or_create(name, Gauge, help)


def histogram(name: str, help: str = "") -> Histogram:
    return _get_or_create(name, Histogram, help)


def registry() -> Dict[str, Metric]:
    with _reg_lock:
        return dict(_registry)


def snapshot() -> Dict[str, dict]:
    """All metrics as JSON-ready dicts, keyed by metric name."""
    with _reg_lock:
        items = list(_registry.items())
    return {name: m.to_dict() for name, m in items}


def reset() -> None:
    """Drop every registered metric (tests / between export epochs)."""
    with _reg_lock:
        _registry.clear()
