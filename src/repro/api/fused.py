"""Fused single-launch execution paths for the pallas backend.

The generic ops pipeline (``ops.py``) surrounds every backend call with
XLA-level passes: the NaN-policy key encode/decode (``keys.py``), the
position-payload build + pytree gather (``payload.py``), and the
descending reverse. Each is an extra HBM round-trip over the full data —
the traffic the paper's single-device merges exist to avoid. This module
short-circuits all of it when the planner picks the pallas backend: the
kernels (``kernels/sort.py``, ``kernels/loms_merge.py``,
``kernels/kway.py``, ``kernels/topk.py``) encode on load, thread an int32
position lane through their permutes, gather payload lanes in VMEM, and
decode on store — one ``pallas_call`` for a float ``repro.sort`` with
``nan_policy="last"`` and a payload.

Differentiability: the in-kernel decode removes the XLA decode step the
custom-VJP machinery in ``ops.py`` wrapped, so each fused entry here is
itself a ``jax.custom_vjp``. Backward recovers the sorting permutation
with one stable argsort of the encoded input (the same subgradient
convention as ``jnp.sort``'s VJP / ``_decode_sorted_bwd``) and scatters
the cotangents — values keep training through fused sorts/merges, and the
fused top-k matches the gather-from-raw VJP the MoE router relies on.

``set_fused_enabled(False)`` (or ``REPRO_DISABLE_FUSED=1``) reverts
*auto* dispatch to the pre-fusion routing (sort and payload merges go
back to the executor; the planner stops offering the fused pallas rows)
and makes the fused entry points here decline, so ops.py falls back to
the executor for permutation-carrying specs. An explicit
``backend="pallas"`` ask is still honored for values-only specs — the
caller named the kernel backend — but runs it unfused (XLA-level
encode/decode around the kernel). This is the benchmark baseline and the
escape hatch.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import encode_key_values, key_transformable

from .spec import SortSpec

_ENABLED = True


def fused_enabled() -> bool:
    return _ENABLED and os.environ.get("REPRO_DISABLE_FUSED") != "1"


def set_fused_enabled(enabled: bool) -> bool:
    """Toggle the fused fast paths (returns the previous value)."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(enabled)
    return prev


# ---------------------------------------------------------------------------
# eligibility
# ---------------------------------------------------------------------------


def fused_eligible(spec: SortSpec) -> bool:
    """Whether the pallas kernels can run ``spec`` as one fused launch.

    ``stable=True`` stays on the executor (the tie-stabilization pass is
    an XLA post-pass by design); ragged 2-way merges defeat the hole-free
    kernel layout; everything else gates on the VMEM fit."""
    from repro.streaming.planner import fits_vmem, kway_fits_vmem, sort_fits_vmem

    if spec.network != "loms" or spec.stable:
        return False
    if spec.op == "sort":
        return sort_fits_vmem(spec.total, dtype=jnp.dtype(spec.dtype))
    if spec.op == "merge":
        return not spec.ragged2 and fits_vmem(
            spec.lengths[0], spec.lengths[1], dtype=jnp.dtype(spec.dtype))
    if spec.op == "merge_k":
        return kway_fits_vmem(spec.total)
    if spec.op == "topk":
        return True
    return False


@dataclasses.dataclass(frozen=True)
class FusedCfg:
    """Static knobs of one fused kernel call (hashable: jit/custom_vjp
    treat it as a nondiff static argument)."""

    op: str
    lens: Tuple[int, ...]
    key_dtype: Optional[str]  # original float dtype name, None = no encode
    descending: bool = False
    block_batch: int = 8
    n_cols: int = 2
    use_mxu: bool = True
    block: int = 0
    k: Optional[int] = None
    network: str = "loms"  # comparator-network family (tournament winner)


def fused_cfg_for(spec: SortSpec, batch: int, dtype) -> Optional[FusedCfg]:
    """Build the static config for one eligible spec (None if ineligible).

    ``dtype`` is the *raw* input dtype — the key transform fuses into the
    kernel whenever ``nan_policy="last"`` covers it, and the permute path
    drops to the exact scatter for int working values."""
    if not fused_enabled() or not fused_eligible(spec):
        return None
    from repro.streaming.planner import plan_op

    key_dtype = (jnp.dtype(dtype).name
                 if spec.nan_policy == "last" and key_transformable(dtype)
                 else None)
    # encoded keys are ints: they must take the exact scatter permute
    float_vals = key_dtype is None and jnp.issubdtype(jnp.dtype(dtype),
                                                      jnp.floating)
    if spec.op == "sort":
        plan = plan_op("sort", spec.lengths, batch=batch, dtype=dtype)
    elif spec.op == "merge":
        plan = plan_op("merge2", spec.lengths, batch=batch, dtype=dtype)
    elif spec.op == "merge_k":
        plan = plan_op("kway", spec.lengths, batch=batch, dtype=dtype)
    else:
        plan = plan_op("topk", spec.lengths, batch=batch, dtype=dtype,
                       k=spec.k)
    return FusedCfg(
        op=spec.op, lens=tuple(spec.lengths), key_dtype=key_dtype,
        descending=spec.descending, block_batch=plan.block_batch,
        n_cols=plan.n_cols if plan.kind == "loms" else 2,
        use_mxu=plan.use_mxu and float_vals, block=plan.block, k=spec.k,
        network=plan.network if plan.kind == "loms" else "loms",
    )


# ---------------------------------------------------------------------------
# backward-pass helpers (shared by every fused vjp)
# ---------------------------------------------------------------------------


def _keys_of(cfg: FusedCfg, x: jnp.ndarray) -> jnp.ndarray:
    return encode_key_values(x) if cfg.key_dtype is not None else x


def _scatter_axis1(ct, order, primal):
    """Cotangent scatter for ``out = primal[:, order]`` (same-shape,
    permutation along axis 1; trailing feature dims broadcast)."""
    idx = order
    if ct.ndim > idx.ndim:
        idx = idx.reshape(idx.shape + (1,) * (ct.ndim - idx.ndim))
        idx = jnp.broadcast_to(idx, ct.shape)
    out = jnp.zeros(primal.shape, dtype=ct.dtype)
    return jnp.put_along_axis(out, idx, ct, axis=1, inplace=False).astype(
        primal.dtype)


def _scatter_ct(ct, order, primal):
    if ct.dtype == jax.dtypes.float0:  # int/bool leaves carry no gradient
        return ct
    return _scatter_axis1(ct, order, primal)


# ---------------------------------------------------------------------------
# fused sort
# ---------------------------------------------------------------------------


def _sort_order(cfg: FusedCfg, x: jnp.ndarray) -> jnp.ndarray:
    order = jnp.argsort(_keys_of(cfg, x), axis=-1, stable=True)
    return order[..., ::-1] if cfg.descending else order


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def fused_sort(cfg: FusedCfg, x: jnp.ndarray, leaves: Tuple[jnp.ndarray, ...]):
    """One-launch sort of (B, n) rows: values + permuted payload leaves."""
    out, _, pouts = _fused_sort_run(cfg, x, leaves, want_perm=False)
    return out, pouts


def _fused_sort_run(cfg, x, leaves, want_perm: bool):
    from repro.kernels.sort import loms_sort_pallas
    from repro.resilience.failpoints import failpoint

    failpoint("fused.launch.sort")

    res = loms_sort_pallas(
        x, tuple(leaves), network=cfg.network, block_batch=cfg.block_batch,
        use_mxu=cfg.use_mxu, key_dtype=cfg.key_dtype,
        descending=cfg.descending, want_perm=want_perm,
    )
    if not leaves and not want_perm:
        return res, None, ()
    out, perm, pouts = res
    return out, perm, tuple(pouts)


def _fused_sort_fwd(cfg, x, leaves):
    # with payload lanes the kernel's *actual* permutation must be the VJP
    # residual: the payload gather is a concrete linear map, and the column
    # devices' tie order need not match a stable argsort's (values-only
    # cotangents may use any tie selection — the jnp.sort subgradient
    # convention — so they recompute and skip the extra output)
    want_perm = bool(leaves)
    out, perm, pouts = _fused_sort_run(cfg, x, leaves, want_perm=want_perm)
    return (out, pouts), (x, leaves, perm)


def _fused_sort_bwd(cfg, residual, cts):
    x, leaves, perm = residual
    ct_out, ct_pouts = cts
    order = perm if perm is not None else _sort_order(cfg, x)
    ct_x = _scatter_ct(ct_out, order, x)
    ct_leaves = tuple(
        _scatter_ct(ct_p, order, leaf)
        for ct_p, leaf in zip(ct_pouts, leaves)
    )
    return ct_x, ct_leaves


fused_sort.defvjp(_fused_sort_fwd, _fused_sort_bwd)


# ---------------------------------------------------------------------------
# fused merge / merge_k
# ---------------------------------------------------------------------------


def _merge_perm(cfg: FusedCfg, lists) -> jnp.ndarray:
    """Recompute the merge permutation (original concat positions) with
    one stable argsort — backward-pass only."""
    ks = [_keys_of(cfg, l) for l in lists]
    if not cfg.descending:
        return jnp.argsort(jnp.concatenate(ks, axis=-1), axis=-1, stable=True)
    # ascending problem = per-list reversal; positions index the original
    # (descending) concat, mirroring the kernels' position lane
    offs, pos, asc = 0, [], []
    for k_ in ks:
        ln = k_.shape[-1]
        asc.append(k_[..., ::-1])
        p = jnp.arange(ln - 1, -1, -1, dtype=jnp.int32) + offs
        pos.append(jnp.broadcast_to(p, k_.shape))
        offs += ln
    order = jnp.argsort(jnp.concatenate(asc, axis=-1), axis=-1, stable=True)
    perm = jnp.take_along_axis(jnp.concatenate(pos, axis=-1), order, axis=-1)
    return perm[..., ::-1]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def fused_merge_k(cfg: FusedCfg, lists: Tuple[jnp.ndarray, ...],
                  leaves: Tuple[jnp.ndarray, ...]):
    """One-launch k-way merge: values + payload leaves (leaves are already
    concatenated along the list axis, (B, total[, F]))."""
    out, _, pouts = _fused_merge_k_run(cfg, lists, leaves, want_perm=False)
    return out, pouts


def _fused_merge_k_run(cfg, lists, leaves, want_perm: bool):
    from repro.resilience.failpoints import failpoint

    failpoint("fused.launch.merge_k")
    if len(lists) == 2 and cfg.op == "merge":
        from repro.kernels.loms_merge import loms_merge2_pallas

        res = loms_merge2_pallas(
            lists[0], lists[1], tuple(leaves), network=cfg.network,
            n_cols=cfg.n_cols, block_batch=cfg.block_batch,
            use_mxu=cfg.use_mxu, key_dtype=cfg.key_dtype,
            descending=cfg.descending, want_perm=want_perm,
        )
    else:
        from repro.kernels.kway import kway_merge_pallas
        from repro.networks import kway_schedule

        sched = kway_schedule(cfg.lens)
        x = jnp.concatenate(list(lists), axis=-1)
        res = kway_merge_pallas(
            x, sched, tuple(leaves), block_batch=cfg.block_batch,
            use_mxu=cfg.use_mxu, lens=cfg.lens, key_dtype=cfg.key_dtype,
            descending=cfg.descending, want_perm=want_perm,
        )
    if not leaves and not want_perm:
        return res, None, ()
    out, perm, pouts = res
    return out, perm, tuple(pouts)


def _fused_merge_k_fwd(cfg, lists, leaves):
    # payload lanes: save the kernel's actual permutation (see the sort
    # fwd for why a stable-argsort reconstruction is not enough)
    want_perm = bool(leaves)
    out, perm, pouts = _fused_merge_k_run(cfg, lists, leaves,
                                          want_perm=want_perm)
    return (out, pouts), (lists, leaves, perm)


def _fused_merge_k_bwd(cfg, residual, cts):
    lists, leaves, perm = residual
    ct_out, ct_pouts = cts
    if perm is None:
        perm = _merge_perm(cfg, lists)
    if ct_out.dtype == jax.dtypes.float0:  # int values carry no gradient
        ct_lists = [np.zeros(l.shape, jax.dtypes.float0) for l in lists]
    else:
        cat = jnp.concatenate(list(lists), axis=-1)
        ct_cat = _scatter_axis1(ct_out, perm, cat)
        offs = 0
        ct_lists = []
        for l in lists:
            ct_lists.append(ct_cat[..., offs:offs + l.shape[-1]])
            offs += l.shape[-1]
    ct_leaves = tuple(
        _scatter_ct(ct_p, perm, leaf)
        for ct_p, leaf in zip(ct_pouts, leaves)
    )
    return tuple(ct_lists), ct_leaves


fused_merge_k.defvjp(_fused_merge_k_fwd, _fused_merge_k_bwd)


# ---------------------------------------------------------------------------
# fused top-k
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def fused_topk(cfg: FusedCfg, x: jnp.ndarray):
    """One-launch (one-per-phase for large axes) descending top-k with the
    key transform fused into the kernels; returns (values, int32 idx)."""
    return _fused_topk_impl(cfg, x)


def _fused_topk_impl(cfg, x):
    from repro.resilience.failpoints import failpoint

    failpoint("fused.launch.topk")
    from repro.kernels.ops import topk_tiles
    from repro.kernels.topk import ROUTER_TOPK_MAX, router_topk_pallas, vocab_topk_pallas

    bsz, e = x.shape
    blk, bb = topk_tiles(bsz, e, block=cfg.block, block_batch=cfg.block_batch)
    kernel = (router_topk_pallas if e <= ROUTER_TOPK_MAX
              else vocab_topk_pallas)
    v, i = kernel(x, k=cfg.k, block=blk, block_batch=bb,
                  use_mxu=cfg.use_mxu, key_dtype=cfg.key_dtype)
    return v, i.astype(jnp.int32)


def _fused_topk_fwd(cfg, x):
    v, i = _fused_topk_impl(cfg, x)
    return (v, i), (x, i)


def _fused_topk_bwd(cfg, residual, cts):
    x, idx = residual
    ct_v, _ = cts  # idx is int: no cotangent
    if ct_v.dtype == jax.dtypes.float0:  # int values carry no gradient
        return (np.zeros(x.shape, jax.dtypes.float0),)
    safe = jnp.where(idx < 0, 0, idx)
    contrib = jnp.where(idx < 0, jnp.zeros_like(ct_v), ct_v)
    rows = jnp.arange(x.shape[0], dtype=jnp.int32)[:, None]
    ct_x = jnp.zeros_like(x).at[rows, safe].add(contrib.astype(x.dtype))
    return (ct_x,)


fused_topk.defvjp(_fused_topk_fwd, _fused_topk_bwd)
