"""SortSpec: the static problem descriptor the dispatch layer plans from.

One frozen dataclass captures everything the planner needs to choose a
backend for a call — operation, per-list lengths, batch, dtype, axis,
ordering/stability flags, payload presence, the caller's backend hint, the
live JAX platform, and whether a usable TP sharding was offered. Specs are
plain static data (no arrays), so they can be built inside a jit trace,
compared in tests, and printed in decision tables.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

OPS = ("merge", "merge_k", "sort", "topk", "median")

BACKEND_AUTO = "auto"


@dataclasses.dataclass(frozen=True)
class SortSpec:
    """Static description of one sort/merge/top-k problem."""

    op: str  # 'merge' | 'merge_k' | 'sort' | 'topk' | 'median'
    lengths: Tuple[int, ...]  # per-input-list lengths along the sort axis
    batch: int = 1  # product of all non-sort dims
    dtype: str = "float32"
    k: Optional[int] = None  # top-k truncation, if any
    axis: int = -1  # caller's sort axis (pre-canonicalization)
    descending: bool = False
    stable: bool = False  # index-augmented tie-break requested
    has_payload: bool = False  # a pytree payload rides the permutation
    network: str = "loms"  # schedule family for the executor backend
    backend: str = BACKEND_AUTO  # caller hint: auto|schedule|pallas|...
    device: str = "cpu"  # jax.default_backend() at call time
    sharded: bool = False  # a Parallelism with a usable TP axis was passed
    #: static CSR segment offsets, one tuple per input list (``None`` =
    #: dense rectangular problem). When set, the op applies *per segment*
    #: — ``sort`` sorts each segment independently, ``merge`` merges
    #: per-segment run pairs, ``topk`` truncates per segment — and the
    #: planner routes to the segmented backend's size-class buckets.
    #: Offsets are trace-time constants: they size networks and launches.
    segment_offsets: Optional[Tuple[Tuple[int, ...], ...]] = None
    #: NaN ordering for float inputs. ``"last"`` (default): NaNs sort
    #: last, like ``jnp.sort`` — implemented by the total-order key
    #: pre-pass (repro.api.keys), which also makes genuine ±inf safe on
    #: the MXU one-hot permute path. ``"unsafe"``: skip the pre-pass and
    #: feed raw floats to the comparison networks — fastest, but the
    #: output is undefined (not even a permutation) if any input is NaN,
    #: and ±inf corrupts MXU-permuted kernels. Integer dtypes ignore it.
    nan_policy: str = "last"

    def __post_init__(self):
        assert self.op in OPS, f"unknown op {self.op!r}"
        assert self.lengths, "at least one input list required"
        assert self.nan_policy in ("last", "unsafe"), self.nan_policy
        if self.segment_offsets is not None:
            assert len(self.segment_offsets) == len(self.lengths), (
                "one offsets tuple per input list",
                self.segment_offsets, self.lengths)
            for offs, ln in zip(self.segment_offsets, self.lengths):
                assert offs and offs[0] == 0 and offs[-1] == ln, (offs, ln)

    @property
    def total(self) -> int:
        """Total element count along the sort axis."""
        return sum(self.lengths)

    @property
    def n_lists(self) -> int:
        return len(self.lengths)

    @property
    def needs_perm(self) -> bool:
        """True when the backend must hand back the input permutation
        (payload gathers and stable tie-breaks both consume it)."""
        return self.stable or self.has_payload

    @property
    def ragged2(self) -> bool:
        """2-way merge whose lengths defeat the hole-free kernel layout
        (no common column count >= 2 divides both lists). Divisor-based:
        (7, 7) or (12, 9) get a real column device (the paper's UP-7/DN-7
        shape class); only coprime-ish pairs like (7, 5) fall back."""
        if self.op != "merge" or len(self.lengths) != 2:
            return False
        import math

        return math.gcd(int(self.lengths[0]), int(self.lengths[1])) < 2

    @property
    def segmented(self) -> bool:
        """True when the problem is CSR ragged (per-segment semantics)."""
        return self.segment_offsets is not None

    @property
    def n_segments(self) -> int:
        return 0 if not self.segmented else len(self.segment_offsets[0]) - 1

    def describe(self) -> str:
        shape = "x".join(str(ln) for ln in self.lengths)
        extra = f" k={self.k}" if self.k is not None else ""
        seg = f" S={self.n_segments}" if self.segmented else ""
        return (f"{self.op}[{shape}]{extra}{seg} b={self.batch} "
                f"{self.dtype} ({self.device})")
