"""Planner-driven backend selection for the unified sort ops.

``plan(spec, par)`` maps a :class:`~repro.api.spec.SortSpec` to a
:class:`Decision` — which backend runs the problem and why. The rules lean
on :mod:`repro.streaming.planner` (the paper's comparator cost model plus
the VMEM budget from DESIGN.md §2), the live JAX platform, and the offered
sharding, so callers state *what* to sort and this module picks *how* —
the one-abstraction-many-realizations stance of the merge literature
(FLiMS, Merge Path) applied to our device family.

The decision table (DESIGN.md §9):

  op       condition                                  backend    detail
  -------  -----------------------------------------  ---------  -----------
  topk     TP-sharded vocab (Parallelism + divisible) sharded    tree_topk
  topk     TPU, axis > 512                            pallas     vocab_topk
  topk     TPU, axis <= 512                           pallas     router_topk
  topk     otherwise (CPU/GPU hosts)                  schedule   blockwise
  sort     TP-sharded + total >= DIST_MIN_TOTAL       sharded    sample_sort
  sort     TPU + fits VMEM, not stable                pallas     sort_fused
  sort     otherwise (stable / over-VMEM / non-TPU)   schedule   merge_tree
  merge    TP-sharded + total >= DIST_MIN_TOTAL       sharded    sample_merge
  merge    payload, TPU + fits VMEM, not stable       pallas     fused_payload
  merge    payload / stable otherwise (perm needed)   schedule   payload
  merge    ragged lengths (no common column count)    schedule   ragged
  merge    working set past the VMEM budget           streaming  chunked
  merge    TPU, fits VMEM                             pallas     loms_merge2
  merge    otherwise                                  schedule   loms_2way
  merge_k  same ladder as merge                       ...        kway/chunked
  median   TPU + equal odd lists, no perm             pallas     kway_median
  median   otherwise                                  schedule   loms_median

The pallas rows run *fused*: NaN-policy key encode/decode, the payload
permute, and descending reversal all execute inside the kernel launch
(repro.api.fused), so a float32 ``repro.sort`` with ``nan_policy="last"``
and a payload is one ``pallas_call`` with no XLA-level encode/decode/
gather around it. Tile knobs (block_batch / n_cols / topk block) come
from the VMEM-aware autotuner (streaming.planner.plan_op: cache-hit
autotuned tiles, VMEM-fit heuristics otherwise).

The sharded rows engage when the caller offered a Parallelism whose TP
axis divides every list length (spec.sharded); below DIST_MIN_TOTAL the
partition + two all_to_alls cost more than they parallelize away, so
small sharded problems stay on the single-device ladder.

Explicit ``backend=`` hints skip the ladder but are still validated against
the backend's capability predicate, so impossible asks fail loudly instead
of silently computing the wrong thing.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax.numpy as jnp

from repro.kernels.topk import ROUTER_TOPK_MAX  # noqa: F401  (re-exported)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from .registry import get_backend
from .spec import BACKEND_AUTO, SortSpec


@dataclasses.dataclass(frozen=True)
class Decision:
    """One routing outcome: backend name, kernel detail, human reason.

    ``source`` records how the backend was picked: ``"rule"`` (the static
    ladder), ``"measured"`` (a faster measured route sample overrode the
    rule), or ``"breaker"`` (an open circuit breaker rerouted the call
    down the degradation ladder — repro.resilience).
    ``measured_us`` carries the winning sample when one existed.
    ``network`` names the comparator-network family the pallas kernels
    will execute (the autotuner-tournament winner when a tuned entry
    exists for this point; ``None`` for non-pallas backends)."""

    backend: str
    detail: str = ""
    reason: str = ""
    source: str = "rule"
    measured_us: Optional[float] = None
    network: Optional[str] = None


def _merge2_fits_vmem(spec: SortSpec) -> bool:
    from repro.streaming.planner import fits_vmem

    m, n = spec.lengths[0], sum(spec.lengths[1:])
    return fits_vmem(m, n, dtype=jnp.dtype(spec.dtype))


def _kway_fits_vmem(spec: SortSpec) -> bool:
    from repro.streaming.planner import kway_fits_vmem

    return kway_fits_vmem(spec.total)


def _dist_min_total() -> int:
    from repro.parallel.dist_sort import DIST_MIN_TOTAL

    return DIST_MIN_TOTAL


def _fused_on() -> bool:
    """The fused-pipeline escape hatch (repro.api.fused): when switched
    off, the auto ladder stops offering the fused pallas rows, so sort
    and payload merges revert to the pre-fusion executor routing.
    Explicit ``backend="pallas"`` asks are still honored."""
    from .fused import fused_enabled

    return fused_enabled()


def _dist_eligible(spec: SortSpec) -> bool:
    """Sharded sample-sort rows: a usable TP axis was offered (the ops
    layer sets spec.sharded only when every list length divides it) and
    the problem is large enough to amortize the two all_to_alls."""
    return (spec.sharded and spec.network == "loms"
            and spec.total >= _dist_min_total())


def _segmented_on() -> bool:
    """The segmented-subsystem escape hatch (repro.segmented): when off,
    auto routing degrades to the per-segment XLA reference instead of the
    bucketed kernel launches. Explicit ``backend="segmented"`` asks are
    still honored (and still run the kernels)."""
    from repro.segmented.core import segmented_enabled

    return segmented_enabled()


def _plan_segmented(spec: SortSpec) -> Decision:
    """Routing for CSR ragged specs: the segmented backend owns them all
    (no other backend understands per-segment semantics); the decision
    detail picks the bucketed kernel path vs the XLA reference."""
    if not _segmented_on():
        return Decision(
            "segmented", "reference",
            "segmented kernels disabled (escape hatch): per-segment XLA "
            "reference path",
        )
    if spec.device == "tpu":
        return Decision(
            "segmented", "bucketed_pallas",
            f"{spec.n_segments} segments in pow2 size classes: one fused "
            "launch per class, FLiMS grid-merge spill",
        )
    return Decision(
        "segmented", "reference",
        f"{spec.device or 'non-TPU'} host: per-segment XLA reference "
        "(kernels available via backend='segmented')",
    )


def plan(spec: SortSpec, par=None) -> Decision:
    """Resolve the backend for one problem. Pure function of (spec, par).

    Every decision is recorded in the obs layer (``plan.decisions``
    counter: op / backend / detail / device labels) when ``REPRO_OBS``
    is on — the route-count telemetry the measured cost model audits
    fused-vs-unfused choices against. Disabled, the extra cost is one
    predicate check."""
    with obs_trace.span("plan", kind="trace", op=spec.op):
        dec = _resolve(spec, par)
        dec = _measured_override(spec, dec)
        # breaker avoidance (repro.resilience): a rung with an open
        # circuit breaker for this (op, shape-class) is skipped before it
        # can fail again; one dict miss when no failure was ever recorded
        dec = _resilience_reroute(spec, dec)
        if dec.backend == "pallas":
            entry = _tuned_entry(spec)
            dec = dataclasses.replace(
                dec, network=str((entry or {}).get("network", "loms")))
    if obs_trace.enabled():
        obs_metrics.counter("plan.decisions").inc(
            op=spec.op, backend=dec.backend, detail=dec.detail,
            device=spec.device or "?", segmented=spec.segmented,
            sharded=spec.sharded, payload=spec.has_payload,
            source=dec.source, network=dec.network or "-",
        )
    return dec


def _resilience_reroute(spec: SortSpec, dec: Decision) -> Decision:
    from repro.resilience.ladder import reroute

    return reroute(spec, dec)


def _resolve(spec: SortSpec, par=None) -> Decision:
    if spec.segmented and spec.backend == BACKEND_AUTO:
        return _plan_segmented(spec)
    if spec.backend != BACKEND_AUTO:
        be = get_backend(spec.backend)
        if not be.supports(spec):
            raise ValueError(
                f"backend {spec.backend!r} cannot run {spec.describe()} "
                f"(payload/stable={spec.needs_perm}, network={spec.network!r})"
            )
        return Decision(spec.backend, detail="explicit", reason="caller override")

    if spec.op == "topk":
        if spec.sharded:
            return Decision(
                "sharded", "tree_topk",
                "TP-sharded vocab: log-depth merge reduction over the mesh axis",
            )
        if spec.device == "tpu":
            if spec.total > ROUTER_TOPK_MAX:
                return Decision(
                    "pallas", "vocab_topk",
                    f"TPU, axis {spec.total} > {ROUTER_TOPK_MAX}: two-phase "
                    "block kernel + truncated merge levels",
                )
            return Decision(
                "pallas", "router_topk",
                f"TPU, axis {spec.total} <= {ROUTER_TOPK_MAX}: single-kernel "
                "blockwise top-k",
            )
        return Decision(
            "schedule", "blockwise_topk",
            f"{spec.device or 'non-TPU'} host: pure-JAX truncated-merge tree",
        )

    if spec.op == "sort":
        if _dist_eligible(spec):
            return Decision(
                "sharded", "sample_sort",
                f"TP-sharded, total {spec.total} >= {_dist_min_total()}: "
                "PSRS sample-sort over the mesh axis",
            )
        if (spec.device == "tpu" and _fused_on()
                and get_backend("pallas").supports(spec)):
            return Decision(
                "pallas", "loms_sort_fused",
                "TPU, fits VMEM: single-launch fused merge-tree sort "
                "(in-kernel key transform + payload lanes)",
            )
        return Decision(
            "schedule", "loms_merge_tree",
            "full sort = 2-sorter pairs + LOMS merge tree (stable / "
            "over-VMEM / non-TPU hosts)",
        )

    if spec.op == "median":
        if spec.device == "tpu" and get_backend("pallas").supports(spec):
            return Decision("pallas", "kway_median", "TPU, equal odd lists")
        return Decision("schedule", "loms_median", "schedule executor median")

    # merge / merge_k
    if _dist_eligible(spec):
        # checked before needs_perm: the sample-sort path carries the
        # position payload through both all_to_alls
        return Decision(
            "sharded", "sample_merge_k",
            f"TP-sharded, total {spec.total} >= {_dist_min_total()}: "
            "local k-way LOMS merge of list slices + PSRS exchange",
        )
    if spec.needs_perm:
        if (spec.device == "tpu" and _fused_on()
                and get_backend("pallas").supports(spec)):
            return Decision(
                "pallas", "fused_payload",
                "TPU, fits VMEM: payload rides the kernel permutes in "
                "VMEM (single fused launch)",
            )
        return Decision(
            "schedule", "payload",
            "payload/stable needs the permutation-carrying executor",
        )
    if spec.network != "loms":
        # pallas/streaming realize the LOMS devices only; an explicit
        # Batcher/MWMS/tree ask must not be silently swapped for LOMS
        return Decision(
            "schedule", "network",
            f"non-default network {spec.network!r}: schedule executor",
        )
    if spec.op == "merge":
        if spec.ragged2:
            return Decision(
                "schedule", "ragged",
                "no common column count divides both lists: hole-y setup "
                "array, executor handles it",
            )
        if not _merge2_fits_vmem(spec):
            return Decision(
                "streaming", "chunked_merge",
                "working set past the VMEM budget: fixed-tile carry-buffer "
                "pipeline",
            )
        if spec.device == "tpu":
            return Decision("pallas", "loms_merge2", "TPU, fits VMEM")
        return Decision(
            "schedule", "loms_2way", f"{spec.device or 'non-TPU'} host"
        )
    # merge_k
    if not _kway_fits_vmem(spec):
        return Decision(
            "streaming", "chunked_merge_k",
            "comparison cloud past the VMEM budget: merge-path tiled pipeline",
        )
    if spec.device == "tpu":
        return Decision("pallas", "kway_merge", "TPU, fits VMEM")
    return Decision("schedule", "loms_kway", f"{spec.device or 'non-TPU'} host")


# ---------------------------------------------------------------------------
# measured-cost dispatch: recorded route timings override the static ladder
# ---------------------------------------------------------------------------

#: single-device backends the measured ranking may choose between
_MEASURED_CANDIDATES = ("pallas", "schedule", "streaming")


def measured_dispatch_enabled() -> bool:
    """``REPRO_MEASURED_DISPATCH=0`` pins routing to the static rules."""
    import os

    return os.environ.get("REPRO_MEASURED_DISPATCH", "1") != "0"


def _route_key(spec: SortSpec, backend: str) -> str:
    """Cache key for one (op, shapes, dtype, k, payload, platform, backend)
    route sample. The platform rides in the key's backend tag so TPU and
    CPU timings never rank against each other."""
    import jax

    from repro.streaming.cache import plan_key

    tag = (f"{jax.default_backend()}:"
           f"{'payload' if spec.has_payload else 'plain'}:{backend}")
    return plan_key(f"route_{spec.op}",
                    shapes=(spec.batch,) + tuple(spec.lengths),
                    dtype=spec.dtype, k=spec.k, backend=tag)


def record_route_us(spec: SortSpec, backend: str, us: float) -> None:
    """Record one measured wall-time sample (µs) for running ``spec``
    through ``backend``. Keeps the fastest sample seen — a robust
    estimator under timer noise, and monotone: a route can only get
    *preferred* by measuring it faster. Benchmarks are the intended
    writers (``benchmarks/api_dispatch.py --measure-routes``); the samples
    persist in the autotune cache alongside the kernel tuning points."""
    from repro.streaming.cache import default_cache

    cache = default_cache()
    key = _route_key(spec, backend)
    prev = cache.get(key)
    best = float(us)
    if prev is not None and "us" in prev:
        best = min(best, float(prev["us"]))
    cache.put(key, {"us": best, "backend": backend, "op": spec.op})


def measured_route_us(spec: SortSpec, backend: str) -> Optional[float]:
    """Fastest recorded sample for routing ``spec`` via ``backend``."""
    from repro.streaming.cache import default_cache

    entry = default_cache().get(_route_key(spec, backend))
    if entry is None or "us" not in entry:
        return None
    return float(entry["us"])


def _measured_override(spec: SortSpec, dec: Decision) -> Decision:
    """Prefer the fastest *measured* candidate over the static rule.

    Engages only for auto, single-device, non-segmented specs, and only
    when at least two capable backends have recorded samples for this
    exact (op, shapes, dtype, k, payload, platform) point — one sample
    can't rank alternatives. Candidates respect the same escape hatches
    as the rules (a fused-pipeline opt-out also removes the fused pallas
    rows from the measured ranking)."""
    if (not measured_dispatch_enabled() or spec.backend != BACKEND_AUTO
            or spec.segmented or spec.sharded or dec.backend == "sharded"):
        return dec
    samples = {}
    for b in _MEASURED_CANDIDATES:
        if (b == "pallas" and not _fused_on()
                and (spec.op == "sort" or spec.needs_perm)):
            continue
        if not get_backend(b).supports(spec):
            continue
        us = measured_route_us(spec, b)
        if us is not None:
            samples[b] = us
    if len(samples) < 2:
        return dec
    winner = min(samples, key=samples.get)
    if winner == dec.backend:
        return dataclasses.replace(dec, measured_us=samples[winner])
    runner_b, runner_us = min(
        ((b, u) for b, u in samples.items() if b != winner),
        key=lambda kv: kv[1])
    return dataclasses.replace(
        dec, backend=winner, detail="measured",
        reason=(f"measured {samples[winner]:.1f}µs via {winner} beats "
                f"{runner_b} {runner_us:.1f}µs (rule chose {dec.backend})"),
        source="measured", measured_us=samples[winner])


def _tuned_entry(spec: SortSpec) -> Optional[dict]:
    """Full cached autotune entry for the spec's kernel tuning point, if
    an autotune sweep ever ran it on this platform. Carries the measured
    ``us`` sample and the ``network`` tournament winner."""
    from repro.streaming.cache import default_cache, plan_key

    op_map = {
        "sort": ("sort", (spec.lengths[0],), None),
        "merge": ("merge2", tuple(spec.lengths), None),
        "merge_k": ("kway", tuple(spec.lengths), None),
        "topk": ("topk", (spec.total,), spec.k),
    }
    if spec.segmented or spec.op not in op_map:
        return None
    op, lengths, k = op_map[spec.op]
    return default_cache().get(
        plan_key(op, shapes=(spec.batch,) + lengths, dtype=spec.dtype, k=k))


def _tuned_us(spec: SortSpec) -> Optional[float]:
    """Cached measured wall time (µs) for the spec's kernel tuning point.
    Surfaces the persisted ``MergePlan.us`` samples in
    :func:`decision_table` so perf regressions are inspectable without
    rerunning benchmarks."""
    entry = _tuned_entry(spec)
    if entry is None or "us" not in entry:
        return None
    return float(entry["us"])


def decision_table(device: Optional[str] = None) -> List[dict]:
    """Representative routing grid for docs and the dispatch benchmark.

    Each row carries ``tuned_us`` — the cached autotune wall-time sample
    for that tuning point (``None`` until an autotune sweep measured it
    on this platform)."""
    devices = (device,) if device else ("cpu", "tpu")
    rows: List[dict] = []
    cases = []
    for dev in devices:
        cases += [
            SortSpec(op="topk", lengths=(256,), k=8, batch=64, device=dev),
            SortSpec(op="topk", lengths=(32_000,), k=64, batch=8, device=dev),
            SortSpec(op="topk", lengths=(32_000,), k=64, batch=8, device=dev,
                     sharded=True),
            SortSpec(op="merge", lengths=(512, 512), batch=8, device=dev),
            SortSpec(op="merge", lengths=(7, 5), batch=8, device=dev),
            SortSpec(op="merge", lengths=(100_000, 100_000), device=dev),
            SortSpec(op="merge", lengths=(512, 512), batch=8, device=dev,
                     has_payload=True),
            SortSpec(op="merge_k", lengths=(64,) * 4, batch=8, device=dev),
            SortSpec(op="merge_k", lengths=(50_000,) * 4, device=dev),
            SortSpec(op="merge_k", lengths=(50_000,) * 4, device=dev,
                     sharded=True),
            SortSpec(op="sort", lengths=(1024,), batch=8, device=dev),
            SortSpec(op="sort", lengths=(1 << 20,), batch=8, device=dev,
                     sharded=True),
            SortSpec(op="median", lengths=(7, 7, 7), batch=8, device=dev),
            # segmented (CSR ragged) rows: MoE variable-capacity dispatch
            # and continuous-batching mixed-k vocab top-k
            SortSpec(op="sort", lengths=(168,), batch=4, device=dev,
                     segment_offsets=((0, 3, 40, 41, 168),)),
            SortSpec(op="topk", lengths=(96,), k=8, batch=3, device=dev,
                     segment_offsets=((0, 32, 64, 96),)),
            SortSpec(op="merge", lengths=(12, 20), batch=2, device=dev,
                     segment_offsets=((0, 5, 12), (0, 16, 20))),
        ]
    for spec in cases:
        dec = plan(spec)
        rows.append({
            "op": spec.op,
            "problem": spec.describe(),
            "sharded": spec.sharded,
            "payload": spec.has_payload,
            "segments": spec.n_segments,
            "backend": dec.backend,
            "detail": dec.detail,
            "reason": dec.reason,
            "source": dec.source,
            "network": dec.network,
            "measured_us": dec.measured_us,
            "tuned_us": _tuned_us(spec),
        })
    return rows
