"""Planner-driven backend selection for the unified sort ops.

``plan(spec, par)`` maps a :class:`~repro.api.spec.SortSpec` to a
:class:`Decision` — which backend runs the problem and why. The rules lean
on :mod:`repro.streaming.planner` (the paper's comparator cost model plus
the VMEM budget from DESIGN.md §2), the live JAX platform, and the offered
sharding, so callers state *what* to sort and this module picks *how* —
the one-abstraction-many-realizations stance of the merge literature
(FLiMS, Merge Path) applied to our device family.

The decision table (DESIGN.md §9):

  op       condition                                  backend    detail
  -------  -----------------------------------------  ---------  -----------
  topk     TP-sharded vocab (Parallelism + divisible) sharded    tree_topk
  topk     TPU, axis > 512                            pallas     vocab_topk
  topk     TPU, axis <= 512                           pallas     router_topk
  topk     otherwise (CPU/GPU hosts)                  schedule   blockwise
  merge    payload / stable (perm needed)             schedule   payload
  merge    ragged lengths (no common column count)    schedule   ragged
  merge    working set past the VMEM budget           streaming  chunked
  merge    TPU, fits VMEM                             pallas     loms_merge2
  merge    otherwise                                  schedule   loms_2way
  merge_k  same ladder as merge                       ...        kway/chunked
  sort     always (no Pallas full-sort kernel yet)    schedule   merge_tree
  median   TPU + equal odd lists, no perm             pallas     kway_median
  median   otherwise                                  schedule   loms_median

Explicit ``backend=`` hints skip the ladder but are still validated against
the backend's capability predicate, so impossible asks fail loudly instead
of silently computing the wrong thing.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax.numpy as jnp

from repro.kernels.topk import ROUTER_TOPK_MAX  # noqa: F401  (re-exported)

from .registry import get_backend
from .spec import BACKEND_AUTO, SortSpec


@dataclasses.dataclass(frozen=True)
class Decision:
    """One routing outcome: backend name, kernel detail, human reason."""

    backend: str
    detail: str = ""
    reason: str = ""


def _merge2_fits_vmem(spec: SortSpec) -> bool:
    from repro.streaming.planner import fits_vmem

    m, n = spec.lengths[0], sum(spec.lengths[1:])
    return fits_vmem(m, n, dtype=jnp.dtype(spec.dtype))


def _kway_fits_vmem(spec: SortSpec) -> bool:
    # the schedule-driven k-way kernel materializes the cross-list
    # comparison cloud: total^2 f32 per batch row (planner plan_chunked_k)
    from repro.streaming.planner import vmem_budget

    return spec.total * spec.total * 4 <= vmem_budget()


def plan(spec: SortSpec, par=None) -> Decision:
    """Resolve the backend for one problem. Pure function of (spec, par)."""
    if spec.backend != BACKEND_AUTO:
        be = get_backend(spec.backend)
        if not be.supports(spec):
            raise ValueError(
                f"backend {spec.backend!r} cannot run {spec.describe()} "
                f"(payload/stable={spec.needs_perm}, network={spec.network!r})"
            )
        return Decision(spec.backend, detail="explicit", reason="caller override")

    if spec.op == "topk":
        if spec.sharded:
            return Decision(
                "sharded", "tree_topk",
                "TP-sharded vocab: log-depth merge reduction over the mesh axis",
            )
        if spec.device == "tpu":
            if spec.total > ROUTER_TOPK_MAX:
                return Decision(
                    "pallas", "vocab_topk",
                    f"TPU, axis {spec.total} > {ROUTER_TOPK_MAX}: two-phase "
                    "block kernel + truncated merge levels",
                )
            return Decision(
                "pallas", "router_topk",
                f"TPU, axis {spec.total} <= {ROUTER_TOPK_MAX}: single-kernel "
                "blockwise top-k",
            )
        return Decision(
            "schedule", "blockwise_topk",
            f"{spec.device or 'non-TPU'} host: pure-JAX truncated-merge tree",
        )

    if spec.op == "sort":
        return Decision(
            "schedule", "loms_merge_tree",
            "full sort = 2-sorter pairs + LOMS merge tree (no Pallas "
            "full-sort kernel yet)",
        )

    if spec.op == "median":
        if spec.device == "tpu" and get_backend("pallas").supports(spec):
            return Decision("pallas", "kway_median", "TPU, equal odd lists")
        return Decision("schedule", "loms_median", "schedule executor median")

    # merge / merge_k
    if spec.needs_perm:
        return Decision(
            "schedule", "payload",
            "payload/stable needs the permutation-carrying executor",
        )
    if spec.network != "loms":
        # pallas/streaming realize the LOMS devices only; an explicit
        # Batcher/MWMS/tree ask must not be silently swapped for LOMS
        return Decision(
            "schedule", "network",
            f"non-default network {spec.network!r}: schedule executor",
        )
    if spec.op == "merge":
        if spec.ragged2:
            return Decision(
                "schedule", "ragged",
                "no common column count divides both lists: hole-y setup "
                "array, executor handles it",
            )
        if not _merge2_fits_vmem(spec):
            return Decision(
                "streaming", "chunked_merge",
                "working set past the VMEM budget: fixed-tile carry-buffer "
                "pipeline",
            )
        if spec.device == "tpu":
            return Decision("pallas", "loms_merge2", "TPU, fits VMEM")
        return Decision(
            "schedule", "loms_2way", f"{spec.device or 'non-TPU'} host"
        )
    # merge_k
    if not _kway_fits_vmem(spec):
        return Decision(
            "streaming", "chunked_merge_k",
            "comparison cloud past the VMEM budget: merge-path tiled pipeline",
        )
    if spec.device == "tpu":
        return Decision("pallas", "kway_merge", "TPU, fits VMEM")
    return Decision("schedule", "loms_kway", f"{spec.device or 'non-TPU'} host")


def decision_table(device: Optional[str] = None) -> List[dict]:
    """Representative routing grid for docs and the dispatch benchmark."""
    devices = (device,) if device else ("cpu", "tpu")
    rows: List[dict] = []
    cases = []
    for dev in devices:
        cases += [
            SortSpec(op="topk", lengths=(256,), k=8, batch=64, device=dev),
            SortSpec(op="topk", lengths=(32_000,), k=64, batch=8, device=dev),
            SortSpec(op="topk", lengths=(32_000,), k=64, batch=8, device=dev,
                     sharded=True),
            SortSpec(op="merge", lengths=(512, 512), batch=8, device=dev),
            SortSpec(op="merge", lengths=(7, 5), batch=8, device=dev),
            SortSpec(op="merge", lengths=(100_000, 100_000), device=dev),
            SortSpec(op="merge", lengths=(512, 512), batch=8, device=dev,
                     has_payload=True),
            SortSpec(op="merge_k", lengths=(64,) * 4, batch=8, device=dev),
            SortSpec(op="merge_k", lengths=(50_000,) * 4, device=dev),
            SortSpec(op="sort", lengths=(1024,), batch=8, device=dev),
            SortSpec(op="median", lengths=(7, 7, 7), batch=8, device=dev),
        ]
    for spec in cases:
        dec = plan(spec)
        rows.append({
            "op": spec.op,
            "problem": spec.describe(),
            "sharded": spec.sharded,
            "payload": spec.has_payload,
            "backend": dec.backend,
            "detail": dec.detail,
            "reason": dec.reason,
        })
    return rows
