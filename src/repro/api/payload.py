"""Axis canonicalization and pytree payload plumbing for the unified API.

Every backend operates on canonical 2-D problems — ``(batch, length)`` with
the sort axis last and ascending order. This module supplies the
translation: moving an arbitrary ``axis`` to the back, flattening leading
dims, gathering arbitrary pytree payloads through the permutation a backend
returns, and the lexicographic (value, position) tie-stabilization pass
that implements ``stable=True`` on top of any backend.

Payload leaves may carry extra *trailing* feature dims beyond the value
array's shape (e.g. sorting tokens that carry embeddings): the permutation
broadcasts across them.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def canonical_axis(axis: int, ndim: int) -> int:
    ax = axis + ndim if axis < 0 else axis
    if not 0 <= ax < ndim:
        raise ValueError(f"axis {axis} out of range for ndim {ndim}")
    return ax


def to_batched_last(x: jnp.ndarray, axis: int) -> Tuple[jnp.ndarray, Tuple[int, ...]]:
    """Move ``axis`` last and flatten the rest -> ((B, L), lead shape)."""
    ax = canonical_axis(axis, x.ndim)
    xm = jnp.moveaxis(x, ax, -1)
    lead = xm.shape[:-1]
    return xm.reshape((-1, xm.shape[-1])), lead


def from_batched_last(
    x2: jnp.ndarray, lead: Tuple[int, ...], axis: int, ndim: int
) -> jnp.ndarray:
    """Inverse of :func:`to_batched_last` (length along the axis may differ,
    e.g. after a merge grew it or a top-k truncated it)."""
    ax = canonical_axis(axis, ndim)
    xm = x2.reshape(lead + (x2.shape[-1],))
    return jnp.moveaxis(xm, -1, ax)


def take_payload_tree(tree, perm: jnp.ndarray, axis: int, ndim: int):
    """Gather every leaf of ``tree`` at ``perm`` along ``axis``.

    ``perm`` has the shape of the *output* values array (ndim dims) and
    holds positions along ``axis`` of the input leaves. Leaves must match
    the value array's shape on its first ``ndim`` dims; extra trailing dims
    ride along (the permutation broadcasts across them). Negative positions
    (top-k pad sentinels) clamp to 0 — their values are sentinels anyway.
    """
    ax = canonical_axis(axis, ndim)
    safe = jnp.where(perm < 0, 0, perm)

    def take_leaf(leaf):
        assert leaf.ndim >= ndim, (leaf.shape, ndim)
        lm = jnp.moveaxis(leaf, ax, ndim - 1)
        idx = jnp.moveaxis(safe, ax, ndim - 1)
        if lm.ndim > ndim:  # broadcast over trailing feature dims
            idx = idx.reshape(idx.shape + (1,) * (lm.ndim - ndim))
        out = jnp.take_along_axis(lm, idx, axis=ndim - 1)
        return jnp.moveaxis(out, ndim - 1, ax)

    return jax.tree.map(take_leaf, tree)


def concat_payload_trees(trees, axis: int, ndim: int):
    """Concatenate per-list payload pytrees along the sort axis (the merge
    analog of ``concat(lists)``); structures must match across lists."""
    ax = canonical_axis(axis, ndim)
    return jax.tree.map(lambda *leaves: jnp.concatenate(leaves, axis=ax), *trees)


#: largest last-axis size stabilized with the oblivious comparison cloud;
#: beyond it the O(L^2) matrix would dwarf the sort itself, so the pass
#: switches to a run-id lexsort (same result, not oblivious).
STABILIZE_CLOUD_MAX = 1024


def stabilize_ties(
    vals: jnp.ndarray, perm: jnp.ndarray, descending: bool = False
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Reorder equal-value runs by ascending original position.

    Backends are per-primitive stable but the multi-stage LOMS routing does
    not preserve global input order among equal keys; this pass restores
    the index-augmented tie-break the API promises for ``stable=True``.
    ``vals`` is already value-sorted — only positions within equal-value
    runs move.

    Up to ``STABILIZE_CLOUD_MAX`` elements this is a depth-1 N-sorter with
    the lexicographic (value, position) comparison cloud — oblivious,
    O(L^2) comparators, matching the paper's devices. Past that, the cloud
    itself would be the memory bottleneck, so the pass switches to sorting
    ``perm`` keyed by the equal-value run id (O(L log L), identical
    output, not oblivious).

    Negative positions are top-k pad sentinels, not real inputs: within a
    tie run they order *after* every real index (a masked -inf logit that
    ties the dtype-min pad must not be displaced by it).
    """
    pos = jnp.where(perm < 0, jnp.iinfo(jnp.int32).max, perm)
    if vals.shape[-1] > STABILIZE_CLOUD_MAX:
        # run id increments whenever the (sorted) value changes, so it is
        # ascending along the axis in both directions; lexsort by
        # (run, position) moves only within-tie positions.
        changed = vals[..., 1:] != vals[..., :-1]
        run = jnp.cumsum(
            jnp.concatenate(
                [jnp.zeros_like(changed[..., :1]), changed], axis=-1
            ).astype(jnp.int32), axis=-1)
        order = jnp.lexsort((pos, run), axis=-1)
        return (jnp.take_along_axis(vals, order, axis=-1),
                jnp.take_along_axis(perm, order, axis=-1))
    v_i, v_j = vals[..., :, None], vals[..., None, :]
    p_i, p_j = pos[..., :, None], pos[..., None, :]
    if descending:
        before = (v_j > v_i) | ((v_j == v_i) & (p_j < p_i))
    else:
        before = (v_j < v_i) | ((v_j == v_i) & (p_j < p_i))
    rank = before.sum(axis=-1).astype(jnp.int32)
    out_v = jnp.put_along_axis(jnp.zeros_like(vals), rank, vals, axis=-1,
                               inplace=False)
    out_p = jnp.put_along_axis(jnp.zeros_like(perm), rank, perm, axis=-1,
                               inplace=False)
    return out_v, out_p
