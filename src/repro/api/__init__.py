"""repro.api — the unified sort dispatch layer (DESIGN.md §9).

One namespace over every merge/sort realization in the repo: callers state
*what* to sort (``merge``/``merge_k``/``sort``/``topk``/``median_of_lists``
with ``axis``/``descending``/``stable``/pytree ``payload``) and the
planner-driven dispatcher picks *how* (schedule executor, Pallas kernel,
chunked streaming pipeline, or the device-tree sharded reduction). The
same functions are re-exported at the top level: ``repro.topk(...)``.
"""
from .dispatch import Decision, ROUTER_TOPK_MAX, decision_table, plan  # noqa: F401
from .fused import fused_enabled, set_fused_enabled  # noqa: F401
from .ops import (  # noqa: F401
    median_of_lists,
    merge,
    merge_k,
    segment_argmax,
    segment_merge,
    segment_sort,
    segment_topk,
    sort,
    topk,
)
from .registry import (  # noqa: F401
    Backend,
    backend_names,
    get_backend,
    register_backend,
)
from .spec import SortSpec  # noqa: F401
