"""Backend registry: pluggable realizations of the unified sort ops.

Every backend is a :class:`Backend` — a name, a capability predicate over
:class:`~repro.api.spec.SortSpec`, and one adapter per op it implements.
Adapters all speak the same canonical calling convention, so the dispatch
layer (and any future backend: a GPU Pallas port, a ``jax.lax.sort``
wrapper, an FPGA bridge) plugs in without touching the public ops:

  merge(a, b, *, spec, pos=None)        -> (out, perm | None)
  merge_k(lists, *, spec, pos=None)     -> (out, perm | None)
  sort(x, *, spec, pos=None)            -> (out, perm | None)
  topk(x, k, *, spec, par=None, block=None) -> (vals desc, idx)
  median(lists, *, spec)                -> out

Inputs are canonical 2-D ``(batch, length)`` problems, sort axis last,
ascending (the ops layer handles axis moves, descending flips, stability,
and payload gathers). ``pos`` is the int32 position payload to thread
through the permutation when the caller needs it; a backend that cannot
carry it must say so in ``supports``. When the caller offers a
:class:`~repro.parallel.sharding.Parallelism`, the ops layer forwards it
as a ``par=`` keyword to merge/merge_k/sort adapters too — the built-ins
all accept it (and ignore it except ``sharded``); third-party backends
only need the keyword if they are used together with ``par``.

Built-in backends: ``schedule`` (pure-JAX executor — runs everything),
``pallas`` (TPU kernels), ``streaming`` (chunked pipelines), ``sharded``
(distributed sample-sort / merge plus device-tree top-k over a mesh
axis), ``lax`` (XLA reference, explicit opt-in only — never chosen by
auto).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.resilience.failpoints import failpoint
from .spec import SortSpec


@dataclasses.dataclass(frozen=True)
class Backend:
    name: str
    run: Mapping[str, Callable]  # op name -> adapter
    supports: Callable[[SortSpec], bool]
    description: str = ""
    #: whether the backend can run ``spec`` as a fused single launch —
    #: key transform, payload lanes and ordering all inside the kernel
    #: (the ops layer then skips its XLA-level pre/post passes and calls
    #: the fused entry points in :mod:`repro.api.fused`)
    supports_fused: Callable[[SortSpec], bool] = lambda spec: False


_REGISTRY: Dict[str, Backend] = {}


def register_backend(backend: Backend, overwrite: bool = False) -> None:
    """Add a backend to the registry (``overwrite=True`` to replace)."""
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def backend_names():
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# schedule — the pure-JAX executor; runs every op, carries payloads
# ---------------------------------------------------------------------------


def _sched_merge(a, b, *, spec, pos=None, par=None):
    from . import schedules

    failpoint("executor.run.merge")

    if pos is None:
        return schedules.merge(a, b, kind=spec.network), None
    return schedules.merge(a, b, kind=spec.network, payload=pos)


def _sched_merge_k(lists, *, spec, pos=None, par=None):
    from . import schedules

    failpoint("executor.run.merge_k")

    if pos is None:
        return schedules.merge_k(lists, kind=spec.network), None
    return schedules.merge_k(lists, kind=spec.network, payload=pos)


def _sched_sort(x, *, spec, pos=None, par=None):
    from . import schedules

    failpoint("executor.run.sort")

    kind = spec.network if spec.network != "batcher-bitonic" else "bitonic"
    if pos is None:
        return schedules.sort(x, kind=kind), None
    return schedules.sort(x, kind=kind, payload=pos)


def _sched_topk(x, k, *, spec, par=None, block=None):
    from . import schedules

    failpoint("executor.run.topk")

    return schedules.topk(x, k, block=block or 0)


def _sched_median(lists, *, spec):
    from . import schedules

    failpoint("executor.run.median")

    kind = "mwms" if spec.network == "mwms" else "loms"
    return schedules.median_of_lists(lists, kind=kind)


register_backend(Backend(
    name="schedule",
    run={"merge": _sched_merge, "merge_k": _sched_merge_k, "sort": _sched_sort,
         "topk": _sched_topk, "median": _sched_median},
    supports=lambda spec: spec.segment_offsets is None,
    description="pure-JAX schedule executor (any shape/op, payload-capable, "
                "GSPMD/shard_map-safe)",
))


# ---------------------------------------------------------------------------
# pallas — the TPU kernels (interpret mode elsewhere); values only
# ---------------------------------------------------------------------------


def _pallas_merge(a, b, *, spec, pos=None, par=None):
    assert pos is None
    from repro.kernels.loms_merge import loms_merge2_pallas
    from repro.streaming.planner import plan_merge2

    plan = plan_merge2(a.shape[-1], b.shape[-1], batch=a.shape[0], dtype=a.dtype)
    if plan.kind != "loms":  # ragged hole-y layout: executor fallback
        from . import schedules

        return schedules.merge(a, b), None
    return loms_merge2_pallas(
        a, b, network=plan.network, n_cols=plan.n_cols,
        block_batch=plan.block_batch, use_mxu=plan.use_mxu,
    ), None


def _pallas_merge_k(lists, *, spec, pos=None, par=None):
    assert pos is None
    from repro.kernels.ops import merge_k as kernel_merge_k

    return kernel_merge_k(lists), None


def _pallas_sort(x, *, spec, pos=None, par=None):
    assert pos is None
    from repro.kernels.ops import sort as kernel_sort

    return kernel_sort(x), None


def _pallas_topk(x, k, *, spec, par=None, block=None):
    from repro.kernels.ops import topk as kernel_topk

    return kernel_topk(x, k, block=block)


def _pallas_median(lists, *, spec):
    from repro.kernels.ops import median_k

    return median_k(lists)


def _pallas_fused(spec: SortSpec) -> bool:
    from .fused import fused_eligible

    return fused_eligible(spec)


def _pallas_supports(spec: SortSpec) -> bool:
    if spec.network not in ("loms",) or spec.segment_offsets is not None:
        return False
    if spec.op == "topk":
        return True  # indices are native; payload/stable ride them
    if spec.op == "sort" or spec.needs_perm:
        # the fused single-launch kernels carry keys + payload lanes in
        # VMEM; stable / ragged / over-VMEM specs stay on the executor
        return _pallas_fused(spec)
    if spec.op == "median":  # loms_median wants equal odd-length lists
        return len(set(spec.lengths)) == 1 and spec.lengths[0] % 2 == 1
    return True


register_backend(Backend(
    name="pallas",
    run={"merge": _pallas_merge, "merge_k": _pallas_merge_k,
         "sort": _pallas_sort, "topk": _pallas_topk,
         "median": _pallas_median},
    supports=_pallas_supports,
    supports_fused=_pallas_fused,
    description="Pallas TPU kernels (interpret mode off-TPU); fused "
                "single-launch sort/merge with in-kernel key transform and "
                "VMEM payload lanes, index-carrying top-k",
))


# ---------------------------------------------------------------------------
# streaming — chunked pipelines for inputs past the VMEM budget
# ---------------------------------------------------------------------------


def _streaming_merge(a, b, *, spec, pos=None, par=None):
    assert pos is None
    from repro.streaming import chunked_merge

    return chunked_merge(a, b), None


def _streaming_merge_k(lists, *, spec, pos=None, par=None):
    assert pos is None
    from repro.streaming import chunked_merge_k

    return chunked_merge_k(lists), None


register_backend(Backend(
    name="streaming",
    run={"merge": _streaming_merge, "merge_k": _streaming_merge_k},
    supports=lambda spec: (spec.op in ("merge", "merge_k")
                           and not spec.needs_perm
                           and spec.segment_offsets is None),
    description="chunked carry-buffer / merge-path pipelines; fixed working "
                "set for unbounded inputs",
))


# ---------------------------------------------------------------------------
# sharded — distributed sample-sort + device-tree top-k over a TP mesh axis
# ---------------------------------------------------------------------------


def _sharded_topk(x, k, *, spec, par=None, block=None):
    from repro.streaming.tree import tree_topk_for

    assert par is not None, "sharded backend needs a Parallelism"
    return tree_topk_for(par, x, k)


def _sharded_sort(x, *, spec, pos=None, par=None):
    from repro.parallel.dist_sort import sample_sort
    from repro.parallel.sharding import dist_sort_axis

    assert par is not None, "sharded backend needs a Parallelism"
    axis = dist_sort_axis(par, (x.shape[-1],))
    assert axis is not None, (x.shape, par.tp_size)
    return sample_sort(x, mesh=par.mesh, axis_name=axis, pos=pos)


def _sharded_merge_k(lists, *, spec, pos=None, par=None):
    from repro.parallel.dist_sort import sample_merge_k
    from repro.parallel.sharding import dist_sort_axis

    assert par is not None, "sharded backend needs a Parallelism"
    axis = dist_sort_axis(par, tuple(l.shape[-1] for l in lists))
    assert axis is not None, ([l.shape for l in lists], par.tp_size)
    return sample_merge_k(lists, mesh=par.mesh, axis_name=axis, pos=pos)


def _sharded_merge(a, b, *, spec, pos=None, par=None):
    return _sharded_merge_k(
        [a, b], spec=spec, pos=None if pos is None else list(pos), par=par)


def _sharded_supports(spec: SortSpec) -> bool:
    if spec.segment_offsets is not None:
        return False
    if spec.op == "topk":
        return spec.sharded
    # sample-sort realizes the LOMS family only; spec.sharded already
    # encodes that every list length divides the offered TP axis
    return (spec.op in ("merge", "merge_k", "sort") and spec.sharded
            and spec.network == "loms")


register_backend(Backend(
    name="sharded",
    run={"topk": _sharded_topk, "sort": _sharded_sort,
         "merge": _sharded_merge, "merge_k": _sharded_merge_k},
    supports=_sharded_supports,
    description="distributed sample-sort / k-way merge (shard_map PSRS: "
                "local LOMS sort, regular-sampling splitters, all_to_all, "
                "per-device merge) and log-depth tree top-k over the TP "
                "axis; data never gathers to one device",
))


# ---------------------------------------------------------------------------
# segmented — CSR ragged ops over size-class buckets
# ---------------------------------------------------------------------------
#
# Calling convention differs from the dense backends: adapters speak flat
# CSR ``(values, segment_offsets)`` problems — the CSR structure rides on
# ``spec.segment_offsets`` — and take the routing's ``use_kernel`` flag
# (bucketed class launches vs the per-segment XLA reference). The
# ``repro.segment_*`` entry points (ops.py) dispatch through these ``run``
# adapters like every dense op does through its backend's.


def _segmented_sort(values, *, spec, **kw):
    from repro.segmented.core import segment_sort_impl

    return segment_sort_impl(values, spec.segment_offsets[0], **kw)


def _segmented_merge(a, b, *, spec, **kw):
    from repro.segmented.core import segment_merge_impl

    offs = spec.segment_offsets
    return segment_merge_impl(a, b, offs[0], offs[1], **kw)


def _segmented_topk(values, k, *, spec, **kw):
    from repro.segmented.core import segment_topk_impl

    return segment_topk_impl(values, spec.segment_offsets[0], k, **kw)


def _segmented_argmax(values, *, spec, **kw):
    from repro.segmented.core import segment_argmax_impl

    return segment_argmax_impl(values, spec.segment_offsets[0], **kw)


def _segmented_supports(spec: SortSpec) -> bool:
    return (spec.segment_offsets is not None and not spec.stable
            and spec.op in ("sort", "merge", "topk"))


register_backend(Backend(
    name="segmented",
    run={"sort": _segmented_sort, "merge": _segmented_merge,
         "topk": _segmented_topk, "argmax": _segmented_argmax},
    supports=_segmented_supports,
    description="CSR ragged segment sort/merge/top-k: trace-time size-class "
                "bucketing, one fused Pallas launch per pow2 class, FLiMS "
                "grid-merge spill for over-tile segments, per-segment XLA "
                "reference fallback",
))


# ---------------------------------------------------------------------------
# lax — XLA reference implementations (explicit opt-in; never auto-picked)
# ---------------------------------------------------------------------------


def _lax_merge(a, b, *, spec, pos=None, par=None):
    return _lax_sort(jnp.concatenate([a, b], axis=-1), spec=spec, pos=(
        None if pos is None else jnp.concatenate([pos[0], pos[1]], axis=-1)))


def _lax_merge_k(lists, *, spec, pos=None, par=None):
    return _lax_sort(jnp.concatenate(list(lists), axis=-1), spec=spec, pos=(
        None if pos is None else jnp.concatenate(list(pos), axis=-1)))


def _lax_sort(x, *, spec, pos=None, par=None):
    if pos is None:
        return jnp.sort(x, axis=-1), None
    order = jnp.argsort(x, axis=-1, stable=True)
    return (jnp.take_along_axis(x, order, axis=-1),
            jnp.take_along_axis(pos, order, axis=-1))


def _lax_topk(x, k, *, spec, par=None, block=None):
    vals, idx = jax.lax.top_k(x, k)
    return vals, idx.astype(jnp.int32)


def _lax_median(lists, *, spec):
    x = jnp.sort(jnp.concatenate(list(lists), axis=-1), axis=-1)
    return x[..., x.shape[-1] // 2]


register_backend(Backend(
    name="lax",
    run={"merge": _lax_merge, "merge_k": _lax_merge_k, "sort": _lax_sort,
         "topk": _lax_topk, "median": _lax_median},
    supports=lambda spec: spec.segment_offsets is None,
    description="XLA sort/top_k reference (not oblivious; benchmarking and "
                "cross-checking only)",
))
