"""The unified sort namespace: ``repro.merge / merge_k / sort / topk / ...``.

One entry point per operation with uniform semantics across every backend:

* ``axis=`` — sort along any axis, not just the last;
* ``descending=`` — inputs/outputs ordered descending (merges expect the
  inputs pre-sorted in the same direction);
* ``stable=`` — index-augmented tie-break: equal values keep ascending
  input position (earlier list first for merges);
* ``payload=`` — an arbitrary pytree rides the permutation (leaves may
  carry extra trailing feature dims);
* ``backend=`` — ``"auto"`` routes through the planner
  (:mod:`repro.api.dispatch`); explicit names force a registered backend.

Callers state *what* to sort; the planner picks *how* — schedule executor,
Pallas kernel, chunked streaming pipeline, or the device-tree sharded
reduction — based on size, dtype, platform, and an optional
:class:`~repro.parallel.sharding.Parallelism`.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from .dispatch import plan
from .fused import fused_cfg_for, fused_merge_k, fused_sort, fused_topk
from .keys import decode_keys, encode_keys, has_key_transform
from .payload import (
    canonical_axis,
    concat_payload_trees,
    from_batched_last,
    stabilize_ties,
    take_payload_tree,
    to_batched_last,
)
from .registry import get_backend
from .spec import SortSpec
from repro.resilience.ladder import LadderSkip, run_ladder, rungs_for

__all__ = ["merge", "merge_k", "sort", "topk", "median_of_lists",
           "segment_sort", "segment_merge", "segment_topk", "segment_argmax"]


def _device() -> str:
    return jax.default_backend()


def _iota_rows(length: int, batch: int, reverse: bool, offset: int = 0):
    pos = jnp.arange(length, dtype=jnp.int32) + offset
    if reverse:
        pos = pos[::-1]
    return jnp.broadcast_to(pos, (batch, length))


def _encode_lists(flats, nan_policy: str):
    """NaN-policy pre-pass (repro.api.keys): floats become total-order
    int keys when nan_policy='last'. Returns (arrays, decode) — decode is
    None when no transform ran (identity)."""
    if nan_policy == "unsafe" or not has_key_transform(flats[0].dtype):
        return list(flats), None
    dtype = flats[0].dtype
    return [encode_keys(f) for f in flats], (lambda out: decode_keys(out, dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _decode_sorted(raw, out_keys, descending):
    """decode of sorted keys with the VJP of a value sort w.r.t. ``raw``.

    The bitcast decode has no meaningful derivative, so a plain decode
    would silently zero every gradient through values-only float sorts and
    merges. The primal is still the cheap decode; the backward pass
    recovers the sorting permutation with one stable argsort of the keys
    (the same tie convention as ``jnp.sort``'s own VJP) and scatters the
    cotangent back to the inputs."""
    return decode_keys(out_keys, raw.dtype)


def _decode_sorted_fwd(raw, out_keys, descending):
    return decode_keys(out_keys, raw.dtype), raw


def _decode_sorted_bwd(descending, raw, ct):
    order = jnp.argsort(encode_keys(raw), axis=-1, stable=True)
    if descending:
        order = order[..., ::-1]
    g = jnp.put_along_axis(jnp.zeros_like(raw), order, ct, axis=-1,
                           inplace=False)
    return g, None


_decode_sorted.defvjp(_decode_sorted_fwd, _decode_sorted_bwd)


@jax.custom_vjp
def _decode_median(raw, out_keys):
    """decode of the (B,) median keys with a real VJP w.r.t. (B, L) raw.

    Backward recovers which input held the median (stable argsort of the
    keys, middle position) and routes the cotangent there — same
    subgradient convention as differentiating through jnp.sort."""
    return decode_keys(out_keys, raw.dtype)


def _decode_median_fwd(raw, out_keys):
    return decode_keys(out_keys, raw.dtype), raw


def _decode_median_bwd(raw, ct):
    order = jnp.argsort(encode_keys(raw), axis=-1, stable=True)
    j = order[..., raw.shape[-1] // 2]
    lane = jnp.arange(raw.shape[-1])
    g = jnp.where(lane == j[..., None], ct[..., None], 0).astype(raw.dtype)
    return g, None


_decode_median.defvjp(_decode_median_fwd, _decode_median_bwd)


def _restore_values(out2, perm2, raw, decode, descending=False):
    """Map sorted keys back to float values.

    When the permutation is available, gather from the raw float input —
    bit-exact (modulo NaN canonicalization, which gather skips) and, unlike
    the bitcast decode, differentiable: gradients keep flowing into the
    selected entries (the MoE router trains through its top-k values).
    Negative entries are pad sentinels (top-k only): those slots keep the
    decoded sentinel value and carry no gradient. Without a permutation
    (values-only sorts/merges) the custom-VJP decode keeps the gradient
    path alive at zero forward cost."""
    if decode is None:
        return out2
    if perm2 is None:
        return _decode_sorted(raw, out2, descending)
    safe = jnp.where(perm2 < 0, 0, perm2)
    gathered = jnp.take_along_axis(raw, safe, axis=-1)
    return jnp.where(perm2 < 0, decode(out2), gathered)


def _dist_sharded(par, lens) -> bool:
    """Whether the offered Parallelism makes the spec sample-sortable."""
    if par is None:
        return False
    from repro.parallel.sharding import dist_sort_axis

    return dist_sort_axis(par, lens) is not None


def _fused_leaves(payload, ax: int, ndim: int):
    """Flatten a payload pytree to canonical (B, L[, F]) kernel lanes.

    Returns (lanes, rebuild): each leaf moves its sort axis to position
    ``ndim-1`` and folds any trailing feature dims into one lane axis —
    pure layout ops, no gathers. ``rebuild(pouts, out_len)`` inverts the
    layout on the kernel outputs and restores the pytree."""
    leaves, treedef = jax.tree.flatten(payload)
    lanes, shapes = [], []
    for leaf in leaves:
        assert leaf.ndim >= ndim, (leaf.shape, ndim)
        lm = jnp.moveaxis(leaf, ax, ndim - 1)
        lead, trail = lm.shape[:ndim], lm.shape[ndim:]
        feat = 1
        for t in trail:
            feat *= t
        l2 = lm.reshape((-1, lead[-1]) + ((feat,) if trail else ()))
        lanes.append(l2)
        shapes.append((lead, trail))

    def rebuild(pouts, out_len: int):
        outs = []
        for p2, (lead, trail) in zip(pouts, shapes):
            pm = p2.reshape(lead[:-1] + (out_len,) + trail)
            outs.append(jnp.moveaxis(pm, ndim - 1, ax))
        return jax.tree.unflatten(treedef, outs)

    return tuple(lanes), rebuild


def _segmented_degrade(spec, call, use_kernel: bool):
    """Kernel → reference degradation for the segmented backend.

    The per-segment XLA reference is the subsystem's own oracle, so when
    the bucketed kernel path fails (resilience on, auto-routed) the op
    re-runs with ``use_kernel=False`` and the failure feeds a breaker on
    the synthetic ``segmented_kernel`` rung — an open breaker then skips
    the kernel attempt outright until its cooldown probe."""
    from repro.resilience.breaker import breaker_for
    from repro.resilience.ladder import resilience_enabled, spec_class
    from .spec import BACKEND_AUTO

    if not use_kernel:
        return call(False)
    if not (resilience_enabled() and spec.backend == BACKEND_AUTO):
        return call(True)
    cls = spec_class(spec)
    br = breaker_for(spec.op, "segmented_kernel", cls, create=False)
    if br is not None and not br.allow():
        return call(False)
    try:
        result = call(True)
    except Exception as e:  # noqa: BLE001 — reference path is the oracle
        from repro.obs import metrics as obs_metrics
        from repro.obs import recorder as obs_recorder

        (br or breaker_for(spec.op, "segmented_kernel", cls)).record_failure()
        obs_metrics.counter("resilience.fallbacks").inc(
            op=spec.op, rung="segmented_kernel", cls=cls,
            err=type(e).__name__)
        obs_recorder.emit("fallback", f"{spec.op}/segmented_kernel/{cls}",
                          err=type(e).__name__)
        return call(False)
    if br is not None:
        br.record_success()
    return result


# ---------------------------------------------------------------------------
# merge / merge_k
# ---------------------------------------------------------------------------


def merge(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    axis: int = -1,
    descending: bool = False,
    stable: bool = False,
    payload=None,
    backend: str = "auto",
    network: str = "loms",
    par=None,
    nan_policy: str = "last",
):
    """Merge two lists sorted along ``axis`` into one sorted list.

    ``payload`` is a pair ``(tree_a, tree_b)`` of matching pytrees whose
    leaves ride the merge permutation. Returns the merged values, or
    ``(values, merged_payload_tree)`` when a payload is given.
    """
    return merge_k(
        [a, b], axis=axis, descending=descending, stable=stable,
        payload=payload, backend=backend, network=network, par=par,
        nan_policy=nan_policy,
    )


def merge_k(
    lists: Sequence[jnp.ndarray],
    *,
    axis: int = -1,
    descending: bool = False,
    stable: bool = False,
    payload=None,
    backend: str = "auto",
    network: str = "loms",
    par=None,
    nan_policy: str = "last",
):
    """k-way merge of lists sorted along ``axis``.

    ``payload`` is a sequence of pytrees (one per list, matching
    structures). Returns merged values, or ``(values, payload_tree)``.
    ``nan_policy="last"`` (default) orders float NaNs last like
    ``jnp.sort`` via the total-order key pre-pass; ``"unsafe"`` skips it
    (raw-float fast path — inputs must be finite and NaN-free).
    """
    lists = list(lists)
    assert len(lists) >= 2, "need at least two lists"
    ndim = lists[0].ndim
    ax = canonical_axis(axis, ndim)
    lens = tuple(int(x.shape[ax]) for x in lists)
    flats, lead = [], None
    for x in lists:
        f, ld = to_batched_last(x, ax)
        assert lead is None or ld == lead, [y.shape for y in lists]
        lead = ld
        flats.append(f)
    batch = flats[0].shape[0]
    if len({f.dtype for f in flats}) > 1:
        # mixed dtypes promoted up front: per-list key encoding at
        # different widths would produce incomparable keys (the pre-key
        # behavior promoted at the backend's concatenate anyway)
        ct = jnp.result_type(*flats)
        flats = [f.astype(ct) for f in flats]
    raw_flats = flats  # original floats: value restore gathers from these
    spec = SortSpec(
        op="merge" if len(lists) == 2 else "merge_k",
        lengths=lens, batch=batch, dtype=jnp.dtype(flats[0].dtype).name,
        axis=axis, descending=descending, stable=stable,
        has_payload=payload is not None, network=network, backend=backend,
        device=_device(), sharded=_dist_sharded(par, lens),
        nan_policy=nan_policy,
    )
    dec = plan(spec, par)

    def attempt(rung: str):
        if rung == "fused":
            # fused single-launch path: key transform, descending handling
            # and payload permutes all run inside the kernel (api.fused)
            cfg = fused_cfg_for(spec, batch, flats[0].dtype)
            if cfg is None:
                raise LadderSkip
            total = sum(lens)
            if payload is None:
                out2, _ = fused_merge_k(cfg, tuple(flats), ())
                return from_batched_last(out2, lead, ax, ndim)
            ptree = concat_payload_trees(list(payload), ax, ndim)
            lanes, rebuild = _fused_leaves(ptree, ax, ndim)
            out2, pouts = fused_merge_k(cfg, tuple(flats), lanes)
            return (from_batched_last(out2, lead, ax, ndim),
                    rebuild(pouts, total))
        be = get_backend(rung)
        enc, decode = _encode_lists(flats, nan_policy)
        run_kw = {} if par is None else {"par": par}

        if descending:  # descending-sorted inputs: reverse -> ascending
            enc = [f[:, ::-1] for f in enc]
        pos = None
        if spec.needs_perm:
            offs = [sum(lens[:i]) for i in range(len(lens))]
            pos = [_iota_rows(ln, batch, descending, off)
                   for ln, off in zip(lens, offs)]
        if spec.op == "merge":
            out2, perm2 = be.run["merge"](enc[0], enc[1], spec=spec,
                                          pos=None if pos is None else (pos[0], pos[1]),
                                          **run_kw)
        else:
            out2, perm2 = be.run["merge_k"](enc, spec=spec, pos=pos, **run_kw)
        if descending:
            out2 = out2[:, ::-1]
            perm2 = None if perm2 is None else perm2[:, ::-1]
        if stable:
            out2, perm2 = stabilize_ties(out2, perm2, descending=descending)
        raw_cat = (None if decode is None
                   else jnp.concatenate(raw_flats, axis=-1))
        out = from_batched_last(
            _restore_values(out2, perm2, raw_cat, decode, descending),
            lead, ax, ndim)
        if payload is None:
            return out
        ptree = concat_payload_trees(list(payload), ax, ndim)
        perm = from_batched_last(perm2, lead, ax, ndim)
        return out, take_payload_tree(ptree, perm, ax, ndim)

    return run_ladder(spec, rungs_for(spec, dec), attempt)


# ---------------------------------------------------------------------------
# sort
# ---------------------------------------------------------------------------


def sort(
    x: jnp.ndarray,
    *,
    axis: int = -1,
    descending: bool = False,
    stable: bool = False,
    payload=None,
    backend: str = "auto",
    network: str = "loms",
    par=None,
    nan_policy: str = "last",
):
    """Full sort of unsorted values along ``axis``.

    ``payload`` is a pytree whose leaves match ``x``'s shape (extra
    trailing dims allowed) and ride the sort permutation. Returns sorted
    values, or ``(values, payload_tree)``. ``nan_policy="last"``
    (default): float NaNs sort last, like ``jnp.sort``; ``"unsafe"``
    skips the key pre-pass (finite NaN-free inputs only). With a
    TP-sharded :class:`Parallelism` whose axis divides the length, large
    sorts route to the distributed sample-sort (parallel.dist_sort).
    """
    ndim = x.ndim
    ax = canonical_axis(axis, ndim)
    x2, lead = to_batched_last(x, ax)
    batch, n = x2.shape
    raw_x2 = x2  # original floats: value restore gathers from these
    spec = SortSpec(
        op="sort", lengths=(n,), batch=batch, dtype=jnp.dtype(x2.dtype).name,
        axis=axis, descending=descending, stable=stable,
        has_payload=payload is not None, network=network, backend=backend,
        device=_device(), sharded=_dist_sharded(par, (n,)),
        nan_policy=nan_policy,
    )
    dec = plan(spec, par)

    def attempt(rung: str):
        if rung == "fused":
            # fused single-launch path: the kernel encodes the total-order
            # keys on load, permutes payload lanes in VMEM, reverses for
            # descending and decodes on store — no XLA encode/decode/gather
            cfg = fused_cfg_for(spec, batch, x2.dtype)
            if cfg is None:
                raise LadderSkip
            if payload is None:
                out2, _ = fused_sort(cfg, x2, ())
                return from_batched_last(out2, lead, ax, ndim)
            lanes, rebuild = _fused_leaves(payload, ax, ndim)
            out2, pouts = fused_sort(cfg, x2, lanes)
            return (from_batched_last(out2, lead, ax, ndim),
                    rebuild(pouts, n))
        be = get_backend(rung)
        (enc,), decode = _encode_lists([x2], nan_policy)
        run_kw = {} if par is None else {"par": par}
        pos = _iota_rows(n, batch, False) if spec.needs_perm else None
        out2, perm2 = be.run["sort"](enc, spec=spec, pos=pos, **run_kw)
        if descending:  # ascending network sort, reversed read-out
            out2 = out2[:, ::-1]
            perm2 = None if perm2 is None else perm2[:, ::-1]
        if stable:
            out2, perm2 = stabilize_ties(out2, perm2, descending=descending)
        out = from_batched_last(
            _restore_values(out2, perm2, raw_x2, decode, descending),
            lead, ax, ndim)
        if payload is None:
            return out
        perm = from_batched_last(perm2, lead, ax, ndim)
        return out, take_payload_tree(payload, perm, ax, ndim)

    return run_ladder(spec, rungs_for(spec, dec), attempt)


# ---------------------------------------------------------------------------
# top-k
# ---------------------------------------------------------------------------


def topk(
    x: jnp.ndarray,
    k: int,
    *,
    axis: int = -1,
    descending: bool = True,
    stable: bool = False,
    payload=None,
    backend: str = "auto",
    block: Optional[int] = None,
    par=None,
    with_indices: bool = True,
    nan_policy: str = "last",
):
    """Top-k along ``axis``: largest ``k`` descending (default), or the
    smallest ``k`` ascending with ``descending=False``.

    Returns ``(values, indices)`` — indices are positions along ``axis``,
    int32, with ``-1`` marking pad-sentinel slots. A ``-1`` appears when
    ``k`` exceeds the real candidates, and can appear when a real value
    equals the dtype minimum (e.g. masked ``-inf`` logits) and ties the
    padding; with ``stable=True`` such sentinels order after every real
    index in the tie. With ``payload`` (a pytree shaped like ``x``),
    returns ``(values, indices, payload_tree)`` gathered at the winners.
    With a TP-sharded :class:`Parallelism` whose axis divides the vocab,
    ``backend="auto"`` routes to the device-tree reduction.

    ``nan_policy="last"`` (default): float NaNs rank above +inf in the
    descending output (the flipped jnp ascending order) and masked
    ``-inf`` logits stay genuine candidates with real indices;
    ``"unsafe"`` skips the key pre-pass (finite NaN-free inputs only).
    """
    ndim = x.ndim
    ax = canonical_axis(axis, ndim)
    x2, lead = to_batched_last(x, ax)
    batch, n = x2.shape
    assert 1 <= k <= n, (k, n)
    raw_x2 = x2  # original floats: value restore gathers from these
    sharded = False
    if par is not None and ax == ndim - 1 and ndim == 2:
        from repro.parallel.sharding import vocab_topk_axis

        sharded = vocab_topk_axis(par, n) is not None
    spec = SortSpec(
        op="topk", lengths=(n,), batch=batch, dtype=jnp.dtype(x2.dtype).name,
        k=k, axis=axis, descending=descending, stable=stable,
        has_payload=payload is not None, backend=backend, device=_device(),
        sharded=sharded, nan_policy=nan_policy,
    )
    if not descending:
        # bottom-k ascending: ascending sort prefix (executor path only)
        if backend not in ("auto", "schedule", "lax"):
            raise ValueError("descending=False supports backend auto|schedule|lax")
        be = get_backend("schedule" if backend == "auto" else backend)
        (enc,), decode = _encode_lists([x2], nan_policy)
        pos = _iota_rows(n, batch, False)
        out2, perm2 = be.run["sort"](enc, spec=spec, pos=pos)
        return _topk_finish(out2[:, :k], perm2[:, :k], decode, raw_x2,
                            lead, ax, ndim, stable, descending, payload,
                            with_indices)

    dec = plan(spec, par)

    def attempt(rung: str):
        if rung == "fused":
            cfg = (fused_cfg_for(spec, batch, x2.dtype)
                   if not stable else None)
            if cfg is None:
                raise LadderSkip
            # fused: key transform inside the kernels, values come back
            # decoded — skip the XLA encode and the gather-restore
            vals2, idx2 = fused_topk(cfg, x2)
            return _topk_finish(vals2, idx2, None, raw_x2, lead, ax, ndim,
                                stable, descending, payload, with_indices)
        be = get_backend(rung)
        (enc,), decode = _encode_lists([x2], nan_policy)
        vals2, idx2 = be.run["topk"](enc, k, spec=spec, par=par, block=block)
        return _topk_finish(vals2, idx2.astype(jnp.int32), decode, raw_x2,
                            lead, ax, ndim, stable, descending, payload,
                            with_indices)

    return run_ladder(spec, rungs_for(spec, dec), attempt)


def _topk_finish(vals2, idx2, decode, raw_x2, lead, ax, ndim, stable,
                 descending, payload, with_indices):
    """Shared top-k post-pass: tie stabilization, value restore, axis
    un-flattening, payload gather."""
    if stable:
        vals2, idx2 = stabilize_ties(vals2, idx2, descending=descending)
    vals = from_batched_last(_restore_values(vals2, idx2, raw_x2, decode),
                             lead, ax, ndim)
    idx = from_batched_last(idx2, lead, ax, ndim)
    if payload is not None:
        ptree = take_payload_tree(payload, idx, ax, ndim)
        return vals, idx, ptree
    if with_indices:
        return vals, idx
    return vals


# ---------------------------------------------------------------------------
# segmented (CSR ragged) ops
# ---------------------------------------------------------------------------
#
# Flat ``(values, segment_offsets)`` problems with *static* CSR offsets:
# segment ``s`` is ``values[offsets[s]:offsets[s+1]]`` and every op applies
# per segment. The planner routes these to the segmented backend — trace-
# time size-class bucketing, one fused Pallas launch per pow2 length class
# (DESIGN.md §12) — or to the per-segment XLA reference off-TPU / under
# the ``REPRO_DISABLE_SEGMENTED`` escape hatch.


def _segmented_call(spec, par=None):
    """plan() a segmented spec; returns the backend and the decision's
    ``use_kernel`` flag (bucketed class launches vs XLA reference)."""
    dec = plan(spec, par)
    assert dec.backend == "segmented", dec
    return get_backend(dec.backend), dec.detail != "reference"


def segment_sort(
    values: jnp.ndarray,
    segment_offsets,
    *,
    descending: bool = False,
    payload=None,
    backend: str = "auto",
    nan_policy: str = "last",
):
    """Sort each CSR segment of ``values`` (1-D, flat) independently.

    ``segment_offsets`` are static ints (CSR row pointers, ``[0, ..., N]``)
    — they size the per-class networks at trace time. ``payload`` is a
    pytree whose leaves lead with the ``N`` axis and ride each segment's
    sort permutation. Returns sorted values in the same CSR layout, or
    ``(values, payload_tree)``. Empty and length-1 segments are exact
    no-ops (they never reach a network)."""
    from repro.segmented.bucketing import normalize_offsets

    offs = normalize_offsets(segment_offsets)
    values = jnp.asarray(values)
    spec = SortSpec(
        op="sort", lengths=(offs[-1],), batch=max(len(offs) - 1, 1),
        dtype=jnp.dtype(values.dtype).name, descending=descending,
        has_payload=payload is not None, backend=backend, device=_device(),
        nan_policy=nan_policy, segment_offsets=(offs,),
    )
    be, use_kernel = _segmented_call(spec)
    out, _, ptree = _segmented_degrade(
        spec, lambda uk: be.run["sort"](
            values, spec=spec, descending=descending, payload=payload,
            nan_policy=nan_policy, use_kernel=uk),
        use_kernel)
    return out if payload is None else (out, ptree)


def segment_merge(
    a: jnp.ndarray,
    b: jnp.ndarray,
    offsets_a,
    offsets_b,
    *,
    descending: bool = False,
    payload=None,
    backend: str = "auto",
    nan_policy: str = "last",
):
    """Merge per-segment sorted runs: output segment ``s`` is the sorted
    union of ``a``'s and ``b``'s segment ``s`` (both CSR, same segment
    count, any mixture of lengths — the paper's mixed-list-size claim).

    ``payload`` is a pair ``(tree_a, tree_b)`` riding the permutation.
    Returns ``(values, out_offsets)`` or ``(values, payload_tree,
    out_offsets)`` with ``out_offsets[s] = offsets_a[s] + offsets_b[s]``.
    """
    from repro.segmented.bucketing import normalize_offsets

    offs_a = normalize_offsets(offsets_a)
    offs_b = normalize_offsets(offsets_b)
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    spec = SortSpec(
        op="merge", lengths=(offs_a[-1], offs_b[-1]),
        batch=max(len(offs_a) - 1, 1), dtype=jnp.dtype(a.dtype).name,
        descending=descending, has_payload=payload is not None,
        backend=backend, device=_device(), nan_policy=nan_policy,
        segment_offsets=(offs_a, offs_b),
    )
    be, use_kernel = _segmented_call(spec)
    out, _, ptree, out_offs = _segmented_degrade(
        spec, lambda uk: be.run["merge"](
            a, b, spec=spec, descending=descending, payload=payload,
            nan_policy=nan_policy, use_kernel=uk),
        use_kernel)
    if payload is None:
        return out, out_offs
    return out, ptree, out_offs


def segment_topk(
    values: jnp.ndarray,
    segment_offsets,
    k,
    *,
    descending: bool = True,
    payload=None,
    backend: str = "auto",
    nan_policy: str = "last",
):
    """Per-segment top-k: the ``min(k_s, len_s)`` largest entries of each
    segment, descending (``descending=False``: smallest, ascending).

    ``k`` is one static int or one per segment — a continuous batch of
    mixed-k requests stays one launch per size class, each segment keeping
    its own prefix. Returns ``(values, idx, out_offsets)`` (or with a
    ``payload_tree`` before the offsets): CSR layout, ``idx`` =
    within-segment input positions, int32."""
    from repro.segmented.bucketing import normalize_offsets
    from repro.segmented.core import _normalize_ks

    offs = normalize_offsets(segment_offsets)
    values = jnp.asarray(values)
    ks = _normalize_ks(k, len(offs) - 1)
    spec = SortSpec(
        op="topk", lengths=(offs[-1],), batch=max(len(offs) - 1, 1),
        dtype=jnp.dtype(values.dtype).name, k=max(ks) if ks else 1,
        descending=descending, has_payload=payload is not None,
        backend=backend, device=_device(), nan_policy=nan_policy,
        segment_offsets=(offs,),
    )
    be, use_kernel = _segmented_call(spec)
    out, idx, ptree, out_offs = _segmented_degrade(
        spec, lambda uk: be.run["topk"](
            values, ks, spec=spec, descending=descending, payload=payload,
            nan_policy=nan_policy, use_kernel=uk),
        use_kernel)
    if payload is None:
        return out, idx, out_offs
    return out, idx, ptree, out_offs


def segment_argmax(
    values: jnp.ndarray,
    segment_offsets,
    *,
    backend: str = "auto",
    nan_policy: str = "last",
):
    """Per-segment argmax -> ``(vals (S,), idx (S,))``; empty segments
    yield the dtype minimum and index ``-1``."""
    from repro.segmented.bucketing import normalize_offsets

    offs = normalize_offsets(segment_offsets)
    values = jnp.asarray(values)
    spec = SortSpec(
        op="topk", lengths=(offs[-1],), batch=max(len(offs) - 1, 1),
        dtype=jnp.dtype(values.dtype).name, k=1, backend=backend,
        device=_device(), nan_policy=nan_policy, segment_offsets=(offs,),
    )
    be, use_kernel = _segmented_call(spec)
    return _segmented_degrade(
        spec, lambda uk: be.run["argmax"](values, spec=spec,
                                          nan_policy=nan_policy,
                                          use_kernel=uk),
        use_kernel)


# ---------------------------------------------------------------------------
# median
# ---------------------------------------------------------------------------


def median_of_lists(
    lists: Sequence[jnp.ndarray],
    *,
    axis: int = -1,
    backend: str = "auto",
    network: str = "loms",
    par=None,
    nan_policy: str = "last",
):
    """Median of k equal odd-length sorted lists (paper §V-A early exit)."""
    lists = list(lists)
    ndim = lists[0].ndim
    ax = canonical_axis(axis, ndim)
    lens = tuple(int(x.shape[ax]) for x in lists)
    flats, lead = [], None
    for x in lists:
        f, ld = to_batched_last(x, ax)
        assert lead is None or ld == lead
        lead = ld
        flats.append(f)
    if len({f.dtype for f in flats}) > 1:
        ct = jnp.result_type(*flats)
        flats = [f.astype(ct) for f in flats]
    flats_raw = flats  # originals: the median VJP recovers the argmedian
    flats, decode = _encode_lists(flats, nan_policy)
    spec = SortSpec(
        op="median", lengths=lens, batch=flats[0].shape[0],
        dtype=jnp.dtype(flats[0].dtype).name, axis=axis, network=network,
        backend=backend, device=_device(), nan_policy=nan_policy,
    )
    dec = plan(spec, par)

    def attempt(rung: str):
        if rung == "fused":
            raise LadderSkip  # no fused median kernel
        be = get_backend(rung)
        out2 = be.run["median"](flats, spec=spec)
        # scalar per batch row: restore the lead shape
        if decode is not None:
            out2 = _decode_median(jnp.concatenate(flats_raw, axis=-1), out2)
        return out2.reshape(lead)

    return run_ladder(spec, rungs_for(spec, dec), attempt)
