"""The unified sort namespace: ``repro.merge / merge_k / sort / topk / ...``.

One entry point per operation with uniform semantics across every backend:

* ``axis=`` — sort along any axis, not just the last;
* ``descending=`` — inputs/outputs ordered descending (merges expect the
  inputs pre-sorted in the same direction);
* ``stable=`` — index-augmented tie-break: equal values keep ascending
  input position (earlier list first for merges);
* ``payload=`` — an arbitrary pytree rides the permutation (leaves may
  carry extra trailing feature dims);
* ``backend=`` — ``"auto"`` routes through the planner
  (:mod:`repro.api.dispatch`); explicit names force a registered backend.

Callers state *what* to sort; the planner picks *how* — schedule executor,
Pallas kernel, chunked streaming pipeline, or the device-tree sharded
reduction — based on size, dtype, platform, and an optional
:class:`~repro.parallel.sharding.Parallelism`.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from .dispatch import plan
from .payload import (
    canonical_axis,
    concat_payload_trees,
    from_batched_last,
    stabilize_ties,
    take_payload_tree,
    to_batched_last,
)
from .registry import get_backend
from .spec import SortSpec

__all__ = ["merge", "merge_k", "sort", "topk", "median_of_lists"]


def _device() -> str:
    return jax.default_backend()


def _iota_rows(length: int, batch: int, reverse: bool, offset: int = 0):
    pos = jnp.arange(length, dtype=jnp.int32) + offset
    if reverse:
        pos = pos[::-1]
    return jnp.broadcast_to(pos, (batch, length))


# ---------------------------------------------------------------------------
# merge / merge_k
# ---------------------------------------------------------------------------


def merge(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    axis: int = -1,
    descending: bool = False,
    stable: bool = False,
    payload=None,
    backend: str = "auto",
    network: str = "loms",
    par=None,
):
    """Merge two lists sorted along ``axis`` into one sorted list.

    ``payload`` is a pair ``(tree_a, tree_b)`` of matching pytrees whose
    leaves ride the merge permutation. Returns the merged values, or
    ``(values, merged_payload_tree)`` when a payload is given.
    """
    return merge_k(
        [a, b], axis=axis, descending=descending, stable=stable,
        payload=payload, backend=backend, network=network, par=par,
    )


def merge_k(
    lists: Sequence[jnp.ndarray],
    *,
    axis: int = -1,
    descending: bool = False,
    stable: bool = False,
    payload=None,
    backend: str = "auto",
    network: str = "loms",
    par=None,
):
    """k-way merge of lists sorted along ``axis``.

    ``payload`` is a sequence of pytrees (one per list, matching
    structures). Returns merged values, or ``(values, payload_tree)``.
    """
    lists = list(lists)
    assert len(lists) >= 2, "need at least two lists"
    ndim = lists[0].ndim
    ax = canonical_axis(axis, ndim)
    lens = tuple(int(x.shape[ax]) for x in lists)
    flats, lead = [], None
    for x in lists:
        f, ld = to_batched_last(x, ax)
        assert lead is None or ld == lead, [y.shape for y in lists]
        lead = ld
        flats.append(f)
    batch = flats[0].shape[0]
    spec = SortSpec(
        op="merge" if len(lists) == 2 else "merge_k",
        lengths=lens, batch=batch, dtype=jnp.dtype(flats[0].dtype).name,
        axis=axis, descending=descending, stable=stable,
        has_payload=payload is not None, network=network, backend=backend,
        device=_device(),
    )
    dec = plan(spec, par)
    be = get_backend(dec.backend)

    if descending:  # descending-sorted inputs: reverse -> ascending problem
        flats = [f[:, ::-1] for f in flats]
    pos = None
    if spec.needs_perm:
        offs = [sum(lens[:i]) for i in range(len(lens))]
        pos = [_iota_rows(ln, batch, descending, off)
               for ln, off in zip(lens, offs)]
    opname = "merge" if spec.op == "merge" else "merge_k"
    if opname == "merge":
        out2, perm2 = be.run["merge"](flats[0], flats[1], spec=spec,
                                      pos=None if pos is None else (pos[0], pos[1]))
    else:
        out2, perm2 = be.run["merge_k"](flats, spec=spec, pos=pos)
    if descending:
        out2 = out2[:, ::-1]
        perm2 = None if perm2 is None else perm2[:, ::-1]
    if stable:
        out2, perm2 = stabilize_ties(out2, perm2, descending=descending)
    out = from_batched_last(out2, lead, ax, ndim)
    if payload is None:
        return out
    ptree = concat_payload_trees(list(payload), ax, ndim)
    perm = from_batched_last(perm2, lead, ax, ndim)
    return out, take_payload_tree(ptree, perm, ax, ndim)


# ---------------------------------------------------------------------------
# sort
# ---------------------------------------------------------------------------


def sort(
    x: jnp.ndarray,
    *,
    axis: int = -1,
    descending: bool = False,
    stable: bool = False,
    payload=None,
    backend: str = "auto",
    network: str = "loms",
    par=None,
):
    """Full sort of unsorted values along ``axis``.

    ``payload`` is a pytree whose leaves match ``x``'s shape (extra
    trailing dims allowed) and ride the sort permutation. Returns sorted
    values, or ``(values, payload_tree)``.
    """
    ndim = x.ndim
    ax = canonical_axis(axis, ndim)
    x2, lead = to_batched_last(x, ax)
    batch, n = x2.shape
    spec = SortSpec(
        op="sort", lengths=(n,), batch=batch, dtype=jnp.dtype(x.dtype).name,
        axis=axis, descending=descending, stable=stable,
        has_payload=payload is not None, network=network, backend=backend,
        device=_device(),
    )
    dec = plan(spec, par)
    be = get_backend(dec.backend)
    pos = _iota_rows(n, batch, False) if spec.needs_perm else None
    out2, perm2 = be.run["sort"](x2, spec=spec, pos=pos)
    if descending:  # ascending network sort, reversed read-out
        out2 = out2[:, ::-1]
        perm2 = None if perm2 is None else perm2[:, ::-1]
    if stable:
        out2, perm2 = stabilize_ties(out2, perm2, descending=descending)
    out = from_batched_last(out2, lead, ax, ndim)
    if payload is None:
        return out
    perm = from_batched_last(perm2, lead, ax, ndim)
    return out, take_payload_tree(payload, perm, ax, ndim)


# ---------------------------------------------------------------------------
# top-k
# ---------------------------------------------------------------------------


def topk(
    x: jnp.ndarray,
    k: int,
    *,
    axis: int = -1,
    descending: bool = True,
    stable: bool = False,
    payload=None,
    backend: str = "auto",
    block: Optional[int] = None,
    par=None,
    with_indices: bool = True,
):
    """Top-k along ``axis``: largest ``k`` descending (default), or the
    smallest ``k`` ascending with ``descending=False``.

    Returns ``(values, indices)`` — indices are positions along ``axis``,
    int32, with ``-1`` marking pad-sentinel slots. A ``-1`` appears when
    ``k`` exceeds the real candidates, and can appear when a real value
    equals the dtype minimum (e.g. masked ``-inf`` logits) and ties the
    padding; with ``stable=True`` such sentinels order after every real
    index in the tie. With ``payload`` (a pytree shaped like ``x``),
    returns ``(values, indices, payload_tree)`` gathered at the winners.
    With a TP-sharded :class:`Parallelism` whose axis divides the vocab,
    ``backend="auto"`` routes to the device-tree reduction.
    """
    ndim = x.ndim
    ax = canonical_axis(axis, ndim)
    x2, lead = to_batched_last(x, ax)
    batch, n = x2.shape
    assert 1 <= k <= n, (k, n)
    sharded = False
    if par is not None and ax == ndim - 1 and ndim == 2:
        from repro.parallel.sharding import vocab_topk_axis

        sharded = vocab_topk_axis(par, n) is not None
    spec = SortSpec(
        op="topk", lengths=(n,), batch=batch, dtype=jnp.dtype(x.dtype).name,
        k=k, axis=axis, descending=descending, stable=stable,
        has_payload=payload is not None, backend=backend, device=_device(),
        sharded=sharded,
    )
    if not descending:
        # bottom-k ascending: ascending sort prefix (executor path only)
        if backend not in ("auto", "schedule", "lax"):
            raise ValueError("descending=False supports backend auto|schedule|lax")
        be = get_backend("schedule" if backend == "auto" else backend)
        pos = _iota_rows(n, batch, False)
        out2, perm2 = be.run["sort"](x2, spec=spec, pos=pos)
        vals2, idx2 = out2[:, :k], perm2[:, :k]
    else:
        dec = plan(spec, par)
        be = get_backend(dec.backend)
        vals2, idx2 = be.run["topk"](x2, k, spec=spec, par=par, block=block)
        idx2 = idx2.astype(jnp.int32)
    if stable:
        vals2, idx2 = stabilize_ties(vals2, idx2, descending=descending)
    vals = from_batched_last(vals2, lead, ax, ndim)
    idx = from_batched_last(idx2, lead, ax, ndim)
    if payload is not None:
        ptree = take_payload_tree(payload, idx, ax, ndim)
        return vals, idx, ptree
    if with_indices:
        return vals, idx
    return vals


# ---------------------------------------------------------------------------
# median
# ---------------------------------------------------------------------------


def median_of_lists(
    lists: Sequence[jnp.ndarray],
    *,
    axis: int = -1,
    backend: str = "auto",
    network: str = "loms",
    par=None,
):
    """Median of k equal odd-length sorted lists (paper §V-A early exit)."""
    lists = list(lists)
    ndim = lists[0].ndim
    ax = canonical_axis(axis, ndim)
    lens = tuple(int(x.shape[ax]) for x in lists)
    flats, lead = [], None
    for x in lists:
        f, ld = to_batched_last(x, ax)
        assert lead is None or ld == lead
        lead = ld
        flats.append(f)
    spec = SortSpec(
        op="median", lengths=lens, batch=flats[0].shape[0],
        dtype=jnp.dtype(flats[0].dtype).name, axis=axis, network=network,
        backend=backend, device=_device(),
    )
    dec = plan(spec, par)
    be = get_backend(dec.backend)
    out2 = be.run["median"](flats, spec=spec)
    # scalar per batch row: restore the lead shape
    return out2.reshape(lead)
