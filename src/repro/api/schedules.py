"""Pure-JAX schedule-executor backend (the paper's devices as jnp).

This is the former ``repro.core.api`` implementation, now the "schedule"
backend of the unified dispatch layer: jit/vmap/pjit-safe compare-exchange
schedules with static shapes and no data-dependent control flow. The last
axis is always the sorted axis; leading axes broadcast (batch). It is the
only backend that runs every op, carries payloads through the permutation,
and traces under GSPMD/shard_map — the others (Pallas kernels, streaming
pipelines, device-tree) are faster realizations of subsets.

  merge(a, b)            2-way merge of two sorted lists (LOMS/S2MS/Batcher)
  merge_k(lists)         k-way merge (LOMS k-way / MWMS / 2-way tree)
  sort(x)                full sort (2-sorter pairs + LOMS merge tree, or
                         Batcher bitonic/OEMS, or single-stage rank sort)
  topk(x, k)             blockwise top-k via truncated LOMS merges
  median_of_lists(ls)    2-stage LOMS median (paper §V-A)
  median9(x)             3x3 median filter core (paper ref [19] use case)
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core import batcher as _batcher
from repro.core import loms as _loms
from repro.core import mwms as _mwms
from repro.core.networks import (
    Schedule,
    apply_schedule,
    apply_schedule_with_payload,
    rank_sort,
)

# ---------------------------------------------------------------------------
# schedule selection
# ---------------------------------------------------------------------------


def merge_schedule(m: int, n: int, kind: str = "loms", n_cols: int = 2) -> Schedule:
    if kind == "loms":
        return _loms.loms_2way(m, n, n_cols)
    if kind == "s2ms":
        # single-stage 2-way merge: one merge group over everything
        from repro.core.networks import Group, Stage

        return Schedule(
            name=f"s2ms_up{m}_dn{n}",
            size=m + n,
            setup_scatter=tuple(range(m + n)),
            output_gather=tuple(range(m + n)),
            stages=(Stage(groups=(Group(idx=tuple(range(m + n)), runs=(m, n)),)),),
            meta=(("kind", "s2ms"), ("lens", (m, n))),
        )
    if kind == "batcher-oe":
        return _batcher.oems_merge(m, n)
    if kind == "batcher-bitonic":
        return _batcher.bitonic_merge(m, n)
    raise ValueError(f"unknown merge kind {kind!r}")


def merge(
    a: jnp.ndarray,
    b: jnp.ndarray,
    kind: str = "loms",
    n_cols: int = 2,
    payload: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
):
    """Merge two sorted-ascending lists along the last axis."""
    m, n = a.shape[-1], b.shape[-1]
    sched = merge_schedule(m, n, kind, n_cols)
    x = jnp.concatenate([a, b], axis=-1)
    if payload is None:
        return apply_schedule(sched, x)
    p = jnp.concatenate([payload[0], payload[1]], axis=-1)
    return apply_schedule_with_payload(sched, x, p)


def merge_k(
    lists: Sequence[jnp.ndarray],
    kind: str = "loms",
    payload: Optional[Sequence[jnp.ndarray]] = None,
):
    """k-way merge of sorted lists. kind: loms | mwms | tree."""
    lens = tuple(int(l.shape[-1]) for l in lists)
    if kind in ("loms", "mwms"):
        sched = _loms.loms_kway(lens) if kind == "loms" else _mwms.mwms_kway(lens)
        x = jnp.concatenate(list(lists), axis=-1)
        if payload is None:
            return apply_schedule(sched, x)
        return apply_schedule_with_payload(
            sched, x, jnp.concatenate(list(payload), axis=-1)
        )
    if kind == "tree":  # binary tree of 2-way LOMS merges (prior-art pattern)
        items = list(lists)
        pls = list(payload) if payload is not None else None
        while len(items) > 1:
            nxt, npl = [], []
            for i in range(0, len(items) - 1, 2):
                if pls is None:
                    nxt.append(merge(items[i], items[i + 1]))
                else:
                    v, p = merge(items[i], items[i + 1], payload=(pls[i], pls[i + 1]))
                    nxt.append(v)
                    npl.append(p)
            if len(items) % 2:
                nxt.append(items[-1])
                if pls is not None:
                    npl.append(pls[-1])
            items, pls = nxt, (npl if pls is not None else None)
        return items[0] if payload is None else (items[0], pls[0])
    raise ValueError(f"unknown merge_k kind {kind!r}")


# ---------------------------------------------------------------------------
# full sort
# ---------------------------------------------------------------------------


def _dtype_max(dtype):
    d = jnp.dtype(dtype)
    if jnp.issubdtype(d, jnp.floating):
        return jnp.inf
    return jnp.iinfo(d).max


def _dtype_min(dtype):
    """Smallest value of ``dtype`` — NOT ``-_dtype_max``: negating the max
    is off by one for signed ints (min+1) and wraps for unsigned ints (a
    ``uint32`` pad of ``-max`` becomes 1, which sorts *above* genuine
    zeros and silently drops them from a top-k)."""
    d = jnp.dtype(dtype)
    if jnp.issubdtype(d, jnp.floating):
        return -jnp.inf
    return jnp.iinfo(d).min


def sort(x: jnp.ndarray, kind: str = "loms", payload: Optional[jnp.ndarray] = None):
    """Full ascending sort along the last axis of unsorted values.

    kind='loms': 2-sorter pair stage, then a LOMS 2-way merge tree with
    doubling runs — every level is a fixed 2-stage device (total depth
    1 + 2*ceil(log2(n/2)) vs Batcher's ~log^2/2). Non-power-of-two sizes are
    padded with +max sentinels and sliced back.
    kind='bitonic'|'oems': Batcher full sorts. kind='rank': single-stage
    rank sort (the N-sorter; O(n^2) comparators, depth 1).

    Non-power-of-two payload sorts ride a canonical position index through
    the network instead of the raw payload: a +max pad can tie a genuine
    dtype-max value, and only an out-of-range index identifies the pad —
    the valid prefix is recovered by mask (``stable_compact``), never by
    value, and the payload is gathered afterwards.
    """
    n = x.shape[-1]
    if n == 1:
        return x if payload is None else (x, payload)
    if kind == "rank":
        return rank_sort(x, payload)
    if kind not in ("loms", "bitonic", "oems"):
        raise ValueError(f"unknown sort kind {kind!r}")
    npad = 1 << (n - 1).bit_length()
    indexed = payload is not None and npad != n
    xp = _pad_to(x, npad)
    if indexed:
        pp = jnp.broadcast_to(jnp.arange(npad, dtype=jnp.int32), xp.shape)
    elif payload is not None:
        pp = payload
    else:
        pp = None
    if kind in ("bitonic", "oems"):
        sched = _batcher.bitonic_sort(npad) if kind == "bitonic" else _batcher.oems_sort(npad)
        if pp is None:
            xp = apply_schedule(sched, xp)
        else:
            xp, pp = apply_schedule_with_payload(sched, xp, pp)
    else:
        run = 1
        while run < npad:
            # view as rows of two sorted runs and LOMS-merge each pair
            shape = xp.shape[:-1] + (npad // (2 * run), 2 * run)
            xv = xp.reshape(shape)
            if pp is not None:
                pv = pp.reshape(shape)
                xv, pv = merge(
                    xv[..., :run], xv[..., run:], payload=(pv[..., :run], pv[..., run:])
                )
                pp = pv.reshape(pp.shape)
            else:
                xv = merge(xv[..., :run], xv[..., run:])
            xp = xv.reshape(xp.shape)
            run *= 2
    if payload is None:
        return xp[..., :n]
    if indexed:
        from repro.kernels.common import stable_compact

        xp, pp = stable_compact(pp < n, xp, pp)
        return xp[..., :n], jnp.take_along_axis(payload, pp[..., :n], axis=-1)
    return xp[..., :n], pp[..., :n]


def _pad_to(x: jnp.ndarray, n: int) -> jnp.ndarray:
    pad = n - x.shape[-1]
    if pad == 0:
        return x
    from repro.kernels.common import np_fill

    fill = np_fill(_dtype_max(x.dtype), x.dtype)
    pad_widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, pad_widths, constant_values=fill)


# ---------------------------------------------------------------------------
# top-k via truncated LOMS merges (the MoE-router / sampler primitive)
# ---------------------------------------------------------------------------


def topk(
    x: jnp.ndarray,
    k: int,
    block: int = 0,
    with_indices: bool = True,
):
    """Top-k (descending) along the last axis via blockwise oblivious merge.

    Split the axis into blocks; single-stage rank-sort each block descending;
    then reduce the per-block top-k sorted lists pairwise with *truncated*
    LOMS UP-k/DN-k merges (keep the top half). Depth = 1 + 2*ceil(log2(#blocks))
    stages, comparator count O(n*block + k^2 * n/block).

    Sentinel slots (the dtype-min padding out to a block multiple) carry
    index -1: a padded slot can tie with a real dtype-min element, and any
    in-range index would silently alias that element's position. The pad
    value is ``_dtype_min`` — negating ``_dtype_max`` is min+1 for signed
    ints and wraps to 1 for unsigned, either of which sorts *above* a
    genuine extreme and drops it from the result entirely.
    """
    n = x.shape[-1]
    assert 1 <= k <= n
    if block <= 0:
        block = max(k, 16)
    block = min(block, n)
    nblk = -(-n // block)
    npad = nblk * block
    neg_inf = _dtype_min(x.dtype)
    pad_widths = [(0, 0)] * (x.ndim - 1) + [(0, npad - n)]
    xp = jnp.pad(x, pad_widths, constant_values=neg_inf)
    idx = jnp.broadcast_to(jnp.arange(npad, dtype=jnp.int32), xp.shape)
    idx = jnp.where(idx < n, idx, -1)  # padded slots must not alias slot 0..n-1
    xb = xp.reshape(xp.shape[:-1] + (nblk, block))
    ib = idx.reshape(xp.shape[:-1] + (nblk, block))
    # descending local sort: rank-sort ascending on negated ordering trick is
    # dtype-hostile; instead sort ascending and reverse.
    vs, is_ = rank_sort(xb, ib)
    vs = vs[..., ::-1][..., : min(k, block)]  # per-block top-k, descending
    is_ = is_[..., ::-1][..., : min(k, block)]
    kk = vs.shape[-1]
    # pairwise truncated merges of descending lists
    while vs.shape[-2] > 1:
        g = vs.shape[-2]
        if g % 2:  # carry odd tail block
            pad = [(0, 0)] * (vs.ndim - 2) + [(0, 1), (0, 0)]
            vs = jnp.pad(vs, pad, constant_values=neg_inf)
            is_ = jnp.pad(is_, pad, constant_values=-1)
            g += 1
        a_v, b_v = vs[..., 0::2, :], vs[..., 1::2, :]
        a_i, b_i = is_[..., 0::2, :], is_[..., 1::2, :]
        # merge two descending lists: reverse -> ascending merge -> take top
        mv, mi = merge(
            a_v[..., ::-1], b_v[..., ::-1], payload=(a_i[..., ::-1], b_i[..., ::-1])
        )
        kk = min(k, 2 * kk)
        vs = mv[..., ::-1][..., :kk]
        is_ = mi[..., ::-1][..., :kk]
    vs = vs[..., 0, :k]
    is_ = is_[..., 0, :k]
    if with_indices:
        return vs, is_
    return vs


# ---------------------------------------------------------------------------
# medians (paper §V-A early exit)
# ---------------------------------------------------------------------------


def median_of_lists(lists: Sequence[jnp.ndarray], kind: str = "loms"):
    """Median of k equal odd-length sorted lists after 2 LOMS stages."""
    lens = tuple(int(l.shape[-1]) for l in lists)
    if kind == "loms":
        sched, pos = _loms.loms_median(lens)
    else:
        sched, pos = _mwms.mwms_median(lens)
    out = apply_schedule(sched, jnp.concatenate(list(lists), axis=-1))
    return out[..., pos]


def median9(window: jnp.ndarray):
    """Median of 9 unsorted values (3x3 image window, ref [19]): 3 parallel
    3-sorters, then the 2-stage 3c_3r LOMS median. Total depth 3."""
    assert window.shape[-1] == 9
    rows = rank_sort(window.reshape(window.shape[:-1] + (3, 3)))
    lists = [rows[..., i, :] for i in range(3)]
    return median_of_lists(lists)
