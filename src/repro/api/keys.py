"""Total-order float keys: the NaN/±inf pre-pass behind ``nan_policy``.

Comparison networks mis-sort special float values two ways:

* a NaN makes every comparator output False, so the rank arithmetic stops
  being a permutation — the output is not even a reordering of the input
  and disagrees with ``jnp.sort`` (which puts NaNs last);
* a genuine ±inf fed through the one-hot MXU permute produces
  ``0 * inf = NaN`` garbage (``kernels/common.py`` keeps *sentinels*
  finite for exactly this reason, but can do nothing about infinite
  *inputs*).

The fix is the classic radix-sort trick: bitcast the float to its signed
integer representation and flip the low bits of the negative half — a
bijective, strictly monotonic map from every float (finite, ±0, ±inf)
onto *finite* integer keys. NaNs are first canonicalized to the positive
quiet-NaN pattern, which maps above ``key(+inf)``: NaNs sort last, the
``jnp.sort`` convention documented on :class:`~repro.api.spec.SortSpec`.
Integer networks never touch the MXU one-hot path (the planner steers
them to the exact scatter permute), so ±inf and NaN inputs become safe on
every backend, including the distributed sample-sort whose splitter
searches would otherwise see unordered rows.

Because the map is bijective, decoding the sorted keys restores the exact
input bit patterns — except that every NaN comes back as the canonical
quiet NaN, which numpy/jnp comparisons treat as the same NaN. The total
order ranks ``-0.0`` strictly below ``+0.0`` (like ``jax.lax.sort``).

The transform math itself lives in :mod:`repro.kernels.common`
(``encode_key_values`` / ``decode_key_values``) so the Pallas kernel
bodies can fuse it — encode on load, decode on store — without an
``api -> kernels -> api`` import cycle; this module is the stable public
face the rest of the api layer imports.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import (  # noqa: F401  (re-exported names)
    KEY_ITYPE as _ITYPE,
    decode_key_values,
    encode_key_values,
    key_transformable,
)


def has_key_transform(dtype) -> bool:
    """Whether ``dtype`` is a float type the key transform covers."""
    return key_transformable(dtype)


def encode_keys(x: jnp.ndarray) -> jnp.ndarray:
    """Float array -> integer keys with the same sort order, NaNs last.

    f32/bf16/f16 keys widen to int32 (the networks' native lane width);
    f64 keys stay int64."""
    return encode_key_values(x)


def decode_keys(k: jnp.ndarray, dtype) -> jnp.ndarray:
    """Exact inverse of :func:`encode_keys` (``dtype`` = original float)."""
    return decode_key_values(k, dtype)
