"""Pallas TPU kernel: Batcher bitonic 2-way merge (the paper's baseline).

The bitonic merge is TPU-pleasant in one way — its compare-exchange pattern
is expressible as strided reshapes (no gathers) — but it needs log2(m+n)
dependent stages over the whole array vs LOMS's 2, so it makes log-many
full passes over the VMEM tile. The benchmark harness contrasts the two.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import pad_batch, resolve_interpret


def _bitonic_merge_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...]  # (bt, m) ascending
    b = b_ref[...]  # (bt, n) ascending
    bt = a.shape[0]
    x = jnp.concatenate([a, b[:, ::-1]], axis=-1)  # bitonic
    total = x.shape[-1]
    d = total // 2
    while d >= 1:
        y = x.reshape(bt, total // (2 * d), 2, d)
        lo = jnp.minimum(y[:, :, 0, :], y[:, :, 1, :])
        hi = jnp.maximum(y[:, :, 0, :], y[:, :, 1, :])
        x = jnp.stack([lo, hi], axis=2).reshape(bt, total)
        d //= 2
    o_ref[...] = x


@functools.partial(jax.jit, static_argnames=("block_batch", "interpret"))
def bitonic_merge2_pallas(
    a: jnp.ndarray, b: jnp.ndarray, *, block_batch: int = 8,
    interpret: Optional[bool] = None
) -> jnp.ndarray:
    """Merge sorted (B, m) and (B, n); m == n == power of two (Batcher's
    constraint, paper §VI). Ragged batch sizes pad up to a ``block_batch``
    multiple and slice back. ``interpret=None`` auto-resolves."""
    interpret = resolve_interpret(interpret)
    (bsz, m), (_, n) = a.shape, b.shape
    assert m == n and (m & (m - 1)) == 0, "Batcher merge needs equal power-of-2 lists"
    a, b = pad_batch(a, block_batch), pad_batch(b, block_batch)
    padded = a.shape[0]
    out = pl.pallas_call(
        _bitonic_merge_kernel,
        grid=(padded // block_batch,),
        in_specs=[
            pl.BlockSpec((block_batch, m), lambda i: (i, 0)),
            pl.BlockSpec((block_batch, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_batch, m + n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, m + n), a.dtype),
        interpret=interpret,
    )(a, b)
    return out[:bsz] if padded != bsz else out
