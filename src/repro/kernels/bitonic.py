"""Deprecated shims: the Batcher bitonic kernel is now the ``bitonic``
network family (``repro.networks``), executed by the shared fused
kernels. The one-off batch-pad wrapper and hand-rolled halver loop are
gone — these aliases route through ``loms_merge2_pallas`` /
``loms_sort_pallas`` with ``network="bitonic"`` (the shared ``pad_batch``
path) and are kept for one release."""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp

from .loms_merge import loms_merge2_pallas
from .sort import loms_sort_pallas


def bitonic_merge2_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_batch: int = 8,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Merge sorted ``a`` (B, m) and ``b`` (B, n), pow2 total, via the
    ``bitonic`` network family. Thin alias over the fused merge kernel."""
    return loms_merge2_pallas(a, b, network="bitonic",
                              block_batch=block_batch, interpret=interpret)


def bitonic_sort_pallas(
    x: jnp.ndarray,
    payloads: Sequence[jnp.ndarray] = (),
    *,
    block_batch: int = 8,
    interpret: Optional[bool] = None,
    **kwargs,
):
    """Full sort via the ``bitonic`` family. Thin alias over the fused
    sort kernel (same return conventions as ``loms_sort_pallas``)."""
    return loms_sort_pallas(x, payloads, network="bitonic",
                            block_batch=block_batch, interpret=interpret,
                            **kwargs)
