"""In-kernel primitives shared by the Pallas sorters.

Everything here is written for the TPU compute units:
  * comparison clouds -> dense boolean matrices on the VPU,
  * output routing (the FPGA MUXF tree) -> one-hot matmul on the MXU,
  * fixed wiring -> constant-index takes, unrolled at trace time.
No data-dependent control flow exists anywhere, mirroring the paper's
oblivious hardware.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _iota(shape, dim, dtype=jnp.int32):
    """broadcasted_iota — the Pallas/Mosaic-safe way to make index ramps
    (captured numpy constants are not allowed inside kernel bodies)."""
    return jax.lax.broadcasted_iota(dtype, shape, dim)


# ---------------------------------------------------------------------------
# total-order float<->int keys (the nan_policy="last" transform)
# ---------------------------------------------------------------------------
# The math lives here — not in repro.api.keys, which re-exports it — so the
# kernel bodies can encode on load and decode on store without an
# api -> kernels -> api import cycle. Everything below is plain jnp and
# traces identically inside a Pallas kernel and at the XLA level.

#: float itemsize -> same-width signed integer type carrying the bit trick
#: (int64 keys require jax_enable_x64, but so does having f64 inputs)
KEY_ITYPE = {2: jnp.int16, 4: jnp.int32, 8: jnp.int64}


def key_transformable(dtype) -> bool:
    """Whether ``dtype`` is a float type the total-order key map covers."""
    d = jnp.dtype(dtype)
    return jnp.issubdtype(d, jnp.floating) and d.itemsize in KEY_ITYPE


def encode_key_values(x: jnp.ndarray) -> jnp.ndarray:
    """Float array -> integer keys with the same sort order, NaNs last.

    Bijective and strictly monotonic over every float (finite, ±0, ±inf);
    NaNs canonicalize to the positive quiet NaN, which maps above
    ``key(+inf)``. f32/bf16/f16 keys widen to int32 (the networks' native
    lane width); f64 keys stay int64. Kernel-safe: pure jnp, no captured
    numpy constants."""
    d = jnp.dtype(x.dtype)
    itype = KEY_ITYPE[d.itemsize]
    mask = itype(jnp.iinfo(itype).max)  # 0x7fff.. : flip all but the sign
    x = jnp.where(jnp.isnan(x), jnp.asarray(jnp.nan, d), x)  # canonical qNaN
    y = jax.lax.bitcast_convert_type(x, itype)
    k = jnp.where(y < 0, y ^ mask, y)
    return k if d.itemsize == 8 else k.astype(jnp.int32)


def decode_key_values(k: jnp.ndarray, dtype) -> jnp.ndarray:
    """Exact inverse of :func:`encode_key_values` (``dtype`` = the original
    float type); every NaN comes back as the canonical quiet NaN."""
    d = jnp.dtype(dtype)
    itype = KEY_ITYPE[d.itemsize]
    mask = itype(jnp.iinfo(itype).max)
    y = k.astype(itype)  # downcast first: the xor must run at key width
    y = jnp.where(y < 0, y ^ mask, y)
    return jax.lax.bitcast_convert_type(y, d)


def ceil_pow2(n: int) -> int:
    """Smallest power of two >= ``n``, with the degenerate guard ``n <= 1
    -> 1``: a 0- or 1-element list needs no comparison network, and the
    naive ``1 << (n - 1).bit_length()`` would emit a phantom 2-wide device
    for ``n == 0`` (``(-1).bit_length() == 1``). Every trace-time pad-to-
    pow2 decision (the fused sort tree, the segmented size-class bucketer)
    must come through here so empty/singleton inputs can never size a
    0-width or oversized network."""
    n = int(n)
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``interpret=None`` -> auto: compile natively on TPU, run the kernel
    body as jnp (interpret mode) on every other platform. A trace-time
    Python decision — safe inside the jit wrappers because ``interpret``
    is always a static argument."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def sentinel_max(dtype):
    """Finite +sentinel: +/-inf would turn the one-hot MXU permute into
    0 * inf = NaN, so sentinels must stay finite."""
    d = jnp.dtype(dtype)
    if jnp.issubdtype(d, jnp.floating):
        return float(jnp.finfo(d).max)
    return int(jnp.iinfo(d).max)


def sentinel_min(dtype):
    d = jnp.dtype(dtype)
    if jnp.issubdtype(d, jnp.floating):
        return float(jnp.finfo(d).min)
    return int(jnp.iinfo(d).min)


def np_fill(value, dtype):
    """Pad value as a numpy scalar of ``dtype``: a bare python uint32-max
    passed to jnp.pad/jnp.full overflows JAX's weak-int32 promotion."""
    return np.asarray(value, jnp.dtype(dtype))


def use_mxu_for(dtype) -> bool:
    """Whether values of ``dtype`` may ride the f32 one-hot MXU permute.

    Integer values — including the total-order float keys of
    ``repro.api.keys`` — overflow the f32 matmul mantissa past 2^24, so
    they must take the exact scatter permute instead."""
    return bool(jnp.issubdtype(jnp.dtype(dtype), jnp.floating))


def pad_batch(x: jnp.ndarray, multiple: int) -> jnp.ndarray:
    """Pad the leading (batch) axis up to a multiple of ``multiple``.

    Pad rows are zeros — every kernel here treats batch rows independently,
    so their (garbage) outputs are sliced away by the caller."""
    pad = (-x.shape[0]) % multiple
    if pad == 0:
        return x
    return jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))


def pad_tail_sorted(x: jnp.ndarray, length: int, descending: bool = False) -> jnp.ndarray:
    """Pad the last (sorted) axis out to ``length`` while keeping each row
    sorted: +sentinel tail for ascending rows, -sentinel for descending.

    Sentinels are dtype extremes, so a genuine extreme value *ties* the
    padding (it can never be displaced past it — the padded row stays a
    sorted permutation of ``values + pads``). Value-only consumers are
    therefore exact under aliasing; anything that carries indices or
    payloads must track validity explicitly (an index ``-1`` per pad slot,
    or a length mask resolved with :func:`stable_compact`)."""
    pad = length - x.shape[-1]
    assert pad >= 0, (x.shape, length)
    if pad == 0:
        return x
    if x.shape[-1] == 0:
        # zero-width row (an empty segment): the "pad" is a pure fill —
        # jnp.pad handles it, but go through jnp.full so the sentinel dtype
        # cast is explicit and a (…, 0) int row cannot weak-promote
        fill = np_fill(sentinel_min(x.dtype) if descending else sentinel_max(x.dtype),
                       x.dtype)
        return jnp.full(x.shape[:-1] + (length,), fill, dtype=x.dtype)
    fill = np_fill(sentinel_min(x.dtype) if descending else sentinel_max(x.dtype),
                   x.dtype)
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)], constant_values=fill)


def stable_compact(valid: jnp.ndarray, *arrays: jnp.ndarray):
    """Stable valid-first compaction along the last axis.

    Permutes each array (same shapes as ``valid``) so the slots where
    ``valid`` is True come first, preserving relative order on both sides.
    This is the mask-based answer to sentinel aliasing: when a genuine
    extreme value ties a padding sentinel, the *mask* — not the value —
    decides what the live prefix contains, so a pad can never displace a
    real element's index or payload. On already value-sorted input whose
    invalid slots all hold the +sentinel, compaction keeps the valid
    prefix sorted (everything it moves past is a tied maximum)."""
    if valid.shape[-1] <= 1:
        # width-0/1 rows are compact by construction; the cumsum/put dance
        # below would still work for width 1 but traces three ops for a
        # no-op, and width 0 has nothing to permute at all
        return arrays if len(arrays) > 1 else arrays[0]
    v = valid.astype(jnp.int32)
    n_valid = v.sum(axis=-1, keepdims=True)
    dest = jnp.where(
        valid,
        jnp.cumsum(v, axis=-1) - 1,
        n_valid + jnp.cumsum(1 - v, axis=-1) - 1,
    )
    outs = tuple(
        jnp.put_along_axis(jnp.zeros_like(a), dest, a, axis=-1, inplace=False)
        for a in arrays
    )
    return outs if len(outs) > 1 else outs[0]


def onehot_permute(vals: jnp.ndarray, rank: jnp.ndarray, payload=None):
    """out[..., rank[i]] = vals[..., i] via one-hot matmul (MXU path).

    rank is a permutation of [0, L). The one-hot matrix is exact in any
    float dtype (one nonzero per row)."""
    l = vals.shape[-1]
    cols = _iota(rank.shape + (l,), rank.ndim)
    oh = (rank[..., :, None] == cols).astype(jnp.float32)
    out = jnp.einsum("...ij,...i->...j", oh, vals.astype(jnp.float32))
    out = out.astype(vals.dtype)
    if payload is None:
        return out
    pout = jnp.einsum("...ij,...i->...j", oh, payload.astype(jnp.float32))
    return out, pout.astype(payload.dtype)


def scatter_permute(vals: jnp.ndarray, rank: jnp.ndarray, payload=None):
    """Same as onehot_permute via put_along_axis (VPU/'fabric' path)."""
    out = jnp.put_along_axis(jnp.zeros_like(vals), rank, vals, axis=-1, inplace=False)
    if payload is None:
        return out
    pout = jnp.put_along_axis(jnp.zeros_like(payload), rank, payload, axis=-1, inplace=False)
    return out, pout


def ranks_sort(x: jnp.ndarray) -> jnp.ndarray:
    """Stable full-sort ranks along the last axis (N-sorter comparator cloud)."""
    n = x.shape[-1]
    i_idx = _iota((n, n), 0)
    j_idx = _iota((n, n), 1)
    j_lt_i = j_idx < i_idx
    before = (x[..., None, :] < x[..., :, None]) | (
        (x[..., None, :] == x[..., :, None]) & j_lt_i
    )
    return before.sum(axis=-1).astype(jnp.int32)


def ranks_merge2(lo: jnp.ndarray, hi: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stable 2-run merge ranks (S2MS cloud): ``lo`` wins ties.

    Returns (rank_lo, rank_hi); both runs ascend. Cross comparisons only —
    m*n comparators, the S2MS resource saving."""
    m, n = lo.shape[-1], hi.shape[-1]
    cmp_ = hi[..., None, :] < lo[..., :, None]  # (.., m, n): hi_j < lo_i
    # lo_i's rank counts strictly-smaller hi; hi_j's rank counts lo_i <= hi_j
    # (lo wins ties) — together a collision-free permutation.
    rank_lo = _iota((1, m), 1)[0] + cmp_.sum(axis=-1)
    rank_hi = _iota((1, n), 1)[0] + (~cmp_).sum(axis=-2)
    return rank_lo.astype(jnp.int32), rank_hi.astype(jnp.int32)


def merge2_sorted(
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    payload: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    use_mxu: bool = True,
):
    """Single-stage stable merge of two ascending runs along the last axis."""
    rank_lo, rank_hi = ranks_merge2(lo, hi)
    vals = jnp.concatenate([lo, hi], axis=-1)
    rank = jnp.concatenate([rank_lo, rank_hi], axis=-1)
    permute = onehot_permute if use_mxu else scatter_permute
    if payload is None:
        return permute(vals, rank)
    return permute(vals, rank, jnp.concatenate([payload[0], payload[1]], axis=-1))


def sort_nsorter(x: jnp.ndarray, payload=None, use_mxu: bool = True):
    """Single-stage N-sorter along the last axis (ascending, stable)."""
    rank = ranks_sort(x)
    permute = onehot_permute if use_mxu else scatter_permute
    return permute(x, rank, payload) if payload is not None else permute(x, rank)


def payload_block_spec(p: jnp.ndarray, block_batch: int) -> pl.BlockSpec:
    """BlockSpec for a (B, L[, F]) payload lane: grid dim 0 tiles the
    batch, the lane (and feature) axes ride whole. The index map swallows
    trailing args so it works under scalar-prefetch grid specs too."""
    if p.ndim == 2:
        return pl.BlockSpec((block_batch, p.shape[1]), lambda i, *_: (i, 0))
    assert p.ndim == 3, p.shape
    return pl.BlockSpec((block_batch, p.shape[1], p.shape[2]),
                        lambda i, *_: (i, 0, 0))


def unpack_fused_results(results, bsz: int, padded: int, n_payload: int,
                         want_perm: bool):
    """Shared epilogue of the fused kernel wrappers: slice off batch
    padding and split (out, perm?, payload outs). Returns the bare ``out``
    for the classic values-only call, else ``(out, perm|None, pouts)``."""
    if not isinstance(results, (list, tuple)):
        results = [results]
    results = [r[:bsz] if padded != bsz else r for r in results]
    out = results[0]
    if n_payload == 0 and not want_perm:
        return out
    perm = results[1] if want_perm else None
    return out, perm, tuple(results[1 + (1 if want_perm else 0):])


def gather_lanes(perm: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """In-kernel payload gather: ``leaf[..., perm, :]`` along the lane axis.

    ``perm`` is (bt, L) int32 input positions; ``leaf`` is (bt, L) or
    (bt, L, F) with trailing feature lanes that broadcast. Runs inside the
    kernel body so payload permutes never leave VMEM. Negative positions
    (top-k pad sentinels) clamp to 0 — their slots are sentinels anyway."""
    idx = jnp.where(perm < 0, 0, perm)
    if leaf.ndim > idx.ndim:
        idx = idx.reshape(idx.shape + (1,) * (leaf.ndim - idx.ndim))
    return jnp.take_along_axis(leaf, idx.astype(jnp.int32), axis=1)
