"""Pallas TPU kernel: generic schedule-driven k-way LOMS merge.

Runs any :class:`repro.core.networks.Schedule` inside a Pallas kernel. The
schedule's wiring (setup scatter, per-stage group indices, output gather)
is passed as int32 operand arrays — Pallas does not allow captured
constants — and every stage unrolls at trace time into:
  wiring take -> comparison cloud (VPU) -> one-hot permute (MXU) -> wiring
  scatter.
This is the general path (3c_7r, mixed list sizes, medians); the 2-way
fast path (pure strided reshapes, no index operands) lives in
loms_merge.py.

Wiring residency: the wiring operands are grid-constant, so when their
total size fits the scalar-memory budget they ride a
``PrefetchScalarGridSpec`` — fetched once into SMEM before the first grid
step instead of being re-blocked by the pipeline on every step. Past the
budget (huge schedules) the legacy per-step ``BlockSpec`` path is kept.

Fused pipeline extensions (DESIGN.md §11): ``key_dtype`` applies the
total-order float->int key transform on load/store inside the kernel,
``payloads`` threads an int32 position lane through every stage permute
and gathers payload lanes in VMEM, ``descending`` reverses each list
segment on load and the output on store — so a NaN-policy payload k-way
merge is still one launch.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.networks import Schedule, _stage_classes

from .common import (
    _iota,
    decode_key_values,
    encode_key_values,
    gather_lanes,
    onehot_permute,
    pad_batch,
    payload_block_spec,
    ranks_sort,
    resolve_interpret,
    scatter_permute,
    unpack_fused_results,
)

#: largest total wiring size (int32 elements) routed through scalar
#: prefetch; SMEM is tens of KiB per core, so bigger schedules keep the
#: legacy VMEM-operand path.
KWAY_PREFETCH_MAX_INTS = 4096


def _schedule_wiring(sched: Schedule, n_stages=None) -> List[np.ndarray]:
    """Collect every constant index array the kernel needs, in read order."""
    wiring = [np.asarray(sched.setup_scatter, dtype=np.int32)]
    stages = sched.stages if n_stages is None else sched.stages[:n_stages]
    for st in stages:
        for _, _, idx in _stage_classes(st):
            wiring.append(idx.reshape(-1).astype(np.int32))
    wiring.append(np.asarray(sched.output_gather, dtype=np.int32))
    return wiring


def _kway_kernel(
    *refs,
    sched: Schedule,
    n_stages,
    use_mxu: bool,
    n_wiring: int,
    prefetch: bool,
    lens: Optional[Tuple[int, ...]],
    key_dtype: Optional[str],
    descending: bool,
    n_payload: int,
    want_perm: bool,
):
    # argument order: prefetch mode puts the scalar wiring refs first,
    # the legacy mode keeps them between x and the payload lanes
    if prefetch:
        wiring = [r[...] for r in refs[:n_wiring]]
        x_ref = refs[n_wiring]
        rest = refs[n_wiring + 1:]
    else:
        x_ref = refs[0]
        wiring = [r[...] for r in refs[1 : 1 + n_wiring]]
        rest = refs[1 + n_wiring:]
    p_refs = rest[:n_payload]
    o_ref = rest[n_payload]
    perm_ref = rest[n_payload + 1] if want_perm else None
    po_refs = rest[n_payload + 1 + (1 if want_perm else 0):]

    x = x_ref[...]
    bt = x.shape[0]
    n_in = x.shape[-1]
    need_pos = n_payload > 0 or want_perm
    pos = _iota((bt, n_in), 1) if need_pos else None
    if descending:
        # reverse each list segment in-register -> ascending problem whose
        # position lane still indexes the original (descending) concat
        assert lens is not None
        offs = np.cumsum((0,) + tuple(lens))
        x = jnp.concatenate(
            [x[:, offs[j] : offs[j + 1]][:, ::-1] for j in range(len(lens))],
            axis=-1,
        )
        if need_pos:
            pos = jnp.concatenate(
                [pos[:, offs[j] : offs[j + 1]][:, ::-1] for j in range(len(lens))],
                axis=-1,
            )
    if key_dtype is not None:  # fused nan_policy="last" encode on load
        x = encode_key_values(x)
    stages = sched.stages if n_stages is None else sched.stages[:n_stages]
    permute = onehot_permute if use_mxu else scatter_permute

    wi = iter(wiring)
    setup = next(wi)
    w = jnp.zeros((bt, sched.size), dtype=x.dtype)
    w = w.at[:, setup].set(x)
    wp = None
    if need_pos:
        wp = jnp.zeros((bt, sched.size), dtype=jnp.int32)
        wp = wp.at[:, setup].set(pos)
    for st in stages:
        for n, runs, idx in _stage_classes(st):
            flat = next(wi)
            vals = jnp.take(w, flat, axis=-1).reshape(bt, *idx.shape)
            if runs is None:
                rank = ranks_sort(vals)
            else:
                offs = np.cumsum((0,) + runs)
                pieces = [vals[..., offs[s] : offs[s + 1]] for s in range(len(runs))]
                rr = []
                for s, vs in enumerate(pieces):
                    r = _iota((1, 1, runs[s]), 2)[0]
                    r = jnp.broadcast_to(r, vs.shape).astype(jnp.int32)
                    for t, vt in enumerate(pieces):
                        if t == s:
                            continue
                        if t < s:
                            cnt = (vt[..., None, :] <= vs[..., :, None]).sum(-1)
                        else:
                            cnt = (vt[..., None, :] < vs[..., :, None]).sum(-1)
                        r = r + cnt.astype(jnp.int32)
                    rr.append(r)
                rank = jnp.concatenate(rr, axis=-1)
            if need_pos:
                pvals = jnp.take(wp, flat, axis=-1).reshape(bt, *idx.shape)
                vals, pvals = permute(vals, rank, pvals)
                wp = wp.at[:, flat].set(pvals.reshape(bt, len(idx.reshape(-1))))
            else:
                vals = permute(vals, rank)
            w = w.at[:, flat].set(vals.reshape(bt, len(idx.reshape(-1))))
    gather = next(wi)
    out = jnp.take(w, gather, axis=-1)
    perm = jnp.take(wp, gather, axis=-1).astype(jnp.int32) if need_pos else None
    if key_dtype is not None:  # fused decode on store
        out = decode_key_values(out, key_dtype)
    if descending:
        out = out[:, ::-1]
        perm = None if perm is None else perm[:, ::-1]
    o_ref[...] = out
    if want_perm:
        perm_ref[...] = perm
    for p_ref, po_ref in zip(p_refs, po_refs):
        po_ref[...] = gather_lanes(perm, p_ref[...])


def kway_merge_pallas(
    x: jnp.ndarray,
    sched: Schedule,
    payloads: Sequence[jnp.ndarray] = (),
    *,
    n_stages: Optional[int] = None,
    block_batch: int = 8,
    use_mxu: bool = True,
    interpret: Optional[bool] = None,
    lens: Optional[Tuple[int, ...]] = None,
    key_dtype: Optional[str] = None,
    descending: bool = False,
    want_perm: bool = False,
):
    """Apply an oblivious schedule to (B, n_inputs) batched lists.

    Ragged batch sizes are padded up to a ``block_batch`` multiple and
    sliced back. ``interpret=None`` auto-resolves: compile on TPU,
    interpret elsewhere.

    Fused-pipeline extras (DESIGN.md §11): ``key_dtype`` (original float
    dtype name) fuses the total-order key encode/decode into the kernel —
    pass ``use_mxu=False`` with it; ``payloads`` is a sequence of
    (B, n_inputs[, F]) lanes riding the permutation in VMEM;
    ``descending`` (requires ``lens``, the per-list lengths) handles
    descending-sorted lists in-register; ``want_perm`` also returns the
    int32 permutation. Returns ``out`` alone in the classic call, else
    ``(out, perm | None, tuple(payload_outs))``.
    """
    interpret = resolve_interpret(interpret)
    bsz, n_in = x.shape
    assert n_in == sched.n_inputs
    payloads = tuple(payloads)
    for p in payloads:
        assert p.ndim in (2, 3) and p.shape[:2] == (bsz, n_in), (
            p.shape, (bsz, n_in))
    if descending:
        assert lens is not None and sum(lens) == n_in, (lens, n_in)
    x = pad_batch(x, block_batch)
    payloads_p = tuple(pad_batch(p, block_batch) for p in payloads)
    padded = x.shape[0]
    wiring = _schedule_wiring(sched, n_stages)
    prefetch = sum(w.size for w in wiring) <= KWAY_PREFETCH_MAX_INTS
    kernel = functools.partial(
        _kway_kernel, sched=sched, n_stages=n_stages, use_mxu=use_mxu,
        n_wiring=len(wiring), prefetch=prefetch, lens=lens,
        key_dtype=key_dtype, descending=descending, n_payload=len(payloads),
        want_perm=want_perm,
    )
    out_specs = [pl.BlockSpec((block_batch, sched.n_outputs),
                              lambda i, *_: (i, 0))]
    out_shapes = [jax.ShapeDtypeStruct((padded, sched.n_outputs), x.dtype)]
    if want_perm:
        out_specs.append(pl.BlockSpec((block_batch, sched.n_outputs),
                                      lambda i, *_: (i, 0)))
        out_shapes.append(jax.ShapeDtypeStruct((padded, sched.n_outputs),
                                               jnp.int32))
    out_specs += [payload_block_spec(p, block_batch) for p in payloads_p]
    out_shapes += [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in payloads_p]
    x_spec = pl.BlockSpec((block_batch, n_in), lambda i, *_: (i, 0))
    p_specs = [payload_block_spec(p, block_batch) for p in payloads_p]
    grid = (padded // block_batch,)
    if prefetch:
        # grid-constant wiring rides scalar prefetch: fetched once, not
        # re-blocked every grid step
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(wiring),
            grid=grid,
            in_specs=[x_spec, *p_specs],
            out_specs=out_specs,
        )
        results = pl.pallas_call(
            kernel, grid_spec=grid_spec, out_shape=out_shapes,
            interpret=interpret,
        )(*[jnp.asarray(w) for w in wiring], x, *payloads_p)
    else:
        in_specs = [x_spec]
        in_specs += [pl.BlockSpec(w.shape, lambda i: (0,)) for w in wiring]
        in_specs += p_specs
        results = pl.pallas_call(
            kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
            out_shape=out_shapes, interpret=interpret,
        )(x, *[jnp.asarray(w) for w in wiring], *payloads_p)
    return unpack_fused_results(results, bsz, padded, len(payloads), want_perm)
