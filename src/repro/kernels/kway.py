"""Pallas TPU kernel: generic schedule-driven k-way LOMS merge.

Runs any :class:`repro.core.networks.Schedule` inside a Pallas kernel. The
schedule's wiring (setup scatter, per-stage group indices, output gather)
is passed as int32 operand arrays — Pallas does not allow captured
constants — and every stage unrolls at trace time into:
  wiring take -> comparison cloud (VPU) -> one-hot permute (MXU) -> wiring
  scatter.
This is the general path (3c_7r, mixed list sizes, medians); the 2-way
fast path (pure strided reshapes, no index operands) lives in
loms_merge.py.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.networks import Schedule, _stage_classes

from .common import (
    _iota,
    onehot_permute,
    pad_batch,
    ranks_sort,
    resolve_interpret,
    scatter_permute,
)


def _schedule_wiring(sched: Schedule, n_stages=None) -> List[np.ndarray]:
    """Collect every constant index array the kernel needs, in read order."""
    wiring = [np.asarray(sched.setup_scatter, dtype=np.int32)]
    stages = sched.stages if n_stages is None else sched.stages[:n_stages]
    for st in stages:
        for _, _, idx in _stage_classes(st):
            wiring.append(idx.reshape(-1).astype(np.int32))
    wiring.append(np.asarray(sched.output_gather, dtype=np.int32))
    return wiring


def _kway_kernel(x_ref, *refs, sched: Schedule, n_stages, use_mxu):
    o_ref = refs[-1]
    wiring = [r[...] for r in refs[:-1]]
    x = x_ref[...]
    bt = x.shape[0]
    stages = sched.stages if n_stages is None else sched.stages[:n_stages]
    permute = onehot_permute if use_mxu else scatter_permute

    wi = iter(wiring)
    setup = next(wi)
    w = jnp.zeros((bt, sched.size), dtype=x.dtype)
    w = w.at[:, setup].set(x)
    for st in stages:
        for n, runs, idx in _stage_classes(st):
            flat = next(wi)
            vals = jnp.take(w, flat, axis=-1).reshape(bt, *idx.shape)
            if runs is None:
                rank = ranks_sort(vals)
            else:
                offs = np.cumsum((0,) + runs)
                pieces = [vals[..., offs[s] : offs[s + 1]] for s in range(len(runs))]
                rr = []
                for s, vs in enumerate(pieces):
                    r = _iota((1, 1, runs[s]), 2)[0]
                    r = jnp.broadcast_to(r, vs.shape).astype(jnp.int32)
                    for t, vt in enumerate(pieces):
                        if t == s:
                            continue
                        if t < s:
                            cnt = (vt[..., None, :] <= vs[..., :, None]).sum(-1)
                        else:
                            cnt = (vt[..., None, :] < vs[..., :, None]).sum(-1)
                        r = r + cnt.astype(jnp.int32)
                    rr.append(r)
                rank = jnp.concatenate(rr, axis=-1)
            vals = permute(vals, rank)
            w = w.at[:, flat].set(vals.reshape(bt, len(idx.reshape(-1))))
    gather = next(wi)
    o_ref[...] = jnp.take(w, gather, axis=-1)


def kway_merge_pallas(
    x: jnp.ndarray,
    sched: Schedule,
    *,
    n_stages: Optional[int] = None,
    block_batch: int = 8,
    use_mxu: bool = True,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Apply an oblivious schedule to (B, n_inputs) batched lists.

    Ragged batch sizes are padded up to a ``block_batch`` multiple and
    sliced back. ``interpret=None`` auto-resolves: compile on TPU,
    interpret elsewhere."""
    interpret = resolve_interpret(interpret)
    bsz, n_in = x.shape
    assert n_in == sched.n_inputs
    x = pad_batch(x, block_batch)
    padded = x.shape[0]
    wiring = _schedule_wiring(sched, n_stages)
    in_specs = [pl.BlockSpec((block_batch, n_in), lambda i: (i, 0))]
    in_specs += [pl.BlockSpec(w.shape, lambda i: (0,)) for w in wiring]
    out = pl.pallas_call(
        functools.partial(_kway_kernel, sched=sched, n_stages=n_stages, use_mxu=use_mxu),
        grid=(padded // block_batch,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_batch, sched.n_outputs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, sched.n_outputs), x.dtype),
        interpret=interpret,
    )(x, *[jnp.asarray(w) for w in wiring])
    return out[:bsz] if padded != bsz else out
