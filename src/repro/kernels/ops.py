"""Public jit'd wrappers over the Pallas sorters.

On TPU hosts the kernels compile natively; everywhere else they run in
``interpret=True`` mode (the kernel body executes as jnp on CPU), so the
whole framework is runnable and testable on this CPU container — the
kernels' ``interpret=None`` default auto-resolves per platform. Ragged
shapes that the fast kernels don't cover fall back to the pure-JAX
schedule executor — same oblivious semantics, no shape restrictions.

Tile selection goes through the VMEM-aware planner
(:func:`repro.streaming.planner.plan_op`): cache-hit autotuned tiles when
a prior sweep ran on this host, closed-form VMEM-fit heuristics
otherwise. Batch tiles are chosen by fit, not divisibility — a prime
batch size pads (``pad_batch``) instead of degenerating to a
``block_batch=1`` grid of B steps.

These wrappers are the "pallas" backend of the unified dispatch layer
(:mod:`repro.api`); prefer ``repro.merge / merge_k / sort / topk`` unless
you need this exact realization.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.networks import kway_schedule, median_schedule
from repro.resilience.failpoints import failpoint

from .kway import kway_merge_pallas
from .loms_merge import loms_merge2_pallas
from .sort import loms_sort_pallas
from .topk import ROUTER_TOPK_MAX, router_topk_pallas, vocab_topk_pallas


def _plan(op, lengths, batch, dtype, k=None):
    # function-level import keeps the module graph's
    # api -> streaming -> kernels -> core arrow intact
    from repro.streaming.planner import plan_op

    return plan_op(op, lengths, batch=batch, dtype=dtype, k=k)


def _pick_block_batch(bsz: int, *, op: str = "merge2",
                      lengths: Sequence[int] = (), dtype=jnp.float32,
                      k: Optional[int] = None) -> int:
    """VMEM-fit batch tile for one kernel call (cache-aware).

    The old divisor-only rule made a prime batch (B=1007) run with
    ``block_batch=1`` and a 1007-step grid; ``pad_batch`` already absorbs
    ragged batches, so the tile is now picked purely by working-set fit."""
    return _plan(op, tuple(lengths) or (1,), bsz, dtype, k).block_batch


def _use_mxu(dtype) -> bool:
    from .common import use_mxu_for

    return use_mxu_for(dtype)


def merge2(
    a: jnp.ndarray, b: jnp.ndarray, *, n_cols: int = 2, kind: str = "loms"
) -> jnp.ndarray:
    """Batched merge of sorted (B, m) and (B, n) lists. ``kind`` names a
    registered network family ("loms", "s2ms", "periodic3",
    "bitonic") — all execute through the one fused merge kernel."""
    assert a.ndim == 2 and b.ndim == 2
    failpoint("kernel.launch.merge2")
    m, n = a.shape[-1], b.shape[-1]
    if kind != "loms":
        return loms_merge2_pallas(
            a, b, network=kind,
            block_batch=_pick_block_batch(a.shape[0], lengths=(m, n),
                                          dtype=a.dtype),
        )
    if m % n_cols == 0 and n % n_cols == 0:
        plan = _plan("merge2", (m, n), a.shape[0], a.dtype)
        return loms_merge2_pallas(
            a, b, network=plan.network, n_cols=n_cols,
            block_batch=plan.block_batch,
            use_mxu=plan.use_mxu and _use_mxu(a.dtype),
        )
    # ragged fallback: the pure-JAX executor (function-level import so the
    # module graph keeps the api -> streaming -> kernels -> core arrow)
    from repro.api import schedules as sched_api

    return sched_api.merge(a, b, n_cols=n_cols)


def merge_k(lists: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Batched k-way LOMS merge of sorted (B, len_i) lists."""
    failpoint("kernel.launch.merge_k")
    lens = tuple(int(l.shape[-1]) for l in lists)
    sched = kway_schedule(lens)
    x = jnp.concatenate(list(lists), axis=-1)
    plan = _plan("kway", lens, x.shape[0], x.dtype)
    return kway_merge_pallas(x, sched, block_batch=plan.block_batch,
                             use_mxu=plan.use_mxu and _use_mxu(x.dtype))


def median_k(lists: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Batched 2-stage LOMS median of k equal odd-length sorted lists."""
    failpoint("kernel.launch.median")
    lens = tuple(int(l.shape[-1]) for l in lists)
    sched, pos = median_schedule(lens)
    x = jnp.concatenate(list(lists), axis=-1)
    plan = _plan("kway", lens, x.shape[0], x.dtype)
    out = kway_merge_pallas(x, sched, block_batch=plan.block_batch,
                            use_mxu=plan.use_mxu and _use_mxu(x.dtype))
    return out[..., pos]


def sort(x: jnp.ndarray) -> jnp.ndarray:
    """Batched full sort over the last axis of (B, n): the fused
    single-launch merge-tree kernel (values only; the api layer's fused
    adapters carry keys/payloads through the same kernel)."""
    assert x.ndim == 2
    failpoint("kernel.launch.sort")
    plan = _plan("sort", (x.shape[-1],), x.shape[0], x.dtype)
    return loms_sort_pallas(x, network=plan.network,
                            block_batch=plan.block_batch,
                            use_mxu=plan.use_mxu and _use_mxu(x.dtype))


def topk_tiles(bsz: int, e: int, *, block: int = 0,
               block_batch: int = 8) -> Tuple[int, int]:
    """Resolve the (block, block_batch) tile pair for the top-k kernels.

    The single home for the top-k divisor fallback: the kernels don't
    batch-pad yet, so block_batch halves until it divides the batch, and
    the router block shrinks until it divides the axis. Shared by this
    wrapper and the fused adapter (repro.api.fused)."""
    bb = max(block_batch, 1)
    while bsz % bb:
        bb //= 2
    bb = max(bb, 1)
    if e <= ROUTER_TOPK_MAX:
        blk = block or max(16, min(64, e))
        while e % blk:
            blk -= 1
    else:
        blk = block or 128
    return blk, bb


def topk(
    x: jnp.ndarray, k: int, *, block: Optional[int] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Batched descending top-k with indices over the last axis of (B, E).

    Dispatches to the single-kernel router path for small E and the
    two-phase vocab path for large E."""
    assert x.ndim == 2
    failpoint("kernel.launch.topk")
    bsz, e = x.shape
    plan = _plan("topk", (e,), bsz, x.dtype, k)
    blk, bb = topk_tiles(bsz, e, block=block or plan.block,
                         block_batch=plan.block_batch)
    use_mxu = plan.use_mxu and _use_mxu(x.dtype)
    if e <= ROUTER_TOPK_MAX:
        return router_topk_pallas(x, k=k, block=blk, block_batch=bb,
                                  use_mxu=use_mxu)
    return vocab_topk_pallas(x, k=k, block=blk, block_batch=bb,
                             use_mxu=use_mxu)
