"""Public jit'd wrappers over the Pallas sorters.

On TPU hosts the kernels compile natively; everywhere else they run in
``interpret=True`` mode (the kernel body executes as jnp on CPU), so the
whole framework is runnable and testable on this CPU container — the
kernels' ``interpret=None`` default auto-resolves per platform. Ragged
shapes that the fast kernels don't cover fall back to the pure-JAX
schedule executor — same oblivious semantics, no shape restrictions.

These wrappers are the "pallas" backend of the unified dispatch layer
(:mod:`repro.api`); prefer ``repro.merge / merge_k / topk`` unless you
need this exact realization.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core import loms as core_loms

from .bitonic import bitonic_merge2_pallas
from .kway import kway_merge_pallas
from .loms_merge import loms_merge2_pallas
from .topk import ROUTER_TOPK_MAX, router_topk_pallas, vocab_topk_pallas


def _pick_block_batch(bsz: int, target: int = 8) -> int:
    for bb in (target, 4, 2, 1):
        if bsz % bb == 0:
            return bb
    return 1


def _use_mxu(dtype) -> bool:
    from .common import use_mxu_for

    return use_mxu_for(dtype)


def merge2(
    a: jnp.ndarray, b: jnp.ndarray, *, n_cols: int = 2, kind: str = "loms"
) -> jnp.ndarray:
    """Batched merge of sorted (B, m) and (B, n) lists."""
    assert a.ndim == 2 and b.ndim == 2
    m, n = a.shape[-1], b.shape[-1]
    if kind == "bitonic":
        return bitonic_merge2_pallas(
            a, b, block_batch=_pick_block_batch(a.shape[0])
        )
    assert kind == "loms"
    if m % n_cols == 0 and n % n_cols == 0:
        return loms_merge2_pallas(
            a, b, n_cols=n_cols, block_batch=_pick_block_batch(a.shape[0]),
            use_mxu=_use_mxu(a.dtype),
        )
    # ragged fallback: the pure-JAX executor (function-level import so the
    # module graph keeps the api -> streaming -> kernels -> core arrow)
    from repro.api import schedules as sched_api

    return sched_api.merge(a, b, n_cols=n_cols)


def merge_k(lists: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Batched k-way LOMS merge of sorted (B, len_i) lists."""
    lens = tuple(int(l.shape[-1]) for l in lists)
    sched = core_loms.loms_kway(lens)
    x = jnp.concatenate(list(lists), axis=-1)
    return kway_merge_pallas(x, sched, block_batch=_pick_block_batch(x.shape[0]),
                             use_mxu=_use_mxu(x.dtype))


def median_k(lists: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Batched 2-stage LOMS median of k equal odd-length sorted lists."""
    lens = tuple(int(l.shape[-1]) for l in lists)
    sched, pos = core_loms.loms_median(lens)
    x = jnp.concatenate(list(lists), axis=-1)
    out = kway_merge_pallas(x, sched, block_batch=_pick_block_batch(x.shape[0]),
                            use_mxu=_use_mxu(x.dtype))
    return out[..., pos]


def topk(
    x: jnp.ndarray, k: int, *, block: Optional[int] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Batched descending top-k with indices over the last axis of (B, E).

    Dispatches to the single-kernel router path for small E and the
    two-phase vocab path for large E."""
    assert x.ndim == 2
    bsz, e = x.shape
    bb = _pick_block_batch(bsz)
    if e <= ROUTER_TOPK_MAX:
        blk = block or max(16, min(64, e))
        while e % blk:
            blk -= 1
        return router_topk_pallas(x, k=k, block=blk, block_batch=bb,
                                  use_mxu=_use_mxu(x.dtype))
    return vocab_topk_pallas(x, k=k, block=block or 128, block_batch=bb,
                             use_mxu=_use_mxu(x.dtype))
