"""Pure-jnp oracles for every kernel (the ground truth in kernel tests)."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def merge2_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Sorted merge of two sorted lists = sort of the concatenation."""
    return jnp.sort(jnp.concatenate([a, b], axis=-1), axis=-1)


def merge_k_ref(x: jnp.ndarray) -> jnp.ndarray:
    """k-way merge oracle on the concatenated input."""
    return jnp.sort(x, axis=-1)


def topk_ref(x: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Descending top-k values + indices (jax.lax.top_k)."""
    import jax

    return jax.lax.top_k(x, k)


def median_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Median of an odd number of values along the last axis."""
    n = x.shape[-1]
    assert n % 2 == 1
    return jnp.sort(x, axis=-1)[..., n // 2]
