"""Pallas TPU kernel: fused single-launch full sort (DESIGN.md §11).

The schedule executor realizes ``repro.sort`` as a 2-sorter stage plus a
LOMS 2-way merge tree — each level a separate XLA op over HBM-resident
data, with the NaN-policy key encode/decode and the payload gather as
further passes. This kernel runs the *whole* pipeline per batch tile in
one ``pallas_call``:

  load -> (encode total-order int keys) -> pad to a power of two with
  +sentinels -> trace-time-unrolled merge tree carrying an int32
  position lane -> slice the live prefix -> (decode) -> (reverse for
  descending) -> store values + gather payload lanes in VMEM.

The tree's level structure comes from the pluggable network layer
(``repro.networks``): ``network=`` names a registered family ("loms",
"s2ms", "periodic3", "bitonic") and the kernel executes whatever
merge-step program the registry hands back — the autotuner tournament
picks the family per size class.

Sentinel handling never relies on tie order: when a position lane is
carried, validity is decided by mask (``stable_compact``); the bare
values-only call needs only multiset-sortedness, under which the first
``n`` output slots are exactly the sorted input for *any* family.

VMEM: the widest tree level materializes a (bt, npad/2, run, run)
comparison cloud ~ bt * npad^2 / 4 f32 entries; ``streaming.planner``
(``plan_sort`` / ``sort_fits_vmem``) sizes ``block_batch`` and gates
routing so this stays inside the budget.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.networks import run_sort_program, sort_program

from .common import (
    _iota,
    ceil_pow2,
    decode_key_values,
    encode_key_values,
    gather_lanes,
    np_fill,
    pad_batch,
    payload_block_spec,
    resolve_interpret,
    sentinel_max,
    stable_compact,
    unpack_fused_results,
)


def _sort_kernel(
    x_ref,
    *refs,
    n: int,
    network: str,
    use_mxu: bool,
    key_dtype: Optional[str],
    descending: bool,
    n_payload: int,
    want_perm: bool,
):
    p_refs = refs[:n_payload]
    o_ref = refs[n_payload]
    perm_ref = refs[n_payload + 1] if want_perm else None
    po_refs = refs[n_payload + 1 + (1 if want_perm else 0):]

    x = x_ref[...]  # (bt, n) unsorted
    bt = x.shape[0]
    if key_dtype is not None:  # fused nan_policy="last" encode on load
        x = encode_key_values(x)
    npad = ceil_pow2(n)
    if npad != n:
        # np_fill: a bare python uint32-max overflows weak-int32 promotion
        fill = np_fill(sentinel_max(x.dtype), x.dtype)
        x = jnp.pad(x, [(0, 0), (0, npad - n)], constant_values=fill)
    need_pos = n_payload > 0 or want_perm
    pos = _iota((bt, npad), 1) if need_pos else None
    # the unrolled merge tree comes from the network registry (shared with
    # the segmented class kernels, column-device cutover included)
    x, pos = run_sort_program(sort_program(network, npad), x, pos, use_mxu)
    if need_pos and npad != n:
        # the column devices make no cross-run tie-order promise, so a tail
        # pad that ties a genuine dtype-max value may land inside the live
        # prefix; validity is decided by the position lane, never by value
        x, pos = stable_compact(pos < n, x, pos)
    out = x[:, :n]  # value-identical under pad/max aliasing (pads tie)
    perm = pos[:, :n].astype(jnp.int32) if need_pos else None
    if key_dtype is not None:  # fused decode on store
        out = decode_key_values(out, key_dtype)
    if descending:
        out = out[:, ::-1]
        perm = None if perm is None else perm[:, ::-1]
    o_ref[...] = out
    if want_perm:
        perm_ref[...] = perm
    for p_ref, po_ref in zip(p_refs, po_refs):
        po_ref[...] = gather_lanes(perm, p_ref[...])


@functools.partial(
    jax.jit,
    static_argnames=(
        "network", "block_batch", "use_mxu", "interpret", "key_dtype",
        "descending", "want_perm",
    ),
)
def loms_sort_pallas(
    x: jnp.ndarray,
    payloads: Sequence[jnp.ndarray] = (),
    *,
    network: str = "loms",
    block_batch: int = 8,
    use_mxu: bool = True,
    interpret: Optional[bool] = None,
    key_dtype: Optional[str] = None,
    descending: bool = False,
    want_perm: bool = False,
):
    """Full sort of unsorted (B, n) rows in one fused kernel launch.

    ``network`` — registered family name executed by the merge tree.

    ``key_dtype`` — original float dtype name: the kernel encodes the
    total-order int keys on load and decodes on store (pass
    ``use_mxu=False``; int keys must take the exact scatter permute).
    ``payloads`` — (B, n[, F]) lanes permuted in VMEM and returned.
    ``descending`` — descending output, handled in-register. ``want_perm``
    — also return the int32 sort permutation (input positions).

    Returns ``out`` alone in the plain call, else
    ``(out, perm | None, tuple(payload_outs))``. Ragged batch sizes pad up
    to a ``block_batch`` multiple and slice back.
    """
    interpret = resolve_interpret(interpret)
    bsz, n = x.shape
    payloads = tuple(payloads)
    for p in payloads:
        assert p.ndim in (2, 3) and p.shape[:2] == (bsz, n), (p.shape, (bsz, n))
    x = pad_batch(x, block_batch)
    payloads_p = tuple(pad_batch(p, block_batch) for p in payloads)
    padded = x.shape[0]
    out_specs = [pl.BlockSpec((block_batch, n), lambda i: (i, 0))]
    out_shapes = [jax.ShapeDtypeStruct((padded, n), x.dtype)]
    if want_perm:
        out_specs.append(pl.BlockSpec((block_batch, n), lambda i: (i, 0)))
        out_shapes.append(jax.ShapeDtypeStruct((padded, n), jnp.int32))
    out_specs += [payload_block_spec(p, block_batch) for p in payloads_p]
    out_shapes += [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in payloads_p]
    results = pl.pallas_call(
        functools.partial(
            _sort_kernel, n=n, network=network, use_mxu=use_mxu,
            key_dtype=key_dtype, descending=descending,
            n_payload=len(payloads), want_perm=want_perm,
        ),
        grid=(padded // block_batch,),
        in_specs=[
            pl.BlockSpec((block_batch, n), lambda i: (i, 0)),
            *[payload_block_spec(p, block_batch) for p in payloads_p],
        ],
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(x, *payloads_p)
    return unpack_fused_results(results, bsz, padded, len(payloads), want_perm)
