"""repro.kernels — Pallas TPU sorters (interpret=True on CPU hosts)."""
from .ops import merge2, merge_k, median_k, topk  # noqa: F401
