"""Pallas TPU kernels: blockwise top-k via truncated LOMS merges.

This is the framework's hot sorting path (MoE router top-k over experts,
decode-time top-k over the vocab). Two kernels:

  * ``router_topk`` — E small (<= ~512): one kernel does local descending
    rank-sorts of E/bs blocks and the full LOMS merge tree in VMEM.
  * ``vocab_topk``  — E large (vocab ~152k): phase-1 kernel grids over
    (batch, vocab-block) producing per-block sorted top-k lists; then a
    log-depth sequence of phase-2 merge kernels, each merging pairs of
    sorted k-lists with a truncated UP-k/DN-k LOMS merge (top half kept —
    exactly the paper's 2-stage device, reading only the upper rows).

Values carry int32 payload indices throughout (compare on value, tie-break
on nothing — payloads ride the permutation). Sentinel slots — block padding
and odd-group merge pads — carry index -1, never an in-range position: a
pad ties with a real dtype-min element, and any non-negative index would
silently alias that element's slot (the repro.topk index contract).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .common import (
    decode_key_values,
    encode_key_values,
    merge2_sorted,
    resolve_interpret,
    sentinel_min,
    sort_nsorter,
)

#: largest last-axis size the single-kernel router path handles; beyond it
#: the two-phase vocab kernel grids over (batch, vocab-block). The dispatch
#: layer (repro.api.dispatch) imports this so routing and realization agree.
ROUTER_TOPK_MAX = 512

_neg_inf = sentinel_min


def _local_sorted_topk(x, idx, k, use_mxu):
    """(bt, G, bs) -> per-block descending top-k (bt, G, k) with payloads."""
    vs, is_ = sort_nsorter(x, idx, use_mxu=use_mxu)
    return vs[..., ::-1][..., :k], is_[..., ::-1][..., :k]


def _merge_desc(av, ai, bv, bi, keep, use_mxu):
    """Merge two descending lists, keep the top ``keep`` (descending)."""
    mv, mi = merge2_sorted(av[..., ::-1], bv[..., ::-1],
                           payload=(ai[..., ::-1], bi[..., ::-1]), use_mxu=use_mxu)
    return mv[..., ::-1][..., :keep], mi[..., ::-1][..., :keep]


def _router_topk_kernel(x_ref, v_ref, i_ref, *, k, block, use_mxu, key_dtype):
    x = x_ref[...]  # (bt, E)
    if key_dtype is not None:  # fused nan_policy="last" encode on load
        x = encode_key_values(x)
    bt, e = x.shape
    g = e // block
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    xb = x.reshape(bt, g, block)
    ib = idx.reshape(bt, g, block)
    kk = min(k, block)
    vs, is_ = _local_sorted_topk(xb, ib, kk, use_mxu)
    while vs.shape[-2] > 1:
        if vs.shape[-2] % 2:
            pad = [(0, 0)] * (vs.ndim - 2) + [(0, 1), (0, 0)]
            vs = jnp.pad(vs, pad, constant_values=_neg_inf(vs.dtype))
            is_ = jnp.pad(is_, pad, constant_values=-1)
        kk = min(k, 2 * kk)
        vs, is_ = _merge_desc(vs[..., 0::2, :], is_[..., 0::2, :],
                              vs[..., 1::2, :], is_[..., 1::2, :], kk, use_mxu)
    vs = vs[..., 0, :k]
    if key_dtype is not None:  # fused decode on store
        vs = decode_key_values(vs, key_dtype)
    v_ref[...] = vs
    i_ref[...] = is_[..., 0, :k]


@functools.partial(jax.jit, static_argnames=(
    "k", "block", "block_batch", "use_mxu", "interpret", "key_dtype"))
def router_topk_pallas(
    x: jnp.ndarray, *, k: int, block: int = 32, block_batch: int = 8,
    use_mxu: bool = True, interpret: Optional[bool] = None,
    key_dtype: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k over the last axis of (T, E) router logits; E % block == 0.
    ``interpret=None`` auto-resolves: compile on TPU, interpret elsewhere.
    ``key_dtype`` fuses the total-order float->int key transform into the
    kernel (encode on load, decode on store; pass ``use_mxu=False``)."""
    interpret = resolve_interpret(interpret)
    t, e = x.shape
    assert e % block == 0 and t % block_batch == 0
    return pl.pallas_call(
        functools.partial(_router_topk_kernel, k=k, block=block,
                          use_mxu=use_mxu, key_dtype=key_dtype),
        grid=(t // block_batch,),
        in_specs=[pl.BlockSpec((block_batch, e), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_batch, k), lambda i: (i, 0)),
            pl.BlockSpec((block_batch, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, k), x.dtype),
            jax.ShapeDtypeStruct((t, k), jnp.int32),
        ],
        interpret=interpret,
    )(x)


# ---------------------------------------------------------------------------
# large-axis (vocab) top-k: phase 1 block kernel + phase 2 merge-level kernel
# ---------------------------------------------------------------------------


def _phase1_kernel(x_ref, v_ref, i_ref, *, k, v_real, use_mxu, key_dtype,
                   decode):
    j = pl.program_id(1)
    x = x_ref[...]  # (bt, bs)
    bt, bs = x.shape
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + (j * bs).astype(jnp.int32)
    if key_dtype is not None:  # fused nan_policy="last" encode on load
        x = encode_key_values(x)
        # V-padding slots become the int-key -sentinel (below key(-inf)),
        # bit-identical to the unfused pipeline's padded encoded array
        x = jnp.where(idx < v_real, x, _neg_inf(x.dtype))
    idx = jnp.where(idx < v_real, idx, -1)  # V-padding slots must not alias
    vs, is_ = sort_nsorter(x, idx, use_mxu=use_mxu)
    vs = vs[..., ::-1][..., :k]
    if decode:  # single-block vocab: this launch is also the last phase
        vs = decode_key_values(vs, key_dtype)
    v_ref[...] = vs[..., None, :]
    i_ref[...] = is_[..., ::-1][..., None, :k]


def _merge_level_kernel(v_ref, i_ref, vo_ref, io_ref, *, keep, use_mxu,
                        decode_dtype):
    v = v_ref[...]  # (bt, 2, k) two descending lists
    i = i_ref[...]
    vo, io = _merge_desc(v[:, 0], i[:, 0], v[:, 1], i[:, 1], keep, use_mxu)
    if decode_dtype is not None:  # last level: fused decode on store
        vo = decode_key_values(vo, decode_dtype)
    vo_ref[...] = vo[:, None, :]
    io_ref[...] = io[:, None, :]


@functools.partial(jax.jit, static_argnames=(
    "k", "block", "block_batch", "use_mxu", "interpret", "key_dtype"))
def vocab_topk_pallas(
    x: jnp.ndarray, *, k: int, block: int = 128, block_batch: int = 8,
    use_mxu: bool = True, interpret: Optional[bool] = None,
    key_dtype: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k over a large last axis (B, V). Pads V to a block multiple.
    ``interpret=None`` auto-resolves: compile on TPU, interpret elsewhere.
    ``key_dtype`` fuses the total-order key transform into the phase
    kernels: phase 1 encodes on load, the final merge level decodes on
    store — the intermediate k-lists stay int keys and never round-trip
    through an XLA encode/decode (pass ``use_mxu=False``)."""
    interpret = resolve_interpret(interpret)
    bsz, v = x.shape
    assert bsz % block_batch == 0
    nblk = -(-v // block)
    # pad to power-of-two block count for a regular merge tree
    nblk = 1 << (nblk - 1).bit_length()
    vp = nblk * block
    if vp != v:
        x = jnp.pad(x, [(0, 0), (0, vp - v)], constant_values=_neg_inf(x.dtype))
    kk = min(k, block)
    work_dtype = x.dtype
    if key_dtype is not None:  # encode_key_values widens sub-64-bit to i32
        work_dtype = jnp.int64 if jnp.dtype(key_dtype).itemsize == 8 else jnp.int32
    vs, is_ = pl.pallas_call(
        functools.partial(_phase1_kernel, k=kk, v_real=v, use_mxu=use_mxu,
                          key_dtype=key_dtype,
                          decode=(key_dtype is not None and nblk == 1)),
        grid=(bsz // block_batch, nblk),
        in_specs=[pl.BlockSpec((block_batch, block), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_batch, 1, kk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_batch, 1, kk), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(
                (bsz, nblk, kk), x.dtype if nblk == 1 else work_dtype),
            jax.ShapeDtypeStruct((bsz, nblk, kk), jnp.int32),
        ],
        interpret=interpret,
    )(x)
    while vs.shape[1] > 1:
        g = vs.shape[1] // 2
        keep = min(k, 2 * vs.shape[-1])
        last = g == 1
        vpair = vs.reshape(bsz * g, 2, vs.shape[-1])
        ipair = is_.reshape(bsz * g, 2, vs.shape[-1])
        bb = block_batch if (bsz * g) % block_batch == 0 else 1
        vs, is_ = pl.pallas_call(
            functools.partial(
                _merge_level_kernel, keep=keep, use_mxu=use_mxu,
                decode_dtype=key_dtype if (key_dtype is not None and last)
                else None),
            grid=((bsz * g) // bb,),
            in_specs=[
                pl.BlockSpec((bb, 2, vpair.shape[-1]), lambda i: (i, 0, 0)),
                pl.BlockSpec((bb, 2, vpair.shape[-1]), lambda i: (i, 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((bb, 1, keep), lambda i: (i, 0, 0)),
                pl.BlockSpec((bb, 1, keep), lambda i: (i, 0, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct(
                    (bsz * g, 1, keep),
                    x.dtype if (key_dtype is not None and last) else vs.dtype),
                jax.ShapeDtypeStruct((bsz * g, 1, keep), jnp.int32),
            ],
            interpret=interpret,
        )(vpair, ipair)
        vs = vs.reshape(bsz, g, keep)
        is_ = is_.reshape(bsz, g, keep)
    return vs[:, 0, :k], is_[:, 0, :k]
