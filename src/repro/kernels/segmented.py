"""Pallas TPU kernels for the segmented (CSR ragged) subsystem.

One kernel launch per **size class**: the bucketer (repro.segmented) packs
every segment whose length rounds up to the same power of two ``W`` into
the rows of a dense ``(n_segments, W)`` tile, with a per-row valid length
riding as an int32 column. The kernel then runs the matching trace-time-
unrolled LOMS network once for the whole class:

  load -> (encode total-order int keys) -> (bit-flip for descending) ->
  overwrite the invalid tail lanes with the key-domain +sentinel ->
  unrolled LOMS merge tree (sort) or column S2MS merge (merge) carrying an
  int32 position lane -> mask-compact validity (``stable_compact`` — a pad
  can never displace a real element, even when a genuine NaN key sits
  above the float sentinel) -> gather the *raw* input values and payload
  lanes at the permutation in VMEM -> store the (optionally truncated)
  prefix.

Because the output values are gathered from the raw input at the
permutation — never decoded from keys — they are bit-exact for every
input including NaN payload bits, and the same gather carries pytree
payload lanes (PR 4's position-lane device). Descending order is a key
bit-flip (``~k`` reverses any integer total order exactly; ``-x`` for the
raw-float unsafe path), so descending-sorted segment *inputs* become
ascending key runs for free — no index reversal anywhere.

``k_out`` truncates the stored prefix, which makes per-segment top-k the
same launch as the class sort with a narrower output block.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.networks import (merge_program, merge_runs, pick_merge_cols,
                            run_sort_program, sort_program)

from .common import (
    _iota,
    encode_key_values,
    gather_lanes,
    pad_batch,
    payload_block_spec,
    resolve_interpret,
    stable_compact,
    unpack_fused_results,
)


def key_sentinel(dtype):
    """+sentinel in the *key* domain: the largest representable value, so
    masked lanes order after every valid key (NaN keys included — the
    total-order encode maps NaN below int-max)."""
    d = jnp.dtype(dtype)
    if jnp.issubdtype(d, jnp.floating):
        return jnp.asarray(jnp.finfo(d).max, d)
    return jnp.asarray(jnp.iinfo(d).max, d)


def flip_keys(k: jnp.ndarray) -> jnp.ndarray:
    """Exact order reversal: bitwise-not for any integer width (bijective,
    no int-min overflow the naive negation has), negation for raw floats
    (unsafe path — finite by contract)."""
    if jnp.issubdtype(k.dtype, jnp.floating):
        return -k
    return ~k


def _prep_keys(x, lens, *, encode: bool, flip: bool):
    """values -> masked network keys + the validity of each input lane."""
    keys = encode_key_values(x) if encode else x
    if flip:
        keys = flip_keys(keys)
    lane = _iota(x.shape, 1)
    valid_in = lane < lens  # lens: (bt, 1) broadcasts over the lane axis
    return jnp.where(valid_in, keys, key_sentinel(keys.dtype)), lane


def _store_prefix(refs, pos, x_vals, p_ins, k_out: int, want_perm: bool,
                  seg_pos=None):
    """Shared epilogue: gather raw values + payload lanes at the compacted
    permutation and store the ``k_out`` prefix."""
    n_payload = len(p_ins)
    o_ref = refs[0]
    perm_ref = refs[1] if want_perm else None
    po_refs = refs[1 + (1 if want_perm else 0):]
    o_ref[...] = gather_lanes(pos, x_vals)[:, :k_out]
    if want_perm:
        perm_ref[...] = (pos if seg_pos is None else seg_pos)[:, :k_out]
    for p_in, po_ref in zip(p_ins, po_refs):
        po_ref[...] = gather_lanes(pos, p_in)[:, :k_out]


def _seg_sort_kernel(
    x_ref, len_ref, *refs,
    w: int, k_out: int, network: str, encode: bool, flip: bool,
    use_mxu: bool, n_payload: int, want_perm: bool,
):
    p_ins = tuple(r[...] for r in refs[:n_payload])
    x = x_ref[...]  # (bt, w) raw, invalid tail lanes hold arbitrary fill
    lens = len_ref[...]  # (bt, 1) per-segment valid lengths
    keys, lane = _prep_keys(x, lens, encode=encode, flip=flip)
    keys, pos = run_sort_program(sort_program(network, w), keys, lane,
                                 use_mxu)
    # validity by mask, never by value: a genuine NaN key sorts above the
    # float sentinel, so the compacted prefix — not the raw network order —
    # defines the live output
    keys, pos = stable_compact(pos < lens, keys, pos)
    _store_prefix(refs[n_payload:], pos, x, p_ins, k_out, want_perm)


def _seg_merge_kernel(
    a_ref, b_ref, la_ref, lb_ref, *refs,
    wa: int, wb: int, k_out: int, network: str, n_cols: int, encode: bool,
    flip: bool, use_mxu: bool, n_payload: int, want_perm: bool,
):
    p_ins = tuple(r[...] for r in refs[:n_payload])
    a = a_ref[...]
    b = b_ref[...]
    lens_a = la_ref[...]
    lens_b = lb_ref[...]
    ka, lane_a = _prep_keys(a, lens_a, encode=encode, flip=flip)
    kb, lane_b = _prep_keys(b, lens_b, encode=encode, flip=flip)
    # dense-coordinate positions: [0, wa) = a lanes, [wa, wa+wb) = b lanes
    prog = merge_program(network, wa, wb,
                         n_cols if network == "loms" else None)
    keys, pos = merge_runs(prog, ka, kb,
                           payload=(lane_a, wa + lane_b), use_mxu=use_mxu)
    valid = jnp.where(pos < wa, pos < lens_a, pos - wa < lens_b)
    keys, pos = stable_compact(valid, keys, pos)
    # perm in *segment* coordinates: b elements continue at len_a, not wa
    seg_pos = jnp.where(pos < wa, pos, lens_a + (pos - wa))
    _store_prefix(refs[n_payload:], pos, jnp.concatenate([a, b], axis=1),
                  p_ins, k_out, want_perm, seg_pos=seg_pos)


def _class_call(kernel, inputs, payloads, *, k_out: int,
                block_batch: int, want_perm: bool, interpret, dtype):
    """Shared pallas_call wrapper: batch-pad, build specs, unpack."""
    interpret = resolve_interpret(interpret)
    bsz = inputs[0].shape[0]
    inputs = [pad_batch(v, block_batch) for v in inputs]
    payloads = tuple(pad_batch(p, block_batch) for p in payloads)
    padded = inputs[0].shape[0]
    in_specs = [pl.BlockSpec((block_batch, v.shape[1]), lambda i: (i, 0))
                for v in inputs]
    in_specs += [payload_block_spec(p, block_batch) for p in payloads]
    out_specs = [pl.BlockSpec((block_batch, k_out), lambda i: (i, 0))]
    out_shapes = [jax.ShapeDtypeStruct((padded, k_out), dtype)]
    if want_perm:
        out_specs.append(pl.BlockSpec((block_batch, k_out), lambda i: (i, 0)))
        out_shapes.append(jax.ShapeDtypeStruct((padded, k_out), jnp.int32))
    for p in payloads:
        shp = (padded, k_out) + p.shape[2:]
        out_specs.append(
            pl.BlockSpec((block_batch, k_out) + p.shape[2:],
                         (lambda i: (i, 0, 0)) if p.ndim == 3
                         else (lambda i: (i, 0))))
        out_shapes.append(jax.ShapeDtypeStruct(shp, p.dtype))
    results = pl.pallas_call(
        kernel,
        grid=(padded // block_batch,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(*inputs, *payloads)
    res = unpack_fused_results(results, bsz, padded, len(payloads), want_perm)
    if not payloads and not want_perm:
        return res, None, ()  # shared epilogue returns the bare values
    return res


@functools.partial(
    jax.jit,
    static_argnames=("k_out", "network", "encode", "flip", "want_perm",
                     "block_batch", "use_mxu", "interpret"),
)
def segment_class_sort_pallas(
    dense: jnp.ndarray,  # (S, W) raw segment rows, W a power of two
    lens: jnp.ndarray,  # (S, 1) int32 valid lengths (0 <= len <= W)
    payloads: Sequence[jnp.ndarray] = (),  # (S, W[, F]) dense lanes
    *,
    k_out: Optional[int] = None,  # truncate stored prefix (top-k); None = W
    network: str = "loms",  # registered network family for the merge tree
    encode: bool = True,  # fuse the total-order float key transform
    flip: bool = False,  # descending order (exact key bit-flip)
    want_perm: bool = False,
    block_batch: int = 8,
    use_mxu: bool = False,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray], Tuple[jnp.ndarray, ...]]:
    """One size-class sort launch: every row sorted independently, valid
    prefix first. Returns ``(out, perm | None, payload_outs)`` — ``out``
    holds raw input values gathered at the sort permutation (bit-exact),
    ``perm`` the within-segment input positions; lanes past ``lens`` are
    unspecified (the CSR scatter never reads them)."""
    s, w = dense.shape
    assert w & (w - 1) == 0, f"class width {w} must be a power of two"
    k_out = w if k_out is None else int(k_out)
    assert 1 <= k_out <= w, (k_out, w)
    encode = encode and jnp.issubdtype(dense.dtype, jnp.floating)
    kernel = functools.partial(
        _seg_sort_kernel, w=w, k_out=k_out, network=network, encode=encode,
        flip=flip, use_mxu=use_mxu, n_payload=len(payloads),
        want_perm=want_perm,
    )
    return _class_call(
        kernel, [dense, lens.astype(jnp.int32)], tuple(payloads),
        k_out=k_out, block_batch=block_batch,
        want_perm=want_perm, interpret=interpret, dtype=dense.dtype,
    )


@functools.partial(
    jax.jit,
    static_argnames=("k_out", "network", "encode", "flip", "want_perm",
                     "block_batch", "use_mxu", "n_cols", "interpret"),
)
def segment_class_merge_pallas(
    dense_a: jnp.ndarray,  # (S, Wa) sorted segment rows (pow2 width)
    dense_b: jnp.ndarray,  # (S, Wb)
    lens_a: jnp.ndarray,  # (S, 1) int32
    lens_b: jnp.ndarray,  # (S, 1) int32
    payloads: Sequence[jnp.ndarray] = (),  # (S, Wa+Wb[, F]) dense-coord lanes
    *,
    k_out: Optional[int] = None,
    network: str = "loms",
    encode: bool = True,
    flip: bool = False,
    want_perm: bool = False,
    block_batch: int = 8,
    use_mxu: bool = False,
    n_cols: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray], Tuple[jnp.ndarray, ...]]:
    """One size-class 2-way merge launch: row ``s`` merges the sorted runs
    ``a[s, :lens_a[s]]`` and ``b[s, :lens_b[s]]``. ``perm`` is in segment
    coordinates (b positions offset by the *valid* a length, matching the
    concatenated-segment payload convention of ``repro.merge``); payload
    lanes arrive in dense ``[a | b]`` coordinates of width ``Wa + Wb``."""
    s, wa = dense_a.shape
    wb = dense_b.shape[1]
    assert wa & (wa - 1) == 0 and wb & (wb - 1) == 0, (wa, wb)
    total = wa + wb
    k_out = total if k_out is None else int(k_out)
    assert 1 <= k_out <= total, (k_out, total)
    encode = encode and jnp.issubdtype(dense_a.dtype, jnp.floating)
    n_cols = pick_merge_cols(wa, wb) if n_cols is None else int(n_cols)
    kernel = functools.partial(
        _seg_merge_kernel, wa=wa, wb=wb, k_out=k_out, network=network,
        n_cols=n_cols, encode=encode, flip=flip, use_mxu=use_mxu,
        n_payload=len(payloads), want_perm=want_perm,
    )
    return _class_call(
        kernel,
        [dense_a, dense_b, lens_a.astype(jnp.int32), lens_b.astype(jnp.int32)],
        tuple(payloads), k_out=k_out,
        block_batch=block_batch, want_perm=want_perm, interpret=interpret,
        dtype=dense_a.dtype,
    )
