"""Pallas TPU kernel: batched 2-way List Offset merge (paper Section IV).

Layout strategy (hardware adaptation, DESIGN.md §2): the k-column setup
array for UP-m/DN-n with C columns assigns
    A_j  -> column j % C            (ascending stride-C slices of ``a``)
    B_j  -> column (n-1-j) % C      (ascending stride-C slices of ``b``)
so for C | m and C | n the whole setup array is built from *strided
reshapes* — no gathers touch VMEM. Stage 1 merges each column's two runs
with the S2MS comparison cloud (VPU) + one-hot permute (MXU); stage 2
rank-sorts each row of C values. Output is the row-major flatten, again a
plain reshape.

Per-block VMEM: (m+n) values + the widest column comparison matrix
(m/C * n/C bools) + the row-sort matrix (R * C^2) — tile the batch so this
fits the ~16 MiB VMEM budget (``ops.loms_merge2`` picks the tile).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import merge2_sorted, pad_batch, resolve_interpret, sort_nsorter


def _loms2_kernel(a_ref, b_ref, o_ref, *, n_cols: int, use_mxu: bool):
    a = a_ref[...]  # (bt, m) ascending
    b = b_ref[...]  # (bt, n) ascending
    bt, m = a.shape
    n = b.shape[-1]
    c_ = n_cols
    # --- setup array as strided views; stage 1: per-column S2MS merges ----
    cols = []
    for c in range(c_):
        av = a[:, c::c_]  # A_j with j % C == c, ascending
        bv = b[:, (c_ - 1 - c) % c_ :: c_]  # B_j with (n-1-j)%C == c
        # column bottom->top = [B run, A run]
        col = merge2_sorted(bv, av, use_mxu=use_mxu)  # (bt, R)
        cols.append(col)
    # --- stage 2: row sorts across columns ---------------------------------
    # ascending within a row is col0, col1, ..., col_{C-1} (right->left)
    arr = jnp.stack(cols, axis=-1)  # (bt, R, C)
    arr = sort_nsorter(arr, use_mxu=use_mxu)
    o_ref[...] = arr.reshape(bt, m + n)


@functools.partial(
    jax.jit, static_argnames=("n_cols", "block_batch", "use_mxu", "interpret")
)
def loms_merge2_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    n_cols: int = 2,
    block_batch: int = 8,
    use_mxu: bool = True,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Merge sorted ``a`` (B, m) and ``b`` (B, n) -> (B, m+n).

    Requires n_cols | m and n_cols | n (the hole-free fast path; ragged
    sizes fall back to the schedule executor in ops.py). Ragged batch
    sizes are padded up to a ``block_batch`` multiple and sliced back.
    ``interpret=None`` auto-resolves: compile on TPU, interpret elsewhere."""
    interpret = resolve_interpret(interpret)
    (bsz, m), (_, n) = a.shape, b.shape
    assert m % n_cols == 0 and n % n_cols == 0, (m, n, n_cols)
    a, b = pad_batch(a, block_batch), pad_batch(b, block_batch)
    padded = a.shape[0]
    grid = (padded // block_batch,)
    out = pl.pallas_call(
        functools.partial(_loms2_kernel, n_cols=n_cols, use_mxu=use_mxu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_batch, m), lambda i: (i, 0)),
            pl.BlockSpec((block_batch, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_batch, m + n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, m + n), a.dtype),
        interpret=interpret,
    )(a, b)
    return out[:bsz] if padded != bsz else out
