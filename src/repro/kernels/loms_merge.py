"""Pallas TPU kernel: batched 2-way List Offset merge (paper Section IV).

Layout strategy (hardware adaptation, DESIGN.md §2): the k-column setup
array for UP-m/DN-n with C columns assigns
    A_j  -> column j % C            (ascending stride-C slices of ``a``)
    B_j  -> column (n-1-j) % C      (ascending stride-C slices of ``b``)
so for C | m and C | n the whole setup array is built from *strided
reshapes* — no gathers touch VMEM. Stage 1 merges each column's two runs
with the S2MS comparison cloud (VPU) + one-hot permute (MXU); stage 2
rank-sorts each row of C values. Output is the row-major flatten, again a
plain reshape.

Fused pipeline extensions (DESIGN.md §11): the kernel optionally
* encodes the total-order float->int key transform on load and decodes it
  on store (``key_dtype=``) so ``nan_policy="last"`` costs zero extra HBM
  passes,
* threads an int32 position lane through the same permutes and gathers
  payload lanes in VMEM (``payloads=``), so payload merges stop
  materializing an index array and gathering at the XLA level,
* handles ``descending=`` inputs by reversing on load/store in-register.

Per-block VMEM: (m+n) values + the widest column comparison matrix
(m/C * n/C bools) + the row-sort matrix (R * C^2) — tile the batch so this
fits the ~16 MiB VMEM budget (``streaming.planner`` picks the tile).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.networks import merge_program, merge_runs

from .common import (
    _iota,
    decode_key_values,
    encode_key_values,
    gather_lanes,
    pad_batch,
    payload_block_spec,
    resolve_interpret,
    unpack_fused_results,
)


def _loms2_kernel(
    a_ref,
    b_ref,
    *refs,
    network: str,
    n_cols: int,
    use_mxu: bool,
    key_dtype: Optional[str],
    descending: bool,
    n_payload: int,
    want_perm: bool,
):
    p_refs = refs[:n_payload]
    o_ref = refs[n_payload]
    perm_ref = refs[n_payload + 1] if want_perm else None
    po_refs = refs[n_payload + 1 + (1 if want_perm else 0):]

    a = a_ref[...]  # (bt, m) ascending (descending reversed below)
    b = b_ref[...]  # (bt, n)
    bt, m = a.shape
    n = b.shape[-1]
    if descending:  # reverse in-register: the merge itself is ascending
        a, b = a[:, ::-1], b[:, ::-1]
    if key_dtype is not None:  # fused nan_policy="last" encode
        a = encode_key_values(a)
        b = encode_key_values(b)
    need_pos = n_payload > 0 or want_perm
    pa = pb = None
    if need_pos:
        # positions index the *original* orientation of concat(a, b), the
        # same convention the unfused executor's position payload uses
        pa = _iota((bt, m), 1)
        pb = _iota((bt, n), 1) + m
        if descending:
            pa = (m - 1) - _iota((bt, m), 1)
            pb = ((n - 1) - _iota((bt, n), 1)) + m
    # the merge structure comes from the network registry: the LOMS column
    # device (n_cols strided views), the S2MS cloud, or a pair network
    prog = merge_program(network, m, n,
                         n_cols if network == "loms" else None)
    if need_pos:
        out, perm = merge_runs(prog, a, b, use_mxu=use_mxu,
                               payload=(pa, pb))
        perm = perm.astype(jnp.int32)
    else:
        out = merge_runs(prog, a, b, use_mxu=use_mxu)
        perm = None
    if key_dtype is not None:  # fused decode on store
        out = decode_key_values(out, key_dtype)
    if descending:
        out = out[:, ::-1]
        perm = None if perm is None else perm[:, ::-1]
    o_ref[...] = out
    if want_perm:
        perm_ref[...] = perm
    for p_ref, po_ref in zip(p_refs, po_refs):
        po_ref[...] = gather_lanes(perm, p_ref[...])


@functools.partial(
    jax.jit,
    static_argnames=(
        "network", "n_cols", "block_batch", "use_mxu", "interpret",
        "key_dtype", "descending", "want_perm",
    ),
)
def loms_merge2_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    payloads: Sequence[jnp.ndarray] = (),
    *,
    network: str = "loms",
    n_cols: int = 2,
    block_batch: int = 8,
    use_mxu: bool = True,
    interpret: Optional[bool] = None,
    key_dtype: Optional[str] = None,
    descending: bool = False,
    want_perm: bool = False,
):
    """Merge sorted ``a`` (B, m) and ``b`` (B, n) -> (B, m+n).

    ``network`` names a registered family (``repro.networks``); the
    default LOMS path requires n_cols | m and n_cols | n (the hole-free
    fast path; ragged sizes fall back to the schedule executor in
    ops.py), other families carry their own shape capability (e.g.
    bitonic needs a pow2 total). Ragged batch sizes are padded up to a
    ``block_batch`` multiple and sliced back. ``interpret=None``
    auto-resolves: compile on TPU, interpret elsewhere.

    Fused-pipeline extras (all handled inside the one kernel launch):
    ``key_dtype`` — name of the original float dtype; the kernel applies
    the total-order int-key encode on load and the inverse on store
    (callers pass int-unsafe ``use_mxu=False``). ``descending`` — inputs
    are descending-sorted; so is the output. ``payloads`` — sequence of
    (B, m+n[, F]) lanes, the per-list payloads already concatenated along
    the list axis; each rides the merge permutation in VMEM and is
    returned permuted. ``want_perm`` — also return the int32 permutation.

    Returns ``out`` alone in the classic call, else
    ``(out, perm | None, tuple(payload_outs))``.
    """
    interpret = resolve_interpret(interpret)
    (bsz, m), (_, n) = a.shape, b.shape
    if network == "loms":
        assert m % n_cols == 0 and n % n_cols == 0, (m, n, n_cols)
    payloads = tuple(payloads)
    for p in payloads:
        assert p.ndim in (2, 3) and p.shape[:2] == (bsz, m + n), (
            p.shape, (bsz, m + n))
    a, b = pad_batch(a, block_batch), pad_batch(b, block_batch)
    payloads = tuple(pad_batch(p, block_batch) for p in payloads)
    padded = a.shape[0]
    grid = (padded // block_batch,)
    p_specs = [payload_block_spec(p, block_batch) for p in payloads]
    out_specs = [pl.BlockSpec((block_batch, m + n), lambda i: (i, 0))]
    out_shapes = [jax.ShapeDtypeStruct((padded, m + n), a.dtype)]
    if want_perm:
        out_specs.append(pl.BlockSpec((block_batch, m + n), lambda i: (i, 0)))
        out_shapes.append(jax.ShapeDtypeStruct((padded, m + n), jnp.int32))
    out_specs += [payload_block_spec(p, block_batch) for p in payloads]
    out_shapes += [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in payloads]
    results = pl.pallas_call(
        functools.partial(
            _loms2_kernel, network=network, n_cols=n_cols, use_mxu=use_mxu,
            key_dtype=key_dtype, descending=descending,
            n_payload=len(payloads), want_perm=want_perm,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_batch, m), lambda i: (i, 0)),
            pl.BlockSpec((block_batch, n), lambda i: (i, 0)),
            *p_specs,
        ],
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(a, b, *payloads)
    return unpack_fused_results(results, bsz, padded, len(payloads), want_perm)
