"""AdamW + warmup-cosine schedule + global-norm clipping (pure JAX).

Optimizer state mirrors the parameter tree, so it inherits the parameter
shardings (ZeRO: moments are partitioned exactly like their weights).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(step, oc: OptConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    t = jnp.clip((step - oc.warmup_steps) /
                 jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = oc.min_lr_frac + (1 - oc.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return oc.lr * warm * cos


def opt_init(params):
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"mu": zeros(params), "nu": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def opt_update(grads, state, params, oc: OptConfig):
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(step, oc)
    b1c = 1 - oc.b1 ** step.astype(jnp.float32)
    b2c = 1 - oc.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = oc.b1 * m + (1 - oc.b1) * g
        v = oc.b2 * v + (1 - oc.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        step_ = mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["mu"])
    flat_v = jax.tree.leaves(state["nu"])
    flat_p = jax.tree.leaves(params)
    new = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(tdef, [n[0] for n in new])
    new_m = jax.tree.unflatten(tdef, [n[1] for n in new])
    new_v = jax.tree.unflatten(tdef, [n[2] for n in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_m, "nu": new_v, "step": step}, metrics
