from .adamw import OptConfig, opt_init, opt_update, schedule, global_norm  # noqa: F401
