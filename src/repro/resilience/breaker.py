"""Per-(op, rung, shape-class) circuit breakers for the dispatch ladder.

A breaker guards one *rung* of the degradation ladder (``"fused"``,
``"pallas"``, ``"streaming"``, ...) for one op at one shape class. The
classic three-state machine:

* **closed** — healthy; every call flows. ``failures`` consecutive
  recorded failures (default :data:`DEFAULT_THRESHOLD`) open it.
* **open** — the rung is skipped at both plan time (``plan()`` reroutes
  down the ladder, ``source="breaker"``) and run time. After
  ``cooldown_s`` the next ``allow()`` becomes the half-open probe.
* **half-open** — exactly one probe call is let through; success closes
  the breaker (failure count reset), failure re-opens it for another
  cooldown. Concurrent calls during the probe stay rerouted.

Shape classes bucket problems by pow2 total size + payload/plain so one
pathological shape can't poison (or be hidden by) every other size, while
cardinality stays bounded. State transitions surface as
``breaker.state`` gauges (0=closed, 1=open, 2=half-open) and
``breaker.transitions`` counters.

The registry starts empty and breakers are created on the first recorded
*failure* — a healthy process pays one dict lookup per plan, nothing
more.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional, Tuple

DEFAULT_THRESHOLD = 3
DEFAULT_COOLDOWN_S = 30.0

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"
_STATE_NUM = {CLOSED: 0, OPEN: 1, HALF_OPEN: 2}


def shape_class(total: int, has_payload: bool) -> str:
    """Bounded-cardinality shape bucket: pow2 ceiling of the total
    element count plus the payload/plain split."""
    p2 = 1
    while p2 < max(int(total), 1):
        p2 <<= 1
    return f"{p2}{'p' if has_payload else 'v'}"


class CircuitBreaker:
    def __init__(self, key: Tuple[str, str, str],
                 threshold: int = DEFAULT_THRESHOLD,
                 cooldown_s: float = DEFAULT_COOLDOWN_S):
        self.key = key  # (op, rung, shape_class)
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self.state = CLOSED
        self.failures = 0
        self.opened_at = 0.0
        self._lock = threading.Lock()

    # ------------------------------------------------------------- state

    def allow(self) -> bool:
        """Whether a call may take this rung now. The transition to
        half-open happens here: the first ``allow()`` past the cooldown
        is the probe and returns True; followers stay blocked until the
        probe reports."""
        with self._lock:
            if self.state == CLOSED:
                return True
            if self.state == OPEN:
                if time.monotonic() - self.opened_at >= self.cooldown_s:
                    self._transition(HALF_OPEN)
                    return True
                return False
            return False  # HALF_OPEN: one probe already in flight

    def peek(self) -> bool:
        """Non-mutating :meth:`allow`: True if a call *would* be admitted.
        Plan-time rerouting peeks so it never consumes the half-open
        probe slot — the run-time walk does the actual admission."""
        with self._lock:
            if self.state == CLOSED:
                return True
            if self.state == OPEN:
                return time.monotonic() - self.opened_at >= self.cooldown_s
            return False

    def record_failure(self) -> None:
        with self._lock:
            self.failures += 1
            if self.state == HALF_OPEN or (
                    self.state == CLOSED and self.failures >= self.threshold):
                self.opened_at = time.monotonic()
                self._transition(OPEN)

    def record_success(self) -> None:
        with self._lock:
            if self.state != CLOSED or self.failures:
                self.failures = 0
                self._transition(CLOSED)

    def _transition(self, state: str) -> None:
        if state == self.state:
            return
        prev, self.state = self.state, state
        from repro.obs import metrics as obs_metrics
        from repro.obs import recorder as obs_recorder

        op, rung, cls = self.key
        obs_metrics.gauge("breaker.state").set(
            _STATE_NUM[state], op=op, rung=rung, cls=cls)
        obs_metrics.counter("breaker.transitions").inc(
            op=op, rung=rung, cls=cls, frm=prev, to=state)
        obs_recorder.emit("breaker", f"{op}/{rung}/{cls}",
                          frm=prev, to=state, failures=self.failures)


_reg_lock = threading.Lock()
_registry: Dict[Tuple[str, str, str], CircuitBreaker] = {}
_threshold = DEFAULT_THRESHOLD
_cooldown_s = DEFAULT_COOLDOWN_S


def breaker_for(op: str, rung: str, cls: str,
                create: bool = True) -> Optional[CircuitBreaker]:
    """The breaker guarding (op, rung, cls); ``create=False`` returns
    None instead of materializing one (the plan-time fast path)."""
    key = (op, rung, cls)
    with _reg_lock:
        br = _registry.get(key)
        if br is None and create:
            br = _registry[key] = CircuitBreaker(key, _threshold, _cooldown_s)
        return br


def rung_allowed(op: str, rung: str, cls: str) -> bool:
    """Plan-time check: True unless an existing breaker blocks the rung.
    Never creates a breaker (with no recorded failures this is one dict
    miss) and never mutates one (:meth:`CircuitBreaker.peek`)."""
    br = breaker_for(op, rung, cls, create=False)
    return True if br is None else br.peek()


def any_breakers() -> bool:
    """Whether any breaker has ever been materialized — the healthy-path
    short-circuit for plan-time rerouting."""
    return bool(_registry)


def configure(threshold: Optional[int] = None,
              cooldown_s: Optional[float] = None) -> None:
    """Set thresholds for breakers created *after* this call (tests and
    embedding apps; existing breakers keep their parameters)."""
    global _threshold, _cooldown_s
    if threshold is not None:
        _threshold = int(threshold)
    if cooldown_s is not None:
        _cooldown_s = float(cooldown_s)


def reset() -> None:
    """Drop every breaker and restore default thresholds (tests)."""
    global _threshold, _cooldown_s
    with _reg_lock:
        _registry.clear()
    _threshold = DEFAULT_THRESHOLD
    _cooldown_s = DEFAULT_COOLDOWN_S


def states() -> Dict[Tuple[str, str, str], str]:
    """Snapshot of every materialized breaker's state."""
    with _reg_lock:
        return {k: br.state for k, br in _registry.items()}
