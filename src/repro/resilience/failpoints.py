"""Deterministic fault injection: named failpoints at the risky seams.

A *failpoint* is a named call site (``failpoint("kernel.launch.sort")``)
threaded through the places where the stack can genuinely die in
production — kernel launch wrappers, autotune-cache I/O, streaming
refill, segmented spill, scheduler prefill/insert/decode. Disarmed (the
default) every call is a strict no-op: one truthiness check on an empty
dict, no allocation, no RNG draw — the chaos suite asserts jaxpr op
counts are unchanged with ``REPRO_FAILPOINTS`` unset.

Armed, a failpoint fires :class:`FailpointError` according to its
*trigger*, every one of which is deterministic given the arming spec:

=============  ========================================================
``once``       fire on the first hit, then disarm
``always``     fire on every hit
``times:N``    fire on the first N hits
``every:N``    fire on every Nth hit (N, 2N, ...)
``p:P[:S]``    fire with probability P per hit, seeded RNG (seed S,
               default 0) — the same hit sequence always fires the same
               hits, across runs and machines
``off``        never fire (placeholder that still counts hits)
=============  ========================================================

Arming happens via the ``REPRO_FAILPOINTS`` env var
(``"name=trigger,name=trigger"``, parsed once at first use) or the
context-manager API::

    with failpoints({"kernel.launch": "once", "cache.load": "p:0.5:7"}):
        ...

Names are hierarchical on dot boundaries: arming ``kernel.launch``
matches calls to ``kernel.launch.sort`` and ``kernel.launch.topk`` (an
exact arming wins over a prefix). Hit and fire counts are queryable
(:func:`hits`, :func:`fires`) and surface as ``failpoints.fired`` obs
counters, so a chaos run can assert exactly which seams were exercised.
"""
from __future__ import annotations

import contextlib
import os
import random
import threading
from typing import Dict, Iterator, Optional

_ENV = "REPRO_FAILPOINTS"


class FailpointError(RuntimeError):
    """The injected failure. Carries the failpoint name so handlers and
    tests can tell an injected fault from a genuine one."""

    def __init__(self, name: str):
        super().__init__(f"injected failpoint {name!r} fired")
        self.name = name


class _Failpoint:
    """One armed failpoint: a trigger plus deterministic hit counters."""

    __slots__ = ("name", "mode", "arg", "seed", "hits", "fires", "_rng")

    def __init__(self, name: str, spec: str):
        self.name = name
        parts = str(spec).split(":")
        self.mode = parts[0]
        self.arg = 0.0
        self.seed = 0
        if self.mode in ("times", "every"):
            self.arg = int(parts[1])
            assert self.arg >= 1, spec
        elif self.mode == "p":
            self.arg = float(parts[1])
            assert 0.0 <= self.arg <= 1.0, spec
            self.seed = int(parts[2]) if len(parts) > 2 else 0
        elif self.mode not in ("once", "always", "off"):
            raise ValueError(
                f"unknown failpoint trigger {spec!r} for {name!r} "
                "(want once|always|times:N|every:N|p:P[:seed]|off)")
        self.hits = 0
        self.fires = 0
        self._rng = random.Random(self.seed)

    def should_fire(self) -> bool:
        self.hits += 1
        if self.mode == "off":
            return False
        if self.mode == "always":
            return True
        if self.mode == "once":
            return self.hits == 1
        if self.mode == "times":
            return self.hits <= self.arg
        if self.mode == "every":
            return self.hits % int(self.arg) == 0
        # mode == "p": one seeded draw per hit — same sequence every run
        return self._rng.random() < self.arg


_lock = threading.Lock()
#: the armed set; empty == fully disabled (the hot-path predicate)
_active: Dict[str, _Failpoint] = {}
_env_parsed = False


def _parse_env() -> None:
    global _env_parsed
    if _env_parsed:
        return
    _env_parsed = True
    raw = os.environ.get(_ENV, "").strip()
    if not raw:
        return
    for item in raw.split(","):
        item = item.strip()
        if not item:
            continue
        name, _, spec = item.partition("=")
        _active[name.strip()] = _Failpoint(name.strip(), spec.strip() or "once")


# parse eagerly at import: the fast path stays one dict-truthiness check
_parse_env()


def _lookup(name: str) -> Optional[_Failpoint]:
    fp = _active.get(name)
    if fp is not None:
        return fp
    # hierarchical prefix match on dot boundaries: "kernel.launch" arms
    # every "kernel.launch.*" call site
    n = name
    while True:
        cut = n.rfind(".")
        if cut < 0:
            return None
        n = n[:cut]
        fp = _active.get(n)
        if fp is not None:
            return fp


def failpoint(name: str) -> None:
    """The seam: raise :class:`FailpointError` if ``name`` is armed and
    its trigger fires. Strict no-op when nothing is armed."""
    if not _active:  # the disabled fast path
        return
    with _lock:
        fp = _lookup(name)
        if fp is None or not fp.should_fire():
            return
        fp.fires += 1
    from repro.obs import metrics as obs_metrics
    from repro.obs import recorder as obs_recorder

    obs_metrics.counter("failpoints.fired").inc(name=fp.name)
    obs_recorder.emit("failpoint", name, armed_as=fp.name, fire=fp.fires)
    raise FailpointError(name)


def arm(name: str, spec: str = "once") -> None:
    """Arm one failpoint programmatically (same spec grammar as the env)."""
    with _lock:
        _active[name] = _Failpoint(name, spec)


def disarm(name: str) -> None:
    with _lock:
        _active.pop(name, None)


def reset() -> None:
    """Disarm everything (tests; does not re-read the env)."""
    with _lock:
        _active.clear()


def active() -> Dict[str, str]:
    """Armed failpoints as {name: mode} (inspection / logging)."""
    with _lock:
        return {n: fp.mode for n, fp in _active.items()}


def hits(name: str) -> int:
    """Times the named failpoint's seam was reached while armed."""
    with _lock:
        fp = _active.get(name)
        return fp.hits if fp else 0


def fires(name: str) -> int:
    """Times the named failpoint actually raised."""
    with _lock:
        fp = _active.get(name)
        return fp.fires if fp else 0


@contextlib.contextmanager
def failpoints(specs: Dict[str, str]) -> Iterator[None]:
    """Arm ``{name: trigger}`` for the body, restoring the previous arming
    (including counters) on exit — nesting composes."""
    with _lock:
        saved = dict(_active)
        for name, spec in specs.items():
            _active[name] = _Failpoint(name, spec)
    try:
        yield
    finally:
        with _lock:
            _active.clear()
            _active.update(saved)
