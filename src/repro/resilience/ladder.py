"""Graceful-degradation ladder: fused-pallas → unfused-pallas →
streaming → schedule → lax, with circuit breakers per rung.

The unified ops (:mod:`repro.api.ops`) build one ``attempt(rung)``
closure per call and hand it here. :func:`run_ladder` walks the rung
list the planner produced (:func:`rungs_for`) and, when resilience is on
and the spec is auto-routed, catches a failed rung — kernel compile
error, injected failpoint, OOM-style launch failure — records it against
that rung's circuit breaker, counts a ``resilience.fallbacks`` sample,
and tries the next rung. Every backend is bit-identical by the repo's
standing contract (the bit-equality suites gate it), so a degraded
answer is the *same* answer, only slower.

Semantics that keep this invisible in healthy runs:

* Resilience off (``REPRO_RESILIENCE=0`` or :func:`set_resilience_enabled`)
  or an explicit ``backend=`` ask: the first applicable rung runs and its
  exceptions propagate untouched — exactly the pre-resilience behavior,
  op-for-op (a rung may still *decline* with :class:`LadderSkip`, which
  reproduces the old fused-config fallthrough).
* No failures ever recorded: the breaker registry is empty, so the
  plan-time check (:func:`reroute`) is one dict miss and the run-time
  walk takes the first rung.

Scope note: a rung failure is observable here when it raises on the
Python side — eager calls, trace/lowering/compile errors under ``jit``.
A hardware fault inside an already-compiled XLA executable raises at the
jit boundary instead; the serving engine's retry/backoff layer
(:mod:`repro.serving.scheduler.engine`) owns that case.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, List, Optional, Sequence

from .breaker import any_breakers, breaker_for, rung_allowed, shape_class

_ENABLED = True

#: degradation tail, most- to least-specialized; ``fused``/``pallas``
#: are prepended when the plan picked the kernel backend
LADDER_TAIL = ("streaming", "schedule", "lax")


def resilience_enabled() -> bool:
    """``REPRO_RESILIENCE=0`` pins every call to its planned rung (a
    failure then propagates instead of degrading)."""
    return _ENABLED and os.environ.get("REPRO_RESILIENCE", "1") != "0"


def set_resilience_enabled(enabled: bool) -> bool:
    """Toggle the ladder programmatically (returns the previous value)."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(enabled)
    return prev


class LadderSkip(Exception):
    """Raised by an ``attempt`` to decline a rung without failing it
    (e.g. the fused config resolved to None). Never counted against a
    breaker."""


class ResilienceExhausted(RuntimeError):
    """Every rung of the ladder failed for this call."""

    def __init__(self, op: str, rungs: Sequence[str]):
        super().__init__(
            f"every ladder rung failed for op {op!r} (tried {list(rungs)})")
        self.op = op
        self.rungs = tuple(rungs)


def _backend_of(rung: str) -> str:
    return "pallas" if rung == "fused" else rung


def spec_class(spec) -> str:
    return shape_class(spec.total, spec.has_payload)


def rungs_for(spec, dec) -> List[str]:
    """Ordered, capability-filtered rung list for one planned call.

    Explicit backend asks get exactly their backend (plus the fused rung
    when that backend is pallas — the fused/unfused split is an internal
    realization detail, not a routing choice). Auto asks get the planned
    rung followed by the degradation tail; rungs whose backend cannot
    run the spec (``supports``) are dropped, as is unfused pallas for
    permutation-carrying specs (its generic adapters are values-only)."""
    from repro.api.registry import get_backend
    from repro.api.spec import BACKEND_AUTO

    if spec.backend != BACKEND_AUTO:
        if dec.backend != "pallas":
            return [dec.backend]  # honor the ask verbatim, errors and all
        if spec.needs_perm and spec.op != "topk":
            return ["fused", "schedule"]  # pre-ladder unfusable remap
        return ["fused", "pallas"]
    head: List[str] = (["fused", "pallas"] if dec.backend == "pallas"
                       else [dec.backend])
    rungs = head + [b for b in LADDER_TAIL if b not in head]
    out: List[str] = []
    for r in rungs:
        if r == "fused":
            out.append(r)  # eligibility resolves at attempt time (cfg)
            continue
        if r == "pallas" and spec.needs_perm and spec.op != "topk":
            continue  # unfused pallas merge/sort adapters are values-only
            # (top-k indices are native, so payload/stable ride them)
        try:
            if get_backend(r).supports(spec):
                out.append(r)
        except ValueError:
            continue
    return out or ["schedule"]


def reroute(spec, dec):
    """Plan-time breaker avoidance: if the planned rung's breaker is open
    for this (op, shape-class), downgrade the decision to the first
    allowed rung (``source="breaker"``). Peeks only — the half-open
    probe admission happens at run time."""
    from repro.api.spec import BACKEND_AUTO

    if (not any_breakers() or not resilience_enabled()
            or spec.backend != BACKEND_AUTO or dec.backend in ("segmented",)):
        return dec
    cls = spec_class(spec)
    rungs = rungs_for(spec, dec)
    for rung in rungs:
        if not rung_allowed(spec.op, rung, cls):
            continue
        backend = _backend_of(rung)
        if backend == dec.backend:
            return dec
        return dataclasses.replace(
            dec, backend=backend, detail="degraded", source="breaker",
            reason=(f"breaker open for ({spec.op}, {dec.backend}, {cls}): "
                    f"degraded to {backend}"))
    return dec  # everything open: keep the plan, run_ladder force-runs


def run_ladder(spec, rungs: Sequence[str], attempt: Callable[[str], object],
               cls: Optional[str] = None):
    """Execute ``attempt`` down the rung list.

    With resilience off or an explicit backend ask this reduces to "run
    the first rung that does not :class:`LadderSkip`" with no exception
    handling — the pre-resilience code path. Otherwise failed rungs feed
    their breakers and the walk continues; if every rung was skipped by
    an open breaker the last capable rung is force-run (an answer beats
    a refusal), and if every rung genuinely failed the last error chains
    into :class:`ResilienceExhausted`."""
    from repro.api.spec import BACKEND_AUTO
    from repro.obs import metrics as obs_metrics
    from repro.obs import recorder as obs_recorder

    catching = resilience_enabled() and spec.backend == BACKEND_AUTO
    if not catching:
        for i, rung in enumerate(rungs):
            try:
                return attempt(rung)
            except LadderSkip:
                if i == len(rungs) - 1:
                    raise
        raise LadderSkip  # unreachable: rungs is never empty

    cls = cls or spec_class(spec)
    last_exc: Optional[BaseException] = None
    blocked: List[str] = []
    for rung in rungs:
        br = breaker_for(spec.op, rung, cls, create=False)
        if br is not None and not br.allow():
            blocked.append(rung)
            continue
        try:
            result = attempt(rung)
        except LadderSkip:
            continue
        except Exception as e:  # noqa: BLE001 — any rung failure degrades
            (br or breaker_for(spec.op, rung, cls)).record_failure()
            obs_metrics.counter("resilience.fallbacks").inc(
                op=spec.op, rung=rung, cls=cls, err=type(e).__name__)
            obs_recorder.emit("fallback", f"{spec.op}/{rung}/{cls}",
                              err=type(e).__name__)
            last_exc = e
            continue
        if br is not None:
            br.record_success()
        return result
    if last_exc is None and blocked:
        # every rung breaker-blocked: force the most degraded one — the
        # ladder exists to keep answering
        obs_metrics.counter("resilience.forced").inc(
            op=spec.op, rung=blocked[-1], cls=cls)
        obs_recorder.emit("forced", f"{spec.op}/{blocked[-1]}/{cls}")
        return attempt(blocked[-1])
    raise ResilienceExhausted(spec.op, rungs) from last_exc
