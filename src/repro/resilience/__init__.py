"""Resilience subsystem (DESIGN.md §16): deterministic fault injection,
the graceful-degradation dispatch ladder, and circuit breakers.

Three pieces, layered so each is useful alone:

* :mod:`repro.resilience.failpoints` — named, seeded fault-injection
  seams (``REPRO_FAILPOINTS`` env / :func:`failpoints` context manager)
  threaded through kernel launches, cache I/O, streaming refill,
  segmented spill, and the serving scheduler. Strict no-op when unarmed.
* :mod:`repro.resilience.breaker` — per-(op, rung, shape-class) circuit
  breakers: N failures open, cooldown, half-open probe, close.
* :mod:`repro.resilience.ladder` — the degradation ladder the unified
  ops execute through: fused-pallas → unfused-pallas → streaming →
  schedule → lax, every rung bit-identical, ``REPRO_RESILIENCE=0``
  opt-out.
"""
from .breaker import (  # noqa: F401
    CircuitBreaker,
    breaker_for,
    configure as configure_breakers,
    reset as reset_breakers,
    rung_allowed,
    shape_class,
    states as breaker_states,
)
from .failpoints import (  # noqa: F401
    FailpointError,
    arm,
    disarm,
    failpoint,
    failpoints,
    fires,
    hits,
    reset as reset_failpoints,
)
from .ladder import (  # noqa: F401
    LadderSkip,
    ResilienceExhausted,
    resilience_enabled,
    reroute,
    run_ladder,
    rungs_for,
    set_resilience_enabled,
)

__all__ = [
    "CircuitBreaker", "FailpointError", "LadderSkip", "ResilienceExhausted",
    "arm", "breaker_for", "breaker_states", "configure_breakers", "disarm",
    "failpoint", "failpoints", "fires", "hits", "reroute",
    "reset_breakers", "reset_failpoints", "resilience_enabled", "run_ladder",
    "rungs_for", "rung_allowed", "set_resilience_enabled", "shape_class",
]
