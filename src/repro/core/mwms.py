"""Multiway Merge Sort (MWMS) baseline — paper refs [4][5].

The paper uses Kent & Pattichis' earlier Multiway Merge Sorting Networks as
the k-way state of the art: k sorted lists arranged WITHOUT the list offset
(each list is simply one column), merged by alternating stages of parallel
single-stage row sorters and column sorters into serpentine order. Without
the offset setup, more alternating stages are needed — the paper reports 5
stages for a full 3c_7r merge and 4 for its median (vs 3 / 2 for LOMS).

We reconstruct the device generically: build the non-offset array, then add
alternating row/column sort stages until the network passes exhaustive 0-1
validation. For 3c_7r this reconstruction needs 6 full-merge stages (5 for
the median) — one more than the published device (an exhaustive search over
row/column/diagonal stage families found no 5-stage non-offset network, so
the original must use a group structure beyond plain row/col sorts). The
comparison tables therefore report both our reconstruction (6/5) and the
published counts (5/4); LOMS wins against either. See EXPERIMENTS.md
§Paper-validation.
"""
from __future__ import annotations

import functools
from typing import Tuple

from .networks import Group, Schedule, Stage, validate_01_merge
from .setup_array import HOLE, SetupArray


def _non_offset_array(lens: Tuple[int, ...]) -> SetupArray:
    """Column c holds list c, ascending bottom->top, bottom-aligned."""
    k = len(lens)
    rows = max(lens)
    grid = []
    for r in range(rows):
        row = []
        for c in range(k):
            # column index 0 is rightmost; put list 0 in the LEFTMOST column
            lst = k - 1 - c
            row.append((lst, r) if r < lens[lst] else HOLE)
        grid.append(tuple(row))
    return SetupArray(lens=tuple(lens), n_cols=k, grid=tuple(grid))


def _row_stage(arr: SetupArray) -> Stage:
    groups = []
    for r in range(arr.n_rows):
        idx = arr.row_cells(r, ascending_right_to_left=(r % 2 == 0))
        if len(idx) >= 2:
            groups.append(Group(idx=idx))
    return Stage(groups=tuple(groups))


def _col_stage(arr: SetupArray) -> Stage:
    groups = []
    for c in range(arr.n_cols):
        cells = arr.column_cells(c)
        if len(cells) >= 2:
            groups.append(Group(idx=tuple(f for f, _ in cells)))
    return Stage(groups=tuple(groups))


@functools.lru_cache(maxsize=None)
def mwms_kway(lens: Tuple[int, ...], max_stages: int = 12) -> Schedule:
    """Non-offset k-way merge network; stage count found by 0-1 validation."""
    lens = tuple(int(x) for x in lens)
    arr = _non_offset_array(lens)
    stages = []
    for s in range(max_stages):
        stages.append(_row_stage(arr) if s % 2 == 0 else _col_stage(arr))
        cand = Schedule(
            name=f"mwms{len(lens)}way_" + "x".join(map(str, lens)),
            size=arr.size,
            setup_scatter=arr.setup_scatter(),
            output_gather=arr.serpentine_output_gather(),
            stages=tuple(stages),
            meta=(("kind", "mwms"), ("lens", lens), ("n_cols", len(lens))),
        )
        if validate_01_merge(cand, lens):
            return cand
    raise RuntimeError(f"MWMS reconstruction did not converge for lens={lens}")


@functools.lru_cache(maxsize=None)
def mwms_median(lens: Tuple[int, ...]) -> Tuple[Schedule, int]:
    """Median via the MWMS device, truncated to the fewest stages whose
    center output is already correct for every 0-1 pattern (the paper
    reports 4 stages for 3c_7r)."""
    import numpy as np

    from .networks import _per_list_sorted_01_patterns, apply_schedule_np

    full = mwms_kway(lens)
    med = (sum(lens) - 1) // 2
    pats = _per_list_sorted_01_patterns(lens)
    want = np.sort(pats, axis=-1)[:, med]
    for n_stages in range(1, len(full.stages) + 1):
        got = apply_schedule_np(full, pats, n_stages)[:, med]
        if (got == want).all():
            sched = Schedule(
                name=full.name + f"_median{n_stages}",
                size=full.size,
                setup_scatter=full.setup_scatter,
                output_gather=full.output_gather,
                stages=full.stages[:n_stages],
                meta=full.meta + (("median_stages", n_stages),),
            )
            return sched, med
    raise RuntimeError("median truncation failed")
