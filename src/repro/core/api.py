"""DEPRECATED — use the unified :mod:`repro.api` namespace.

This module was the original public sorting API. The implementations moved
to :mod:`repro.api.schedules` (the "schedule" backend of the dispatch
layer) and the public surface is now ``repro.merge / merge_k / sort /
topk / median_of_lists`` with planner-driven backend selection, any-axis
support, and pytree payloads. Every function here forwards to its
replacement and emits a :class:`DeprecationWarning`; the shims last one
release and then this module goes away.
"""
from __future__ import annotations

import warnings


def _deprecated(replacement: str):
    def deco(fn):
        def wrapper(*args, **kwargs):
            warnings.warn(
                f"repro.core.api.{fn.__name__} is deprecated; "
                f"use {replacement} instead",
                DeprecationWarning,
                stacklevel=2,
            )
            return fn(*args, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__name__
        wrapper.__doc__ = (
            f"Deprecated: use ``{replacement}``.\n\n{fn.__doc__ or ''}"
        )
        return wrapper

    return deco


def _shim(name: str, replacement: str):
    """Late-bound forward into repro.api.schedules — the implementation
    module imports repro.core, so binding must wait until first call."""

    def fn(*args, **kwargs):
        from repro.api import schedules as _impl

        return getattr(_impl, name)(*args, **kwargs)

    fn.__name__ = name
    fn.__doc__ = f"Forwarded to repro.api.schedules.{name}."
    return _deprecated(replacement)(fn)


merge_schedule = _shim("merge_schedule", "repro.api.schedules.merge_schedule")
merge = _shim("merge", "repro.merge")
merge_k = _shim("merge_k", "repro.merge_k")
sort = _shim("sort", "repro.sort")
topk = _shim("topk", "repro.topk")
median_of_lists = _shim("median_of_lists", "repro.median_of_lists")
median9 = _shim("median9", "repro.api.schedules.median9")


# ---------------------------------------------------------------------------
# streaming subsystem mirrors (use repro.streaming / repro.merge directly)
# ---------------------------------------------------------------------------


@_deprecated("repro.streaming.chunked_merge (or repro.merge, auto-routed)")
def chunked_merge(a, b, **kw):
    from repro.streaming import chunked_merge as _cm

    return _cm(a, b, **kw)


@_deprecated("repro.streaming.chunked_merge_k (or repro.merge_k, auto-routed)")
def chunked_merge_k(lists, **kw):
    from repro.streaming import chunked_merge_k as _cmk

    return _cmk(lists, **kw)


@_deprecated("repro.streaming.tree_topk (or repro.topk with par=)")
def tree_topk(x, k, **kw):
    from repro.streaming import tree_topk as _tt

    return _tt(x, k, **kw)


@_deprecated("repro.streaming.plan_merge2")
def plan_merge(m, n, **kw):
    from repro.streaming import plan_merge2 as _pm

    return _pm(m, n, **kw)
