"""REMOVED — this module's shims lasted the promised one release.

The original ``repro.core.api`` sorting entry points moved to the unified
``repro.*`` namespace two releases ago (the implementations live in
:mod:`repro.api.schedules` as the "schedule" backend). The deprecation
shims that forwarded from here are now gone; any remaining import gets a
precise error instead of a silent behavior drift.

Migration map:

  repro.core.api.merge / merge_k / sort / topk / median_of_lists
      -> repro.merge / merge_k / sort / topk / median_of_lists
  repro.core.api.merge_schedule / median9
      -> repro.api.schedules.merge_schedule / median9
  repro.core.api.chunked_merge / chunked_merge_k
      -> repro.streaming.chunked_merge / chunked_merge_k
         (or repro.merge / merge_k, auto-routed)
  repro.core.api.tree_topk -> repro.streaming.tree_topk
         (or repro.topk with par=)
  repro.core.api.plan_merge -> repro.streaming.plan_merge2
"""
from __future__ import annotations

_MOVED = {
    "merge": "repro.merge",
    "merge_k": "repro.merge_k",
    "sort": "repro.sort",
    "topk": "repro.topk",
    "median_of_lists": "repro.median_of_lists",
    "merge_schedule": "repro.api.schedules.merge_schedule",
    "median9": "repro.api.schedules.median9",
    "chunked_merge": "repro.streaming.chunked_merge",
    "chunked_merge_k": "repro.streaming.chunked_merge_k",
    "tree_topk": "repro.streaming.tree_topk",
    "plan_merge": "repro.streaming.plan_merge2",
}


def __getattr__(name: str):
    if name in _MOVED:
        raise ImportError(
            f"repro.core.api.{name} was removed (its one-release "
            f"deprecation shim expired); use {_MOVED[name]} instead"
        )
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
