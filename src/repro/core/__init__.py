"""repro.core — List Offset Merge Sorters as oblivious JAX sort networks."""
from .api import (  # noqa: F401
    chunked_merge,
    chunked_merge_k,
    median9,
    median_of_lists,
    merge,
    merge_k,
    merge_schedule,
    plan_merge,
    sort,
    topk,
    tree_topk,
)
from .loms import loms_2way, loms_kway, loms_median, table1_stages  # noqa: F401
from .networks import (  # noqa: F401
    Group,
    Schedule,
    Stage,
    apply_schedule,
    apply_schedule_with_payload,
    comparator_count,
    depth,
    rank_merge_runs,
    rank_sort,
    validate_01_merge,
    validate_01_sort,
)
from .setup_array import SetupArray, build_2way_setup, build_kway_setup  # noqa: F401
