"""repro.core — List Offset Merge Sorters as oblivious JAX sort networks.

The sorting *API* that once lived here moved to the unified ``repro.*``
namespace (PR 2); the former ``repro.core.api`` shims are gone and its
module now only raises pointed ImportErrors. This package keeps the
network/schedule machinery the backends are built from.
"""
from .loms import loms_2way, loms_kway, loms_median, table1_stages  # noqa: F401
from .networks import (  # noqa: F401
    Group,
    Schedule,
    Stage,
    apply_schedule,
    apply_schedule_with_payload,
    comparator_count,
    depth,
    rank_merge_runs,
    rank_sort,
    validate_01_merge,
    validate_01_sort,
)
from .setup_array import SetupArray, build_2way_setup, build_kway_setup  # noqa: F401
