"""Structural cost models — the TPU analog of the paper's speed/LUT axes.

The paper characterizes devices by (a) combinational propagation delay and
(b) FPGA LUT usage. Neither has a literal TPU meaning, so we report:

  * ``depth``            — dependent stages (delay analog; LOMS=2, Batcher=log).
  * ``comparators``      — pairwise compare count (the comparator cloud).
  * ``lut_proxy``        — a calibrated FPGA-style resource model so the
                           paper's resource *rankings* can be reproduced:
                           - a b-bit ge/eq comparison ~ ceil(b/4) LUT6 (carry
                             chain packing, 4 value bits per LUT);
                           - each output bit's mux tree over f candidate
                             inputs ~ ceil(f/2) LUTs in '2insLUT' mode (2
                             data bits + 1 select per LUT, MUXF* combine) or
                             ~ ceil(f/4) LUTs + 1 extra series level in
                             '4insLUT' mode (paper §VI-A).
  * ``vmem_bytes``       — working set of the TPU kernel realization
                           (values + comparison matrices + one-hot permute),
                           the analog of "does this S2MS fit in the FPGA".
"""
from __future__ import annotations

import math
from typing import Dict

from .networks import Schedule


def depth(sched: Schedule) -> int:
    return len(sched.stages)


def comparators(sched: Schedule) -> int:
    return sum(st.comparators() for st in sched.stages)


def _group_output_fanin(n: int, runs) -> float:
    """Candidate inputs per output (mux fan-in). In an S2MS merge, output t
    can receive at most min(t, n-t) + r-ish inputs; we use the paper-faithful
    bound: every output of a merge group can see one element per run plus
    its own-run window, approximated by min(n, #runs * 2); full sorts see n."""
    if runs is None:
        return n
    return min(n, len(runs) * 2)


def lut_proxy(sched: Schedule, bits: int = 32, mode: str = "2insLUT") -> int:
    assert mode in ("2insLUT", "4insLUT")
    total = 0
    cmp_luts = math.ceil(bits / 4)
    for st in sched.stages:
        for g in st.groups:
            if g.n <= 1:
                continue
            total += g.comparators() * cmp_luts
            fanin = _group_output_fanin(g.n, g.runs)
            per_bit = math.ceil(fanin / 2) if mode == "2insLUT" else math.ceil(fanin / 4) + 1
            total += g.n * bits * per_bit
    return total


def series_levels(sched: Schedule, mode: str = "2insLUT") -> int:
    """Delay proxy: stages, each costing 1 level, plus the 4insLUT series
    penalty (paper §VI-A: the function-signal LUT is in series)."""
    penalty = 0 if mode == "2insLUT" else 1
    levels = 0
    for st in sched.stages:
        widest = max((g.n for g in st.groups), default=2)
        # a depth-1 rank sorter/merger is 1 compare level + a MUXF-style
        # mux tree of ceil(log2(fanin)) levels (on TPU: 1 VPU + 1 MXU pass)
        levels += 1 + math.ceil(math.log2(max(widest, 2))) + penalty
    return levels


def vmem_bytes(sched: Schedule, bits: int = 32, batch: int = 1) -> int:
    """Peak working set of the kernel realization for one batch tile:
    values + widest stage's comparison matrices + one-hot permute buffers."""
    val_bytes = bits // 8
    values = sched.size * val_bytes * batch
    widest = 0
    for st in sched.stages:
        stage_cmp = 0
        for g in st.groups:
            if g.n <= 1:
                continue
            if g.runs is None:
                stage_cmp += g.n * g.n
            else:
                stage_cmp += 2 * g.comparators()
        widest = max(widest, stage_cmp)
    # comparison matrices in int8 + one-hot permute in value dtype
    return values * 2 + widest * batch * (1 + val_bytes)


def summarize(sched: Schedule, bits: int = 32) -> Dict[str, object]:
    return {
        "name": sched.name,
        "n_inputs": sched.n_inputs,
        "depth": depth(sched),
        "comparators": comparators(sched),
        "lut2ins": lut_proxy(sched, bits, "2insLUT"),
        "lut4ins": lut_proxy(sched, bits, "4insLUT"),
        "vmem_bytes": vmem_bytes(sched, bits),
    }
