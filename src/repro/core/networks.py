"""Data-oblivious sort/merge network representation and JAX executor.

The paper's hardware devices (LOMS, S2MS, Batcher, MWMS, N-sorters) are all
fixed comparator structures: their wiring does not depend on the data. We
represent every device as a :class:`Schedule`:

  * a *working vector* of ``size`` cells (the 2-D setup array, flattened,
    holes included but never touched),
  * ``setup_scatter`` — where each input value is written (the paper's
    "setup array" mapping, Appendix A),
  * a tuple of :class:`Stage`\\ s; each stage is a set of disjoint
    :class:`Group`\\ s sorted *in parallel* (the paper's parallel column /
    row sorters),
  * ``output_gather`` — the final read-out order (row-major for 2-way,
    serpentine for k-way).

A :class:`Group` lists the cell indices in ascending output order. If
``runs`` is given, the group's input is a concatenation of pre-sorted
ascending runs and is executed as a *stable multi-run rank-merge* (the
hardware S2MS: all cross-run comparisons in parallel, depth 1). Otherwise it
is executed as a *stable rank-sort* (the hardware single-stage N-sorter:
full pairwise comparison matrix, depth 1).

TPU adaptation (see DESIGN.md §2): the FPGA mux tree that routes each input
to its output becomes a one-hot permutation matmul (MXU) or a scatter (VPU);
the comparison cloud is a dense pairwise boolean matrix (VPU). There is no
data-dependent control flow anywhere — the schedule is static, so the
executor is trivially jit/vmap/shard-compatible.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Group",
    "Stage",
    "Schedule",
    "apply_schedule",
    "apply_schedule_with_payload",
    "rank_sort",
    "rank_merge_runs",
    "depth",
    "comparator_count",
    "validate_01_merge",
    "validate_01_sort",
]


@dataclasses.dataclass(frozen=True)
class Group:
    """A set of cells sorted together in one stage.

    idx:  cell indices of the working vector, listed in ascending output
          order (idx[0] receives the minimum).
    runs: optional run lengths. When present, sum(runs) == len(idx) and the
          group's *input* values, read in idx order, form len(runs)
          concatenated ascending runs -> executed as a stable rank-merge
          (S2MS analog). When None -> stable rank-sort (N-sorter analog).
    """

    idx: Tuple[int, ...]
    runs: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.runs is not None:
            assert sum(self.runs) == len(self.idx), (self.runs, self.idx)
            assert all(r > 0 for r in self.runs)

    @property
    def n(self) -> int:
        return len(self.idx)

    def comparators(self) -> int:
        """Comparator count: cross-run pairs for merges, all pairs for sorts."""
        if self.n <= 1:
            return 0
        if self.runs is None:
            return self.n * (self.n - 1) // 2
        total = 0
        for i in range(len(self.runs)):
            for j in range(i + 1, len(self.runs)):
                total += self.runs[i] * self.runs[j]
        return total


@dataclasses.dataclass(frozen=True)
class Stage:
    groups: Tuple[Group, ...]

    def __post_init__(self):
        seen = set()
        for g in self.groups:
            for i in g.idx:
                assert i not in seen, f"cell {i} appears twice in one stage"
                seen.add(i)

    def comparators(self) -> int:
        return sum(g.comparators() for g in self.groups)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A complete oblivious merge/sort device."""

    name: str
    size: int  # working vector length (R*C of the setup array)
    setup_scatter: Tuple[int, ...]  # input position -> working cell
    output_gather: Tuple[int, ...]  # output position -> working cell
    stages: Tuple[Stage, ...]
    meta: Tuple[Tuple[str, object], ...] = ()

    @property
    def n_inputs(self) -> int:
        return len(self.setup_scatter)

    @property
    def n_outputs(self) -> int:
        return len(self.output_gather)

    def meta_dict(self) -> dict:
        return dict(self.meta)


# ---------------------------------------------------------------------------
# Depth-1 primitives: rank-sort (N-sorter) and multi-run rank-merge (S2MS)
# ---------------------------------------------------------------------------


def _scatter_last(base_shape_like: jnp.ndarray, pos: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """out[..., pos[..., i]] = vals[..., i] along the last axis."""
    return jnp.put_along_axis(
        jnp.zeros_like(vals, shape=base_shape_like.shape), pos, vals, axis=-1, inplace=False
    )


def rank_sort(x: jnp.ndarray, payload: Optional[jnp.ndarray] = None):
    """Stable single-stage N-sorter along the last axis.

    Computes the full pairwise comparison matrix (the hardware comparator
    cloud), derives each element's output rank, and permutes by scatter.
    Ascending. Stable: ties keep input order.
    """
    n = x.shape[-1]
    if n == 1:
        return (x, payload) if payload is not None else x
    v = x[..., :, None]  # i
    w = x[..., None, :]  # j
    j_lt_i = np.tril(np.ones((n, n), dtype=bool), k=-1)  # j < i
    # j goes before i  iff  w_j < v_i, or equal and j < i (stability)
    before = (w < v) | ((w == v) & j_lt_i)
    rank = before.sum(axis=-1).astype(jnp.int32)  # (..., n)
    out = _scatter_last(x, rank, x)
    if payload is None:
        return out
    pout = jnp.put_along_axis(jnp.zeros_like(payload), rank, payload, axis=-1, inplace=False)
    return out, pout


def rank_merge_runs(
    x: jnp.ndarray, runs: Sequence[int], payload: Optional[jnp.ndarray] = None
):
    """Stable single-stage merge of pre-sorted ascending runs (S2MS analog).

    ``x[..., :]`` is a concatenation of ``len(runs)`` ascending runs with the
    given (static) lengths. Only cross-run comparisons are computed — this is
    the resource saving of S2MS vs a full N-sorter. Earlier runs win ties.
    """
    runs = tuple(int(r) for r in runs)
    n = x.shape[-1]
    assert sum(runs) == n
    if len(runs) == 1 or n == 1:
        return (x, payload) if payload is not None else x
    offs = np.cumsum((0,) + runs)
    # rank = own index within run + for each other run: #elements that go before
    rank = jnp.zeros(x.shape, dtype=jnp.int32)
    pieces = [x[..., offs[s] : offs[s + 1]] for s in range(len(runs))]
    ranks = []
    for s, vs in enumerate(pieces):
        r = jnp.arange(runs[s], dtype=jnp.int32)
        r = jnp.broadcast_to(r, vs.shape)
        for t, vt in enumerate(pieces):
            if t == s:
                continue
            if t < s:  # earlier run goes first on ties
                cnt = (vt[..., None, :] <= vs[..., :, None]).sum(axis=-1)
            else:
                cnt = (vt[..., None, :] < vs[..., :, None]).sum(axis=-1)
            r = r + cnt.astype(jnp.int32)
        ranks.append(r)
    rank = jnp.concatenate(ranks, axis=-1)
    out = _scatter_last(x, rank, x)
    if payload is None:
        return out
    pout = jnp.put_along_axis(jnp.zeros_like(payload), rank, payload, axis=-1, inplace=False)
    return out, pout


def _compare_exchange_pairs(x, payload=None):
    """Fast path for groups of 2: plain min/max (the hardware 2-sorter)."""
    a, b = x[..., 0], x[..., 1]
    swap = a > b
    lo = jnp.where(swap, b, a)
    hi = jnp.where(swap, a, b)
    out = jnp.stack([lo, hi], axis=-1)
    if payload is None:
        return out
    pa, pb = payload[..., 0], payload[..., 1]
    plo = jnp.where(swap, pb, pa)
    phi = jnp.where(swap, pa, pb)
    return out, jnp.stack([plo, phi], axis=-1)


# ---------------------------------------------------------------------------
# Schedule executor
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _stage_classes(stage: Stage):
    """Group the stage's groups into batches of identical (length, runs)."""
    classes = {}
    for g in stage.groups:
        if g.n <= 1:
            continue
        key = (g.n, g.runs)
        classes.setdefault(key, []).append(g.idx)
    out = []
    for (n, runs), idx_lists in classes.items():
        idx = np.asarray(idx_lists, dtype=np.int32)  # (G, n)
        out.append((n, runs, idx))
    return tuple(out)


def _apply_stage(stage: Stage, w: jnp.ndarray, pw: Optional[jnp.ndarray]):
    for n, runs, idx in _stage_classes(stage):
        flat = idx.reshape(-1)
        vals = jnp.take(w, flat, axis=-1)
        vals = vals.reshape(vals.shape[:-1] + idx.shape)  # (..., G, n)
        pv = None
        if pw is not None:
            pv = jnp.take(pw, flat, axis=-1)
            pv = pv.reshape(pv.shape[:-1] + idx.shape)
        if n == 2 and runs in (None, (1, 1)):
            res = _compare_exchange_pairs(vals, pv)
        elif runs is None:
            res = rank_sort(vals, pv)
        else:
            res = rank_merge_runs(vals, runs, pv)
        if pw is not None:
            vals, pv = res
            pw = pw.at[..., flat].set(pv.reshape(pv.shape[:-2] + (len(flat),)))
        else:
            vals = res
        w = w.at[..., flat].set(vals.reshape(vals.shape[:-2] + (len(flat),)))
    return w, pw


def apply_schedule(sched: Schedule, x: jnp.ndarray, n_stages: Optional[int] = None) -> jnp.ndarray:
    """Run the oblivious device on ``x`` (last axis = the input list concat).

    ``n_stages`` truncates execution (the paper's early-exit: e.g. median
    after 2 of 3 stages)."""
    assert x.shape[-1] == sched.n_inputs, (x.shape, sched.n_inputs)
    setup = np.asarray(sched.setup_scatter, dtype=np.int32)
    gather = np.asarray(sched.output_gather, dtype=np.int32)
    w = jnp.zeros(x.shape[:-1] + (sched.size,), dtype=x.dtype)
    w = w.at[..., setup].set(x)
    stages = sched.stages if n_stages is None else sched.stages[:n_stages]
    for st in stages:
        w, _ = _apply_stage(st, w, None)
    return jnp.take(w, gather, axis=-1)


def apply_schedule_with_payload(
    sched: Schedule, x: jnp.ndarray, payload: jnp.ndarray, n_stages: Optional[int] = None
):
    """Same as :func:`apply_schedule` but carries a payload (e.g. indices)."""
    assert x.shape == payload.shape[: x.ndim] and x.shape[-1] == sched.n_inputs
    setup = np.asarray(sched.setup_scatter, dtype=np.int32)
    gather = np.asarray(sched.output_gather, dtype=np.int32)
    w = jnp.zeros(x.shape[:-1] + (sched.size,), dtype=x.dtype)
    w = w.at[..., setup].set(x)
    pw = jnp.zeros(payload.shape[:-1] + (sched.size,), dtype=payload.dtype)
    pw = pw.at[..., setup].set(payload)
    stages = sched.stages if n_stages is None else sched.stages[:n_stages]
    for st in stages:
        w, pw = _apply_stage(st, w, pw)
    return jnp.take(w, gather, axis=-1), jnp.take(pw, gather, axis=-1)


# ---------------------------------------------------------------------------
# Structural metrics + 0-1 principle validation
# ---------------------------------------------------------------------------


def depth(sched: Schedule) -> int:
    """Number of dependent stages (the hardware propagation-delay analog)."""
    return len(sched.stages)


def comparator_count(sched: Schedule) -> int:
    return sum(st.comparators() for st in sched.stages)


def _per_list_sorted_01_patterns(lens: Sequence[int]) -> np.ndarray:
    """All 0-1 inputs where each input list is individually sorted ascending.

    For a merge network the 0-1 principle only needs these prod(len+1)
    patterns (each sorted 0-1 list is determined by its number of ones)."""
    parts = []
    for ln in lens:
        rows = []
        for ones in range(ln + 1):
            rows.append([0] * (ln - ones) + [1] * ones)
        parts.append(np.asarray(rows, dtype=np.int32))
    grids = np.meshgrid(*[np.arange(p.shape[0]) for p in parts], indexing="ij")
    idxs = [g.reshape(-1) for g in grids]
    cols = [p[i] for p, i in zip(parts, idxs)]
    return np.concatenate(cols, axis=-1)


def _np_rank_sort(vals: np.ndarray) -> np.ndarray:
    return np.sort(vals, axis=-1)  # stable rank-sort == sort on values


def _np_rank_merge(vals: np.ndarray, runs) -> np.ndarray:
    """Exact numpy replica of rank_merge_runs — NOT a sort: if the run
    assumption is violated the device misbehaves identically here, so the
    0-1 validation exercises the true hardware semantics."""
    offs = np.cumsum((0,) + tuple(runs))
    pieces = [vals[..., offs[s] : offs[s + 1]] for s in range(len(runs))]
    ranks = []
    for s, vs in enumerate(pieces):
        r = np.broadcast_to(np.arange(runs[s]), vs.shape).copy()
        for t, vt in enumerate(pieces):
            if t == s:
                continue
            if t < s:
                r = r + (vt[..., None, :] <= vs[..., :, None]).sum(axis=-1)
            else:
                r = r + (vt[..., None, :] < vs[..., :, None]).sum(axis=-1)
        ranks.append(r)
    rank = np.concatenate(ranks, axis=-1)
    out = np.zeros_like(vals)
    np.put_along_axis(out, rank, vals, axis=-1)
    return out


def apply_schedule_np(sched: Schedule, x: np.ndarray, n_stages=None) -> np.ndarray:
    """Pure-numpy executor (used by builders' eager 0-1 validation so that
    schedule construction is legal inside jit traces)."""
    setup = np.asarray(sched.setup_scatter, dtype=np.int32)
    gather = np.asarray(sched.output_gather, dtype=np.int32)
    w = np.zeros(x.shape[:-1] + (sched.size,), dtype=x.dtype)
    w[..., setup] = x
    stages = sched.stages if n_stages is None else sched.stages[:n_stages]
    for st in stages:
        for n, runs, idx in _stage_classes(st):
            flat = idx.reshape(-1)
            vals = w[..., flat].reshape(x.shape[:-1] + idx.shape)
            if runs is None:
                vals = _np_rank_sort(vals)
            else:
                vals = _np_rank_merge(vals, runs)
            w[..., flat] = vals.reshape(x.shape[:-1] + (len(flat),))
    return w[..., gather]


def validate_01_merge(sched: Schedule, lens: Sequence[int], n_stages=None) -> bool:
    """0-1-principle check that ``sched`` merges any per-list-sorted input."""
    pats = _per_list_sorted_01_patterns(lens)
    out = apply_schedule_np(sched, pats, n_stages)
    return bool((np.diff(out, axis=-1) >= 0).all())


def validate_01_sort(sched: Schedule) -> bool:
    """0-1-principle check for an unrestricted sorting network (2^n inputs)."""
    n = sched.n_inputs
    assert n <= 22, "exhaustive 0-1 validation limited to n<=22"
    pats = ((np.arange(2**n)[:, None] >> np.arange(n)[None, :]) & 1).astype(np.int32)
    out = apply_schedule_np(sched, pats)
    return bool((np.diff(out, axis=-1) >= 0).all())
