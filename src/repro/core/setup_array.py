"""LOMS setup arrays — the paper's Appendix A, implemented literally.

A setup array is a small 2-D grid of cells; each populated cell names one
element of one sorted input list. Column index 0 is the RIGHTMOST column
(paper convention); row 0 is the BOTTOM row. Value index 0 of every list is
its minimum (ascending lists — the paper indexes _00 = min up to _NN = max,
identical convention).

Construction (k-way, Appendix A):
  1. lists are laid out top-down, each list's block below the previous;
     within a block, values DESCEND row-major left->right; list ``l`` starts
     ``l`` columns further right (the "offset"), overflowing into virtual
     columns right of col 0;
  2. virtual-column overflow wraps ``k`` columns left into the same row;
  3. per column, populated cells slide UP, holes collect at the bottom;
  4. fully-empty bottom rows are removed.

The 2-column 2-way array is the k=2 case of the same construction. Multi-
column 2-way arrays (Section IV, Fig. 4) use the UP/DN orientation rule:
the A (UP) block fills top rows, ascending right->left then upward; the
B (DN) block fills bottom rows mirrored, ascending left->right then upward.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

HOLE = (-1, -1)


@dataclasses.dataclass(frozen=True)
class SetupArray:
    """grid[r][c] = (list_id, value_index) or HOLE. r=0 bottom, c=0 RIGHT."""

    lens: Tuple[int, ...]
    n_cols: int
    grid: Tuple[Tuple[Tuple[int, int], ...], ...]  # grid[row][col]

    @property
    def n_rows(self) -> int:
        return len(self.grid)

    def cell_flat(self, r: int, c: int) -> int:
        """Flat working-vector index of cell (r, c)."""
        return r * self.n_cols + c

    @property
    def size(self) -> int:
        return self.n_rows * self.n_cols

    def populated(self, r: int, c: int) -> bool:
        return self.grid[r][c] != HOLE

    def input_position(self, list_id: int, value_idx: int) -> int:
        """Position in the concatenated input vector [list0..listk-1]."""
        return int(sum(self.lens[:list_id]) + value_idx)

    # -- derived mappings ---------------------------------------------------

    def setup_scatter(self) -> Tuple[int, ...]:
        """For input position p -> flat working cell index."""
        out = [None] * sum(self.lens)
        for r in range(self.n_rows):
            for c in range(self.n_cols):
                cell = self.grid[r][c]
                if cell != HOLE:
                    out[self.input_position(*cell)] = self.cell_flat(r, c)
        assert all(v is not None for v in out)
        return tuple(out)

    def rowmajor_output_gather(self) -> Tuple[int, ...]:
        """Ascending read-out: bottom row up, right->left (col0 first). k=2."""
        out = []
        for r in range(self.n_rows):
            for c in range(self.n_cols):
                if self.populated(r, c):
                    out.append(self.cell_flat(r, c))
        return tuple(out)

    def serpentine_output_gather(self) -> Tuple[int, ...]:
        """Ascending serpentine read-out (k>=3): even rows right->left,
        odd rows left->right (paper Fig. 5)."""
        out = []
        for r in range(self.n_rows):
            cols = range(self.n_cols) if r % 2 == 0 else range(self.n_cols - 1, -1, -1)
            for c in cols:
                if self.populated(r, c):
                    out.append(self.cell_flat(r, c))
        return tuple(out)

    # -- group extraction ---------------------------------------------------

    def column_cells(self, c: int) -> List[Tuple[int, Tuple[int, int]]]:
        """Populated (flat_idx, content) of column c, bottom -> top."""
        return [
            (self.cell_flat(r, c), self.grid[r][c])
            for r in range(self.n_rows)
            if self.populated(r, c)
        ]

    def stage1_column_runs(self, c: int) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
        """(cell indices bottom->top, run lengths) for the stage-1 column
        merge. Within a column the cells of one list appear in ascending
        order bottom->top (a consequence of the setup construction), so runs
        are the maximal same-list segments."""
        cells = self.column_cells(c)
        idx = tuple(f for f, _ in cells)
        runs: List[int] = []
        prev_list: Optional[int] = None
        prev_val: Optional[int] = None
        for _, (lst, val) in cells:
            if lst == prev_list and prev_val is not None and val > prev_val:
                runs[-1] += 1
            else:
                runs.append(1)
            prev_list, prev_val = lst, val
        return idx, tuple(runs)

    def row_cells(self, r: int, ascending_right_to_left: bool) -> Tuple[int, ...]:
        """Populated cells of row r in ascending output order."""
        cols = range(self.n_cols) if ascending_right_to_left else range(self.n_cols - 1, -1, -1)
        return tuple(self.cell_flat(r, c) for c in cols if self.populated(r, c))


def _compact_columns_and_trim(cells: np.ndarray) -> np.ndarray:
    """Step 3+4: per column slide populated cells up; drop empty bottom rows.

    ``cells``: (R, C, 2) int array, HOLE = (-1,-1); row 0 = bottom."""
    r_, c_, _ = cells.shape
    out = np.full_like(cells, -1)
    for c in range(c_):
        col = [cells[r, c] for r in range(r_) if cells[r, c][0] >= 0]
        # populated cells keep their bottom->top order, pushed to the top
        start = r_ - len(col)
        for i, v in enumerate(col):
            out[start + i, c] = v
    # drop fully-empty rows (they can only be at the bottom now)
    keep = [(out[r] >= 0).any() for r in range(r_)]
    return out[np.asarray(keep, dtype=bool)]


def build_kway_setup(lens: Sequence[int]) -> SetupArray:
    """Appendix-A construction for k lists into a k-column array."""
    lens = tuple(int(x) for x in lens)
    k = len(lens)
    assert k >= 2 and all(l >= 1 for l in lens)
    blocks = []
    for l_id, ln in enumerate(lens):
        rows_needed = -(-(ln) // k) + 1  # slack row for offset overflow
        block = np.full((rows_needed, k, 2), -1, dtype=np.int64)
        for d in range(ln):  # d = descending position, d=0 is the max
            val = ln - 1 - d
            row_top_down = d // k
            col = ((k - 1 - l_id) - (d % k)) % k  # offset + wrap (steps 1+2)
            # rows are stored bottom-up; convert top-down block row
            block[rows_needed - 1 - row_top_down, col] = (l_id, val)
        # trim unused rows inside the block
        used = [(block[r] >= 0).any() for r in range(rows_needed)]
        blocks.append(block[np.asarray(used, dtype=bool)])
    # stack: list 0 on top (highest rows), last list at the bottom
    cells = np.concatenate(list(reversed(blocks)), axis=0)
    cells = _compact_columns_and_trim(cells)
    grid = tuple(
        tuple((int(cells[r, c, 0]), int(cells[r, c, 1])) for c in range(k))
        for r in range(cells.shape[0])
    )
    return SetupArray(lens=lens, n_cols=k, grid=grid)


def build_2way_setup(m: int, n: int, n_cols: int = 2) -> SetupArray:
    """Section-IV 2-way setup: UP list A (m values) above DN list B (n
    values), in ``n_cols`` columns. For n_cols == 2 this coincides with the
    k=2 Appendix-A construction (verified in tests)."""
    assert m >= 1 and n >= 1 and n_cols >= 2
    c_ = n_cols
    a_rows = -(-m // c_)
    b_rows = -(-n // c_)
    cells = np.full((a_rows + b_rows, c_, 2), -1, dtype=np.int64)
    # Both blocks fill DESCENDING row-major from their top row (paper Fig. 1).
    # A (UP) block, top rows: max at top-LEFT, each row descends left->right.
    for d in range(m):  # d = descending position, d=0 is the max
        row = b_rows + (a_rows - 1 - d // c_)
        cells[row, c_ - 1 - (d % c_)] = (0, m - 1 - d)
    # B (DN) block, bottom rows: max at top-RIGHT, each row descends
    # right->left (the DN mirror orientation).
    for d in range(n):
        row = b_rows - 1 - d // c_
        cells[row, d % c_] = (1, n - 1 - d)
    cells = _compact_columns_and_trim(cells)
    grid = tuple(
        tuple((int(cells[r, c, 0]), int(cells[r, c, 1])) for c in range(c_))
        for r in range(cells.shape[0])
    )
    return SetupArray(lens=(m, n), n_cols=c_, grid=grid)
