"""List Offset Merge Sorter schedule builders (the paper's contribution).

``loms_2way``   — Section IV: 2 stages (S2MS column merges, then row sorts),
                  any UP-x/DN-y mixture, 2/4/8/... columns.
``loms_kway``   — Section V: k-column k-way merge, alternating column/row
                  stages; stage counts per paper Table 1. k=3 uses the
                  paper's minimal stage-3 (edge-column boundary pair sorts).
``loms_median`` — Section V-A: median of k equal odd lists after only the
                  first two stages (read the center cell).

Every built schedule of modest size is 0-1-validated at construction time
(cached), so an incorrect schedule cannot silently escape.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

from .networks import Group, Schedule, Stage, validate_01_merge
from .setup_array import SetupArray, build_2way_setup, build_kway_setup

# Paper Table 1: total alternating column/row sorts for a k-way merge.
_TABLE1 = {2: 2, 3: 3, 4: 4, 5: 4, 6: 5}


def table1_stages(k: int) -> int:
    if k in _TABLE1:
        return _TABLE1[k]
    if 7 <= k <= 14:
        return 6
    raise ValueError(f"paper Table 1 covers k in [2, 14]; got k={k}")


# Validation budget: exhaustive 0-1 merge validation costs prod(len+1)
# patterns; keep it cheap but meaningful.
_VALIDATE_LIMIT = 60_000


def _maybe_validate(sched: Schedule, lens: Sequence[int]) -> Schedule:
    n_pats = 1
    for ln in lens:
        n_pats *= ln + 1
    if n_pats <= _VALIDATE_LIMIT:
        ok = validate_01_merge(sched, lens)
        assert ok, f"schedule {sched.name} failed 0-1 validation for lens={lens}"
    return sched


def _stage1_columns(arr: SetupArray) -> Stage:
    groups = []
    for c in range(arr.n_cols):
        idx, runs = arr.stage1_column_runs(c)
        if len(idx) >= 2 and len(runs) >= 2:
            groups.append(Group(idx=idx, runs=runs))
    return Stage(groups=tuple(groups))


def _row_stage(arr: SetupArray, serpentine: bool) -> Stage:
    groups = []
    for r in range(arr.n_rows):
        asc_r2l = True if not serpentine else (r % 2 == 0)
        idx = arr.row_cells(r, ascending_right_to_left=asc_r2l)
        if len(idx) >= 2:
            groups.append(Group(idx=idx))
    return Stage(groups=tuple(groups))


def _full_column_stage(arr: SetupArray) -> Stage:
    groups = []
    for c in range(arr.n_cols):
        cells = arr.column_cells(c)
        if len(cells) >= 2:
            groups.append(Group(idx=tuple(f for f, _ in cells)))
    return Stage(groups=tuple(groups))


def _edge_pair_column_stage(arr: SetupArray) -> Stage:
    """Paper Fig. 6 stage 3 for 3-way: 2-sorters at the serpentine row
    boundaries, edge columns only (col 0 joins rows (2j+1, 2j+2); the
    leftmost column joins rows (2j, 2j+1))."""
    groups = []
    left = arr.n_cols - 1
    for r in range(0, arr.n_rows - 1, 2):  # rows (2j, 2j+1) at leftmost col
        if arr.populated(r, left) and arr.populated(r + 1, left):
            groups.append(Group(idx=(arr.cell_flat(r, left), arr.cell_flat(r + 1, left))))
    for r in range(1, arr.n_rows - 1, 2):  # rows (2j+1, 2j+2) at col 0
        if arr.populated(r, 0) and arr.populated(r + 1, 0):
            groups.append(Group(idx=(arr.cell_flat(r, 0), arr.cell_flat(r + 1, 0))))
    return Stage(groups=tuple(groups))


@functools.lru_cache(maxsize=None)
def loms_2way(m: int, n: int, n_cols: int = 2) -> Schedule:
    """2-stage UP-m/DN-n List Offset merge in ``n_cols`` columns."""
    arr = build_2way_setup(m, n, n_cols)
    stages = (_stage1_columns(arr), _row_stage(arr, serpentine=False))
    sched = Schedule(
        name=f"loms2way_up{m}_dn{n}_{n_cols}col",
        size=arr.size,
        setup_scatter=arr.setup_scatter(),
        output_gather=arr.rowmajor_output_gather(),
        stages=stages,
        meta=(("kind", "loms2"), ("lens", (m, n)), ("n_cols", n_cols)),
    )
    return _maybe_validate(sched, (m, n))


@functools.lru_cache(maxsize=None)
def loms_kway(lens: Tuple[int, ...], n_stages: Optional[int] = None) -> Schedule:
    """k-way LOMS merge (k = len(lens) columns). ``n_stages`` defaults to
    paper Table 1. Stage 1 = column S2MS merges, stage 2 = serpentine row
    sorts, then alternating column/row sorts. For k == 3 the third stage is
    the paper's minimal edge-column pair sort; other later column stages are
    full column sorts (a validated superset of the paper's unspecified
    minimal extents — see DESIGN.md §7)."""
    lens = tuple(int(x) for x in lens)
    k = len(lens)
    assert k >= 2
    if k == 2:
        return loms_2way(lens[0], lens[1], 2)
    total = n_stages if n_stages is not None else table1_stages(k)
    arr = build_kway_setup(lens)
    stages = [_stage1_columns(arr), _row_stage(arr, serpentine=True)]
    s = 2
    while s < total:
        if s % 2 == 0:  # column stage
            if k == 3 and total == 3:
                stages.append(_edge_pair_column_stage(arr))
            else:
                stages.append(_full_column_stage(arr))
        else:
            stages.append(_row_stage(arr, serpentine=True))
        s += 1
    sched = Schedule(
        name=f"loms{k}way_" + "x".join(map(str, lens)),
        size=arr.size,
        setup_scatter=arr.setup_scatter(),
        output_gather=arr.serpentine_output_gather(),
        stages=tuple(stages),
        meta=(("kind", "lomsk"), ("lens", lens), ("n_cols", k)),
    )
    return _maybe_validate(sched, lens)


@functools.lru_cache(maxsize=None)
def loms_median(lens: Tuple[int, ...]) -> Tuple[Schedule, int]:
    """2-stage median device for k equal odd-length lists (paper §V-A).

    Returns (schedule truncated to 2 stages, output position of the median
    in the schedule's output list). The median sits at the center cell of
    the array after stage 2."""
    lens = tuple(int(x) for x in lens)
    k = len(lens)
    assert k >= 3 and k % 2 == 1, "median early-exit needs odd k"
    assert all(l == lens[0] for l in lens) and lens[0] % 2 == 1, (
        "median early-exit needs equal odd-length lists"
    )
    arr = build_kway_setup(lens)
    stages = (_stage1_columns(arr), _row_stage(arr, serpentine=True))
    gather = arr.serpentine_output_gather()
    center_cell = arr.cell_flat(arr.n_rows // 2, arr.n_cols // 2)
    median_pos = gather.index(center_cell)
    assert median_pos == (sum(lens) - 1) // 2, (median_pos, lens)
    sched = Schedule(
        name=f"loms{k}median_" + "x".join(map(str, lens)),
        size=arr.size,
        setup_scatter=arr.setup_scatter(),
        output_gather=gather,
        stages=stages,
        meta=(("kind", "loms_median"), ("lens", lens), ("n_cols", k)),
    )
    return sched, median_pos
