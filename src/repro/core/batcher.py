"""Batcher Odd-Even Merge Sort and Bitonic Merge Sort baselines.

These are the paper's state-of-the-art 2-way comparison points. Both are
multistage 2-sorter networks with depth log2(m+n) for a 2-way merge of
power-of-two lists (vs LOMS's fixed 2 stages). As the paper notes, Batcher
devices are only straightforward for equal power-of-two list sizes; we
implement exactly that case and raise otherwise.
"""
from __future__ import annotations

import functools
from typing import List, Tuple

from .networks import Group, Schedule, Stage


def _is_pow2(x: int) -> bool:
    return x >= 1 and (x & (x - 1)) == 0


def _pack_stages(comparators: List[Tuple[int, int]]) -> Tuple[Stage, ...]:
    """ASAP level-schedule. Comparator lists from the recursions below are
    emitted in dependency order (dependencies only exist through shared
    cells), so scheduling each comparator right after the last prior use of
    either cell attains the canonical network depth."""
    last_used: dict = {}
    stages: List[List[Group]] = []
    for a, b in comparators:
        s = max(last_used.get(a, -1), last_used.get(b, -1)) + 1
        while len(stages) <= s:
            stages.append([])
        stages[s].append(Group(idx=(a, b)))
        last_used[a] = s
        last_used[b] = s
    return tuple(Stage(groups=tuple(gs)) for gs in stages)


def _oddeven_merge_comparators(lo: int, n: int, r: int, out: List[Tuple[int, int]]):
    """Batcher odd-even merge of the n power-of-two cells starting at lo,
    assuming halves sorted."""
    step = r * 2
    if step < n:
        _oddeven_merge_comparators(lo, n, step, out)
        _oddeven_merge_comparators(lo + r, n, step, out)
        i = lo + r
        while i + r < lo + n:
            out.append((i, i + r))
            i += step
    else:
        out.append((lo, lo + r))


@functools.lru_cache(maxsize=None)
def oems_merge(m: int, n: int) -> Schedule:
    """Batcher Odd-Even 2-way merge of two sorted power-of-two lists."""
    if m != n or not _is_pow2(m):
        raise ValueError(
            "Batcher odd-even merge implemented for equal power-of-two lists "
            f"only (paper §VI); got UP-{m}/DN-{n}"
        )
    total = m + n
    comps: List[Tuple[int, int]] = []
    _oddeven_merge_comparators(0, total, 1, comps)
    return Schedule(
        name=f"oems_up{m}_dn{n}",
        size=total,
        setup_scatter=tuple(range(total)),
        output_gather=tuple(range(total)),
        stages=_pack_stages(comps),
        meta=(("kind", "oems"), ("lens", (m, n))),
    )


@functools.lru_cache(maxsize=None)
def bitonic_merge(m: int, n: int) -> Schedule:
    """Batcher bitonic 2-way merge: B is written reversed (descending) so
    [A, reversed(B)] is bitonic, then log2(m+n) halving stages."""
    if m != n or not _is_pow2(m):
        raise ValueError(
            "bitonic merge implemented for equal power-of-two lists only "
            f"(paper §VI); got UP-{m}/DN-{n}"
        )
    total = m + n
    # setup: A identity; B reversed
    setup = tuple(range(m)) + tuple(range(total - 1, m - 1, -1))
    comps: List[Tuple[int, int]] = []
    d = total // 2
    while d >= 1:
        for i in range(total):
            if (i % (2 * d)) < d:
                comps.append((i, i + d))
        d //= 2
    return Schedule(
        name=f"bitonic_up{m}_dn{n}",
        size=total,
        setup_scatter=setup,
        output_gather=tuple(range(total)),
        stages=_pack_stages(comps),
        meta=(("kind", "bitonic"), ("lens", (m, n))),
    )


def _oddeven_sort_comparators(lo: int, n: int, out: List[Tuple[int, int]]):
    if n <= 1:
        return
    h = n // 2
    _oddeven_sort_comparators(lo, h, out)
    _oddeven_sort_comparators(lo + h, h, out)
    _oddeven_merge_comparators(lo, n, 1, out)


@functools.lru_cache(maxsize=None)
def oems_sort(n: int) -> Schedule:
    """Full Batcher odd-even merge sort of n (power-of-two) unsorted values."""
    if not _is_pow2(n):
        raise ValueError(f"odd-even merge sort needs power-of-two n, got {n}")
    comps: List[Tuple[int, int]] = []
    _oddeven_sort_comparators(0, n, comps)
    return Schedule(
        name=f"oems_sort{n}",
        size=n,
        setup_scatter=tuple(range(n)),
        output_gather=tuple(range(n)),
        stages=_pack_stages(comps),
        meta=(("kind", "oems_sort"), ("lens", (n,))),
    )


@functools.lru_cache(maxsize=None)
def bitonic_sort(n: int) -> Schedule:
    """Full bitonic sort of n (power-of-two) unsorted values."""
    if not _is_pow2(n):
        raise ValueError(f"bitonic sort needs power-of-two n, got {n}")
    comps: List[Tuple[int, int]] = []
    k = 2
    while k <= n:
        d = k // 2
        while d >= 1:
            for i in range(n):
                j = i ^ d
                if j > i:
                    # ascending blocks of size k; descending handled by
                    # orienting the comparator
                    if (i & k) == 0:
                        comps.append((i, j))
                    else:
                        comps.append((j, i))
            d //= 2
        k *= 2
    # comparators with reversed orientation: Group idx order encodes
    # ascending output, so (j, i) already expresses the descending pair.
    return Schedule(
        name=f"bitonic_sort{n}",
        size=n,
        setup_scatter=tuple(range(n)),
        output_gather=tuple(range(n)),
        stages=_pack_stages(comps),
        meta=(("kind", "bitonic_sort"), ("lens", (n,))),
    )
