"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 50 --batch 4 --seq 128 [--retries 2] [--ckpt-dir DIR]

Full (non-smoke) configs are meant for real accelerator fleets; on this
CPU host use --smoke. Fault tolerance: any crash restarts from the latest
atomic checkpoint (see repro.runtime.train_loop).
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--retries", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--data-bin", default=None)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.data import DataConfig
    from repro.optim import OptConfig
    from repro.runtime import TrainConfig, train_with_retries

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dc = DataConfig(seq_len=args.seq, global_batch=args.batch,
                    bin_path=args.data_bin)
    tc = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir, remat=args.remat)
    oc = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                   total_steps=args.steps)
    out = train_with_retries(cfg, dc, tc, oc, retries=args.retries)
    print(f"[launch] done: final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
