import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import — jax locks the
device count at first init, and the production meshes need 512 host
placeholder devices. Never set this flag globally: smoke tests and
benchmarks are single-device.

For each cell this lowers the production step function with
ShapeDtypeStruct stand-ins (zero allocation), compiles it for the mesh,
and records:
  * memory_analysis  (per-device bytes — proves it fits in 16 GiB HBM)
  * cost_analysis    (per-device HLO flops/bytes for the roofline)
  * collective bytes (parsed from the compiled per-device HLO: all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute)
Results go to experiments/dryrun/<mesh>/<arch>__<shape>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import functools
import json
import re
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, get_shape
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models import decode_step, init_cache, loss_fn, model_init, prefill
from repro.optim import OptConfig, opt_init, opt_update
from repro.parallel import build_param_pspecs, cache_pspecs, make_parallelism

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def cell_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> "Optional[str]":
    if shape.kind == "decode" and cfg.is_encoder_only:
        return "encoder-only: no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "pure full-attention arch: 500k decode needs sub-quadratic mixing"
    return None


# ---------------------------------------------------------------------------
# abstract shapes (no allocation anywhere)
# ---------------------------------------------------------------------------


def shapes_and_specs(cfg: ModelConfig):
    """ShapeDtypeStruct params + logical-axis spec tree, via eval_shape."""
    cell = {}

    def only_params(key):
        p, s = model_init(key, cfg)
        cell["specs"] = s  # static python objects, captured during trace
        return p

    shapes = jax.eval_shape(only_params, jax.random.PRNGKey(0))
    return shapes, cell["specs"]


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), jnp.bfloat16),
                "targets": jax.ShapeDtypeStruct((b, s), i32),
            }
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                 "targets": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            batch["tokens"] = jax.ShapeDtypeStruct((b, s - cfg.frontend_len), i32)
            batch["targets"] = jax.ShapeDtypeStruct((b, s - cfg.frontend_len), i32)
            batch["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.frontend_dim), jnp.bfloat16)
        return batch
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "positions": jax.ShapeDtypeStruct((b, 1), i32)}


def _maybe(axes, size, mesh):
    ax = axes if isinstance(axes, tuple) else (axes,)
    n = int(np.prod([mesh.shape[a] for a in ax]))
    return axes if size % n == 0 and size >= n else None


def batch_pspecs_for(cfg, shape, par, mesh):
    dp = _maybe(par.dp_axes, shape.global_batch, mesh)
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            return {"frames": P(dp, None, None), "targets": P(dp, None)}
        out = {"tokens": P(dp, None), "targets": P(dp, None)}
        if cfg.family == "vlm":
            out["patches"] = P(dp, None, None)
        return out
    return {"tokens": P(dp, None), "positions": P(dp, None)}


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               remat: str = "dots", cache_dtype=jnp.bfloat16,
               grad_accum: "Optional[int]" = None):
    par = make_parallelism(mesh, ep=cfg.moe is not None)
    params_shapes, specs = shapes_and_specs(cfg)
    param_ps = build_param_pspecs(params_shapes, specs, mesh)
    batch_ps = batch_pspecs_for(cfg, shape, par, mesh)
    inputs = input_specs(cfg, shape)
    named = lambda t: jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), t, is_leaf=lambda x: isinstance(x, P))
    dp = _maybe(par.dp_axes, shape.global_batch, mesh)

    if shape.kind == "train":
        oc = OptConfig(total_steps=10_000)
        opt_shapes = jax.eval_shape(opt_init, params_shapes)
        opt_ps = {"mu": param_ps, "nu": param_ps, "step": P()}
        # gradient accumulation: keep the per-microbatch activation stack
        # (n_layers x B_loc x S x D) under ~4 GiB/device
        act_bytes = (cfg.n_layers * (shape.global_batch / max(1, par.dp_size))
                     * shape.seq_len * cfg.d_model * 2)
        k_acc = 1
        while act_bytes / k_acc > 4 * 2**30 and k_acc < shape.global_batch:
            k_acc *= 2
        if grad_accum is not None:
            k_acc = grad_accum

        def train_step(params, opt_state, batch):
            if k_acc > 1:
                mb = jax.tree.map(
                    lambda x: x.reshape((k_acc, x.shape[0] // k_acc) + x.shape[1:]),
                    batch)
                mb = jax.tree.map(
                    lambda x: par.constrain(
                        x, None, par.dp_for(x.shape[1]), *([None] * (x.ndim - 2))),
                    mb)

                def mb_step(acc, mbatch):
                    loss, g = jax.value_and_grad(
                        lambda p: loss_fn(p, mbatch, cfg, par=par, remat=remat))(params)
                    # anchor grads to the param shardings so the cross-dp
                    # reduction lowers to reduce-scatter, not all-reduce
                    g = jax.tree.map(
                        lambda gr, ps: jax.lax.with_sharding_constraint(
                            gr, NamedSharding(mesh, ps)),
                        g, param_ps, is_leaf=lambda x: not isinstance(x, (dict, list)))
                    return jax.tree.map(jnp.add, acc, g), loss

                g0 = jax.tree.map(jnp.zeros_like, params)
                grads, losses = jax.lax.scan(mb_step, g0, mb)
                grads = jax.tree.map(lambda g: g / k_acc, grads)
                loss = losses.mean()
            else:
                loss, grads = jax.value_and_grad(
                    lambda p: loss_fn(p, batch, cfg, par=par, remat=remat))(params)
            params, opt_state, metrics = opt_update(grads, opt_state, params, oc)
            return params, opt_state, loss

        fn = jax.jit(
            train_step,
            in_shardings=(named(param_ps), named(opt_ps), named(batch_ps)),
            out_shardings=(named(param_ps), named(opt_ps), None),
            donate_argnums=(0, 1),
        )
        return fn, (params_shapes, opt_shapes, inputs)

    if cfg.is_encoder_only:
        # encoders have no cache: prefill == full forward
        from repro.models import forward

        def encode_step(params, batch):
            return forward(params, batch, cfg, par=par)

        fn = jax.jit(encode_step,
                     in_shardings=(named(param_ps), named(batch_ps)),
                     out_shardings=None)
        return fn, (params_shapes, inputs)

    # inference cells need an abstract cache
    b = shape.global_batch
    max_len = shape.seq_len
    cache_shapes = jax.eval_shape(
        lambda: init_cache(cfg, b, max_len))
    cache_ps = cache_pspecs(cfg, par, cache_shapes)

    if shape.kind == "prefill":
        def prefill_step(params, batch, cache):
            return prefill(params, batch, cache, cfg, par=par)

        fn = jax.jit(
            prefill_step,
            in_shardings=(named(param_ps), named(batch_ps), named(cache_ps)),
            out_shardings=(None, named(cache_ps)),
            donate_argnums=(2,),
        )
        return fn, (params_shapes, inputs, cache_shapes)

    # decode
    def serve_step(params, tokens, positions, cache):
        logits, cache = decode_step(params, tokens, cache, cfg,
                                    positions=positions, par=par)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    fn = jax.jit(
        serve_step,
        in_shardings=(named(param_ps), NamedSharding(mesh, P(dp, None)),
                      NamedSharding(mesh, P(dp, None)), named(cache_ps)),
        out_shardings=(NamedSharding(mesh, P(dp)), named(cache_ps)),
        donate_argnums=(3,),
    )
    return fn, (params_shapes, inputs["tokens"], inputs["positions"], cache_shapes)


# ---------------------------------------------------------------------------
# HLO analysis
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_UPCAST_RE = re.compile(
    r"%(\S+) = (f32|bf16)\[([0-9,]*)\]\S* (convert|copy|fusion)\(")


def cpu_upcast_bytes(hlo_text: str) -> int:
    """Bytes of big convert/copy buffers that exist only because XLA:CPU
    lacks native bf16/f8 dots (operands get upcast into materialized
    copies) or relies on layout copies a TPU compiler fuses/aliases.
    Subtracting them gives the TPU-realistic estimate. Only buffers
    >= 256 MiB are counted (one per op name) so genuine activation temps
    are untouched."""
    seen = set()
    total = 0
    for m in _UPCAST_RE.finditer(hlo_text):
        name, dt, dims, op = m.groups()
        if op == "fusion" and not name.startswith("wrapped_convert"):
            continue
        if name in seen:
            continue
        seen.add(name)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        bytes_ = n * (4 if dt == "f32" else 2)
        if bytes_ >= 256 * 2**20:
            total += bytes_
    return total


_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|([a-z0-9_]+\[[0-9,]*\])\S*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")


def _bytes_of(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


# result-bytes -> per-device ring link traffic: AG moves ~result bytes,
# AR ~2x result (reduce + broadcast phases), RS moves ~input = result x
# group (approximated with the 16-way mesh axis), A2A/CP ~result.
_LINK_WEIGHT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 16.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def collective_bytes(hlo_text: str) -> dict:
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for m in _COLL_RE.finditer(hlo_text):
        tuple_types, single, op = m.groups()
        total = 0
        if single:
            total = _bytes_of(single)
        else:
            for part in _SHAPE_RE.finditer(tuple_types or ""):
                total += _bytes_of(part.group(0))
        out[op] += total
        counts[op] += 1
    link = {k: v * _LINK_WEIGHT[k] for k, v in out.items()}
    return {"bytes": out, "counts": counts, "link_bytes": link,
            "total_bytes": sum(out.values()),
            "total_link_bytes": sum(link.values())}


# ---------------------------------------------------------------------------
# extrapolated cost estimation
#
# XLA's cost_analysis counts while-loop (scan) bodies ONCE, so a scanned
# 62-layer model reports ~1/62 of the real FLOPs. We therefore compile
# analysis variants whose scans are removed or short and extrapolate:
#   * attention archs: attn_chunk = seq (full-attention einsum, exact S^2
#     cost in one op) x {1, 2}-layer depth -> linear depth extrapolation;
#   * ssm archs: SSD cost is linear in both depth and #chunks -> bilinear
#     (depth x seq) 4-point extrapolation at the production chunk size;
#   * hybrid (zamba2): ssm part as above + n_groups x (2-pt dense-variant
#     per-shared-attention-block cost);
#   * decode cells: no seq scans at decode -> depth extrapolation only.
# ---------------------------------------------------------------------------

import dataclasses as _dc


def _variant_depths(cfg: ModelConfig):
    if cfg.family == "hybrid":
        unit = cfg.attn_every
        return unit, 2 * unit, cfg.n_layers // unit
    n_head = cfg.moe.first_dense_layers if cfg.moe is not None else 0
    return n_head + 1, n_head + 2, cfg.n_layers - n_head


def _compile_cost(cfg, shape, mesh, remat):
    fn, args = build_cell(cfg, shape, mesh, remat=remat, grad_accum=1)
    compiled = fn.lower(*args).compile()
    ca = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll["link_bytes"], "coll_counts": coll["counts"]}


def _combine(c1, c2, scale_fn):
    """elementwise extrapolation: out = scale_fn(v1, v2)."""
    out = {}
    for key in ("flops", "bytes"):
        out[key] = scale_fn(c1[key], c2[key])
    out["coll"] = {k: scale_fn(c1["coll"][k], c2["coll"][k]) for k in c1["coll"]}
    return out


def estimate_cost(cfg: ModelConfig, shape: ShapeConfig, mesh, remat="full"):
    d1, d2, units = _variant_depths(cfg)
    if shape.kind == "decode" or cfg.family not in ("ssm", "hybrid"):
        # full-attention analysis variant for train/prefill; decode keeps
        # production config (no seq scans at decode)
        # keep flash chunking but cap the unrolled body count at ~4x4
        ac = max(cfg.attn_chunk, shape.seq_len // 4) if shape.kind != "decode" \
            else cfg.attn_chunk
        mk = lambda L: _dc.replace(cfg, n_layers=L, attn_chunk=ac,
                                   unroll_layers=True)
        c1 = _compile_cost(mk(d1), shape, mesh, remat)
        c2 = _compile_cost(mk(d2), shape, mesh, remat)
        est = _combine(c1, c2, lambda a, b: a + (units - 1) * (b - a))
        if cfg.family in ("ssm", "hybrid") and shape.kind == "decode":
            return est
        if cfg.family in ("ssm", "hybrid"):
            raise AssertionError  # handled below
        return est

    q = cfg.ssm.chunk
    sub_shape = lambda n: _dc.replace(shape, seq_len=n * q,
                                      global_batch=shape.global_batch)
    nc = shape.seq_len // q
    if cfg.family == "ssm":
        mk = lambda L: _dc.replace(cfg, n_layers=L, unroll_layers=True)
        c11 = _compile_cost(mk(d1), sub_shape(1), mesh, remat)
        c12 = _compile_cost(mk(d1), sub_shape(2), mesh, remat)
        c21 = _compile_cost(mk(d2), sub_shape(1), mesh, remat)
        c22 = _compile_cost(mk(d2), sub_shape(2), mesh, remat)
        # bilinear: c(L, n) = a + b L + g n + d L n, evaluate (units, nc)
        def bil(v11, v12, v21, v22):
            dd = d2 - d1
            bL = (v21 - v11) / dd
            gn = v12 - v11
            dn = ((v22 - v21) - (v12 - v11)) / dd
            a = v11 - bL * d1 - gn * 1 - dn * d1 * 1
            lfull = cfg.n_layers  # == units for ssm (no head layers)
            return a + bL * lfull + gn * nc + dn * lfull * nc
        out = {"flops": bil(c11["flops"], c12["flops"], c21["flops"], c22["flops"]),
               "bytes": bil(c11["bytes"], c12["bytes"], c21["bytes"], c22["bytes"]),
               "coll": {k: bil(c11["coll"][k], c12["coll"][k], c21["coll"][k],
                               c22["coll"][k]) for k in c11["coll"]}}
        return out
    # hybrid: ssm-only bilinear + per-shared-attn-block 2-point (full attn)
    ssm_cfg = _dc.replace(cfg, family="ssm", attn_every=0)
    ssm_est = estimate_cost(_dc.replace(ssm_cfg, n_layers=cfg.n_layers),
                            shape, mesh, remat)
    dense_cfg = lambda L: _dc.replace(cfg, family="dense", ssm=None,
                                      attn_every=0, n_layers=L,
                                      attn_chunk=max(cfg.attn_chunk,
                                                     shape.seq_len // 4),
                                      unroll_layers=True)
    a1 = _compile_cost(dense_cfg(1), shape, mesh, remat)
    a2 = _compile_cost(dense_cfg(2), shape, mesh, remat)
    per_blk = _combine(a1, a2, lambda a, b: b - a)
    n_groups = cfg.n_layers // cfg.attn_every
    return _combine(ssm_est, per_blk, lambda s, p: s + n_groups * p)


HBM_BUDGET = 15 * 2**30  # leave headroom under 16 GiB


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             remat: str = "dots", cache_dtype=None) -> dict:
    cfg = get_config(arch)
    if cache_dtype:
        import dataclasses as _dcl
        cfg = _dcl.replace(cfg, cache_dtype=cache_dtype)
    shape = get_shape(shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "kind": shape.kind}
    reason = cell_skip_reason(cfg, shape)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    t0 = time.time()
    with mesh:
        fn, args = build_cell(cfg, shape, mesh, remat=remat)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    rec["status"] = "ok"
    rec["lower_s"] = round(t_lower, 1)
    rec["compile_s"] = round(t_compile, 1)
    hlo_text = None
    try:
        hlo_text = compiled.as_text()
    except Exception:  # noqa: BLE001
        pass
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(ma, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(ma, k)
        }
        args_b = rec["memory"].get("argument_size_in_bytes", 0)
        temp_b = rec["memory"].get("temp_size_in_bytes", 0)
        out_b = rec["memory"].get("output_size_in_bytes", 0)
        alias_b = rec["memory"].get("alias_size_in_bytes", 0)
        rec["memory"]["per_device_total_bytes"] = args_b + temp_b + max(
            out_b - alias_b, 0)
        if hlo_text:
            upcast = cpu_upcast_bytes(hlo_text)
            rec["memory"]["cpu_bf16_upcast_bytes"] = upcast
            rec["memory"]["per_device_total_bytes_tpu_estimate"] = max(
                rec["memory"]["per_device_total_bytes"] - upcast, args_b)
    except Exception as e:  # noqa: BLE001
        rec["memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        rec["cost"] = {k: float(ca[k]) for k in ("flops", "bytes accessed")
                       if k in ca}
        for k, v in ca.items():
            if k.startswith("bytes accessed") and isinstance(v, (int, float)):
                rec["cost"][k] = float(v)
    except Exception as e:  # noqa: BLE001
        rec["cost"] = {"error": str(e)}
    try:
        rec["collectives"] = collective_bytes(compiled.as_text())
    except Exception as e:  # noqa: BLE001
        rec["collectives"] = {"error": str(e)}
    n_chips = int(np.prod(list(mesh.shape.values())))
    rec["n_chips"] = n_chips
    try:
        with mesh:
            rec["cost_extrapolated"] = estimate_cost(cfg, shape, mesh,
                                                     remat=remat)
    except Exception as e:  # noqa: BLE001
        rec["cost_extrapolated"] = {"error": str(e),
                                    "trace": traceback.format_exc()}
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out_dir = args.out or os.path.abspath(OUT_DIR)
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape))

    failures = 0
    for mesh_kind in meshes:
        os.makedirs(os.path.join(out_dir, mesh_kind), exist_ok=True)
        for arch, shape in cells:
            tag = f"{mesh_kind}/{arch}__{shape}"
            path = os.path.join(out_dir, mesh_kind, f"{arch}__{shape}.json")
            try:
                rec = run_cell(arch, shape, mesh_kind, remat=args.remat)
                mem = rec.get("memory", {})
                if (rec.get("status") == "ok" and rec.get("kind") == "decode"
                        and mem.get("argument_size_in_bytes", 0) > HBM_BUDGET):
                    # bf16 cache alone exceeds HBM: retry with an fp8 cache
                    rec = run_cell(arch, shape, mesh_kind, remat=args.remat,
                                   cache_dtype="float8_e4m3fn")
                    rec["kv_cache_dtype"] = "float8_e4m3fn"
            except Exception:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                       "status": "error", "trace": traceback.format_exc()}
                failures += 1
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = rec.get("status")
            extra = ""
            if status == "ok":
                mem = rec.get("memory", {}).get("per_device_total_bytes")
                fl = rec.get("cost", {}).get("flops")
                extra = (f" mem/dev={mem/2**30:.2f}GiB" if mem else "") + \
                        (f" flops/dev={fl:.3g}" if fl else "") + \
                        f" compile={rec.get('compile_s')}s"
            elif status == "skipped":
                extra = f" ({rec['reason']})"
            print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
