"""Production meshes. A FUNCTION, not a constant — importing this module
never touches jax device state (required by the dry-run's
xla_force_host_platform_device_count dance)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods =
    512 chips as (pod=2, data=16, model=16); the 'pod' axis carries only
    data parallelism (gradient all-reduce crosses the inter-pod links)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this host actually has (tests/examples)."""
    n = jax.device_count()
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
