"""Serving launcher: batched generation with LOMS top-k sampling.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16 --top-k 16
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--top-k", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, get_smoke_config
    from repro.models import model_init
    from repro.serving.engine import ServeConfig, generate

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    assert not cfg.is_encoder_only, f"{cfg.name} is encoder-only: no decode"
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.frontend_len, cfg.frontend_dim)),
            jnp.float32)
    out = generate(params, batch, cfg,
                   ServeConfig(max_new_tokens=args.new_tokens, top_k=args.top_k,
                               temperature=args.temperature))
    print(f"[serve] tokens shape {out['tokens'].shape} "
          f"prefill {out['prefill_s']*1e3:.1f}ms "
          f"decode {out['tok_per_s']:.1f} tok/s")
    print(out["tokens"][:2])


if __name__ == "__main__":
    main()
