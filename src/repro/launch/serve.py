"""Serving launcher: batched generation with LOMS top-k sampling.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16 --top-k 16

Ragged prompts (attention-cache families): ``--ragged`` draws a random
length per request in [1, prompt_len], right-pads the batch, and prefill
gathers each row's logits at its own last valid position — bit-identical
per row to running the unpadded prompt alone.

Engine mode: ``--engine`` routes the same request mix through the
continuous-batching scheduler (admission queue, paged KV-cache slots,
disaggregated prefill/decode) instead of one-shot ``generate()``.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--ragged", action="store_true",
                    help="random per-request prompt lengths in "
                         "[1, prompt_len], right-padded per bucket")
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--top-k", type=int, default=16)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--engine", action="store_true",
                    help="serve through the request scheduler "
                         "(paged slots + continuous batching)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, get_smoke_config
    from repro.models import model_init
    from repro.serving.engine import ServeConfig, generate

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    assert not cfg.is_encoder_only, f"{cfg.name} is encoder-only: no decode"
    params, _ = model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    lengths = None
    if args.ragged:
        assert cfg.family in ("dense", "moe"), \
            f"--ragged needs attention caches, not {cfg.family}"
        lengths = rng.integers(1, args.prompt_len + 1, args.batch).astype(np.int32)
    tokens = rng.integers(0, cfg.vocab_size,
                          (args.batch, args.prompt_len)).astype(np.int32)
    if lengths is not None:
        for r, n in enumerate(lengths):  # right-pad past each valid length
            tokens[r, n:] = 0

    if args.engine:
        from repro.serving.scheduler import (
            SamplingParams, ScheduledEngine, SchedulerConfig)

        assert cfg.family == "dense", \
            "--engine bit-equality contract covers dense stacks"
        lens = lengths if lengths is not None \
            else np.full(args.batch, args.prompt_len, np.int32)
        import math
        pages = math.ceil((args.prompt_len + args.new_tokens) / args.page_size)
        eng = ScheduledEngine(params, cfg, SchedulerConfig(
            n_slots=args.slots, page_size=args.page_size,
            pages_per_slot=pages))
        rids = [eng.submit(tokens[r, :lens[r]],
                           SamplingParams(k=args.top_k, top_p=args.top_p,
                                          temperature=args.temperature,
                                          max_new_tokens=args.new_tokens,
                                          seed=r),
                           arrival=r)
                for r in range(args.batch)]
        out = eng.run()
        print(f"[serve] engine drained {len(out)} requests in {eng.t} ticks "
              f"({args.slots} slots, page {args.page_size})")
        for rid in rids[:2]:
            print(f"  rid {rid}: {out[rid]}")
        return

    batch = {"tokens": jnp.asarray(tokens)}
    if lengths is not None:
        batch["lengths"] = lengths
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.frontend_len, cfg.frontend_dim)),
            jnp.float32)
    out = generate(params, batch, cfg,
                   ServeConfig(max_new_tokens=args.new_tokens, top_k=args.top_k,
                               top_p=args.top_p,
                               temperature=args.temperature))
    print(f"[serve] tokens shape {out['tokens'].shape} "
          f"prefill {out['prefill_s']*1e3:.1f}ms "
          f"decode {out['tok_per_s']:.1f} tok/s")
    print(out["tokens"][:2])


if __name__ == "__main__":
    main()
