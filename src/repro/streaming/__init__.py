"""repro.streaming — composed LOMS pipelines for production-scale workloads.

The layer between the fixed-shape Pallas sorters (``repro.kernels``) and
serving (``repro.serving``): chunked merges that stream arbitrarily long
sorted inputs through tile-sized kernel invocations, a device-tree sharded
top-k for TP-sharded vocabs, and a planner + disk-backed autotune cache
that picks the kernel knobs per problem shape. See DESIGN.md §8.

This package provides the "streaming" and "sharded" backends of the
unified dispatch layer (``repro.merge``/``repro.topk`` route here for
past-VMEM inputs and TP-sharded vocabs; DESIGN.md §9) — prefer those
entry points unless you need a specific realization.
"""
from .cache import (  # noqa: F401
    SCHEMA_VERSION,
    AutotuneCache,
    default_cache,
    default_cache_path,
    plan_key,
)
from .chunked import chunked_merge, chunked_merge_k  # noqa: F401
from .grid_merge import grid_chunked_merge2  # noqa: F401
from .planner import (  # noqa: F401
    MergePlan,
    autotune_merge2,
    autotune_op,
    autotune_sort,
    autotune_topk,
    fits_vmem,
    kway_fits_vmem,
    pick_block_batch,
    plan_chunked,
    plan_chunked_k,
    plan_merge2,
    plan_op,
    plan_sort,
    sort_fits_vmem,
    vmem_budget,
)
from .tree import local_topk_desc, tree_topk, tree_topk_for  # noqa: F401
