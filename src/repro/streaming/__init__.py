"""repro.streaming — composed LOMS pipelines for production-scale workloads.

The layer between the fixed-shape Pallas sorters (``repro.kernels``) and
serving (``repro.serving``): chunked merges that stream arbitrarily long
sorted inputs through tile-sized kernel invocations, a device-tree sharded
top-k for TP-sharded vocabs, and a planner + disk-backed autotune cache
that picks the kernel knobs per problem shape. See DESIGN.md §8.
"""
from .cache import AutotuneCache, default_cache, default_cache_path, plan_key  # noqa: F401
from .chunked import chunked_merge, chunked_merge_k  # noqa: F401
from .planner import (  # noqa: F401
    MergePlan,
    autotune_merge2,
    plan_chunked,
    plan_chunked_k,
    plan_merge2,
)
from .tree import local_topk_desc, tree_topk, tree_topk_for  # noqa: F401
