"""repro.streaming — composed LOMS pipelines for production-scale workloads.

The layer between the fixed-shape Pallas sorters (``repro.kernels``) and
serving (``repro.serving``): chunked merges that stream arbitrarily long
sorted inputs through tile-sized kernel invocations, a device-tree sharded
top-k for TP-sharded vocabs, and a planner + disk-backed autotune cache
that picks the kernel knobs per problem shape. See DESIGN.md §8.

This package provides the "streaming" and "sharded" backends of the
unified dispatch layer (``repro.merge``/``repro.topk`` route here for
past-VMEM inputs and TP-sharded vocabs; DESIGN.md §9) — prefer those
entry points unless you need a specific realization.
"""
from .cache import AutotuneCache, default_cache, default_cache_path, plan_key  # noqa: F401
from .chunked import chunked_merge, chunked_merge_k  # noqa: F401
from .planner import (  # noqa: F401
    MergePlan,
    autotune_merge2,
    fits_vmem,
    kway_fits_vmem,
    plan_chunked,
    plan_chunked_k,
    plan_merge2,
    vmem_budget,
)
from .tree import local_topk_desc, tree_topk, tree_topk_for  # noqa: F401
