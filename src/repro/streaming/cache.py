"""Disk-backed autotune cache for the merge planner.

One JSON file maps a plan key — ``(op, shapes, k, dtype, backend)`` encoded
as a string — to the winning :class:`~repro.streaming.planner.MergePlan`
fields plus the measured time. Writes are atomic (tmp file +
``os.replace``) so concurrent benchmark runs can never leave a torn file;
``save`` first merges entries another writer landed since our load (last
writer wins per key, nobody's keys are dropped). Reads tolerate a missing
file by starting empty; a *corrupt* file (torn write from a crashed
pre-atomic tool, disk garbage) is quarantined to a ``<path>.bad`` sidecar
— counted under the ``autotune.cache`` counter, ``result="quarantined"``
— so the next run starts clean instead of crashing on the same bytes
forever (an autotune cache is always reconstructible). I/O failures in
``put``/``save`` degrade to in-memory-only operation
(``result="store_failed"``) rather than failing the sort that triggered
the write.

Entries are stamped with :data:`SCHEMA_VERSION`. ``get`` ignores entries
written under a different schema (or none): when the plan fields change
meaning across releases, stale entries silently degrade to a heuristic
re-plan instead of mis-parameterizing a kernel.

The default location is ``$REPRO_AUTOTUNE_CACHE`` or
``~/.cache/repro_loms/autotune.json``.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Any, Dict, Optional

from repro.obs import metrics as obs_metrics

#: entry-format version; bump when MergePlan fields change meaning.
#: v2 added the fused-pipeline knobs (``block``) and the VMEM-fit
#: (non-divisor) block_batch semantics. v3 added the segmented size-class
#: plan family (``segmented|batch x widths`` keys, block_batch counting
#: segments per tile) — pre-segmented caches are ignored wholesale rather
#: than risking a dense-era entry mis-tiling a class launch. v4 added the
#: ``network`` field (the per-size-class family-tournament winner:
#: "loms" | "s2ms" | "periodic3" | "bitonic") — v3 entries were tuned
#: LOMS-only, so replaying them would silently pin every size class to
#: the column device and skip the tournament's measured choice.
SCHEMA_VERSION = 4


def default_cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro_loms", "autotune.json"
    )


def plan_key(op: str, *, shapes, dtype, k: Optional[int] = None,
             backend: Optional[str] = None) -> str:
    """Stable string key for one tuning point.

    ``shapes`` is any nested int structure (list lengths + batch); ``k`` the
    truncation (top-k) if any; ``backend`` defaults to the active JAX
    backend so TPU and CPU-interpret timings never mix."""
    if backend is None:
        import jax

        backend = jax.default_backend()
    shp = "x".join(str(int(s)) for s in _flat_ints(shapes))
    return f"{op}|{shp}|k{k if k is not None else '-'}|{dtype}|{backend}"


def _flat_ints(obj):
    if isinstance(obj, (list, tuple)):
        for o in obj:
            yield from _flat_ints(o)
    else:
        yield int(obj)


class AutotuneCache:
    """get/put dict-of-json-scalars entries keyed by :func:`plan_key`."""

    def __init__(self, path: Optional[str] = None, autosave: bool = True):
        self.path = path or default_cache_path()
        self.autosave = autosave
        self._lock = threading.Lock()
        self._entries: Dict[str, Dict[str, Any]] = {}
        self.load()

    def load(self) -> None:
        self._entries = {}
        try:
            from repro.resilience.failpoints import failpoint

            failpoint("cache.load")
            data = self._read_disk()
        except FileNotFoundError:
            return  # first run: nothing to load, nothing to report
        except ValueError:
            # corrupt JSON: quarantine the bytes and start empty — the
            # sidecar keeps the evidence without re-crashing every run
            self._quarantine()
            return
        except Exception:  # noqa: BLE001 — cache is reconstructible
            obs_metrics.counter("autotune.cache").inc(op="-",
                                                      result="load_failed")
            return
        if isinstance(data, dict):
            self._entries = {str(k): dict(v) for k, v in data.items()
                             if isinstance(v, dict)}

    def _read_disk(self) -> Any:
        with open(self.path) as f:
            return json.load(f)

    def _quarantine(self) -> None:
        from repro.obs import recorder as obs_recorder

        obs_metrics.counter("autotune.cache").inc(op="-",
                                                  result="quarantined")
        obs_recorder.emit("quarantine", self.path,
                          sidecar=self.path + ".bad")
        try:
            os.replace(self.path, self.path + ".bad")
        except OSError:
            pass  # racing writer already replaced it; nothing to keep

    def save(self) -> None:
        with self._lock:
            self._save_locked()

    def _save_locked(self) -> None:
        from repro.resilience.failpoints import failpoint

        failpoint("cache.store")
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        # merge entries a concurrent writer landed since our load: ours
        # win per-key, theirs survive wholesale (corrupt on-disk state is
        # ignored here — load() owns quarantine)
        merged: Dict[str, Dict[str, Any]] = {}
        try:
            data = self._read_disk()
            if isinstance(data, dict):
                merged = {str(k): dict(v) for k, v in data.items()
                          if isinstance(v, dict)}
        except (OSError, ValueError):
            pass
        merged.update(self._entries)
        self._entries = merged
        fd, tmp = tempfile.mkstemp(
            dir=os.path.dirname(self.path) or ".", suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self._entries, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)  # atomic swap
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        with self._lock:
            entry = self._entries.get(key)
        # hit / miss / stale-schema telemetry: the op is the key's first
        # component (low-cardinality by construction), the result label is
        # what the measured-cost planner reads to know its coverage
        op = key.split("|", 1)[0]
        if entry is None:
            obs_metrics.counter("autotune.cache").inc(op=op, result="miss")
            return None
        if entry.get("_schema") != SCHEMA_VERSION:
            obs_metrics.counter("autotune.cache").inc(op=op,
                                                      result="stale_schema")
            return None  # stale-schema entries degrade to a heuristic plan
        obs_metrics.counter("autotune.cache").inc(op=op, result="hit")
        return entry

    def put(self, key: str, value: Dict[str, Any]) -> None:
        with self._lock:
            self._entries[key] = dict(value, _schema=SCHEMA_VERSION)
            if not self.autosave:
                return
            try:
                self._save_locked()
            except Exception:  # noqa: BLE001 — keep tuning in memory
                obs_metrics.counter("autotune.cache").inc(
                    op=key.split("|", 1)[0], result="store_failed")

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: str) -> bool:
        return key in self._entries


_default: Optional[AutotuneCache] = None


def default_cache() -> AutotuneCache:
    global _default
    if _default is None:
        _default = AutotuneCache()
    return _default


def set_default_cache(cache: Optional[AutotuneCache]) -> Optional[AutotuneCache]:
    """Swap the process-default cache (``None`` resets to lazy re-init).
    Returns the previous instance — tests point dispatch/planner lookups
    at a temp file without monkeypatching module internals."""
    global _default
    prev = _default
    _default = cache
    return prev
