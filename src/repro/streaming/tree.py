"""Device-tree sharded top-k: log-depth LOMS merge reduction over a mesh axis.

Serving-scale decode needs top-k over a vocab that is sharded across the
tensor-parallel axis. Each device computes a local blockwise LOMS top-k of
its vocab slice (global indices restored from the shard offset), then the
per-shard (value, index) candidate lists reduce across the axis through a
log-depth tree of truncated UP-k/DN-k merges — the paper's 2-stage merge
device reading only its upper rows, exactly as in ``kernels/topk.py`` but
with the tree edges mapped onto inter-device links:

* power-of-two axis: a butterfly exchange (``lax.ppermute`` partners at
  XOR distance 1, 2, 4, ...) — k values per link per step, every shard
  finishes with the replicated global top-k;
* any other axis size: one ``lax.all_gather`` of the k-candidate lists
  followed by the same log-depth merge tree computed redundantly per shard.

Everything inside the ``shard_map`` body is plain jnp built from the same
comparison-cloud/one-hot primitives the Pallas kernels use, so it traces
under manual sharding on any backend.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.common import (
    merge2_sorted,
    sentinel_min,
    sort_nsorter,
    use_mxu_for,
)


def _resolve_mxu(use_mxu: Optional[bool], dtype) -> bool:
    """``use_mxu=None`` -> by dtype (kernels.common.use_mxu_for): int
    values would overflow the f32 one-hot matmul mantissa."""
    if use_mxu is None:
        return use_mxu_for(dtype)
    return bool(use_mxu)


def _merge_desc(av, ai, bv, bi, keep: int, use_mxu: bool):
    """Merge two descending (value, index) lists, keep the top ``keep``."""
    mv, mi = merge2_sorted(av[..., ::-1], bv[..., ::-1],
                           payload=(ai[..., ::-1], bi[..., ::-1]),
                           use_mxu=use_mxu)
    return mv[..., ::-1][..., :keep], mi[..., ::-1][..., :keep]


def _tree_reduce_desc(vs, is_, k: int, use_mxu: bool):
    """Reduce a (..., S, k) stack of descending lists to (..., k)."""
    neg = sentinel_min(vs.dtype)
    while vs.shape[-2] > 1:
        if vs.shape[-2] % 2:
            pad = [(0, 0)] * (vs.ndim - 2) + [(0, 1), (0, 0)]
            vs = jnp.pad(vs, pad, constant_values=neg)
            is_ = jnp.pad(is_, pad, constant_values=-1)  # never alias slot 0
        vs, is_ = _merge_desc(vs[..., 0::2, :], is_[..., 0::2, :],
                              vs[..., 1::2, :], is_[..., 1::2, :], k, use_mxu)
    return vs[..., 0, :], is_[..., 0, :]


def local_topk_desc(
    x: jnp.ndarray,
    k: int,
    *,
    block: int = 128,
    offset=0,
    use_mxu: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise descending top-k of (B, E) with global indices ``+offset``.

    The in-kernel algorithm of ``router_topk_pallas`` as plain jnp: N-sorter
    per block, then a log-depth tree of truncated LOMS merges. Safe inside
    shard_map/vmap (no pallas_call)."""
    use_mxu = _resolve_mxu(use_mxu, x.dtype)
    bsz, e = x.shape
    neg = sentinel_min(x.dtype)
    nblk = -(-e // block)
    ep = nblk * block
    if ep != e:
        x = jnp.pad(x, [(0, 0), (0, ep - e)], constant_values=neg)
    lane = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    idx = jnp.where(lane < e, lane + jnp.asarray(offset, jnp.int32), -1)
    xb = x.reshape(bsz, nblk, block)
    ib = idx.reshape(bsz, nblk, block)
    vs, is_ = sort_nsorter(xb, ib, use_mxu=use_mxu)
    kk = min(k, block)
    vs = vs[..., ::-1][..., :kk]
    is_ = is_[..., ::-1][..., :kk]
    vs, is_ = _tree_reduce_desc(vs, is_, k, use_mxu)
    if vs.shape[-1] < k:  # degenerate: fewer candidates than k on this shard
        pad = [(0, 0)] * (vs.ndim - 1) + [(0, k - vs.shape[-1])]
        vs = jnp.pad(vs, pad, constant_values=neg)
        is_ = jnp.pad(is_, pad, constant_values=-1)
    return vs, is_


def _butterfly_topk(vals, idxs, k: int, axis: str, size: int, use_mxu: bool):
    """XOR-partner butterfly: after log2(size) exchange+merge steps every
    shard holds the identical global top-k."""
    step = 1
    while step < size:
        perm = [(i, i ^ step) for i in range(size)]
        ov = jax.lax.ppermute(vals, axis, perm)
        oi = jax.lax.ppermute(idxs, axis, perm)
        vals, idxs = _merge_desc(vals, idxs, ov, oi, k, use_mxu)
        step *= 2
    return vals, idxs


def tree_topk(
    x: jnp.ndarray,
    k: int,
    *,
    mesh: Optional[Mesh] = None,
    axis: Optional[str] = None,
    block: int = 128,
    use_mxu: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Descending top-k (values, int32 indices) over the last axis of (B, E).

    With ``mesh``/``axis`` given and the axis larger than 1, E is treated as
    sharded over that axis and reduced by the device-tree; otherwise this is
    the single-device log-tree (same merge network, local edges)."""
    assert x.ndim == 2, x.shape
    use_mxu = _resolve_mxu(use_mxu, x.dtype)
    bsz, e = x.shape
    if mesh is None or axis is None or mesh.shape[axis] == 1:
        vs, is_ = local_topk_desc(x, k, block=block, use_mxu=use_mxu)
        return vs, is_
    size = int(mesh.shape[axis])
    assert e % size == 0, (e, size)
    shard = e // size
    pow2 = size & (size - 1) == 0

    def body(xs):  # xs: (B, E/size) local shard
        me = jax.lax.axis_index(axis)
        off = (me * shard).astype(jnp.int32)
        vs, is_ = local_topk_desc(xs, k, block=min(block, shard), offset=off,
                                  use_mxu=use_mxu)
        if pow2:
            return _butterfly_topk(vs, is_, k, axis, size, use_mxu)
        allv = jax.lax.all_gather(vs, axis, axis=1)  # (B, S, k)
        alli = jax.lax.all_gather(is_, axis, axis=1)
        return _tree_reduce_desc(allv, alli, k, use_mxu)

    from repro.parallel.sharding import shard_map_compat

    fn = shard_map_compat(
        body,
        mesh,
        in_specs=P(None, axis),
        out_specs=(P(None, None), P(None, None)),
    )
    return fn(x)


def tree_topk_for(par, x: jnp.ndarray, k: int, **kw):
    """Top-k routed by a :class:`repro.parallel.sharding.Parallelism`: the
    device-tree over the TP axis when the vocab divides it, else local."""
    from repro.parallel.sharding import vocab_topk_axis

    axis = vocab_topk_axis(par, x.shape[-1]) if par is not None else None
    if axis is None:
        return tree_topk(x, k, **kw)
    return tree_topk(x, k, mesh=par.mesh, axis=axis, **kw)
