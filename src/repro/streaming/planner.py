"""Merge planner: pick the kernel schedule knobs for a given problem.

Two layers:

* :func:`plan_merge2` / :func:`plan_chunked` — closed-form heuristics from
  the paper's cost model (stage-1 comparison cloud is ``m*n/C`` comparators,
  stage-2 row sorts are ``(m+n)*C``; optimal column count sits near
  ``sqrt(m*n/(m+n))``) plus the ~16 MiB VMEM budget from DESIGN.md §2.
* :func:`autotune_merge2` — measure a small candidate grid on the live
  backend and persist the winner in the :mod:`~repro.streaming.cache`
  autotune cache, so the second process on the same host skips the sweep.

A plan never changes semantics — every candidate computes the same merge —
so a stale cache entry costs speed, not correctness.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .cache import AutotuneCache, default_cache, plan_key

# conservative per-core on-chip working-set budget (bytes); DESIGN.md §2
_VMEM_BUDGET = 8 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class MergePlan:
    """Resolved knobs for one merge problem (all kernel-static)."""

    kind: str = "loms"  # 'loms' | 'bitonic' | 'schedule' (ragged fallback)
    n_cols: int = 2
    block_batch: int = 8
    use_mxu: bool = True
    tile: int = 512  # chunked/streaming tile size (per input)
    source: str = "heuristic"  # 'heuristic' | 'autotune' | 'cache'

    def to_entry(self, us: Optional[float] = None) -> dict:
        d = {
            "kind": self.kind,
            "n_cols": self.n_cols,
            "block_batch": self.block_batch,
            "use_mxu": self.use_mxu,
            "tile": self.tile,
        }
        if us is not None:
            d["us"] = float(us)
        return d

    @classmethod
    def from_entry(cls, entry: dict, source: str = "cache") -> "MergePlan":
        return cls(
            kind=str(entry.get("kind", "loms")),
            n_cols=int(entry["n_cols"]),
            block_batch=int(entry["block_batch"]),
            use_mxu=bool(entry["use_mxu"]),
            tile=int(entry.get("tile", 512)),
            source=source,
        )


def _itemsize(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def _is_float(dtype) -> bool:
    return jnp.issubdtype(jnp.dtype(dtype), jnp.floating)


def _vmem_bytes_merge2(m: int, n: int, n_cols: int, block_batch: int, dtype) -> int:
    """Rough stage-1 + stage-2 working set of the 2-way LOMS kernel."""
    it = max(_itemsize(dtype), 4)  # comparison/permute matrices go via f32
    vals = (m + n) * it
    cloud = (m // n_cols) * (n // n_cols) * 4  # widest column S2MS matrix
    rows = ((m + n) // n_cols) * n_cols * n_cols * 4  # row-sort matrices
    return block_batch * (vals + cloud + rows)


def _feasible_cols(m: int, n: int) -> Tuple[int, ...]:
    return tuple(c for c in (2, 4, 8, 16) if m % c == 0 and n % c == 0)


def vmem_budget() -> int:
    """Per-core on-chip working-set budget (bytes) the plans target."""
    return _VMEM_BUDGET


def fits_vmem(
    m: int, n: int, *, n_cols: int = 2, block_batch: int = 1, dtype=jnp.float32
) -> bool:
    """Whether one UP-m/DN-n kernel invocation stays inside the VMEM
    budget — the dispatch layer's direct-kernel vs streaming cutover."""
    return _vmem_bytes_merge2(m, n, n_cols, block_batch, dtype) <= _VMEM_BUDGET


def kway_fits_vmem(total: int) -> bool:
    """Whether a schedule-driven k-way merge of ``total`` elements stays
    inside the budget: it materializes a total^2 f32 comparison cloud per
    batch row. Shared by the dispatch ladder and the distributed
    sample-sort's per-device merge choice."""
    return total * total * 4 <= _VMEM_BUDGET


def plan_merge2(
    m: int,
    n: int,
    *,
    batch: int = 8,
    dtype=jnp.float32,
    target_block_batch: int = 8,
) -> MergePlan:
    """Heuristic plan for one UP-m/DN-n batched merge."""
    cols = _feasible_cols(m, n)
    if not cols:
        # hole-y setup array: the pure-JAX schedule executor handles it
        return MergePlan(kind="schedule", n_cols=2, block_batch=1,
                         use_mxu=_is_float(dtype), source="heuristic")
    # comparator cost model: stage1 m*n/C + stage2 (m+n)*C, minimized near
    # C* = sqrt(m*n/(m+n)); take the nearest feasible column count.
    c_star = float(np.sqrt(m * n / max(m + n, 1)))
    n_cols = min(cols, key=lambda c: abs(c - c_star))
    bb = target_block_batch
    while bb > 1 and _vmem_bytes_merge2(m, n, n_cols, bb, dtype) > _VMEM_BUDGET:
        bb //= 2
    bb = max(1, min(bb, batch))
    # int32+ values overflow the f32 one-hot matmul mantissa; route ints
    # through the exact scatter permute.
    use_mxu = _is_float(dtype)
    return MergePlan(kind="loms", n_cols=n_cols, block_batch=bb,
                     use_mxu=use_mxu, source="heuristic")


def plan_chunked(
    total_a: int,
    total_b: int,
    *,
    batch: int = 1,
    dtype=jnp.float32,
    tile: Optional[int] = None,
) -> MergePlan:
    """Plan for the streaming 2-way chunked merge (carry + tile kernels)."""
    if tile is None:
        # one tile step merges carry(T) with tile(T): keep 2T + matrices in
        # budget across the whole batch (the streaming loop runs batch-wide)
        tile = 512
        while tile > 32 and _vmem_bytes_merge2(
            tile, tile, 2, max(batch, 1), dtype
        ) > _VMEM_BUDGET:
            tile //= 2
    tile = max(2, tile - (tile % 2))  # n_cols=2 fast path needs even tiles
    base = plan_merge2(tile, tile, batch=batch, dtype=dtype)
    return dataclasses.replace(base, tile=tile)


def plan_chunked_k(
    lens: Sequence[int],
    *,
    batch: int = 1,
    dtype=jnp.float32,
    tile: Optional[int] = None,
) -> MergePlan:
    """Plan for the k-way chunked merge (k tile-segments per output tile)."""
    k = len(lens)
    if tile is None:
        tile = 128
        while tile > 16 and max(batch, 1) * (k * tile) * (k * tile) * 4 > _VMEM_BUDGET:
            tile //= 2
    return MergePlan(kind="schedule", n_cols=k, block_batch=max(1, min(8, batch)),
                     use_mxu=_is_float(dtype), tile=int(tile), source="heuristic")


# ---------------------------------------------------------------------------
# benchmark-backed autotune
# ---------------------------------------------------------------------------


def _time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6  # us


def _merge2_candidates(m: int, n: int, batch: int, dtype) -> Iterable[MergePlan]:
    for n_cols in _feasible_cols(m, n) or ():
        for bb in (16, 8, 4, 1):
            if bb > batch:
                continue
            if _vmem_bytes_merge2(m, n, n_cols, bb, dtype) > 2 * _VMEM_BUDGET:
                continue
            for use_mxu in ((True, False) if _is_float(dtype) else (False,)):
                yield MergePlan(kind="loms", n_cols=n_cols, block_batch=bb,
                                use_mxu=use_mxu, source="autotune")


def autotune_merge2(
    m: int,
    n: int,
    *,
    batch: int = 8,
    dtype=jnp.float32,
    cache: Optional[AutotuneCache] = None,
    candidates: Optional[Sequence[MergePlan]] = None,
    interpret: Optional[bool] = None,
    iters: int = 3,
) -> MergePlan:
    """Measure candidate (n_cols, block_batch, use_mxu) triples for one
    UP-m/DN-n batched merge; persist and return the winner.

    A cache hit skips measurement entirely. Falls back to the heuristic
    plan when no candidate is feasible (ragged m/n)."""
    from repro.kernels.loms_merge import loms_merge2_pallas

    cache = cache if cache is not None else default_cache()
    key = plan_key("merge2", shapes=(batch, m, n), dtype=jnp.dtype(dtype).name)
    hit = cache.get(key)
    if hit is not None:
        return MergePlan.from_entry(hit, source="cache")
    cands = list(candidates) if candidates is not None else list(
        _merge2_candidates(m, n, batch, dtype)
    )
    if not cands:
        return plan_merge2(m, n, batch=batch, dtype=dtype)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    rng = np.random.default_rng(0)
    a = jnp.sort(jnp.asarray(rng.integers(0, 1 << 16, (batch, m))).astype(dtype), -1)
    b = jnp.sort(jnp.asarray(rng.integers(0, 1 << 16, (batch, n))).astype(dtype), -1)
    best, best_us = None, float("inf")
    for plan in cands:
        us = _time_call(
            lambda x, y, p=plan: loms_merge2_pallas(
                x, y, n_cols=p.n_cols, block_batch=p.block_batch,
                use_mxu=p.use_mxu, interpret=interpret,
            ),
            a, b, iters=iters,
        )
        if us < best_us:
            best, best_us = plan, us
    cache.put(key, best.to_entry(best_us))
    return best
