"""Merge planner: pick the kernel schedule knobs for a given problem.

Three layers:

* :func:`plan_merge2` / :func:`plan_chunked` / :func:`plan_sort` /
  :func:`plan_topk` — closed-form heuristics from the paper's cost model
  (stage-1 comparison cloud is ``m*n/C`` comparators, stage-2 row sorts
  are ``(m+n)*C``; optimal column count sits near ``sqrt(m*n/(m+n))``)
  plus the ~16 MiB VMEM budget from DESIGN.md §2. Tiles are picked by
  **VMEM fit, not batch divisibility** — every kernel pads ragged batches
  (``kernels.common.pad_batch``), so a prime batch size no longer
  degrades to ``block_batch=1``.
* :func:`plan_op` — the cache-aware front door the kernel wrappers and
  the dispatch layer call: one (op, shapes, dtype, k, platform) key into
  the :mod:`~repro.streaming.cache` autotune cache, falling back to the
  heuristic plan on a miss. Runtime stays deterministic — a miss never
  triggers measurement.
* :func:`autotune_op` (and the op-specific ``autotune_*``) — measure a
  small candidate grid on the live backend and persist the winner, so the
  second process on the same host skips the sweep.

A plan never changes semantics — every candidate computes the same merge —
so a stale cache entry costs speed, not correctness.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import ceil_pow2
from repro.networks import capable_families, divisor_cols, pick_merge_cols
from repro.obs import metrics as obs_metrics
from repro.obs import recorder as obs_recorder
from repro.obs import trace as obs_trace
from repro.obs.timing import time_jitted

from .cache import AutotuneCache, default_cache, plan_key

# conservative per-core on-chip working-set budget (bytes); DESIGN.md §2
_VMEM_BUDGET = 8 * 1024 * 1024

#: block_batch candidates, largest first (power-of-two tiles pipeline best;
#: pad_batch absorbs ragged batch sizes)
_BB_CANDIDATES = (128, 64, 32, 16, 8, 4, 2, 1)


def _target_bb(batch: int, target: int) -> int:
    """Platform-aware batch-tile target. On TPU the default (8 sublanes)
    balances VMEM pressure against pipelining; off-TPU the kernels run in
    interpret mode where each grid step re-executes the kernel body, so
    the best tile is the whole batch (fewest steps) within the budget."""
    if jax.default_backend() == "tpu":
        return target
    return max(target, min(batch, _BB_CANDIDATES[0]))


@dataclasses.dataclass(frozen=True)
class MergePlan:
    """Resolved knobs for one sort/merge problem (all kernel-static)."""

    kind: str = "loms"  # 'loms' (pallas network kernel) | 'schedule' (ragged fallback)
    n_cols: int = 2
    #: comparator-network family executed by the pallas kernels — the
    #: per-size-class tournament winner ("loms", "s2ms", "periodic3",
    #: "bitonic"); heuristic plans default to the paper's column device
    network: str = "loms"
    block_batch: int = 8
    use_mxu: bool = True
    tile: int = 512  # chunked/streaming tile size (per input)
    block: int = 0  # topk block size (0 = op default)
    source: str = "heuristic"  # 'heuristic' | 'autotune' | 'cache'
    #: measured p50 wall time (µs) from the autotune sweep that produced
    #: this plan; ``None`` for heuristic plans. Round-trips through the
    #: cache (``to_entry``/``from_entry``) so the measured sample survives
    #: into later processes — the raw material of measured-cost dispatch.
    us: Optional[float] = None

    def to_entry(self, us: Optional[float] = None) -> dict:
        d = {
            "kind": self.kind,
            "network": self.network,
            "n_cols": self.n_cols,
            "block_batch": self.block_batch,
            "use_mxu": self.use_mxu,
            "tile": self.tile,
            "block": self.block,
        }
        measured = us if us is not None else self.us
        if measured is not None:
            d["us"] = float(measured)
        return d

    @classmethod
    def from_entry(cls, entry: dict, source: str = "cache") -> "MergePlan":
        us = entry.get("us")
        return cls(
            kind=str(entry.get("kind", "loms")),
            network=str(entry.get("network", "loms")),
            n_cols=int(entry["n_cols"]),
            block_batch=int(entry["block_batch"]),
            use_mxu=bool(entry["use_mxu"]),
            tile=int(entry.get("tile", 512)),
            block=int(entry.get("block", 0)),
            source=source,
            us=float(us) if us is not None else None,
        )


def _itemsize(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def _is_float(dtype) -> bool:
    return jnp.issubdtype(jnp.dtype(dtype), jnp.floating)


def _vmem_bytes_merge2(m: int, n: int, n_cols: int, block_batch: int, dtype) -> int:
    """Rough stage-1 + stage-2 working set of the 2-way LOMS kernel."""
    it = max(_itemsize(dtype), 4)  # comparison/permute matrices go via f32
    vals = (m + n) * it
    cloud = (m // n_cols) * (n // n_cols) * 4  # widest column S2MS matrix
    rows = ((m + n) // n_cols) * n_cols * n_cols * 4  # row-sort matrices
    return block_batch * (vals + cloud + rows)


def _vmem_bytes_sort(n: int, block_batch: int, dtype) -> int:
    """Rough working set of the fused merge-tree sort kernel: the widest
    tree level materializes a (npad/2, npad/2) rank cloud per row pair
    plus the value/position lanes."""
    it = max(_itemsize(dtype), 4)
    npad = ceil_pow2(n)
    cloud = (npad // 2) * (npad // 2) * 4 * 2  # cmp counts + rank ints
    lanes = npad * (it + 4) * 2  # values + int32 position lane, double-buffered
    return block_batch * (cloud + lanes)


def _feasible_cols(m: int, n: int) -> Tuple[int, ...]:
    """All feasible LOMS column counts — the actual common divisors of
    (m, n) >= 2 (``repro.networks.divisor_cols``), not a hardcoded pow2
    list, so non-pow2 runs get real column-device candidates too."""
    return divisor_cols(m, n)


def _tournament_cols(m: int, n: int, limit: int = 3) -> Tuple[int, ...]:
    """The ``limit`` feasible column counts nearest the comparator-cost
    optimum C* = sqrt(m*n/(m+n)) — the sweep grid stays small even when
    (m, n) has many divisors."""
    cols = _feasible_cols(m, n)
    if not cols:
        return ()
    c_star = (m * n / max(m + n, 1)) ** 0.5
    return tuple(sorted(sorted(cols, key=lambda c: abs(c - c_star))[:limit]))


def vmem_budget() -> int:
    """Per-core on-chip working-set budget (bytes) the plans target."""
    return _VMEM_BUDGET


def pick_block_batch(
    batch: int, row_bytes: Callable[[int], int], target: int = 8
) -> int:
    """Largest power-of-two batch tile whose working set fits the budget.

    ``row_bytes(bb)`` returns the kernel working set for a ``bb``-row tile.
    No divisibility requirement — ragged batches pad (``pad_batch``) and
    slice back, so a prime batch (B=1007) still runs with a wide tile and
    a short grid instead of degenerating to ``block_batch=1``."""
    batch = max(batch, 1)
    target = _target_bb(batch, max(target, 1))
    for bb in _BB_CANDIDATES:
        # allow one pad-up to the next power of two (a 5-row batch runs as
        # one 8-row tile), never more — padded rows are wasted compute
        if bb > target or bb >= 2 * batch:
            continue
        if row_bytes(bb) <= _VMEM_BUDGET:
            return bb
    return 1


def fits_vmem(
    m: int, n: int, *, n_cols: int = 2, block_batch: int = 1, dtype=jnp.float32
) -> bool:
    """Whether one UP-m/DN-n kernel invocation stays inside the VMEM
    budget — the dispatch layer's direct-kernel vs streaming cutover."""
    return _vmem_bytes_merge2(m, n, n_cols, block_batch, dtype) <= _VMEM_BUDGET


def kway_fits_vmem(total: int) -> bool:
    """Whether a schedule-driven k-way merge of ``total`` elements stays
    inside the budget: it materializes a total^2 f32 comparison cloud per
    batch row. Shared by the dispatch ladder and the distributed
    sample-sort's per-device merge choice."""
    return total * total * 4 <= _VMEM_BUDGET


def sort_fits_vmem(n: int, *, block_batch: int = 1, dtype=jnp.float32) -> bool:
    """Whether the fused single-launch sort kernel (kernels/sort.py) can
    run ``n``-element rows inside the budget — the dispatch layer's
    fused-pallas vs schedule-executor cutover for ``repro.sort``."""
    return _vmem_bytes_sort(n, block_batch, dtype) <= _VMEM_BUDGET


def plan_merge2(
    m: int,
    n: int,
    *,
    batch: int = 8,
    dtype=jnp.float32,
    target_block_batch: int = 8,
) -> MergePlan:
    """Heuristic plan for one UP-m/DN-n batched merge."""
    # comparator cost model: stage1 m*n/C + stage2 (m+n)*C, minimized near
    # C* = sqrt(m*n/(m+n)) — the one home for the rule is
    # repro.networks.pick_merge_cols (the family generators share it)
    n_cols = pick_merge_cols(m, n)
    if n_cols == 1:
        # hole-y setup array: the pure-JAX schedule executor handles it
        return MergePlan(kind="schedule", n_cols=2, block_batch=1,
                         use_mxu=_is_float(dtype), source="heuristic")
    bb = pick_block_batch(
        batch, lambda b: _vmem_bytes_merge2(m, n, n_cols, b, dtype),
        target=target_block_batch,
    )
    # int32+ values overflow the f32 one-hot matmul mantissa; route ints
    # through the exact scatter permute.
    use_mxu = _is_float(dtype)
    return MergePlan(kind="loms", n_cols=n_cols, block_batch=bb,
                     use_mxu=use_mxu, source="heuristic")


def plan_sort(n: int, *, batch: int = 8, dtype=jnp.float32,
              target_block_batch: int = 8) -> MergePlan:
    """Heuristic plan for the fused single-launch sort kernel."""
    bb = pick_block_batch(
        batch, lambda b: _vmem_bytes_sort(n, b, dtype),
        target=target_block_batch,
    )
    return MergePlan(kind="loms", n_cols=2, block_batch=bb,
                     use_mxu=_is_float(dtype), source="heuristic")


def plan_kway(total: int, *, batch: int = 8, dtype=jnp.float32,
              target_block_batch: int = 8) -> MergePlan:
    """Heuristic plan for the schedule-driven k-way merge kernel (its
    widest stage materializes a ~total^2 f32 cloud per row)."""
    bb = pick_block_batch(
        batch, lambda b: b * total * total * 4, target=target_block_batch,
    )
    return MergePlan(kind="loms", n_cols=2, block_batch=bb,
                     use_mxu=_is_float(dtype), source="heuristic")


def plan_topk(n: int, k: int, *, batch: int = 8, dtype=jnp.float32,
              target_block_batch: int = 8) -> MergePlan:
    """Heuristic plan for the blockwise top-k kernels: block ~ the point
    where the local n*block sort cloud balances the k^2 * n/block merge
    tree, clamped to the kernel-friendly range."""
    block = int(min(max(16, 1 << max(k - 1, 1).bit_length()), 128, n))
    while n % block and block > 16:
        block //= 2
    bb = pick_block_batch(
        batch, lambda b: b * n * (max(_itemsize(dtype), 4) + block * 4),
        target=target_block_batch,
    )
    return MergePlan(kind="loms", block=block, block_batch=bb,
                     use_mxu=_is_float(dtype), source="heuristic")


def plan_segmented(
    widths: Sequence[int],
    *,
    n_segments: int = 8,
    dtype=jnp.float32,
    target_block_batch: int = 8,
) -> MergePlan:
    """Heuristic plan for one segmented size-class launch.

    ``widths`` is the class's pow2 tile width — one entry for a class
    sort (kernels/segmented.py packs ``n_segments`` rows per tile and
    runs the unrolled LOMS tree, the same working set as the fused sort),
    two for a class merge (the column S2MS working set). ``block_batch``
    counts *segments* per tile, picked by VMEM fit exactly like the dense
    kernels — a class of 1007 ragged segments pads, it never degrades to
    1-row tiles."""
    widths = tuple(int(w) for w in widths)
    if len(widths) == 1:
        row_bytes = lambda bb: _vmem_bytes_sort(widths[0], bb, dtype)  # noqa: E731
        n_cols = 2
    else:
        assert len(widths) == 2, widths
        n_cols = max(pick_merge_cols(widths[0], widths[1]), 1)
        row_bytes = lambda bb: _vmem_bytes_merge2(  # noqa: E731
            widths[0], widths[1], n_cols, bb, dtype)
    bb = pick_block_batch(n_segments, row_bytes, target=target_block_batch)
    return MergePlan(kind="loms", n_cols=n_cols, block_batch=bb,
                     use_mxu=_is_float(dtype), source="heuristic")


def plan_chunked(
    total_a: int,
    total_b: int,
    *,
    batch: int = 1,
    dtype=jnp.float32,
    tile: Optional[int] = None,
) -> MergePlan:
    """Plan for the streaming 2-way chunked merge (carry + tile kernels)."""
    if tile is None:
        # one tile step merges carry(T) with tile(T): keep 2T + matrices in
        # budget across the whole batch (the streaming loop runs batch-wide)
        tile = 512
        while tile > 32 and _vmem_bytes_merge2(
            tile, tile, 2, max(batch, 1), dtype
        ) > _VMEM_BUDGET:
            tile //= 2
    tile = max(2, tile - (tile % 2))  # n_cols=2 fast path needs even tiles
    base = plan_merge2(tile, tile, batch=batch, dtype=dtype)
    return dataclasses.replace(base, tile=tile)


def plan_chunked_k(
    lens: Sequence[int],
    *,
    batch: int = 1,
    dtype=jnp.float32,
    tile: Optional[int] = None,
) -> MergePlan:
    """Plan for the k-way chunked merge (k tile-segments per output tile)."""
    k = len(lens)
    if tile is None:
        tile = 128
        while tile > 16 and max(batch, 1) * (k * tile) * (k * tile) * 4 > _VMEM_BUDGET:
            tile //= 2
    return MergePlan(kind="schedule", n_cols=k, block_batch=max(1, min(8, batch)),
                     use_mxu=_is_float(dtype), tile=int(tile), source="heuristic")


# ---------------------------------------------------------------------------
# cache-aware front door: one key per (op, shapes, dtype, k, platform)
# ---------------------------------------------------------------------------

_HEURISTICS: Dict[str, Callable[..., MergePlan]] = {}


def _register_heuristic(op: str):
    def deco(fn):
        _HEURISTICS[op] = fn
        return fn
    return deco


_register_heuristic("merge2")(
    lambda lengths, batch, dtype, k: plan_merge2(
        lengths[0], lengths[1], batch=batch, dtype=dtype))
_register_heuristic("sort")(
    lambda lengths, batch, dtype, k: plan_sort(
        lengths[0], batch=batch, dtype=dtype))
_register_heuristic("kway")(
    lambda lengths, batch, dtype, k: plan_kway(
        sum(lengths), batch=batch, dtype=dtype))
_register_heuristic("topk")(
    lambda lengths, batch, dtype, k: plan_topk(
        lengths[0], k or 1, batch=batch, dtype=dtype))
_register_heuristic("segmented")(
    lambda lengths, batch, dtype, k: plan_segmented(
        lengths, n_segments=batch, dtype=dtype))
_register_heuristic("chunked2")(
    lambda lengths, batch, dtype, k: plan_chunked(
        lengths[0], lengths[1], batch=batch, dtype=dtype))
_register_heuristic("chunked_k")(
    lambda lengths, batch, dtype, k: plan_chunked_k(
        lengths, batch=batch, dtype=dtype))


def estimate_vmem_bytes(
    op: str, lengths: Sequence[int], plan: MergePlan, dtype=jnp.float32
) -> int:
    """Estimated on-chip working set (bytes) of ``plan`` applied to one
    ``op`` problem — the TPU analog of the paper's LUT-usage column, and
    the per-plan resource figure the obs layer records."""
    lengths = tuple(int(x) for x in lengths)
    bb = max(plan.block_batch, 1)
    if op == "merge2":
        return _vmem_bytes_merge2(lengths[0], lengths[1], plan.n_cols, bb,
                                  dtype)
    if op == "sort":
        return _vmem_bytes_sort(lengths[0], bb, dtype)
    if op == "kway":
        total = sum(lengths)
        return bb * total * total * 4
    if op == "topk":
        block = plan.block or 32
        return bb * lengths[0] * (max(_itemsize(dtype), 4) + block * 4)
    if op == "segmented":
        if len(lengths) == 1:
            return _vmem_bytes_sort(lengths[0], bb, dtype)
        return _vmem_bytes_merge2(lengths[0], lengths[1], plan.n_cols, bb,
                                  dtype)
    if op in ("chunked2", "chunked_k"):
        t = max(plan.tile, 1)
        return _vmem_bytes_merge2(t, t, max(plan.n_cols, 2), bb, dtype)
    return 0


def plan_op(
    op: str,
    lengths: Sequence[int],
    *,
    batch: int = 8,
    dtype=jnp.float32,
    k: Optional[int] = None,
    cache: Optional[AutotuneCache] = None,
) -> MergePlan:
    """Cache-aware tile plan for one kernel problem.

    Looks up the autotune cache under a key that encodes the op, every
    list length, the batch, the dtype, ``k`` and the live platform; falls
    back to the closed-form heuristic on a miss (no measurement at
    runtime — only :func:`autotune_op` fills the cache)."""
    assert op in _HEURISTICS, (op, sorted(_HEURISTICS))
    cache = cache if cache is not None else default_cache()
    key = plan_key(op, shapes=(batch,) + tuple(lengths),
                   dtype=jnp.dtype(dtype).name, k=k)
    with obs_trace.span("plan_op", kind="trace", op=op):
        hit = cache.get(key)
        if hit is not None:
            plan = MergePlan.from_entry(hit, source="cache")
        else:
            plan = _HEURISTICS[op](tuple(lengths), batch, dtype, k)
    if obs_trace.enabled():
        obs_metrics.counter("plan.tile_plans").inc(op=op, source=plan.source)
        obs_metrics.histogram("plan.vmem_bytes").observe(
            estimate_vmem_bytes(op, lengths, plan, dtype), op=op)
    return plan


# ---------------------------------------------------------------------------
# benchmark-backed autotune
# ---------------------------------------------------------------------------


def _time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """p50 µs of one candidate, via the shared obs timing helper (the
    planner's former private copy of the warmup+sync pattern)."""
    return time_jitted(fn, *args, warmup=warmup, iters=iters).p50_us


def _sorted_rows(rng, batch, n, dtype):
    return jnp.sort(
        jnp.asarray(rng.integers(0, 1 << 16, (batch, n))).astype(dtype), -1)


def _network_mxu_opts(family: str, dtype) -> Tuple[bool, ...]:
    # pair-network families never permute (compare-exchange in place), so
    # use_mxu is a no-op for them; column devices sweep both engines
    if family in ("loms", "s2ms") and _is_float(dtype):
        return (True, False)
    return (False,)


def _merge2_candidates(m: int, n: int, batch: int, dtype) -> Iterable[MergePlan]:
    """Per-size-class tournament grid: every capable network family
    (columns swept for the LOMS device) x block_batch x permute engine."""
    for family in capable_families("merge2", (m, n)):
        cols = _tournament_cols(m, n) if family == "loms" else (1,)
        for n_cols in cols:
            if family == "loms" and n_cols < 2:
                continue
            for bb in (16, 8, 4, 1):
                if bb > batch:
                    continue
                if _vmem_bytes_merge2(m, n, max(n_cols, 1), bb,
                                      dtype) > 2 * _VMEM_BUDGET:
                    continue
                for use_mxu in _network_mxu_opts(family, dtype):
                    yield MergePlan(kind="loms", network=family,
                                    n_cols=n_cols, block_batch=bb,
                                    use_mxu=use_mxu, source="autotune")


def _sort_candidates(n: int, batch: int, dtype) -> Iterable[MergePlan]:
    for family in capable_families("sort", (n,)):
        for bb in (16, 8, 4, 1):
            if bb > batch:
                continue
            if _vmem_bytes_sort(n, bb, dtype) > 2 * _VMEM_BUDGET:
                continue
            for use_mxu in _network_mxu_opts(family, dtype):
                yield MergePlan(kind="loms", network=family, block_batch=bb,
                                use_mxu=use_mxu, source="autotune")


def _topk_candidates(n: int, k: int, batch: int, dtype) -> Iterable[MergePlan]:
    for block in (16, 32, 64, 128):
        if block > n or n % block:
            continue
        for bb in (16, 8, 4, 1):
            if bb > batch:
                continue
            for use_mxu in ((True, False) if _is_float(dtype) else (False,)):
                yield MergePlan(kind="loms", block=block, block_batch=bb,
                                use_mxu=use_mxu, source="autotune")


def _autotune(
    op: str,
    key: str,
    cands: Sequence[MergePlan],
    runner: Callable[[MergePlan], Callable],
    fallback: MergePlan,
    cache: AutotuneCache,
    iters: int,
) -> MergePlan:
    if not cands:
        return fallback
    best, best_us = None, float("inf")
    with obs_trace.span(f"autotune.{op}", kind="run",
                        candidates=len(cands)):
        for plan in cands:
            us = _time_call(runner(plan), iters=iters)
            if us < best_us:
                best, best_us = plan, us
    # the measured p50 persists with the winner: plan_op cache hits carry
    # it back out (MergePlan.us) and decision_table() surfaces it
    best = dataclasses.replace(best, us=best_us)
    cache.put(key, best.to_entry())
    obs_metrics.counter("autotune.sweeps").inc(op=op)
    obs_metrics.histogram("autotune.best_us").observe(best_us, op=op)
    # tournament telemetry: how many sweeps compared multiple network
    # families, and which family each size class picked
    if len({c.network for c in cands}) > 1:
        obs_metrics.counter("tournament.sweeps").inc(op=op)
    obs_metrics.counter("tournament.picks").inc(op=op, family=best.network)
    obs_recorder.emit("tournament", f"{op}:{best.network}", key=key,
                      family=best.network, us=round(best_us, 2),
                      candidates=len(cands))
    return best


def autotune_merge2(
    m: int,
    n: int,
    *,
    batch: int = 8,
    dtype=jnp.float32,
    cache: Optional[AutotuneCache] = None,
    candidates: Optional[Sequence[MergePlan]] = None,
    interpret: Optional[bool] = None,
    iters: int = 3,
) -> MergePlan:
    """Per-size-class tournament for one UP-m/DN-n batched merge: sweep
    every capable network family (LOMS column counts included) crossed
    with (block_batch, use_mxu); persist and return the winner.

    A cache hit skips measurement entirely. Falls back to the heuristic
    plan when no candidate is feasible."""
    from repro.kernels.loms_merge import loms_merge2_pallas

    cache = cache if cache is not None else default_cache()
    key = plan_key("merge2", shapes=(batch, m, n), dtype=jnp.dtype(dtype).name)
    hit = cache.get(key)
    if hit is not None:
        return MergePlan.from_entry(hit, source="cache")
    cands = list(candidates) if candidates is not None else list(
        _merge2_candidates(m, n, batch, dtype)
    )
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    rng = np.random.default_rng(0)
    a = _sorted_rows(rng, batch, m, dtype)
    b = _sorted_rows(rng, batch, n, dtype)

    def runner(p: MergePlan):
        return lambda: loms_merge2_pallas(
            a, b, network=p.network, n_cols=p.n_cols,
            block_batch=p.block_batch, use_mxu=p.use_mxu,
            interpret=interpret,
        )

    return _autotune("merge2", key, cands, runner,
                     plan_merge2(m, n, batch=batch, dtype=dtype), cache, iters)


def autotune_sort(
    n: int,
    *,
    batch: int = 8,
    dtype=jnp.float32,
    cache: Optional[AutotuneCache] = None,
    interpret: Optional[bool] = None,
    iters: int = 3,
) -> MergePlan:
    """Per-size-class tournament for the fused sort kernel: capable
    network families x block_batch x use_mxu."""
    from repro.kernels.sort import loms_sort_pallas

    cache = cache if cache is not None else default_cache()
    key = plan_key("sort", shapes=(batch, n), dtype=jnp.dtype(dtype).name)
    hit = cache.get(key)
    if hit is not None:
        return MergePlan.from_entry(hit, source="cache")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 1 << 16, (batch, n))).astype(dtype)

    def runner(p: MergePlan):
        return lambda: loms_sort_pallas(
            x, network=p.network, block_batch=p.block_batch,
            use_mxu=p.use_mxu, interpret=interpret,
        )

    return _autotune("sort", key, list(_sort_candidates(n, batch, dtype)),
                     runner, plan_sort(n, batch=batch, dtype=dtype), cache,
                     iters)


def autotune_topk(
    n: int,
    k: int,
    *,
    batch: int = 8,
    dtype=jnp.float32,
    cache: Optional[AutotuneCache] = None,
    interpret: Optional[bool] = None,
    iters: int = 3,
) -> MergePlan:
    """Measure (block, block_batch, use_mxu) candidates for router top-k."""
    from repro.kernels.topk import ROUTER_TOPK_MAX, router_topk_pallas

    cache = cache if cache is not None else default_cache()
    key = plan_key("topk", shapes=(batch, n), k=k, dtype=jnp.dtype(dtype).name)
    hit = cache.get(key)
    if hit is not None:
        return MergePlan.from_entry(hit, source="cache")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fallback = plan_topk(n, k, batch=batch, dtype=dtype)
    if n > ROUTER_TOPK_MAX:
        return fallback
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 1 << 16, (batch, n))).astype(dtype)

    def runner(p: MergePlan):
        return lambda: router_topk_pallas(
            x, k=k, block=p.block or 32, block_batch=p.block_batch,
            use_mxu=p.use_mxu, interpret=interpret,
        )

    return _autotune("topk", key, list(_topk_candidates(n, k, batch, dtype)),
                     runner, fallback, cache, iters)


def autotune_segmented(
    widths: Sequence[int],
    *,
    n_segments: int = 8,
    dtype=jnp.float32,
    cache: Optional[AutotuneCache] = None,
    interpret: Optional[bool] = None,
    iters: int = 3,
) -> MergePlan:
    """Per-size-class tournament for one segmented class launch (sort
    when ``widths`` has one entry, 2-way merge when two) — the segmented
    bucketer's classes pick a network the same way the dense ops do."""
    from repro.kernels.segmented import (segment_class_merge_pallas,
                                         segment_class_sort_pallas)

    widths = tuple(int(w) for w in widths)
    cache = cache if cache is not None else default_cache()
    key = plan_key("segmented", shapes=(n_segments,) + widths,
                   dtype=jnp.dtype(dtype).name)
    hit = cache.get(key)
    if hit is not None:
        return MergePlan.from_entry(hit, source="cache")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fallback = plan_segmented(widths, n_segments=n_segments, dtype=dtype)
    rng = np.random.default_rng(0)
    if len(widths) == 1:
        w = widths[0]
        x = jnp.asarray(rng.normal(size=(n_segments, w))).astype(dtype)
        lens = jnp.asarray(
            rng.integers(1, w + 1, (n_segments, 1)), jnp.int32)
        cands = list(_sort_candidates(w, n_segments, dtype))

        def runner(p: MergePlan):
            return lambda: segment_class_sort_pallas(
                x, lens, network=p.network, block_batch=p.block_batch,
                use_mxu=p.use_mxu, interpret=interpret)[0]
    else:
        wa, wb = widths
        a = _sorted_rows(rng, n_segments, wa, dtype)
        b = _sorted_rows(rng, n_segments, wb, dtype)
        la = jnp.asarray(rng.integers(1, wa + 1, (n_segments, 1)), jnp.int32)
        lb = jnp.asarray(rng.integers(1, wb + 1, (n_segments, 1)), jnp.int32)
        cands = list(_merge2_candidates(wa, wb, n_segments, dtype))

        def runner(p: MergePlan):
            return lambda: segment_class_merge_pallas(
                a, b, la, lb, network=p.network, n_cols=max(p.n_cols, 1),
                block_batch=p.block_batch, use_mxu=p.use_mxu,
                interpret=interpret)[0]

    return _autotune("segmented", key, cands, runner, fallback, cache, iters)


def autotune_op(
    op: str,
    lengths: Sequence[int],
    *,
    batch: int = 8,
    dtype=jnp.float32,
    k: Optional[int] = None,
    cache: Optional[AutotuneCache] = None,
    interpret: Optional[bool] = None,
    iters: int = 3,
) -> MergePlan:
    """Autotune front door mirroring :func:`plan_op` keys."""
    if op == "merge2":
        return autotune_merge2(lengths[0], lengths[1], batch=batch,
                               dtype=dtype, cache=cache, interpret=interpret,
                               iters=iters)
    if op == "sort":
        return autotune_sort(lengths[0], batch=batch, dtype=dtype,
                             cache=cache, interpret=interpret, iters=iters)
    if op == "topk":
        return autotune_topk(lengths[0], k or 1, batch=batch, dtype=dtype,
                             cache=cache, interpret=interpret, iters=iters)
    if op == "segmented":
        return autotune_segmented(lengths, n_segments=batch, dtype=dtype,
                                  cache=cache, interpret=interpret,
                                  iters=iters)
    # no measured tuner yet: fall back to the heuristic (still cached-keyed
    # so a future tuner slots in without call-site changes)
    return plan_op(op, lengths, batch=batch, dtype=dtype, k=k, cache=cache)
