"""Grid-resident streaming 2-way merge: one launch, carry in VMEM scratch.

The original chunked merge (``chunked.py``) drives the FLiMS carry-buffer
loop from XLA: every tile step is its own ``pallas_call``, so the carry
buffer and the stream pointers round-trip through HBM between steps —
exactly the intermediate traffic the paper's devices exist to avoid.

This kernel keeps the whole pipeline resident for the duration of one
``pallas_call`` (DESIGN.md §11):

* grid = (batch, out_tiles); the TPU grid iterates the last dimension
  innermost, so each batch row runs its tile steps back to back;
* the carry tile lives in **VMEM scratch** and persists across grid
  steps (Pallas scratch is allocated once per launch, not per step);
* the stream pointers and last-loaded values live in **SMEM scratch**;
* the inputs stay in HBM/ANY and each refill is one async DMA of a single
  tile, chosen by the FLiMS rule (refill whichever stream's *last loaded*
  element is smaller — the bound that makes a fixed emission rate safe);
* only the emitted lower halves are written back, through the blocked
  output spec.

HBM traffic is therefore one read of each input element, one write of
each output element, and nothing else — the FLiMS property — instead of
one carry round-trip per tile. Values-only (the streaming backend's
contract); works for any dtype including the total-order int keys.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import pad_tail_sorted, resolve_interpret
from repro.networks import merge_program, merge_runs
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def _grid_merge2_kernel(
    a_hbm, b_hbm, o_ref, carry_ref, buf_ref, ptr_ref, last_ref, sem,
    *, t: int, la: int, lb: int, prog, use_mxu: bool,
):
    r = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _prologue():
        # load the first tile of each stream, emit the lower half
        cp = pltpu.make_async_copy(a_hbm.at[r, pl.ds(0, t)], buf_ref.at[0], sem)
        cp.start()
        cp.wait()
        cp = pltpu.make_async_copy(b_hbm.at[r, pl.ds(0, t)], buf_ref.at[1], sem)
        cp.start()
        cp.wait()
        ta = buf_ref[0][None, :]
        tb = buf_ref[1][None, :]
        merged = merge_runs(prog, ta, tb, use_mxu=use_mxu)
        o_ref[...] = merged[:, :t]
        carry_ref[...] = merged[:, t:]
        ptr_ref[0] = t
        ptr_ref[1] = t
        last_ref[0] = buf_ref[0, t - 1]
        last_ref[1] = buf_ref[1, t - 1]

    @pl.when(i > 0)
    def _step():
        pa = ptr_ref[0]
        pb = ptr_ref[1]
        last_a = last_ref[0]
        last_b = last_ref[1]
        sel_a = last_a <= last_b  # FLiMS rule: refill the lagging stream

        @pl.when(sel_a)
        def _():
            cp = pltpu.make_async_copy(
                a_hbm.at[r, pl.ds(pa, t)], buf_ref.at[0], sem)
            cp.start()
            cp.wait()

        @pl.when(jnp.logical_not(sel_a))
        def _():
            cp = pltpu.make_async_copy(
                b_hbm.at[r, pl.ds(pb, t)], buf_ref.at[0], sem)
            cp.start()
            cp.wait()

        cur = buf_ref[0][None, :]
        tail = buf_ref[0, t - 1]
        last_ref[0] = jnp.where(sel_a, tail, last_a)
        last_ref[1] = jnp.where(sel_a, last_b, tail)
        # pointers clamp at the all-sentinel drain tile, so an exhausted
        # stream reads sentinels forever
        ptr_ref[0] = jnp.where(sel_a, jnp.minimum(pa + t, la - t), pa)
        ptr_ref[1] = jnp.where(sel_a, pb, jnp.minimum(pb + t, lb - t))
        merged = merge_runs(prog, carry_ref[...], cur, use_mxu=use_mxu)
        o_ref[...] = merged[:, :t]
        carry_ref[...] = merged[:, t:]


@functools.partial(jax.jit, static_argnames=("tile", "network", "use_mxu",
                                             "interpret"))
def grid_chunked_merge2(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    tile: int = 512,
    network: str = "loms",
    use_mxu: bool = True,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Single-launch streaming merge of ascending (B, Na) and (B, Nb).

    Equivalent to ``sort(concat([a, b], -1))`` with an O(tile) on-chip
    working set per row; the carry buffer never leaves VMEM between tile
    steps. The emitted prefix is exact for any input length (drain tiles
    carry the finite dtype +sentinel; see chunked.py on aliasing).
    ``network`` names the registered family executing each tile merge —
    the program is built outside the kernel, a static trace-time
    constant."""
    from repro.resilience.failpoints import failpoint

    # trace-time seam: fires when this signature (re)compiles, the same
    # scope as a genuine refill-pipeline lowering failure — already-cached
    # executables are past the point this layer can observe
    failpoint("grid_merge.refill")
    interpret = resolve_interpret(interpret)
    bsz, na = a.shape
    nb = b.shape[-1]
    t = int(tile)
    total = na + nb
    out_tiles = -(-total // t)
    if obs_trace.enabled():
        # trace-time telemetry (this body runs once per compilation): the
        # prologue DMAs two tiles per row, every later grid step one —
        # the HBM-refill count the FLiMS carry pipeline is sized by
        obs_metrics.counter("grid_merge.launches").inc(tile=t)
        obs_metrics.counter("grid_merge.refill_tiles").inc(
            bsz * (out_tiles + 1), tile=t)
    # each stream gets one all-sentinel drain tile past its (padded) tail
    la = (-(-na // t) + 1) * t
    lb = (-(-nb // t) + 1) * t
    ap = pad_tail_sorted(a, la)
    bp = pad_tail_sorted(b, lb)
    out = pl.pallas_call(
        functools.partial(_grid_merge2_kernel, t=t, la=la, lb=lb,
                          prog=merge_program(network, t, t),
                          use_mxu=use_mxu),
        grid=(bsz, out_tiles),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, t), lambda r, i: (r, i)),
        out_shape=jax.ShapeDtypeStruct((bsz, out_tiles * t), a.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, t), a.dtype),   # carry (resident across steps)
            pltpu.VMEM((2, t), a.dtype),   # refill buffers
            pltpu.SMEM((2,), jnp.int32),   # stream pointers
            pltpu.SMEM((2,), a.dtype),     # last-loaded values
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(ap, bp)
    return out[:, :total]
