"""Chunked (streaming) merges: arbitrarily long sorted inputs, fixed tiles.

The paper's LOMS devices are fixed-size blocks; this module composes them
into pipelines the way FLiMS streams a fixed 2-way merger over unbounded
inputs (DESIGN.md §8):

* :func:`chunked_merge` — 2-way streaming merge with a carry buffer. Each
  step loads one tile of ``T`` values from whichever stream's *last loaded*
  element is smaller, merges it with the ``T``-value carry, emits the lower
  half and keeps the upper half as the next carry. Selecting on the
  last-loaded element (not the head) is what makes a fixed emission rate
  safe: every carry element is bounded by the larger of the two last-loaded
  values, so the emitted lower half can never overtake an unloaded element.
  Working set is O(batch * tile) regardless of input length. By default the
  whole loop runs as **one grid-resident kernel launch** whose carry buffer
  lives in VMEM scratch (:mod:`~repro.streaming.grid_merge`); the legacy
  one-``pallas_call``-per-tile XLA loop is kept as ``mode="loop"``.

* :func:`chunked_merge_k` — k-way tiled merge via merge-path partitioning:
  the global rank of every element is computed with vectorized binary
  searches, output-tile split points are read off the rank arrays, and each
  output tile is produced by one ``kway_merge_pallas`` call over k
  tile-sized segments (sentinel-padded at the ragged tails). The scan over
  output tiles keeps the kernel working set fixed.

Both produce exactly ``sort(concat(inputs))`` — bit-identical values — for
NaN-free inputs of any length, batched or unbatched.

Sentinel aliasing: drain tiles and ragged tail segments are padded with
the finite ``sentinel_max`` of the dtype, so a genuine extreme value
(``INT32_MAX``, ``uint`` max) *ties* its padding. That is safe here —
these pipelines are value-only, the output is ascending, and a sentinel
emitted inside the live prefix is value-identical to the tied genuine
element it stands in for (regression-tested in
tests/test_sentinels.py). The k-way tail segments additionally carry an
explicit valid-length mask (``lane < seg_len``) rather than trusting the
pad value. Anything index- or payload-carrying must not reuse this
scheme — see ``kernels.common.stable_compact`` and the ``-1`` position
convention in ``parallel/dist_sort.py``.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import np_fill, pad_tail_sorted, sentinel_max
from repro.kernels.kway import kway_merge_pallas
from repro.kernels.loms_merge import loms_merge2_pallas
from repro.networks import kway_schedule

from .planner import MergePlan, plan_chunked, plan_chunked_k


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _as_batched(x: jnp.ndarray) -> Tuple[jnp.ndarray, Tuple[int, ...]]:
    """Flatten leading axes to one batch axis; remember them for unflatten."""
    if x.ndim == 1:
        return x[None, :], ()
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _merge_pair(carry: jnp.ndarray, tile: jnp.ndarray, plan: MergePlan,
                interpret: bool) -> jnp.ndarray:
    """(B, T) + (B, T) -> (B, 2T) ascending via the 2-way Pallas kernel."""
    t = carry.shape[-1]
    if plan.kind == "loms" and t % plan.n_cols == 0:
        return loms_merge2_pallas(
            carry, tile, n_cols=plan.n_cols, block_batch=plan.block_batch,
            use_mxu=plan.use_mxu, interpret=interpret,
        )
    from repro.api import schedules as sched_api  # ragged fallback, no Pallas

    return sched_api.merge(carry, tile)


def chunked_merge(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    tile: Optional[int] = None,
    plan: Optional[MergePlan] = None,
    interpret: Optional[bool] = None,
    mode: str = "grid",
) -> jnp.ndarray:
    """Streaming 2-way merge of ascending ``a`` (..., Na) and ``b`` (..., Nb).

    Equivalent to ``sort(concat([a, b], -1))`` but built from fixed
    ``tile``-sized LOMS merge steps with an O(batch*tile) carry — inputs
    far larger than VMEM merge at fixed on-chip memory. ``mode="grid"``
    (default) runs the whole stream as one grid-resident kernel launch
    with the carry in VMEM scratch; ``mode="loop"`` is the legacy
    one-launch-per-tile XLA loop."""
    assert mode in ("grid", "loop"), mode
    a2, lead = _as_batched(a)
    b2, lead_b = _as_batched(b)
    assert lead == lead_b, (a.shape, b.shape)
    bsz, na = a2.shape
    nb = b2.shape[-1]
    if plan is None:
        plan = plan_chunked(na, nb, batch=bsz, dtype=a2.dtype, tile=tile)
    t = int(tile if tile is not None else plan.tile)
    t = max(2, t - (t % 2))
    if interpret is None:
        interpret = _interpret()
    if mode == "grid":
        from .grid_merge import grid_chunked_merge2

        use_mxu = plan.use_mxu and jnp.issubdtype(a2.dtype, jnp.floating)
        out = grid_chunked_merge2(a2, b2, tile=t, use_mxu=use_mxu,
                                  interpret=interpret)
    else:
        out = _chunked_merge2(a2, b2, tile=t, plan=plan, interpret=interpret)
    return out.reshape(lead + (na + nb,)) if lead else out[0]


@functools.partial(jax.jit, static_argnames=("tile", "plan", "interpret"))
def _chunked_merge2(a, b, *, tile: int, plan: MergePlan, interpret: bool):
    bsz, na = a.shape
    nb = b.shape[-1]
    t = tile
    total = na + nb
    out_tiles = -(-total // t)
    # each stream gets one all-sentinel drain tile past its (padded) tail;
    # pointers clamp there, so an exhausted stream reads sentinels forever
    la = (-(-na // t) + 1) * t
    lb = (-(-nb // t) + 1) * t
    ap = pad_tail_sorted(a, la)
    bp = pad_tail_sorted(b, lb)

    # prologue: load the first tile of each stream, emit the lower half
    ta, tb = ap[:, :t], bp[:, :t]
    merged = _merge_pair(ta, tb, plan, interpret)
    out = jnp.zeros((bsz, out_tiles * t), a.dtype)
    out = jax.lax.dynamic_update_slice(out, merged[:, :t], (0, 0))
    carry = merged[:, t:]
    last_a, last_b = ta[:, -1], tb[:, -1]
    pa = jnp.full((bsz,), t, jnp.int32)
    pb = jnp.full((bsz,), t, jnp.int32)

    load = jax.vmap(lambda row, p: jax.lax.dynamic_slice(row, (p,), (t,)))

    def body(i, state):
        out, carry, pa, pb, last_a, last_b = state
        sel_a = last_a <= last_b  # FLiMS rule: refill the lagging stream
        tile_a = load(ap, pa)
        tile_b = load(bp, pb)
        cur = jnp.where(sel_a[:, None], tile_a, tile_b)
        last_a = jnp.where(sel_a, cur[:, -1], last_a)
        last_b = jnp.where(sel_a, last_b, cur[:, -1])
        pa = jnp.where(sel_a, jnp.minimum(pa + t, la - t), pa)
        pb = jnp.where(sel_a, pb, jnp.minimum(pb + t, lb - t))
        merged = _merge_pair(carry, cur, plan, interpret)
        out = jax.lax.dynamic_update_slice(out, merged[:, :t], (0, i * t))
        return out, merged[:, t:], pa, pb, last_a, last_b

    state = (out, carry, pa, pb, last_a, last_b)
    out = jax.lax.fori_loop(1, out_tiles, body, state)[0]
    return out[:, :total]


# ---------------------------------------------------------------------------
# k-way: merge-path partition + one k-way kernel call per output tile
# ---------------------------------------------------------------------------


def _global_positions(lists: Sequence[jnp.ndarray]) -> list:
    """Final merged position of every element (stable: list order breaks
    ties). All counts are vectorized binary searches over sorted rows."""
    pos = []
    for j, lj in enumerate(lists):
        p = jnp.broadcast_to(
            jnp.arange(lj.shape[-1], dtype=jnp.int32), lj.shape
        ).astype(jnp.int32)
        for l, ll in enumerate(lists):
            if l == j:
                continue
            side = "right" if l < j else "left"
            cnt = jax.vmap(
                lambda arr, q, s=side: jnp.searchsorted(arr, q, side=s)
            )(ll, lj)
            p = p + cnt.astype(jnp.int32)
        pos.append(p)
    return pos


def chunked_merge_k(
    lists: Sequence[jnp.ndarray],
    *,
    tile: Optional[int] = None,
    plan: Optional[MergePlan] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """k-way tiled merge of ascending lists -> ascending (..., sum(len)).

    Each output tile is one ``kway_merge_pallas`` call over k sentinel-padded
    tile segments located by merge-path split points, so the kernel working
    set stays fixed no matter how long the inputs are."""
    assert len(lists) >= 2, "need at least two lists"
    if len(lists) == 2:
        return chunked_merge(lists[0], lists[1], tile=tile, plan=plan,
                             interpret=interpret)
    flat = []
    lead = None
    for l in lists:
        f, ld = _as_batched(l)
        assert lead is None or ld == lead, [x.shape for x in lists]
        lead = ld
        flat.append(f)
    lens = tuple(int(l.shape[-1]) for l in flat)
    bsz = flat[0].shape[0]
    k = len(flat)
    if plan is None:
        plan = plan_chunked_k(lens, batch=bsz, dtype=flat[0].dtype, tile=tile)
    t = int(tile if tile is not None else plan.tile)
    if interpret is None:
        interpret = _interpret()
    total = sum(lens)
    out_tiles = -(-total // t)
    sched = kway_schedule((t,) * k)

    pos = _global_positions(flat)  # per-list (B, n_j) global ranks
    grid = jnp.arange(out_tiles + 1, dtype=jnp.int32) * t
    # splits[j][:, i] = how many of list j land in the first i*t outputs
    splits = [
        jax.vmap(lambda p: jnp.searchsorted(p, grid, side="left"))(pj).astype(
            jnp.int32
        )
        for pj in pos
    ]
    padded = [pad_tail_sorted(f, lens[j] + t) for j, f in enumerate(flat)]
    fill = np_fill(sentinel_max(flat[0].dtype), flat[0].dtype)
    lane = jnp.arange(t, dtype=jnp.int32)
    load = jax.vmap(lambda row, p: jax.lax.dynamic_slice(row, (p,), (t,)))

    def one_tile(i):
        segs = []
        for j in range(k):
            start = splits[j][:, i]
            seg_len = splits[j][:, i + 1] - start
            seg = load(padded[j], start)
            seg = jnp.where(lane[None, :] < seg_len[:, None], seg, fill)
            segs.append(seg)
        merged = kway_merge_pallas(
            jnp.concatenate(segs, axis=-1), sched,
            block_batch=plan.block_batch, use_mxu=plan.use_mxu,
            interpret=interpret,
        )
        return merged[:, :t]

    tiles = jax.lax.map(one_tile, jnp.arange(out_tiles, dtype=jnp.int32))
    out = jnp.moveaxis(tiles, 0, 1).reshape(bsz, out_tiles * t)[:, :total]
    return out.reshape(lead + (total,)) if lead else out[0]
