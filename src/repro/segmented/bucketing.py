"""Trace-time size-class bucketing over static CSR segment offsets.

Everything here is pure Python/numpy over *static* offsets: the CSR
structure of a segmented problem must be known at trace time (it sizes
networks, tiles and gather maps), exactly like shapes. The bucketer
groups segments into power-of-two length classes — the bucketed-network-
selection idea of the multiway-sorting-network literature: pick the
sorter that matches each list's size class instead of padding every list
to the global maximum. A segment of length L lands in the class of width
``ceil_pow2(L)`` (kernels.common — guarded so empty and length-1 segments
can never size a 0-width network); classes wider than ``max_width`` spill
to the streaming/batched paths.

The gather/scatter index maps between the flat CSR layout and each
class's dense ``(n_segments, width)`` tile are numpy constants, so they
lower to single XLA gathers around the one Pallas launch per class.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels.common import ceil_pow2


def normalize_offsets(offsets) -> Tuple[int, ...]:
    """Validate CSR offsets into a static int tuple.

    Offsets must be trace-time constants: they decide network widths and
    launch counts, which JAX cannot retrace per value. Concrete values of
    any array type (numpy, a non-traced jax.Array) convert fine; only a
    genuinely *traced* value is a usage error with a clear message.
    """
    import jax

    if isinstance(offsets, jax.core.Tracer):
        raise TypeError(
            "segment_offsets must be static (Python ints / numpy / a "
            "concrete array): the size-class bucketer sizes sorting "
            "networks from them at trace time. Got a traced JAX value — "
            "hoist the offsets out of jit, or mark them static_argnums."
        )
    offs = tuple(int(o) for o in np.asarray(offsets).reshape(-1))
    if len(offs) < 1:
        raise ValueError("segment_offsets needs at least one entry")
    if offs[0] != 0:
        raise ValueError(f"segment_offsets must start at 0, got {offs[0]}")
    if any(b < a for a, b in zip(offs, offs[1:])):
        raise ValueError(f"segment_offsets must be non-decreasing: {offs}")
    return offs


def segment_lengths(offsets: Tuple[int, ...]) -> np.ndarray:
    return np.diff(np.asarray(offsets, np.int64)).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class SizeClass:
    """One bucket: every member segment rounds up to the same pow2 width."""

    width: int  # pow2 class width (dense tile lane count)
    seg_ids: Tuple[int, ...]  # member segment indices, ascending
    lens: Tuple[int, ...]  # true member lengths (0 < len <= width)

    @property
    def n(self) -> int:
        return len(self.seg_ids)


def bucket_segments(
    lengths: np.ndarray, max_width: int
) -> Tuple[List[SizeClass], List[SizeClass]]:
    """Group segments into pow2 size classes.

    Returns ``(classes, spill)``: ``classes`` hold every segment whose
    class width fits ``max_width`` (one Pallas launch each); ``spill``
    groups the longer segments by *exact* length (equal-length spill
    segments batch into one streaming/executor call). Empty segments are
    dropped — they produce no output and must never reach a network.
    """
    by_width: Dict[int, List[int]] = {}
    spill_by_len: Dict[int, List[int]] = {}
    for sid, ln in enumerate(np.asarray(lengths).tolist()):
        ln = int(ln)
        if ln == 0:
            continue
        w = ceil_pow2(ln)
        if w <= max_width:
            by_width.setdefault(w, []).append(sid)
        else:
            spill_by_len.setdefault(ln, []).append(sid)
    lengths = np.asarray(lengths)
    classes = [
        SizeClass(width=w, seg_ids=tuple(ids),
                  lens=tuple(int(lengths[i]) for i in ids))
        for w, ids in sorted(by_width.items())
    ]
    spill = [
        SizeClass(width=ln, seg_ids=tuple(ids), lens=(ln,) * len(ids))
        for ln, ids in sorted(spill_by_len.items())
    ]
    return classes, spill


def bucket_merge_pairs(
    lens_a: np.ndarray, lens_b: np.ndarray, max_width: int
) -> Tuple[List[Tuple[SizeClass, SizeClass]], List[Tuple[SizeClass, SizeClass]]]:
    """Bucket per-segment (a, b) merge pairs by the pow2 class of each run.

    A pair where either run is empty still routes through the class of the
    pair — the kernels handle len 0 by mask — but a pair whose *combined*
    class width exceeds ``max_width`` spills (grouped by exact lengths).
    """
    by_key: Dict[Tuple[int, int], List[int]] = {}
    spill_by_len: Dict[Tuple[int, int], List[int]] = {}
    la = np.asarray(lens_a)
    lb = np.asarray(lens_b)
    for sid in range(len(la)):
        a, b = int(la[sid]), int(lb[sid])
        if a == 0 and b == 0:
            continue
        wa, wb = ceil_pow2(a), ceil_pow2(b)
        if wa + wb <= max_width:
            by_key.setdefault((wa, wb), []).append(sid)
        else:
            spill_by_len.setdefault((a, b), []).append(sid)

    def pair(key, ids, exact):
        ka, kb = key
        return (
            SizeClass(width=ka, seg_ids=tuple(ids),
                      lens=tuple(int(la[i]) for i in ids) if not exact
                      else (ka,) * len(ids)),
            SizeClass(width=kb, seg_ids=tuple(ids),
                      lens=tuple(int(lb[i]) for i in ids) if not exact
                      else (kb,) * len(ids)),
        )

    classes = [pair(k, ids, False) for k, ids in sorted(by_key.items())]
    spill = [pair(k, ids, True) for k, ids in sorted(spill_by_len.items())]
    return classes, spill


def gather_map(offsets: Sequence[int], cls: SizeClass,
               sentinel: int) -> np.ndarray:
    """(n, width) int32 indices from the class tile into the flat CSR
    array extended with one trailing pad slot at ``sentinel`` (= N)."""
    n, w = cls.n, cls.width
    idx = np.full((n, w), sentinel, np.int32)
    lane = np.arange(w)
    for r, (sid, ln) in enumerate(zip(cls.seg_ids, cls.lens)):
        off = offsets[sid]
        idx[r, :ln] = off + lane[:ln]
    return idx


def scatter_map(out_offsets: Sequence[int], cls: SizeClass, width: int,
                counts: Optional[Sequence[int]] = None,
                trash: Optional[int] = None) -> np.ndarray:
    """(n, width) int32 flat output positions for the class tile's valid
    prefix; invalid lanes route to the ``trash`` slot (default: the total
    output length, i.e. one past the last real element).

    ``counts`` overrides the per-row valid count (top-k truncation);
    otherwise the segment's true length is used.
    """
    if trash is None:
        trash = int(out_offsets[-1])
    n = cls.n
    idx = np.full((n, width), trash, np.int32)
    lane = np.arange(width)
    for r, (sid, ln) in enumerate(zip(cls.seg_ids, cls.lens)):
        cnt = int(counts[r]) if counts is not None else int(ln)
        cnt = min(cnt, width)
        idx[r, :cnt] = int(out_offsets[sid]) + lane[:cnt]
    return idx
