"""Segmented (CSR ragged) sort / merge / top-k over size-class buckets.

The execution pipeline for one segmented call (DESIGN.md §12):

1. ``bucketing`` groups the static segments into pow2 size classes.
2. Per class, a numpy gather map packs the member segments into a dense
   ``(n_segments, width)`` tile (invalid lanes point at one shared pad
   slot), and **one** Pallas launch (`kernels.segmented`) sorts/merges
   every row — key encode, descending flip, validity compaction and the
   raw-value/payload gather all inside the kernel.
3. A numpy scatter map writes each row's valid prefix back to the flat
   CSR output; invalid lanes route to a trash slot that is sliced away.

Segments whose class exceeds the VMEM tile budget spill: equal-length
spill groups batch together, values-only spills chunk-sort in one class
launch and then reduce with the grid-resident FLiMS carry merge
(``streaming.grid_merge``), and permutation-carrying spills take the
batched XLA path (stable argsort of the total-order keys).

Values are always *gathered from the raw input at the permutation* (or
produced by monotone key decode on the values-only spill path), so the
output is bit-identical to a per-segment ``jnp.sort`` for every input —
the paper's "any mixture of input list sizes" property as a first-class
workload instead of a pad-to-max fallback.

Tile knobs (``block_batch``) come from ``streaming.planner.plan_segmented``
through the autotune cache; the escape hatch (``REPRO_DISABLE_SEGMENTED``
/ ``set_segmented_enabled``) and non-TPU auto routing fall back to
:mod:`repro.segmented.reference`.
"""
from __future__ import annotations

import os
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import (
    encode_key_values,
    key_transformable,
    np_fill,
    sentinel_min,
)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.kernels.segmented import (
    flip_keys,
    key_sentinel,
    segment_class_merge_pallas,
    segment_class_sort_pallas,
)

from .bucketing import (
    SizeClass,
    bucket_merge_pairs,
    bucket_segments,
    gather_map,
    normalize_offsets,
    scatter_map,
    segment_lengths,
)
from .reference import ref_segment_merge, ref_segment_sort, ref_segment_topk

_ENABLED = True

#: hard cap on the dense class width; the planner's VMEM fit can only
#: shrink it further
MAX_CLASS_WIDTH = 2048


def segmented_enabled() -> bool:
    """Whether the bucketed kernel path may be auto-selected (the
    ``REPRO_DISABLE_FUSED``-style escape hatch for this subsystem)."""
    return _ENABLED and os.environ.get("REPRO_DISABLE_SEGMENTED") != "1"


def set_segmented_enabled(enabled: bool) -> bool:
    """Toggle the bucketed kernel path (returns the previous value)."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(enabled)
    return prev


def max_class_width(dtype) -> int:
    """Largest pow2 class width whose sort working set fits the VMEM
    budget at a 1-row tile — the bucketed-kernel vs spill cutover."""
    from repro.streaming.planner import sort_fits_vmem

    w = MAX_CLASS_WIDTH
    while w > 2 and not sort_fits_vmem(w, block_batch=1, dtype=dtype):
        w //= 2
    return w


def _class_plan(widths: Tuple[int, ...], n_segs: int, dtype):
    from repro.streaming.planner import plan_op

    return plan_op("segmented", widths, batch=n_segs, dtype=dtype)


def _record_bucketing(op: str, classes, spill) -> None:
    """Bucketing telemetry for one segmented call (trace-time: these fire
    once per compilation, the deterministic count). ``classes``/``spill``
    hold :class:`SizeClass` entries for sort/topk and ``(ca, cb)`` pairs
    for merge.

    The padded-slot waste fraction is the segmented analog of the paper's
    resource column: the share of class-kernel lanes that carry sentinel
    padding rather than data."""
    if not obs_trace.enabled():
        return

    def slots(group) -> int:
        if isinstance(group, tuple):  # merge pair
            ca, cb = group
            return ca.n * (ca.width + cb.width)
        return group.n * group.width

    def valid(group) -> int:
        if isinstance(group, tuple):
            return sum(group[0].lens) + sum(group[1].lens)
        return sum(group.lens)

    class_slots = sum(slots(g) for g in classes)
    class_valid = sum(valid(g) for g in classes)
    spill_segs = sum((g[0].n if isinstance(g, tuple) else g.n)
                     for g in spill)
    obs_metrics.counter("segmented.class_launches").inc(len(classes), op=op)
    obs_metrics.counter("segmented.spill_groups").inc(len(spill), op=op)
    obs_metrics.counter("segmented.spill_segments").inc(spill_segs, op=op)
    obs_metrics.counter("segmented.padded_slots").inc(
        class_slots - class_valid, op=op)
    obs_metrics.counter("segmented.valid_slots").inc(class_valid, op=op)
    if class_slots:
        obs_metrics.histogram("segmented.padded_waste_frac").observe(
            (class_slots - class_valid) / class_slots, op=op)


def _flatten_leaves(payload, n: int):
    """Payload pytree -> flat (N[, F]) lanes + a rebuild closure."""
    leaves, treedef = jax.tree.flatten(payload)
    lanes, trails = [], []
    for leaf in leaves:
        assert leaf.ndim >= 1 and leaf.shape[0] == n, (leaf.shape, n)
        trail = leaf.shape[1:]
        lanes.append(leaf.reshape(n, -1) if trail else leaf)
        trails.append(trail)

    def rebuild(outs, m: int):
        return jax.tree.unflatten(
            treedef, [o.reshape((m,) + t) for o, t in zip(outs, trails)])

    return lanes, rebuild


def _ext(x: jnp.ndarray) -> jnp.ndarray:
    """Append one zero pad slot so gather maps have a safe sentinel row."""
    return jnp.concatenate([x, jnp.zeros((1,) + x.shape[1:], x.dtype)], 0)


def _take(ext: jnp.ndarray, gmap: np.ndarray) -> jnp.ndarray:
    return ext[jnp.asarray(gmap)]


def _scatter(out: jnp.ndarray, smap: np.ndarray, dense: jnp.ndarray):
    """Write the class tile into the flat output; trash lanes collide on
    the last slot, which the caller slices away."""
    idx = jnp.asarray(smap).reshape(-1)
    flat = dense.reshape((-1,) + dense.shape[2:])
    return out.at[idx].set(flat)


def _take_perm(dense_lane: jnp.ndarray, perm: jnp.ndarray) -> jnp.ndarray:
    """``take_along_axis`` over a dense ``(S, L[, F])`` lane with the
    permutation broadcast across trailing feature dims (the XLA-level
    sibling of the in-kernel ``gather_lanes``)."""
    idx = perm
    if dense_lane.ndim > idx.ndim:
        idx = idx.reshape(idx.shape + (1,) * (dense_lane.ndim - idx.ndim))
    return jnp.take_along_axis(dense_lane, idx, axis=1)


def _lens_col(cls: SizeClass) -> jnp.ndarray:
    return jnp.asarray(np.asarray(cls.lens, np.int32)[:, None])


def _keys_for(x: jnp.ndarray, nan_policy: str, descending: bool):
    """XLA-level key build for the spill paths (mirrors the in-kernel
    transform): total-order encode for floats under ``"last"``, exact
    bit-flip for descending. Returns (keys, undo) with ``undo`` mapping
    sorted keys back to values (monotone, bijective)."""
    encode = nan_policy == "last" and key_transformable(x.dtype)
    keys = encode_key_values(x) if encode else x
    if descending:
        keys = flip_keys(keys)

    def undo(k):
        v = flip_keys(k) if descending else k
        if encode:
            from repro.kernels.common import decode_key_values

            v = decode_key_values(v, x.dtype)
        return v

    return keys, undo


def _use_mxu(plan, encode: bool, dtype) -> bool:
    # encoded keys are ints: exact scatter permute only; the raw-float
    # unsafe path may ride the one-hot MXU device
    return bool(plan.use_mxu and not encode
                and jnp.issubdtype(jnp.dtype(dtype), jnp.floating))


# ---------------------------------------------------------------------------
# segment_sort
# ---------------------------------------------------------------------------


def _spill_sort_values(dense: jnp.ndarray, *, descending: bool,
                       nan_policy: str, tile: int, interpret) -> jnp.ndarray:
    """Values-only sort of equal-length long rows: chunk-sort every tile in
    one class launch, then reduce each row's sorted runs with the
    grid-resident FLiMS carry merge (one read/write per element)."""
    from repro.resilience.failpoints import failpoint
    from repro.streaming.grid_merge import grid_chunked_merge2

    failpoint("segmented.spill.values")
    s, ln = dense.shape
    keys, undo = _keys_for(dense, nan_policy, descending)
    c = -(-ln // tile)
    pad = c * tile - ln
    if pad:
        keys = jnp.pad(keys, [(0, 0), (0, pad)],
                       constant_values=np_fill(
                           key_sentinel(keys.dtype), keys.dtype))
    chunks = keys.reshape(s * c, tile)
    lens = jnp.full((s * c, 1), tile, jnp.int32)
    chunk_plan = _class_plan((tile,), s * c, keys.dtype)
    sorted_chunks, _, _ = segment_class_sort_pallas(
        chunks, lens, (), encode=False, flip=False, want_perm=False,
        network=chunk_plan.network, block_batch=chunk_plan.block_batch,
        use_mxu=False, interpret=interpret,
    )
    runs: List[jnp.ndarray] = list(
        jnp.moveaxis(sorted_chunks.reshape(s, c, tile), 1, 0))
    while len(runs) > 1:
        nxt = []
        for i in range(0, len(runs) - 1, 2):
            nxt.append(grid_chunked_merge2(runs[i], runs[i + 1], tile=tile,
                                           use_mxu=False,
                                           interpret=interpret))
        if len(runs) % 2:
            nxt.append(runs[-1])
        runs = nxt
    return undo(runs[0][:, :ln])


def _spill_sort_perm(dense: jnp.ndarray, *, descending: bool,
                     nan_policy: str):
    """Permutation-carrying spill rows: batched XLA stable argsort of the
    total-order keys (documented non-kernel path)."""
    from repro.resilience.failpoints import failpoint

    failpoint("segmented.spill.perm")
    keys, _ = _keys_for(dense, nan_policy, descending)
    order = jnp.argsort(keys, axis=-1, stable=True).astype(jnp.int32)
    return jnp.take_along_axis(dense, order, axis=-1), order


def segment_sort_impl(
    values: jnp.ndarray,
    offsets,
    *,
    descending: bool = False,
    payload=None,
    nan_policy: str = "last",
    use_kernel: bool = True,
    want_perm: bool = False,
    interpret: Optional[bool] = None,
):
    """Sort each CSR segment independently. Returns
    ``(values, perm | None, payload_tree | None)``."""
    offs = normalize_offsets(offsets)
    n = offs[-1]
    values = jnp.asarray(values)
    assert values.ndim == 1 and values.shape[0] == n, (values.shape, n)
    lanes, rebuild = ([], None)
    if payload is not None:
        lanes, rebuild = _flatten_leaves(payload, n)
    need_perm = want_perm or payload is not None

    if not use_kernel:
        out, perm, pouts = ref_segment_sort(
            values, offs, descending=descending, nan_policy=nan_policy,
            payload_lanes=lanes, want_perm=need_perm)
        ptree = None if payload is None else rebuild(pouts, n)
        return out, (perm if want_perm else None), ptree

    lengths = segment_lengths(offs)
    mw = max_class_width(values.dtype)
    classes, spill = bucket_segments(lengths, mw)
    _record_bucketing("segment_sort", classes, spill)
    encode = nan_policy == "last" and key_transformable(values.dtype)
    vext = _ext(values)
    lext = [_ext(l) for l in lanes]
    out_v = jnp.zeros((n + 1,), values.dtype)
    out_p = jnp.zeros((n + 1,), jnp.int32) if need_perm else None
    out_l = [jnp.zeros((n + 1,) + l.shape[1:], l.dtype) for l in lanes]

    for cls in classes:
        gmap = gather_map(offs, cls, n)
        dense = _take(vext, gmap)
        p_dense = [_take(lx, gmap) for lx in lext]
        if cls.width == 1:
            # singleton class: nothing to sort, no network, no launch
            res_v, res_perm, res_l = dense, jnp.zeros_like(gmap), p_dense
        else:
            plan = _class_plan((cls.width,), cls.n, values.dtype)
            res_v, res_perm, res_l = segment_class_sort_pallas(
                dense, _lens_col(cls), tuple(p_dense), encode=encode,
                flip=descending, want_perm=need_perm,
                network=plan.network, block_batch=plan.block_batch,
                use_mxu=_use_mxu(plan, encode, values.dtype),
                interpret=interpret,
            )
        smap = scatter_map(offs, cls, cls.width)
        out_v = _scatter(out_v, smap, res_v)
        if need_perm:
            out_p = _scatter(out_p, smap, res_perm)
        out_l = [_scatter(o, smap, r) for o, r in zip(out_l, res_l)]

    for cls in spill:  # equal exact-length groups past the class budget
        gmap = gather_map(offs, cls, n)
        dense = _take(vext, gmap)
        smap = scatter_map(offs, cls, cls.width)
        if need_perm:
            res_v, res_perm = _spill_sort_perm(
                dense, descending=descending, nan_policy=nan_policy)
            out_p = _scatter(out_p, smap, res_perm)
            for o_i, lx in enumerate(lext):
                out_l[o_i] = _scatter(
                    out_l[o_i], smap, _take_perm(_take(lx, gmap), res_perm))
        else:
            res_v = _spill_sort_values(
                dense, descending=descending, nan_policy=nan_policy,
                tile=min(512, mw), interpret=interpret)
        out_v = _scatter(out_v, smap, res_v)

    ptree = None if payload is None else rebuild([o[:n] for o in out_l], n)
    return out_v[:n], (out_p[:n] if want_perm else None), ptree


# ---------------------------------------------------------------------------
# segment_merge
# ---------------------------------------------------------------------------


def segment_merge_impl(
    a: jnp.ndarray,
    b: jnp.ndarray,
    offsets_a,
    offsets_b,
    *,
    descending: bool = False,
    payload=None,  # (tree_a, tree_b) riding the merge permutation
    nan_policy: str = "last",
    use_kernel: bool = True,
    want_perm: bool = False,
    interpret: Optional[bool] = None,
):
    """Merge per-segment sorted runs ``a[s]`` and ``b[s]``. Returns
    ``(values, perm | None, payload_tree | None, out_offsets)`` — the
    output CSR segment ``s`` is the sorted union of the two runs, and
    ``perm`` holds concatenated-segment positions (a first, then b)."""
    offs_a = normalize_offsets(offsets_a)
    offs_b = normalize_offsets(offsets_b)
    assert len(offs_a) == len(offs_b), (len(offs_a), len(offs_b))
    na, nb = offs_a[-1], offs_b[-1]
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    assert a.shape == (na,) and b.shape == (nb,), (a.shape, b.shape, na, nb)
    out_offs = tuple(x + y for x, y in zip(offs_a, offs_b))
    total = na + nb

    lanes, rebuild = ([], None)
    if payload is not None:
        # per-list payload trees concatenate per segment into the merged
        # CSR layout the permutation indexes
        tree_a, tree_b = payload
        la, rebuild = _flatten_leaves(tree_a, na)
        lb, _ = _flatten_leaves(tree_b, nb)
        lanes = [_cat_csr(pa, pb, offs_a, offs_b) for pa, pb in zip(la, lb)]
    need_perm = want_perm or payload is not None

    if not use_kernel:
        out, perm, pouts, _ = ref_segment_merge(
            a, b, offs_a, offs_b, descending=descending,
            nan_policy=nan_policy, payload_lanes=lanes, want_perm=need_perm)
        ptree = None if payload is None else rebuild(pouts, total)
        return out, (perm if want_perm else None), ptree, out_offs

    lens_a = segment_lengths(offs_a)
    lens_b = segment_lengths(offs_b)
    mw = max_class_width(a.dtype)
    classes, spill = bucket_merge_pairs(lens_a, lens_b, mw)
    _record_bucketing("segment_merge", classes, spill)
    encode = nan_policy == "last" and key_transformable(a.dtype)
    aext, bext = _ext(a), _ext(b)
    lext = [_ext(l) for l in lanes]
    out_v = jnp.zeros((total + 1,), a.dtype)
    out_p = jnp.zeros((total + 1,), jnp.int32) if need_perm else None
    out_l = [jnp.zeros((total + 1,) + l.shape[1:], l.dtype) for l in lanes]

    def lane_gmap(ca: SizeClass, cb: SizeClass) -> np.ndarray:
        """Dense-coordinate gather map for the merged-CSR payload lanes:
        a lanes fill [0, Wa), b lanes [Wa, Wa+Wb)."""
        ga = np.full((ca.n, ca.width), total, np.int32)
        gb = np.full((cb.n, cb.width), total, np.int32)
        lane = np.arange(max(ca.width, cb.width))
        for r, sid in enumerate(ca.seg_ids):
            o0 = out_offs[sid]
            ga[r, :ca.lens[r]] = o0 + lane[:ca.lens[r]]
            gb[r, :cb.lens[r]] = o0 + ca.lens[r] + lane[:cb.lens[r]]
        return np.concatenate([ga, gb], axis=1)

    for ca, cb in classes:
        dense_a = _take(aext, gather_map(offs_a, ca, na))
        dense_b = _take(bext, gather_map(offs_b, cb, nb))
        p_dense = [_take(lx, lane_gmap(ca, cb)) for lx in lext]
        plan = _class_plan((ca.width, cb.width), ca.n, a.dtype)
        res_v, res_perm, res_l = segment_class_merge_pallas(
            dense_a, dense_b, _lens_col(ca), _lens_col(cb), tuple(p_dense),
            encode=encode, flip=descending, want_perm=need_perm,
            network=plan.network, block_batch=plan.block_batch,
            use_mxu=_use_mxu(plan, encode, a.dtype),
            n_cols=plan.n_cols if plan.network == "loms" else None,
            interpret=interpret,
        )
        out_cls = SizeClass(width=ca.width + cb.width, seg_ids=ca.seg_ids,
                            lens=tuple(x + y for x, y in
                                       zip(ca.lens, cb.lens)))
        smap = scatter_map(out_offs, out_cls, out_cls.width)
        out_v = _scatter(out_v, smap, res_v)
        if need_perm:
            out_p = _scatter(out_p, smap, res_perm)
        out_l = [_scatter(o, smap, r) for o, r in zip(out_l, res_l)]

    for ca, cb in spill:  # exact-length groups past the class budget
        dense_a = _take(aext, gather_map(offs_a, ca, na))
        dense_b = _take(bext, gather_map(offs_b, cb, nb))
        ln = ca.width + cb.width
        out_cls = SizeClass(width=ln, seg_ids=ca.seg_ids,
                            lens=(ln,) * ca.n)
        smap = scatter_map(out_offs, out_cls, ln)
        if need_perm:
            cat = jnp.concatenate([dense_a, dense_b], axis=1)
            res_v, res_perm = _spill_sort_perm(
                cat, descending=descending, nan_policy=nan_policy)
            out_p = _scatter(out_p, smap, res_perm)
            for o_i, lx in enumerate(lext):
                out_l[o_i] = _scatter(
                    out_l[o_i], smap,
                    _take_perm(_take(lx, lane_gmap(ca, cb)), res_perm))
        else:
            from repro.streaming.grid_merge import grid_chunked_merge2
            from repro.streaming.planner import plan_chunked

            ka, undo = _keys_for(dense_a, nan_policy, descending)
            kb, _ = _keys_for(dense_b, nan_policy, descending)
            tile = plan_chunked(ca.width, cb.width, batch=ca.n,
                                dtype=ka.dtype).tile
            res_v = undo(grid_chunked_merge2(ka, kb, tile=tile,
                                             use_mxu=False,
                                             interpret=interpret))
        out_v = _scatter(out_v, smap, res_v)

    ptree = None if payload is None else rebuild([o[:total] for o in out_l],
                                                 total)
    return out_v[:total], (out_p[:total] if want_perm else None), ptree, out_offs


def _cat_csr(lane_a: jnp.ndarray, lane_b: jnp.ndarray,
             offs_a: Tuple[int, ...], offs_b: Tuple[int, ...]) -> jnp.ndarray:
    """Interleave two CSR lanes into the merged layout (per segment: a's
    entries then b's) with one static gather."""
    na, nb = offs_a[-1], offs_b[-1]
    idx = np.empty(na + nb, np.int64)
    pos = 0
    for s in range(len(offs_a) - 1):
        la = offs_a[s + 1] - offs_a[s]
        lb = offs_b[s + 1] - offs_b[s]
        idx[pos:pos + la] = np.arange(offs_a[s], offs_a[s + 1])
        idx[pos + la:pos + la + lb] = na + np.arange(offs_b[s], offs_b[s + 1])
        pos += la + lb
    cat = jnp.concatenate([lane_a, lane_b], axis=0)
    return cat[jnp.asarray(idx)]


# ---------------------------------------------------------------------------
# segment_topk / segment_argmax
# ---------------------------------------------------------------------------


def _normalize_ks(k, n_segs: int) -> Tuple[int, ...]:
    if isinstance(k, (int, np.integer)):
        ks = (int(k),) * n_segs
    else:
        ks = tuple(int(x) for x in k)
        assert len(ks) == n_segs, (len(ks), n_segs)
    assert all(x >= 0 for x in ks), ks
    return ks


def segment_topk_impl(
    values: jnp.ndarray,
    offsets,
    k,
    *,
    descending: bool = True,
    payload=None,
    nan_policy: str = "last",
    use_kernel: bool = True,
    interpret: Optional[bool] = None,
):
    """Per-segment top-k (largest first by default; ``descending=False``
    selects the smallest ascending). ``k`` may be one int or one per
    segment — a size-class bucket runs **one** launch with the class's
    max k and each segment keeps its own prefix. Returns
    ``(values, idx, payload_tree | None, out_offsets)`` in CSR layout
    with ``min(k_s, len_s)`` entries per segment; ``idx`` holds
    within-segment input positions."""
    offs = normalize_offsets(offsets)
    n = offs[-1]
    values = jnp.asarray(values)
    assert values.ndim == 1 and values.shape[0] == n, (values.shape, n)
    lengths = segment_lengths(offs)
    ks = _normalize_ks(k, len(offs) - 1)
    counts = [min(k_s, int(ln)) for k_s, ln in zip(ks, lengths)]
    out_offs = tuple(np.concatenate([[0], np.cumsum(counts)]).tolist())
    total = out_offs[-1]

    lanes, rebuild = ([], None)
    if payload is not None:
        lanes, rebuild = _flatten_leaves(payload, n)

    if not use_kernel:
        out, idx, pouts, ref_offs = ref_segment_topk(
            values, offs, ks, descending=descending, nan_policy=nan_policy,
            payload_lanes=lanes)
        assert ref_offs == out_offs
        ptree = None if payload is None else rebuild(pouts, total)
        return out, idx, ptree, out_offs

    mw = max_class_width(values.dtype)
    classes, spill = bucket_segments(lengths, mw)
    _record_bucketing("segment_topk", classes, spill)
    encode = nan_policy == "last" and key_transformable(values.dtype)
    vext = _ext(values)
    lext = [_ext(l) for l in lanes]
    out_v = jnp.zeros((total + 1,), values.dtype)
    out_i = jnp.zeros((total + 1,), jnp.int32)
    out_l = [jnp.zeros((total + 1,) + l.shape[1:], l.dtype) for l in lanes]

    def cls_counts(cls: SizeClass):
        return [counts[sid] for sid in cls.seg_ids]

    for cls in classes:
        cnts = cls_counts(cls)
        k_out = max(max(cnts), 1)
        gmap = gather_map(offs, cls, n)
        dense = _take(vext, gmap)
        p_dense = [_take(lx, gmap) for lx in lext]
        if cls.width == 1:
            res_v = dense[:, :1]
            res_perm = jnp.zeros((cls.n, 1), jnp.int32)
            res_l = [p[:, :1] for p in p_dense]
        else:
            plan = _class_plan((cls.width,), cls.n, values.dtype)
            res_v, res_perm, res_l = segment_class_sort_pallas(
                dense, _lens_col(cls), tuple(p_dense), k_out=k_out,
                encode=encode, flip=descending, want_perm=True,
                network=plan.network, block_batch=plan.block_batch,
                use_mxu=_use_mxu(plan, encode, values.dtype),
                interpret=interpret,
            )
        smap = scatter_map(out_offs, cls, k_out, counts=cnts, trash=total)
        out_v = _scatter(out_v, smap, res_v)
        out_i = _scatter(out_i, smap, res_perm)
        out_l = [_scatter(o, smap, r) for o, r in zip(out_l, res_l)]

    for cls in spill:  # equal-length vocab-scale rows: batched unified topk
        from repro.resilience.failpoints import failpoint

        failpoint("segmented.spill.topk")
        cnts = cls_counts(cls)
        k_out = max(max(cnts), 1)
        gmap = gather_map(offs, cls, n)
        dense = _take(vext, gmap)
        if descending:
            from repro.api.ops import topk as unified_topk

            # stable=True upholds the segment_topk contract that idx are
            # genuine within-segment positions: the dense topk's -1
            # pad-aliasing sentinel (a real value tying the dtype minimum,
            # e.g. masked -inf logits) orders after every real index under
            # stable ties, and k_out <= len means real candidates always
            # fill the prefix — so -1 can never surface here
            res_v, res_perm = unified_topk(dense, k_out, stable=True,
                                           nan_policy=nan_policy)
        else:
            keys, _ = _keys_for(dense, nan_policy, False)
            order = jnp.argsort(keys, axis=-1,
                                stable=True)[:, :k_out].astype(jnp.int32)
            res_v = jnp.take_along_axis(dense, order, axis=1)
            res_perm = order
        smap = scatter_map(out_offs, cls, k_out, counts=cnts, trash=total)
        out_v = _scatter(out_v, smap, res_v)
        out_i = _scatter(out_i, smap, res_perm)
        for o_i, lx in enumerate(lext):
            out_l[o_i] = _scatter(out_l[o_i], smap,
                                  _take_perm(_take(lx, gmap), res_perm))

    ptree = None if payload is None else rebuild([o[:total] for o in out_l],
                                                 total)
    return out_v[:total], out_i[:total], ptree, out_offs


def segment_argmax_impl(
    values: jnp.ndarray,
    offsets,
    *,
    nan_policy: str = "last",
    use_kernel: bool = True,
    interpret: Optional[bool] = None,
):
    """Per-segment argmax: ``(vals (S,), idx (S,))``; an empty segment
    yields the dtype minimum and index ``-1``."""
    offs = normalize_offsets(offsets)
    n_segs = len(offs) - 1
    vals, idx, _, out_offs = segment_topk_impl(
        values, offs, 1, descending=True, nan_policy=nan_policy,
        use_kernel=use_kernel, interpret=interpret)
    has = np.diff(np.asarray(out_offs)) > 0  # static per-segment hit mask
    src = np.minimum(np.asarray(out_offs[:-1]), max(out_offs[-1] - 1, 0))
    gathered_v = vals[jnp.asarray(src)] if out_offs[-1] else jnp.zeros(
        (n_segs,), values.dtype)
    gathered_i = idx[jnp.asarray(src)] if out_offs[-1] else jnp.zeros(
        (n_segs,), jnp.int32)
    fill_v = np_fill(sentinel_min(values.dtype), values.dtype)
    has_j = jnp.asarray(has)
    out_v = jnp.where(has_j, gathered_v, fill_v)
    out_i = jnp.where(has_j, gathered_i, jnp.int32(-1))
    return out_v, out_i
