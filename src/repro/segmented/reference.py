"""Per-segment XLA reference path for the segmented ops.

This is the escape hatch (``REPRO_DISABLE_SEGMENTED`` /
``set_segmented_enabled(False)``), the non-TPU auto route, and the test
oracle: one ``jnp.sort`` / stable ``argsort`` per segment, stitched back
into the CSR layout. Static offsets make every slice a compile-time
constant, so this traces to plain XLA slices/sorts/concats — slower than
the bucketed launches (one sort per segment instead of one per size
class) but correct for every input, and the bit-equality target the
kernel path is tested against.

Ordering conventions match the kernel path: ``descending`` is a *stable
ascending sort of the bit-flipped keys* (``kernels.segmented.flip_keys``
— the same transform the class kernels apply in VMEM), so NaNs come
first under ``nan_policy="last"`` — never the reverse-of-ascending
convention, whose tie order would invert the kernels' on every
duplicate. Values are gathered from the raw input at the permutation,
never decoded from keys, and are bit-identical to the kernel path for
every input. The *permutation* among tied values additionally matches
the kernels on every stable sub-path (classes narrower than the
column-device cutover); wider classes run whatever comparator-network
family the tournament picked (``repro.networks.merge_runs``), which —
exactly like the dense ``repro.sort`` without ``stable=True`` — makes
no tie-order promise, so perm/idx on duplicates is unspecified there,
not part of the contract.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import encode_key_values, key_transformable
from repro.kernels.segmented import flip_keys


def _seg_order(seg: jnp.ndarray, descending: bool, nan_policy: str):
    """Stable ascending argsort of the segment's (flipped-for-descending)
    total-order keys — bit-for-bit the kernel path's tie convention."""
    keys = seg
    if nan_policy == "last" and key_transformable(seg.dtype):
        keys = encode_key_values(seg)
    if descending:
        keys = flip_keys(keys)
    return jnp.argsort(keys, stable=True)


def ref_segment_sort(
    values: jnp.ndarray,
    offsets: Tuple[int, ...],
    *,
    descending: bool = False,
    nan_policy: str = "last",
    payload_lanes: Sequence[jnp.ndarray] = (),
    want_perm: bool = False,
):
    """Per-segment sort; returns ``(values, perm | None, payload_outs)``."""
    need_perm = want_perm or bool(payload_lanes)
    outs, perms = [], []
    pouts = [[] for _ in payload_lanes]
    for o0, o1 in zip(offsets, offsets[1:]):
        seg = values[o0:o1]
        if o1 - o0 <= 1:
            outs.append(seg)
            if need_perm:
                perms.append(jnp.zeros((o1 - o0,), jnp.int32))
                for i, lane in enumerate(payload_lanes):
                    pouts[i].append(lane[o0:o1])
            continue
        order = _seg_order(seg, descending, nan_policy)
        outs.append(seg[order])
        if need_perm:
            perms.append(order.astype(jnp.int32))
            for i, lane in enumerate(payload_lanes):
                pouts[i].append(lane[o0:o1][order])

    def cat(parts, like):
        return jnp.concatenate(parts) if parts else like[:0]

    out = cat(outs, values)
    perm = cat(perms, jnp.zeros((0,), jnp.int32)) if need_perm else None
    return out, perm, tuple(cat(p, lane) for p, lane in
                            zip(pouts, payload_lanes))


def ref_segment_merge(
    a: jnp.ndarray,
    b: jnp.ndarray,
    offsets_a: Tuple[int, ...],
    offsets_b: Tuple[int, ...],
    *,
    descending: bool = False,
    nan_policy: str = "last",
    payload_lanes: Sequence[jnp.ndarray] = (),  # segment-concat CSR lanes
    want_perm: bool = False,
):
    """Per-segment 2-way merge of sorted runs. ``payload_lanes`` are in
    the merged CSR layout (per segment: a's payload then b's). Returns
    ``(values, perm | None, payload_outs, out_offsets)``."""
    need_perm = want_perm or bool(payload_lanes)
    out_offsets = tuple(oa + ob for oa, ob in zip(offsets_a, offsets_b))
    outs, perms = [], []
    pouts = [[] for _ in payload_lanes]
    for s in range(len(offsets_a) - 1):
        a0, a1 = offsets_a[s], offsets_a[s + 1]
        b0, b1 = offsets_b[s], offsets_b[s + 1]
        seg = jnp.concatenate([a[a0:a1], b[b0:b1]])
        if seg.shape[0] <= 1:
            order = jnp.zeros(seg.shape, jnp.int32)
        else:
            order = _seg_order(seg, descending, nan_policy).astype(jnp.int32)
        outs.append(seg[order] if seg.shape[0] > 1 else seg)
        if need_perm:
            perms.append(order)
            o0 = out_offsets[s]
            for i, lane in enumerate(payload_lanes):
                pouts[i].append(lane[o0:o0 + seg.shape[0]][order])

    def cat(parts, like):
        return jnp.concatenate(parts) if parts else like[:0]

    out = cat(outs, a)
    perm = cat(perms, jnp.zeros((0,), jnp.int32)) if need_perm else None
    return out, perm, tuple(cat(p, lane) for p, lane in
                            zip(pouts, payload_lanes)), out_offsets


def ref_segment_topk(
    values: jnp.ndarray,
    offsets: Tuple[int, ...],
    ks: Tuple[int, ...],
    *,
    descending: bool = True,
    nan_policy: str = "last",
    payload_lanes: Sequence[jnp.ndarray] = (),
):
    """Per-segment top-k (``descending=False`` = bottom-k). Returns
    ``(values, idx, payload_outs, out_offsets)`` in CSR layout with
    ``out_offsets[s+1]-out_offsets[s] == min(ks[s], len_s)``."""
    outs, idxs = [], []
    pouts = [[] for _ in payload_lanes]
    out_offsets = [0]
    for s, (o0, o1) in enumerate(zip(offsets, offsets[1:])):
        ln = o1 - o0
        cnt = min(int(ks[s]), ln)
        out_offsets.append(out_offsets[-1] + cnt)
        if cnt == 0:
            continue
        seg = values[o0:o1]
        order = (_seg_order(seg, descending, nan_policy)[:cnt]
                 if ln > 1 else jnp.zeros((cnt,), jnp.int32))
        outs.append(seg[order])
        idxs.append(order.astype(jnp.int32))
        for i, lane in enumerate(payload_lanes):
            pouts[i].append(lane[o0:o1][order])

    def cat(parts, like):
        return jnp.concatenate(parts) if parts else like[:0]

    return (cat(outs, values), cat(idxs, jnp.zeros((0,), jnp.int32)),
            tuple(cat(p, lane) for p, lane in zip(pouts, payload_lanes)),
            tuple(out_offsets))
