"""repro.segmented — CSR ragged sort/merge/top-k over size-class buckets.

The paper's "any mixture of input list sizes" property as a first-class
workload (DESIGN.md §12): segments with static CSR offsets bucket into
pow2 length classes at trace time, each class runs one fused Pallas
launch, and over-tile segments spill to the FLiMS grid merge. Public
entry points live on the unified namespace —
``repro.segment_sort / segment_merge / segment_topk / segment_argmax`` —
and dispatch through the planner like every other op; this package holds
the machinery.
"""
from .bucketing import (  # noqa: F401
    SizeClass,
    bucket_merge_pairs,
    bucket_segments,
    normalize_offsets,
    segment_lengths,
)
from .core import (  # noqa: F401
    MAX_CLASS_WIDTH,
    max_class_width,
    segment_argmax_impl,
    segment_merge_impl,
    segment_sort_impl,
    segment_topk_impl,
    segmented_enabled,
    set_segmented_enabled,
)
