from .dist_sort import DIST_MIN_TOTAL, sample_merge_k, sample_sort  # noqa: F401
from .sharding import (  # noqa: F401
    Parallelism,
    batch_pspecs,
    build_param_pspecs,
    cache_pspecs,
    dist_sort_axis,
    make_parallelism,
    shard_map_compat,
    to_named,
    vocab_topk_axis,
)
