from .sharding import Parallelism, batch_pspecs, build_param_pspecs, cache_pspecs, make_parallelism, to_named  # noqa: F401
