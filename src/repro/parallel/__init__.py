from .sharding import (  # noqa: F401
    Parallelism,
    batch_pspecs,
    build_param_pspecs,
    cache_pspecs,
    make_parallelism,
    shard_map_compat,
    to_named,
    vocab_topk_axis,
)
