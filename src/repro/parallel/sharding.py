"""Logical-axis sharding rules: FSDP over 'data', TP/EP over 'model'.

Every parameter carries a tuple of logical axis names (from the model
``init`` functions). ``build_param_pspecs`` maps logical axes onto mesh
axes with divisibility and no-duplicate checks, falling back to
replication — so a 40-head qwen1.5 on a 16-way model axis simply leaves
heads unsharded rather than failing.

FSDP: the 'embed'-like dimension of every weight shards over 'data' —
parameters and optimizer states are ZeRO-3 partitioned over both mesh
axes; GSPMD inserts the per-layer all-gathers inside the scan and
reduce-scatters the gradients.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Parallelism:
    mesh: Mesh
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    ep_enabled: bool = True
    fsdp_axis: Optional[str] = "data"
    remat: str = "dots"

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.dp_axes]))

    @property
    def tp_size(self) -> int:
        return int(self.mesh.shape[self.tp_axis])

    def dp_for(self, size: int):
        """dp axes if the batch size divides across them, else None."""
        return self.dp_axes if size % self.dp_size == 0 and size >= self.dp_size else None

    def tp_for(self, size: int):
        return self.tp_axis if size % self.tp_size == 0 and size >= self.tp_size else None

    def constrain(self, x, *dims):
        """with_sharding_constraint shorthand; dims are mesh axis names/None.

        Explicit anchors are required because sharding propagation through
        remat + scan + custom_vjp loses activation shardings (observed:
        replicated flash-attention buffers at 453 GiB/device)."""
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*dims)))


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across jax versions: new releases expose it at the
    top level with ``check_vma``; 0.4.x has ``jax.experimental.shard_map``
    with ``check_rep``."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)


def dist_sort_axis(par: Optional[Parallelism], lengths) -> Optional[str]:
    """Mesh axis for the distributed sample-sort (parallel.dist_sort), or
    None when the lists cannot shard evenly over the TP axis — every input
    list must split into equal per-device slices for the static-shape
    ``shard_map`` pipeline."""
    if par is None or getattr(par, "tp_size", 1) <= 1:
        return None
    if any(ln < par.tp_size or ln % par.tp_size for ln in lengths):
        return None
    return par.tp_axis


def vocab_topk_axis(par: Parallelism, vocab_size: int) -> Optional[str]:
    """Mesh axis for the serving device-tree top-k (streaming.tree), or None
    when the vocab can't shard over TP and sampling stays single-device."""
    if par is None or par.tp_size <= 1:
        return None
    if vocab_size % par.tp_size != 0:
        return None
    return par.tp_axis


def make_parallelism(mesh: Mesh, *, ep: bool = True, remat: str = "dots") -> Parallelism:
    axes = mesh.axis_names
    dp = tuple(a for a in axes if a in ("pod", "data"))
    return Parallelism(mesh=mesh, dp_axes=dp, tp_axis="model", ep_enabled=ep,
                       fsdp_axis="data", remat=remat)


# logical axis -> candidate mesh axis (first feasible wins; None = replicate)
LOGICAL_RULES = {
    "vocab": ("model",),
    "mlp": ("model",),
    "heads": ("model",),
    "heads_flat": ("model",),
    "kv_heads": ("model",),
    "expert": ("model",),
    "embed": ("data",),  # FSDP shard
    "kv_lora": ("data",),
    "frontend": (),
    "head_dim": (),
    None: (),
}


def _pspec_for(shape: Tuple[int, ...], logical: Sequence, mesh: Mesh) -> P:
    """Map one array. If ndim == len(logical)+1 the array is scan-stacked:
    the leading 'layers' axis stays unsharded."""
    names: list = list(logical)
    offset = len(shape) - len(names)
    assert offset in (0, 1), (shape, logical)
    out = [None] * len(shape)
    used = set()
    for i, name in enumerate(names):
        dim = shape[offset + i]
        for cand in LOGICAL_RULES.get(name, ()):  # first feasible rule
            if cand in used or cand not in mesh.axis_names:
                continue
            if dim % mesh.shape[cand] == 0 and dim >= mesh.shape[cand]:
                out[offset + i] = cand
                used.add(cand)
                break
    return P(*out)


def build_param_pspecs(param_shapes, specs, mesh: Mesh):
    """param_shapes: pytree of ShapeDtypeStruct (from jax.eval_shape);
    specs: matching pytree of logical-axis tuples. Returns PartitionSpecs."""

    def walk(shapes, spec):
        if isinstance(shapes, dict):
            out = {}
            for k, v in shapes.items():
                out[k] = walk(v, spec[k] if isinstance(spec, dict) else spec)
            return out
        if isinstance(shapes, (list, tuple)):
            return type(shapes)(
                walk(v, spec[i] if isinstance(spec, (list, tuple)) else spec)
                for i, v in enumerate(shapes))
        logical = spec if isinstance(spec, tuple) else ()
        return _pspec_for(shapes.shape, logical, mesh)

    return walk(param_shapes, specs)


def to_named(tree, mesh: Mesh):
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), tree,
        is_leaf=lambda x: isinstance(x, P))


def batch_pspecs(cfg, par: Parallelism):
    dp = par.dp_axes
    out = {"tokens": P(dp, None), "targets": P(dp, None)}
    if cfg.family == "vlm":
        out["tokens"] = P(dp, None)
        out["patches"] = P(dp, None, None)
    if cfg.family == "audio":
        out = {"frames": P(dp, None, None), "targets": P(dp, None)}
    return out


def cache_pspecs(cfg, par: Parallelism, cache_shapes):
    """Shard caches by name: batch over dp; the attention *contraction* dim
    (kv heads / head_dim / latent / ssm heads) over TP. Never the sequence
    dim — decode writes there (dynamic_update_slice at a traced index) and
    the partitioner would fully rematerialize the cache every token."""
    dp, tp = par.dp_axes, par.tp_axis
    tpn = par.tp_size
    dpn = par.dp_size

    def div(n):
        return n % tpn == 0 and n >= tpn

    def leaf_spec(name, shp):
        # shp excludes any layer-stacking prefix; shp[0] = batch
        base = [dp if shp[0] % dpn == 0 else None]
        rest = list(shp[1:])
        out = [None] * len(rest)
        if name == "k":  # (H, D, S)
            out[0 if div(rest[0]) else 1] = tp if (div(rest[0]) or div(rest[1])) else None
        elif name == "v":  # (H, S, Dv)
            out[0 if div(rest[0]) else 2] = tp if (div(rest[0]) or div(rest[2])) else None
        elif name in ("ckv", "kpe"):  # (L, S) / (R, S)
            out[0] = tp if div(rest[0]) else None
        elif name == "conv":  # (K-1, conv_dim)
            out[1] = tp if div(rest[1]) else None
        elif name == "ssm":  # (H, P, N)
            out[0 if div(rest[0]) else 2] = tp if (div(rest[0]) or div(rest[2])) else None
        return base + out

    def walk(tree, stacked):
        if isinstance(tree, dict):
            return {k: walk_leaf(k, v, stacked) if not isinstance(v, (dict, list))
                    else walk(v, stacked) for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(v, stacked) for v in tree]
        raise TypeError(type(tree))

    def walk_leaf(name, x, stacked):
        shp = list(x.shape)
        if stacked:
            shp = shp[1:]
        if len(shp) == 0:
            return P(None) if stacked else P()
        spec = leaf_spec(name, shp)
        if stacked:
            spec = [None] + spec
        return P(*spec)

    out = {}
    for key, sub in cache_shapes.items():
        out[key] = walk(sub, stacked=(key != "head"))
    return out
