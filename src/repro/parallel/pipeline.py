"""GPipe-style pipeline parallelism over a 'pipe' mesh axis.

Not enabled on the 512-chip production mesh (scan-over-layers + FSDP + TP
covers it; DESIGN.md §6), but provided — and tested — for fleets where a
third axis is worth it (e.g. (pipe=8, data=16, model=16) at 2048 chips,
where FSDP gathers would otherwise cross slow edges).

Implementation: shard_map over the pipe axis; each rank owns a contiguous
stage (a stack of layers it scans locally). The classic skew-and-rotate
schedule runs n_micro + n_stages - 1 ticks; activations hop stages via
collective_permute. Bubble fraction = (S-1)/(S-1+M).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(
    stage_fn: Callable,  # (stage_params, x) -> x, applied by every rank
    stage_params,  # pytree stacked on a leading 'stage' axis
    x: jnp.ndarray,  # (n_micro, mb, ...) microbatched input
    mesh: Mesh,
    axis: str = "pipe",
):
    """Run x through n_stages sequential stages, pipelined over ``axis``."""
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]

    def body(params_local, x_local):
        # params_local: this rank's stage params (leading axis of size 1)
        params_local = jax.tree.map(lambda a: a[0], params_local)
        rank = jax.lax.axis_index(axis)
        fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        state = jnp.zeros_like(x_local[0])
        outputs = jnp.zeros_like(x_local)

        def tick(carry, t):
            state, outputs = carry
            # stage 0 ingests microbatch t (while t < n_micro)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            injected = jax.lax.dynamic_index_in_dim(x_local, mb_idx, 0,
                                                    keepdims=False)
            state = jnp.where(rank == 0, injected, state)
            state = stage_fn(params_local, state)
            # the last stage emits microbatch t - (n_stages - 1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = jnp.logical_and(rank == n_stages - 1,
                                   t >= n_stages - 1)
            outputs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, state, out_idx, 0),
                lambda o: o,
                outputs)
            # rotate activations one stage forward
            state = jax.lax.ppermute(state, axis, fwd_perm)
            return (state, outputs), None

        (state, outputs), _ = jax.lax.scan(
            tick, (state, outputs), jnp.arange(n_micro + n_stages - 1))
        return outputs

    in_specs = (
        jax.tree.map(lambda _: P(axis), stage_params),
        P(None),  # every rank sees all microbatches (input broadcast)
    )
    from .sharding import shard_map_compat

    out = shard_map_compat(body, mesh, in_specs=in_specs,
                           out_specs=P(axis, None))(stage_params, x)
    # out is (pipe, n_micro/..., ...) — only the last stage's slice holds
    # real outputs; gather it
    return out.reshape((n_stages, n_micro) + x.shape[1:])[-1]
