"""Error-feedback int8 gradient compression for cross-replica reduction.

At 1000+ nodes the DP gradient reduce-scatter is the dominant inter-pod
collective. This module provides a drop-in compressor: per-block int8
quantization with an error-feedback residual so compression noise is
re-injected next step (convergence-safe in practice; see DeepSeed/1-bit
Adam literature).

Usage (manual-DP mode): q, scale = compress(g + err); g_hat = decompress(
psum(q), ...); err = g - g_hat. Under pure GSPMD the reduction is implicit,
so the framework applies compression only when ``train.grad_compress`` is
on AND the step uses the shard_map DP path; the dry-run baseline keeps it
off (documented in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_len(n: int) -> int:
    return -(-n // BLOCK) * BLOCK


def compress(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """g (any shape) -> (int8 codes, per-block fp32 scales)."""
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = _pad_len(n) - n
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale[:, 0]


def decompress(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compressed_psum(g: jnp.ndarray, axis_name: str, err: jnp.ndarray):
    """Error-feedback compressed all-reduce over ``axis_name`` (inside
    shard_map). Returns (reduced gradient, new error residual)."""
    g_in = g + err
    q, s = compress(g_in)
    # sum int32 codes and scales: unbiased when scales are close; the error
    # feedback absorbs the remaining quantization noise
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    s_sum = jax.lax.psum(s, axis_name)
    n_dev = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    g_hat = decompress((q_sum.astype(jnp.float32) / n_dev).astype(jnp.float32),
                       s_sum / n_dev, g.shape)
    # local view of what was actually transmitted for this shard
    g_local_hat = decompress(q.astype(jnp.float32), s, g.shape)
    new_err = g_in - g_local_hat
    return g_hat * n_dev, new_err


def compress_tree(grads, errs, axis_name: str):
    """Apply compressed_psum over a gradient pytree."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errs)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        gh, ne = compressed_psum(g, axis_name, e)
        out_g.append(gh)
        out_e.append(ne)
    return jax.tree.unflatten(tdef, out_g), jax.tree.unflatten(tdef, out_e)
