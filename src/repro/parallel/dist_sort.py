"""Distributed sample-sort: ``repro.sort`` / ``repro.merge_k`` over a mesh axis.

The paper's central claim is that LOMS devices merge *any mixture of input
list sizes* in a fixed small number of stages — exactly the primitive a
multi-device sort needs after an all-to-all partition. This module builds
PSRS (parallel sorting by regular sampling) out of LOMS devices, under
``shard_map`` over one mesh axis of ``P`` devices:

1. **local sort** — each device runs the LOMS merge-tree schedule on its
   contiguous slice of the input (for ``merge_k``: a k-way LOMS merge of
   its slices of the pre-sorted input lists — a contiguous slice of a
   sorted list is itself sorted, so the merge devices apply directly);
2. **splitters** — P regular samples per device, all-gathered and sorted
   (a P²-input LOMS sort computed replicated), every P-th picked as one of
   the P-1 splitters;
3. **partition** — per-row bucket boundaries by binary search over the
   sorted local run (``side='right'``: a global equal-value class never
   straddles a bucket), one ``lax.all_to_all`` moving bucket ``j`` to
   device ``j`` as capacity-padded blocks with explicit per-block valid
   counts riding along;
4. **merge** — each device k-way merges the P received runs: the LOMS
   k-way device while the comparison cloud fits the VMEM budget, the
   streaming ``chunked_merge_k`` pipeline (FLiMS refill rule) past it,
   and a log-depth tree of binary-search rank-merges for payload-carrying
   oversized partitions;
5. **rebalance** — bucket sizes are data-dependent, so a second
   ``all_to_all`` redistributes by *global rank* back onto the even output
   sharding. Validity masks are derived from the all-gathered bucket
   sizes, never from sentinel values.

Exactness: the partition capacity is the full local length, so no bucket
can overflow regardless of splitter quality (splitters only affect load
balance, never correctness), and sentinel-padded slots are tracked by
masks / ``-1`` positions end to end — a genuine dtype-max value ties the
pad but is never displaced by it (:func:`~repro.kernels.common.stable_compact`
resolves such ties by validity). The result is bit-identical to the
single-device backends for any input, including the int32 position
payload the unified API threads for ``stable=`` / ``payload=`` calls.
Float inputs arrive from the ops layer as total-order integer keys
(:mod:`repro.api.keys`), so the splitter searches never see NaN/±inf.

The data-dependent scatter/gather of phases 3 and 5 means the *schedule*
of the distributed path is not oblivious (unlike everything below it);
the per-device compute — every compare-exchange — still is.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels.common import np_fill, sentinel_max, stable_compact
from repro.obs import metrics as obs_metrics

#: below this total length the partition + two exchanges dominate the
#: device-parallel merge win; plan() keeps single-device backends.
DIST_MIN_TOTAL = 8192


# ---------------------------------------------------------------------------
# per-device building blocks (plain jnp; run inside the shard_map body)
# ---------------------------------------------------------------------------


def _fits_kway_budget(total: int) -> bool:
    from repro.streaming.planner import kway_fits_vmem

    return kway_fits_vmem(total)


def _merge2_ranked(
    av: jnp.ndarray, ap: Optional[jnp.ndarray],
    bv: jnp.ndarray, bp: Optional[jnp.ndarray],
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Stable 2-run merge by binary-search ranks (first run wins ties).

    O(n log n) with no comparator cloud — the payload-carrying analog of
    the streaming fallback for runs far past the VMEM budget."""
    m, n = av.shape[-1], bv.shape[-1]
    ra = jnp.arange(m, dtype=jnp.int32) + jax.vmap(
        lambda hay, q: jnp.searchsorted(hay, q, side="left"))(bv, av).astype(jnp.int32)
    rb = jnp.arange(n, dtype=jnp.int32) + jax.vmap(
        lambda hay, q: jnp.searchsorted(hay, q, side="right"))(av, bv).astype(jnp.int32)
    vals = jnp.concatenate([av, bv], axis=-1)
    rank = jnp.concatenate([ra, rb], axis=-1)
    out_v = jnp.put_along_axis(jnp.zeros_like(vals), rank, vals, axis=-1,
                               inplace=False)
    if ap is None:
        return out_v, None
    pos = jnp.concatenate([ap, bp], axis=-1)
    out_p = jnp.put_along_axis(jnp.zeros_like(pos), rank, pos, axis=-1,
                               inplace=False)
    return out_v, out_p


def _merge_sorted_runs(
    runs: List[jnp.ndarray], pos_runs: Optional[List[jnp.ndarray]]
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """k-way merge of sorted (B, n_i) runs with the VMEM-budget ladder.

    Inside the budget: a binary tree of 2-way LOMS devices (valid for any
    length mixture — the flat k-way setup array rejects some ragged
    mixes). Past it: the streaming ``chunked_merge_k`` pipeline on TPU
    (value-only), the log-depth binary-search rank-merge tree everywhere
    interpret mode would make the tiled kernels crawl, and always for
    payload-carrying runs (streaming cannot thread positions)."""
    if len(runs) == 1:
        return runs[0], (None if pos_runs is None else pos_runs[0])
    total = sum(r.shape[-1] for r in runs)
    if _fits_kway_budget(total):
        from repro.api import schedules

        if pos_runs is None:
            return schedules.merge_k(runs, kind="tree"), None
        return schedules.merge_k(runs, kind="tree", payload=pos_runs)
    if pos_runs is None and jax.default_backend() == "tpu":
        from repro.streaming import chunked_merge_k

        return chunked_merge_k(runs), None
    items = list(runs)
    pls = list(pos_runs) if pos_runs is not None else [None] * len(runs)
    while len(items) > 1:
        nxt, npl = [], []
        for i in range(0, len(items) - 1, 2):
            v, p = _merge2_ranked(items[i], pls[i], items[i + 1], pls[i + 1])
            nxt.append(v)
            npl.append(p)
        if len(items) % 2:
            nxt.append(items[-1])
            npl.append(pls[-1])
        items, pls = nxt, npl
    return items[0], pls[0]


def _splitters(xs: jnp.ndarray, axis_name: str, p: int) -> jnp.ndarray:
    """Regular-sampling splitters, replicated per device: (B, P-1)."""
    from repro.api import schedules

    n_local = xs.shape[-1]
    samp_idx = np.arange(p, dtype=np.int32) * n_local // p
    samp = xs[:, samp_idx]  # (B, P) regular samples of the sorted run
    gathered = jax.lax.all_gather(samp, axis_name, axis=1, tiled=True)
    ssort = schedules.sort(gathered)  # P^2-input LOMS sort, replicated
    return ssort[:, p - 1 :: p][:, : p - 1]


def _partition(
    xs: jnp.ndarray, ps: Optional[jnp.ndarray], split: jnp.ndarray, fill
):
    """Scatter each row of the sorted run into P capacity-C send blocks.

    Capacity is the full local length, so overflow is impossible; unused
    slots carry the +sentinel (runs stay sorted) and position ``-1``."""
    b, n_local = xs.shape
    p = split.shape[-1] + 1
    # first index > split_j: equal values all stay left of the boundary,
    # so an equal-value class lands in one bucket on every device
    sb = jax.vmap(lambda row, s: jnp.searchsorted(row, s, side="right"))(
        xs, split).astype(jnp.int32)
    bounds = jnp.concatenate(
        [jnp.zeros((b, 1), jnp.int32), sb, jnp.full((b, 1), n_local, jnp.int32)],
        axis=1)  # (B, P+1)
    lane = jnp.arange(n_local, dtype=jnp.int32)
    bucket = jax.vmap(lambda s: jnp.searchsorted(s, lane, side="right"))(
        sb).astype(jnp.int32)  # (B, n_local) destination bucket per element
    start = jnp.take_along_axis(bounds, bucket, axis=1)
    dest = bucket * n_local + (lane[None, :] - start)
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    send = jnp.full((b, p * n_local), fill, xs.dtype).at[rows, dest].set(xs)
    cnt = bounds[:, 1:] - bounds[:, :-1]  # (B, P) per-bucket valid counts
    psend = None
    if ps is not None:
        psend = jnp.full((b, p * n_local), -1, jnp.int32).at[rows, dest].set(ps)
        psend = psend.reshape(b, p, n_local)
    return send.reshape(b, p, n_local), cnt, psend


def _a2a(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Send split-axis slice j to device j; received slices stack there."""
    # per-device payload bytes of this exchange, recorded at trace time
    # (one count per compilation — the interconnect-traffic figure the
    # DIST_MIN_TOTAL cutover is meant to amortize)
    obs_metrics.counter("dist_sort.all_to_all_bytes").inc(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize)
    return jax.lax.all_to_all(x, axis_name, split_axis=1, concat_axis=1)


def _rebalance(
    vals: jnp.ndarray, pos: Optional[jnp.ndarray], v_count: jnp.ndarray,
    axis_name: str, p: int, n_local: int, fill,
):
    """Redistribute merged buckets by global rank onto even output shards.

    Element i of this device's bucket has global rank ``off_me + i``; it
    belongs to output device ``rank // n_local`` at offset
    ``rank % n_local``. Receive-side validity comes from the all-gathered
    bucket sizes — disjoint rank intervals that exactly tile the segment —
    never from comparing against sentinel values."""
    b, l = vals.shape
    me = jax.lax.axis_index(axis_name)
    v_all = jax.lax.all_gather(v_count, axis_name, axis=1,
                               tiled=False).astype(jnp.int32)  # (B, P)
    off = jnp.cumsum(v_all, axis=1) - v_all  # (B, P) bucket start ranks
    my_off = jnp.take(off, me, axis=1)  # (B,)
    lane = jnp.arange(l, dtype=jnp.int32)
    rank = my_off[:, None] + lane[None, :]
    valid = lane[None, :] < v_count[:, None]
    dest = jnp.clip(rank // n_local, 0, p - 1) * n_local + rank % n_local
    slot = jnp.where(valid, dest, p * n_local)  # invalid -> trash slot
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    send = jnp.full((b, p * n_local + 1), fill, vals.dtype).at[rows, slot].set(vals)
    recv = _a2a(send[:, :-1].reshape(b, p, n_local), axis_name)
    precv = None
    if pos is not None:
        psend = jnp.full((b, p * n_local + 1), -1, jnp.int32).at[rows, slot].set(pos)
        precv = _a2a(psend[:, :-1].reshape(b, p, n_local), axis_name)
    out = jnp.full((b, n_local), fill, vals.dtype)
    pout = None if pos is None else jnp.full((b, n_local), -1, jnp.int32)
    q = jnp.arange(n_local, dtype=jnp.int32)[None, :]
    my_lo = me * n_local
    for i in range(p):  # P is static: unrolled masked selects
        lo = off[:, i][:, None] - my_lo
        hi = lo + v_all[:, i][:, None]
        m = (q >= lo) & (q < hi)
        out = jnp.where(m, recv[:, i, :], out)
        if pos is not None:
            pout = jnp.where(m, precv[:, i, :], pout)
    return out, pout


def _psrs_tail(
    xs: jnp.ndarray, ps: Optional[jnp.ndarray], *, axis_name: str, p: int, fill
):
    """Phases 2-5 on an already locally sorted (B, n_local) run."""
    n_local = xs.shape[-1]
    split = _splitters(xs, axis_name, p)
    send, cnt, psend = _partition(xs, ps, split, fill)
    recv = _a2a(send, axis_name)  # (B, P, C): run i from device i
    rcnt = _a2a(cnt, axis_name)  # (B, P): its valid length
    precv = None if psend is None else _a2a(psend, axis_name)
    runs = [recv[:, i, :] for i in range(p)]
    pruns = None if precv is None else [precv[:, i, :] for i in range(p)]
    merged, pmerged = _merge_sorted_runs(runs, pruns)
    if pmerged is not None:
        # pads tie genuine dtype-max values; validity (pos >= 0), not the
        # value, decides the live prefix
        merged, pmerged = stable_compact(pmerged >= 0, merged, pmerged)
    v_count = rcnt.sum(axis=1).astype(jnp.int32)
    return _rebalance(merged, pmerged, v_count, axis_name, p, n_local, fill)


# ---------------------------------------------------------------------------
# public entry points (full logical arrays in, full logical arrays out)
# ---------------------------------------------------------------------------
#
# The pipelines are jitted at module level with the mesh/axis as static
# arguments: the shard_map bodies are thousands of small compare-exchange
# ops, so eager per-device dispatch would dominate, and a per-call jax.jit
# wrapper would recompile on every invocation.


def _fill_for(dtype):
    return np_fill(sentinel_max(dtype), dtype)


@functools.partial(jax.jit, static_argnames=("mesh", "axis_name", "with_pos"))
def _sample_sort_jit(x, pos, *, mesh, axis_name, with_pos):
    from repro.api import schedules
    from repro.parallel.sharding import shard_map_compat

    p = int(mesh.shape[axis_name])
    fill = _fill_for(x.dtype)
    spec = P(None, axis_name)

    if not with_pos:
        def body(xl):
            out, _ = _psrs_tail(schedules.sort(xl), None,
                                axis_name=axis_name, p=p, fill=fill)
            return out

        return shard_map_compat(body, mesh, in_specs=spec, out_specs=spec)(x)

    def body(xl, pl):
        xs, psl = schedules.sort(xl, payload=pl)
        return _psrs_tail(xs, psl, axis_name=axis_name, p=p, fill=fill)

    return shard_map_compat(body, mesh, in_specs=(spec, spec),
                            out_specs=(spec, spec))(x, pos)


def sample_sort(
    x: jnp.ndarray, *, mesh, axis_name: str, pos: Optional[jnp.ndarray] = None
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Ascending sort of canonical (B, N) over ``mesh[axis_name]``.

    ``N`` must divide evenly over the axis (the planner only offers this
    backend when it does). ``pos`` is the int32 position payload of the
    registry convention; returns ``(sorted, pos_out | None)``."""
    p = int(mesh.shape[axis_name])
    n = x.shape[-1]
    assert n % p == 0 and n >= p, (n, p)
    if pos is None:
        out = _sample_sort_jit(x, jnp.zeros((), jnp.int32), mesh=mesh,
                               axis_name=axis_name, with_pos=False)
        return out, None
    return _sample_sort_jit(x, pos, mesh=mesh, axis_name=axis_name,
                            with_pos=True)


@functools.partial(jax.jit,
                   static_argnames=("mesh", "axis_name", "k", "with_pos"))
def _sample_merge_jit(*arrs, mesh, axis_name, k, with_pos):
    from repro.parallel.sharding import shard_map_compat

    p = int(mesh.shape[axis_name])
    fill = _fill_for(arrs[0].dtype)
    spec = P(None, axis_name)

    if not with_pos:
        def body(*locs):
            merged, _ = _merge_sorted_runs(list(locs), None)
            out, _ = _psrs_tail(merged, None, axis_name=axis_name, p=p,
                                fill=fill)
            return out

        return shard_map_compat(body, mesh, in_specs=tuple(spec for _ in arrs),
                                out_specs=spec)(*arrs)

    def body(*args):
        merged, pmerged = _merge_sorted_runs(list(args[:k]), list(args[k:]))
        return _psrs_tail(merged, pmerged, axis_name=axis_name, p=p, fill=fill)

    return shard_map_compat(body, mesh, in_specs=tuple(spec for _ in arrs),
                            out_specs=(spec, spec))(*arrs)


def sample_merge_k(
    lists: Sequence[jnp.ndarray], *, mesh, axis_name: str,
    pos: Optional[Sequence[jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """k-way merge of sorted (B, n_i) lists over ``mesh[axis_name]``.

    Each list shards evenly; a device's slice of a sorted list is sorted,
    so phase 1 is a local k-way LOMS merge instead of a full sort — the
    paper's merge-any-mixture primitive doing the work a sort would."""
    lists = list(lists)
    p = int(mesh.shape[axis_name])
    lens = [int(l.shape[-1]) for l in lists]
    assert all(ln % p == 0 and ln >= p for ln in lens), (lens, p)
    k = len(lists)
    if pos is None:
        out = _sample_merge_jit(*lists, mesh=mesh, axis_name=axis_name, k=k,
                                with_pos=False)
        return out, None
    return _sample_merge_jit(*lists, *list(pos), mesh=mesh,
                             axis_name=axis_name, k=k, with_pos=True)
