"""Family registry: the only door kernels use to obtain network programs.

Kernels (``kernels/sort.py``, ``kernels/segmented.py``,
``kernels/loms_merge.py``, ``streaming/grid_merge.py``) request programs
by family *name* — never by importing a generator — so that the
autotuner tournament can swap families per size class and the set of
families stays open (``register_family`` accepts out-of-tree
generators). ``kway_schedule``/``median_schedule`` route the k-way
Schedule builders through the same door, keeping ``repro.core.loms``
out of the kernel layer entirely.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

from .families import BUILTIN_FAMILIES
from .program import MergeProgram, SortProgram

__all__ = [
    "NetworkFamily",
    "register_family",
    "get_family",
    "family_names",
    "merge_program",
    "sort_program",
    "capable_families",
    "kway_schedule",
    "median_schedule",
]


@dataclasses.dataclass(frozen=True)
class NetworkFamily:
    name: str
    merge: Callable[..., MergeProgram]  # (m, n, n_cols=None)
    sort: Callable[[int], SortProgram]  # (w) — w a pow2 width
    merge_capable: Callable[[int, int], bool]
    sort_capable: Callable[[int], bool]


_REGISTRY: dict = {}


def register_family(fam: NetworkFamily) -> None:
    _REGISTRY[fam.name] = fam


def get_family(name: str) -> NetworkFamily:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown network family {name!r}; "
            f"registered: {sorted(_REGISTRY)}") from None


def family_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


for _name, (_m, _s, _mc, _sc) in BUILTIN_FAMILIES.items():
    register_family(NetworkFamily(name=_name, merge=_m, sort=_s,
                                  merge_capable=_mc, sort_capable=_sc))


def merge_program(family: str, m: int, n: int,
                  n_cols: Optional[int] = None) -> MergeProgram:
    """The 2-run merge program for ``family`` at static shape (m, n).

    ``n_cols`` overrides the column count for column-device families
    (ignored by pair families)."""
    return get_family(family).merge(int(m), int(n), n_cols)


def sort_program(family: str, width: int) -> SortProgram:
    """The pow2-width merge-tree sort program for ``family``."""
    return get_family(family).sort(int(width))


def capable_families(op: str, lengths: Sequence[int]) -> Tuple[str, ...]:
    """Family names (registration order — 'loms' first) able to realize
    ``op`` at the given static lengths. ``op='merge2'`` takes ``(m, n)``;
    ``op='sort'`` takes ``(n,)`` and checks the padded pow2 width."""
    if op == "merge2":
        m, n = (int(x) for x in lengths)
        return tuple(f for f in _REGISTRY
                     if _REGISTRY[f].merge_capable(m, n))
    if op == "sort":
        from repro.kernels.common import ceil_pow2

        w = ceil_pow2(int(lengths[0]))
        return tuple(f for f in _REGISTRY if _REGISTRY[f].sort_capable(w))
    raise ValueError(f"capable_families: unknown op {op!r}")


def kway_schedule(lens: Sequence[int], n_stages: Optional[int] = None):
    """K-way LOMS merge Schedule (the paper's Table 1 stage counts) —
    the registry-level door to ``core.loms.loms_kway``."""
    from repro.core import loms as _core_loms

    return _core_loms.loms_kway(tuple(int(x) for x in lens), n_stages)


def median_schedule(lens: Sequence[int]):
    """(Schedule, median position) for the early-exit k-way median."""
    from repro.core import loms as _core_loms

    return _core_loms.loms_median(tuple(int(x) for x in lens))
