"""Pluggable comparator-network layer (DESIGN.md §15).

One trace-time home for every merge/sort network structure the Pallas
kernels execute: family generators (LOMS column device, S2MS, 3-periodic,
Batcher bitonic) emit compact merge-step programs; kernels run them via
:func:`merge_runs` / :func:`run_sort_program`; the streaming autotuner
holds a per-size-class tournament over the capable families.
"""
from .families import PERIODIC3_MAX_WIDTH, divisor_cols, pick_merge_cols
from .program import (MergeProgram, PairStage, SortProgram, merge_runs,
                      program_to_schedule, run_sort_program,
                      sort_program_to_schedule)
from .registry import (NetworkFamily, capable_families, family_names,
                       get_family, kway_schedule, median_schedule,
                       merge_program, register_family, sort_program)

__all__ = [
    "PERIODIC3_MAX_WIDTH",
    "divisor_cols",
    "pick_merge_cols",
    "MergeProgram",
    "PairStage",
    "SortProgram",
    "merge_runs",
    "run_sort_program",
    "program_to_schedule",
    "sort_program_to_schedule",
    "NetworkFamily",
    "capable_families",
    "family_names",
    "get_family",
    "kway_schedule",
    "median_schedule",
    "merge_program",
    "register_family",
    "sort_program",
]
