"""Merge-step program IR: the lowered, kernel-friendly sibling of
``core.networks.Schedule``.

A :class:`MergeProgram` describes one oblivious 2-run merge as either

* ``kind='columns'`` — the paper's column device family: ``n_cols == 1``
  is the single-stage S2MS rank-merge; ``n_cols == C > 1`` is the LOMS
  UP-m/DN-n device (stage 1: C strided-column S2MS merges, stage 2: row
  rank-sorts of the (R, C) stack); or
* ``kind='pairs'`` — a sequence of compare-exchange
  :class:`PairStage`\\ s over the concatenated runs (optionally with the
  hi run reversed on entry), which expresses Batcher bitonic halvers and
  periodic brick/reflect networks.

Programs are frozen trace-time constants built by the family generators
in :mod:`repro.networks.families` and handed to kernels through
:mod:`repro.networks.registry` — kernels never import a generator
directly, so tie-order and cutover behavior live in exactly one place.
The executors here (:func:`merge_runs`, :func:`run_sort_program`) are
plain ``jnp`` on the last axis — safe inside Pallas kernel bodies (no
captured numpy index constants; only reshapes, static slices, reversals
and :func:`repro.kernels.common._iota`).

:func:`program_to_schedule` lifts a program back into the validated
``Schedule`` IR so the 0-1-principle checkers in ``core.networks`` apply
to every family at every emitted width.

Tie caution (same contract as the old ``merge2_cols``): only the
``columns``/``n_cols == 1`` S2MS program is a *stable* merge (lo run
wins ties). Column devices and pair networks make no cross-run tie-order
promise — callers whose sentinels can tie genuine values must resolve
validity by mask (``stable_compact``), never by position.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.kernels.common import _iota, merge2_sorted, sort_nsorter

__all__ = [
    "PairStage",
    "MergeProgram",
    "SortProgram",
    "merge_runs",
    "run_sort_program",
    "program_to_schedule",
    "sort_program_to_schedule",
]


@dataclasses.dataclass(frozen=True)
class PairStage:
    """One compare-exchange stage over the working vector of length L.

    kind='xor'       — partner lanes ``i`` and ``i ^ d`` (the Batcher
                       halver stride; requires ``2*d | L``).
    kind='reflect'   — partner lanes ``i`` and ``L-1-i`` (the periodic
                       network's folding stage; requires L even).
    kind='brick_odd' — odd brick: pairs (1,2), (3,4), ... (L-3,L-2); the
                       ends idle (requires L even).

    Every stage is a standard comparator set: the min lands on the
    lower-indexed lane.
    """

    kind: str
    d: int = 1

    def __post_init__(self):
        assert self.kind in ("xor", "reflect", "brick_odd"), self.kind
        assert self.d >= 1


@dataclasses.dataclass(frozen=True)
class MergeProgram:
    """A lowered 2-run merge: ``(m, n) -> m + n`` along the last axis."""

    family: str
    m: int
    n: int
    kind: str  # 'columns' | 'pairs'
    n_cols: int = 1
    reverse_hi: bool = False
    stages: Tuple[PairStage, ...] = ()

    def __post_init__(self):
        assert self.kind in ("columns", "pairs"), self.kind
        if self.kind == "columns" and self.n_cols > 1:
            assert self.m % self.n_cols == 0 and self.n % self.n_cols == 0, (
                self.m, self.n, self.n_cols)

    @property
    def total(self) -> int:
        return self.m + self.n


@dataclasses.dataclass(frozen=True)
class SortProgram:
    """A pow2-width merge-tree sort: ``levels[i]`` merges run pairs of
    length ``2**i`` (so ``levels[i].m == levels[i].n == 2**i``)."""

    family: str
    width: int
    levels: Tuple[MergeProgram, ...] = ()

    def __post_init__(self):
        run = 1
        for mp in self.levels:
            assert mp.m == run and mp.n == run, (mp.m, mp.n, run)
            run *= 2
        assert run == max(self.width, 1), (self.width, len(self.levels))


# ---------------------------------------------------------------------------
# Executors (kernel-safe jnp)
# ---------------------------------------------------------------------------


def _xor_exchange(x, p, d: int):
    """Compare-exchange lanes (i, i^d) on the last axis (2*d | L)."""
    lead, L = x.shape[:-1], x.shape[-1]
    y = x.reshape(lead + (L // (2 * d), 2, d))
    a, b = y[..., 0, :], y[..., 1, :]
    swap = a > b
    out = jnp.stack([jnp.where(swap, b, a), jnp.where(swap, a, b)],
                    axis=-2).reshape(lead + (L,))
    if p is None:
        return out, None
    q = p.reshape(lead + (L // (2 * d), 2, d))
    pa, pb = q[..., 0, :], q[..., 1, :]
    pout = jnp.stack([jnp.where(swap, pb, pa), jnp.where(swap, pa, pb)],
                     axis=-2).reshape(lead + (L,))
    return out, pout


def _apply_pair_stage(st: PairStage, x, p):
    L = x.shape[-1]
    if st.kind == "xor":
        return _xor_exchange(x, p, st.d)
    if st.kind == "reflect":
        # lanes i and L-1-i; both halves evaluate the same strict
        # comparison so the swap mask is self-consistent under ties
        r = x[..., ::-1]
        left = _iota(x.shape, x.ndim - 1) < (L // 2)
        swap = jnp.where(left, x > r, r > x)
        out = jnp.where(swap, r, x)
        if p is None:
            return out, None
        return out, jnp.where(swap, p[..., ::-1], p)
    assert st.kind == "brick_odd"
    if L <= 2:
        return x, p
    head, mid, tail = x[..., :1], x[..., 1:L - 1], x[..., L - 1:]
    pm = None if p is None else p[..., 1:L - 1]
    mid, pm = _xor_exchange(mid, pm, 1)
    out = jnp.concatenate([head, mid, tail], axis=-1)
    if p is None:
        return out, None
    pout = jnp.concatenate([p[..., :1], pm, p[..., L - 1:]], axis=-1)
    return out, pout


def _merge_columns(prog: MergeProgram, lo, hi, payload, use_mxu: bool):
    """The paper's UP-m/DN-n column device as strided views: column ``c``
    holds the ascending stride-C slices ``lo[c::C]`` and
    ``hi[(C-1-c)%C::C]``, each column is one S2MS merge (``m*n/C^2``
    comparators instead of the plain S2MS ``m*n``), stage 2 rank-sorts
    each row of C values."""
    m, n = prog.m, prog.n
    c_ = prog.n_cols
    if c_ <= 1 or m % c_ or n % c_:
        return merge2_sorted(lo, hi, payload=payload, use_mxu=use_mxu)
    plo, phi = payload if payload is not None else (None, None)
    cols, pcols = [], []
    for c in range(c_):
        av = lo[..., c::c_]
        bv = hi[..., (c_ - 1 - c) % c_ :: c_]
        if payload is not None:
            col, pcol = merge2_sorted(
                bv, av,
                payload=(phi[..., (c_ - 1 - c) % c_ :: c_], plo[..., c::c_]),
                use_mxu=use_mxu,
            )
            pcols.append(pcol)
        else:
            col = merge2_sorted(bv, av, use_mxu=use_mxu)
        cols.append(col)
    arr = jnp.stack(cols, axis=-1)  # (..., R, C)
    shape = lo.shape[:-1] + (m + n,)
    if payload is not None:
        arr, parr = sort_nsorter(arr, jnp.stack(pcols, axis=-1),
                                 use_mxu=use_mxu)
        return arr.reshape(shape), parr.reshape(shape)
    return sort_nsorter(arr, use_mxu=use_mxu).reshape(shape)


def _merge_pairs(prog: MergeProgram, lo, hi, payload):
    hi_ = hi[..., ::-1] if prog.reverse_hi else hi
    x = jnp.concatenate([lo, hi_], axis=-1)
    p = None
    if payload is not None:
        plo, phi = payload
        p = jnp.concatenate(
            [plo, phi[..., ::-1] if prog.reverse_hi else phi], axis=-1)
    for st in prog.stages:
        x, p = _apply_pair_stage(st, x, p)
    return (x, p) if payload is not None else x


def merge_runs(
    prog: MergeProgram,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    *,
    payload: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    use_mxu: bool = True,
):
    """Execute one merge program on ascending runs ``lo``/``hi`` (last
    axis). With ``payload=(plo, phi)`` returns ``(vals, pvals)``."""
    assert lo.shape[-1] == prog.m and hi.shape[-1] == prog.n, (
        lo.shape, hi.shape, prog)
    if prog.kind == "columns":
        return _merge_columns(prog, lo, hi, payload, use_mxu)
    return _merge_pairs(prog, lo, hi, payload)


def run_sort_program(prog: SortProgram, keys: jnp.ndarray,
                     pos: Optional[jnp.ndarray], use_mxu: bool):
    """Trace-time-unrolled merge-tree sort over pow2-width ``(bt, w)``
    rows, optionally threading an int32 position lane through every
    permute. The one home for the tree loop — the fused dense sort
    (kernels/sort.py) and the segmented class sort share it, so level
    structure (e.g. the LOMS column-device cutover, chosen by the family
    generator) and tie-order behavior can never diverge between them.
    Returns ``(keys, pos)``."""
    bt = keys.shape[0]
    w = prog.width
    assert keys.shape[-1] == w, (keys.shape, w)
    for mp in prog.levels:
        run = mp.m
        g = w // (2 * run)
        kv = keys.reshape(bt, g, 2 * run)
        if pos is not None:
            pv = pos.reshape(bt, g, 2 * run)
            kv, pv = merge_runs(
                mp, kv[..., :run], kv[..., run:],
                payload=(pv[..., :run], pv[..., run:]), use_mxu=use_mxu,
            )
            pos = pv.reshape(bt, w)
        else:
            kv = merge_runs(mp, kv[..., :run], kv[..., run:],
                            use_mxu=use_mxu)
        keys = kv.reshape(bt, w)
    return keys, pos


# ---------------------------------------------------------------------------
# Lifting back into the validated Schedule IR (for 0-1 checks / metrics)
# ---------------------------------------------------------------------------


def _pair_stage_to_groups(st: PairStage, L: int):
    from repro.core.networks import Group

    if st.kind == "xor":
        return tuple(
            Group(idx=(base + k, base + k + st.d))
            for base in range(0, L, 2 * st.d) for k in range(st.d))
    if st.kind == "reflect":
        return tuple(Group(idx=(i, L - 1 - i)) for i in range(L // 2))
    return tuple(Group(idx=(i, i + 1)) for i in range(1, L - 2, 2))


def program_to_schedule(mp: MergeProgram):
    """Lift a merge program into a ``core.networks.Schedule`` so the
    0-1-principle validators and depth/comparator metrics apply."""
    from repro.core.networks import Group, Schedule, Stage

    m, n, size = mp.m, mp.n, mp.total
    ident = tuple(range(size))
    name = f"{mp.family}_merge_{m}x{n}"
    meta = (("family", mp.family), ("kind", mp.kind))
    if mp.kind == "columns":
        if mp.n_cols > 1:
            from repro.core.loms import loms_2way

            return loms_2way(m, n, n_cols=mp.n_cols)
        runs = tuple(r for r in (m, n) if r > 0)
        group = Group(idx=ident, runs=runs if len(runs) > 1 else None)
        return Schedule(name=name, size=size, setup_scatter=ident,
                        output_gather=ident,
                        stages=(Stage(groups=(group,)),), meta=meta)
    setup = list(ident)
    if mp.reverse_hi:
        for j in range(n):
            setup[m + j] = m + (n - 1 - j)
    stages = tuple(
        Stage(groups=groups)
        for groups in (_pair_stage_to_groups(st, size) for st in mp.stages)
        if groups)
    return Schedule(name=name, size=size, setup_scatter=tuple(setup),
                    output_gather=ident, stages=stages, meta=meta)


def sort_program_to_schedule(prog: SortProgram):
    """Compose a sort program's levels into one merge-tree ``Schedule``.

    Only levels that are depth-1 group merges on the identity layout
    (``columns`` with ``n_cols == 1``) compose without inter-level
    permutations; programs with column-device or pair levels raise —
    validate those per-level via :func:`program_to_schedule` plus an
    executor-level exhaustive 0-1 sweep instead."""
    from repro.core.networks import Group, Schedule, Stage

    w = prog.width
    stages = []
    for mp in prog.levels:
        if mp.kind != "columns" or mp.n_cols > 1:
            raise ValueError(
                f"level {mp.m}x{mp.n} of {prog.family} is not a "
                "composable depth-1 merge")
        run = mp.m
        stages.append(Stage(groups=tuple(
            Group(idx=tuple(range(b, b + 2 * run)), runs=(run, run))
            for b in range(0, w, 2 * run))))
    ident = tuple(range(w))
    return Schedule(name=f"{prog.family}_sort_{w}", size=w,
                    setup_scatter=ident, output_gather=ident,
                    stages=tuple(stages), meta=(("family", prog.family),))
