"""Pluggable comparator-network family generators.

Each family turns a static merge shape ``(m, n)`` (or a pow2 sort width
``w``) into a :class:`repro.networks.program.MergeProgram` /
``SortProgram``. Families:

``loms``
    The paper's List Offset Merge Sorter column device. Column count
    defaults to :func:`pick_merge_cols` (the comparator-cost optimum
    ``C* = sqrt(m*n/(m+n))`` over the common divisors of ``(m, n)``).
    The sort tree keeps the ``run >= 64`` column-device cutover — below
    that the S2MS cloud is cheap enough that the stage-2 stack does not
    pay — and this generator is that heuristic's only home.

``s2ms``
    Single-stage stable 2-way rank-merge (depth 1, ``m*n`` comparators):
    the fastest and most resource-hungry point of the family, and the
    only *stable* one (lo run wins ties).

``periodic3``
    A 3-periodic merging network in the spirit of Piotrów's "Faster
    3-Periodic Merging Networks": one fixed period of three
    compare-exchange stages — reflect ``(i, L-1-i)``, even brick
    ``(0,1)(2,3)...``, odd brick ``(1,2)(3,4)...`` — applied ``t``
    times. The reflect stage performs a bitonic-style first split; the
    embedded odd-even transposition bricks guarantee termination. The
    minimal ``t`` is found at generation time by exhaustive 0-1
    merge-pattern simulation (a complete proof by the 0-1 principle),
    and grows linearly in the worst case for this simple period, so the
    family caps out at total width :data:`PERIODIC3_MAX_WIDTH`.

``bitonic``
    Batcher's bitonic merger, folding the old one-off
    ``kernels/bitonic.py`` into the family: ``[lo, reverse(hi)]`` is
    bitonic for *any* ``(m, n)`` with pow2 total, then ``log2(m+n)``
    xor-halver stages — so unlike LOMS it covers ragged pow2-total
    merges such as (3, 5).

Kernels must not import this module — go through
:mod:`repro.networks.registry` (enforced by a test).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import numpy as np

from .program import MergeProgram, PairStage, SortProgram

__all__ = [
    "divisor_cols",
    "pick_merge_cols",
    "PERIODIC3_MAX_WIDTH",
    "BUILTIN_FAMILIES",
]

#: total-width cap for the 3-periodic family: the simple reflect+brick
#: period needs O(m) periods in the worst case, so past this the network
#: is too deep to ever win a tournament (and slow to even generate).
PERIODIC3_MAX_WIDTH = 64


def divisor_cols(m: int, n: int) -> Tuple[int, ...]:
    """All feasible LOMS column counts: common divisors >= 2 of (m, n)."""
    g = math.gcd(int(m), int(n))
    return tuple(c for c in range(2, g + 1) if g % c == 0)


def pick_merge_cols(m: int, n: int) -> int:
    """Feasible LOMS column count nearest the comparator-cost optimum
    ``C* = sqrt(m*n/(m+n))`` (1 when the runs share no divisor >= 2).

    Candidates are the actual common divisors of ``(m, n)`` — not a
    hardcoded pow2 list — so non-pow2 runs (the paper's UP-7/DN-7 3-way
    example) get a real column device too."""
    cols = divisor_cols(m, n)
    if not cols:
        return 1
    c_star = (m * n / max(m + n, 1)) ** 0.5
    return min(cols, key=lambda c: abs(c - c_star))


def _is_pow2(x: int) -> bool:
    return x >= 1 and (x & (x - 1)) == 0


# ---------------------------------------------------------------------------
# loms / s2ms (column-device kinds)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _loms_merge(m: int, n: int, n_cols=None) -> MergeProgram:
    c = pick_merge_cols(m, n) if n_cols is None else int(n_cols)
    if c > 1 and (m % c or n % c):
        raise ValueError(f"n_cols={c} does not divide runs ({m}, {n})")
    return MergeProgram(family="loms", m=m, n=n, kind="columns", n_cols=c)


def _loms_merge_capable(m: int, n: int) -> bool:
    return m >= 1 and n >= 1


@functools.lru_cache(maxsize=None)
def _loms_sort(w: int) -> SortProgram:
    """LOMS merge tree with the column-device cutover: runs below 64 use
    the plain S2MS (C=1) level, wider runs take the 2-stage column
    device at the divisor-optimal count."""
    assert _is_pow2(w), w
    levels, run = [], 1
    while run < w:
        c = pick_merge_cols(run, run) if run >= 64 else 1
        levels.append(MergeProgram(family="loms", m=run, n=run,
                                   kind="columns", n_cols=c))
        run *= 2
    return SortProgram(family="loms", width=w, levels=tuple(levels))


@functools.lru_cache(maxsize=None)
def _s2ms_merge(m: int, n: int, n_cols=None) -> MergeProgram:
    return MergeProgram(family="s2ms", m=m, n=n, kind="columns", n_cols=1)


@functools.lru_cache(maxsize=None)
def _s2ms_sort(w: int) -> SortProgram:
    assert _is_pow2(w), w
    levels, run = [], 1
    while run < w:
        levels.append(MergeProgram(family="s2ms", m=run, n=run,
                                   kind="columns", n_cols=1))
        run *= 2
    return SortProgram(family="s2ms", width=w, levels=tuple(levels))


# ---------------------------------------------------------------------------
# bitonic (Batcher baseline, pairs kind)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _bitonic_merge(m: int, n: int, n_cols=None) -> MergeProgram:
    total = m + n
    if not _is_pow2(total):
        raise ValueError(f"bitonic merge needs pow2 total, got {m}+{n}")
    stages, d = [], total // 2
    while d >= 1:
        stages.append(PairStage(kind="xor", d=d))
        d //= 2
    return MergeProgram(family="bitonic", m=m, n=n, kind="pairs",
                        reverse_hi=True, stages=tuple(stages))


def _bitonic_merge_capable(m: int, n: int) -> bool:
    return m >= 1 and n >= 1 and _is_pow2(m + n)


@functools.lru_cache(maxsize=None)
def _bitonic_sort(w: int) -> SortProgram:
    assert _is_pow2(w), w
    levels, run = [], 1
    while run < w:
        levels.append(_bitonic_merge(run, run))
        run *= 2
    return SortProgram(family="bitonic", width=w, levels=tuple(levels))


# ---------------------------------------------------------------------------
# periodic3 (constant-period merging network, pairs kind)
# ---------------------------------------------------------------------------

_PERIOD = (PairStage(kind="reflect"), PairStage(kind="xor", d=1),
           PairStage(kind="brick_odd"))


def _np_period(x: np.ndarray) -> np.ndarray:
    """Numpy replica of one 3-stage period, for the minimal-t search."""
    L = x.shape[-1]
    r = x[..., ::-1]
    left = np.arange(L) < L // 2
    swap = np.where(left, x > r, r > x)
    x = np.where(swap, r, x)
    # even brick (xor d=1)
    y = x.reshape(x.shape[:-1] + (L // 2, 2))
    x = np.concatenate([y.min(-1, keepdims=True),
                        y.max(-1, keepdims=True)], -1).reshape(x.shape)
    # odd brick
    if L > 2:
        mid = x[..., 1:L - 1]
        y = mid.reshape(mid.shape[:-1] + ((L - 2) // 2, 2))
        mid = np.concatenate([y.min(-1, keepdims=True),
                              y.max(-1, keepdims=True)],
                             -1).reshape(mid.shape)
        x = np.concatenate([x[..., :1], mid, x[..., L - 1:]], -1)
    return x


@functools.lru_cache(maxsize=None)
def _periodic3_periods(m: int, n: int):
    """Minimal number of periods that merges every per-list-sorted 0-1
    pattern — exhaustive over all (m+1)(n+1) patterns, so by the 0-1
    principle the result is a proof, not a heuristic. Returns None when
    the bound is exceeded (treated as not capable)."""
    from repro.core.networks import _per_list_sorted_01_patterns

    x = _per_list_sorted_01_patterns((m, n)).astype(np.int32)
    L = m + n
    # the period embeds a full even+odd transposition pass, so L//2 + 1
    # periods always suffice (odd-even transposition sorts in L stages)
    for t in range(L // 2 + 2):
        if bool((np.diff(x, axis=-1) >= 0).all()):
            return t
        x = _np_period(x)
    return None


@functools.lru_cache(maxsize=None)
def _periodic3_merge(m: int, n: int, n_cols=None) -> MergeProgram:
    t = _periodic3_periods(m, n) if _periodic3_capable(m, n) else None
    if t is None:
        raise ValueError(f"periodic3 not capable of merge ({m}, {n})")
    return MergeProgram(family="periodic3", m=m, n=n, kind="pairs",
                        stages=_PERIOD * t)


def _periodic3_capable(m: int, n: int) -> bool:
    total = m + n
    return (m >= 1 and n >= 1 and total % 2 == 0
            and total <= PERIODIC3_MAX_WIDTH)


def _periodic3_merge_capable(m: int, n: int) -> bool:
    return _periodic3_capable(m, n) and _periodic3_periods(m, n) is not None


@functools.lru_cache(maxsize=None)
def _periodic3_sort(w: int) -> SortProgram:
    assert _is_pow2(w) and w <= PERIODIC3_MAX_WIDTH, w
    levels, run = [], 1
    while run < w:
        levels.append(_periodic3_merge(run, run))
        run *= 2
    return SortProgram(family="periodic3", width=w, levels=tuple(levels))


def _periodic3_sort_capable(w: int) -> bool:
    return w <= PERIODIC3_MAX_WIDTH


#: name -> (merge_fn(m, n, n_cols=None), sort_fn(w),
#:          merge_capable(m, n), sort_capable(w))
BUILTIN_FAMILIES = {
    "loms": (_loms_merge, _loms_sort, _loms_merge_capable, lambda w: True),
    "s2ms": (_s2ms_merge, _s2ms_sort, _loms_merge_capable, lambda w: True),
    "periodic3": (_periodic3_merge, _periodic3_sort,
                  _periodic3_merge_capable, _periodic3_sort_capable),
    "bitonic": (_bitonic_merge, _bitonic_sort, _bitonic_merge_capable,
                lambda w: True),
}
