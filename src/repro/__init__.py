"""repro — List Offset Merge Sorters (LOMS/S2MS) reproduction in JAX/Pallas.

The top level re-exports the unified sort API (see ``repro.api``): one
namespace, planner-driven backend selection, pytree payloads.

    import repro
    vals, idx = repro.topk(logits, 64)          # auto-routed
    merged = repro.merge(a, b, axis=0)          # any axis
    x, tree = repro.sort(x, stable=True, payload={"emb": emb})

Subsystems: ``repro.core`` (schedules + executor), ``repro.kernels``
(Pallas TPU sorters), ``repro.streaming`` (chunked pipelines, planner,
device-tree top-k), ``repro.models`` / ``repro.serving`` (the LLM stack
consuming them), ``repro.obs`` (span tracing + metrics + timing export,
inert unless ``REPRO_OBS`` is set; DESIGN.md §13), ``repro.resilience``
(fault injection + degradation ladder + circuit breakers, DESIGN.md §16).
"""
from repro import obs  # noqa: F401
from repro import resilience  # noqa: F401
from repro.api import (  # noqa: F401
    Backend,
    Decision,
    SortSpec,
    backend_names,
    decision_table,
    get_backend,
    median_of_lists,
    merge,
    merge_k,
    plan,
    register_backend,
    segment_argmax,
    segment_merge,
    segment_sort,
    segment_topk,
    sort,
    topk,
)

__all__ = [
    "Backend",
    "Decision",
    "SortSpec",
    "backend_names",
    "decision_table",
    "get_backend",
    "median_of_lists",
    "merge",
    "merge_k",
    "obs",
    "plan",
    "register_backend",
    "resilience",
    "segment_argmax",
    "segment_merge",
    "segment_sort",
    "segment_topk",
    "sort",
    "topk",
]
