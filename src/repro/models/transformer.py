"""Block assembly and scan-over-layers stacks for all 10 architectures.

Every stack is a ``jax.lax.scan`` over stacked layer params so the HLO (and
compile time at 512 devices) is O(1) in depth. Heterogeneous pieces —
DeepSeek's first dense layer, zamba2's shared attention block — sit outside
the scan or as closures over shared weights.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .attention import attn_apply, attn_cache_init, attn_init, mla_apply, mla_cache_init, mla_init
from .layers import mlp_apply, mlp_init, rmsnorm_apply, rmsnorm_init
from .moe import moe_apply, moe_init
from .ssm import ssm_apply, ssm_cache_init, ssm_init

Params = dict


def _stack_params(per_layer):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig, *, ffn: str):
    """ffn: 'dense' | 'moe' | 'none' (ssm block)."""
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    if cfg.family == "ssm" or ffn == "ssm":
        p["norm"], s["norm"] = rmsnorm_init(cfg.d_model)
        p["mixer"], s["mixer"] = ssm_init(ks[0], cfg)
        return p, s
    p["ln1"], s["ln1"] = rmsnorm_init(cfg.d_model)
    if cfg.mla is not None:
        p["attn"], s["attn"] = mla_init(ks[0], cfg)
    else:
        p["attn"], s["attn"] = attn_init(ks[0], cfg)
    p["ln2"], s["ln2"] = rmsnorm_init(cfg.d_model)
    if ffn == "moe":
        p["ffn"], s["ffn"] = moe_init(ks[1], cfg)
    else:
        p["ffn"], s["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act)
    return p, s


def block_apply(p, x, cfg: ModelConfig, *, ffn: str, mode: str, cache=None,
                positions=None, par=None):
    if par is not None and x.ndim == 3:
        x = par.constrain(x, par.dp_for(x.shape[0]), None, None)
    if cfg.family == "ssm" or ffn == "ssm":
        h = rmsnorm_apply(p["norm"], x, cfg.norm_eps)
        y, cache = ssm_apply(p["mixer"], h, cfg, cache=cache, mode=mode, par=par)
        return x + y, cache
    h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a, cache = mla_apply(p["attn"], h, cfg, cache=cache, mode=mode,
                             positions=positions, par=par)
    else:
        a, cache = attn_apply(p["attn"], h, cfg, cache=cache, mode=mode,
                              positions=positions, par=par)
    x = x + a
    h = rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
    if ffn == "moe":
        y = moe_apply(p["ffn"], h, cfg, par=par)
    else:
        y = mlp_apply(p["ffn"], h, cfg.mlp_act)
    return x + y, cache


def block_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype, *, ffn: str):
    if cfg.family == "ssm" or ffn == "ssm":
        return ssm_cache_init(cfg, batch, dtype)
    if cfg.mla is not None:
        return mla_cache_init(cfg, batch, max_len, dtype)
    return attn_cache_init(cfg, batch, max_len, dtype)


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------


def _layer_ffn_kind(cfg: ModelConfig, layer: int) -> str:
    if cfg.family == "ssm":
        return "ssm"
    if cfg.moe is not None and layer >= cfg.moe.first_dense_layers:
        return "moe"
    return "dense"


def stack_init(key, cfg: ModelConfig):
    """Returns (params, specs). Layout:
      head: list of unscanned leading blocks (e.g. DeepSeek dense layer 0)
      body: scanned stacked params ('layers' leading axis)
      shared: zamba2 shared attention block (hybrid only)
    """
    p, s = {}, {}
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        keys = jax.random.split(key, cfg.n_layers + 1)
        per = [block_init(keys[i], cfg, ffn="ssm") for i in range(cfg.n_layers)]
        bp, bs = zip(*per)
        p["body"], s["body"] = _stack_params(bp), bs[0]
        p["shared"], s["shared"] = block_init(keys[-1], cfg, ffn="dense")
        del n_groups
        return p, s
    n_head = cfg.moe.first_dense_layers if cfg.moe is not None else 0
    keys = jax.random.split(key, cfg.n_layers)
    head = [block_init(keys[i], cfg, ffn="dense") for i in range(n_head)]
    body = [
        block_init(keys[i], cfg, ffn=_layer_ffn_kind(cfg, i))
        for i in range(n_head, cfg.n_layers)
    ]
    if head:
        hp, hs = zip(*head)
        p["head"], s["head"] = list(hp), list(hs)
    bp, bs = zip(*body)
    p["body"], s["body"] = _stack_params(bp), bs[0]
    return p, s


def stack_apply(
    p: Params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    mode: str = "train",
    caches: Optional[dict] = None,
    positions=None,
    par=None,
    remat: str = "none",  # none | full | dots
):
    """Run the whole stack. ``caches`` mirrors the param layout:
    {'head': [cache...], 'body': stacked cache, 'shared': stacked cache}."""

    def wrap(fn):
        if remat == "full":
            return jax.checkpoint(fn)
        if remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        return fn

    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        body = jax.tree.map(
            lambda a: a.reshape((n_groups, cfg.attn_every) + a.shape[1:]),
            {k: v for k, v in p["body"].items()})
        shared = p["shared"]

        if caches is not None:
            def group_fn(x, inp):
                bp, bc, sc = inp  # group params, group caches, shared cache

                def layer_fn(x, inp2):
                    lp, lc = inp2
                    x, lc = wrap(functools.partial(
                        block_apply, cfg=cfg, ffn="ssm", mode=mode,
                        positions=positions, par=par))(lp, x, cache=lc)
                    return x, lc

                if cfg.unroll_layers:
                    lcs = []
                    for j in range(cfg.attn_every):
                        x, lcj = layer_fn(x, (jax.tree.map(lambda a, j=j: a[j], bp),
                                              jax.tree.map(lambda a, j=j: a[j], bc)))
                        lcs.append(lcj)
                    bc = jax.tree.map(lambda *xs: jnp.stack(xs), *lcs)
                else:
                    x, bc = jax.lax.scan(layer_fn, x, (bp, bc))
                x, sc = wrap(functools.partial(
                    block_apply, cfg=cfg, ffn="dense", mode=mode,
                    positions=positions, par=par))(shared, x, cache=sc)
                return x, (bc, sc)

            bcaches = jax.tree.map(
                lambda a: a.reshape((n_groups, cfg.attn_every) + a.shape[1:]),
                caches["body"])
            if cfg.unroll_layers:
                bcs, scs = [], []
                for i in range(n_groups):
                    gi = lambda a: a[i]
                    x, (bci, sci) = group_fn(x, (
                        jax.tree.map(gi, body), jax.tree.map(gi, bcaches),
                        jax.tree.map(gi, caches["shared"])))
                    bcs.append(bci); scs.append(sci)
                bc = jax.tree.map(lambda *xs: jnp.stack(xs), *bcs)
                sc = jax.tree.map(lambda *xs: jnp.stack(xs), *scs)
            else:
                x, (bc, sc) = jax.lax.scan(group_fn, x,
                                           (body, bcaches, caches["shared"]))
            bc = jax.tree.map(
                lambda a: a.reshape((n_groups * cfg.attn_every,) + a.shape[2:]), bc)
            return x, {"body": bc, "shared": sc}

        def group_fn_nc(x, bp):
            def layer_fn(x, lp):
                x, _ = wrap(functools.partial(
                    block_apply, cfg=cfg, ffn="ssm", mode=mode,
                    positions=positions, par=par))(lp, x, cache=None)
                return x, None

            if cfg.unroll_layers:
                for j in range(cfg.attn_every):
                    x, _ = layer_fn(x, jax.tree.map(lambda a, j=j: a[j], bp))
            else:
                x, _ = jax.lax.scan(layer_fn, x, bp)
            x, _ = wrap(functools.partial(
                block_apply, cfg=cfg, ffn="dense", mode=mode,
                positions=positions, par=par))(shared, x, cache=None)
            return x, None

        if cfg.unroll_layers:
            for i in range(n_groups):
                x, _ = group_fn_nc(x, jax.tree.map(lambda a, i=i: a[i], body))
            return x, None
        x, _ = jax.lax.scan(group_fn_nc, x, body)
        return x, None

    # homogeneous (dense / moe / ssm / encoder) stacks
    n_head = len(p.get("head", []))
    new_head_caches = []
    for i in range(n_head):
        c = caches["head"][i] if caches else None
        x, c = wrap(functools.partial(
            block_apply, cfg=cfg, ffn="dense", mode=mode,
            positions=positions, par=par))(p["head"][i], x, cache=c)
        new_head_caches.append(c)

    ffn_kind = _layer_ffn_kind(cfg, n_head)

    n_body = jax.tree.leaves(p["body"])[0].shape[0]

    if caches is not None:
        def layer_fn(x, inp):
            lp, lc = inp
            x, lc = wrap(functools.partial(
                block_apply, cfg=cfg, ffn=ffn_kind, mode=mode,
                positions=positions, par=par))(lp, x, cache=lc)
            return x, lc

        if cfg.unroll_layers:
            ncs = []
            for i in range(n_body):
                lp = jax.tree.map(lambda a, i=i: a[i], p["body"])
                lc = jax.tree.map(lambda a, i=i: a[i], caches["body"])
                x, lc = layer_fn(x, (lp, lc))
                ncs.append(lc)
            bc = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
        else:
            x, bc = jax.lax.scan(layer_fn, x, (p["body"], caches["body"]))
        out_caches = {"body": bc}
        if n_head:
            out_caches["head"] = new_head_caches
        return x, out_caches

    def layer_fn_nc(x, lp):
        x, _ = wrap(functools.partial(
            block_apply, cfg=cfg, ffn=ffn_kind, mode=mode,
            positions=positions, par=par))(lp, x, cache=None)
        return x, None

    if cfg.unroll_layers:
        for i in range(n_body):
            x, _ = layer_fn_nc(x, jax.tree.map(lambda a, i=i: a[i], p["body"]))
        return x, None
    x, _ = jax.lax.scan(layer_fn_nc, x, p["body"])
    return x, None


def stack_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype):
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        body_one = block_cache_init(cfg, batch, max_len, dtype, ffn="ssm")
        body = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), body_one)
        sh_one = block_cache_init(cfg, batch, max_len, dtype, ffn="dense")
        shared = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), sh_one)
        return {"body": body, "shared": shared}
    n_head = cfg.moe.first_dense_layers if cfg.moe is not None else 0
    out = {}
    if n_head:
        out["head"] = [
            block_cache_init(cfg, batch, max_len, dtype, ffn="dense")
            for _ in range(n_head)
        ]
    ffn_kind = _layer_ffn_kind(cfg, n_head)
    body_one = block_cache_init(cfg, batch, max_len, dtype, ffn=ffn_kind)
    n_body = cfg.n_layers - n_head
    out["body"] = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_body,) + a.shape), body_one)
    return out
