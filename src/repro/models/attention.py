"""Attention: GQA/MHA (+qk_norm, qkv bias, partial RoPE), MLA, KV caches.

Memory discipline: prefill/train attention is computed with a double
chunked scan (flash-style running-softmax over KV chunks) so the S x S
score matrix is never materialized — required for the 32k-prefill dry-run
shapes. Decode attends one query against the cache with fp32 softmax; with
the cache sequence dimension sharded over 'model', the reductions lower to
partial-softmax + small all-reduces (flash-decode; see parallel/sharding).

MLA (DeepSeek-V2) caches only the compressed latent (kv_lora + rope dims)
and uses the absorbed-matmul form at decode, so its 32k cache is ~9x
smaller than GQA's at kv=16.
"""
from __future__ import annotations

from typing import Optional, Tuple

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from .layers import apply_rope, dense_apply, dense_init, head_rmsnorm_init, rmsnorm_apply

Params = dict


# ---------------------------------------------------------------------------
# chunked (flash-style) attention
# ---------------------------------------------------------------------------


def _fit_chunk(size, want):  # largest divisor of size that is <= want
    c = min(want, size)
    while size % c:
        c -= 1
    return c


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal: bool, q_offset: int, chunk: int, scale: float,
           unroll: bool = False):
    out, _ = _flash_fwd_impl(q, k, v, causal, q_offset, chunk, scale, unroll)
    return out


def _flash_fwd_impl(q, k, v, causal, q_offset, chunk, scale, unroll=False):
    b, sq, hkv, g, d = q.shape
    sk, dv = k.shape[1], v.shape[-1]
    cq, ck = _fit_chunk(sq, chunk), _fit_chunk(sk, chunk)
    nq, nk = sq // cq, sk // ck
    qc = jnp.moveaxis(q.reshape(b, nq, cq, hkv, g, d), 1, 0)
    kc = jnp.moveaxis(k.reshape(b, nk, ck, hkv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nk, ck, hkv, dv), 1, 0)

    def q_step(_, iq_and_q):
        iq, qi = iq_and_q  # qi: (b, cq, hkv, g, d)
        qpos = q_offset + iq * cq + jnp.arange(cq)

        def kv_step(carry, ik_and_kv):
            m, l, acc = carry
            ik, ki, vi = ik_and_kv
            s = jnp.einsum(
                "bqhgd,bkhd->bqhgk", qi, ki, preferred_element_type=jnp.float32
            ) * scale
            if causal:
                kpos = ik * ck + jnp.arange(ck)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, :, None, None, :], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p.astype(vi.dtype), vi,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, cq, hkv, g), -1e30, jnp.float32)
        l0 = jnp.zeros((b, cq, hkv, g), jnp.float32)
        a0 = jnp.zeros((b, cq, hkv, g, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (jnp.arange(nk), kc, vc), unroll=unroll)
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))  # (b, cq, hkv, g)
        return None, (out, lse)

    _, (oc, lsec) = jax.lax.scan(q_step, None, (jnp.arange(nq), qc),
                                 unroll=unroll)
    out = jnp.moveaxis(oc, 0, 1).reshape(b, sq, hkv, g, dv)
    lse = jnp.moveaxis(lsec, 0, 1).reshape(b, sq, hkv, g)
    return out, lse


def _flash_fwd(q, k, v, causal, q_offset, chunk, scale, unroll=False):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_offset, chunk, scale, unroll)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_offset, chunk, scale, unroll, res, dout):
    """Flash backward: recompute per-chunk probabilities from (q, k, lse)
    instead of saving the S x S matrices — O(S) memory, the standard
    flash-attention gradient."""
    q, k, v, out, lse = res
    b, sq, hkv, g, d = q.shape
    sk, dv = k.shape[1], v.shape[-1]
    cq, ck = _fit_chunk(sq, chunk), _fit_chunk(sk, chunk)
    nq, nk = sq // cq, sk // ck
    f32 = jnp.float32
    delta = (dout.astype(f32) * out.astype(f32)).sum(-1)  # (b,sq,hkv,g)

    qc = jnp.moveaxis(q.reshape(b, nq, cq, hkv, g, d), 1, 0)
    doc = jnp.moveaxis(dout.reshape(b, nq, cq, hkv, g, dv), 1, 0)
    lc = jnp.moveaxis(lse.reshape(b, nq, cq, hkv, g), 1, 0)
    dc = jnp.moveaxis(delta.reshape(b, nq, cq, hkv, g), 1, 0)
    kc = jnp.moveaxis(k.reshape(b, nk, ck, hkv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nk, ck, hkv, dv), 1, 0)

    def q_step(carry, inp):
        dk_all, dv_all = carry  # (nk, b, ck, hkv, d/dv) f32
        iq, qi, doi, lsei, di = inp
        qpos = q_offset + iq * cq + jnp.arange(cq)

        def kv_step(carry2, inp2):
            dqi, dk_a, dv_a = carry2
            ik, ki, vi = inp2
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qi, ki,
                           preferred_element_type=f32) * scale
            if causal:
                kpos = ik * ck + jnp.arange(ck)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, :, None, None, :], s, -1e30)
            p = jnp.exp(s - lsei[..., None])  # (b,cq,hkv,g,ck)
            dvk = jnp.einsum("bqhgk,bqhgv->bkhv", p, doi.astype(f32))
            dp = jnp.einsum("bqhgv,bkhv->bqhgk", doi.astype(f32), vi.astype(f32))
            ds = p * (dp - di[..., None]) * scale
            dqi = dqi + jnp.einsum("bqhgk,bkhd->bqhgd", ds, ki.astype(f32))
            dkk = jnp.einsum("bqhgk,bqhgd->bkhd", ds, qi.astype(f32))
            dk_a = jax.lax.dynamic_update_index_in_dim(
                dk_a, dk_a[ik] + dkk, ik, 0)
            dv_a = jax.lax.dynamic_update_index_in_dim(
                dv_a, dv_a[ik] + dvk, ik, 0)
            return (dqi, dk_a, dv_a), None

        dq0 = jnp.zeros((b, cq, hkv, g, d), f32)
        (dqi, dk_all, dv_all), _ = jax.lax.scan(
            kv_step, (dq0, dk_all, dv_all), (jnp.arange(nk), kc, vc),
            unroll=unroll)
        return (dk_all, dv_all), dqi

    dk0 = jnp.zeros((nk, b, ck, hkv, d), f32)
    dv0 = jnp.zeros((nk, b, ck, hkv, dv), f32)
    (dkc, dvc), dqc = jax.lax.scan(q_step, (dk0, dv0),
                                   (jnp.arange(nq), qc, doc, lc, dc),
                                   unroll=unroll)
    dq = jnp.moveaxis(dqc, 0, 1).reshape(b, sq, hkv, g, d).astype(q.dtype)
    dk = jnp.moveaxis(dkc, 0, 1).reshape(b, sk, hkv, d).astype(k.dtype)
    dv = jnp.moveaxis(dvc, 0, 1).reshape(b, sk, hkv, dv).astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jnp.ndarray,  # (B, Sq, Hkv, G, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, Dv)
    *,
    causal: bool,
    q_offset: int = 0,
    chunk: int = 1024,
    scale: Optional[float] = None,
    unroll: bool = False,
) -> jnp.ndarray:
    """Chunked attention with a flash custom VJP (never materializes SxS)."""
    d = q.shape[-1]
    scale = scale if scale is not None else float(1.0 / np.sqrt(d))
    return _flash(q, k, v, bool(causal), int(q_offset), int(chunk), float(scale),
                  bool(unroll))


def decode_attention(
    q: jnp.ndarray,  # (B, Hkv, G, D) single query
    k_cache: jnp.ndarray,  # (B, Hkv, D, S)  — contraction-friendly layout
    v_cache: jnp.ndarray,  # (B, Hkv, S, Dv)
    valid_len: jnp.ndarray,  # () or (B,) number of valid cache slots
    scale: Optional[float] = None,
    par=None,
) -> jnp.ndarray:
    d = q.shape[-1]
    s = k_cache.shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    # layouts are chosen so both dots are transpose-free: contracting d
    # (sharded over TP) yields partial logits + one small psum; the cache
    # is never copied (observed 2.5x cache-size temp with (B,S,H,D))
    # NOTE: no preferred_element_type here — it would materialize an f32
    # copy of the whole cache (2x cache bytes); logits are upcast instead.
    # fp8 caches are read through an explicit convert (fused on TPU).
    if k_cache.dtype != q.dtype:
        k_cache = k_cache.astype(q.dtype)
    if v_cache.dtype != q.dtype:
        v_cache = v_cache.astype(q.dtype)
    pos = jnp.arange(s)
    if par is None:
        # per-row body via lax.map: the body is compiled once with
        # batch-free shapes, so a request's attention bits are invariant
        # to the decode batch it rides in. The serving scheduler's
        # bit-equality oracle (a request alone through generate() vs the
        # same request in a continuous batch) depends on this — the
        # batched einsum lets XLA pick batch-size-dependent reduction
        # tilings that perturb last-bit results.
        valid = jnp.broadcast_to(jnp.reshape(valid_len, (-1,)), (q.shape[0],))

        def row(args):
            qr, kr, vr, vlr = args  # (hkv,g,d) (hkv,d,S) (hkv,S,dv) ()
            lg = jnp.einsum("hgd,hds->hgs", qr, kr).astype(jnp.float32) * scale
            lg = jnp.where((pos < vlr)[None, None, :], lg, -1e30)
            w = jax.nn.softmax(lg, axis=-1)
            return jnp.einsum("hgs,hsv->hgv", w.astype(vr.dtype), vr)

        out = jax.lax.map(row, (q, k_cache, v_cache, valid))
        return out.astype(q.dtype)
    logits = jnp.einsum("bhgd,bhds->bhgs", q, k_cache).astype(jnp.float32) * scale
    mask = pos[None, :] < jnp.reshape(valid_len, (-1, 1))
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bhsv->bhgv", w.astype(v_cache.dtype), v_cache)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention module
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ModelConfig):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["wq"], s["wq"] = dense_init(ks[0], d, (h, hd), ("embed", "heads", "head_dim"),
                                  bias=cfg.qkv_bias)
    p["wk"], s["wk"] = dense_init(ks[1], d, (hkv, hd), ("embed", "kv_heads", "head_dim"),
                                  bias=cfg.qkv_bias)
    p["wv"], s["wv"] = dense_init(ks[2], d, (hkv, hd), ("embed", "kv_heads", "head_dim"),
                                  bias=cfg.qkv_bias)
    p["wo"], s["wo"] = dense_init(ks[3], h * hd, d, ("heads_flat", "embed"))
    if cfg.qk_norm:
        p["qn"], s["qn"] = head_rmsnorm_init(hd)
        p["kn"], s["kn"] = head_rmsnorm_init(hd)
    return p, s


def _qk_norm(p, q, k, cfg):
    if not cfg.qk_norm:
        return q, k
    qn = {"scale": p["qn"]["scale"]}
    kn = {"scale": p["kn"]["scale"]}
    q = rmsnorm_apply({"scale": qn["scale"]}, q, cfg.norm_eps)
    k = rmsnorm_apply({"scale": kn["scale"]}, k, cfg.norm_eps)
    return q, k


def attn_apply(
    p: Params,
    x: jnp.ndarray,  # (B, S, D)
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[dict] = None,
    mode: str = "train",  # train | prefill | decode
    par=None,
):
    b, sq, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // hkv
    q = dense_apply(p["wq"], x, "btd,dhq->bthq")
    k = dense_apply(p["wk"], x, "btd,dhq->bthq")
    v = dense_apply(p["wv"], x, "btd,dhq->bthq")
    q, k = _qk_norm(p, q, k, cfg)
    if positions is None:
        positions = jnp.arange(sq)[None, :]
    q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)

    new_cache = cache
    if mode == "decode":
        assert sq == 1 and cache is not None
        idx = cache["pos"]  # int32 slot to write: scalar, or (B,) per-row
        k_t = jnp.moveaxis(k, 1, -1).astype(cache["k"].dtype)  # (b,hkv,d,1)
        v_t = jnp.moveaxis(v, 1, 2).astype(cache["v"].dtype)  # (b,hkv,1,dv)
        if jnp.ndim(idx) == 0:
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_t, idx, 3)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_t, idx, 2)
        else:
            # per-row write positions (paged slot views: every request sits
            # at its own depth); scatter one column per batch row
            rows = jnp.arange(b)
            k_cache = cache["k"].at[rows, :, :, idx].set(k_t[..., 0])
            v_cache = cache["v"].at[rows, :, idx, :].set(v_t[:, :, 0, :])
        new_cache = {"k": k_cache, "v": v_cache, "pos": idx + 1}
        qh = q[:, 0].reshape(b, hkv, g, hd)
        out = decode_attention(qh, k_cache, v_cache, valid_len=idx + 1, par=par)
        out = out.reshape(b, 1, h * hd)
    else:
        if mode == "prefill" and cache is not None:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], jnp.moveaxis(k, 1, -1).astype(cache["k"].dtype), 0, 3)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], jnp.moveaxis(v, 1, 2).astype(cache["v"].dtype), 0, 2)
            new_cache = {"k": k_cache, "v": v_cache, "pos": jnp.int32(sq)}
        hkv_eff, g_eff = hkv, g
        if (par is not None and not par.tp_for(hkv) and not par.tp_for(g)
                and par.tp_for(h) and g > 1):
            # GQA-TP repair (§Perf iter 1): neither kv-heads (8) nor groups
            # (6) divide the 16-way TP axis, but FLAT heads (48) do. Repeat
            # kv to full heads so attention shards head-wise instead of
            # falling back to sequence-sharded q + replicated kv, which
            # cost 7.5 TB/device/step of all-gathers on internvl2.
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
            hkv_eff, g_eff = h, 1
        qg = q.reshape(b, sq, hkv_eff, g_eff, hd)
        if par is not None:
            # anchor activation shardings (DESIGN.md §6): prefer kv-head TP,
            # then q-group TP, else sequence-parallel q with replicated kv
            dp = par.dp_for(b)
            if par.tp_for(hkv_eff):
                qg = par.constrain(qg, dp, None, par.tp_axis, None, None)
                k = par.constrain(k, dp, None, par.tp_axis, None)
                v = par.constrain(v, dp, None, par.tp_axis, None)
            elif par.tp_for(g_eff):
                qg = par.constrain(qg, dp, None, None, par.tp_axis, None)
                k = par.constrain(k, dp, None, None, None)
                v = par.constrain(v, dp, None, None, None)
            else:
                qg = par.constrain(qg, dp, par.tp_axis, None, None, None)
                k = par.constrain(k, dp, None, None, None)
                v = par.constrain(v, dp, None, None, None)
        out = flash_attention(qg, k, v, causal=cfg.causal, chunk=cfg.attn_chunk,
                              unroll=cfg.unroll_layers)
        out = out.reshape(b, sq, h * hd)
    out = dense_apply(p["wo"], out, "btf,fd->btd")
    return out, new_cache


def attn_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, hkv, hd, max_len), dtype),
        "v": jnp.zeros((batch, hkv, max_len, hd), dtype),
        "pos": jnp.int32(0),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 Multi-head Latent Attention)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    p["wq"], s["wq"] = dense_init(ks[0], d, (h, qd), ("embed", "heads", "head_dim"))
    p["wdkv"], s["wdkv"] = dense_init(ks[1], d, m.kv_lora_rank + m.qk_rope_head_dim,
                                      ("embed", "kv_lora"))
    p["kv_norm"] = {"scale": jnp.ones((m.kv_lora_rank,), jnp.float32)}
    s["kv_norm"] = {"scale": ("kv_lora",)}
    p["wuk"], s["wuk"] = dense_init(ks[2], m.kv_lora_rank, (h, m.qk_nope_head_dim),
                                    ("kv_lora", "heads", "head_dim"))
    p["wuv"], s["wuv"] = dense_init(ks[3], m.kv_lora_rank, (h, m.v_head_dim),
                                    ("kv_lora", "heads", "head_dim"))
    p["wo"], s["wo"] = dense_init(ks[4], h * m.v_head_dim, d, ("heads_flat", "embed"))
    return p, s


def _mla_qkv(p, x, cfg, positions):
    m = cfg.mla
    q = dense_apply(p["wq"], x, "btd,dhq->bthq")
    q_nope, q_pe = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_pe = apply_rope(q_pe, positions, 1.0, cfg.rope_theta)
    dkv = dense_apply(p["wdkv"], x, "btd,dl->btl")
    ckv, k_pe = dkv[..., : m.kv_lora_rank], dkv[..., m.kv_lora_rank :]
    ckv = rmsnorm_apply(p["kv_norm"], ckv, cfg.norm_eps)
    k_pe = apply_rope(k_pe[:, :, None, :], positions, 1.0, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_pe, ckv, k_pe


def mla_apply(
    p: Params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[dict] = None,
    mode: str = "train",
    par=None,
):
    m = cfg.mla
    b, sq, _ = x.shape
    h = cfg.n_heads
    if positions is None:
        positions = jnp.arange(sq)[None, :]
    q_nope, q_pe, ckv, k_pe = _mla_qkv(p, x, cfg, positions)
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)

    new_cache = cache
    if mode == "decode":
        assert sq == 1 and cache is not None
        idx = cache["pos"]
        ckv_t = jnp.moveaxis(ckv, 1, -1).astype(cache["ckv"].dtype)  # (b,l,1)
        kpe_t = jnp.moveaxis(k_pe, 1, -1).astype(cache["kpe"].dtype)
        if jnp.ndim(idx) == 0:
            ckv_c = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_t, idx, 2)
            kpe_c = jax.lax.dynamic_update_slice_in_dim(cache["kpe"], kpe_t, idx, 2)
        else:
            rows = jnp.arange(b)
            ckv_c = cache["ckv"].at[rows, :, idx].set(ckv_t[..., 0])
            kpe_c = cache["kpe"].at[rows, :, idx].set(kpe_t[..., 0])
        new_cache = {"ckv": ckv_c, "kpe": kpe_c, "pos": idx + 1}
        # absorbed form: score = (q_nope W_uk) . ckv + q_pe . k_pe
        q_lat = jnp.einsum("bhq,lhq->bhl", q_nope[:, 0], p["wuk"]["w"].astype(x.dtype))
        ckv_r = ckv_c.astype(x.dtype) if ckv_c.dtype != x.dtype else ckv_c
        kpe_r = kpe_c.astype(x.dtype) if kpe_c.dtype != x.dtype else kpe_c
        s_lat = jnp.einsum("bhl,bls->bhs", q_lat, ckv_r).astype(jnp.float32)
        s_pe = jnp.einsum("bhr,brs->bhs", q_pe[:, 0], kpe_r).astype(jnp.float32)
        logits = (s_lat + s_pe) * scale
        pos_ids = jnp.arange(ckv_c.shape[-1])
        mask = pos_ids[None, :] < jnp.reshape(idx + 1, (-1, 1))
        logits = jnp.where(mask[:, None, :], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        ctx_lat = jnp.einsum("bhs,bls->bhl", w.astype(ckv_r.dtype),
                             ckv_r).astype(x.dtype)
        ctx = jnp.einsum("bhl,lhv->bhv", ctx_lat, p["wuv"]["w"].astype(x.dtype))
        out = ctx.reshape(b, 1, h * m.v_head_dim)
    else:
        if mode == "prefill" and cache is not None:
            ckv_c = jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], jnp.moveaxis(ckv, 1, -1).astype(cache["ckv"].dtype), 0, 2)
            kpe_c = jax.lax.dynamic_update_slice_in_dim(
                cache["kpe"], jnp.moveaxis(k_pe, 1, -1).astype(cache["kpe"].dtype), 0, 2)
            new_cache = {"ckv": ckv_c, "kpe": kpe_c, "pos": jnp.int32(sq)}
        if par is not None and par.tp_for(h):
            dp = par.dp_for(b)
            q_nope = par.constrain(q_nope, dp, None, par.tp_axis, None)
            q_pe = par.constrain(q_pe, dp, None, par.tp_axis, None)
        k_nope = jnp.einsum("btl,lhq->bthq", ckv, p["wuk"]["w"].astype(x.dtype))
        v = jnp.einsum("btl,lhv->bthv", ckv, p["wuv"]["w"].astype(x.dtype))
        if par is not None and par.tp_for(h):
            k_nope = par.constrain(k_nope, par.dp_for(b), None, par.tp_axis, None)
            v = par.constrain(v, par.dp_for(b), None, par.tp_axis, None)
        k_pe_b = jnp.broadcast_to(k_pe[:, :, None, :], (b, sq, h, m.qk_rope_head_dim))
        k = jnp.concatenate([k_nope, k_pe_b], axis=-1)
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        qg = q[:, :, :, None, :]  # MHA: hkv = h, g = 1
        out = flash_attention(qg, k, v, causal=cfg.causal, chunk=cfg.attn_chunk,
                              scale=scale, unroll=cfg.unroll_layers)
        out = out.reshape(b, sq, h * m.v_head_dim)
    out = dense_apply(p["wo"], out, "btf,fd->btd")
    return out, new_cache


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, m.kv_lora_rank, max_len), dtype),
        "kpe": jnp.zeros((batch, m.qk_rope_head_dim, max_len), dtype),
        "pos": jnp.int32(0),
    }
